"""L2 correctness: model entrypoints vs jax.grad and end-to-end GD descent."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import gradient as K


def make_problem(m, d, seed=0, noise=0.0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (m, d), jnp.float32)
    beta_star = jax.random.normal(k2, (d,), jnp.float32)
    y = x @ beta_star + noise * jax.random.normal(k3, (m,), jnp.float32)
    return x, y, beta_star


def mean_loss(beta, x, y):
    r = x @ beta - y
    return 0.5 * jnp.mean(r * r) * 1.0  # scalar


def test_partial_grad_equals_autodiff():
    x, y, _ = make_problem(200, 32, seed=1, noise=0.3)
    beta = jnp.zeros((32,), jnp.float32)
    (g,) = model.partial_grad(beta, x, y)
    # autodiff of the mean loss: note model normalizes by m, and
    # d/dbeta [0.5/m ||r||^2] = X^T r / m
    g_auto = jax.grad(lambda b: 0.5 / x.shape[0] * jnp.sum((x @ b - y) ** 2))(beta)
    np.testing.assert_allclose(g, g_auto, rtol=2e-4, atol=2e-4)


def test_partial_grad_loss_consistency():
    x, y, _ = make_problem(128, 16, seed=2, noise=0.1)
    beta = jnp.ones((16,), jnp.float32) * 0.1
    g, loss = model.partial_grad_loss(beta, x, y)
    (g_only,) = model.partial_grad(beta, x, y)
    np.testing.assert_allclose(g, g_only, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(loss[0], mean_loss(beta, x, y), rtol=2e-4, atol=2e-4)


def test_sgd_update():
    beta = jnp.arange(8, dtype=jnp.float32)
    g = jnp.ones((8,), jnp.float32)
    (b2,) = model.sgd_update(beta, g, jnp.asarray(0.5, jnp.float32))
    np.testing.assert_allclose(b2, beta - 0.5)


def test_full_step_equals_partial_plus_update():
    x, y, _ = make_problem(96, 12, seed=3, noise=0.05)
    beta = jnp.zeros((12,), jnp.float32)
    lr = jnp.asarray(0.01, jnp.float32)
    b_full, loss_full = model.full_step(beta, x, y, lr)
    g, loss = model.partial_grad_loss(beta, x, y)
    (b_two,) = model.sgd_update(beta, g, lr)
    np.testing.assert_allclose(b_full, b_two, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(loss_full, loss, rtol=1e-5, atol=1e-5)


def test_gd_converges_to_ground_truth():
    """A few hundred full steps on noiseless data recover beta*."""
    x, y, beta_star = make_problem(256, 8, seed=4, noise=0.0)
    beta = jnp.zeros((8,), jnp.float32)
    lr = jnp.asarray(0.05, jnp.float32)
    losses = []
    for _ in range(300):
        beta, loss = model.full_step(beta, x, y, lr)
        losses.append(float(loss[0]))
    assert losses[-1] < 1e-6
    assert losses[-1] < losses[0] * 1e-4
    np.testing.assert_allclose(beta, beta_star, rtol=1e-2, atol=1e-2)


def test_loss_curve_monotone_under_small_lr():
    x, y, _ = make_problem(128, 6, seed=5, noise=0.2)
    beta = jnp.zeros((6,), jnp.float32)
    lr = jnp.asarray(0.01, jnp.float32)
    prev = float("inf")
    for _ in range(50):
        beta, loss = model.full_step(beta, x, y, lr)
        assert float(loss[0]) <= prev + 1e-6
        prev = float(loss[0])


@settings(max_examples=10, deadline=None)
@given(m=st.integers(8, 128), d=st.integers(1, 24), seed=st.integers(0, 999))
def test_aggregated_shards_equal_global_gradient(m, d, seed):
    """Master-side invariant: the mean of per-shard mean-gradients over
    equal shards equals the global mean gradient (what replication must
    preserve regardless of which replica answers)."""
    x, y, _ = make_problem(2 * m, d, seed=seed, noise=0.5)
    beta = jax.random.normal(jax.random.PRNGKey(seed + 1), (d,), jnp.float32)
    (g_all,) = model.partial_grad(beta, x, y)
    (g1,) = model.partial_grad(beta, x[:m], y[:m])
    (g2,) = model.partial_grad(beta, x[m:], y[m:])
    np.testing.assert_allclose((g1 + g2) / 2, g_all, rtol=5e-4, atol=5e-4)
