"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled hot path; hypothesis
sweeps shapes, block sizes, and dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gradient as K
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def make_data(m, d, dtype=jnp.float32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (m, d), dtype)
    beta = jax.random.normal(k2, (d,), dtype)
    y = jax.random.normal(k3, (m,), dtype)
    return beta, x, y


def tol(dtype):
    # bf16 has ~8 mantissa bits; tile-order changes the accumulation, so
    # allow a couple of ULPs of relative slack.
    return dict(rtol=6e-2, atol=5e-1) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=300),
    d=st.integers(min_value=1, max_value=96),
    block_m=st.sampled_from([8, 32, 128]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_partial_gradient_matches_ref(m, d, block_m, seed):
    beta, x, y = make_data(m, d, seed=seed)
    got = K.partial_gradient(beta, x, y, block_m=block_m)
    want = ref.partial_gradient_ref(beta, x, y)
    np.testing.assert_allclose(got, want, **tol(jnp.float32))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=300),
    d=st.integers(min_value=1, max_value=96),
    block_m=st.sampled_from([8, 32, 128]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_grad_and_loss_matches_ref(m, d, block_m, seed):
    beta, x, y = make_data(m, d, seed=seed)
    g, loss = K.grad_and_loss(beta, x, y, block_m=block_m)
    g_ref, loss_ref = ref.grad_and_loss_ref(beta, x, y)
    np.testing.assert_allclose(g, g_ref, **tol(jnp.float32))
    np.testing.assert_allclose(loss, loss_ref, **tol(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,d", [(64, 16), (200, 33)])
def test_dtypes(dtype, m, d):
    beta, x, y = make_data(m, d, dtype=dtype, seed=7)
    g = K.partial_gradient(beta, x, y, block_m=32)
    want = ref.partial_gradient_ref(beta, x, y)
    np.testing.assert_allclose(
        np.asarray(g, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )
    assert g.dtype == dtype


def test_exact_fit_gives_zero_gradient():
    """If y = X beta exactly, the gradient and loss must be ~0."""
    beta, x, _ = make_data(128, 24, seed=3)
    y = x @ beta
    g, loss = K.grad_and_loss(beta, x, y, block_m=32)
    np.testing.assert_allclose(g, np.zeros(24), atol=1e-3)
    np.testing.assert_allclose(loss, np.zeros(1), atol=1e-3)


def test_zero_beta_gradient_is_minus_xty():
    beta = jnp.zeros((24,), jnp.float32)
    _, x, y = make_data(100, 24, seed=4)
    g = K.partial_gradient(beta, x, y, block_m=32)
    np.testing.assert_allclose(g, -(x.T @ y), rtol=2e-4, atol=2e-4)


def test_single_row_shard():
    beta, x, y = make_data(1, 8, seed=5)
    g = K.partial_gradient(beta, x, y, block_m=128)
    np.testing.assert_allclose(g, ref.partial_gradient_ref(beta, x, y), rtol=2e-4, atol=2e-4)


def test_block_larger_than_m_is_clamped():
    beta, x, y = make_data(17, 5, seed=6)
    g = K.partial_gradient(beta, x, y, block_m=512)
    np.testing.assert_allclose(g, ref.partial_gradient_ref(beta, x, y), rtol=2e-4, atol=2e-4)


def test_ragged_tail_block_is_masked():
    """m deliberately not divisible by block_m: padding rows contribute 0."""
    beta, x, y = make_data(130, 16, seed=8)
    g_ragged = K.partial_gradient(beta, x, y, block_m=64)  # grid of 3, last partial
    g_exact = K.partial_gradient(beta, x, y, block_m=130)  # single block
    np.testing.assert_allclose(g_ragged, g_exact, rtol=2e-4, atol=2e-4)


def test_gradient_is_linear_in_y():
    """g(beta, X, y1+y2) + X^T(X beta) = g(beta,X,y1) + g(beta,X,y2) sanity."""
    beta, x, y1 = make_data(96, 12, seed=9)
    _, _, y2 = make_data(96, 12, seed=10)
    g12 = K.partial_gradient(beta, x, y1 + y2, block_m=32)
    g1 = K.partial_gradient(beta, x, y1, block_m=32)
    g2 = K.partial_gradient(beta, x, y2, block_m=32)
    extra = x.T @ (x @ beta)  # the X^T X beta term double-counted in g1+g2
    np.testing.assert_allclose(g12, g1 + g2 - extra, rtol=1e-3, atol=1e-3)


def test_vmem_footprint_estimate():
    fp = K.vmem_footprint_bytes(m=4096, d=128, block_m=128)
    # 128x128 tile + vectors: must fit comfortably under 4 MiB (DESIGN SS Perf)
    assert fp < 4 * 1024 * 1024
    assert fp == 4 * (128 * 128 + 128 + 128 + 128 + 1)
