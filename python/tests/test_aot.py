"""AOT path: artifacts lower to parseable HLO text with a consistent manifest."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.lower_all(out, m=32, d=8)
    return out, manifest


def test_manifest_written(artifacts):
    out, manifest = artifacts
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["dtype"] == "f32"
    names = [e["name"] for e in on_disk["entries"]]
    assert "partial_grad_m32_d8" in names
    assert "partial_grad_loss_m32_d8" in names
    assert "full_step_m32_d8" in names
    assert "sgd_update_d8" in names
    # half-size shard variants
    assert "partial_grad_m16_d8" in names


def test_hlo_files_exist_and_parse_shape(artifacts):
    out, manifest = artifacts
    for e in manifest["entries"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert "ENTRY" in text, f"{e['name']} has no ENTRY computation"
        assert "HloModule" in text
        # return_tuple=True: root must be a tuple
        assert "tuple(" in text or "(f32[" in text


def test_manifest_shapes(artifacts):
    _, manifest = artifacts
    by_name = {e["name"]: e for e in manifest["entries"]}
    pg = by_name["partial_grad_m32_d8"]
    assert pg["args"][0]["shape"] == [8]
    assert pg["args"][1]["shape"] == [32, 8]
    assert pg["args"][2]["shape"] == [32]
    assert pg["outputs"] == 1
    fs = by_name["full_step_m32_d8"]
    assert fs["args"][3]["shape"] == []  # scalar lr
    assert fs["outputs"] == 2


def test_no_custom_calls(artifacts):
    """interpret=True must lower to plain HLO: the CPU PJRT client cannot
    run Mosaic custom-calls."""
    out, manifest = artifacts
    for e in manifest["entries"]:
        text = open(os.path.join(out, e["file"])).read()
        assert "custom-call" not in text, f"{e['name']} contains a custom-call"
