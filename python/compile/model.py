"""L2: the JAX compute graph for the paper's distributed-GD workload.

The paper (Sec. II-B, eq. (2)) motivates its replication analysis with
distributed gradient descent: the master holds the model ``beta``, the
dataset is chunked into shards, and every worker computes the gradient of
the loss over its shard. These functions are the *per-worker task* and
the master's update rule; they call the L1 Pallas kernels and are lowered
once by ``compile.aot`` to HLO-text artifacts the Rust coordinator
executes via PJRT.

All entrypoints return tuples (lowered with ``return_tuple=True``).
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import gradient as K


def partial_grad(beta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray):
    """Per-worker task: mean partial gradient over the shard, shape (d,)."""
    m = x.shape[0]
    g = K.partial_gradient(beta, x, y)
    return (g / jnp.asarray(m, x.dtype),)


def partial_grad_loss(beta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray):
    """Per-worker task returning (mean gradient (d,), mean loss (1,))."""
    m = x.shape[0]
    g, loss = K.grad_and_loss(beta, x, y)
    inv_m = jnp.asarray(1.0 / m, x.dtype)
    return (g * inv_m, loss * inv_m)


def sgd_update(beta: jnp.ndarray, g: jnp.ndarray, lr: jnp.ndarray):
    """Master update: beta' = beta - lr * g (lr is a scalar array)."""
    return (beta - lr * g,)


def full_step(beta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray, lr: jnp.ndarray):
    """Single-worker reference path: one fused GD step.

    Returns (beta', mean loss (1,)); used by the runtime as the
    no-replication baseline and by tests as the end-to-end oracle.
    """
    m = x.shape[0]
    g, loss = K.grad_and_loss(beta, x, y)
    inv_m = jnp.asarray(1.0 / m, x.dtype)
    return (beta - lr * (g * inv_m), loss * inv_m)
