"""AOT compile path: lower L2 entrypoints to HLO *text* artifacts.

Run once by ``make artifacts``; the Rust coordinator loads the text via
``HloModuleProto::from_text_file`` and executes on the PJRT CPU client.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts [--d 64] [--m 256]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

DTYPE = jnp.float32
DTYPE_NAME = "f32"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def entrypoints(m: int, d: int):
    """(name, fn, arg_specs, output arity) for every artifact we emit.

    Two shard sizes are emitted for each per-worker task: the primary
    ``m`` and a half-size shard, so the coordinator can serve batches at
    two granularities without re-lowering.
    """
    eps = []
    for mm in sorted({m, max(8, m // 2)}):
        eps.append((f"partial_grad_m{mm}_d{d}", model.partial_grad,
                    [_spec(d), _spec(mm, d), _spec(mm)], 1))
        eps.append((f"partial_grad_loss_m{mm}_d{d}", model.partial_grad_loss,
                    [_spec(d), _spec(mm, d), _spec(mm)], 2))
        eps.append((f"full_step_m{mm}_d{d}", model.full_step,
                    [_spec(d), _spec(mm, d), _spec(mm), _spec()], 2))
    eps.append((f"sgd_update_d{d}", model.sgd_update,
                [_spec(d), _spec(d), _spec()], 1))
    return eps


def lower_all(out_dir: str, m: int, d: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"dtype": DTYPE_NAME, "d": d, "m": m, "entries": []}
    for name, fn, specs, n_out in entrypoints(m, d):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["entries"].append({
            "name": name,
            "file": fname,
            "args": [{"shape": list(s.shape), "dtype": DTYPE_NAME} for s in specs],
            "outputs": n_out,
        })
        print(f"  lowered {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--d", type=int, default=64, help="feature dimension")
    ap.add_argument("--m", type=int, default=256, help="primary shard rows")
    args = ap.parse_args()
    manifest = lower_all(args.out_dir, args.m, args.d)
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
