"""L1 Pallas kernels: the partial-gradient hot-spot of distributed GD.

TPU-first design (see DESIGN.md SS Hardware-Adaptation):

* The shard matrix ``X (m, d)`` streams HBM->VMEM in row tiles of
  ``block_m`` rows via ``BlockSpec``; ``beta (d,)`` and the ``(d,)``
  gradient accumulator stay resident in VMEM for the whole grid.
* Each grid step performs two MXU-shaped contractions over the tile:
  ``r = X_t @ beta - y_t`` and ``g += X_t^T @ r`` -- the canonical
  "normal equations" tiling, so arithmetic intensity grows with ``d``.
* The fused variant also accumulates ``0.5 * ||r||^2`` so the residual is
  computed once (no recomputation between grad and loss -- an L2 perf
  item in DESIGN.md SS Perf).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels lower to plain HLO. Correctness vs
``kernels.ref`` is enforced by pytest + hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 128


def _grid(m: int, block_m: int) -> int:
    """Number of row tiles (ceil division)."""
    return (m + block_m - 1) // block_m


def _masked_tile(x_ref, y_ref, step, block_m: int, m: int):
    """Load a row tile with grid-padding rows *zeroed*.

    The last grid step may run past ``m``; padded rows hold garbage (NaN
    under interpret mode), and ``NaN * 0 == NaN``, so the mask must be a
    ``where``-select on the inputs rather than a multiplicative mask on
    the residual.
    """
    row = step * block_m + jax.lax.broadcasted_iota(jnp.int32, (block_m,), 0)
    valid = row < m
    x_t = jnp.where(valid[:, None], x_ref[...], 0)
    y_t = jnp.where(valid, y_ref[...], 0)
    return x_t, y_t


def _partial_gradient_kernel(x_ref, beta_ref, y_ref, g_ref, *, block_m: int, m: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    x_t, y_t = _masked_tile(x_ref, y_ref, step, block_m, m)
    residual = x_t @ beta_ref[...] - y_t
    g_ref[...] += x_t.T @ residual


def _grad_and_loss_kernel(x_ref, beta_ref, y_ref, g_ref, loss_ref, *, block_m: int, m: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    x_t, y_t = _masked_tile(x_ref, y_ref, step, block_m, m)
    residual = x_t @ beta_ref[...] - y_t
    g_ref[...] += x_t.T @ residual
    loss_ref[...] += 0.5 * jnp.sum(residual * residual, keepdims=True)


def _specs(block_m: int, d: int):
    """Input BlockSpecs shared by both kernels: X tiled, beta/y per-tile."""
    return [
        pl.BlockSpec((block_m, d), lambda i: (i, 0)),  # X: row tiles
        pl.BlockSpec((d,), lambda i: (0,)),  # beta: VMEM-resident
        pl.BlockSpec((block_m,), lambda i: (i,)),  # y: row tiles
    ]


@functools.partial(jax.jit, static_argnames=("block_m",))
def partial_gradient(beta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray,
                     *, block_m: int = DEFAULT_BLOCK_M) -> jnp.ndarray:
    """Unnormalized partial gradient ``X^T (X beta - y)`` via Pallas.

    Args:
      beta: model vector, shape ``(d,)``.
      x: shard design matrix, shape ``(m, d)``.
      y: shard targets, shape ``(m,)``.
      block_m: rows per VMEM tile (grid is ``ceil(m / block_m)``).

    Returns:
      Gradient of shape ``(d,)`` matching ``ref.partial_gradient_ref``.
    """
    m, d = x.shape
    block_m = min(block_m, m)
    kernel = functools.partial(_partial_gradient_kernel, block_m=block_m, m=m)
    return pl.pallas_call(
        kernel,
        grid=(_grid(m, block_m),),
        in_specs=_specs(block_m, d),
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(x, beta, y)


@functools.partial(jax.jit, static_argnames=("block_m",))
def grad_and_loss(beta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray,
                  *, block_m: int = DEFAULT_BLOCK_M):
    """Fused unnormalized (gradient, loss): one pass over the shard.

    Returns ``(g, loss)`` with shapes ``((d,), (1,))`` matching
    ``ref.grad_and_loss_ref``.
    """
    m, d = x.shape
    block_m = min(block_m, m)
    kernel = functools.partial(_grad_and_loss_kernel, block_m=block_m, m=m)
    return pl.pallas_call(
        kernel,
        grid=(_grid(m, block_m),),
        in_specs=_specs(block_m, d),
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=True,
    )(x, beta, y)


def vmem_footprint_bytes(m: int, d: int, block_m: int = DEFAULT_BLOCK_M,
                         dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (see DESIGN.md SS Perf).

    X tile + y tile + beta + gradient accumulator + loss accumulator.
    """
    block_m = min(block_m, m)
    return dtype_bytes * (block_m * d + block_m + d + d + 1)
