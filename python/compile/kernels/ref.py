"""Pure-jnp oracles for the Pallas kernels (L1 correctness reference).

The workload is the paper's motivating distributed-gradient-descent job
(Sec. II-B, eq. (2)): a worker holds a shard ``(X, y)`` of the dataset and
computes the partial gradient of the squared loss

    L(beta; X, y) = 0.5 * ||X @ beta - y||^2

All reference functions return *unnormalized sums* (no division by the
shard size); layer 2 (`compile.model`) owns normalization so the kernel
and the oracle stay bit-comparable.
"""

from __future__ import annotations

import jax.numpy as jnp


def partial_gradient_ref(beta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized partial gradient  X^T (X beta - y)  of shape (d,)."""
    residual = x @ beta - y
    return x.T @ residual


def partial_loss_ref(beta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized partial squared loss  0.5 ||X beta - y||^2, shape (1,)."""
    residual = x @ beta - y
    return 0.5 * jnp.sum(residual * residual, keepdims=True)


def grad_and_loss_ref(beta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray):
    """Fused (gradient, loss) pair sharing one residual computation."""
    residual = x @ beta - y
    grad = x.T @ residual
    loss = 0.5 * jnp.sum(residual * residual, keepdims=True)
    return grad, loss


def sgd_update_ref(beta: jnp.ndarray, grad: jnp.ndarray, lr: jnp.ndarray) -> jnp.ndarray:
    """Plain gradient step  beta - lr * grad."""
    return beta - lr * grad
