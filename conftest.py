"""Repo-root pytest shim: make `pytest python/tests/` work from the
repository root (the `compile` package lives under python/)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
