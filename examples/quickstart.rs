//! Quickstart: plan a redundancy level, then verify it by simulation.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use replica::batching::Policy;
use replica::dist::ServiceDist;
use replica::metrics::{fnum, Table};
use replica::planner::{Objective, Planner};
use replica::sim::montecarlo::simulate_policy;

fn main() -> replica::Result<()> {
    // A cluster of N = 100 workers whose task service times are
    // shifted-exponential: at least 50 ms, then an Exp(1) tail.
    let n = 100;
    let tau = ServiceDist::shifted_exp(0.05, 1.0);

    println!("service model: {}\n", tau.label());

    // 1. Plan the optimal batch count for mean completion time.
    let planner = Planner::new(n, tau.clone());
    let plan = planner.plan(Objective::MeanCompletion);
    println!(
        "planner: split the job into B = {} batches of {} tasks, each \
         replicated on {} workers ({:?} regime)",
        plan.batches,
        plan.batch_size,
        plan.replication,
        plan.regime.unwrap()
    );
    println!(
        "predicted E[T] = {}  (speedup {}x over no redundancy)\n",
        fnum(plan.predicted_mean),
        fnum(plan.speedup_vs_no_redundancy)
    );

    // 2. Verify by Monte-Carlo across the whole spectrum.
    let mut table = Table::new(
        "diversity–parallelism spectrum (20k replications per point)",
        vec!["B", "replication", "E[T] analytic", "E[T] simulated", "CoV"],
    );
    for point in planner.sweep() {
        let est = simulate_policy(
            n,
            &Policy::BalancedNonOverlapping { batches: point.batches },
            &tau,
            20_000,
            42,
        )?;
        let marker = if point.batches == plan.batches { " <- planned" } else { "" };
        table.row(vec![
            format!("{}{marker}", point.batches),
            (n / point.batches).to_string(),
            fnum(point.mean),
            format!("{} ± {}", fnum(est.mean), fnum(est.ci95)),
            fnum(est.cov),
        ]);
    }
    table.print();

    // 3. The predictability trade-off (Theorems 4/7/10).
    let cov_plan = planner.plan(Objective::Predictability);
    println!(
        "\nmost predictable point: B = {} (CoV {}) — mean-optimal was B = {}:",
        cov_plan.batches,
        fnum(cov_plan.predicted_cov),
        plan.batches
    );
    println!("optimizing for predictability costs mean completion time (§VI).");
    Ok(())
}
