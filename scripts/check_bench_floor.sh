#!/usr/bin/env bash
# Gate a BENCH_eval.json snapshot: it must be a real measurement
# (measured == true, i.e. not the committed placeholder) and its
# pooled/serial speedup must clear the floor.
#
# Usage: scripts/check_bench_floor.sh [BENCH_eval.json] [FLOOR]
# The floor defaults to $BENCH_SPEEDUP_FLOOR, then 2.0 — the CI gate on
# ~4-vCPU hosted runners; the 8-physical-core aspiration recorded in
# the snapshot's "target" field is >= 3x.
set -euo pipefail
FILE="${1:-BENCH_eval.json}"
FLOOR="${2:-${BENCH_SPEEDUP_FLOOR:-2.0}}"

python3 - "$FILE" "$FLOOR" <<'EOF'
import json
import sys

path, floor = sys.argv[1], float(sys.argv[2])
with open(path) as f:
    snap = json.load(f)
if not snap.get("measured"):
    sys.exit(f"{path}: not a measured snapshot (measured != true); "
             "run scripts/bench_snapshot.sh first")
speedup = snap.get("speedup")
serial = snap.get("serial_reps_per_sec")
pooled = snap.get("pooled_reps_per_sec")
if not isinstance(speedup, (int, float)):
    sys.exit(f"{path}: missing/invalid 'speedup' field: {speedup!r}")
print(f"serial {serial:.0f} reps/s, pooled {pooled:.0f} reps/s, "
      f"speedup {speedup:.2f}x (floor {floor:.2f}x, "
      f"pool_threads={snap.get('pool_threads')})")
if speedup < floor:
    sys.exit(f"FAIL: pooled speedup {speedup:.2f}x is below the "
             f"{floor:.2f}x floor")
print("OK: pooled-speedup floor holds")
EOF
