#!/usr/bin/env bash
# Snapshot Monte-Carlo eval throughput (serial vs pooled reps/sec over
# the ≥20-scenario benchmark batch) into BENCH_eval.json at the repo
# root, seeding the perf trajectory across PRs.
#
# Usage: scripts/bench_snapshot.sh [OUTPUT_JSON] [--smoke]
set -euo pipefail
cd "$(dirname "$0")/.."

OUT="${1:-BENCH_eval.json}"
shift || true

case "$OUT" in
/*) JSON_ARG="$OUT" ;;
*) JSON_ARG="../$OUT" ;;
esac

(cd rust && cargo bench --bench bench_eval -- --json "$JSON_ARG" "$@")
echo "wrote $OUT"
