#!/usr/bin/env bash
# Gate the variance efficiency of the paired (CRN) spectrum: to resolve
# every B-vs-best difference to the same ±eps, common random numbers
# must need at most 1/FLOOR of the replications that independent
# per-scenario streams need on the same spec.
#
# Usage: scripts/check_variance_floor.sh [SPEC] [FLOOR]
# The floor defaults to $CRN_REPS_FLOOR, then 5 — deliberately below
# the ~10x typically measured, because both arms double their
# replication counts in power-of-2 waves (a true 9x gain can quantize
# down to 8x realized; it cannot quantize below 5x unless the real
# gain is gone).
set -euo pipefail
SPEC="${1:-specs/trace_scale.json}"
FLOOR="${2:-${CRN_REPS_FLOOR:-5}}"

root="$(cd "$(dirname "$0")/.." && pwd)"
bin="$root/rust/target/release/replica"
if [ ! -x "$bin" ]; then
  (cd "$root/rust" && cargo build --release)
fi

line="$("$bin" crn-bench --spec "$SPEC" --eps-rel 0.02 --max-reps 32768 --seed 0)"
echo "$line"

python3 - "$line" "$FLOOR" <<'EOF'
import json
import sys

snap, floor = json.loads(sys.argv[1]), float(sys.argv[2])
paired = snap["paired_reps"]
independent = snap["independent_reps"]
ratio = snap["ratio"]
print(f"paired {paired} reps vs independent {independent} reps for "
      f"eps {snap['eps']:.4g}: {ratio:.2f}x (floor {floor:.2f}x)")
if independent < floor * paired:
    sys.exit(f"FAIL: CRN used {paired} reps, independent streams "
             f"{independent}; ratio {ratio:.2f}x is below the "
             f"{floor:.2f}x variance-efficiency floor")
print("OK: variance-efficiency floor holds")
EOF
