#!/usr/bin/env bash
# Fan one sweep spec across M local shard processes, then merge the
# per-shard stores into the canonical grid-ordered store.
#
#   scripts/sweep_shards.sh SPEC OUT M [extra `replica sweep` flags...]
#
# Each shard process runs `replica sweep --spec SPEC --out OUT
# --shard K/M`, writing OUT's per-shard store (OUT with `.jsonl`
# replaced by `.shard-K-of-M.jsonl`) and a per-shard estimate cache —
# no file is shared between processes. A failed or killed shard can be
# resumed by rerunning this script (finished shards are no-op resumes).
# The final merge writes OUT byte-identical to a single-process
# `replica sweep --spec SPEC --out OUT` run; CI's
# sweep-shard-determinism job cmp's exactly that.
set -euo pipefail

if [ "$#" -lt 3 ]; then
  echo "usage: $0 SPEC OUT M [extra sweep flags...]" >&2
  exit 2
fi
spec=$1
out=$2
m=$3
shift 3

root="$(cd "$(dirname "$0")/.." && pwd)"
bin="$root/rust/target/release/replica"
if [ ! -x "$bin" ]; then
  (cd "$root/rust" && cargo build --release)
fi

pids=()
for ((k = 0; k < m; k++)); do
  "$bin" sweep --spec "$spec" --out "$out" --shard "$k/$m" "$@" &
  pids+=("$!")
done

status=0
for pid in "${pids[@]}"; do
  wait "$pid" || status=1
done
if [ "$status" -ne 0 ]; then
  echo "sweep_shards: a shard process failed; rerun this script to resume" >&2
  exit 1
fi

"$bin" sweep-merge --spec "$spec" --out "$out" --shards "$m" "$@"
