#!/usr/bin/env bash
# Chaos test for the fault-tolerant sweep cluster: run a coordinator
# plus W workers, SIGKILL a random worker mid-sweep, then SIGKILL and
# restart the coordinator itself, and byte-compare the assembled store
# against a single-process `replica sweep` run.
#
#   scripts/cluster_chaos.sh SPEC OUTDIR [WORKERS]
#
# The invariant under test is the cluster module's headline contract:
# every case's RNG stream is a function of its content key alone, so no
# amount of lease reassignment, duplicate recomputation, or coordinator
# restart can change a single output byte. CI's cluster-chaos job runs
# exactly this script and fails on the final cmp.
set -euo pipefail

if [ "$#" -lt 2 ]; then
  echo "usage: $0 SPEC OUTDIR [WORKERS]" >&2
  exit 2
fi
spec=$1
outdir=$2
workers=${3:-4}

root="$(cd "$(dirname "$0")/.." && pwd)"
bin="$root/rust/target/release/replica"
if [ ! -x "$bin" ]; then
  (cd "$root/rust" && cargo build --release)
fi

mkdir -p "$outdir"
single="$outdir/single.jsonl"
clustered="$outdir/clustered.jsonl"
port=$((20000 + RANDOM % 20000))
addr="127.0.0.1:$port"

# Block until FILE holds at least N lines (the estimate cache grows by
# one line per finished case, so this is a progress gate). Gives up
# after ~120s so a wedged cluster fails loudly instead of hanging CI.
wait_for_lines() {
  local file=$1 n=$2 i lines
  for ((i = 0; i < 600; i++)); do
    lines=0
    if [ -f "$file" ]; then
      lines=$(wc -l <"$file")
    fi
    if [ "$lines" -ge "$n" ]; then
      return 0
    fi
    sleep 0.2
  done
  echo "cluster_chaos: timed out waiting for $n lines in $file" >&2
  return 1
}

echo "=== single-process reference run"
"$bin" sweep --spec "$spec" --out "$single" >/dev/null

echo "=== coordinator on $addr + $workers workers"
"$bin" cluster-serve --spec "$spec" --out "$clustered" --listen "$addr" \
  >"$outdir/serve-1.log" 2>&1 &
serve_pid=$!

worker_pids=()
for ((w = 0; w < workers; w++)); do
  "$bin" cluster-work --connect "$addr" --worker "chaos-w$w" \
    >"$outdir/worker-$w.log" 2>&1 &
  worker_pids+=("$!")
done

echo "=== SIGKILL a random worker mid-sweep"
wait_for_lines "$clustered.cache.jsonl" 40
victim_idx=$((RANDOM % workers))
victim=${worker_pids[victim_idx]}
echo "killing worker chaos-w$victim_idx (pid $victim)"
kill -9 "$victim" 2>/dev/null || true

echo "=== SIGKILL the coordinator mid-sweep, then restart it"
wait_for_lines "$clustered.cache.jsonl" 120
kill -9 "$serve_pid" 2>/dev/null || true
wait "$serve_pid" 2>/dev/null || true
sleep 1
"$bin" cluster-serve --spec "$spec" --out "$clustered" --listen "$addr" \
  >"$outdir/serve-2.log" 2>&1 &
serve_pid=$!

# replace the killed worker so capacity survives the chaos
"$bin" cluster-work --connect "$addr" --worker "chaos-replacement" \
  >"$outdir/worker-replacement.log" 2>&1 &
worker_pids+=("$!")

echo "=== waiting for the restarted coordinator to finish"
if ! wait "$serve_pid"; then
  echo "cluster_chaos: restarted coordinator failed" >&2
  sed -n '1,50p' "$outdir/serve-2.log" >&2 || true
  exit 1
fi

for pid in "${worker_pids[@]}"; do
  # the SIGKILLed worker reports failure by design; survivors must not
  wait "$pid" 2>/dev/null || true
done

echo "=== byte-compare clustered store vs single-process store"
cmp "$single" "$clustered"
echo "byte-identical: $(sha256sum "$single")"
grep -h "resumed from disk" "$outdir/serve-2.log" || true
