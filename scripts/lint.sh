#!/usr/bin/env bash
# Run the full lint gate locally, mirroring CI's blocking lint jobs:
#
#   1. detlint      — source-level determinism & safety rules (D1-D4),
#                     configured by rust/detlint.toml; stale or
#                     unjustified allowlist entries fail too
#   2. clippy       — whole workspace, all targets, warnings denied
#   3. rustfmt      — formatting check only (nothing is rewritten)
#
# Usage: scripts/lint.sh
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== detlint (determinism & safety rules, rust/detlint.toml) =="
cargo run -q -p detlint

echo "== clippy (workspace, all targets, -D warnings) =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== rustfmt (check only) =="
cargo fmt --all --check

echo "OK: all lint gates passed"
