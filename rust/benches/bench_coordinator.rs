//! Bench: end-to-end coordinator — round latency across the
//! diversity–parallelism spectrum on the live thread-pool system, plus
//! raw PJRT gradient-execution latency when artifacts are present.

use std::sync::Arc;

use replica::coordinator::{
    ComputeBackend, Coordinator, Dataset, GdConfig, NativeBackend, PjrtBackend,
};
use replica::dist::ServiceDist;
use replica::metrics::{bench, fnum, Table};
use replica::runtime::{artifacts_available, artifacts_dir, GradientOps, RuntimeService};

fn main() {
    let workers = 8;
    let (m, d) = (64, 16);
    let rounds = 40;
    let straggler = ServiceDist::pareto(0.02, 1.3);

    // ---- spectrum latency on the live coordinator (native backend) ----
    let mut t = Table::new(
        "live coordinator: mean round latency across the spectrum \
         (N=8 threads, heavy-tail stragglers, native backend)",
        vec!["B", "replication", "mean latency (ms)", "discarded/round"],
    );
    for b in [1usize, 2, 4, 8] {
        let cfg = GdConfig {
            workers,
            batches: b,
            rounds,
            lr: 0.1,
            straggler: straggler.clone(),
            time_scale: 2e-3,
            seed: 7,
        };
        let ds = Dataset::synthetic(workers, m, d, 0.05, 3);
        let mut coord =
            Coordinator::new(cfg, ds, Arc::new(NativeBackend::new(m, d))).expect("coord");
        let rep = coord.run().expect("run");
        t.row(vec![
            b.to_string(),
            (workers / b).to_string(),
            fnum(rep.mean_latency() * 1e3),
            fnum(rep.total_discarded as f64 / rounds as f64),
        ]);
    }
    t.print();
    println!();

    // ---- backend micro-latency ----
    let native = NativeBackend::new(m, d);
    let ds = Dataset::synthetic(1, m, d, 0.05, 5);
    let beta = vec![0.1f32; d];
    bench("native partial_grad_loss (64x16)", 30.0, || {
        std::hint::black_box(
            native.partial_grad_loss(&beta, &ds.shards[0].x, &ds.shards[0].y).unwrap(),
        );
    });

    if artifacts_available() {
        let service = RuntimeService::start(&artifacts_dir()).expect("runtime");
        let manifest = service.handle().manifest().clone();
        let ops = GradientOps::new(service.handle(), manifest.m).expect("ops");
        let pjrt = PjrtBackend::new(ops);
        let dsp = Dataset::synthetic(1, manifest.m, manifest.d, 0.05, 6);
        let beta = vec![0.1f32; manifest.d];
        let label = format!(
            "pjrt partial_grad_loss ({}x{}) via runtime thread",
            manifest.m, manifest.d
        );
        bench(&label, 60.0, || {
            std::hint::black_box(
                pjrt.partial_grad_loss(&beta, &dsp.shards[0].x, &dsp.shards[0].y).unwrap(),
            );
        });
        // §Perf: cached-shard variant — x/y stay device-resident, only
        // beta crosses the boundary each call
        let label2 = format!(
            "pjrt partial_grad_loss CACHED shard ({}x{})",
            manifest.m, manifest.d
        );
        bench(&label2, 60.0, || {
            std::hint::black_box(
                pjrt.ops()
                    .partial_grad_loss_cached(&beta, 0, &dsp.shards[0].x, &dsp.shards[0].y)
                    .unwrap(),
            );
        });
        // dispatch-overhead probe: sgd_update moves only ~512 B, so its
        // latency ≈ the fixed PJRT/channel dispatch cost
        let g = vec![0.01f32; manifest.d];
        bench("pjrt sgd_update (d-vector only) dispatch probe", 60.0, || {
            std::hint::black_box(pjrt.ops().sgd_update(&beta, &g, 0.1).unwrap());
        });

        // end-to-end pjrt coordinator round latency at the planned point
        let cfg = GdConfig {
            workers: 4,
            batches: 2,
            rounds: 20,
            lr: 0.1,
            straggler: ServiceDist::shifted_exp(0.001, 1000.0),
            time_scale: 1e-4,
            seed: 9,
        };
        let ds = Dataset::synthetic(4, manifest.m, manifest.d, 0.05, 7);
        let ops = GradientOps::new(service.handle(), manifest.m).expect("ops");
        let mut coord =
            Coordinator::new(cfg, ds, Arc::new(PjrtBackend::new(ops))).expect("coord");
        let rep = coord.run().expect("run");
        println!(
            "pjrt e2e: {} rounds, mean latency {} ms, final loss {}",
            rep.rounds.len(),
            fnum(rep.mean_latency() * 1e3),
            fnum(rep.final_global_loss)
        );
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT benches)");
    }
}
