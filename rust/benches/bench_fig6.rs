//! Bench/regeneration: Fig. 6 + eq. (17) — overlapping vs
//! non-overlapping batches (N=6, B=3).

use replica::batching::Policy;
use replica::dist::ServiceDist;
use replica::experiments::fig6;
use replica::metrics::bench;
use replica::sim::montecarlo::simulate_policy;

fn main() {
    let mus = [0.25, 0.5, 1.0, 2.0, 4.0];
    let rows = fig6::run(&mus, 60_000, 42).expect("fig6");
    fig6::table(&rows).print();
    println!();

    let tau = ServiceDist::exp(1.0);
    for policy in [
        Policy::BalancedNonOverlapping { batches: 3 },
        Policy::CyclicOverlapping { batches: 3 },
        Policy::HybridOverlapping { batches: 3 },
    ] {
        let name = format!("simulate_policy N=6 {} (1k reps)", policy.name());
        bench(&name, 40.0, || {
            std::hint::black_box(
                simulate_policy(6, &policy, &tau, 1_000, 7).expect("sim"),
            );
        });
    }
}
