//! Bench/regeneration: Fig. 6 + eq. (17) — overlapping vs
//! non-overlapping batches (N=6, B=3).

use replica::batching::Policy;
use replica::dist::ServiceDist;
use replica::eval::{Estimator, MonteCarlo, Scenario};
use replica::experiments::fig6;
use replica::metrics::bench;

fn main() {
    let mus = [0.25, 0.5, 1.0, 2.0, 4.0];
    let rows = fig6::run(&mus, 60_000, 42).expect("fig6");
    fig6::table(&rows).print();
    println!();

    let tau = ServiceDist::exp(1.0);
    let mc = MonteCarlo::serial(1_000, 7);
    for policy in [
        Policy::BalancedNonOverlapping { batches: 3 },
        Policy::CyclicOverlapping { batches: 3 },
        Policy::HybridOverlapping { batches: 3 },
    ] {
        let scenario = Scenario::new(6, policy, tau.clone());
        let name = format!(
            "MonteCarlo::evaluate N=6 {} (1k reps)",
            scenario.policy.name()
        );
        bench(&name, 40.0, || {
            std::hint::black_box(mc.evaluate(&scenario).expect("sim"));
        });
    }
}
