//! Bench/regeneration: Fig. 3 — coverage probability of random
//! assignment (Lemma 1), plus timing of the occupancy recurrence.

use replica::experiments::fig3;
use replica::metrics::bench;

fn main() {
    fig3::table(&fig3::PAPER_NS).print();
    println!();

    // representative curve values (the paper's N=100 line)
    let series = fig3::run(&[100]);
    println!("Fig 3 series, N=100 (B, Pr[cover]):");
    for (b, p) in series[0].points.iter().step_by(10) {
        println!("  B={b:<4} p={:.6}", p[0]);
    }
    println!();

    bench("coverage_probability(N=100, B=50)", 30.0, || {
        std::hint::black_box(replica::analysis::coverage::coverage_probability(100, 50));
    });
    bench("coverage_probability(N=1000, B=300)", 60.0, || {
        std::hint::black_box(replica::analysis::coverage::coverage_probability(1000, 300));
    });
}
