//! Bench: serial vs multi-threaded Monte-Carlo evaluation throughput
//! (replications/sec) across cluster sizes, plus the determinism
//! contract check (bit-identical estimates for any thread fan-out).

use replica::dist::ServiceDist;
use replica::eval::{Estimator, MonteCarlo, Scenario};
use replica::metrics::bench;

fn main() {
    let cores =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("available cores: {cores}\n");

    let tau = ServiceDist::shifted_exp(0.05, 1.0);
    let reps = 30_000;

    for n in [20usize, 100, 200] {
        // interior operating point with replication degree 5
        let b = n / 5;
        let scenario = Scenario::balanced(n, b, tau.clone());

        let mut serial_per_iter = f64::NAN;
        for threads in [1usize, 2, 4, 0] {
            let mc = MonteCarlo { reps, seed: 42, threads };
            let shown = if threads == 0 {
                format!("auto({cores})")
            } else {
                threads.to_string()
            };
            let label = format!("MonteCarlo N={n} B={b} reps=30k threads={shown}");
            let r = bench(&label, 200.0, || {
                std::hint::black_box(mc.evaluate(&scenario).expect("eval"));
            });
            let reps_per_sec = reps as f64 * r.per_second();
            if threads == 1 {
                serial_per_iter = r.secs_per_iter;
                println!("  -> {:.2} M reps/s", 1e-6 * reps_per_sec);
            } else {
                println!(
                    "  -> {:.2} M reps/s ({:.2}x vs serial)",
                    1e-6 * reps_per_sec,
                    serial_per_iter / r.secs_per_iter
                );
            }
        }

        // determinism contract: the estimates above must be bit-identical
        let a = MonteCarlo { reps, seed: 42, threads: 1 }.evaluate(&scenario).unwrap();
        let b_est = MonteCarlo { reps, seed: 42, threads: 0 }.evaluate(&scenario).unwrap();
        assert_eq!(
            a.mean.to_bits(),
            b_est.mean.to_bits(),
            "thread fan-out changed the estimate at N={n}"
        );
        println!("  determinism: serial and threaded estimates bit-identical\n");
    }
}
