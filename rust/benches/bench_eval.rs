//! Bench: Monte-Carlo evaluation throughput, serial vs the persistent
//! worker pool — single scenarios and a whole-sweep batch — plus the
//! determinism contract check (bit-identical estimates for any
//! fan-out).
//!
//! Flags (after `--`, e.g. `cargo bench --bench bench_eval -- --smoke`):
//!
//! * `--smoke`       short CI run (fewer reps, one timing iteration)
//! * `--json PATH`   write the batch-sweep throughput snapshot to PATH
//!                   (the `scripts/bench_snapshot.sh` → `BENCH_eval.json`
//!                   flow)

use std::time::Instant;

use replica::dist::ServiceDist;
use replica::eval::{Estimator, MonteCarlo, Scenario};
use replica::sim::WorkerPool;
use replica::util::json::Json;

fn divisors(n: usize) -> Vec<usize> {
    (1..=n).filter(|b| n % b == 0).collect()
}

/// The benchmark batch: every operating point of N = 60 and N = 120
/// (12 + 16 divisors = 28 scenarios ≥ the 20-point floor).
fn sweep_scenarios() -> Vec<Scenario> {
    let tau = ServiceDist::shifted_exp(0.05, 1.0);
    let mut scenarios = Vec::new();
    for n in [60usize, 120] {
        for b in divisors(n) {
            scenarios.push(Scenario::balanced(n, b, tau.clone()));
        }
    }
    scenarios
}

/// Mean seconds per `evaluate_many` call (one warm-up, then `iters`
/// timed calls).
fn time_batch(mc: &MonteCarlo, scenarios: &[Scenario], iters: usize) -> f64 {
    std::hint::black_box(mc.evaluate_many(scenarios).expect("eval"));
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(mc.evaluate_many(scenarios).expect("eval"));
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();

    let pool_width = WorkerPool::global().threads();
    println!("worker pool width: {pool_width}{}\n", if smoke { " (smoke)" } else { "" });

    let reps = if smoke { 3_000 } else { 30_000 };
    let iters = if smoke { 1 } else { 3 };

    // ---- single scenarios: per-scenario fan-out ---------------------
    let tau = ServiceDist::shifted_exp(0.05, 1.0);
    for n in [20usize, 100, 200] {
        let b = n / 5; // interior operating point, replication degree 5
        let scenario = Scenario::balanced(n, b, tau.clone());
        let mut serial_secs = f64::NAN;
        for threads in [1usize, 2, 4, 0] {
            let mc = MonteCarlo { reps, seed: 42, threads };
            let secs = time_batch(&mc, std::slice::from_ref(&scenario), iters);
            let shown = if threads == 0 {
                format!("pool({pool_width})")
            } else {
                threads.to_string()
            };
            if threads == 1 {
                serial_secs = secs;
                println!(
                    "single N={n} B={b} threads={shown}: {:.2} M reps/s",
                    1e-6 * reps as f64 / secs
                );
            } else {
                println!(
                    "single N={n} B={b} threads={shown}: {:.2} M reps/s ({:.2}x vs serial)",
                    1e-6 * reps as f64 / secs,
                    serial_secs / secs
                );
            }
        }
        println!();
    }

    // ---- whole-sweep batch: two-level scenario×chunk parallelism ----
    let scenarios = sweep_scenarios();
    let total_reps = (scenarios.len() * reps) as f64;
    let serial = MonteCarlo::serial(reps, 42);
    let pooled = MonteCarlo::new(reps, 42);
    let serial_secs = time_batch(&serial, &scenarios, iters);
    let pooled_secs = time_batch(&pooled, &scenarios, iters);
    let serial_rps = total_reps / serial_secs;
    let pooled_rps = total_reps / pooled_secs;
    println!(
        "batch sweep ({} scenarios x {reps} reps): serial {:.2} M reps/s, \
         pooled {:.2} M reps/s ({:.2}x)",
        scenarios.len(),
        1e-6 * serial_rps,
        1e-6 * pooled_rps,
        serial_secs / pooled_secs
    );

    // ---- determinism contract ---------------------------------------
    let a = serial.evaluate_many(&scenarios).expect("serial eval");
    let b = pooled.evaluate_many(&scenarios).expect("pooled eval");
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(
            x.mean.to_bits(),
            y.mean.to_bits(),
            "pool execution changed the estimate of batch item {i}"
        );
        assert_eq!(x.p99.to_bits(), y.p99.to_bits(), "item {i}");
    }
    let spot = pooled.evaluate_at(&scenarios[3], 3).expect("eval_at");
    assert_eq!(
        b[3].mean.to_bits(),
        spot.mean.to_bits(),
        "evaluate_many item 3 diverged from evaluate_at substream 3"
    );
    println!("determinism: serial and pooled estimates bit-identical\n");

    if let Some(path) = json_path {
        let snapshot = Json::obj(vec![
            ("bench", Json::Str("bench_eval batch sweep".into())),
            ("scenarios", Json::Num(scenarios.len() as f64)),
            ("reps_per_scenario", Json::Num(reps as f64)),
            ("pool_threads", Json::Num(pool_width as f64)),
            ("serial_reps_per_sec", Json::Num(serial_rps)),
            ("pooled_reps_per_sec", Json::Num(pooled_rps)),
            ("speedup", Json::Num(serial_secs / pooled_secs)),
            ("smoke", Json::Bool(smoke)),
            ("measured", Json::Bool(true)),
        ]);
        std::fs::write(&path, snapshot.to_string_pretty()).expect("write snapshot");
        println!("wrote {path}");
    }
}
