//! Bench/regeneration: Lemma 2/3 — balanced vs unbalanced assignments
//! across the three stochastically-convex families.

use replica::dist::ServiceDist;
use replica::experiments::assignment;
use replica::metrics::bench;

fn main() {
    for tau in [
        ServiceDist::exp(1.0),
        ServiceDist::shifted_exp(0.1, 1.0),
        ServiceDist::pareto(1.0, 2.5),
    ] {
        let rows = assignment::run(8, 2, &tau, 30_000, 11).expect("assignment");
        assignment::table(8, 2, &tau, &rows).print();
        println!();
    }

    // N=12, B=3: the richer partition lattice
    let tau = ServiceDist::exp(1.0);
    let rows = assignment::run(12, 3, &tau, 10_000, 13).expect("assignment");
    assignment::table(12, 3, &tau, &rows).print();
    println!();

    let batch = ServiceDist::scaled(4.0, ServiceDist::exp(1.0));
    bench("numeric_mean_var_assignment [4,4,4]", 40.0, || {
        std::hint::black_box(
            replica::analysis::closed_form::numeric_mean_var_assignment(&[4, 4, 4], &batch),
        );
    });
}
