//! Bench/regeneration: §VII trace experiments — Figs. 11, 12, 13 and
//! the headline speedup.

use replica::experiments::traces_exp;
use replica::metrics::{bench, fnum};
use replica::traces::JobAnalysis;

fn main() {
    let reps = 10_000;
    let seed = 42;
    let trace = traces_exp::standard_trace(seed);

    // Fig 11 summary (full CCDF series exported by `replica experiment traces --out`)
    println!("Fig 11: per-job tail classification");
    for a in JobAnalysis::all(&trace) {
        println!(
            "  job {:<2} tasks={} mean={:>9}s p99={:>10}s tail={} (cov {:.2}, hill {:.2})",
            a.job_id,
            a.n_tasks,
            fnum(a.mean),
            fnum(a.p99),
            if a.is_heavy_tail() { "heavy" } else { "exp  " },
            a.fit.excess_cov,
            a.fit.tail_alpha,
        );
    }
    println!();

    traces_exp::table(
        "Fig 12: normalized E[T] vs B — exponential-tail jobs",
        &trace,
        &traces_exp::EXP_TAIL_JOBS,
        reps,
        seed,
    )
    .expect("fig12")
    .print();
    println!();
    traces_exp::table(
        "Fig 13: normalized E[T] vs B — heavy-tail jobs",
        &trace,
        &traces_exp::HEAVY_TAIL_JOBS,
        reps,
        seed,
    )
    .expect("fig13")
    .print();
    println!();
    let headline = traces_exp::headline_speedup(&trace, reps, seed).expect("headline");
    println!("headline speedup (best heavy-tail job): {}x\n", fnum(headline));

    bench("JobAnalysis::all (10 jobs x 100 tasks)", 30.0, || {
        std::hint::black_box(JobAnalysis::all(&trace));
    });
    bench("job_sweep heavy job (1k reps/point)", 60.0, || {
        std::hint::black_box(traces_exp::job_sweep(&trace, 7, 1_000, 3).expect("sweep"));
    });
}
