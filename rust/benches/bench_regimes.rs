//! Bench/regeneration: Theorem 6/7/9 regime tables and the optimizer.

use replica::dist::ServiceDist;
use replica::experiments::regimes;
use replica::metrics::bench;
use replica::planner::{Objective, Planner};

fn main() {
    regimes::sexp_mean_table(100, 0.05, &[0.1, 0.5, 1.0, 2.0, 5.0, 14.0, 20.0]).print();
    println!();
    regimes::sexp_cov_table(100, 0.05, &[0.2, 0.5, 3.0, 40.0]).print();
    println!();
    regimes::pareto_table(100, 1.0, &[1.5, 2.5, 3.5, 5.0, 7.0]).print();
    println!();
    regimes::tradeoff_table(100).print();
    println!();
    // extension: the paper's open problem (concave service families)
    replica::experiments::open_problem::table(8, 2).expect("open problem").print();
    println!();

    let planner = Planner::new(100, ServiceDist::shifted_exp(0.05, 1.0));
    bench("Planner::plan mean (SExp, N=100)", 20.0, || {
        std::hint::black_box(planner.plan(Objective::MeanCompletion));
    });
    let planner_p = Planner::new(100, ServiceDist::pareto(1.0, 2.5));
    bench("Planner::plan mean (Pareto, N=100)", 20.0, || {
        std::hint::black_box(planner_p.plan(Objective::MeanCompletion));
    });
    bench("Planner::tradeoff_front (N=100)", 20.0, || {
        std::hint::black_box(planner.tradeoff_front());
    });
}
