//! Bench/regeneration: Figs. 7–8 — E[T] and CoV[T] vs B for
//! shifted-exponential service times (N=100, Δ=0.05).

use replica::experiments::fig7_8;
use replica::metrics::bench;

fn main() {
    fig7_8::table(&fig7_8::PAPER_MUS).print();
    println!();

    println!("Monte-Carlo cross-check, mu = 1.0 (8k reps per point):");
    for (b, analytic, sim, ci) in fig7_8::mc_crosscheck(1.0, 8_000, 1).expect("mc") {
        println!("  B={b:<4} analytic={analytic:.4}  simulated={sim:.4} ± {ci:.4}");
    }
    println!();

    bench("sexp closed-form sweep (N=100, all B)", 20.0, || {
        std::hint::black_box(fig7_8::sweep(100, 0.05, 1.0));
    });
}
