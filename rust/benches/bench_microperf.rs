//! Micro-benchmarks of the simulation hot path (the §Perf targets in
//! DESIGN.md): RNG, distribution sampling, single-job simulation,
//! closed forms, numeric integration.

use replica::analysis::closed_form;
use replica::batching::Policy;
use replica::dist::ServiceDist;
use replica::metrics::bench;
use replica::sim::JobSimulator;
use replica::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::new(1);

    let r = bench("Pcg64::next_u64 x1000", 20.0, || {
        let mut acc = 0u64;
        for _ in 0..1000 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        std::hint::black_box(acc);
    });
    println!("  -> {:.1} M u64/s", 1e-6 * 1000.0 * r.per_second());

    for tau in [
        ServiceDist::exp(1.0),
        ServiceDist::shifted_exp(0.05, 1.0),
        ServiceDist::pareto(1.0, 2.0),
        ServiceDist::weibull(0.7, 1.0),
    ] {
        let label = format!("{} sample x1000", tau.label());
        let r = bench(&label, 20.0, || {
            let mut acc = 0.0;
            for _ in 0..1000 {
                acc += tau.sample(&mut rng);
            }
            std::hint::black_box(acc);
        });
        println!("  -> {:.1} M samples/s", 1e-6 * 1000.0 * r.per_second());
    }

    // single-job simulation throughput across spectrum points
    for (n, b) in [(100usize, 1usize), (100, 10), (100, 100)] {
        let layout = Policy::BalancedNonOverlapping { batches: b }
            .layout(n, &mut rng)
            .unwrap();
        let sim = JobSimulator::new(layout, ServiceDist::shifted_exp(0.05, 1.0));
        let label = format!("JobSimulator::sample N={n} B={b}");
        let r = bench(&label, 30.0, || {
            std::hint::black_box(sim.sample(&mut rng));
        });
        println!(
            "  -> {:.2} M batch-services/s",
            1e-6 * n as f64 * r.per_second()
        );
    }

    bench("closed_form::sexp_mean full sweep N=100", 10.0, || {
        for b in replica::analysis::optimizer::feasible_b(100) {
            std::hint::black_box(closed_form::sexp_mean(100, b, 0.05, 1.0));
        }
    });
    bench("closed_form::pareto_cov N=100 B=10", 10.0, || {
        std::hint::black_box(closed_form::pareto_cov(100, 10, 2.5));
    });
    bench("numeric_mean_var_t N=20 B=4 (weibull)", 100.0, || {
        std::hint::black_box(closed_form::numeric_mean_var_t(
            20,
            4,
            &ServiceDist::weibull(0.7, 1.0),
        ));
    });
    bench("lgamma x1000", 10.0, || {
        let mut acc = 0.0;
        for i in 1..=1000 {
            acc += replica::util::math::lgamma(i as f64 * 0.37);
        }
        std::hint::black_box(acc);
    });
}
