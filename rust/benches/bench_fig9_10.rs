//! Bench/regeneration: Figs. 9–10 — E[T] and CoV[T] vs B for Pareto
//! service times (N=100, σ=1).

use replica::experiments::fig9_10;
use replica::metrics::bench;

fn main() {
    fig9_10::table(&fig9_10::PAPER_ALPHAS).print();
    println!();

    println!("Monte-Carlo cross-check, alpha = 3.5 (8k reps per point):");
    for (b, analytic, sim, ci) in fig9_10::mc_crosscheck(3.5, 8_000, 2).expect("mc") {
        println!("  B={b:<4} analytic={analytic:.4}  simulated={sim:.4} ± {ci:.4}");
    }
    println!();

    bench("pareto closed-form sweep (N=100, all B)", 20.0, || {
        std::hint::black_box(fig9_10::sweep(100, 1.0, 2.5));
    });
    bench("pareto_alpha_star(N=100) root find", 20.0, || {
        std::hint::black_box(replica::analysis::optimizer::pareto_alpha_star(100));
    });
}
