//! Integration: analysis ↔ simulation ↔ planner agree end-to-end,
//! all through the `eval::Estimator` API.

use replica::analysis::closed_form;
use replica::analysis::optimizer::feasible_b;
use replica::batching::Policy;
use replica::dist::ServiceDist;
use replica::eval::{Estimator, MonteCarlo, Scenario};
use replica::planner::{Objective, Planner};

/// The three closed-form families: simulation reproduces the analytic
/// E[T] curve across the whole spectrum within CI.
#[test]
fn closed_forms_match_simulation_across_spectrum() {
    let n = 20;
    let cases = vec![
        ServiceDist::exp(1.0),
        ServiceDist::shifted_exp(0.05, 1.0),
        ServiceDist::shifted_exp(0.05, 5.0),
        ServiceDist::pareto(1.0, 3.0),
    ];
    for tau in cases {
        for (op, est) in MonteCarlo::new(20_000, 9_000).sweep(n, &tau).unwrap() {
            let analytic = closed_form::mean_t(n, op.batches, &tau);
            assert!(
                (est.mean - analytic).abs() < (4.0 * est.ci95).max(0.03 * analytic),
                "{} B={}: sim {} vs analytic {analytic} (ci {})",
                tau.label(),
                op.batches,
                est.mean,
                est.ci95
            );
        }
    }
}

/// The planner's chosen B actually minimizes the simulated mean among
/// feasible points (within simulation noise).
#[test]
fn planner_choice_is_simulation_optimal() {
    let n = 20;
    for tau in [ServiceDist::shifted_exp(0.05, 1.0), ServiceDist::pareto(1.0, 2.0)] {
        let plan = Planner::new(n, tau.clone()).plan(Objective::MeanCompletion);
        let mc = MonteCarlo::new(30_000, 1);
        let planned = mc
            .evaluate(&Scenario::balanced(n, plan.batches, tau.clone()))
            .unwrap()
            .mean;
        for b in feasible_b(n) {
            let other = mc
                .evaluate_at(&Scenario::balanced(n, b, tau.clone()), 2 + b as u64)
                .unwrap()
                .mean;
            assert!(
                planned <= other * 1.05,
                "{}: planned B={} ({planned}) worse than B={b} ({other})",
                tau.label(),
                plan.batches
            );
        }
    }
}

/// Lemma 2 (majorization) holds under Monte-Carlo, not just numerically:
/// simulated E[T] respects the majorization partial order.
#[test]
fn majorization_order_holds_in_simulation() {
    use replica::analysis::majorization::{all_assignments, majorizes};
    let tau = ServiceDist::shifted_exp(0.1, 1.0);
    let (n, b) = (8usize, 2usize);
    let mc = MonteCarlo::new(40_000, 77);
    let mut results = Vec::new();
    for a in all_assignments(n, b) {
        let est = mc
            .evaluate(&Scenario::new(
                n,
                Policy::UnbalancedNonOverlapping { assignment: a.clone() },
                tau.clone(),
            ))
            .unwrap();
        results.push((a, est.mean));
    }
    for (a1, m1) in &results {
        for (a2, m2) in &results {
            if majorizes(a1, a2) && a1 != a2 {
                assert!(
                    *m1 > m2 - 0.03 * m2,
                    "{a1:?} ⪰ {a2:?} but sim means {m1} < {m2}"
                );
            }
        }
    }
}

/// Overlap comparison (§V): simulated eq. (17) ordering at several rates.
#[test]
fn overlap_ordering_eq17() {
    let rows = replica::experiments::fig6::run(&[0.5, 1.0, 3.0], 50_000, 5).unwrap();
    for r in &rows {
        assert!(r.nonoverlap < r.hybrid && r.hybrid < r.cyclic, "{r:?}");
    }
}

/// Coverage probability: analytic Lemma 1 matches the failure rate the
/// simulator observes with random assignment.
#[test]
fn lemma1_coverage_matches_simulated_failures() {
    use replica::analysis::coverage::coverage_probability;
    let (n, b) = (30usize, 10usize);
    let est = MonteCarlo::new(30_000, 3)
        .evaluate(&Scenario::new(
            n,
            Policy::RandomNonOverlapping { batches: b },
            ServiceDist::exp(1.0),
        ))
        .unwrap();
    let want_fail = 1.0 - coverage_probability(n, b);
    assert!(
        (est.failure_rate - want_fail).abs() < 0.01,
        "sim {} vs analytic {want_fail}",
        est.failure_rate
    );
}

/// Trace pipeline end-to-end: generate → save → load → analyze → plan.
#[test]
fn trace_pipeline_end_to_end() {
    use replica::planner::plan_from_samples;
    use replica::traces::{load_trace, write_trace, GeneratorConfig, JobAnalysis};
    let dir = std::env::temp_dir().join("replica_it_trace");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.csv");
    let trace = GeneratorConfig::paper_workload(100, 3).generate();
    write_trace(&path, &trace).unwrap();
    let loaded = load_trace(&path).unwrap();
    let analyses = JobAnalysis::all(&loaded);
    assert_eq!(analyses.len(), 10);
    // heavy-tail job: planner recommends real redundancy
    let heavy = analyses.iter().find(|a| a.job_id == 7).unwrap();
    let (plan, _fit) =
        plan_from_samples(100, heavy.empirical.data(), Objective::MeanCompletion);
    assert!(plan.batches < 100, "heavy job should get redundancy, got B={}", plan.batches);
    std::fs::remove_dir_all(&dir).ok();
}
