//! Integration: the estimator backends agree with each other and keep
//! their determinism contracts.

use replica::batching::Policy;
use replica::dist::ServiceDist;
use replica::eval::{substream, Analytic, Auto, Estimator, MonteCarlo, Provenance, Scenario};
use replica::sim::FailureModel;
use replica::util::rng::Pcg64;

/// Every closed-form `ServiceDist` family × every feasible B at N=20:
/// `Analytic` and `MonteCarlo` agree within 4×CI on the mean, and the
/// MC CoV lands near the analytic CoV.
#[test]
fn analytic_and_monte_carlo_agree_across_families_and_spectrum() {
    let n = 20;
    let families = vec![
        ServiceDist::exp(1.0),
        ServiceDist::shifted_exp(0.05, 1.0),
        ServiceDist::pareto(1.0, 3.0),
    ];
    for tau in families {
        let exact = Analytic.sweep(n, &tau).unwrap();
        let sampled = MonteCarlo::new(20_000, 1234).sweep(n, &tau).unwrap();
        assert_eq!(exact.len(), sampled.len());
        for ((op, a), (_, mc)) in exact.iter().zip(&sampled) {
            assert_eq!(a.provenance, Provenance::Analytic);
            assert!(
                (a.mean - mc.mean).abs() < (4.0 * mc.ci95).max(0.03 * a.mean),
                "{} B={}: analytic {} vs mc {} (ci {})",
                tau.label(),
                op.batches,
                a.mean,
                mc.mean,
                mc.ci95
            );
            // CoV needs a finite 4th moment for a stable sample-variance
            // estimator: for Pareto the batch-level tail index is Nα/B,
            // so only assert where Nα/B > 4.
            let cov_reliable = match &tau {
                ServiceDist::Pareto { alpha, .. } => {
                    (n as f64) * *alpha > 4.0 * op.batches as f64
                }
                _ => true,
            };
            if cov_reliable {
                assert!(
                    (a.cov - mc.cov).abs() < 0.15 * a.cov.max(0.05),
                    "{} B={}: analytic CoV {} vs mc {}",
                    tau.label(),
                    op.batches,
                    a.cov,
                    mc.cov
                );
            }
            // analytic percentiles bracket the MC ones within noise
            assert!(
                (a.p99 - mc.p99).abs() < 0.25 * a.p99,
                "{} B={}: analytic p99 {} vs mc {}",
                tau.label(),
                op.batches,
                a.p99,
                mc.p99
            );
        }
    }
}

/// Any `threads` fan-out produces bit-identical estimates for the same
/// seed — on plain, randomized, and failing scenarios, and through the
/// batched entry points (which now run scenario×chunk units on the
/// persistent worker pool).
#[test]
fn thread_count_never_changes_the_estimate() {
    let scenarios = vec![
        Scenario::balanced(20, 4, ServiceDist::shifted_exp(0.05, 1.0)),
        Scenario::new(
            20,
            Policy::RandomNonOverlapping { batches: 5 },
            ServiceDist::exp(1.0),
        ),
        Scenario::new(
            6,
            Policy::CyclicOverlapping { batches: 3 },
            ServiceDist::pareto(1.0, 2.5),
        ),
        Scenario::balanced(10, 2, ServiceDist::exp(1.0))
            .with_failures(FailureModel::Crash { p: 0.2 }),
    ];
    let one = MonteCarlo { reps: 6_000, seed: 99, threads: 1 };
    let serial = one.evaluate_many(&scenarios).unwrap();
    for threads in [2usize, 4, 8] {
        let mc = MonteCarlo { reps: 6_000, seed: 99, threads };
        let parallel = mc.evaluate_many(&scenarios).unwrap();
        for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
            let tag = format!("threads={threads} scenario {i}");
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "{tag}");
            assert_eq!(a.cov.to_bits(), b.cov.to_bits(), "{tag}");
            assert_eq!(a.p50.to_bits(), b.p50.to_bits(), "{tag}");
            assert_eq!(a.p95.to_bits(), b.p95.to_bits(), "{tag}");
            assert_eq!(a.p99.to_bits(), b.p99.to_bits(), "{tag}");
            assert_eq!(a.failure_rate, b.failure_rate, "{tag}");
            assert_eq!(a.completed, b.completed, "{tag}");
        }
        // batch item i must equal the evaluate_at(·, i) substream
        for (i, scenario) in scenarios.iter().enumerate() {
            let single = mc.evaluate_at(scenario, i as u64).unwrap();
            assert_eq!(
                parallel[i].mean.to_bits(),
                single.mean.to_bits(),
                "threads={threads} item {i} ordering"
            );
        }
    }
}

/// `Auto` routes exactly as documented, with the choice visible in the
/// provenance.
#[test]
fn auto_provenance_records_the_backend_choice() {
    let auto = Auto::new(2_000, 8);
    // closed-form ground: Exp/SExp/Pareto, balanced, no failures
    for tau in [
        ServiceDist::exp(1.0),
        ServiceDist::shifted_exp(0.05, 1.0),
        ServiceDist::pareto(1.0, 3.0),
    ] {
        let est = auto.evaluate(&Scenario::balanced(20, 5, tau.clone())).unwrap();
        assert_eq!(est.provenance, Provenance::Analytic, "{}", tau.label());
    }
    // empirical and bimodal service fall back to MC
    let mut rng = Pcg64::new(4);
    let base = ServiceDist::exp(1.0);
    let samples: Vec<f64> = (0..1_000).map(|_| base.sample(&mut rng)).collect();
    for tau in [
        ServiceDist::empirical(samples),
        ServiceDist::bimodal(0.1, (0.1, 10.0), (5.0, 1.0)),
    ] {
        let est = auto.evaluate(&Scenario::balanced(20, 5, tau.clone())).unwrap();
        assert!(
            matches!(est.provenance, Provenance::MonteCarlo { .. }),
            "{}",
            tau.label()
        );
    }
    // overlapping policies fall back to MC even for Exp service
    for policy in [
        Policy::CyclicOverlapping { batches: 3 },
        Policy::HybridOverlapping { batches: 3 },
        Policy::RandomNonOverlapping { batches: 3 },
    ] {
        let est =
            auto.evaluate(&Scenario::new(6, policy.clone(), ServiceDist::exp(1.0))).unwrap();
        assert!(
            matches!(est.provenance, Provenance::MonteCarlo { .. }),
            "{}",
            policy.name()
        );
    }
}

/// The zero-completed degenerate case is explicit end-to-end.
#[test]
fn all_replications_failing_is_explicit_not_accidental_nan() {
    let scenario = Scenario::balanced(10, 5, ServiceDist::exp(1.0))
        .with_failures(FailureModel::Crash { p: 1.0 });
    let est = MonteCarlo::new(300, 5).evaluate(&scenario).unwrap();
    assert!(est.all_failed());
    assert_eq!(est.replications, 300);
    assert_eq!(est.completed, 0);
    assert_eq!(est.failure_rate, 1.0);
    for (name, v) in [
        ("mean", est.mean),
        ("ci95", est.ci95),
        ("cov", est.cov),
        ("p50", est.p50),
        ("p95", est.p95),
        ("p99", est.p99),
    ] {
        assert!(v.is_nan(), "{name} should be NaN when nothing completed, got {v}");
    }
}

/// `substream` separates batch items: sweeping twice with the same seed
/// reproduces itself exactly, while different indices differ.
#[test]
fn substreams_are_stable_and_distinct() {
    let tau = ServiceDist::exp(1.0);
    let mc = MonteCarlo::new(2_000, 31);
    let a = mc.sweep(12, &tau).unwrap();
    let b = mc.sweep(12, &tau).unwrap();
    for ((_, x), (_, y)) in a.iter().zip(&b) {
        assert_eq!(x.mean.to_bits(), y.mean.to_bits());
    }
    // distinct indices → distinct streams (the scenario is identical,
    // so equal means would indicate stream reuse)
    let s = Scenario::balanced(12, 2, tau);
    let x = mc.evaluate_at(&s, 0).unwrap();
    let y = mc.evaluate_at(&s, 1).unwrap();
    assert_ne!(x.mean.to_bits(), y.mean.to_bits());
    assert_ne!(substream(31, 0), substream(31, 1));
}
