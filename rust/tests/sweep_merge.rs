//! Integration tests for the multi-process sweep path: per-shard
//! stores, the deterministic merge, and estimate-cache GC.
//!
//! The contract under test is the one CI's `sweep-shard-determinism`
//! job enforces at cluster scale: running a sweep as M shard processes
//! and merging their stores must produce a canonical store
//! **byte-identical** to a single-process run — including across
//! overlapping shardings, kills mid-shard, and resumes — while foreign
//! or incomplete shards are refused with actionable errors.

use std::path::{Path, PathBuf};

use replica::sweep::{
    merge, merge_partial, merge_shards, run, shard_path, EstimateCache, MissingRange,
    RunConfig, ScenarioSet, SweepSpec, Workload,
};

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("replica_sweep_merge_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spec(seed: u64) -> SweepSpec {
    let mut spec = SweepSpec::for_trace();
    spec.workload = Some(Workload::Generate { jobs: 3, tasks_per_job: 12, seed: 7 });
    spec.reps = 150;
    spec.seed = seed;
    spec.shard_size = 4;
    spec
}

fn expand(spec: &SweepSpec) -> ScenarioSet {
    ScenarioSet::from_trace(&spec.load_trace().unwrap(), spec).unwrap()
}

/// Single-process reference run into `dir/single.jsonl`.
fn reference_store(set: &ScenarioSet, dir: &Path) -> String {
    let out = dir.join("single.jsonl");
    let cfg = RunConfig { shard_size: 4, ..RunConfig::persisted(out.clone()) };
    let results = run(set, &cfg).unwrap();
    assert_eq!(results.len(), set.len());
    std::fs::read_to_string(&out).unwrap()
}

/// Run shard `k` of `m` to completion against canonical path `out`.
fn run_shard(set: &ScenarioSet, out: &Path, k: usize, m: usize) {
    let cfg = RunConfig { shard_size: 4, ..RunConfig::sharded(out.to_path_buf(), k, m) };
    let results = run(set, &cfg).unwrap();
    assert_eq!(results.len(), set.shard(k, m).unwrap().len());
}

#[test]
fn sharded_run_merges_byte_identical_to_single_process() {
    let spec = spec(5);
    let set = expand(&spec);
    assert_eq!(set.len(), 18); // 3 jobs x 6 divisors of 12

    let dir = test_dir("identical");
    let reference = reference_store(&set, &dir);

    let out = dir.join("merged.jsonl");
    for k in 0..3 {
        run_shard(&set, &out, k, 3);
        assert!(shard_path(&out, k, 3).exists());
    }
    let (report, outcomes) = merge_shards(&set, &out, 3).unwrap();
    assert_eq!((report.shards, report.cases, report.duplicates), (3, 18, 0));
    assert_eq!(outcomes.len(), 18, "merge returns every outcome in grid order");
    assert_eq!(
        std::fs::read_to_string(&out).unwrap(),
        reference,
        "merged multi-process store must be byte-identical to the single-process run"
    );

    // merging again over the same shard files is idempotent
    merge_shards(&set, &out, 3).unwrap();
    assert_eq!(std::fs::read_to_string(&out).unwrap(), reference);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn overlapping_shardings_merge_cleanly() {
    let spec = spec(8);
    let set = expand(&spec);
    let dir = test_dir("overlap");
    let reference = reference_store(&set, &dir);

    let out = dir.join("merged.jsonl");
    // a 2-way sharding plus a 1-way (whole-grid) shard: every case is
    // covered at least twice, with shard boundaries that disagree
    run_shard(&set, &out, 0, 2);
    run_shard(&set, &out, 1, 2);
    run_shard(&set, &out, 0, 1);
    let files = vec![
        shard_path(&out, 0, 2),
        shard_path(&out, 1, 2),
        shard_path(&out, 0, 1),
    ];
    let (report, _) = merge(&set, &files, &out).unwrap();
    assert_eq!(report.shards, 3);
    assert_eq!(report.duplicates, set.len(), "every case seen twice");
    assert_eq!(std::fs::read_to_string(&out).unwrap(), reference);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_and_incomplete_shards_are_refused_with_context() {
    let spec = spec(11);
    let set = expand(&spec);
    let dir = test_dir("missing");
    let out = dir.join("merged.jsonl");

    // only shard 0 of 2 ran: shard 1's file does not exist
    run_shard(&set, &out, 0, 2);
    let err = merge_shards(&set, &out, 2).unwrap_err();
    assert!(err.to_string().contains("cannot read shard file"), "{err}");

    // shard 1 ran but was stopped after one engine shard (4 of 9 cases)
    let partial = RunConfig {
        shard_size: 4,
        limit_shards: Some(1),
        ..RunConfig::sharded(out.clone(), 1, 2)
    };
    let results = run(&set, &partial).unwrap();
    assert_eq!(results.len(), 4);
    let err = merge_shards(&set, &out, 2).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("missing 5 of 18 cases"), "{msg}");
    assert!(msg.contains("re-merge"), "{msg}");

    // resuming shard 1 to completion fixes the merge
    run_shard(&set, &out, 1, 2);
    let (report, _) = merge_shards(&set, &out, 2).unwrap();
    assert_eq!(report.cases, 18);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn foreign_shards_are_refused_at_open_and_at_merge() {
    let spec_a = spec(5);
    let spec_b = spec(6); // different seed => every key differs
    let set_a = expand(&spec_a);
    let set_b = expand(&spec_b);
    let dir = test_dir("foreign");
    let out = dir.join("merged.jsonl");

    run_shard(&set_a, &out, 0, 1);

    // the merge refuses a shard whose header names another sweep
    let files = vec![shard_path(&out, 0, 1)];
    let err = merge(&set_b, &files, &out).unwrap_err();
    assert!(err.to_string().contains("different sweep"), "{err}");

    // a shard *run* against the existing file of another sweep is
    // refused too (never truncated)
    let before = std::fs::read_to_string(shard_path(&out, 0, 1)).unwrap();
    let cfg = RunConfig { shard_size: 4, ..RunConfig::sharded(out.clone(), 0, 1) };
    let err = run(&set_b, &cfg).unwrap_err();
    assert!(err.to_string().contains("refusing to overwrite"), "{err}");
    assert_eq!(std::fs::read_to_string(shard_path(&out, 0, 1)).unwrap(), before);

    // a canonical (headerless) store is not a shard file
    let single = dir.join("single.jsonl");
    run(&set_a, &RunConfig { shard_size: 4, ..RunConfig::persisted(single.clone()) }).unwrap();
    let err = merge(&set_a, &[single], &out).unwrap_err();
    assert!(err.to_string().contains("not a shard store"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn partial_merge_writes_prefix_and_names_missing_ranges() {
    let spec = spec(21);
    let set = expand(&spec);
    let dir = test_dir("partial");
    let reference = reference_store(&set, &dir);
    let out = dir.join("merged.jsonl");

    // only shards 0 and 2 of a 4-way sharding ran: coverage has two
    // holes (shard 1's slice and shard 3's slice)
    run_shard(&set, &out, 0, 4);
    run_shard(&set, &out, 2, 4);
    let lens: Vec<usize> = (0..4).map(|k| set.shard(k, 4).unwrap().len()).collect();
    let starts: Vec<usize> = (0..4).map(|k| lens[..k].iter().sum()).collect();

    // the strict merge refuses and points at --allow-partial
    let files = vec![shard_path(&out, 0, 4), shard_path(&out, 2, 4)];
    let err = merge(&set, &files, &out).unwrap_err();
    assert!(err.to_string().contains("--allow-partial"), "{err}");

    let report = merge_partial(&set, &files, &out).unwrap();
    assert_eq!(report.cases, set.len());
    assert_eq!(report.merged, lens[0], "prefix = shard 0's contiguous slice");
    assert_eq!(report.covered, lens[0] + lens[2]);
    assert_eq!(
        report.missing,
        vec![
            MissingRange {
                lo: starts[1],
                hi: starts[2],
                first_key: set.cases[starts[1]].key
            },
            MissingRange {
                lo: starts[3],
                hi: set.len(),
                first_key: set.cases[starts[3]].key
            },
        ]
    );

    // the written prefix is exactly the reference's first lines — a
    // valid store the single-process engine resumes from
    let written = std::fs::read_to_string(&out).unwrap();
    assert_eq!(written.lines().count(), lens[0]);
    assert!(reference.starts_with(&written), "partial store must be a reference prefix");
    let resume = RunConfig { shard_size: 4, ..RunConfig::persisted(out.clone()) };
    run(&set, &resume).unwrap();
    assert_eq!(std::fs::read_to_string(&out).unwrap(), reference);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn partial_merge_of_complete_shards_equals_strict_merge() {
    let spec = spec(22);
    let set = expand(&spec);
    let dir = test_dir("partial_complete");
    let reference = reference_store(&set, &dir);
    let out = dir.join("merged.jsonl");
    for k in 0..2 {
        run_shard(&set, &out, k, 2);
    }
    let files = vec![shard_path(&out, 0, 2), shard_path(&out, 1, 2)];
    let report = merge_partial(&set, &files, &out).unwrap();
    assert_eq!(report.merged, set.len());
    assert_eq!(report.covered, set.len());
    assert!(report.missing.is_empty());
    assert_eq!(std::fs::read_to_string(&out).unwrap(), reference);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn killed_mid_shard_resume_keeps_merge_byte_identical() {
    let spec = spec(9);
    let set = expand(&spec);
    let dir = test_dir("kill_resume");
    let reference = reference_store(&set, &dir);

    let out = dir.join("merged.jsonl");
    run_shard(&set, &out, 1, 2);
    run_shard(&set, &out, 0, 2);
    let shard0 = shard_path(&out, 0, 2);
    let full = std::fs::read(&shard0).unwrap();

    // "kill" shard 0 at arbitrary bytes — inside the header line, at a
    // record boundary, mid-record, one byte short — then resume it and
    // re-merge; the canonical store never changes
    let offsets =
        [0usize, 3, full.len() / 4, full.len() / 2, full.len() - 1];
    for &cut in &offsets {
        std::fs::write(&shard0, &full[..cut]).unwrap();
        run_shard(&set, &out, 0, 2); // resume
        assert_eq!(
            std::fs::read(&shard0).unwrap(),
            full,
            "cut at byte {cut}: resumed shard store diverged"
        );
        let (report, _) = merge_shards(&set, &out, 2).unwrap();
        assert_eq!(report.cases, set.len());
        assert_eq!(
            std::fs::read_to_string(&out).unwrap(),
            reference,
            "cut at byte {cut}: merged store diverged from the single-process run"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shard_cache_gc_drops_only_dead_keys() {
    let wide = spec(13);
    let set_wide = expand(&wide);
    let dir = test_dir("cache_gc");
    let out = dir.join("results.jsonl");

    // one persisted run fills the cache with the wide grid
    let cfg = RunConfig { shard_size: 4, ..RunConfig::persisted(out.clone()) };
    run(&set_wide, &cfg).unwrap();
    let cache_path = cfg.cache.clone().unwrap();
    let before = std::fs::read_to_string(&cache_path).unwrap().lines().count();
    assert_eq!(before, 18);

    // the spec narrows to one job: two thirds of the cache is dead
    let mut narrow = wide.clone();
    narrow.jobs = Some(vec![2]);
    let set_narrow = expand(&narrow);
    let live: std::collections::BTreeSet<u64> =
        set_narrow.expected_keys().into_iter().collect();
    let mut cache = EstimateCache::open(&cache_path).unwrap();
    let stats = cache.gc(&live).unwrap();
    drop(cache);
    assert_eq!((stats.live, stats.dead), (6, 12));
    assert!(stats.reclaimed_bytes > 0);
    assert_eq!(std::fs::read_to_string(&cache_path).unwrap().lines().count(), 6);

    // the surviving entries still serve the narrow sweep: a re-run is
    // pure cache hits (no new cache lines) and matches the wide run's
    // records for job 2 bit-for-bit
    let narrow_cfg = RunConfig {
        out: Some(dir.join("narrow.jsonl")),
        cache: Some(cache_path.clone()),
        shard_size: 4,
        ..RunConfig::default()
    };
    let narrow_results = run(&set_narrow, &narrow_cfg).unwrap();
    assert_eq!(narrow_results.len(), 6);
    assert_eq!(std::fs::read_to_string(&cache_path).unwrap().lines().count(), 6);
    std::fs::remove_dir_all(&dir).ok();
}
