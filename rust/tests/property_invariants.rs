//! Property-based tests over coordinator/batching/analysis invariants
//! (randomized via the in-house `forall` driver — DESIGN.md
//! §Substitutions).

use replica::analysis::closed_form;
use replica::analysis::majorization::{balanced, majorizes};
use replica::batching::Policy;
use replica::dist::ServiceDist;
use replica::sim::{JobOutcome, JobSimulator};
use replica::util::proptest::forall;
use replica::util::rng::Pcg64;

fn random_dist(rng: &mut Pcg64) -> ServiceDist {
    match rng.below(4) {
        0 => ServiceDist::exp(0.1 + 5.0 * rng.uniform()),
        1 => ServiceDist::shifted_exp(rng.uniform(), 0.1 + 5.0 * rng.uniform()),
        2 => ServiceDist::pareto(0.1 + rng.uniform(), 1.1 + 3.0 * rng.uniform()),
        _ => ServiceDist::weibull(0.4 + rng.uniform(), 0.5 + rng.uniform()),
    }
}

fn random_feasible(rng: &mut Pcg64) -> (usize, usize) {
    let b = *rng.choose(&[1usize, 2, 3, 4, 6]);
    let n = b * rng.range(1, 5);
    (n, b)
}

#[test]
fn layouts_always_validate_and_cover() {
    forall("layout validity", 200, |rng| {
        let (n, b) = random_feasible(rng);
        let policies = vec![
            Policy::BalancedNonOverlapping { batches: b },
            Policy::CyclicOverlapping { batches: b },
        ];
        for p in policies {
            let layout = p.layout(n, rng).unwrap();
            layout.validate().unwrap();
            assert!(layout.covers_all_tasks(), "{} N={n} B={b}", p.name());
            // every worker executes exactly N/B tasks
            assert!(layout.worker_tasks.iter().all(|t| t.len() == n / b));
        }
    });
}

#[test]
fn job_time_is_positive_and_bounded_by_slowest_worker() {
    forall("job time bounds", 150, |rng| {
        let (n, b) = random_feasible(rng);
        let tau = random_dist(rng);
        let layout = Policy::BalancedNonOverlapping { batches: b }.layout(n, rng).unwrap();
        let sim = JobSimulator::new(layout, tau);
        match sim.sample(rng) {
            JobOutcome::Done(t) => assert!(t > 0.0 && t.is_finite()),
            JobOutcome::Failed => panic!("no-failure sim cannot fail"),
        }
    });
}

#[test]
fn more_replication_never_hurts_stochastically() {
    // E[T] under B=1 (max diversity) ≤ E[T] under B=N for Exp service
    // (Theorem 3), regardless of rate.
    forall("replication helps exp", 20, |rng| {
        let mu = 0.2 + 5.0 * rng.uniform();
        let m1 = closed_form::exp_mean(1, mu);
        let mn = closed_form::exp_mean(64, mu);
        assert!(m1 < mn);
    });
}

#[test]
fn balanced_is_majorized_by_random_assignments() {
    forall("balanced majorized", 200, |rng| {
        let b = rng.range(2, 5);
        let r = rng.range(1, 5);
        let n = b * r;
        // random composition of n into b positive parts
        let mut parts = vec![1usize; b];
        for _ in 0..(n - b) {
            parts[rng.range(0, b)] += 1;
        }
        assert!(majorizes(&parts, &balanced(n, b)), "{parts:?}");
    });
}

#[test]
fn closed_form_mean_is_positive_and_finite_when_it_should_be() {
    forall("closed forms finite", 200, |rng| {
        let (n, b) = random_feasible(rng);
        let tau = random_dist(rng);
        let m = closed_form::mean_t(n, b, &tau);
        // Pareto with B/(Nα) ≥ 1 is legitimately infinite; everything
        // else must be finite and positive.
        if let ServiceDist::Pareto { alpha, .. } = tau {
            if (b as f64) / (n as f64 * alpha) >= 1.0 {
                assert!(m.is_infinite());
                return;
            }
        }
        assert!(m.is_finite() && m > 0.0, "{} N={n} B={b}: {m}", tau.label());
    });
}

#[test]
fn quantile_cdf_inverse_property() {
    forall("quantile inverse", 150, |rng| {
        let tau = random_dist(rng);
        let p = 0.02 + 0.96 * rng.uniform();
        let t = tau.quantile(p);
        let back = tau.cdf(t);
        assert!((back - p).abs() < 1e-6, "{}: p={p} t={t} back={back}", tau.label());
    });
}

#[test]
fn min_of_closure_agrees_with_ccdf_power() {
    // S_min(t) = S(t)^k for families closed under minima
    forall("min closure", 150, |rng| {
        let tau = random_dist(rng);
        let k = rng.range(2, 6);
        if let Some(min_dist) = tau.min_of(k) {
            let t = tau.quantile(0.3 + 0.5 * rng.uniform());
            let want = tau.ccdf(t).powi(k as i32);
            let got = min_dist.ccdf(t);
            assert!((got - want).abs() < 1e-9, "{} k={k}: {got} vs {want}", tau.label());
        }
    });
}

#[test]
fn simulator_seed_determinism() {
    forall("sim determinism", 50, |rng| {
        let (n, b) = random_feasible(rng);
        let tau = random_dist(rng);
        let seed = rng.next_u64();
        let layout = Policy::BalancedNonOverlapping { batches: b }
            .layout(n, &mut Pcg64::new(seed))
            .unwrap();
        let sim = JobSimulator::new(layout, tau);
        let a = sim.sample(&mut Pcg64::new(seed)).time();
        let b2 = sim.sample(&mut Pcg64::new(seed)).time();
        assert_eq!(a, b2);
    });
}
