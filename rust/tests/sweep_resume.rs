//! Integration tests for the sweep engine's resumability contract:
//! killing a run at *any* byte (simulated by truncating the JSONL
//! store mid-line) and resuming must produce output byte-identical to
//! an uninterrupted run, with cache hits never re-evaluated and
//! degraded scenarios (all replications failed) surfaced per record.

use std::path::{Path, PathBuf};

use replica::sweep::{
    gain_report, run, CaseOutcome, RunConfig, ScenarioSet, SweepSpec, Workload,
};
use replica::traces::{GeneratorConfig, Trace};
use replica::util::json;

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("replica_sweep_resume_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spec(seed: u64) -> SweepSpec {
    let mut spec = SweepSpec::for_trace();
    spec.workload = Some(Workload::Generate { jobs: 3, tasks_per_job: 12, seed: 7 });
    spec.reps = 200;
    spec.seed = seed;
    spec.shard_size = 4;
    spec
}

fn trace_for(spec: &SweepSpec) -> Trace {
    spec.load_trace().unwrap()
}

fn cfg(dir: &Path) -> RunConfig {
    RunConfig {
        out: Some(dir.join("results.jsonl")),
        cache: Some(dir.join("cache.jsonl")),
        shard_size: 4,
        ..RunConfig::default()
    }
}

fn run_to_completion(set: &ScenarioSet, dir: &Path) -> String {
    let results = run(set, &cfg(dir)).unwrap();
    assert_eq!(results.len(), set.len());
    std::fs::read_to_string(dir.join("results.jsonl")).unwrap()
}

#[test]
fn truncate_anywhere_then_resume_is_byte_identical() {
    let spec = spec(5);
    let trace = trace_for(&spec);
    let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
    assert_eq!(set.len(), 18); // 3 jobs x 6 divisors of 12

    let ref_dir = test_dir("reference");
    let reference = run_to_completion(&set, &ref_dir);
    assert_eq!(reference.lines().count(), 18);

    let dir = test_dir("truncate");
    let results_path = dir.join("results.jsonl");
    let full = run_to_completion(&set, &dir);
    assert_eq!(full, reference, "two fresh runs must already agree");

    // "kill" the run at arbitrary byte offsets — line boundaries,
    // mid-line, byte zero, one byte short of complete — then resume
    let bytes = reference.as_bytes();
    let first_newline = reference.find('\n').unwrap() + 1;
    let offsets = [
        0usize,
        1,
        first_newline,
        bytes.len() / 3,
        bytes.len() / 2,
        bytes.len() - 1,
    ];
    for (round, &cut) in offsets.iter().enumerate() {
        std::fs::write(&results_path, &bytes[..cut]).unwrap();
        if round % 2 == 1 {
            // every other round, corrupt the cache tail too: resume
            // must recompute what the cache lost and still match
            let cache_path = dir.join("cache.jsonl");
            let cache = std::fs::read(&cache_path).unwrap();
            std::fs::write(&cache_path, &cache[..cache.len() * 2 / 3]).unwrap();
        }
        let resumed = run_to_completion(&set, &dir);
        assert_eq!(
            resumed, reference,
            "resume after truncation at byte {cut} diverged from the uninterrupted run"
        );
    }

    // nuking the cache entirely forces full recomputation — output is
    // still byte-identical because estimates depend only on content
    std::fs::write(&results_path, &bytes[..bytes.len() / 4]).unwrap();
    std::fs::remove_file(dir.join("cache.jsonl")).unwrap();
    let resumed = run_to_completion(&set, &dir);
    assert_eq!(resumed, reference);

    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shard_budgeted_kill_then_resume_is_byte_identical() {
    let spec = spec(9);
    let trace = trace_for(&spec);
    let set = ScenarioSet::from_trace(&trace, &spec).unwrap();

    let ref_dir = test_dir("budget_reference");
    let reference = run_to_completion(&set, &ref_dir);

    // stop after one shard (a clean mid-run exit rather than a kill)
    let dir = test_dir("budget");
    let mut budgeted = cfg(&dir);
    budgeted.limit_shards = Some(1);
    let partial = run(&set, &budgeted).unwrap();
    assert_eq!(partial.len(), 4);
    let partial_text = std::fs::read_to_string(dir.join("results.jsonl")).unwrap();
    assert_eq!(partial_text.lines().count(), 4);
    assert!(reference.starts_with(&partial_text), "partial output must be a prefix");

    // second invocation resumes the remaining shards
    let resumed = run_to_completion(&set, &dir);
    assert_eq!(resumed, reference);

    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn widened_spec_reuses_the_cache_incrementally() {
    let trace = GeneratorConfig::paper_workload(12, 3).generate();
    let dir = test_dir("widen");

    let mut narrow = spec(5);
    narrow.workload = None;
    narrow.jobs = Some(vec![1]);
    let narrow_set = ScenarioSet::from_trace(&trace, &narrow).unwrap();
    let mut narrow_cfg = cfg(&dir);
    narrow_cfg.out = Some(dir.join("narrow.jsonl"));
    let narrow_results = run(&narrow_set, &narrow_cfg).unwrap();
    let cache_lines = std::fs::read_to_string(dir.join("cache.jsonl")).unwrap();
    assert_eq!(cache_lines.lines().count(), 6);

    // widen to two jobs, same cache: job 1's scenarios are cache hits
    let mut wide = narrow.clone();
    wide.jobs = Some(vec![1, 2]);
    let wide_set = ScenarioSet::from_trace(&trace, &wide).unwrap();
    let mut wide_cfg = cfg(&dir);
    wide_cfg.out = Some(dir.join("wide.jsonl"));
    let wide_results = run(&wide_set, &wide_cfg).unwrap();
    let cache_lines = std::fs::read_to_string(dir.join("cache.jsonl")).unwrap();
    assert_eq!(cache_lines.lines().count(), 12, "only job 2's 6 scenarios were fresh");

    // the shared scenarios' estimates are bitwise equal across runs
    for (a, b) in narrow_results.iter().zip(&wide_results) {
        assert_eq!(a.case.key, b.case.key);
        let (CaseOutcome::Ok(a), CaseOutcome::Ok(b)) = (&a.outcome, &b.outcome) else {
            panic!("unexpected error outcome");
        };
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.p99.to_bits(), b.p99.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn all_failed_scenarios_surface_per_record_not_per_shard() {
    // crash axis {0, 1}: the p=1 scenarios have zero completed
    // replications; they must land in the store as parseable records
    // flagged all_failed while their shard-mates stay healthy
    let mut spec = spec(11);
    spec.jobs = Some(vec![1]);
    spec.crash = vec![0.0, 1.0];
    let trace = trace_for(&spec);
    let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
    assert_eq!(set.len(), 12); // 6 divisors x 2 crash levels

    let dir = test_dir("all_failed");
    let reference = run_to_completion(&set, &dir);

    let mut healthy = 0;
    let mut failed = 0;
    for line in reference.lines() {
        let doc = json::parse(line).expect("every record line must stay parseable JSON");
        let crash = doc.get("crash").unwrap().as_f64().unwrap();
        let all_failed = doc.get("all_failed").unwrap().as_bool().unwrap();
        if crash == 1.0 {
            assert!(all_failed, "{line}");
            assert_eq!(doc.get("mean").unwrap(), &json::Json::Null, "{line}");
            assert_eq!(doc.get("failure_rate").unwrap().as_f64(), Some(1.0));
            assert_eq!(doc.get("completed").unwrap().as_usize(), Some(0));
            failed += 1;
        } else {
            assert!(!all_failed, "{line}");
            assert!(doc.get("mean").unwrap().as_f64().unwrap().is_finite());
            healthy += 1;
        }
    }
    assert_eq!((healthy, failed), (6, 6));

    // the degenerate records don't break resume byte-identity either
    let bytes = reference.as_bytes();
    std::fs::write(dir.join("results.jsonl"), &bytes[..bytes.len() * 2 / 5]).unwrap();
    let resumed = run_to_completion(&set, &dir);
    assert_eq!(resumed, reference);

    // and the gain report skips them instead of crashing
    let results = run(&set, &cfg(&dir)).unwrap();
    let rows = gain_report(&results, Some(&trace), replica::planner::Objective::MeanCompletion);
    assert_eq!(rows.len(), 2);
    let failed_row = rows.iter().find(|r| r.crash == 1.0).unwrap();
    assert_eq!(failed_row.all_failed_points, 6);
    assert!(failed_row.optimum.is_none());

    std::fs::remove_dir_all(&dir).ok();
}
