//! Integration tests for the fault-tolerant sweep cluster: a real
//! coordinator and real workers over loopback TCP, in one process.
//!
//! The contract under test is the headline invariant CI's
//! `cluster-chaos` job enforces with OS processes and SIGKILL: however
//! the grid is leased, reassigned, or resumed, the assembled store is
//! **byte-identical** to a single-process `replica sweep` run.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::thread;

use replica::cluster::{serve, work, ServeOptions, WorkOptions};
use replica::config::ClusterConfig;
use replica::sweep::{run, RunConfig, ScenarioSet, SweepSpec};
use replica::util::clock::MonotonicClock;

const SPEC: &str = r#"{"workload": {"generate": {"jobs": 2, "tasks_per_job": 12, "seed": 3}},
    "reps": 100, "seed": 1, "shard_size": 4}"#;

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("replica_cluster_runtime_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Timing tuned for tests: short leases, fast polls, a linger long
/// enough that no worker's final request can miss the coordinator.
fn quick_cfg() -> ClusterConfig {
    ClusterConfig {
        lease_timeout_ms: 4_000,
        heartbeat_ms: 500,
        poll_ms: 25,
        min_lease: 1,
        max_lease: 4,
        chunk: 2,
        reconnect_base_ms: 50,
        reconnect_max_ms: 200,
        max_reconnects: 40,
        linger_ms: 600,
    }
}

/// Reserve a loopback address that is free right now.
fn free_addr() -> String {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    drop(listener);
    addr.to_string()
}

/// Single-process reference store for [`SPEC`].
fn reference_store(dir: &Path) -> String {
    let spec = SweepSpec::from_json(SPEC).unwrap();
    let set = ScenarioSet::from_trace(&spec.load_trace().unwrap(), &spec).unwrap();
    assert_eq!(set.len(), 12);
    let out = dir.join("single.jsonl");
    let cfg = RunConfig { shard_size: 4, ..RunConfig::persisted(out.clone()) };
    run(&set, &cfg).unwrap();
    std::fs::read_to_string(&out).unwrap()
}

fn serve_opts(out: &Path, listen: &str) -> ServeOptions {
    ServeOptions {
        spec_text: SPEC.to_string(),
        reps_override: None,
        seed_override: None,
        out: out.to_path_buf(),
        listen: listen.to_string(),
        cfg: quick_cfg(),
    }
}

fn work_opts(connect: &str, worker: &str) -> WorkOptions {
    WorkOptions {
        connect: connect.to_string(),
        worker: worker.to_string(),
        threads: 1,
        cfg: quick_cfg(),
    }
}

#[test]
fn cluster_sweep_is_byte_identical_to_single_process() {
    let dir = test_dir("identity");
    let reference = reference_store(&dir);

    let out = dir.join("cluster.jsonl");
    let addr = free_addr();
    let opts = serve_opts(&out, &addr);
    let server = thread::spawn(move || serve(&opts, Arc::new(MonotonicClock::new())));
    let workers: Vec<_> = (0..3)
        .map(|i| {
            let opts = work_opts(&addr, &format!("w{i}"));
            thread::spawn(move || work(&opts, &MonotonicClock::new()))
        })
        .collect();

    let mut delivered = 0usize;
    for w in workers {
        let report = w.join().unwrap().unwrap();
        delivered += report.cases;
    }
    let report = server.join().unwrap().unwrap();
    assert_eq!(report.cases, 12);
    assert_eq!(report.resumed, 0);
    assert!(report.workers >= 1, "at least one worker must have held a lease");
    assert!(delivered >= 12, "every case was delivered at least once");

    assert_eq!(
        std::fs::read_to_string(&out).unwrap(),
        reference,
        "cluster-assembled store must be byte-identical to a single-process run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn restarted_coordinator_resumes_from_store_prefix() {
    let dir = test_dir("resume_prefix");
    let reference = reference_store(&dir);

    // simulate a coordinator killed after 4 cases: its store holds a
    // valid 4-record prefix and no cache survives
    let out = dir.join("cluster.jsonl");
    let prefix: String =
        reference.lines().take(4).map(|l| format!("{l}\n")).collect();
    std::fs::write(&out, &prefix).unwrap();

    let addr = free_addr();
    let opts = serve_opts(&out, &addr);
    let server = thread::spawn(move || serve(&opts, Arc::new(MonotonicClock::new())));
    let workers: Vec<_> = (0..2)
        .map(|i| {
            let opts = work_opts(&addr, &format!("w{i}"));
            thread::spawn(move || work(&opts, &MonotonicClock::new()))
        })
        .collect();
    let mut delivered = 0usize;
    for w in workers {
        delivered += w.join().unwrap().unwrap().cases;
    }
    let report = server.join().unwrap().unwrap();
    assert_eq!(report.resumed, 4, "the store prefix must be adopted, not recomputed");
    assert!(delivered >= 8, "only the uncovered 8 cases needed work");
    assert_eq!(std::fs::read_to_string(&out).unwrap(), reference);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn restarted_coordinator_resumes_from_cache_without_workers() {
    let dir = test_dir("resume_cache");

    // a full cluster run leaves store + cache; "kill" the coordinator
    // by truncating the store to nothing while the cache survives
    let spec = SweepSpec::from_json(SPEC).unwrap();
    let set = ScenarioSet::from_trace(&spec.load_trace().unwrap(), &spec).unwrap();
    let out = dir.join("cluster.jsonl");
    let cfg = RunConfig { shard_size: 4, ..RunConfig::persisted(out.clone()) };
    run(&set, &cfg).unwrap();
    let reference = std::fs::read_to_string(&out).unwrap();
    std::fs::write(&out, "").unwrap();

    // the restarted serve needs no workers at all: coverage is rebuilt
    // from the content-keyed cache and the store re-extended from it
    let addr = free_addr();
    let report =
        serve(&serve_opts(&out, &addr), Arc::new(MonotonicClock::new())).unwrap();
    assert_eq!(report.resumed, 12);
    assert_eq!(report.workers, 0);
    assert_eq!(std::fs::read_to_string(&out).unwrap(), reference);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn worker_gives_up_after_bounded_reconnects() {
    // nothing listens here: the worker must back off, retry its bounded
    // number of attempts, and fail with a clear error — never spin
    let addr = free_addr();
    let mut opts = work_opts(&addr, "w-orphan");
    opts.cfg.max_reconnects = 2;
    let err = work(&opts, &MonotonicClock::new()).unwrap_err();
    assert!(err.to_string().contains("gave up"), "{err}");
}
