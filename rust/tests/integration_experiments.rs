//! Integration: every paper-figure experiment regenerates with the
//! paper's qualitative shape (who wins, where the optimum sits).

use replica::experiments::*;

#[test]
fn fig3_table_and_series() {
    let t = fig3::table(&fig3::PAPER_NS);
    assert_eq!(t.n_rows(), 4);
    let series = fig3::run(&fig3::PAPER_NS);
    // larger N covers more batches at 99%: the table's reading
    let covered_99: Vec<usize> = fig3::PAPER_NS
        .iter()
        .map(|&n| {
            (1..=n)
                .rev()
                .find(|&b| replica::analysis::coverage::coverage_probability(n, b) >= 0.99)
                .unwrap_or(0)
        })
        .collect();
    assert!(covered_99.windows(2).all(|w| w[0] <= w[1]), "{covered_99:?}");
    assert_eq!(series.len(), 4);
}

#[test]
fn fig7_8_reproduce_regime_structure() {
    // minima per μ (Fig. 7): 0.1 → B=1; 15 → B=100
    let m01 = fig7_8::sweep(100, 0.05, 0.1);
    let best01 = m01.iter().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap().0;
    assert_eq!(best01, 1);
    let m15 = fig7_8::sweep(100, 0.05, 15.0);
    let best15 = m15.iter().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap().0;
    assert_eq!(best15, 100);
}

#[test]
fn fig9_10_reproduce_regime_structure() {
    // α = 1.5 interior optimum; α = 7 (> α* ≈ 4.7) full parallelism
    let s15 = fig9_10::sweep(100, 1.0, 1.5);
    let b15 = s15.iter().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap().0;
    assert!(b15 > 1 && b15 < 100, "B*={b15}");
    let s7 = fig9_10::sweep(100, 1.0, 7.0);
    let b7 = s7.iter().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap().0;
    assert_eq!(b7, 100);
    // Fig. 10: CoV argmin at B=1 for all α > 2
    let c = fig9_10::sweep(100, 1.0, 3.5);
    let bc = c.iter().min_by(|a, b| a.2.partial_cmp(&b.2).unwrap()).unwrap().0;
    assert_eq!(bc, 1);
}

#[test]
fn regime_tables_render() {
    let t = regimes::sexp_mean_table(100, 0.05, &[0.1, 1.0, 15.0]);
    assert!(t.render().contains("middle"));
    let t = regimes::pareto_table(100, 1.0, &[1.5, 7.0]);
    assert!(t.render().contains("full-parallelism"));
    let t = regimes::tradeoff_table(100);
    assert!(t.render().contains("YES"));
}

#[test]
fn traces_experiment_full_pipeline() {
    let trace = traces_exp::standard_trace(42);
    // Fig 11
    assert_eq!(traces_exp::fig11_series(&trace).len(), 10);
    // Fig 12/13 tables build and carry a speedup row
    let t12 = traces_exp::table("fig12", &trace, &traces_exp::EXP_TAIL_JOBS, 2_000, 1).unwrap();
    let t13 =
        traces_exp::table("fig13", &trace, &traces_exp::HEAVY_TAIL_JOBS, 2_000, 1).unwrap();
    assert!(t12.render().contains("speedup"));
    assert!(t13.render().contains("speedup"));
    // headline speedup from heavy-tail jobs
    let s = traces_exp::headline_speedup(&trace, 3_000, 2).unwrap();
    assert!(s > 3.0, "headline {s}");
}

#[test]
fn exported_csvs_parse_back() {
    use replica::metrics::export_csv;
    let dir = std::env::temp_dir().join("replica_it_export");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("fig3.csv");
    export_csv(&p, &fig3::run(&[20, 50])).unwrap();
    let t = replica::util::csv::Table::read_from(&p).unwrap();
    assert_eq!(t.header[0], "series");
    assert!(t.rows.len() >= 70);
    std::fs::remove_dir_all(&dir).ok();
}
