//! Property coverage for the `dist` subsystem: analytic moments vs
//! large-sample Monte-Carlo for every family, quantile/CDF round trips,
//! exact Empirical order statistics, and tail classification.

use replica::dist::{Empirical, ServiceDist, TailClass, TailFit};
use replica::util::proptest::forall;
use replica::util::rng::Pcg64;

fn mc_moments(d: &ServiceDist, n: usize, seed: u64) -> (f64, f64) {
    let mut rng = Pcg64::new(seed);
    let (mut s, mut s2) = (0.0, 0.0);
    for _ in 0..n {
        let x = d.sample(&mut rng);
        s += x;
        s2 += x * x;
    }
    let mean = s / n as f64;
    (mean, s2 / n as f64 - mean * mean)
}

/// Every family with finite variance: analytic `mean()`/`variance()`
/// agree with 200k-sample Monte-Carlo estimates within CLT tolerance.
#[test]
fn analytic_moments_match_monte_carlo_for_every_family() {
    let empirical_data: Vec<f64> = {
        let d = ServiceDist::shifted_exp(1.0, 2.0);
        let mut rng = Pcg64::new(17);
        (0..5_000).map(|_| d.sample(&mut rng)).collect()
    };
    let families = vec![
        ServiceDist::exp(1.3),
        ServiceDist::shifted_exp(0.5, 2.0),
        // alpha = 6: finite fourth moment, so the sample variance is stable
        ServiceDist::pareto(1.0, 6.0),
        ServiceDist::weibull(1.7, 2.0),
        ServiceDist::weibull(0.7, 1.0),
        ServiceDist::gamma_dist(2.5, 0.8),
        ServiceDist::gamma_dist(0.7, 1.5),
        ServiceDist::bimodal(0.2, (0.1, 10.0), (5.0, 1.0)),
        ServiceDist::empirical(empirical_data),
        ServiceDist::scaled(3.0, ServiceDist::shifted_exp(0.5, 2.0)),
    ];
    for (i, d) in families.iter().enumerate() {
        let (m, v) = mc_moments(d, 200_000, 100 + i as u64);
        let mean = d.mean();
        let var = d.variance();
        assert!(mean.is_finite() && var.is_finite(), "{}", d.label());
        assert!((m - mean).abs() / mean < 0.02, "{}: mc mean {m} vs {mean}", d.label());
        assert!((v - var).abs() / var < 0.10, "{}: mc var {v} vs {var}", d.label());
    }
}

/// `quantile ∘ cdf` is the identity on interior points for every family
/// (exact closed-form inversion where it exists, bisection otherwise).
#[test]
fn quantile_cdf_round_trips_on_interior_points() {
    let families = vec![
        ServiceDist::exp(1.3),
        ServiceDist::shifted_exp(0.5, 2.0),
        ServiceDist::pareto(1.0, 1.5),
        ServiceDist::weibull(0.7, 1.0),
        ServiceDist::gamma_dist(2.0, 1.5),
        ServiceDist::gamma_dist(0.7, 1.0),
        ServiceDist::bimodal(0.1, (0.1, 10.0), (5.0, 1.0)),
    ];
    for d in &families {
        for i in 1..40 {
            let q = i as f64 / 40.0;
            let t = d.quantile(q);
            let back = d.cdf(t);
            assert!((back - q).abs() < 1e-6, "{}: q={q} t={t} back={back}", d.label());
        }
        // monotone in q
        let mut prev = f64::NEG_INFINITY;
        for i in 1..20 {
            let t = d.quantile(i as f64 / 20.0);
            assert!(t >= prev, "{}", d.label());
            prev = t;
        }
    }
}

/// Empirical quantiles are the sample order statistics, exactly.
#[test]
fn empirical_quantiles_are_exact_order_statistics() {
    let d = ServiceDist::pareto(2.0, 1.4);
    let mut rng = Pcg64::new(23);
    let raw: Vec<f64> = (0..997).map(|_| d.sample(&mut rng)).collect();
    let e = Empirical::new(raw.clone());
    let n = e.len();
    let mut sorted = raw;
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (i, &x) in sorted.iter().enumerate() {
        let q = (i + 1) as f64 / n as f64;
        // bit-exact: no interpolation, no binning
        assert_eq!(e.quantile(q).to_bits(), x.to_bits(), "i={i}");
    }
    assert_eq!(e.quantile(0.0).to_bits(), sorted[0].to_bits());
    assert_eq!(e.quantile(1.0).to_bits(), sorted[n - 1].to_bits());
    // the ECDF inverts back: quantile(cdf(x)) == x for every sample
    for &x in sorted.iter() {
        assert_eq!(e.quantile(e.cdf(x)).to_bits(), x.to_bits());
    }
}

/// The §VII classifier separates the paper's two families: SExp samples
/// label `ExponentialTail`, Pareto samples label `HeavyTail`.
#[test]
fn tail_classifier_separates_the_paper_families() {
    forall("tailfit separates families", 20, |rng| {
        let n = 2_000 + rng.range(0, 3_000);
        // exponential family: paper-like shifts (jobs 1-4)
        let delta = 5.0 + 20.0 * rng.uniform();
        let mu = 0.2 + 2.0 * rng.uniform();
        let sexp = ServiceDist::shifted_exp(delta, mu);
        let xs: Vec<f64> = (0..n).map(|_| sexp.sample(rng)).collect();
        let fit = TailFit::classify(&xs);
        assert_eq!(fit.class, TailClass::ExponentialTail, "{}: {fit:?}", sexp.label());

        // heavy family: paper-like tail indices (jobs 6-10)
        let sigma = 1.0 + 20.0 * rng.uniform();
        let alpha = 1.1 + 0.7 * rng.uniform();
        let pareto = ServiceDist::pareto(sigma, alpha);
        let xs: Vec<f64> = (0..n).map(|_| pareto.sample(rng)).collect();
        let fit = TailFit::classify(&xs);
        assert_eq!(fit.class, TailClass::HeavyTail, "{}: {fit:?}", pareto.label());
        assert!(fit.tail_alpha < 4.0, "{}: hill {}", pareto.label(), fit.tail_alpha);
    });
}

/// Sampling, CDF and survival stay mutually consistent: the empirical
/// CDF of drawn samples tracks the analytic CDF (a one-sided
/// Kolmogorov-style check at fixed probe points).
#[test]
fn sampling_matches_the_analytic_cdf() {
    let families = vec![
        ServiceDist::exp(1.0),
        ServiceDist::pareto(1.0, 1.5),
        ServiceDist::weibull(0.7, 1.0),
        ServiceDist::gamma_dist(2.0, 1.0),
        ServiceDist::bimodal(0.3, (0.1, 10.0), (5.0, 1.0)),
    ];
    let n = 100_000;
    for (i, d) in families.iter().enumerate() {
        let mut rng = Pcg64::new(500 + i as u64);
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        for q in [0.1, 0.5, 0.9, 0.99] {
            let t = d.quantile(q);
            let emp = xs.iter().filter(|&&x| x <= t).count() as f64 / n as f64;
            assert!((emp - q).abs() < 0.01, "{}: q={q} empirical {emp}", d.label());
        }
    }
}
