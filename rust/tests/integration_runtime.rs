//! Integration: PJRT runtime executes the AOT artifacts and agrees with
//! the native reference numerics.
//!
//! Requires `make artifacts` (each test skips with a message otherwise).

use replica::coordinator::{ComputeBackend, NativeBackend};
use replica::runtime::{artifacts_available, artifacts_dir, GradientOps, RuntimeService};
use replica::util::rng::Pcg64;

fn require_artifacts() -> Option<RuntimeService> {
    if !artifacts_available() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(RuntimeService::start(&artifacts_dir()).expect("runtime service"))
}

fn random_problem(m: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::new(seed);
    let beta: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let x: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
    (beta, x, y)
}

#[test]
fn pjrt_gradient_matches_native_backend() {
    let Some(service) = require_artifacts() else { return };
    let manifest = service.handle().manifest().clone();
    let (m, d) = (manifest.m, manifest.d);
    let ops = GradientOps::new(service.handle(), m).unwrap();
    let native = NativeBackend::new(m, d);

    for seed in 0..5 {
        let (beta, x, y) = random_problem(m, d, seed);
        let (g_pjrt, l_pjrt) = ops.partial_grad_loss(&beta, &x, &y).unwrap();
        let (g_native, l_native) = native.partial_grad_loss(&beta, &x, &y).unwrap();
        assert_eq!(g_pjrt.len(), d);
        for (a, b) in g_pjrt.iter().zip(&g_native) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "seed {seed}: {a} vs {b}");
        }
        assert!((l_pjrt - l_native).abs() < 1e-3 * (1.0 + l_native.abs()));
    }
}

#[test]
fn pjrt_sgd_update_and_full_step_consistent() {
    let Some(service) = require_artifacts() else { return };
    let manifest = service.handle().manifest().clone();
    let m = manifest.m;
    let ops = GradientOps::new(service.handle(), m).unwrap();

    let (beta, x, y) = random_problem(m, manifest.d, 42);
    let lr = 0.05f32;
    // full_step == partial_grad_loss + sgd_update
    let (beta_fused, loss_fused) = ops.full_step(&beta, &x, &y, lr).unwrap();
    let (g, loss_two) = ops.partial_grad_loss(&beta, &x, &y).unwrap();
    let beta_two = ops.sgd_update(&beta, &g, lr).unwrap();
    assert!((loss_fused - loss_two).abs() < 1e-4 * (1.0 + loss_two.abs()));
    for (a, b) in beta_fused.iter().zip(&beta_two) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn pjrt_half_shard_artifact_works() {
    let Some(service) = require_artifacts() else { return };
    let manifest = service.handle().manifest().clone();
    let m_half = manifest.m / 2;
    if m_half < 8 {
        return;
    }
    let ops = GradientOps::new(service.handle(), m_half).unwrap();
    let (beta, x, y) = random_problem(m_half, manifest.d, 7);
    let (g, loss) = ops.partial_grad_loss(&beta, &x, &y).unwrap();
    assert_eq!(g.len(), manifest.d);
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn pjrt_rejects_wrong_shapes() {
    let Some(service) = require_artifacts() else { return };
    let manifest = service.handle().manifest().clone();
    let ops = GradientOps::new(service.handle(), manifest.m).unwrap();
    let bad_beta = vec![0.0f32; manifest.d + 1];
    let x = vec![0.0f32; manifest.m * manifest.d];
    let y = vec![0.0f32; manifest.m];
    assert!(ops.partial_grad_loss(&bad_beta, &x, &y).is_err());
}

#[test]
fn pjrt_handles_concurrent_callers() {
    let Some(service) = require_artifacts() else { return };
    let manifest = service.handle().manifest().clone();
    let (m, d) = (manifest.m, manifest.d);
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let ops = GradientOps::new(service.handle(), m).unwrap();
        joins.push(std::thread::spawn(move || {
            let (beta, x, y) = random_problem(m, d, 100 + t);
            for _ in 0..5 {
                let (g, loss) = ops.partial_grad_loss(&beta, &x, &y).unwrap();
                assert_eq!(g.len(), d);
                assert!(loss.is_finite());
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
}

#[test]
fn gradient_ops_missing_shape_is_clear_error() {
    let Some(service) = require_artifacts() else { return };
    let err = match GradientOps::new(service.handle(), 12345) {
        Ok(_) => panic!("m=12345 should not exist in the manifest"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("make artifacts"), "{err}");
}
