//! Physics validation of the open-system serving simulator: the
//! [`replica::eval::OpenSystem`] estimator against queueing theory, the
//! closed-system estimators in its ρ → 0 limit, the determinism
//! contract across fan-out widths, and the headline B*-vs-load flip.
//!
//! Tolerances are deliberately generous: pooled sojourn times are
//! autocorrelated within a replication, so the reported `ci95`
//! (computed as if samples were independent) underestimates the real
//! sampling error.

use std::sync::Arc;

use replica::dist::ServiceDist;
use replica::eval::{Estimator, MonteCarlo, OpenConfig, OpenSystem, Scenario};
use replica::planner::{choose, Objective, SweepPoint};

/// N = 1, B = 1, Exp(µ) service: the simulator degenerates to a
/// textbook M/M/1 queue, so E[T] = 1/(µ − λ) — at µ = 1, ρ = 0.5 that
/// is exactly 2.0 — and utilization equals ρ.
#[test]
fn mm1_sojourn_matches_theory() {
    let scenario = Scenario::balanced(1, 1, Arc::new(ServiceDist::exp(1.0)));
    let os = OpenSystem {
        reps: 64,
        seed: 42,
        threads: 0,
        open: OpenConfig { rho: 0.5, jobs: 400, warmup: 100 },
    };
    let oe = os.evaluate_open(&scenario).unwrap();
    assert!(
        (oe.estimate.mean - 2.0).abs() < 0.25,
        "M/M/1 at rho=0.5 must have E[T] ~ 2.0, got {}",
        oe.estimate.mean
    );
    assert!(
        (oe.utilization - 0.5).abs() < 0.05,
        "M/M/1 utilization must track rho, got {}",
        oe.utilization
    );
    assert!((oe.lambda - 0.5).abs() < 1e-12);
    // percentiles of an M/M/1 sojourn are exponential with mean 2:
    // p50 = 2 ln 2 ~ 1.386, p95 = 2 ln 20 ~ 5.99
    assert!((oe.estimate.p50 - 1.386).abs() < 0.3, "p50 {}", oe.estimate.p50);
    assert!((oe.estimate.p95 - 5.99).abs() < 1.2, "p95 {}", oe.estimate.p95);
}

/// As ρ → 0 jobs never queue behind each other, so the open-system
/// sojourn distribution collapses to the closed-system job compute
/// time that `MonteCarlo` estimates on idle workers.
#[test]
fn rho_to_zero_limit_agrees_with_closed_system() {
    let tau = Arc::new(ServiceDist::shifted_exp(0.1, 1.0));
    for b in [1usize, 2, 4] {
        let scenario = Scenario::balanced(4, b, Arc::clone(&tau));
        let os = OpenSystem {
            reps: 128,
            seed: 9,
            threads: 0,
            open: OpenConfig { rho: 0.002, jobs: 60, warmup: 10 },
        };
        let open = os.evaluate_open(&scenario).unwrap();
        let closed =
            MonteCarlo { reps: 20_000, seed: 11, threads: 0 }.evaluate(&scenario).unwrap();
        let diff = (open.estimate.mean - closed.mean).abs();
        let band = 0.04 * closed.mean + open.estimate.ci95 + closed.ci95;
        assert!(
            diff < band,
            "B={b}: open mean {} vs closed mean {} (band {band})",
            open.estimate.mean,
            closed.mean
        );
    }
}

/// The determinism contract: every replication's RNG stream is fixed by
/// `substream(stream_seed, rep)` and the reduce is serial in rep order,
/// so the estimate is bit-identical no matter how wide the fan-out.
#[test]
fn open_estimates_are_bit_identical_across_fanout_widths() {
    let scenario =
        Scenario::balanced(8, 2, Arc::new(ServiceDist::pareto(1.0, 2.2)));
    let reference = OpenSystem {
        reps: 48,
        seed: 77,
        threads: 1,
        open: OpenConfig { rho: 0.4, jobs: 50, warmup: 10 },
    }
    .evaluate_open(&scenario)
    .unwrap();
    for threads in [2usize, 4, 8] {
        let oe = OpenSystem {
            reps: 48,
            seed: 77,
            threads,
            open: OpenConfig { rho: 0.4, jobs: 50, warmup: 10 },
        }
        .evaluate_open(&scenario)
        .unwrap();
        assert_eq!(
            oe.estimate.mean.to_bits(),
            reference.estimate.mean.to_bits(),
            "threads={threads}"
        );
        assert_eq!(oe.estimate.p99.to_bits(), reference.estimate.p99.to_bits());
        assert_eq!(oe.estimate.cost.to_bits(), reference.estimate.cost.to_bits());
        assert_eq!(oe.utilization.to_bits(), reference.utilization.to_bits());
    }
}

/// The headline result: B* depends on load. For sexp(0.1, 1) on N = 4
/// workers, full diversity (B = 1) minimizes E[T] on idle workers
/// (4·(δ + 1/(4µ)) = 1.4 < δ + H₄/µ ≈ 2.18), but its 4× worker-seconds
/// exceed capacity once λ·5.6 > 4 — so under heavy load the optimum
/// collapses to full parallelism (B = N).
#[test]
fn b_star_flips_from_diversity_to_parallelism_with_load() {
    let tau = Arc::new(ServiceDist::shifted_exp(0.1, 1.0));
    let spectrum_at = |rho: f64| -> Vec<SweepPoint> {
        [1usize, 2, 4]
            .iter()
            .map(|&b| {
                let scenario = Scenario::balanced(4, b, Arc::clone(&tau));
                let oe = OpenSystem {
                    reps: 96,
                    seed: 23,
                    threads: 0,
                    open: OpenConfig { rho, jobs: 80, warmup: 20 },
                }
                .evaluate_open(&scenario)
                .unwrap();
                SweepPoint {
                    batches: b,
                    mean: oe.estimate.mean,
                    cov: oe.estimate.cov,
                    cost: oe.estimate.cost,
                    ci95: oe.estimate.ci95,
                }
            })
            .collect()
    };
    let light = choose(&spectrum_at(0.05), Objective::MeanCompletion).unwrap();
    assert_eq!(light.batches, 1, "light load must pick full diversity");
    let heavy = choose(&spectrum_at(0.9), Objective::MeanCompletion).unwrap();
    assert_eq!(heavy.batches, 4, "heavy load must pick full parallelism");
    // and the mechanism is visible in the cost column: B = 1 burns ~4x
    // the worker-seconds of B = 4 per job at light load
    let light_points = spectrum_at(0.05);
    let (b1, b4) = (light_points[0].cost, light_points[2].cost);
    assert!(b1 > 2.5 * b4, "B=1 cost {b1} must dwarf B=4 cost {b4}");
}
