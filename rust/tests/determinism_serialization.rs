//! Byte-identity regression tests for every result-serialization path.
//!
//! The determinism contract (rust/README.md, enforced at the source
//! level by `tools/detlint`) promises that serialized results are
//! **byte-identical** across repeated runs and across evaluation
//! fan-out widths. These tests pin the contract end to end: evaluate →
//! serialize twice → compare raw bytes, so an accidental `HashMap` (or
//! any other iteration-order dependence) on an export path fails CI
//! with a one-line diff, not a flaky downstream figure.

use std::path::PathBuf;

use replica::metrics::{export_csv, export_json, SeriesExport};
use replica::sweep::{run, CaseOutcome, RunConfig, ScenarioSet, SweepSpec, Workload};

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("replica_det_ser_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn small_set() -> ScenarioSet {
    let mut spec = SweepSpec::for_trace();
    spec.workload = Some(Workload::Generate { jobs: 2, tasks_per_job: 8, seed: 11 });
    spec.reps = 120;
    spec.seed = 3;
    spec.shard_size = 4;
    ScenarioSet::from_trace(&spec.load_trace().unwrap(), &spec).unwrap()
}

/// Evaluate the set at the given fan-out width and serialize the
/// resulting curve through both exporters, returning the raw bytes.
fn evaluate_and_export(dir: &std::path::Path, tag: &str, threads: usize) -> (String, String) {
    let set = small_set();
    let cfg = RunConfig { threads, ..RunConfig::default() };
    let results = run(&set, &cfg).unwrap();
    assert_eq!(results.len(), set.len());

    let mut series = SeriesExport::new("sweep", "case", vec!["mean", "p99"]);
    for (i, result) in results.iter().enumerate() {
        let est = match &result.outcome {
            CaseOutcome::Ok(est) => est,
            CaseOutcome::Error(msg) => panic!("case {i} failed: {msg}"),
        };
        series.push(i as f64, vec![est.mean, est.p99]);
    }
    let csv_path = dir.join(format!("{tag}.csv"));
    let json_path = dir.join(format!("{tag}.json"));
    export_csv(&csv_path, &[series.clone()]).unwrap();
    export_json(&json_path, &[series]).unwrap();
    (std::fs::read_to_string(&csv_path).unwrap(), std::fs::read_to_string(&json_path).unwrap())
}

#[test]
fn exports_are_byte_identical_across_runs_and_fanout() {
    let dir = test_dir("fanout");
    // serial run, run 1
    let (csv_a, json_a) = evaluate_and_export(&dir, "a", 1);
    // serial run, run 2: identical process state must not matter
    let (csv_b, json_b) = evaluate_and_export(&dir, "b", 1);
    // wide run: pool scheduling must not reach the output bytes
    let (csv_c, json_c) = evaluate_and_export(&dir, "c", 4);
    assert_eq!(csv_a, csv_b, "CSV export differs between identical runs");
    assert_eq!(json_a, json_b, "JSON export differs between identical runs");
    assert_eq!(csv_a, csv_c, "CSV export depends on evaluation fan-out width");
    assert_eq!(json_a, json_c, "JSON export depends on evaluation fan-out width");
    assert!(csv_a.lines().count() > 1, "export actually carried rows");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn persisted_store_is_byte_identical_across_runs() {
    let dir = test_dir("store");
    let set = small_set();
    let mut stores = Vec::new();
    for tag in ["x", "y"] {
        let out = dir.join(format!("{tag}.jsonl"));
        let cfg = RunConfig { shard_size: 4, ..RunConfig::persisted(out.clone()) };
        let results = run(&set, &cfg).unwrap();
        assert_eq!(results.len(), set.len());
        stores.push(std::fs::read_to_string(&out).unwrap());
    }
    assert_eq!(stores[0], stores[1], "persisted sweep store differs between identical runs");
    std::fs::remove_dir_all(&dir).ok();
}
