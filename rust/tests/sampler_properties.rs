//! Property tests for the batched sampling layer and the pooled
//! Monte-Carlo execution:
//!
//! * the alias-table-backed samplers (Bimodal, Empirical) match
//!   inverse-CDF / order-statistics sampling **in distribution**
//!   (moments + a KS-style quantile-grid check);
//! * pooled two-level execution is bit-identical to
//!   `MonteCarlo::serial` for fixed seeds across thread counts
//!   {1, 2, 4, 8}, including `evaluate_many` item ordering;
//! * the variance-reduced fills (`fill_antithetic`, `fill_stratified`)
//!   keep each inverse-CDF family's marginal distribution exact
//!   (moments + quantile grid), fall back to the plain fill bitwise
//!   for the alias/rejection families, and the paired (CRN) spectrum
//!   built on them is bit-identical across pool widths.

use replica::batching::Policy;
use replica::dist::{FillMode, Sampler, ServiceDist};
use replica::eval::{Estimator, MonteCarlo, Scenario};
use replica::planner::Planner;
use replica::sim::FailureModel;
use replica::util::rng::Pcg64;

/// Draw `n` samples through the compiled (alias-table) sampler and
/// return them sorted.
fn batch_sorted(dist: &ServiceDist, n: usize, seed: u64) -> Vec<f64> {
    let sampler = Sampler::compile(dist);
    let mut rng = Pcg64::new(seed);
    let mut samples = vec![0.0; n];
    sampler.fill(&mut rng, &mut samples);
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples
}

/// Empirical CDF of a sorted sample at `t`.
fn ecdf(sorted: &[f64], t: f64) -> f64 {
    sorted.partition_point(|x| *x <= t) as f64 / sorted.len() as f64
}

/// KS-style check: at every point of a quantile grid of the target
/// distribution, the sampler's empirical CDF must agree with the exact
/// CDF within `tol` (≈ 3/√n sampling noise).
fn assert_cdf_matches(dist: &ServiceDist, n: usize, seed: u64, tol: f64) {
    let sorted = batch_sorted(dist, n, seed);
    for i in 1..100 {
        let q = i as f64 / 100.0;
        let t = dist.quantile(q);
        let have = ecdf(&sorted, t);
        let want = dist.cdf(t);
        assert!(
            (have - want).abs() < tol,
            "{} at q={q} (t={t}): ecdf {have} vs cdf {want}",
            dist.label()
        );
    }
}

fn assert_moments_match(dist: &ServiceDist, n: usize, seed: u64) {
    let samples = batch_sorted(dist, n, seed);
    let nf = n as f64;
    let mean = samples.iter().sum::<f64>() / nf;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / nf;
    assert!(
        (mean - dist.mean()).abs() / dist.mean() < 0.02,
        "{}: mean {mean} vs {}",
        dist.label(),
        dist.mean()
    );
    assert!(
        (var - dist.variance()).abs() / dist.variance() < 0.06,
        "{}: var {var} vs {}",
        dist.label(),
        dist.variance()
    );
}

#[test]
fn bimodal_alias_sampler_matches_inverse_cdf_in_distribution() {
    for (p_slow, fast, slow) in [
        (0.1, (0.1, 10.0), (5.0, 1.0)),
        (0.5, (0.0, 2.0), (1.0, 0.5)),
        (0.95, (0.1, 10.0), (5.0, 1.0)),
    ] {
        let dist = ServiceDist::bimodal(p_slow, fast, slow);
        assert_moments_match(&dist, 200_000, 11);
        assert_cdf_matches(&dist, 200_000, 12, 0.01);
    }
}

#[test]
fn empirical_alias_sampler_matches_order_statistics_in_distribution() {
    // bootstrap over 500 distinct observed values
    let base = ServiceDist::pareto(1.0, 2.5);
    let mut rng = Pcg64::new(3);
    let observed: Vec<f64> = (0..500).map(|_| base.sample(&mut rng)).collect();
    let dist = ServiceDist::empirical(observed.clone());
    assert_moments_match(&dist, 200_000, 21);

    // exact step-function check: at every observed value the bootstrap
    // ECDF must reproduce the exact order-statistics CDF
    let sorted_samples = batch_sorted(&dist, 200_000, 22);
    let mut support = observed;
    support.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (i, &v) in support.iter().enumerate() {
        let have = ecdf(&sorted_samples, v);
        let want = dist.cdf(v);
        assert!(
            (have - want).abs() < 0.01,
            "support point {i} (v={v}): ecdf {have} vs exact {want}"
        );
    }
    // and every drawn value is an observed value
    assert!(sorted_samples.iter().all(|x| support.contains(x)));
}

#[test]
fn degenerate_bimodal_weights_match_their_component() {
    // p_slow = 0 and 1 must collapse exactly to one SExp component
    for (p_slow, delta, mu) in [(0.0, 0.1, 10.0), (1.0, 5.0, 1.0)] {
        let dist = ServiceDist::bimodal(p_slow, (0.1, 10.0), (5.0, 1.0));
        let component = ServiceDist::shifted_exp(delta, mu);
        let sorted = batch_sorted(&dist, 100_000, 31);
        for i in 1..50 {
            let q = i as f64 / 50.0;
            let t = component.quantile(q);
            let have = ecdf(&sorted, t);
            assert!(
                (have - q).abs() < 0.012,
                "p_slow={p_slow} q={q}: ecdf {have}"
            );
        }
    }
}

/// The scenario mix exercises every replication path: fixed layouts
/// (closed-form and alias-sampled service), the pick-based randomized
/// path, the per-replication materialization path (random + failures),
/// and the event-driven failure path.
fn determinism_scenarios() -> Vec<Scenario> {
    let mut rng = Pcg64::new(8);
    let base = ServiceDist::exp(1.0);
    let observed: Vec<f64> = (0..300).map(|_| base.sample(&mut rng)).collect();
    vec![
        Scenario::balanced(20, 4, ServiceDist::shifted_exp(0.05, 1.0)),
        Scenario::balanced(20, 5, ServiceDist::bimodal(0.1, (0.1, 10.0), (5.0, 1.0))),
        Scenario::balanced(12, 3, ServiceDist::empirical(observed)),
        Scenario::new(
            20,
            Policy::RandomNonOverlapping { batches: 5 },
            ServiceDist::exp(1.0),
        ),
        Scenario::new(
            12,
            Policy::RandomNonOverlapping { batches: 3 },
            ServiceDist::exp(1.0),
        )
        .with_failures(FailureModel::Crash { p: 0.2 }),
        Scenario::new(
            6,
            Policy::CyclicOverlapping { batches: 3 },
            ServiceDist::pareto(1.0, 2.5),
        ),
        Scenario::balanced(10, 2, ServiceDist::exp(1.0))
            .with_failures(FailureModel::CrashRestart { p: 0.3, delay: 2.0 }),
    ]
}

/// Draw `n` samples through a variance-reduced fill and return them
/// sorted, plus the strategy that actually ran.
fn batch_sorted_reduced(
    dist: &ServiceDist,
    n: usize,
    seed: u64,
    antithetic: bool,
) -> (Vec<f64>, FillMode) {
    let sampler = Sampler::compile(dist);
    let mut rng = Pcg64::new(seed);
    let mut samples = vec![0.0; n];
    let mode = if antithetic {
        sampler.fill_antithetic(&mut rng, &mut samples)
    } else {
        sampler.fill_stratified(&mut rng, &mut samples)
    };
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (samples, mode)
}

/// The closed-form inverse-CDF families the variance-reduced fills
/// cover without fallback.
fn inverse_cdf_families() -> Vec<ServiceDist> {
    vec![
        ServiceDist::exp(1.3),
        ServiceDist::shifted_exp(0.5, 2.0),
        ServiceDist::pareto(1.0, 3.0),
        ServiceDist::weibull(0.7, 1.5),
    ]
}

#[test]
fn variance_reduced_fills_keep_the_marginal_distribution_exact() {
    // a u/1−u pair (antithetic) and a per-stratum draw (stratified)
    // are each marginally distributed as the target, so the pooled
    // batch must still pass the same quantile-grid check as plain
    // fills — variance reduction must never shift the distribution
    for dist in inverse_cdf_families() {
        for (antithetic, want) in [(true, FillMode::Antithetic), (false, FillMode::Stratified)]
        {
            let (sorted, mode) = batch_sorted_reduced(&dist, 200_000, 41, antithetic);
            assert_eq!(mode, want, "{}", dist.label());
            for i in 1..100 {
                let q = i as f64 / 100.0;
                let t = dist.quantile(q);
                let have = ecdf(&sorted, t);
                let wantq = dist.cdf(t);
                assert!(
                    (have - wantq).abs() < 0.01,
                    "{} {:?} at q={q}: ecdf {have} vs cdf {wantq}",
                    dist.label(),
                    mode
                );
            }
            let nf = sorted.len() as f64;
            let mean = sorted.iter().sum::<f64>() / nf;
            assert!(
                (mean - dist.mean()).abs() / dist.mean() < 0.02,
                "{} {:?}: mean {mean} vs {}",
                dist.label(),
                mode,
                dist.mean()
            );
            // the sample-variance estimator needs a finite 4th moment
            // to settle at n = 200k; Pareto(α=3) does not have one, so
            // its spread is covered by the quantile grid above
            if !matches!(dist, ServiceDist::Pareto { .. }) {
                let var =
                    sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / nf;
                assert!(
                    (var - dist.variance()).abs() / dist.variance() < 0.06,
                    "{} {:?}: var {var} vs {}",
                    dist.label(),
                    mode,
                    dist.variance()
                );
            }
        }
    }
}

#[test]
fn stratified_fill_is_super_uniform_on_the_stratum_grid() {
    // slot i's CDF value lands in [i/n, (i+1)/n) by construction, so
    // at every stratum boundary the empirical CDF is *exact* — far
    // beyond the 3/sqrt(n) a plain fill can promise
    let dist = ServiceDist::shifted_exp(0.1, 1.0);
    let n = 10_000usize;
    let (sorted, mode) = batch_sorted_reduced(&dist, n, 43, false);
    assert_eq!(mode, FillMode::Stratified);
    for i in (500..n).step_by(500) {
        let q = i as f64 / n as f64;
        let have = ecdf(&sorted, dist.quantile(q));
        assert!(
            (have - q).abs() <= 1.0 / n as f64 + 1e-12,
            "stratum boundary q={q}: ecdf {have}"
        );
    }
}

#[test]
fn antithetic_pairing_cuts_the_mean_estimator_variance() {
    // the point of u/1−u pairing: for a monotone kernel the pair means
    // are negatively correlated, so the batch-mean estimator must beat
    // independent draws by a wide margin at equal draw count
    let dist = ServiceDist::exp(1.0);
    let sampler = Sampler::compile(&dist);
    let (batches, width) = (400usize, 64usize);
    let mut plain_means = Vec::with_capacity(batches);
    let mut anti_means = Vec::with_capacity(batches);
    let mut buf = vec![0.0; width];
    let mut rng_plain = Pcg64::new(51);
    let mut rng_anti = Pcg64::new(52);
    for _ in 0..batches {
        sampler.fill(&mut rng_plain, &mut buf);
        plain_means.push(buf.iter().sum::<f64>() / width as f64);
        assert_eq!(sampler.fill_antithetic(&mut rng_anti, &mut buf), FillMode::Antithetic);
        anti_means.push(buf.iter().sum::<f64>() / width as f64);
    }
    let var_of = |xs: &[f64]| {
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
    };
    let (vp, va) = (var_of(&plain_means), var_of(&anti_means));
    assert!(
        va < vp / 2.0,
        "antithetic batch-mean variance {va} not well below plain {vp}"
    );
}

#[test]
fn alias_and_rejection_families_fall_back_to_plain_fills_bitwise() {
    // Gamma (rejection loop) and the alias-table families have no
    // single-uniform inverse-CDF kernel; a variance-reduced fill
    // request must degrade to exactly the plain fill — same draws,
    // same RNG consumption — and report the fallback
    let mut rng = Pcg64::new(3);
    let base = ServiceDist::pareto(1.0, 2.5);
    let observed: Vec<f64> = (0..100).map(|_| base.sample(&mut rng)).collect();
    for dist in [
        ServiceDist::gamma_dist(2.5, 0.8),
        ServiceDist::bimodal(0.15, (0.1, 10.0), (5.0, 1.0)),
        ServiceDist::empirical(observed),
    ] {
        let sampler = Sampler::compile(&dist);
        let mut plain = vec![0.0; 1001];
        sampler.fill(&mut Pcg64::new(17), &mut plain);
        let mut reduced = vec![0.0; 1001];
        assert_eq!(
            sampler.fill_antithetic(&mut Pcg64::new(17), &mut reduced),
            FillMode::Plain,
            "{}",
            dist.label()
        );
        assert_eq!(to_bits(&plain), to_bits(&reduced), "{} antithetic", dist.label());
        assert_eq!(
            sampler.fill_stratified(&mut Pcg64::new(17), &mut reduced),
            FillMode::Plain,
            "{}",
            dist.label()
        );
        assert_eq!(to_bits(&plain), to_bits(&reduced), "{} stratified", dist.label());
    }
}

fn to_bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn paired_spectrum_is_bit_identical_across_pool_widths() {
    // the CRN spectrum shares one stream seed across every B; sharing
    // must not reintroduce any thread-count dependence
    let tau = ServiceDist::shifted_exp(0.1, 1.0);
    let golden = Planner::new(12, tau.clone())
        .sweep_paired_mc(&MonteCarlo { reps: 2_000, seed: 9, threads: 1 })
        .unwrap();
    for threads in [2usize, 4, 8] {
        let spectrum = Planner::new(12, tau.clone())
            .sweep_paired_mc(&MonteCarlo { reps: 2_000, seed: 9, threads })
            .unwrap();
        assert_eq!(spectrum.reference, golden.reference, "threads={threads}");
        assert_eq!(spectrum.replications, golden.replications, "threads={threads}");
        for (i, (a, b)) in golden.points.iter().zip(&spectrum.points).enumerate() {
            let tag = format!("threads={threads} point {i}");
            assert_eq!(a.point.batches, b.point.batches, "{tag}");
            assert_eq!(a.point.mean.to_bits(), b.point.mean.to_bits(), "{tag} mean");
            assert_eq!(a.point.ci95.to_bits(), b.point.ci95.to_bits(), "{tag} ci95");
            assert_eq!(a.diff_mean.to_bits(), b.diff_mean.to_bits(), "{tag} diff");
            assert_eq!(
                a.diff_ci95.to_bits(),
                b.diff_ci95.to_bits(),
                "{tag} diff ci95"
            );
            assert_eq!(a.paired, b.paired, "{tag} paired");
        }
    }
}

#[test]
fn pooled_two_level_execution_is_bit_identical_to_serial() {
    let scenarios = determinism_scenarios();
    let golden = MonteCarlo::serial(3_000, 99).evaluate_many(&scenarios).unwrap();
    for threads in [1usize, 2, 4, 8] {
        let mc = MonteCarlo { reps: 3_000, seed: 99, threads };
        let batch = mc.evaluate_many(&scenarios).unwrap();
        for (i, (a, b)) in golden.iter().zip(&batch).enumerate() {
            let tag = format!("threads={threads} scenario {i}");
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "{tag} mean");
            assert_eq!(a.ci95.to_bits(), b.ci95.to_bits(), "{tag} ci95");
            assert_eq!(a.cov.to_bits(), b.cov.to_bits(), "{tag} cov");
            assert_eq!(a.p50.to_bits(), b.p50.to_bits(), "{tag} p50");
            assert_eq!(a.p95.to_bits(), b.p95.to_bits(), "{tag} p95");
            assert_eq!(a.p99.to_bits(), b.p99.to_bits(), "{tag} p99");
            assert_eq!(a.failure_rate, b.failure_rate, "{tag} failure_rate");
            assert_eq!(a.completed, b.completed, "{tag} completed");
        }
    }
}

#[test]
fn evaluate_many_ordering_matches_evaluate_at_for_every_fanout() {
    let scenarios = determinism_scenarios();
    for threads in [1usize, 2, 4, 8] {
        let mc = MonteCarlo { reps: 1_500, seed: 7, threads };
        let batch = mc.evaluate_many(&scenarios).unwrap();
        for (i, scenario) in scenarios.iter().enumerate() {
            let single = mc.evaluate_at(scenario, i as u64).unwrap();
            assert_eq!(
                batch[i].mean.to_bits(),
                single.mean.to_bits(),
                "threads={threads} item {i}: batch diverged from substream"
            );
            assert_eq!(batch[i].completed, single.completed);
        }
    }
}

#[test]
fn pool_width_does_not_leak_into_results() {
    // same scenario, same seed, widely different rep budgets per unit:
    // chunking must never change which substream a replication uses
    let scenario = Scenario::balanced(20, 4, ServiceDist::pareto(1.0, 2.5));
    let reference = MonteCarlo::serial(2_048, 5).evaluate(&scenario).unwrap();
    for threads in [2usize, 3, 5, 8, 16] {
        let est = MonteCarlo { reps: 2_048, seed: 5, threads }
            .evaluate(&scenario)
            .unwrap();
        assert_eq!(reference.mean.to_bits(), est.mean.to_bits(), "threads={threads}");
        assert_eq!(reference.p99.to_bits(), est.p99.to_bits(), "threads={threads}");
    }
}
