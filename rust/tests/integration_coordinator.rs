//! Integration: the live coordinator trains end-to-end, on both
//! backends, and its latency behaviour matches the paper's analysis.

use std::sync::Arc;

use replica::coordinator::{
    Coordinator, Dataset, GdConfig, NativeBackend, PjrtBackend,
};
use replica::dist::ServiceDist;
use replica::planner::{Objective, Planner};
use replica::runtime::{artifacts_available, artifacts_dir, GradientOps, RuntimeService};

fn cfg(workers: usize, batches: usize, rounds: usize, tau: ServiceDist) -> GdConfig {
    GdConfig {
        workers,
        batches,
        rounds,
        lr: 0.1,
        straggler: tau,
        time_scale: 1e-4,
        seed: 5,
    }
}

#[test]
fn native_training_converges_on_planned_redundancy() {
    // Plan redundancy for a heavy-tail straggler model, then train.
    let tau = ServiceDist::pareto(0.01, 1.5);
    let n = 8;
    let plan = Planner::new(n, tau.clone()).plan(Objective::MeanCompletion);
    let (m, d) = (16, 4);
    let ds = Dataset::synthetic(n, m, d, 0.0, 9);
    let mut coord = Coordinator::new(
        cfg(n, plan.batches, 150, tau),
        ds,
        Arc::new(NativeBackend::new(m, d)),
    )
    .unwrap();
    let report = coord.run().unwrap();
    assert!(report.final_global_loss < 1e-4, "loss {}", report.final_global_loss);
    // replication means late copies get discarded
    if plan.batches < n {
        assert!(report.total_discarded > 0);
    }
}

#[test]
fn pjrt_training_matches_native_training() {
    if !artifacts_available() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    }
    let service = RuntimeService::start(&artifacts_dir()).unwrap();
    let manifest = service.handle().manifest().clone();
    let (m, d) = (manifest.m, manifest.d);
    let n = 4;
    let rounds = 25;
    let tau = ServiceDist::shifted_exp(0.001, 100.0);

    let ds = Dataset::synthetic(n, m, d, 0.05, 31);
    let mut native = Coordinator::new(
        cfg(n, 2, rounds, tau.clone()),
        ds.clone(),
        Arc::new(NativeBackend::new(m, d)),
    )
    .unwrap();
    let native_report = native.run().unwrap();

    let ops = GradientOps::new(service.handle(), m).unwrap();
    let mut pjrt =
        Coordinator::new(cfg(n, 2, rounds, tau), ds, Arc::new(PjrtBackend::new(ops)))
            .unwrap();
    let pjrt_report = pjrt.run().unwrap();

    // identical seeds → identical replication/straggler draws; gradient
    // math agrees to f32 tolerance, so the loss curves must match closely
    for (a, b) in native_report.losses().iter().zip(pjrt_report.losses()) {
        assert!(
            (a - b).abs() < 1e-3 * (1.0 + b.abs()),
            "native {a} vs pjrt {b}"
        );
    }
    assert!(
        (native_report.final_global_loss - pjrt_report.final_global_loss).abs() < 1e-3
    );
}

#[test]
fn round_latency_scales_with_straggler_delays() {
    // With deterministic-ish service (huge mu → tiny randomness) the
    // round latency ≈ batch_size · delta · time_scale.
    let n = 4;
    let (m, d) = (8, 3);
    let delta = 2.0;
    let tau = ServiceDist::shifted_exp(delta, 1e6);
    let time_scale = 5e-3;
    let mut coord = Coordinator::new(
        GdConfig {
            workers: n,
            batches: 2, // batch size 2 → service ≈ 2·delta
            rounds: 5,
            lr: 0.1,
            straggler: tau,
            time_scale,
            seed: 3,
        },
        Dataset::synthetic(n, m, d, 0.0, 4),
        Arc::new(NativeBackend::new(m, d)),
    )
    .unwrap();
    let report = coord.run().unwrap();
    let want = 2.0 * delta * time_scale; // 20 ms
    let got = report.mean_latency();
    assert!(
        (got - want).abs() < 0.6 * want,
        "latency {got:.4}s vs expected ≈{want:.4}s"
    );
}
