//! Redundancy-planner walkthrough: how the optimal operating point
//! moves with the service-time family and its parameters — the
//! decision procedure the paper's §VI derives.
//!
//! ```bash
//! cargo run --release --example redundancy_planner
//! ```

use replica::dist::ServiceDist;
use replica::experiments::regimes;
use replica::metrics::{fnum, Table};
use replica::planner::{Objective, Planner};

fn main() {
    let n = 100;

    // 1. Regime tables straight from the theorems.
    regimes::sexp_mean_table(n, 0.05, &[0.1, 0.5, 1.0, 2.0, 5.0, 14.0, 20.0]).print();
    println!();
    regimes::sexp_cov_table(n, 0.05, &[0.2, 0.5, 3.0, 40.0]).print();
    println!();
    regimes::pareto_table(n, 1.0, &[1.5, 2.5, 3.5, 5.0, 7.0]).print();
    println!();
    regimes::tradeoff_table(n).print();

    // 2. A worked plan for each family.
    println!();
    let mut t = Table::new(
        "planner decisions (N=100, objective = mean completion)",
        vec!["service dist", "B*", "replication", "E[T]", "speedup vs B=N"],
    );
    for tau in [
        ServiceDist::exp(1.0),
        ServiceDist::shifted_exp(0.05, 1.0),
        ServiceDist::shifted_exp(1.0, 5.0),
        ServiceDist::pareto(1.0, 1.5),
        ServiceDist::pareto(1.0, 7.0),
        ServiceDist::weibull(0.6, 1.0),
    ] {
        let plan = Planner::new(n, tau.clone()).plan(Objective::MeanCompletion);
        t.row(vec![
            tau.label(),
            plan.batches.to_string(),
            plan.replication.to_string(),
            fnum(plan.predicted_mean),
            format!("{}x", fnum(plan.speedup_vs_no_redundancy)),
        ]);
    }
    t.print();

    // 3. The Pareto front a system administrator picks from.
    println!();
    let planner = Planner::new(n, ServiceDist::shifted_exp(0.05, 1.0));
    let mut front = Table::new(
        "mean/CoV Pareto front, tau ~ SExp(0.05, 1), N=100",
        vec!["B", "E[T]", "CoV[T]"],
    );
    for p in planner.tradeoff_front() {
        front.row(vec![p.batches.to_string(), fnum(p.mean), fnum(p.cov)]);
    }
    front.print();
}
