//! The three `eval::Estimator` backends compared on one scenario.
//!
//! ```bash
//! cargo run --release --example estimator_backends
//! ```
//!
//! `Analytic` answers from the paper's closed forms (exact, free),
//! `MonteCarlo` simulates (works everywhere, seed-stable across thread
//! counts), and `Auto` picks whichever applies — recording its choice
//! in the estimate's provenance.

use std::time::Instant;

use replica::batching::Policy;
use replica::dist::ServiceDist;
use replica::eval::{Analytic, Auto, Estimate, Estimator, MonteCarlo, Scenario};
use replica::metrics::{fnum, Table};

fn row(name: &str, est: &replica::Result<Estimate>, elapsed: f64) -> Vec<String> {
    match est {
        Ok(e) => vec![
            name.to_string(),
            e.provenance.backend().to_string(),
            format!("{} ± {}", fnum(e.mean), fnum(e.ci95)),
            fnum(e.cov),
            fnum(e.p99),
            format!("{:.1} ms", elapsed * 1e3),
        ],
        Err(err) => vec![
            name.to_string(),
            "-".into(),
            format!("error: {err}"),
            "-".into(),
            "-".into(),
            format!("{:.1} ms", elapsed * 1e3),
        ],
    }
}

fn compare(title: &str, scenario: &Scenario) {
    let mut t = Table::new(
        title,
        vec!["estimator", "backend used", "E[T]", "CoV", "p99", "time"],
    );
    let analytic = Analytic;
    let mc = MonteCarlo::new(50_000, 42);
    let auto = Auto::new(50_000, 42);

    let t0 = Instant::now();
    let a = analytic.evaluate(scenario);
    t.row(row("Analytic", &a, t0.elapsed().as_secs_f64()));

    let t0 = Instant::now();
    let m = mc.evaluate(scenario);
    t.row(row("MonteCarlo", &m, t0.elapsed().as_secs_f64()));

    let t0 = Instant::now();
    let u = auto.evaluate(scenario);
    t.row(row("Auto", &u, t0.elapsed().as_secs_f64()));

    t.print();
    println!();
}

fn main() {
    // 1. Closed-form ground: all three backends answer; Analytic and
    //    Auto agree exactly, MonteCarlo agrees within its CI.
    compare(
        "N=100, B=20, tau ~ SExp(0.05, 1): closed form exists",
        &Scenario::balanced(100, 20, ServiceDist::shifted_exp(0.05, 1.0)),
    );

    // 2. Bimodal stragglers: no closed form — Analytic errors cleanly,
    //    Auto transparently falls back to Monte-Carlo.
    compare(
        "N=100, B=20, tau ~ bimodal stragglers: Monte-Carlo territory",
        &Scenario::balanced(
            100,
            20,
            ServiceDist::bimodal(0.1, (0.1, 10.0), (5.0, 1.0)),
        ),
    );

    // 3. Overlapping policy: closed forms don't cover overlap either.
    compare(
        "N=6, cyclic overlap (Fig. 5 scheme 1), tau ~ Exp(1)",
        &Scenario::new(
            6,
            Policy::CyclicOverlapping { batches: 3 },
            ServiceDist::exp(1.0),
        ),
    );
}
