//! Trace replay: the paper's §VII experiment end-to-end.
//!
//! Generates a Google-cluster-shaped trace (10 jobs, two tail
//! families), classifies every job's tail, sweeps the redundancy level
//! by trace-driven simulation, and reports the per-job optimum and the
//! headline speedup — Figs. 11–13.
//!
//! ```bash
//! cargo run --release --example trace_replay [-- --tasks 100 --reps 20000]
//! ```

use replica::experiments::traces_exp;
use replica::metrics::{fnum, Table};
use replica::planner::{plan_from_samples, Objective};
use replica::traces::JobAnalysis;

fn main() -> replica::Result<()> {
    let reps = 10_000;
    let seed = 42;
    let trace = traces_exp::standard_trace(seed);

    // ---- Fig 11: tail classification ----
    let mut t = Table::new(
        "Fig 11: per-job task service times (synthetic Google-shaped trace)",
        vec!["job", "mean (s)", "min (s)", "p99 (s)", "tail class", "fitted model"],
    );
    for a in JobAnalysis::all(&trace) {
        let class = if a.is_heavy_tail() { "heavy" } else { "exp" };
        t.row(vec![
            a.job_id.to_string(),
            fnum(a.mean),
            fnum(a.min),
            fnum(a.p99),
            class.to_string(),
            a.fit.best().label(),
        ]);
    }
    t.print();
    println!();

    // ---- Figs 12 & 13: redundancy sweeps ----
    traces_exp::table(
        "Fig 12: normalized E[T] vs B — exponential-tail jobs (1-5)",
        &trace,
        &traces_exp::EXP_TAIL_JOBS,
        reps,
        seed,
    )?
    .print();
    println!();
    traces_exp::table(
        "Fig 13: normalized E[T] vs B — heavy-tail jobs (6-10)",
        &trace,
        &traces_exp::HEAVY_TAIL_JOBS,
        reps,
        seed,
    )?
    .print();

    // ---- planner vs sweep: does the analytic plan match? ----
    println!();
    let mut p = Table::new(
        "planner recommendation per job (record-driven sweep plan)",
        vec!["job", "fitted", "planned B*", "sweep B*"],
    );
    for a in JobAnalysis::all(&trace) {
        let (plan, fit) =
            plan_from_samples(a.n_tasks, a.empirical.data(), Objective::MeanCompletion);
        let sweep = traces_exp::job_sweep(&trace, a.job_id, 4_000, seed)?;
        let sweep_best =
            sweep.iter().min_by(|x, y| x.1.partial_cmp(&y.1).unwrap()).unwrap().0;
        p.row(vec![
            a.job_id.to_string(),
            fit.best().label(),
            plan.batches.to_string(),
            sweep_best.to_string(),
        ]);
    }
    p.print();

    let headline = traces_exp::headline_speedup(&trace, reps, seed)?;
    println!(
        "\nheadline: best heavy-tail job speeds up {}x with planned redundancy \
         (paper: \"an order of magnitude\")",
        fnum(headline)
    );
    Ok(())
}
