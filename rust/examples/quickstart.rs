//! Quickstart: plan a redundancy level, then verify it by simulation.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use replica::dist::ServiceDist;
use replica::eval::{Estimator, MonteCarlo, Scenario};
use replica::metrics::{fnum, Table};
use replica::planner::{Objective, Planner};

fn main() -> replica::Result<()> {
    // A cluster of N = 100 workers whose task service times are
    // shifted-exponential: at least 50 ms, then an Exp(1) tail.
    let n = 100;
    let tau = ServiceDist::shifted_exp(0.05, 1.0);

    println!("service model: {}\n", tau.label());

    // 1. Plan the optimal batch count for mean completion time.
    let planner = Planner::new(n, tau.clone());
    let plan = planner.plan(Objective::MeanCompletion);
    println!(
        "planner: split the job into B = {} batches of {} tasks, each \
         replicated on {} workers ({:?} regime)",
        plan.batches,
        plan.batch_size,
        plan.replication,
        plan.regime.unwrap()
    );
    println!(
        "predicted E[T] = {}  (speedup {}x over no redundancy)\n",
        fnum(plan.predicted_mean),
        fnum(plan.speedup_vs_no_redundancy)
    );

    // 2. Verify by Monte-Carlo across the whole spectrum: the estimator
    //    sweep gives every operating point its own RNG substream and
    //    fans replications across all cores, bit-stable per seed.
    let mut table = Table::new(
        "diversity–parallelism spectrum (20k replications per point)",
        vec!["B", "replication", "E[T] analytic", "E[T] simulated", "CoV"],
    );
    let analytic = planner.sweep();
    let mc = MonteCarlo::new(20_000, 42);
    for (point, (_, est)) in analytic.iter().zip(mc.sweep(n, &tau)?) {
        let marker = if point.batches == plan.batches {
            " <- planned"
        } else {
            ""
        };
        table.row(vec![
            format!("{}{marker}", point.batches),
            (n / point.batches).to_string(),
            fnum(point.mean),
            format!("{} ± {}", fnum(est.mean), fnum(est.ci95)),
            fnum(est.cov),
        ]);
    }
    table.print();

    // ... or ask about a single scenario directly:
    let one = mc.evaluate(&Scenario::balanced(n, plan.batches, tau.clone()))?;
    println!(
        "\nplanned point via {}: p50 {} / p95 {} / p99 {}",
        one.provenance.backend(),
        fnum(one.p50),
        fnum(one.p95),
        fnum(one.p99)
    );

    // 3. The predictability trade-off (Theorems 4/7/10).
    let cov_plan = planner.plan(Objective::Predictability);
    println!(
        "\nmost predictable point: B = {} (CoV {}) — mean-optimal was B = {}:",
        cov_plan.batches,
        fnum(cov_plan.predicted_cov),
        plan.batches
    );
    println!("optimizing for predictability costs mean completion time (§VI).");
    Ok(())
}
