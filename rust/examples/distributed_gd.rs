//! End-to-end driver: live distributed gradient descent through all
//! three layers.
//!
//! * L1/L2 — the gradient kernel + model were written in JAX/Pallas and
//!   AOT-compiled to `artifacts/*.hlo.txt` (`make artifacts`);
//! * runtime — this binary loads them via PJRT and serves executions to
//!   the worker pool (Python is NOT running);
//! * L3 — the coordinator plans replication for a heavy-tail straggler
//!   model, injects sampled delays, applies first-copy-wins, and trains
//!   a linear model for several hundred rounds, logging the loss curve.
//!
//! It then re-runs the same workload at three operating points
//! (B = 1, planned B*, B = N) and reports the latency comparison — the
//! paper's diversity–parallelism experiment on a *live* system.
//!
//! ```bash
//! make artifacts && cargo run --release --example distributed_gd
//! ```

use std::sync::Arc;

use replica::coordinator::{
    ComputeBackend, Coordinator, Dataset, GdConfig, NativeBackend, PjrtBackend,
};
use replica::dist::ServiceDist;
use replica::metrics::{fnum, Table};
use replica::planner::{Objective, Planner};
use replica::runtime::{artifacts_available, artifacts_dir, GradientOps, RuntimeService};

fn main() -> replica::Result<()> {
    let workers = 16;
    let rounds = 300;
    // Heavy-tailed stragglers: the regime where replication shines.
    let straggler = ServiceDist::pareto(0.02, 1.3);

    // ---- backend: PJRT artifacts if available, native otherwise ----
    let mut _service_keepalive = None;
    let (backend, m, d, backend_name): (Arc<dyn ComputeBackend>, usize, usize, &str) =
        if artifacts_available() {
            let service = RuntimeService::start(&artifacts_dir())?;
            let manifest = service.handle().manifest().clone();
            let ops = GradientOps::new(service.handle(), manifest.m)?;
            let (m, d) = (ops.m, ops.d);
            let b = Arc::new(PjrtBackend::new(ops));
            _service_keepalive = Some(service);
            (b, m, d, "pjrt (AOT JAX+Pallas artifacts)")
        } else {
            eprintln!("note: artifacts/ missing — run `make artifacts` for the PJRT path;");
            eprintln!("      falling back to the native Rust backend.\n");
            (Arc::new(NativeBackend::new(256, 64)), 256, 64, "native")
        };
    println!("backend: {backend_name}  (shard {m}x{d}, {workers} workers)\n");

    // ---- plan replication for the straggler model ----
    let plan = Planner::new(workers, straggler.clone()).plan(Objective::MeanCompletion);
    println!(
        "planned operating point: B = {} (replication {}), predicted speedup {}x\n",
        plan.batches,
        plan.replication,
        fnum(plan.speedup_vs_no_redundancy)
    );

    // ---- train at the planned point, log the loss curve ----
    let cfg = GdConfig {
        workers,
        batches: plan.batches,
        rounds,
        lr: 0.2,
        straggler: straggler.clone(),
        time_scale: 2e-4,
        seed: 7,
    };
    let dataset = Dataset::synthetic(workers, m, d, 0.05, 1234);
    let mut coord = Coordinator::new(cfg.clone(), dataset.clone(), backend.clone())?;
    let report = coord.run()?;

    let mut curve = Table::new(
        &format!("loss curve (B = {}, {rounds} rounds)", plan.batches),
        vec!["round", "train loss", "round latency (ms)"],
    );
    for (i, r) in report.rounds.iter().enumerate() {
        if i % 30 == 0 || i + 1 == rounds {
            curve.row(vec![i.to_string(), fnum(r.loss), fnum(r.latency * 1e3)]);
        }
    }
    curve.print();
    println!(
        "\nfinal global loss: {}   late replicas discarded: {}\n",
        fnum(report.final_global_loss),
        report.total_discarded
    );

    // ---- latency comparison across the spectrum ----
    //
    // For the comparison the injected straggler delays must dominate the
    // (single-core, serialized) PJRT compute — otherwise the replicas'
    // redundant compute masks the queueing effect the paper analyzes.
    // time_scale = 1.0 puts mean delays in the 100 ms – 1 s range vs
    // ~1 ms per gradient execution.
    let mut cmp = Table::new(
        "operating-point comparison (same workload, 30 rounds each, delay-dominant)",
        vec!["B", "mode", "mean round latency (ms)", "final loss"],
    );
    let mut planned_latency = None;
    let mut parallel_latency = None;
    for b in [1, plan.batches, workers] {
        let mut c = cfg.clone();
        c.batches = b;
        c.rounds = 30;
        c.time_scale = 1.0;
        let mut coord = Coordinator::new(c, dataset.clone(), backend.clone())?;
        let rep = coord.run()?;
        let mode = if b == 1 {
            "full diversity"
        } else if b == workers {
            "full parallelism"
        } else {
            "planned"
        };
        if b == plan.batches {
            planned_latency = Some(rep.mean_latency());
        }
        if b == workers {
            parallel_latency = Some(rep.mean_latency());
        }
        cmp.row(vec![
            b.to_string(),
            mode.to_string(),
            fnum(rep.mean_latency() * 1e3),
            fnum(rep.final_global_loss),
        ]);
    }
    cmp.print();
    if let (Some(p), Some(np)) = (planned_latency, parallel_latency) {
        println!(
            "\nmeasured speedup of planned redundancy vs no redundancy: {}x",
            fnum(np / p)
        );
    }
    Ok(())
}
