//@path: src/sweep/notes.rs
//! Doc comment mentions HashMap, x.unwrap() and Instant::now().

/* block comment: SystemTime::now, static mut, Pcg64::new(1)
   /* nested: .expect( todo! */ still a comment */
pub fn describe() -> String {
    let plain = "HashMap .unwrap() Instant::now() env::var";
    let raw = r#"panic!("inside a raw string") todo!"#;
    let brace = '{';
    let escaped = '\n';
    format!("{plain}{raw}{brace}{escaped}")
}
