//@path: src/dist/sampling.rs
pub fn support() -> usize {
    4
}

#[cfg(test)]
mod tests {
    use crate::util::rng::Pcg64;

    #[test]
    fn seeded_rng_is_fine_in_tests() {
        let mut rng = Pcg64::new(7);
        assert!(rng.next_f64() >= 0.0);
    }
}
