//@path: src/eval/batch_ok.rs
use crate::sim::pool::WorkerPool;

fn reduce(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>()
}
