//@path: src/util/clock.rs
use std::time::Instant;

pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { origin: Instant::now() }
    }

    pub fn now_millis(&self) -> u64 {
        self.origin.elapsed().as_millis() as u64
    }
}
