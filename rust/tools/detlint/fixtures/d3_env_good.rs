//@path: src/config/env.rs
pub fn knob() -> Option<String> {
    std::env::var("REPLICA_KNOB").ok()
}
