//@path: src/eval/batch.rs
use crate::sim::pool::WorkerPool;

pub fn mean_of(xs: &[f64]) -> f64 {
    let total = xs.iter().sum::<f64>();
    total / xs.len() as f64
}
