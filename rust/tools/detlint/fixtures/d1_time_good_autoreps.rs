//@path: src/eval/until.rs
//! The deterministic form of the same loop: waves double until the
//! accumulated ci95 half-width meets the target or the rep ceiling —
//! no clocks, no environment reads, so shards and resumes agree
//! bitwise on the realized count.

pub fn until_ci95(eps: f64, max: usize) -> usize {
    let mut reps = 64usize.min(max);
    loop {
        let ci95 = wave_ci95(reps);
        // NaN ci95 (fewer than two completions) compares false and
        // keeps doubling toward the ceiling
        if ci95 <= eps || reps == max {
            return reps;
        }
        reps = reps.saturating_mul(2).min(max);
    }
}

fn wave_ci95(reps: usize) -> f64 {
    1.0 / reps as f64
}
