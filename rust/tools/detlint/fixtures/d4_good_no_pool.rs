//@path: src/analysis/moments.rs
pub fn mean_of(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}
