//@path: src/util/counter.rs
static mut HITS: u64 = 0;

pub fn bump() {
    // a real implementation would also need unsafe access
}
