//@path: src/util/counter_atomic.rs
use std::sync::atomic::{AtomicU64, Ordering};

static HITS: AtomicU64 = AtomicU64::new(0);

pub fn bump() {
    HITS.fetch_add(1, Ordering::Relaxed);
}
