//@path: src/sim/tuning.rs
pub fn knob() -> Option<String> {
    std::env::var("REPLICA_KNOB").ok()
}
