//@path: src/eval/streams.rs
use crate::eval::substream;
use crate::util::rng::Pcg64;

pub fn stream(seed: u64, index: u64) -> Pcg64 {
    Pcg64::new(substream(seed, index))
}
