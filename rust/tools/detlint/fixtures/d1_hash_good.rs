//@path: src/runtime/lookup.rs
use std::collections::HashMap;

pub struct Lookup {
    entries: HashMap<u64, String>,
}
