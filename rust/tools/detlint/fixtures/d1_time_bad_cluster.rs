//@path: src/cluster/server.rs
use std::time::Instant;

pub fn lease_deadline() -> Instant {
    // cluster code must inject util::clock::Clock instead
    Instant::now()
}
