//@path: src/sweep/cache_map.rs
use std::collections::HashMap;

pub struct Cache {
    entries: HashMap<u64, String>,
}
