//@path: src/eval/until.rs
//! A precision-targeted stopping loop must be a function of the
//! accumulated estimate alone; this one reads the wall clock and the
//! process environment, so sharded and resumed runs would disagree.
use std::time::Instant;

pub fn until_ci95_wallclock(eps: f64, max: usize) -> usize {
    let start = Instant::now();
    let budget: u64 = match std::env::var("REPLICA_AUTO_BUDGET_SECS") {
        Ok(v) => v.parse().unwrap_or(60),
        Err(_) => 60,
    };
    let mut reps = 64usize.min(max);
    loop {
        let ci95 = wave_ci95(reps);
        if ci95 <= eps || reps == max || start.elapsed().as_secs() >= budget {
            return reps;
        }
        reps = reps.saturating_mul(2).min(max);
    }
}

fn wave_ci95(reps: usize) -> f64 {
    1.0 / reps as f64
}
