//@path: src/util/numbers.rs
pub fn parse(s: &str) -> u32 {
    let v = s.parse::<u32>().unwrap();
    let w = v.checked_add(1).expect("overflow");
    if w == 0 {
        panic!("zero");
    }
    todo!()
}
