//@path: benches/bench_clock.rs
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    println!("{:?}", t0.elapsed());
}
