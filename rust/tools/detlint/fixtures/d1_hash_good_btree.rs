//@path: src/sweep/cache_tree.rs
use std::collections::BTreeMap;

pub struct Cache {
    entries: BTreeMap<u64, String>,
}
