//@path: src/metrics/wallclock.rs
use std::time::Instant;

pub fn stamp() -> Instant {
    Instant::now()
}
