//@path: src/util/checked.rs
pub fn safe(v: Option<u32>) -> u32 {
    v.unwrap_or(0).max(v.unwrap_or_else(|| 1))
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_are_fine_in_tests() {
        let x: Option<u32> = Some(1);
        assert_eq!(x.unwrap(), 1);
        let y: Option<u32> = Some(2);
        y.expect("present");
        if false {
            panic!("unreached");
        }
    }
}
