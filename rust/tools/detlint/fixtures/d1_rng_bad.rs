//@path: src/dist/jitter.rs
use crate::util::rng::Pcg64;

pub fn jitter() -> f64 {
    let mut rng = Pcg64::new(42);
    rng.next_f64()
}
