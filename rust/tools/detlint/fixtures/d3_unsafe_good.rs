//@path: src/util/bytes_ok.rs
pub fn first_byte(v: &[u8]) -> u8 {
    assert!(!v.is_empty());
    // SAFETY: the assert above guarantees the slice is non-empty, so
    // the pointer is valid for one read.
    unsafe { *v.as_ptr() }
}
