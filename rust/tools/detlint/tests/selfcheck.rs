//! The shipped configuration must hold: linting the real crate with
//! the committed `detlint.toml` yields zero findings — no unfixed
//! violations, no unjustified or stale allowlist entries.

use std::path::PathBuf;

use detlint::{lint_repo, Config};

fn rust_root() -> PathBuf {
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    root.pop();
    root.pop();
    root
}

#[test]
fn repo_is_clean_under_shipped_config() {
    let root = rust_root();
    let text = std::fs::read_to_string(root.join("detlint.toml"))
        .expect("detlint.toml is committed at the rust/ root");
    let cfg = Config::parse(&text).expect("detlint.toml parses");
    let report = lint_repo(&root, &cfg).expect("walk the crate");
    let rendered: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule.id(), f.message))
        .collect();
    assert!(
        rendered.is_empty(),
        "detlint is not clean on the repo:\n{}",
        rendered.join("\n")
    );
    assert!(report.files >= 60, "expected the whole crate, scanned {}", report.files);
}

#[test]
fn shipped_config_justifies_every_entry() {
    let root = rust_root();
    let text = std::fs::read_to_string(root.join("detlint.toml"))
        .expect("detlint.toml is committed at the rust/ root");
    let cfg = Config::parse(&text).expect("detlint.toml parses");
    assert!(!cfg.allows.is_empty(), "the shipped allowlist documents known exceptions");
    for entry in &cfg.allows {
        assert!(
            entry.reason.trim().len() >= 10,
            "detlint.toml:{}: reason too thin: {:?}",
            entry.line,
            entry.reason
        );
    }
}
