//! Golden-file tests over the fixture corpus: every fixture's findings
//! must match its `.expected` file exactly, and the corpus must give
//! every rule at least one true-positive and one true-negative.

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

use detlint::{lint_repo, lint_source, Config};

fn fixtures_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

fn fixture_sources() -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = fs::read_dir(fixtures_dir())
        .expect("fixtures/ exists")
        .map(|e| e.expect("read fixtures dir").path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("rs"))
        .collect();
    out.sort();
    out
}

/// The `//@path:` directive on a fixture's first line.
fn pseudo_path(path: &Path, src: &str) -> String {
    src.lines()
        .next()
        .and_then(|l| l.strip_prefix("//@path:"))
        .unwrap_or_else(|| panic!("{}: missing //@path directive", path.display()))
        .trim()
        .to_string()
}

#[test]
fn fixtures_match_goldens() {
    let sources = fixture_sources();
    assert!(sources.len() >= 16, "fixture corpus shrank: {} files", sources.len());
    for path in sources {
        let src = fs::read_to_string(&path).expect("read fixture");
        let pseudo = pseudo_path(&path, &src);
        let got: Vec<String> = lint_source(&pseudo, &src, &Config::default())
            .into_iter()
            .map(|f| format!("{} {}", f.line, f.rule.id()))
            .collect();
        let golden = path.with_extension("expected");
        let want: Vec<String> = fs::read_to_string(&golden)
            .unwrap_or_else(|_| panic!("{}: missing golden file", golden.display()))
            .lines()
            .map(|l| l.trim().to_string())
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .collect();
        assert_eq!(got, want, "{} disagrees with its golden", path.display());
    }
}

#[test]
fn every_rule_has_positive_and_negative_fixtures() {
    let all_rules: BTreeSet<&str> = [
        "D1-TIME", "D1-HASH", "D1-RNG", "D2", "D3-MUT", "D3-ENV", "D3-UNSAFE", "D4",
    ]
    .into_iter()
    .collect();
    let mut positives: BTreeSet<String> = BTreeSet::new();
    let mut negative_stems: BTreeSet<String> = BTreeSet::new();
    for path in fixture_sources() {
        let src = fs::read_to_string(&path).expect("read fixture");
        let findings = lint_source(&pseudo_path(&path, &src), &src, &Config::default());
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("").to_string();
        if findings.is_empty() {
            negative_stems.insert(stem);
        } else {
            for f in findings {
                positives.insert(f.rule.id().to_string());
            }
        }
    }
    for rule in &all_rules {
        assert!(positives.contains(*rule), "no true-positive fixture for {rule}");
        let prefix = rule.to_lowercase().replace('-', "_");
        assert!(
            negative_stems.iter().any(|s| s.starts_with(&prefix) && s.contains("good"))
                || negative_stems.contains("lexer_tricky"),
            "no true-negative fixture for {rule}"
        );
    }
}

/// A scratch repo layout for exercising `lint_repo` end to end.
fn scratch_repo(tag: &str, lib_rs: &str) -> PathBuf {
    let root = std::env::temp_dir().join(format!("detlint-test-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&root);
    fs::create_dir_all(root.join("src")).expect("create scratch repo");
    fs::write(root.join("src/lib.rs"), lib_rs).expect("write scratch lib.rs");
    root
}

#[test]
fn allowlist_suppresses_matches_and_flags_stale_entries() {
    let root = scratch_repo("allow", "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n");
    let toml = "\
[[allow]]
file = \"src/lib.rs\"
rule = \"D2\"
pattern = \".unwrap()\"
reason = \"exercised by the golden test\"

[[allow]]
file = \"src/lib.rs\"
rule = \"D2\"
pattern = \".expect(\"
reason = \"nothing matches this pattern\"

[[allow]]
file = \"src/gone.rs\"
rule = \"D2\"
pattern = \".unwrap()\"
reason = \"file was deleted\"
";
    let cfg = Config::parse(toml).expect("config parses");
    let report = lint_repo(&root, &cfg).expect("lint scratch repo");
    fs::remove_dir_all(&root).expect("clean up scratch repo");

    let rendered: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{} {} {}", f.file, f.line, f.rule.id(), f.message))
        .collect();
    // the unwrap is suppressed; the other two entries are stale
    assert_eq!(rendered.len(), 2, "got: {rendered:?}");
    assert!(rendered[0].contains("detlint.toml:7"), "got: {rendered:?}");
    assert!(rendered[0].contains("suppresses nothing"), "got: {rendered:?}");
    assert!(rendered[1].contains("detlint.toml:13"), "got: {rendered:?}");
    assert!(rendered[1].contains("does not exist"), "got: {rendered:?}");
}

#[test]
fn unexplained_allowlist_entry_is_a_finding() {
    let root = scratch_repo("reason", "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n");
    let toml = "\
[[allow]]
file = \"src/lib.rs\"
rule = \"D2\"
pattern = \".unwrap()\"
";
    let cfg = Config::parse(toml).expect("config parses");
    let report = lint_repo(&root, &cfg).expect("lint scratch repo");
    fs::remove_dir_all(&root).expect("clean up scratch repo");

    assert_eq!(report.findings.len(), 1);
    let f = &report.findings[0];
    assert_eq!(f.rule.id(), "ALLOWLIST");
    assert!(f.message.contains("justification"), "got: {}", f.message);
}

#[test]
fn unsuppressed_findings_survive_lint_repo() {
    let root = scratch_repo("plain", "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n");
    let report = lint_repo(&root, &Config::default()).expect("lint scratch repo");
    fs::remove_dir_all(&root).expect("clean up scratch repo");

    assert_eq!(report.files, 1);
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].rule.id(), "D2");
    assert_eq!(report.findings[0].line, 2);
}
