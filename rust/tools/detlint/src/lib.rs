//! detlint — source-level determinism & safety lint for the replica
//! crate.
//!
//! The crate's headline guarantee is that estimates are bit-identical
//! across thread counts, shard layouts, and kill/resume. detlint
//! enforces the source-level half of that contract (see the
//! "Determinism contract" section of `rust/README.md`):
//!
//! - **D1-TIME** — no `Instant::now`/`SystemTime::now` outside
//!   `metrics/` and `benches/`.
//! - **D1-HASH** — no `HashMap`/`HashSet` in result-serializing
//!   modules (`sweep/`, `metrics/`, `planner/`, `util/json.rs`).
//! - **D1-RNG** — no direct `Pcg64::new` seeding outside `util/rng`
//!   and `eval/` (substream derivation).
//! - **D2** — no `unwrap`/`expect`/`panic!`/`todo!` in non-test
//!   library code.
//! - **D3-MUT / D3-ENV / D3-UNSAFE** — no `static mut`, no
//!   environment reads outside `config/` + `sim/pool.rs`, and every
//!   `unsafe` carries a `// SAFETY:` comment.
//! - **D4** — float reductions in pool-parallel files must live in a
//!   serial-reduction helper.
//!
//! Violations are either fixed or allowlisted in `rust/detlint.toml`,
//! where every entry needs a one-line justification; unexplained or
//! stale entries are themselves findings (rule `ALLOWLIST`).

pub mod config;
pub mod lexer;
pub mod rules;

pub use config::{AllowEntry, Config};
pub use rules::{lint_source, Finding, Rule};

use std::path::{Path, PathBuf};

/// Directories under the `rust/` root that are linted.
pub const WALK_DIRS: &[&str] = &["src", "tests", "benches", "examples"];

/// Result of a whole-repo lint run.
#[derive(Debug)]
pub struct Report {
    /// Findings that survived the allowlist, plus allowlist problems.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files: usize,
}

/// Lint every `.rs` file under the walk dirs of `root` (the `rust/`
/// directory), apply the allowlist, and validate the allowlist itself.
pub fn lint_repo(root: &Path, cfg: &Config) -> std::io::Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for dir in WALK_DIRS {
        collect_rs_files(&root.join(dir), &mut files)?;
    }
    files.sort();

    let mut findings: Vec<Finding> = Vec::new();
    let mut used = vec![false; cfg.allows.len()];
    for path in &files {
        let rel = rel_path(root, path);
        let src = std::fs::read_to_string(path)?;
        for finding in lint_source(&rel, &src, cfg) {
            let mut suppressed = false;
            for (i, entry) in cfg.allows.iter().enumerate() {
                if entry.file == finding.file
                    && entry.rule == finding.rule.id()
                    && !entry.pattern.is_empty()
                    && finding.raw.contains(&entry.pattern)
                {
                    used[i] = true;
                    suppressed = true;
                }
            }
            if !suppressed {
                findings.push(finding);
            }
        }
    }

    for (i, entry) in cfg.allows.iter().enumerate() {
        let mut bad = |message: String| {
            findings.push(Finding {
                file: "detlint.toml".to_string(),
                line: entry.line,
                rule: Rule::Allowlist,
                message,
                raw: String::new(),
            });
        };
        let well_formed = describe_malformed(entry);
        if let Some(problem) = well_formed {
            bad(problem);
        } else if !root.join(&entry.file).is_file() {
            bad(format!("stale entry: `{}` does not exist", entry.file));
        } else if !used[i] {
            bad(format!(
                "stale entry: `{}` / {} / `{}` suppresses nothing",
                entry.file, entry.rule, entry.pattern
            ));
        }
    }

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(Report { findings, files: files.len() })
}

/// Structural problems with one allowlist entry, if any.
fn describe_malformed(entry: &AllowEntry) -> Option<String> {
    if entry.file.is_empty() {
        return Some("entry is missing `file`".to_string());
    }
    if Rule::from_id(&entry.rule).is_none() {
        return Some(format!("unknown rule `{}`", entry.rule));
    }
    if entry.pattern.is_empty() {
        return Some("entry is missing `pattern` (blanket allows are not allowed)".into());
    }
    if entry.reason.trim().is_empty() {
        return Some(format!(
            "entry for `{}` / {} has no justification (`reason = ...`)",
            entry.file, entry.rule
        ));
    }
    None
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// `root/src/sim/pool.rs` → `src/sim/pool.rs`, with `/` separators.
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_path_uses_forward_slashes() {
        let root = Path::new("/repo/rust");
        let path = Path::new("/repo/rust/src/sim/pool.rs");
        assert_eq!(rel_path(root, path), "src/sim/pool.rs");
    }

    #[test]
    fn malformed_entries_are_described() {
        let mut entry = AllowEntry {
            file: "src/lib.rs".into(),
            rule: "D2".into(),
            pattern: ".unwrap()".into(),
            reason: "because".into(),
            line: 1,
        };
        assert!(describe_malformed(&entry).is_none());
        entry.reason.clear();
        assert!(describe_malformed(&entry).is_some_and(|m| m.contains("justification")));
        entry.rule = "D9".into();
        assert!(describe_malformed(&entry).is_some_and(|m| m.contains("unknown rule")));
        entry.rule = "D2".into();
        entry.pattern.clear();
        assert!(describe_malformed(&entry).is_some_and(|m| m.contains("pattern")));
    }
}
