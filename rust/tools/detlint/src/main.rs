//! CLI entry point: `cargo run -p detlint [-- --root PATH]`.
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/config/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

use detlint::{lint_repo, Config};

fn main() -> ExitCode {
    match run() {
        Ok(findings) if findings == 0 => ExitCode::SUCCESS,
        Ok(_) => ExitCode::FAILURE,
        Err(message) => {
            eprintln!("detlint: {message}");
            ExitCode::from(2)
        }
    }
}

fn run() -> Result<usize, String> {
    // Default root: the rust/ directory two levels above this crate.
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    root.pop();
    root.pop();
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => {
                root = PathBuf::from(
                    args.next().ok_or_else(|| "--root needs a path".to_string())?,
                );
            }
            "--help" | "-h" => {
                println!("usage: detlint [--root PATH]");
                println!("lints src/, tests/, benches/, examples/ under PATH");
                println!("against the rules in PATH/detlint.toml");
                return Ok(0);
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }

    let config_path = root.join("detlint.toml");
    let cfg = if config_path.is_file() {
        let text = std::fs::read_to_string(&config_path)
            .map_err(|e| format!("{}: {e}", config_path.display()))?;
        Config::parse(&text)?
    } else {
        Config::default()
    };

    let report = lint_repo(&root, &cfg).map_err(|e| format!("walking {}: {e}", root.display()))?;
    for f in &report.findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule.id(), f.message);
    }
    if report.findings.is_empty() {
        println!("detlint: clean ({} files)", report.files);
    } else {
        println!("detlint: {} finding(s) in {} files", report.findings.len(), report.files);
    }
    Ok(report.findings.len())
}
