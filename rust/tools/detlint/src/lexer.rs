//! A tiny line-preserving Rust scanner.
//!
//! Not a real lexer: it only needs to tell *code* apart from *comments
//! and literal contents*, so the rule patterns in [`crate::rules`]
//! never fire on a string that happens to contain `.unwrap()` or a
//! comment that mentions `HashMap`. The scanner handles line comments,
//! nested block comments, string literals with escapes (including the
//! `\<newline>` continuation, which must not swallow the line break),
//! raw strings (`r"…"`, `r#"…"#`), and char literals vs lifetimes.
//!
//! Known simplification: byte/raw-byte literals (`b"…"`, `br"…"`) are
//! scanned as ordinary strings, which is fine because `b"…"` allows
//! the same escapes and `br"…"` does not occur in this crate.

/// One source line, split into blanked code and extracted comments.
#[derive(Clone, Debug, Default)]
pub struct LexedLine {
    /// Code with comments and literal *contents* blanked to spaces.
    /// Delimiters (`"`, `'`, `r#"`) are preserved so columns line up.
    pub code: String,
    /// The comment text that appeared on this line, if any.
    pub comment: String,
}

enum State {
    Normal,
    LineComment,
    /// Block comment with its nesting depth.
    Block(u32),
    Str,
    /// Raw string with its `#` count.
    RawStr(usize),
    Char,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Scan `src` into per-line code/comment pairs. Line `i` of the input
/// (0-based) is element `i` of the output.
pub fn lex(src: &str) -> Vec<LexedLine> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Vec::new();
    let mut line = LexedLine::default();
    let mut state = State::Normal;
    let mut i = 0;
    while i < n {
        let c = chars[i];
        let next = if i + 1 < n { chars[i + 1] } else { '\0' };
        if c == '\n' {
            out.push(std::mem::take(&mut line));
            if matches!(state, State::LineComment) {
                state = State::Normal;
            }
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                if c == '/' && next == '/' {
                    state = State::LineComment;
                    line.code.push_str("  ");
                    i += 2;
                } else if c == '/' && next == '*' {
                    state = State::Block(1);
                    line.code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    line.code.push('"');
                    i += 1;
                } else if c == 'r' && raw_string_hashes(&chars, i).is_some() {
                    let hashes = raw_string_hashes(&chars, i).unwrap_or(0);
                    state = State::RawStr(hashes);
                    line.code.push('r');
                    for _ in 0..hashes {
                        line.code.push('#');
                    }
                    line.code.push('"');
                    i += 2 + hashes;
                } else if c == '\'' {
                    if next == '\\' {
                        // escaped char literal: '\n', '\u{..}', ...
                        state = State::Char;
                        line.code.push('\'');
                        i += 1;
                    } else if i + 2 < n && chars[i + 2] == '\'' && next != '\'' {
                        // simple char literal 'x' — blank the payload so
                        // '{' and '}' cannot corrupt brace depth
                        line.code.push('\'');
                        line.code.push_str("  ");
                        i += 3;
                    } else {
                        // a lifetime: keep as code
                        line.code.push('\'');
                        i += 1;
                    }
                } else {
                    line.code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                line.comment.push(c);
                line.code.push(' ');
                i += 1;
            }
            State::Block(depth) => {
                if c == '/' && next == '*' {
                    state = State::Block(depth + 1);
                    line.code.push_str("  ");
                    i += 2;
                } else if c == '*' && next == '/' {
                    line.code.push_str("  ");
                    state = if depth == 1 {
                        State::Normal
                    } else {
                        State::Block(depth - 1)
                    };
                    i += 2;
                } else {
                    line.comment.push(c);
                    line.code.push(' ');
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    if next == '\n' {
                        // string continuation: let the main loop see the
                        // newline so line numbers stay correct
                        line.code.push(' ');
                        i += 1;
                    } else {
                        line.code.push_str("  ");
                        i += 2;
                    }
                } else if c == '"' {
                    state = State::Normal;
                    line.code.push('"');
                    i += 1;
                } else {
                    line.code.push(' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                let closes = c == '"'
                    && chars[i + 1..].iter().take(hashes).filter(|&&h| h == '#').count()
                        == hashes;
                if closes {
                    state = State::Normal;
                    line.code.push('"');
                    for _ in 0..hashes {
                        line.code.push('#');
                    }
                    i += 1 + hashes;
                } else {
                    line.code.push(' ');
                    i += 1;
                }
            }
            State::Char => {
                if c == '\\' {
                    line.code.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    state = State::Normal;
                    line.code.push('\'');
                    i += 1;
                } else {
                    line.code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !line.code.is_empty() || !line.comment.is_empty() {
        out.push(line);
    }
    out
}

/// If position `i` (an `r`) starts a raw string, return its `#` count.
/// The `r` must not be the tail of an identifier (`for r in ...`).
fn raw_string_hashes(chars: &[char], i: usize) -> Option<usize> {
    if i > 0 && is_ident(chars[i - 1]) {
        return None;
    }
    let mut j = i + 1;
    let mut hashes = 0;
    while j < chars.len() && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < chars.len() && chars[j] == '"' {
        Some(hashes)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_are_blanked() {
        let code = code_of("let x = 1; // x.unwrap()\n");
        assert_eq!(code[0].trim_end(), "let x = 1;");
        let comments: Vec<String> = lex("let x = 1; // x.unwrap()\n")
            .into_iter()
            .map(|l| l.comment)
            .collect();
        assert!(comments[0].contains("x.unwrap()"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* one /* two */ still */ b\nc\n";
        let code = code_of(src);
        assert!(!code[0].contains("one"));
        assert!(!code[0].contains("still"));
        assert!(code[0].contains('a') && code[0].contains('b'));
        assert_eq!(code[1].trim_end(), "c");
    }

    #[test]
    fn string_contents_are_blanked_delimiters_kept() {
        let code = code_of("let s = \".unwrap()\";\n");
        assert!(!code[0].contains(".unwrap()"));
        assert!(code[0].contains('"'));
    }

    #[test]
    fn string_continuation_keeps_line_count() {
        let src = "let s = \"first \\\n    second\";\nlet y = 2;\n";
        let code = code_of(src);
        assert_eq!(code.len(), 3);
        assert_eq!(code[2].trim_end(), "let y = 2;");
    }

    #[test]
    fn raw_strings() {
        let code = code_of("let s = r#\"no \".unwrap()\" here\"#;\nnext\n");
        assert!(!code[0].contains(".unwrap()"));
        assert_eq!(code[1].trim_end(), "next");
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let code = code_of("let c = '{'; let v: Vec<&'static str> = vec![];\n");
        assert!(!code[0].contains('{'), "char payload must be blanked: {}", code[0]);
        assert!(code[0].contains("'static"));
    }

    #[test]
    fn escaped_char_literal() {
        let code = code_of("let c = '\\n'; let d = x.unwrap();\n");
        assert!(code[0].contains(".unwrap()"));
    }

    #[test]
    fn multiline_string_tracks_lines() {
        let src = "let s = \"line one\nline two\";\nlet z = 1;\n";
        let code = code_of(src);
        assert_eq!(code.len(), 3);
        assert!(!code[1].contains("line two"));
        assert_eq!(code[2].trim_end(), "let z = 1;");
    }
}
