//! Hand-rolled parser for `detlint.toml`.
//!
//! The environment is fully offline, so no `toml` crate: the config is
//! restricted to the tiny subset the lint needs — `[[allow]]` tables
//! with string values and a `[d4]` table with one string array. Every
//! allowlist entry must carry a one-line `reason`; entries without one
//! are reported as lint errors by [`crate::lint_repo`], not here.

/// One `[[allow]]` entry: suppress findings of `rule` in `file` on
/// lines whose raw text contains `pattern`, because `reason`.
#[derive(Clone, Debug)]
pub struct AllowEntry {
    pub file: String,
    pub rule: String,
    pub pattern: String,
    pub reason: String,
    /// Line of the `[[allow]]` header in detlint.toml (diagnostics).
    pub line: usize,
}

/// Parsed detlint configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub allows: Vec<AllowEntry>,
    /// Function names that are allowed to hold float reductions in
    /// pool-parallel files (the serial-reduction helpers, rule D4).
    pub d4_helpers: Vec<String>,
}

impl Default for Config {
    fn default() -> Config {
        Config { allows: Vec::new(), d4_helpers: vec!["reduce".to_string()] }
    }
}

enum Section {
    Top,
    Allow,
    D4,
}

impl Config {
    /// Parse the configuration text. Structural problems (unknown
    /// sections or keys, unquoted values) are hard errors; *semantic*
    /// problems (stale entries, missing reasons) are lint findings.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = Section::Top;
        for (idx, raw) in text.lines().enumerate() {
            let ln = idx + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line == "[[allow]]" {
                cfg.allows.push(AllowEntry {
                    file: String::new(),
                    rule: String::new(),
                    pattern: String::new(),
                    reason: String::new(),
                    line: ln,
                });
                section = Section::Allow;
            } else if line == "[d4]" {
                section = Section::D4;
            } else if line.starts_with('[') {
                return Err(format!("detlint.toml:{ln}: unknown section `{line}`"));
            } else if let Some((key, value)) = line.split_once('=') {
                let key = key.trim();
                let value = value.trim();
                match section {
                    Section::Top => {
                        return Err(format!(
                            "detlint.toml:{ln}: key `{key}` outside any section"
                        ));
                    }
                    Section::Allow => {
                        let entry = cfg
                            .allows
                            .last_mut()
                            .ok_or_else(|| format!("detlint.toml:{ln}: no open entry"))?;
                        let s = unquote(value).ok_or_else(|| {
                            format!("detlint.toml:{ln}: `{key}` wants a quoted string")
                        })?;
                        match key {
                            "file" => entry.file = s,
                            "rule" => entry.rule = s,
                            "pattern" => entry.pattern = s,
                            "reason" => entry.reason = s,
                            _ => {
                                return Err(format!(
                                    "detlint.toml:{ln}: unknown key `{key}` in [[allow]]"
                                ));
                            }
                        }
                    }
                    Section::D4 => match key {
                        "helpers" => {
                            cfg.d4_helpers = parse_string_array(value).ok_or_else(|| {
                                format!(
                                    "detlint.toml:{ln}: `helpers` wants an array of strings"
                                )
                            })?;
                        }
                        _ => {
                            return Err(format!(
                                "detlint.toml:{ln}: unknown key `{key}` in [d4]"
                            ));
                        }
                    },
                }
            } else {
                return Err(format!("detlint.toml:{ln}: cannot parse `{line}`"));
            }
        }
        Ok(cfg)
    }
}

/// `"text"` → `text`. Rejects anything else, including embedded quotes
/// (patterns never need them: they match raw source substrings).
fn unquote(value: &str) -> Option<String> {
    let inner = value.strip_prefix('"')?.strip_suffix('"')?;
    if inner.contains('"') {
        return None;
    }
    Some(inner.to_string())
}

fn parse_string_array(value: &str) -> Option<Vec<String>> {
    let inner = value.strip_prefix('[')?.strip_suffix(']')?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(unquote(part)?);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_entries_and_helpers() {
        let text = "\
# a comment

[d4]
helpers = [\"reduce\", \"merge_serial\"]

[[allow]]
file = \"src/sim/pool.rs\"
rule = \"D2\"
pattern = \".unwrap()\"
reason = \"poisoning implies a worker already panicked\"
";
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.d4_helpers, vec!["reduce", "merge_serial"]);
        assert_eq!(cfg.allows.len(), 1);
        let e = &cfg.allows[0];
        assert_eq!(e.file, "src/sim/pool.rs");
        assert_eq!(e.rule, "D2");
        assert_eq!(e.pattern, ".unwrap()");
        assert!(e.reason.contains("panicked"));
        assert_eq!(e.line, 6);
    }

    #[test]
    fn empty_config_keeps_default_helpers() {
        let cfg = Config::parse("").unwrap();
        assert!(cfg.allows.is_empty());
        assert_eq!(cfg.d4_helpers, vec!["reduce"]);
    }

    #[test]
    fn rejects_unknown_section() {
        assert!(Config::parse("[nope]\n").is_err());
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(Config::parse("[[allow]]\nfiles = \"x\"\n").is_err());
    }

    #[test]
    fn rejects_unquoted_value() {
        assert!(Config::parse("[[allow]]\nfile = src/lib.rs\n").is_err());
    }

    #[test]
    fn rejects_key_outside_section() {
        assert!(Config::parse("file = \"x\"\n").is_err());
    }
}
