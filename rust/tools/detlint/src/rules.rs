//! The determinism & safety rules (D1–D4) and the per-file scanner.
//!
//! Rules operate on comment/literal-blanked code lines from
//! [`crate::lexer`], with two pieces of region state tracked by brace
//! depth: test regions (`#[cfg(test)]` mods and `#[test]` fns, where
//! most rules do not apply) and the enclosing function name (for the
//! D4 serial-reduction helpers).

use crate::config::Config;
use crate::lexer::{lex, LexedLine};

/// Rule identifiers. `Allowlist` covers problems with detlint.toml
/// itself (missing justification, stale entry) — those are produced by
/// [`crate::lint_repo`], never by [`lint_source`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    D1Time,
    D1Hash,
    D1Rng,
    D2,
    D3Mut,
    D3Env,
    D3Unsafe,
    D4,
    Allowlist,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::D1Time => "D1-TIME",
            Rule::D1Hash => "D1-HASH",
            Rule::D1Rng => "D1-RNG",
            Rule::D2 => "D2",
            Rule::D3Mut => "D3-MUT",
            Rule::D3Env => "D3-ENV",
            Rule::D3Unsafe => "D3-UNSAFE",
            Rule::D4 => "D4",
            Rule::Allowlist => "ALLOWLIST",
        }
    }

    /// The rules an `[[allow]]` entry may name (everything but
    /// `Allowlist`: config problems cannot be allowlisted away).
    pub fn from_id(id: &str) -> Option<Rule> {
        match id {
            "D1-TIME" => Some(Rule::D1Time),
            "D1-HASH" => Some(Rule::D1Hash),
            "D1-RNG" => Some(Rule::D1Rng),
            "D2" => Some(Rule::D2),
            "D3-MUT" => Some(Rule::D3Mut),
            "D3-ENV" => Some(Rule::D3Env),
            "D3-UNSAFE" => Some(Rule::D3Unsafe),
            "D4" => Some(Rule::D4),
            _ => None,
        }
    }
}

/// One lint finding.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the `rust/` root, with `/` separators.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
    /// Raw source text of the line (allowlist patterns match this).
    pub raw: String,
}

/// How many comment lines above an `unsafe` keyword may hold its
/// `// SAFETY:` justification.
const SAFETY_LOOKBACK: usize = 10;

/// Lint one file. `path` is the `rust/`-relative path and drives the
/// per-module scoping below; fixtures use an `//@path:` directive to
/// pick theirs.
pub fn lint_source(path: &str, src: &str, cfg: &Config) -> Vec<Finding> {
    let lines = lex(src);
    let raw_lines: Vec<&str> = src.lines().collect();

    let in_src = path.starts_with("src/");
    let in_examples = path.starts_with("examples/");
    // D1-TIME: wall-clock reads are fine in metrics (that is what the
    // module is for), in benches (they *measure* wall-clock), and in
    // util/clock.rs — the one audited `Instant::now` site behind the
    // injectable `Clock` trait that all cluster timing goes through.
    let time_exempt = path.starts_with("src/metrics/")
        || path.starts_with("benches/")
        || path == "src/util/clock.rs";
    // D1-HASH: modules that serialize or reduce results, where
    // iteration order would reach bytes on disk.
    let hash_scoped = path.starts_with("src/sweep/")
        || path.starts_with("src/metrics/")
        || path.starts_with("src/planner/")
        || path == "src/util/json.rs";
    // D1-RNG: seeding is the business of util/rng and eval::substream.
    let rng_exempt = path == "src/util/rng.rs" || path.starts_with("src/eval/");
    // D3-ENV: process environment is config, read in config/ (and the
    // pool's thread-count override, set before the pool starts).
    let env_exempt = path.starts_with("src/config/") || path == "src/sim/pool.rs";
    // D4 applies to files that touch the worker pool.
    let pool_file = lines.iter().any(|l| {
        l.code.contains("WorkerPool")
            || l.code.contains("PoolScope")
            || l.code.contains("sim::pool")
    });

    let mut findings: Vec<Finding> = Vec::new();
    // Depths at which a test region / named fn opened.
    let mut test_stack: Vec<i64> = Vec::new();
    let mut fn_stack: Vec<(i64, String)> = Vec::new();
    let mut pending_test = false;
    let mut pending_test_item = false;
    let mut pending_fn: Option<String> = None;
    let mut depth: i64 = 0;

    for (idx, line) in lines.iter().enumerate() {
        let ln = idx + 1;
        let code = line.code.as_str();
        let squeezed: String = code.chars().filter(|c| !c.is_whitespace()).collect();
        if squeezed.contains("#[cfg(test)]") || squeezed.contains("#[test]") {
            pending_test = true;
            pending_test_item = false;
        }
        if pending_test && (contains_word(code, "mod") || contains_word(code, "fn")) {
            pending_test_item = true;
        }
        if let Some(name) = fn_name(code) {
            pending_fn = Some(name);
        }

        // Region state as of the *start* of this line.
        let in_test = !test_stack.is_empty();
        let cur_fn = fn_stack.last().map(|(_, n)| n.as_str()).unwrap_or("");
        let raw = raw_lines.get(idx).copied().unwrap_or("");
        let mut push = |rule: Rule, message: String| {
            findings.push(Finding {
                file: path.to_string(),
                line: ln,
                rule,
                message,
                raw: raw.to_string(),
            });
        };

        if !in_test {
            if (in_src || in_examples) && !time_exempt {
                for pat in ["Instant::now", "SystemTime::now"] {
                    if code.contains(pat) {
                        push(
                            Rule::D1Time,
                            format!("`{pat}` outside metrics/ and benches/"),
                        );
                    }
                }
            }
            if in_src {
                if !rng_exempt && code.contains("Pcg64::new(") {
                    push(
                        Rule::D1Rng,
                        "direct RNG seeding outside util/rng and eval::substream"
                            .to_string(),
                    );
                }
                for pat in [".unwrap()", ".expect(", "panic!", "todo!"] {
                    if code.contains(pat) {
                        push(Rule::D2, format!("`{pat}` in non-test library code"));
                    }
                }
                if !env_exempt && code.contains("env::var") {
                    push(
                        Rule::D3Env,
                        "environment read outside config/ and sim/pool.rs".to_string(),
                    );
                }
                if pool_file && !cfg.d4_helpers.iter().any(|h| h == cur_fn) {
                    let reductions =
                        [".sum::<f32>(", ".sum::<f64>(", ".product::<", ".fold("];
                    for pat in reductions {
                        if code.contains(pat) {
                            push(
                                Rule::D4,
                                format!(
                                    "`{pat}` reduction in pool-parallel code outside a \
                                     serial-reduction helper"
                                ),
                            );
                        }
                    }
                }
                if hash_scoped && (code.contains("HashMap") || code.contains("HashSet")) {
                    push(
                        Rule::D1Hash,
                        "hash collection in a result-serializing module (iteration \
                         order reaches output bytes) — use BTreeMap/BTreeSet"
                            .to_string(),
                    );
                }
            }
        }
        if code.contains("static mut") {
            push(Rule::D3Mut, "`static mut` is forbidden".to_string());
        }
        if contains_word(code, "unsafe") {
            let lookback = idx.saturating_sub(SAFETY_LOOKBACK);
            let justified =
                lines[lookback..=idx].iter().any(|l| l.comment.contains("SAFETY:"));
            if !justified {
                push(
                    Rule::D3Unsafe,
                    format!(
                        "`unsafe` without a `// SAFETY:` comment within \
                         {SAFETY_LOOKBACK} lines"
                    ),
                );
            }
        }

        // Brace scan: update region state for the following lines.
        for c in code.chars() {
            match c {
                '{' => {
                    if pending_test && pending_test_item {
                        test_stack.push(depth);
                        pending_test = false;
                    }
                    if let Some(name) = pending_fn.take() {
                        fn_stack.push((depth, name));
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if test_stack.last() == Some(&depth) {
                        test_stack.pop();
                    }
                    if fn_stack.last().map(|(d, _)| *d) == Some(depth) {
                        fn_stack.pop();
                    }
                }
                ';' => {
                    // a bodyless item (`fn f();`, `#[cfg(test)] mod t;`)
                    // resolves its pending state without a brace
                    if !code.contains('{') {
                        pending_fn = None;
                        if pending_test && pending_test_item {
                            pending_test = false;
                        }
                    }
                }
                _ => {}
            }
        }
    }
    findings
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Does `code` contain `word` with non-identifier chars on both sides?
/// `word` must be ASCII (all our keywords are).
fn contains_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        let end = at + word.len();
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        from = at + 1;
    }
    false
}

/// The name after the first `fn` keyword on the line, if any.
fn fn_name(code: &str) -> Option<String> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find("fn") {
        let at = from + pos;
        let end = at + 2;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let after_ws = end < bytes.len() && bytes[end].is_ascii_whitespace();
        if before_ok && after_ws {
            let name: String = code[end..]
                .trim_start()
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
        from = at + 2;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(path: &str, src: &str) -> Vec<(usize, &'static str)> {
        lint_source(path, src, &Config::default())
            .into_iter()
            .map(|f| (f.line, f.rule.id()))
            .collect()
    }

    #[test]
    fn d2_flags_library_code_not_tests() {
        let src = "\
pub fn go() {
    let x = y.unwrap();
}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let x = y.unwrap();
    }
}
";
        assert_eq!(ids("src/a.rs", src), vec![(2, "D2")]);
    }

    #[test]
    fn d2_ignores_unwrap_or_variants() {
        let src = "pub fn go() -> u32 {\n    y.unwrap_or(0).max(y.unwrap_or_else(|| 1))\n}\n";
        assert!(ids("src/a.rs", src).is_empty());
    }

    #[test]
    fn d1_time_scoping() {
        let src = "fn f() {\n    let t = Instant::now();\n}\n";
        assert_eq!(ids("src/sim/job.rs", src), vec![(2, "D1-TIME")]);
        assert!(ids("src/metrics/timer.rs", src).is_empty());
        assert!(ids("benches/bench_x.rs", src).is_empty());
        // the Clock abstraction is the one library-code call site...
        assert!(ids("src/util/clock.rs", src).is_empty());
        // ...and the exemption is exact-path, not a prefix
        assert_eq!(ids("src/util/clock_extra.rs", src), vec![(2, "D1-TIME")]);
    }

    #[test]
    fn d1_hash_scoping() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(ids("src/sweep/report.rs", src), vec![(1, "D1-HASH")]);
        assert!(ids("src/runtime/engine.rs", src).is_empty());
    }

    #[test]
    fn d1_rng_scoping() {
        let src = "fn f() {\n    let rng = Pcg64::new(7);\n}\n";
        assert_eq!(ids("src/dist/sample.rs", src), vec![(2, "D1-RNG")]);
        assert!(ids("src/eval/montecarlo.rs", src).is_empty());
        assert!(ids("src/util/rng.rs", src).is_empty());
    }

    #[test]
    fn d3_env_scoping() {
        let src = "fn f() {\n    let v = std::env::var(\"X\");\n}\n";
        assert_eq!(ids("src/util/misc.rs", src), vec![(2, "D3-ENV")]);
        assert!(ids("src/config/load.rs", src).is_empty());
        assert!(ids("src/sim/pool.rs", src).is_empty());
    }

    #[test]
    fn d3_unsafe_needs_safety_comment() {
        let bad = "fn f() {\n    unsafe { ptr.read() }\n}\n";
        assert_eq!(ids("src/a.rs", bad), vec![(2, "D3-UNSAFE")]);
        let good =
            "fn f() {\n    // SAFETY: ptr is valid for reads\n    unsafe { ptr.read() }\n}\n";
        assert!(ids("src/a.rs", good).is_empty());
    }

    #[test]
    fn d4_only_in_pool_files_outside_helpers() {
        let pool = "\
use crate::sim::pool::WorkerPool;
fn gather(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>()
}
fn reduce(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>()
}
";
        assert_eq!(ids("src/eval/x.rs", pool), vec![(3, "D4")]);
        let no_pool = "fn gather(xs: &[f64]) -> f64 {\n    xs.iter().sum::<f64>()\n}\n";
        assert!(ids("src/eval/x.rs", no_pool).is_empty());
    }

    #[test]
    fn patterns_in_strings_and_comments_do_not_fire() {
        let src = "\
fn f() -> String {
    // HashMap iteration would be bad here; x.unwrap() too
    let s = \"Instant::now() .unwrap() HashMap\";
    s.to_string()
}
";
        assert!(ids("src/sweep/report.rs", src).is_empty());
    }

    #[test]
    fn out_of_line_test_mod_does_not_poison_rest_of_file() {
        let src = "\
#[cfg(test)]
mod tests;
pub fn f() {
    x.unwrap();
}
";
        assert_eq!(ids("src/a.rs", src), vec![(4, "D2")]);
    }
}
