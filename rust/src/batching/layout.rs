//! Materialized task-to-worker layouts.

use std::collections::BTreeSet;

use crate::util::error::{Error, Result};

/// Task index in `0..N`.
pub type TaskId = usize;
/// Worker index in `0..N`.
pub type WorkerId = usize;
/// Batch index.
pub type BatchId = usize;

/// A materialized assignment: which tasks each worker executes, and the
/// batch structure used for completion tracking.
///
/// Completion semantics (paper §II-B): a worker reports once *all* its
/// assigned tasks finish; the job completes when every task has been
/// reported by at least one finished worker.
#[derive(Clone, Debug)]
pub struct Layout {
    /// Total number of tasks (= worker budget N in the paper's model).
    pub n_tasks: usize,
    /// `worker_tasks[w]` = sorted task ids worker `w` executes.
    pub worker_tasks: Vec<Vec<TaskId>>,
    /// `batches[b]` = sorted task ids of batch `b` (batch structure; for
    /// overlapping policies batches coincide with workers).
    pub batches: Vec<Vec<TaskId>>,
    /// `batch_workers[b]` = workers hosting exactly batch `b`.
    pub batch_workers: Vec<Vec<WorkerId>>,
}

impl Layout {
    /// Number of workers.
    pub fn n_workers(&self) -> usize {
        self.worker_tasks.len()
    }

    /// Batch size (uniform across batches by construction).
    pub fn batch_size(&self) -> usize {
        self.batches.first().map_or(0, |b| b.len())
    }

    /// Replication degree of each task: how many workers host it.
    pub fn task_replication(&self) -> Vec<usize> {
        let mut rep = vec![0usize; self.n_tasks];
        for tasks in &self.worker_tasks {
            for &t in tasks {
                rep[t] += 1;
            }
        }
        rep
    }

    /// The assignment vector `N̄ = (N₁,…,N_B)` — workers per batch.
    pub fn assignment_vector(&self) -> Vec<usize> {
        self.batch_workers.iter().map(|ws| ws.len()).collect()
    }

    /// Is every task hosted by at least one worker? (Random assignment
    /// can violate this — the coverage failure of Lemma 1.)
    pub fn covers_all_tasks(&self) -> bool {
        self.task_replication().iter().all(|&r| r > 0)
    }

    /// Structural sanity checks used by tests and the coordinator.
    pub fn validate(&self) -> Result<()> {
        if self.worker_tasks.is_empty() {
            return Err(Error::Policy("layout has no workers".into()));
        }
        let size = self.batch_size();
        for (b, tasks) in self.batches.iter().enumerate() {
            if tasks.len() != size {
                return Err(Error::Policy(format!(
                    "batch {b} has size {} != {size}",
                    tasks.len()
                )));
            }
            let set: BTreeSet<_> = tasks.iter().collect();
            if set.len() != tasks.len() {
                return Err(Error::Policy(format!("batch {b} has duplicate tasks")));
            }
            if tasks.iter().any(|&t| t >= self.n_tasks) {
                return Err(Error::Policy(format!("batch {b} has out-of-range task")));
            }
        }
        for (w, tasks) in self.worker_tasks.iter().enumerate() {
            if tasks.windows(2).any(|p| p[0] >= p[1]) {
                return Err(Error::Policy(format!("worker {w} tasks not sorted/unique")));
            }
        }
        for (b, workers) in self.batch_workers.iter().enumerate() {
            for &w in workers {
                if self.worker_tasks[w] != self.batches[b] {
                    return Err(Error::Policy(format!(
                        "worker {w} listed for batch {b} but executes different tasks"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Given the set of finished workers, is the job complete (every
    /// task recovered from at least one finished worker)?
    pub fn complete(&self, finished: &[bool]) -> bool {
        debug_assert_eq!(finished.len(), self.n_workers());
        let mut covered = vec![false; self.n_tasks];
        for (w, tasks) in self.worker_tasks.iter().enumerate() {
            if finished[w] {
                for &t in tasks {
                    covered[t] = true;
                }
            }
        }
        covered.into_iter().all(|c| c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_layout() -> Layout {
        // N=4, B=2, balanced: batches {0,1},{2,3}, workers 0,1 -> b0; 2,3 -> b1
        Layout {
            n_tasks: 4,
            worker_tasks: vec![vec![0, 1], vec![0, 1], vec![2, 3], vec![2, 3]],
            batches: vec![vec![0, 1], vec![2, 3]],
            batch_workers: vec![vec![0, 1], vec![2, 3]],
        }
    }

    #[test]
    fn replication_and_vector() {
        let l = tiny_layout();
        assert_eq!(l.task_replication(), vec![2, 2, 2, 2]);
        assert_eq!(l.assignment_vector(), vec![2, 2]);
        assert!(l.covers_all_tasks());
        l.validate().unwrap();
    }

    #[test]
    fn completion_logic_first_copy_wins() {
        let l = tiny_layout();
        assert!(!l.complete(&[true, false, false, false])); // batch 1 missing
        assert!(l.complete(&[true, false, false, true])); // one worker per batch
        assert!(l.complete(&[false, true, true, false]));
        assert!(!l.complete(&[false, false, false, false]));
    }

    #[test]
    fn validate_catches_duplicates() {
        let mut l = tiny_layout();
        l.batches[0] = vec![0, 0];
        assert!(l.validate().is_err());
    }

    #[test]
    fn validate_catches_mismatched_batch_worker() {
        let mut l = tiny_layout();
        l.batch_workers[0] = vec![2]; // worker 2 executes batch 1, not 0
        assert!(l.validate().is_err());
    }
}
