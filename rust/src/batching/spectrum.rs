//! The diversity–parallelism spectrum (paper §I, §VI).
//!
//! Every feasible batch count B (a divisor of N) is one operating
//! point: B = 1 is *full diversity* (the whole job replicated on every
//! worker), B = N is *full parallelism* (no redundancy).

use crate::analysis::optimizer::feasible_b;

/// One operating point in the spectrum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OperatingPoint {
    /// Batch count B.
    pub batches: usize,
    /// Tasks per batch (= N/B).
    pub batch_size: usize,
    /// Replication degree of each batch under the balanced policy
    /// (= N/B).
    pub replication: usize,
}

impl OperatingPoint {
    pub fn is_full_diversity(&self) -> bool {
        self.batches == 1
    }

    pub fn is_full_parallelism(&self) -> bool {
        self.replication == 1
    }

    /// Redundancy fraction: how much of the cluster's total work is
    /// redundant (0 at full parallelism, (N−1)/N at full diversity).
    pub fn redundancy(&self, n: usize) -> f64 {
        1.0 - self.batches as f64 / n as f64
    }
}

/// All operating points for a worker budget N, ordered from full
/// diversity to full parallelism.
pub fn operating_points(n: usize) -> Vec<OperatingPoint> {
    feasible_b(n)
        .into_iter()
        .map(|b| OperatingPoint { batches: b, batch_size: n / b, replication: n / b })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectrum_for_100() {
        let pts = operating_points(100);
        assert_eq!(pts.len(), 9); // divisors of 100
        assert!(pts[0].is_full_diversity());
        assert!(pts.last().unwrap().is_full_parallelism());
        assert_eq!(pts[0].batch_size, 100);
        assert_eq!(pts.last().unwrap().batch_size, 1);
        for p in &pts {
            assert_eq!(p.batches * p.batch_size, 100);
            assert_eq!(p.replication, p.batch_size);
        }
    }

    #[test]
    fn redundancy_fraction() {
        let pts = operating_points(10);
        assert_eq!(pts[0].redundancy(10), 0.9);
        assert_eq!(pts.last().unwrap().redundancy(10), 0.0);
    }

    #[test]
    fn prime_n_has_two_points() {
        let pts = operating_points(7);
        assert_eq!(pts.len(), 2);
        assert!(pts[0].is_full_diversity() && pts[1].is_full_parallelism());
    }
}
