//! Task replication policies (paper §III, §V).
//!
//! A policy is a two-stage process: (1) group the N tasks into batches,
//! (2) assign batches to the N workers. [`Policy`] enumerates every
//! scheme the paper analyzes:
//!
//! * balanced non-overlapping (the provably optimal one, Theorems 1–2)
//! * unbalanced non-overlapping (for the majorization experiments)
//! * random non-overlapping (coupon-collector, Li et al. \[72\])
//! * cyclic overlapping (scheme 1 of Fig. 5; gradient coding \[41\])
//! * hybrid overlapping (scheme 2 of Fig. 5)
//!
//! [`Layout`] is the materialized result: for each worker, the set of
//! task ids it must execute; plus the batch structure needed by the
//! completion logic.

mod layout;
mod policies;
mod spectrum;

pub use layout::{BatchId, Layout, TaskId, WorkerId};
pub use policies::Policy;
pub use spectrum::{operating_points, OperatingPoint};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn module_level_smoke() {
        let mut rng = Pcg64::new(0);
        for policy in [
            Policy::BalancedNonOverlapping { batches: 3 },
            Policy::CyclicOverlapping { batches: 3 },
        ] {
            let layout = policy.layout(6, &mut rng).unwrap();
            layout.validate().unwrap();
        }
    }
}
