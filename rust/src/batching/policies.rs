//! Policy definitions and layout materialization.

use crate::batching::layout::Layout;
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;

/// A task-replication policy (paper §III and §V / Fig. 5).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Policy {
    /// B non-overlapping batches of size N/B, each replicated on N/B
    /// workers (the optimal policy of Theorems 1–2). Requires B | N.
    BalancedNonOverlapping { batches: usize },
    /// B non-overlapping batches of size N/B with an explicit assignment
    /// vector (workers per batch, summing to N) — the majorization
    /// experiments of Lemma 2.
    UnbalancedNonOverlapping { assignment: Vec<usize> },
    /// B non-overlapping batches; every worker draws one uniformly at
    /// random with replacement (Li et al. \[72\]; coverage analyzed by
    /// Lemma 1). May leave tasks uncovered.
    RandomNonOverlapping { batches: usize },
    /// Scheme 1 of Fig. 5: N cyclic overlapping batches of size N/B,
    /// one per worker (the gradient-coding layout \[41\]).
    CyclicOverlapping { batches: usize },
    /// Scheme 2 of Fig. 5: a cyclic group over the first N−N/B tasks
    /// plus one replicated non-overlapping batch on the remaining
    /// workers.
    HybridOverlapping { batches: usize },
}

impl Policy {
    /// Human-readable name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Policy::BalancedNonOverlapping { .. } => "balanced-nonoverlap",
            Policy::UnbalancedNonOverlapping { .. } => "unbalanced-nonoverlap",
            Policy::RandomNonOverlapping { .. } => "random-nonoverlap",
            Policy::CyclicOverlapping { .. } => "cyclic-overlap",
            Policy::HybridOverlapping { .. } => "hybrid-overlap",
        }
    }

    /// Number of distinct batches the policy uses.
    pub fn batch_count(&self, n: usize) -> usize {
        match self {
            Policy::BalancedNonOverlapping { batches }
            | Policy::RandomNonOverlapping { batches } => *batches,
            Policy::UnbalancedNonOverlapping { assignment } => assignment.len(),
            Policy::CyclicOverlapping { .. } => n,
            Policy::HybridOverlapping { batches } => n - n / *batches + 1,
        }
    }

    /// Materialize the layout for `n` tasks on `n` workers.
    pub fn layout(&self, n: usize, rng: &mut Pcg64) -> Result<Layout> {
        match self {
            Policy::BalancedNonOverlapping { batches } => {
                let b = *batches;
                check_divides(n, b)?;
                let assignment = vec![n / b; b];
                nonoverlapping(n, &assignment)
            }
            Policy::UnbalancedNonOverlapping { assignment } => {
                let b = assignment.len();
                check_divides(n, b)?;
                if assignment.iter().sum::<usize>() != n {
                    return Err(Error::Policy(format!(
                        "assignment {:?} must sum to N={n}",
                        assignment
                    )));
                }
                if assignment.iter().any(|&x| x == 0) {
                    return Err(Error::Policy(
                        "assignment entries must be >= 1 (zero leaves a batch uncovered)"
                            .into(),
                    ));
                }
                nonoverlapping(n, assignment)
            }
            Policy::RandomNonOverlapping { batches } => {
                let b = *batches;
                check_divides(n, b)?;
                let batch_tasks = chop(n, b);
                let mut worker_tasks = Vec::with_capacity(n);
                let mut batch_workers = vec![Vec::new(); b];
                for w in 0..n {
                    let pick = rng.below(b as u64) as usize;
                    worker_tasks.push(batch_tasks[pick].clone());
                    batch_workers[pick].push(w);
                }
                Ok(Layout { n_tasks: n, worker_tasks, batches: batch_tasks, batch_workers })
            }
            Policy::CyclicOverlapping { batches } => {
                let b = *batches;
                check_divides(n, b)?;
                let size = n / b;
                let mut worker_tasks = Vec::with_capacity(n);
                let mut batch_tasks = Vec::with_capacity(n);
                let mut batch_workers = Vec::with_capacity(n);
                for w in 0..n {
                    let mut tasks: Vec<usize> = (0..size).map(|i| (w + i) % n).collect();
                    tasks.sort_unstable();
                    worker_tasks.push(tasks.clone());
                    batch_tasks.push(tasks);
                    batch_workers.push(vec![w]);
                }
                Ok(Layout { n_tasks: n, worker_tasks, batches: batch_tasks, batch_workers })
            }
            Policy::HybridOverlapping { batches } => {
                let b = *batches;
                check_divides(n, b)?;
                let size = n / b;
                if size >= n {
                    return Err(Error::Policy(
                        "hybrid scheme needs B >= 2 (batch smaller than task set)".into(),
                    ));
                }
                let head = n - size; // cyclic region (tasks 0..head)
                let mut worker_tasks = Vec::with_capacity(n);
                let mut batch_tasks = Vec::new();
                let mut batch_workers = Vec::new();
                // cyclic group over the head tasks, one batch per worker
                for w in 0..head {
                    let mut tasks: Vec<usize> =
                        (0..size).map(|i| (w + i) % head).collect();
                    tasks.sort_unstable();
                    worker_tasks.push(tasks.clone());
                    batch_tasks.push(tasks);
                    batch_workers.push(vec![w]);
                }
                // one replicated tail batch on the remaining `size` workers
                let tail: Vec<usize> = (head..n).collect();
                for _w in head..n {
                    worker_tasks.push(tail.clone());
                }
                batch_tasks.push(tail);
                batch_workers.push((head..n).collect());
                Ok(Layout { n_tasks: n, worker_tasks, batches: batch_tasks, batch_workers })
            }
        }
    }
}

fn check_divides(n: usize, b: usize) -> Result<()> {
    if b == 0 || b > n || n % b != 0 {
        return Err(Error::Policy(format!("B={b} must divide N={n} (1 ≤ B ≤ N)")));
    }
    Ok(())
}

/// Chop tasks `0..n` into `b` contiguous batches of size n/b.
fn chop(n: usize, b: usize) -> Vec<Vec<usize>> {
    let size = n / b;
    (0..b).map(|i| (i * size..(i + 1) * size).collect()).collect()
}

/// Build a non-overlapping layout from an assignment vector.
fn nonoverlapping(n: usize, assignment: &[usize]) -> Result<Layout> {
    let b = assignment.len();
    let batch_tasks = chop(n, b);
    let mut worker_tasks = Vec::with_capacity(n);
    let mut batch_workers = vec![Vec::new(); b];
    let mut w = 0usize;
    for (i, &cnt) in assignment.iter().enumerate() {
        for _ in 0..cnt {
            worker_tasks.push(batch_tasks[i].clone());
            batch_workers[i].push(w);
            w += 1;
        }
    }
    Ok(Layout { n_tasks: n, worker_tasks, batches: batch_tasks, batch_workers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Pcg64;

    #[test]
    fn balanced_layout_structure() {
        let mut rng = Pcg64::new(0);
        let l = Policy::BalancedNonOverlapping { batches: 3 }.layout(6, &mut rng).unwrap();
        l.validate().unwrap();
        assert_eq!(l.batches.len(), 3);
        assert_eq!(l.batch_size(), 2);
        assert_eq!(l.assignment_vector(), vec![2, 2, 2]);
        assert_eq!(l.task_replication(), vec![2; 6]);
        assert!(l.covers_all_tasks());
    }

    #[test]
    fn balanced_full_diversity_and_parallelism() {
        let mut rng = Pcg64::new(0);
        // B=1: every worker hosts the whole job
        let l = Policy::BalancedNonOverlapping { batches: 1 }.layout(4, &mut rng).unwrap();
        assert!(l.worker_tasks.iter().all(|t| t.len() == 4));
        assert_eq!(l.assignment_vector(), vec![4]);
        // B=N: no redundancy
        let l = Policy::BalancedNonOverlapping { batches: 4 }.layout(4, &mut rng).unwrap();
        assert_eq!(l.task_replication(), vec![1; 4]);
    }

    #[test]
    fn unbalanced_respects_vector() {
        let mut rng = Pcg64::new(0);
        let l = Policy::UnbalancedNonOverlapping { assignment: vec![4, 1, 1] }
            .layout(6, &mut rng)
            .unwrap();
        l.validate().unwrap();
        assert_eq!(l.assignment_vector(), vec![4, 1, 1]);
        // batch size is still N/B = 2
        assert_eq!(l.batch_size(), 2);
    }

    #[test]
    fn unbalanced_rejects_bad_vectors() {
        let mut rng = Pcg64::new(0);
        assert!(Policy::UnbalancedNonOverlapping { assignment: vec![3, 2] }
            .layout(6, &mut rng)
            .is_err()); // sums to 5
        assert!(Policy::UnbalancedNonOverlapping { assignment: vec![6, 0] }
            .layout(6, &mut rng)
            .is_err()); // zero entry
    }

    #[test]
    fn cyclic_matches_fig5_scheme1() {
        let mut rng = Pcg64::new(0);
        let l = Policy::CyclicOverlapping { batches: 3 }.layout(6, &mut rng).unwrap();
        l.validate().unwrap();
        // W1..W6 host (1,2),(2,3),...,(6,1) in 0-based: w hosts {w, w+1 mod 6}
        assert_eq!(l.worker_tasks[0], vec![0, 1]);
        assert_eq!(l.worker_tasks[4], vec![4, 5]);
        assert_eq!(l.worker_tasks[5], vec![0, 5]);
        assert_eq!(l.task_replication(), vec![2; 6]);
        // each batch shares a task with 2(N/B - 1) = 2 other batches
        let overlaps = |a: &Vec<usize>, b: &Vec<usize>| a.iter().any(|t| b.contains(t));
        for i in 0..6 {
            let cnt = (0..6)
                .filter(|&j| j != i && overlaps(&l.batches[i], &l.batches[j]))
                .count();
            assert_eq!(cnt, 2, "batch {i}");
        }
    }

    #[test]
    fn hybrid_matches_fig5_scheme2() {
        let mut rng = Pcg64::new(0);
        let l = Policy::HybridOverlapping { batches: 3 }.layout(6, &mut rng).unwrap();
        l.validate().unwrap();
        // first 4 workers cyclic over tasks 0..4, last 2 share batch {4,5}
        assert_eq!(l.worker_tasks[0], vec![0, 1]);
        assert_eq!(l.worker_tasks[3], vec![0, 3]);
        assert_eq!(l.worker_tasks[4], vec![4, 5]);
        assert_eq!(l.worker_tasks[5], vec![4, 5]);
        assert_eq!(l.task_replication(), vec![2; 6]);
        assert_eq!(l.batches.len(), 5);
    }

    #[test]
    fn random_layout_statistics() {
        // coverage frequency should match Lemma 1
        let (n, b) = (20usize, 4usize);
        let mut rng = Pcg64::new(5);
        let trials = 20_000;
        let mut covered = 0;
        for _ in 0..trials {
            let l = Policy::RandomNonOverlapping { batches: b }.layout(n, &mut rng).unwrap();
            l.validate().unwrap();
            if l.covers_all_tasks() {
                covered += 1;
            }
        }
        let emp = covered as f64 / trials as f64;
        let exact = crate::analysis::coverage::coverage_probability(n, b);
        assert!((emp - exact).abs() < 0.01, "{emp} vs {exact}");
    }

    #[test]
    fn divisibility_enforced() {
        let mut rng = Pcg64::new(0);
        for p in [
            Policy::BalancedNonOverlapping { batches: 3 },
            Policy::RandomNonOverlapping { batches: 3 },
            Policy::CyclicOverlapping { batches: 3 },
        ] {
            assert!(p.layout(10, &mut rng).is_err(), "{}", p.name());
        }
        assert!(Policy::BalancedNonOverlapping { batches: 0 }.layout(6, &mut rng).is_err());
    }

    #[test]
    fn all_policies_are_fair_when_feasible() {
        // every task replicated the same number of times (the fairness
        // property §V assumes) — except random, which is unfair by design
        forall("policy fairness", 40, |rng| {
            let b = *rng.choose(&[1usize, 2, 3, 4, 6]);
            let n = b * rng.range(1, 5);
            for p in [
                Policy::BalancedNonOverlapping { batches: b },
                Policy::CyclicOverlapping { batches: b },
            ] {
                if let Ok(l) = p.layout(n, rng) {
                    l.validate().unwrap();
                    let rep = l.task_replication();
                    assert!(
                        rep.windows(2).all(|w| w[0] == w[1]),
                        "{} N={n} B={b}: {rep:?}",
                        p.name()
                    );
                }
            }
        });
    }

    #[test]
    fn hybrid_needs_b_at_least_2() {
        let mut rng = Pcg64::new(0);
        assert!(Policy::HybridOverlapping { batches: 1 }.layout(6, &mut rng).is_err());
    }
}
