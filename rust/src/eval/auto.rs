//! Auto backend: exact when possible, simulation when not.

use crate::eval::{Analytic, Estimate, Estimator, MonteCarlo, Scenario};
use crate::util::error::Result;

/// Analytic-first estimator with a transparent Monte-Carlo fallback.
///
/// Scenarios with an exact closed form (Exp/SExp/Pareto service,
/// balanced non-overlapping policy, no failures) are answered by
/// [`Analytic`]; everything else — empirical or bimodal service times,
/// overlapping/random policies, failure injection — falls back to the
/// configured [`MonteCarlo`]. Which path answered is recorded in
/// [`Estimate::provenance`], so consumers can always tell simulation
/// noise from exact numbers.
#[derive(Clone, Copy, Debug, Default)]
pub struct Auto {
    /// The Monte-Carlo estimator used when no closed form exists.
    pub fallback: MonteCarlo,
}

impl Auto {
    /// Auto backend whose fallback runs `reps` replications from `seed`
    /// on all available cores.
    pub fn new(reps: usize, seed: u64) -> Auto {
        Auto { fallback: MonteCarlo::new(reps, seed) }
    }

    /// Name of the backend that would answer this scenario.
    pub fn backend_for(scenario: &Scenario) -> &'static str {
        if Analytic::supports(scenario) {
            "analytic"
        } else {
            "monte-carlo"
        }
    }
}

impl Estimator for Auto {
    fn evaluate(&self, scenario: &Scenario) -> Result<Estimate> {
        if Analytic::supports(scenario) {
            Analytic.evaluate(scenario)
        } else {
            self.fallback.evaluate(scenario)
        }
    }

    fn evaluate_at(&self, scenario: &Scenario, index: u64) -> Result<Estimate> {
        if Analytic::supports(scenario) {
            Analytic.evaluate(scenario)
        } else {
            self.fallback.evaluate_at(scenario, index)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::Policy;
    use crate::dist::ServiceDist;
    use crate::eval::Provenance;
    use crate::sim::job::FailureModel;
    use crate::util::rng::Pcg64;

    #[test]
    fn closed_form_families_stay_analytic() {
        let auto = Auto::new(2_000, 5);
        for tau in [
            ServiceDist::exp(1.0),
            ServiceDist::shifted_exp(0.05, 1.0),
            ServiceDist::pareto(1.0, 3.0),
        ] {
            let est = auto.evaluate(&Scenario::balanced(20, 4, tau.clone())).unwrap();
            assert_eq!(est.provenance, Provenance::Analytic, "{}", tau.label());
        }
    }

    #[test]
    fn empirical_and_bimodal_fall_back_to_monte_carlo() {
        let auto = Auto::new(2_000, 5);
        let mut rng = Pcg64::new(1);
        let d = ServiceDist::exp(1.0);
        let samples: Vec<f64> = (0..500).map(|_| d.sample(&mut rng)).collect();
        for tau in [
            ServiceDist::empirical(samples),
            ServiceDist::bimodal(0.1, (0.1, 10.0), (5.0, 1.0)),
        ] {
            let est = auto.evaluate(&Scenario::balanced(20, 4, tau.clone())).unwrap();
            assert!(
                matches!(est.provenance, Provenance::MonteCarlo { .. }),
                "{}",
                tau.label()
            );
        }
    }

    #[test]
    fn overlapping_policies_and_failures_fall_back() {
        let auto = Auto::new(2_000, 5);
        let s = Scenario::new(
            6,
            Policy::CyclicOverlapping { batches: 3 },
            ServiceDist::exp(1.0),
        );
        assert_eq!(Auto::backend_for(&s), "monte-carlo");
        let est = auto.evaluate(&s).unwrap();
        assert!(matches!(est.provenance, Provenance::MonteCarlo { .. }));

        let s = Scenario::balanced(6, 3, ServiceDist::exp(1.0))
            .with_failures(FailureModel::Crash { p: 0.2 });
        let est = auto.evaluate(&s).unwrap();
        assert!(matches!(est.provenance, Provenance::MonteCarlo { .. }));
    }

    #[test]
    fn fallback_agrees_with_analytic_on_shared_ground() {
        // same scenario through both paths: MC should land within CI
        let scenario = Scenario::balanced(20, 5, ServiceDist::exp(1.0));
        let exact = Analytic.evaluate(&scenario).unwrap();
        let mc = Auto::new(30_000, 9).fallback.evaluate(&scenario).unwrap();
        assert!((exact.mean - mc.mean).abs() < 4.0 * mc.ci95.max(1e-3));
    }
}
