//! Auto backend: exact when possible, simulation when not.

use crate::eval::{substream, Analytic, Estimate, Estimator, MonteCarlo, Scenario};
use crate::util::error::{Error, Result};

/// Analytic-first estimator with a transparent Monte-Carlo fallback.
///
/// Scenarios with an exact closed form (Exp/SExp/Pareto service,
/// balanced non-overlapping policy, no failures) are answered by
/// [`Analytic`]; everything else — empirical or bimodal service times,
/// overlapping/random policies, failure injection — falls back to the
/// configured [`MonteCarlo`]. Which path answered is recorded in
/// [`Estimate::provenance`], so consumers can always tell simulation
/// noise from exact numbers.
#[derive(Clone, Copy, Debug, Default)]
pub struct Auto {
    /// The Monte-Carlo estimator used when no closed form exists.
    pub fallback: MonteCarlo,
}

impl Auto {
    /// Auto backend whose fallback runs `reps` replications from `seed`
    /// on all available cores.
    pub fn new(reps: usize, seed: u64) -> Auto {
        Auto { fallback: MonteCarlo::new(reps, seed) }
    }

    /// Name of the backend that would answer this scenario.
    pub fn backend_for(scenario: &Scenario) -> &'static str {
        if Analytic::supports(scenario) {
            "analytic"
        } else {
            "monte-carlo"
        }
    }
}

impl Estimator for Auto {
    fn evaluate(&self, scenario: &Scenario) -> Result<Estimate> {
        if Analytic::supports(scenario) {
            Analytic.evaluate(scenario)
        } else {
            self.fallback.evaluate(scenario)
        }
    }

    fn evaluate_at(&self, scenario: &Scenario, index: u64) -> Result<Estimate> {
        if Analytic::supports(scenario) {
            Analytic.evaluate(scenario)
        } else {
            self.fallback.evaluate_at(scenario, index)
        }
    }

    /// Batched routing: closed-form items are answered inline; every
    /// Monte-Carlo-bound item is collected into **one** pooled
    /// `run_batch` call so a mixed sweep still saturates the worker
    /// pool. Each item keeps its original substream index, so results
    /// stay bit-identical to calling [`Estimator::evaluate_at`] item
    /// by item.
    fn evaluate_many(&self, scenarios: &[Scenario]) -> Result<Vec<Estimate>> {
        let mut results: Vec<Option<Estimate>> = vec![None; scenarios.len()];
        let mut mc_indices: Vec<usize> = Vec::new();
        for (i, scenario) in scenarios.iter().enumerate() {
            if Analytic::supports(scenario) {
                results[i] = Some(Analytic.evaluate(scenario)?);
            } else {
                mc_indices.push(i);
            }
        }
        if !mc_indices.is_empty() {
            let items: Vec<(&Scenario, u64)> = mc_indices
                .iter()
                .map(|&i| (&scenarios[i], substream(self.fallback.seed, i as u64)))
                .collect();
            let estimates = self.fallback.run_batch(&items)?;
            for (&i, estimate) in mc_indices.iter().zip(estimates) {
                results[i] = Some(estimate);
            }
        }
        results
            .into_iter()
            .enumerate()
            .map(|(i, estimate)| {
                estimate.ok_or_else(|| {
                    Error::Internal(format!("scenario {i} answered by neither backend"))
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::Policy;
    use crate::dist::ServiceDist;
    use crate::eval::Provenance;
    use crate::sim::job::FailureModel;
    use crate::util::rng::Pcg64;

    #[test]
    fn closed_form_families_stay_analytic() {
        let auto = Auto::new(2_000, 5);
        for tau in [
            ServiceDist::exp(1.0),
            ServiceDist::shifted_exp(0.05, 1.0),
            ServiceDist::pareto(1.0, 3.0),
        ] {
            let est = auto.evaluate(&Scenario::balanced(20, 4, tau.clone())).unwrap();
            assert_eq!(est.provenance, Provenance::Analytic, "{}", tau.label());
        }
    }

    #[test]
    fn empirical_and_bimodal_fall_back_to_monte_carlo() {
        let auto = Auto::new(2_000, 5);
        let mut rng = Pcg64::new(1);
        let d = ServiceDist::exp(1.0);
        let samples: Vec<f64> = (0..500).map(|_| d.sample(&mut rng)).collect();
        for tau in [
            ServiceDist::empirical(samples),
            ServiceDist::bimodal(0.1, (0.1, 10.0), (5.0, 1.0)),
        ] {
            let est = auto.evaluate(&Scenario::balanced(20, 4, tau.clone())).unwrap();
            assert!(
                matches!(est.provenance, Provenance::MonteCarlo { .. }),
                "{}",
                tau.label()
            );
        }
    }

    #[test]
    fn overlapping_policies_and_failures_fall_back() {
        let auto = Auto::new(2_000, 5);
        let s = Scenario::new(
            6,
            Policy::CyclicOverlapping { batches: 3 },
            ServiceDist::exp(1.0),
        );
        assert_eq!(Auto::backend_for(&s), "monte-carlo");
        let est = auto.evaluate(&s).unwrap();
        assert!(matches!(est.provenance, Provenance::MonteCarlo { .. }));

        let s = Scenario::balanced(6, 3, ServiceDist::exp(1.0))
            .with_failures(FailureModel::Crash { p: 0.2 });
        let est = auto.evaluate(&s).unwrap();
        assert!(matches!(est.provenance, Provenance::MonteCarlo { .. }));
    }

    #[test]
    fn evaluate_many_routes_per_item_and_matches_evaluate_at() {
        // mixed batch: analytic, MC (bimodal), analytic, MC (random)
        let auto = Auto::new(1_500, 13);
        let scenarios = vec![
            Scenario::balanced(12, 3, ServiceDist::exp(1.0)),
            Scenario::balanced(12, 3, ServiceDist::bimodal(0.1, (0.1, 10.0), (5.0, 1.0))),
            Scenario::balanced(12, 4, ServiceDist::shifted_exp(0.05, 1.0)),
            Scenario::new(
                12,
                Policy::RandomNonOverlapping { batches: 3 },
                ServiceDist::exp(1.0),
            ),
        ];
        let batch = auto.evaluate_many(&scenarios).unwrap();
        assert_eq!(batch[0].provenance, Provenance::Analytic);
        assert!(matches!(batch[1].provenance, Provenance::MonteCarlo { .. }));
        assert_eq!(batch[2].provenance, Provenance::Analytic);
        assert!(matches!(batch[3].provenance, Provenance::MonteCarlo { .. }));
        for (i, scenario) in scenarios.iter().enumerate() {
            let single = auto.evaluate_at(scenario, i as u64).unwrap();
            assert_eq!(
                batch[i].mean.to_bits(),
                single.mean.to_bits(),
                "item {i} diverged from its substream"
            );
        }
    }

    #[test]
    fn fallback_agrees_with_analytic_on_shared_ground() {
        // same scenario through both paths: MC should land within CI
        let scenario = Scenario::balanced(20, 5, ServiceDist::exp(1.0));
        let exact = Analytic.evaluate(&scenario).unwrap();
        let mc = Auto::new(30_000, 9).fallback.evaluate(&scenario).unwrap();
        assert!((exact.mean - mc.mean).abs() < 4.0 * mc.ci95.max(1e-3));
    }
}
