//! Open-system backend: sojourn-time statistics under offered load.
//!
//! [`OpenSystem`] drives the [`crate::sim::queue`] cluster simulator
//! behind the [`Estimator`] trait: each replication simulates a whole
//! Poisson job stream at offered load ρ through the scenario's cluster
//! and the estimate summarizes the *sojourn* time (arrival → last batch
//! complete) pooled over every measured job of every replication.
//!
//! ## Offered load
//!
//! ρ is normalized to the no-replication capacity: at `B = N` each job
//! carries `N·E[τ]` worker-seconds of useful work, so the cluster
//! saturates at one job per `E[τ]` and the arrival rate is
//! `λ = ρ / E[τ]`. Replication (`B < N`) *adds* load on top — the extra
//! copies burn worker-seconds that kill-on-batch-complete only partially
//! recovers — which is exactly why B* shifts toward `N` as ρ grows.
//!
//! ## Field semantics
//!
//! The returned [`Estimate`] reuses the closed-system shape with
//! open-system meanings:
//!
//! * `mean`/`cov`/percentiles — pooled per-job sojourn times,
//! * `ci95` — the half-width treating pooled jobs as independent (jobs
//!   within one stream are positively correlated, so read it as a lower
//!   bound on the true uncertainty),
//! * `cost` — mean busy worker-seconds burned per *arriving* job
//!   (warmup included; killed and crashed copies count up to the
//!   instant they stop),
//! * `failure_rate` — fraction of measured jobs lost to crash faults,
//! * `replications`/`completed` — simulated streams / streams with at
//!   least one completed job.
//!
//! [`OpenEstimate`] adds the quantities with no closed-system analogue:
//! worker utilization and the resolved arrival rate λ.
//!
//! ## Determinism
//!
//! Replication `rep` draws from `Pcg64::new(substream(stream_seed,
//! rep))` and writes into its own pre-assigned slot; the reduction runs
//! serially in replication order. Estimates are bit-identical for a
//! fixed seed regardless of thread count or pool width.

use std::sync::Mutex;

use crate::batching::Policy;
use crate::eval::{substream, Estimate, Estimator, Provenance, Scenario};
use crate::metrics::Summary;
use crate::sim::pool::WorkerPool;
use crate::sim::queue::{Arrivals, OpenRun, OpenSim};
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;

/// Default measured jobs per replication.
pub const DEFAULT_OPEN_JOBS: usize = 200;
/// Default warmup jobs (simulated, excluded from statistics).
pub const DEFAULT_OPEN_WARMUP: usize = 50;

/// Replications below this length are not worth a pool unit of their
/// own: one open-system replication is a whole stream simulation,
/// orders of magnitude heavier than a closed-system job draw.
const MIN_UNIT_OPEN_REPS: usize = 8;

/// First wave size for precision-targeted ([`OpenSystem::until_ci95`])
/// evaluation — smaller than the closed-system start because one
/// open-system replication is a whole stream simulation.
const AUTO_OPEN_WAVE_START: usize = 8;

/// Open-system operating point: the offered load and the measurement
/// window, carried per sweep case and hashed into its content key.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpenConfig {
    /// Offered load ρ ∈ (0, ∞), normalized so ρ = 1 saturates the
    /// cluster at B = N (no replication). ρ ≥ 1 — and, with replication
    /// overhead, loads well below 1 — can be unstable: the simulator
    /// still terminates (finitely many jobs) but sojourns grow with the
    /// measurement window.
    pub rho: f64,
    /// Measured jobs per replication.
    pub jobs: usize,
    /// Leading jobs simulated but excluded from statistics.
    pub warmup: usize,
}

impl OpenConfig {
    /// Operating point at load `rho` with the default window.
    pub fn at(rho: f64) -> OpenConfig {
        OpenConfig { rho, jobs: DEFAULT_OPEN_JOBS, warmup: DEFAULT_OPEN_WARMUP }
    }
}

/// Open-system Monte-Carlo estimator (see the module docs).
#[derive(Clone, Copy, Debug)]
pub struct OpenSystem {
    /// Independent job-stream replications.
    pub reps: usize,
    /// Base seed; batch entry points derive per-item streams via
    /// [`substream`].
    pub seed: u64,
    /// Fan-out cap: `0` defers to the pool width, `1` forces inline
    /// serial execution.
    pub threads: usize,
    /// Offered load and measurement window.
    pub open: OpenConfig,
}

/// An [`Estimate`] plus the open-system-only quantities.
#[derive(Clone, Debug)]
pub struct OpenEstimate {
    /// Sojourn-time statistics (field semantics in the module docs).
    pub estimate: Estimate,
    /// Mean worker utilization: busy worker-seconds over `N · horizon`,
    /// averaged across replications. Rises above ρ exactly when
    /// replication overhead is not recovered by kills.
    pub utilization: f64,
    /// Resolved Poisson arrival rate `λ = ρ / E[τ]`.
    pub lambda: f64,
}

impl OpenSystem {
    /// Estimator at load `rho` with default window, seed, and pool-width
    /// fan-out.
    pub fn at(rho: f64, reps: usize, seed: u64) -> OpenSystem {
        OpenSystem { reps, seed, threads: 0, open: OpenConfig::at(rho) }
    }

    /// Evaluate one scenario, returning utilization alongside the
    /// estimate.
    pub fn evaluate_open(&self, scenario: &Scenario) -> Result<OpenEstimate> {
        self.evaluate_open_seeded(scenario, self.seed)
    }

    /// Evaluate on an explicit stream seed (the sweep runner passes the
    /// case's content-derived `stream_seed` so results are independent
    /// of grid position).
    pub fn evaluate_open_seeded(
        &self,
        scenario: &Scenario,
        stream_seed: u64,
    ) -> Result<OpenEstimate> {
        if self.reps == 0 {
            return Err(Error::Config("open-system estimator needs reps ≥ 1".into()));
        }
        let batches = match scenario.policy {
            Policy::BalancedNonOverlapping { batches } => batches,
            _ => {
                return Err(Error::Config(format!(
                    "open-system evaluation supports only the balanced \
                     non-overlapping policy, not {}",
                    scenario.policy.name()
                )))
            }
        };
        if !self.open.rho.is_finite() || self.open.rho <= 0.0 {
            return Err(Error::Config(format!(
                "offered load rho must be finite and positive, got {}",
                self.open.rho
            )));
        }
        let mean_tau = scenario.tau.mean();
        if !mean_tau.is_finite() || mean_tau <= 0.0 {
            return Err(Error::Config(format!(
                "offered load needs a finite positive mean service time \
                 (E[tau] = {mean_tau} for {})",
                scenario.tau.label()
            )));
        }
        let lambda = self.open.rho / mean_tau;
        let sampler = scenario.tau.sampler();
        let spec = OpenSim {
            workers: scenario.workers,
            batches,
            sampler: &sampler,
            replication: scenario.replication,
            failures: scenario.failures,
            arrivals: Arrivals::Poisson { rate: lambda },
            warmup: self.open.warmup,
            jobs: self.open.jobs,
        };
        // Surface configuration errors before any pool unit queues.
        spec.check()?;

        let mut slots: Vec<Option<OpenRun>> = vec![None; self.reps];
        let first_error: Mutex<Option<(usize, Error)>> = Mutex::new(None);
        let threads = if self.threads == 0 {
            WorkerPool::global().threads()
        } else {
            self.threads
        };
        if threads <= 1 {
            for (rep, slot) in slots.iter_mut().enumerate() {
                run_rep(&spec, stream_seed, rep, slot, &first_error);
            }
        } else {
            let chunk_len = self.reps.div_ceil(unit_count(threads, self.reps));
            let errors = &first_error;
            let spec_ref = &spec;
            WorkerPool::global().scope(|scope| {
                let mut lo = 0usize;
                for chunk in slots.chunks_mut(chunk_len) {
                    let len = chunk.len();
                    scope.submit(move || {
                        for (k, slot) in chunk.iter_mut().enumerate() {
                            run_rep(spec_ref, stream_seed, lo + k, slot, errors);
                        }
                    });
                    lo += len;
                }
            });
        }
        let first_error =
            first_error.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some((_, error)) = first_error {
            return Err(error);
        }
        Ok(self.reduce(&slots, scenario.workers, lambda, stream_seed, threads))
    }

    /// Precision-targeted evaluation, mirroring
    /// [`crate::eval::MonteCarlo::until_ci95`]: double the stream count
    /// in waves (from [`AUTO_OPEN_WAVE_START`]) until the sojourn
    /// estimate's ci95 half-width drops to `eps` or the count reaches
    /// `max`. Each wave recomputes from replication 0 on
    /// `substream(stream_seed, rep)`, so the result is exactly the
    /// fixed-reps estimate at the realized count — byte-identical
    /// across thread counts, shards, and resume. The stopping rule
    /// depends only on the accumulated estimate (never wall-clock); a
    /// NaN ci95 keeps doubling until `max`.
    pub fn until_ci95(
        &self,
        scenario: &Scenario,
        stream_seed: u64,
        eps: f64,
        max: usize,
    ) -> Result<OpenEstimate> {
        if !(eps.is_finite() && eps > 0.0) {
            return Err(Error::Config(format!(
                "auto-reps eps must be finite and > 0, got {eps}"
            )));
        }
        if max == 0 {
            return Err(Error::Config("auto-reps max must be >= 1".into()));
        }
        let mut reps = AUTO_OPEN_WAVE_START.min(max);
        loop {
            let wave = OpenSystem { reps, ..*self };
            let open = wave.evaluate_open_seeded(scenario, stream_seed)?;
            if open.estimate.ci95 <= eps || reps == max {
                return Ok(open);
            }
            reps = reps.saturating_mul(2).min(max);
        }
    }

    /// Serial reduction in replication order — float accumulation is
    /// independent of how units were scheduled above.
    fn reduce(
        &self,
        runs: &[Option<OpenRun>],
        workers: usize,
        lambda: f64,
        seed: u64,
        threads: usize,
    ) -> OpenEstimate {
        let mut summary = Summary::new();
        let mut busy = 0.0_f64;
        let mut util = 0.0_f64;
        let mut failed = 0usize;
        let mut live_reps = 0usize;
        for run in runs.iter().flatten() {
            for &s in &run.sojourns {
                summary.record(s);
            }
            failed += run.failed;
            busy += run.busy;
            if run.horizon > 0.0 {
                util += run.busy / (workers as f64 * run.horizon);
            }
            if !run.sojourns.is_empty() {
                live_reps += 1;
            }
        }
        let measured = self.reps * self.open.jobs;
        let arrivals = self.reps * (self.open.jobs + self.open.warmup);
        let utilization = util / self.reps as f64;
        let provenance = Provenance::MonteCarlo { reps: self.reps, seed, threads };
        let estimate = if summary.count() == 0 {
            // Every measured job failed: no sojourn to summarize.
            Estimate {
                mean: f64::NAN,
                ci95: f64::NAN,
                cov: f64::NAN,
                p50: f64::NAN,
                p95: f64::NAN,
                p99: f64::NAN,
                cost: f64::NAN,
                failure_rate: 1.0,
                replications: self.reps,
                completed: 0,
                provenance,
            }
        } else {
            Estimate {
                mean: summary.mean(),
                ci95: summary.ci95(),
                cov: summary.cov(),
                p50: summary.quantile(0.50),
                p95: summary.quantile(0.95),
                p99: summary.quantile(0.99),
                cost: busy / arrivals as f64,
                failure_rate: failed as f64 / measured as f64,
                replications: self.reps,
                completed: live_reps,
                provenance,
            }
        };
        OpenEstimate { estimate, utilization, lambda }
    }
}

impl Estimator for OpenSystem {
    fn evaluate(&self, scenario: &Scenario) -> Result<Estimate> {
        Ok(self.evaluate_open(scenario)?.estimate)
    }

    fn evaluate_at(&self, scenario: &Scenario, index: u64) -> Result<Estimate> {
        let seed = substream(self.seed, index);
        Ok(self.evaluate_open_seeded(scenario, seed)?.estimate)
    }
}

/// Units to carve `reps` into: enough to saturate `threads` workers,
/// but never units smaller than [`MIN_UNIT_OPEN_REPS`] replications.
fn unit_count(threads: usize, reps: usize) -> usize {
    let max_by_reps = reps.div_ceil(MIN_UNIT_OPEN_REPS).max(1);
    (threads * 2).min(max_by_reps).max(1)
}

/// Run one replication into its pre-assigned slot; on error record the
/// lowest-replication failure so the reported error is deterministic.
fn run_rep(
    spec: &OpenSim<'_>,
    stream_seed: u64,
    rep: usize,
    slot: &mut Option<OpenRun>,
    errors: &Mutex<Option<(usize, Error)>>,
) {
    {
        let guard = errors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if guard.is_some() {
            return; // the batch already failed; stop early
        }
    }
    let mut rng = Pcg64::new(substream(stream_seed, rep as u64));
    match spec.run(&mut rng) {
        Ok(run) => *slot = Some(run),
        Err(error) => {
            let mut guard =
                errors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            match guard.as_ref() {
                Some((prev, _)) if *prev <= rep => {}
                _ => *guard = Some((rep, error)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ServiceDist;
    use crate::sim::job::FailureModel;

    fn scenario(workers: usize, batches: usize) -> Scenario {
        Scenario::balanced(workers, batches, ServiceDist::exp(1.0))
    }

    fn small(rho: f64) -> OpenSystem {
        OpenSystem {
            reps: 40,
            seed: 42,
            threads: 0,
            open: OpenConfig { rho, jobs: 60, warmup: 15 },
        }
    }

    #[test]
    fn produces_finite_statistics() {
        let est = small(0.3);
        let open = est.evaluate_open(&scenario(4, 2)).unwrap();
        let e = &open.estimate;
        assert!(e.mean.is_finite() && e.mean > 0.0);
        assert!(e.p50 <= e.p95 && e.p95 <= e.p99);
        assert!(e.cost.is_finite() && e.cost > 0.0);
        assert_eq!(e.failure_rate, 0.0);
        assert_eq!(e.replications, 40);
        assert_eq!(e.completed, 40);
        assert!(open.utilization > 0.0 && open.utilization < 1.0);
        // Exponential service: kill-on-complete recovers replication
        // overhead in expectation, so utilization stays near rho.
        assert!((open.lambda - 0.3).abs() < 1e-12);
    }

    #[test]
    fn estimator_trait_matches_direct_evaluation() {
        let est = small(0.2);
        let s = scenario(4, 4);
        let via_trait = est.evaluate(&s).unwrap();
        let direct = est.evaluate_open(&s).unwrap().estimate;
        assert_eq!(via_trait.mean.to_bits(), direct.mean.to_bits());
        assert_eq!(via_trait.p99.to_bits(), direct.p99.to_bits());
    }

    #[test]
    fn bit_identical_across_thread_caps() {
        let s = scenario(4, 2);
        let mut base: Option<Estimate> = None;
        for threads in [1usize, 2, 4, 8] {
            let est = OpenSystem { threads, ..small(0.5) };
            let e = est.evaluate(&s).unwrap();
            if let Some(b) = &base {
                assert_eq!(b.mean.to_bits(), e.mean.to_bits(), "threads={threads}");
                assert_eq!(b.ci95.to_bits(), e.ci95.to_bits(), "threads={threads}");
                assert_eq!(b.p99.to_bits(), e.p99.to_bits(), "threads={threads}");
                assert_eq!(b.cost.to_bits(), e.cost.to_bits(), "threads={threads}");
            } else {
                base = Some(e);
            }
        }
    }

    #[test]
    fn rejects_bad_configurations() {
        let est = small(0.0);
        assert!(est.evaluate(&scenario(4, 2)).is_err()); // rho = 0
        let est = small(f64::NAN);
        assert!(est.evaluate(&scenario(4, 2)).is_err());
        let est = OpenSystem { reps: 0, ..small(0.5) };
        assert!(est.evaluate(&scenario(4, 2)).is_err());
        // Infinite-mean service has no finite arrival rate.
        let heavy = Scenario::balanced(4, 2, ServiceDist::pareto(1.0, 0.9));
        assert!(small(0.5).evaluate(&heavy).is_err());
        // Timed policy + crash faults is rejected, as closed-system.
        let s = scenario(4, 2)
            .with_failures(FailureModel::Crash { p: 0.1 })
            .with_replication(crate::sim::ReplicationPolicy::SpeculativeAt { t: 1.0 });
        assert!(small(0.5).evaluate(&s).is_err());
    }

    #[test]
    fn crash_faults_surface_in_failure_rate() {
        let mut est = small(0.2);
        est.reps = 30;
        let s = scenario(4, 2).with_failures(FailureModel::Crash { p: 0.3 });
        let e = est.evaluate(&s).unwrap();
        assert!(e.failure_rate > 0.0 && e.failure_rate < 1.0);
        let all = scenario(4, 2).with_failures(FailureModel::Crash { p: 1.0 });
        let e = est.evaluate(&all).unwrap();
        assert!(e.all_failed());
        assert_eq!(e.failure_rate, 1.0);
    }

    #[test]
    fn until_ci95_matches_fixed_reps_at_the_realized_count() {
        let s = scenario(4, 2);
        let base = small(0.3);
        let auto = base.until_ci95(&s, 11, 0.2, 256).unwrap();
        assert!(auto.estimate.ci95 <= 0.2, "ci95 {}", auto.estimate.ci95);
        let fixed = OpenSystem { reps: auto.estimate.replications, ..base }
            .evaluate_open_seeded(&s, 11)
            .unwrap();
        assert_eq!(auto.estimate.mean.to_bits(), fixed.estimate.mean.to_bits());
        assert_eq!(auto.estimate.ci95.to_bits(), fixed.estimate.ci95.to_bits());
        assert_eq!(auto.utilization.to_bits(), fixed.utilization.to_bits());
        // unreachable target stops exactly at max, thread-invariantly
        let capped = base.until_ci95(&s, 11, 1e-12, 24).unwrap();
        assert_eq!(capped.estimate.replications, 24);
        let wide = OpenSystem { threads: 4, ..base }
            .until_ci95(&s, 11, 1e-12, 24)
            .unwrap();
        assert_eq!(capped.estimate.mean.to_bits(), wide.estimate.mean.to_bits());
        // bad targets are rejected
        assert!(base.until_ci95(&s, 11, 0.0, 24).is_err());
        assert!(base.until_ci95(&s, 11, f64::NAN, 24).is_err());
        assert!(base.until_ci95(&s, 11, 0.1, 0).is_err());
    }

    #[test]
    fn load_hurts_sojourn_time() {
        // The same cluster at 4x the load queues more: mean sojourn
        // must rise (deterministic seeds; comfortably separated loads).
        let s = scenario(4, 4);
        let light = small(0.1).evaluate(&s).unwrap();
        let heavy = small(0.8).evaluate(&s).unwrap();
        assert!(heavy.mean > light.mean);
    }
}
