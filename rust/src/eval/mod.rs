//! Unified scenario evaluation — one API over analytic, Monte-Carlo,
//! and (future) backends.
//!
//! The paper's central workflow is: evaluate E\[T\] and CoV\[T\] for a
//! `(N, policy, τ)` scenario, then optimize over the batch count B.
//! This module gives that workflow a single pluggable interface:
//!
//! * [`Scenario`] — the value being asked about: worker budget,
//!   replication policy, task service-time model, failure model.
//! * [`Estimate`] — the rich answer: mean with a 95% CI, CoV,
//!   p50/p95/p99, failure rate, and a [`Provenance`] recording which
//!   backend produced it.
//! * [`Estimator`] — the trait every backend implements, with batched
//!   entry points ([`Estimator::evaluate_many`], [`Estimator::sweep`])
//!   that amortize allocation across the operating-point spectrum.
//!
//! Three backends ship today:
//!
//! * [`Analytic`] — the paper's closed forms (eqs. 18–26). Exact and
//!   effectively free, but only exists for Exp/SExp/Pareto service
//!   under the balanced non-overlapping policy with no failures; errors
//!   cleanly otherwise.
//! * [`MonteCarlo`] — the replication driver, executed on the
//!   persistent [`crate::sim::pool::WorkerPool`] with two-level
//!   scenario×replication-chunk parallelism (batch entry points run
//!   whole sweeps concurrently). Per-replication counter-based RNG
//!   streams (see [`substream`]) make results bit-identical for a
//!   fixed seed regardless of thread count or pool width.
//! * [`Auto`] — analytic when exact, transparent Monte-Carlo fallback
//!   for empirical/bimodal service times, overlapping policies, and
//!   failure injection. The choice is visible in
//!   [`Estimate::provenance`].
//! * [`OpenSystem`] — the *open-system* mode: instead of one job on an
//!   idle cluster, a Poisson job stream at offered load ρ queues per
//!   worker ([`crate::sim::queue`]) and the estimate summarizes sojourn
//!   times. Same determinism contract (per-replication substreams);
//!   [`OpenEstimate`] adds worker utilization.
//!
//! Consumers (planner, experiments, CLI, benches) write against
//! [`Estimator`] and never hand-roll seed salting or layout reuse.

mod analytic;
mod auto;
mod montecarlo;
mod opensys;

pub use analytic::Analytic;
pub use auto::Auto;
pub use montecarlo::MonteCarlo;
pub use opensys::{
    OpenConfig, OpenEstimate, OpenSystem, DEFAULT_OPEN_JOBS, DEFAULT_OPEN_WARMUP,
};

use std::sync::Arc;

use crate::batching::{operating_points, OperatingPoint, Policy};
use crate::dist::ServiceDist;
use crate::sim::job::FailureModel;
use crate::sim::policy::ReplicationPolicy;
use crate::util::error::Result;

/// Default replication count for Monte-Carlo backends constructed via
/// `Default` (re-exported as `experiments::DEFAULT_REPS`).
pub const DEFAULT_REPS: usize = 20_000;

/// Derive the seed of an independent RNG substream.
///
/// This is the one sanctioned way to split a user-facing seed into
/// per-replication / per-operating-point / per-job streams: a
/// SplitMix64 finalization of `seed ⊕ index·φ⁻¹` (the same mixer
/// [`crate::util::rng::Pcg64::new`] seeds through). Distinct indices
/// give well-separated streams even for adjacent seeds, and the
/// mapping is pure — callers running in parallel need no shared state.
pub fn substream(seed: u64, index: u64) -> u64 {
    let mut z = seed ^ index.wrapping_mul(0x9E3779B97F4A7C15);
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One evaluation question: "what does job compute time look like for
/// this cluster?".
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Worker budget N (= task count under the paper's model).
    pub workers: usize,
    /// Task replication policy.
    pub policy: Policy,
    /// Task service-time distribution τ, shared by reference: cloning a
    /// `Scenario` (or expanding one job into a whole sweep grid) bumps
    /// a refcount instead of copying the distribution — an empirical τ
    /// carries every trace sample (~8 KB at cluster scale), and sweep
    /// grids hold thousands of cases per job.
    pub tau: Arc<ServiceDist>,
    /// Worker failure model (only the Monte-Carlo backend can evaluate
    /// scenarios with failures).
    pub failures: FailureModel,
    /// Replication *timing* policy: when a batch's replicas launch
    /// (up-front by default — the paper's model; timed policies are
    /// Monte-Carlo-only and add a worker-seconds cost axis).
    pub replication: ReplicationPolicy,
}

impl Scenario {
    /// Scenario with no failure injection. Accepts an owned
    /// [`ServiceDist`] or an already-shared `Arc<ServiceDist>`; callers
    /// building many scenarios over one τ should pass `Arc` clones so
    /// the distribution is allocated once.
    pub fn new(
        workers: usize,
        policy: Policy,
        tau: impl Into<Arc<ServiceDist>>,
    ) -> Scenario {
        Scenario {
            workers,
            policy,
            tau: tau.into(),
            failures: FailureModel::None,
            replication: ReplicationPolicy::Upfront,
        }
    }

    /// The common case: balanced non-overlapping batches (the provably
    /// optimal family, Theorems 1–2).
    pub fn balanced(
        workers: usize,
        batches: usize,
        tau: impl Into<Arc<ServiceDist>>,
    ) -> Scenario {
        Scenario::new(workers, Policy::BalancedNonOverlapping { batches }, tau)
    }

    pub fn with_failures(mut self, failures: FailureModel) -> Scenario {
        self.failures = failures;
        self
    }

    /// Select the replication timing policy (see [`ReplicationPolicy`]).
    pub fn with_replication(mut self, replication: ReplicationPolicy) -> Scenario {
        self.replication = replication;
        self
    }

    /// Short human-readable description for errors and reports. The
    /// replication policy appears only when it is not the up-front
    /// default, keeping pre-policy labels stable.
    pub fn label(&self) -> String {
        let base =
            format!("N={} {} tau~{}", self.workers, self.policy.name(), self.tau.label());
        if self.replication.is_upfront() {
            base
        } else {
            format!("{base} {}", self.replication.label())
        }
    }
}

/// Which backend produced an [`Estimate`], with enough detail to
/// reproduce it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Provenance {
    /// Exact closed forms (eqs. 18–26) + CDF inversion for quantiles.
    Analytic,
    /// Monte-Carlo sampling with the recorded parameters (`seed` is the
    /// resolved per-call stream seed, `threads` the resolved fan-out).
    MonteCarlo { reps: usize, seed: u64, threads: usize },
}

impl Provenance {
    /// Backend name for tables / logs.
    pub fn backend(&self) -> &'static str {
        match self {
            Provenance::Analytic => "analytic",
            Provenance::MonteCarlo { .. } => "monte-carlo",
        }
    }
}

/// Compute-time statistics for one [`Scenario`].
///
/// Degenerate case: when **every** Monte-Carlo replication fails
/// coverage ([`Estimate::all_failed`] is true), there is no completion
/// time to summarize — `mean`, `ci95`, `cov` and the percentiles are
/// all `NaN` by construction and `failure_rate` is exactly 1.0. With a
/// single completed replication, `ci95` is `NaN` (a CI needs ≥ 2
/// samples) while `mean` is that sample.
#[derive(Clone, Debug)]
pub struct Estimate {
    /// Mean completion time (over completed replications for MC).
    pub mean: f64,
    /// 95% CI half-width of the mean (0 for analytic estimates).
    pub ci95: f64,
    /// Coefficient of variation of completion time.
    pub cov: f64,
    /// Percentiles of completion time.
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// Expected total **cost** in worker-seconds under
    /// kill-at-batch-completion (see [`ReplicationPolicy`] for the
    /// per-policy semantics). NaN when the evaluation path does not
    /// track cost (overlapping layouts, failure injection,
    /// materialized random layouts) or when every replication failed.
    pub cost: f64,
    /// Fraction of replications where coverage failed (always 0 for
    /// analytic estimates — closed forms assume full coverage).
    pub failure_rate: f64,
    /// Monte-Carlo replication count (0 for analytic estimates).
    pub replications: usize,
    /// Replications that completed (0 for analytic estimates).
    pub completed: usize,
    /// Which backend produced this estimate.
    pub provenance: Provenance,
}

impl Estimate {
    /// True when a Monte-Carlo run had *zero* completed replications
    /// (every replication failed coverage): all statistics are `NaN`
    /// and only `failure_rate` (= 1.0) is meaningful.
    pub fn all_failed(&self) -> bool {
        self.replications > 0 && self.completed == 0
    }
}

/// A scenario-evaluation backend.
///
/// Implementations must be deterministic: the same estimator value
/// applied to the same scenario yields the same estimate, regardless of
/// thread count or call order.
pub trait Estimator {
    /// Evaluate one scenario.
    fn evaluate(&self, scenario: &Scenario) -> Result<Estimate>;

    /// Evaluate one scenario on an independent substream.
    ///
    /// Stochastic backends derive their RNG stream from
    /// [`substream`]`(seed, index)` so that batch entry points get
    /// independent randomness per item without hand-rolled seed
    /// salting. Deterministic backends ignore `index`.
    fn evaluate_at(&self, scenario: &Scenario, index: u64) -> Result<Estimate> {
        let _ = index;
        self.evaluate(scenario)
    }

    /// Evaluate a batch of scenarios, item `i` on substream `i`.
    ///
    /// Backends may override this to amortize allocation across items
    /// (the Monte-Carlo backend reuses one replication buffer).
    fn evaluate_many(&self, scenarios: &[Scenario]) -> Result<Vec<Estimate>> {
        scenarios
            .iter()
            .enumerate()
            .map(|(i, s)| self.evaluate_at(s, i as u64))
            .collect()
    }

    /// Evaluate the full diversity–parallelism spectrum: one balanced
    /// scenario per feasible B (divisors of `workers`, ascending), each
    /// on its own substream. The whole spectrum shares one τ allocation.
    fn sweep(
        &self,
        workers: usize,
        tau: &ServiceDist,
    ) -> Result<Vec<(OperatingPoint, Estimate)>> {
        let points = operating_points(workers);
        let shared: Arc<ServiceDist> = Arc::new(tau.clone());
        let scenarios: Vec<Scenario> = points
            .iter()
            .map(|op| Scenario::balanced(workers, op.batches, Arc::clone(&shared)))
            .collect();
        Ok(points.into_iter().zip(self.evaluate_many(&scenarios)?).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn substream_is_deterministic_and_index_sensitive() {
        assert_eq!(substream(42, 7), substream(42, 7));
        let streams: Vec<u64> = (0..64).map(|i| substream(42, i)).collect();
        for (i, a) in streams.iter().enumerate() {
            for (j, b) in streams.iter().enumerate() {
                if i != j {
                    assert_ne!(a, b, "indices {i} and {j} collide");
                }
            }
        }
        assert_ne!(substream(1, 0), substream(2, 0));
    }

    #[test]
    fn scenario_constructors() {
        let s = Scenario::balanced(12, 3, ServiceDist::exp(1.0));
        assert_eq!(s.workers, 12);
        assert_eq!(s.failures, FailureModel::None);
        assert_eq!(s.replication, ReplicationPolicy::Upfront);
        assert!(matches!(s.policy, Policy::BalancedNonOverlapping { batches: 3 }));
        let s = s.with_failures(FailureModel::Crash { p: 0.1 });
        assert!(matches!(s.failures, FailureModel::Crash { .. }));
        assert!(s.label().contains("balanced-nonoverlap"));
        // the up-front default keeps pre-policy labels byte-stable
        assert!(!s.label().contains("upfront"));
        let timed = Scenario::balanced(12, 3, ServiceDist::exp(1.0))
            .with_replication(ReplicationPolicy::SpeculativeAt { t: 0.5 });
        assert!(timed.label().contains("speculative(t=0.5)"));
    }

    #[test]
    fn provenance_backend_names() {
        assert_eq!(Provenance::Analytic.backend(), "analytic");
        assert_eq!(
            Provenance::MonteCarlo { reps: 1, seed: 0, threads: 1 }.backend(),
            "monte-carlo"
        );
    }

    #[test]
    fn sweep_covers_the_spectrum_in_order() {
        let est = Analytic;
        let rows = est.sweep(12, &ServiceDist::exp(1.0)).unwrap();
        assert_eq!(rows.len(), 6); // divisors of 12
        assert!(rows[0].0.is_full_diversity());
        assert!(rows.last().unwrap().0.is_full_parallelism());
        // Theorem 3: mean increasing in B for Exp service
        for w in rows.windows(2) {
            assert!(w[1].1.mean > w[0].1.mean);
        }
    }
}
