//! Closed-form backend: exact answers where the paper derives them.

use crate::analysis::closed_form;
use crate::batching::Policy;
use crate::dist::ServiceDist;
use crate::eval::{Estimate, Estimator, Provenance, Scenario};
use crate::sim::job::FailureModel;
use crate::util::error::{Error, Result};
use crate::util::math::bisect;

/// The analytic estimator: eqs. (18)–(26) for mean and CoV, plus exact
/// CDF inversion for the percentiles.
///
/// Only scenarios the paper has closed forms for are supported —
/// Exp/SExp/Pareto service times under the balanced non-overlapping
/// policy with no failure injection. Anything else is a clean
/// [`Error::Config`]; use [`crate::eval::MonteCarlo`] or
/// [`crate::eval::Auto`] there instead.
#[derive(Clone, Copy, Debug, Default)]
pub struct Analytic;

impl Analytic {
    /// Does a closed form exist for this scenario? Timed replication
    /// policies have none (the paper only derives up-front forms), so
    /// they always route to Monte-Carlo.
    pub fn supports(scenario: &Scenario) -> bool {
        matches!(scenario.policy, Policy::BalancedNonOverlapping { .. })
            && scenario.failures == FailureModel::None
            && scenario.replication.is_upfront()
            && matches!(
                *scenario.tau,
                ServiceDist::Exp { .. }
                    | ServiceDist::ShiftedExp { .. }
                    | ServiceDist::Pareto { .. }
            )
    }
}

impl Estimator for Analytic {
    fn evaluate(&self, scenario: &Scenario) -> Result<Estimate> {
        if !Analytic::supports(scenario) {
            return Err(Error::Config(format!(
                "no closed form for scenario [{}] (closed forms cover \
                 Exp/SExp/Pareto service under the balanced non-overlapping \
                 policy without failures); use the MonteCarlo or Auto backend",
                scenario.label()
            )));
        }
        let n = scenario.workers;
        let b = match scenario.policy {
            Policy::BalancedNonOverlapping { batches } => batches,
            _ => unreachable!("supports() checked the policy"),
        };
        if b == 0 || b > n || n % b != 0 {
            return Err(Error::Policy(format!("B={b} must divide N={n} (1 ≤ B ≤ N)")));
        }
        Ok(Estimate {
            mean: closed_form::mean_t(n, b, &scenario.tau),
            ci95: 0.0,
            cov: closed_form::cov_t(n, b, &scenario.tau),
            p50: job_quantile(n, b, &scenario.tau, 0.50),
            p95: job_quantile(n, b, &scenario.tau, 0.95),
            p99: job_quantile(n, b, &scenario.tau, 0.99),
            cost: closed_form::cost_t(n, b, &scenario.tau),
            failure_rate: 0.0,
            replications: 0,
            completed: 0,
            provenance: Provenance::Analytic,
        })
    }
}

/// Quantile of the job compute time `T = max_i min_{j≤N/B} (N/B)·τ_ij`
/// under the balanced policy, by bisecting the exact CDF
/// `F(t) = (1 − S_batch(t)^r)^B` with `r = N/B`.
fn job_quantile(n: usize, b: usize, tau: &ServiceDist, q: f64) -> f64 {
    let r = n / b;
    let batch = ServiceDist::scaled(r as f64, tau.clone());
    let cdf = |t: f64| -> f64 {
        let s = batch.ccdf(t);
        (1.0 - s.powi(r as i32)).powi(b as i32)
    };
    // Bracket the quantile: start at a high batch-level quantile and
    // double until the job CDF clears q (heavy tails need room).
    let mut hi = batch.quantile(0.99).max(1e-9);
    let mut guard = 0;
    while cdf(hi) < q && guard < 200 {
        hi *= 2.0;
        guard += 1;
    }
    bisect(|t| cdf(t) - q, 0.0, hi, 1e-10 * hi.max(1.0)).unwrap_or(f64::NAN)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::harmonic::h1;

    #[test]
    fn exp_closed_forms_flow_through() {
        // B=4, Exp(2): E[T] = H_4/2
        let est = Analytic.evaluate(&Scenario::balanced(20, 4, ServiceDist::exp(2.0))).unwrap();
        assert!((est.mean - h1(4) / 2.0).abs() < 1e-12);
        // up-front cost for Exp(μ) is N/μ regardless of B
        assert!((est.cost - 10.0).abs() < 1e-12);
        assert_eq!(est.provenance, Provenance::Analytic);
        assert_eq!(est.failure_rate, 0.0);
        assert_eq!(est.ci95, 0.0);
        assert_eq!(est.replications, 0);
        assert!(!est.all_failed());
    }

    #[test]
    fn quantiles_invert_the_job_cdf() {
        // B=1, r=N: T = min over N workers of N·τ. For Exp(μ) that min is
        // Exp(Nμ/N·... ) — easier: check round trip through the CDF.
        let (n, b) = (10usize, 2usize);
        let tau = ServiceDist::exp(1.0);
        let est = Analytic.evaluate(&Scenario::balanced(n, b, tau.clone())).unwrap();
        let r = n / b;
        let batch = ServiceDist::scaled(r as f64, tau);
        for (t, q) in [(est.p50, 0.50), (est.p95, 0.95), (est.p99, 0.99)] {
            let back = (1.0 - batch.ccdf(t).powi(r as i32)).powi(b as i32);
            assert!((back - q).abs() < 1e-6, "q={q}: t={t} back={back}");
        }
        assert!(est.p50 < est.p95 && est.p95 < est.p99);
    }

    #[test]
    fn unsupported_scenarios_error_cleanly() {
        // overlapping policy
        let s = Scenario::new(
            6,
            Policy::CyclicOverlapping { batches: 3 },
            ServiceDist::exp(1.0),
        );
        assert!(Analytic.evaluate(&s).is_err());
        // no closed form for Weibull
        let s = Scenario::balanced(6, 3, ServiceDist::weibull(0.7, 1.0));
        assert!(Analytic.evaluate(&s).is_err());
        // failure injection
        let s = Scenario::balanced(6, 3, ServiceDist::exp(1.0))
            .with_failures(FailureModel::Crash { p: 0.1 });
        assert!(Analytic.evaluate(&s).is_err());
        // timed replication policy (no closed forms)
        let s = Scenario::balanced(6, 3, ServiceDist::exp(1.0)).with_replication(
            crate::sim::policy::ReplicationPolicy::SpeculativeAt { t: 1.0 },
        );
        assert!(Analytic.evaluate(&s).is_err());
        // infeasible B
        let s = Scenario::balanced(10, 3, ServiceDist::exp(1.0));
        assert!(Analytic.evaluate(&s).is_err());
    }

    #[test]
    fn pareto_infinite_mean_is_reported_as_infinity() {
        // B/(Nα) ≥ 1 → infinite mean, finite quantiles
        let est = Analytic
            .evaluate(&Scenario::balanced(4, 4, ServiceDist::pareto(1.0, 0.9)))
            .unwrap();
        assert!(est.mean.is_infinite());
        assert!(est.p50.is_finite() && est.p50 > 0.0);
    }
}
