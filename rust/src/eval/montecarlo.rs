//! Monte-Carlo backend: threaded replication with counter-based RNG
//! streams.

use crate::batching::Policy;
use crate::eval::{substream, Estimate, Estimator, Provenance, Scenario};
use crate::metrics::Summary;
use crate::sim::job::{JobOutcome, JobSimulator};
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;

/// Substream index reserved for layout materialization (replication
/// streams use indices `0..reps`, far below this).
const LAYOUT_STREAM: u64 = u64::MAX;

/// The Monte-Carlo estimator.
///
/// Replications are fanned out across OS threads, but every replication
/// draws from its own counter-based RNG stream
/// (`substream(seed, rep)`) and results are reduced serially in
/// replication order — so for a fixed seed the estimate is
/// **bit-identical regardless of `threads`**. Layout-randomizing
/// policies (random assignment) draw a fresh layout per replication
/// from that same stream; deterministic policies materialize one layout
/// up front and share it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MonteCarlo {
    /// Number of independent replications.
    pub reps: usize,
    /// Base seed; batch entry points derive per-item streams from it
    /// via [`substream`].
    pub seed: u64,
    /// OS threads to fan replications across; 0 means "all available
    /// cores".
    pub threads: usize,
}

impl MonteCarlo {
    /// Estimator with the given replication budget, using every
    /// available core.
    pub fn new(reps: usize, seed: u64) -> MonteCarlo {
        MonteCarlo { reps, seed, threads: 0 }
    }

    /// Restrict (or widen) the thread fan-out. `0` = all cores.
    pub fn with_threads(mut self, threads: usize) -> MonteCarlo {
        self.threads = threads;
        self
    }

    /// Single-threaded variant (useful for micro-benchmark baselines).
    pub fn serial(reps: usize, seed: u64) -> MonteCarlo {
        MonteCarlo { reps, seed, threads: 1 }
    }

    fn effective_threads(&self) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        };
        t.clamp(1, self.reps.max(1))
    }

    /// Core driver: evaluate `scenario` with the given stream seed,
    /// reusing `outcomes` as the replication buffer (batch entry points
    /// amortize this allocation across calls).
    fn run(
        &self,
        scenario: &Scenario,
        seed: u64,
        outcomes: &mut Vec<JobOutcome>,
    ) -> Result<Estimate> {
        if self.reps == 0 {
            return Err(Error::Config("MonteCarlo needs reps >= 1".into()));
        }
        let n = scenario.workers;
        let randomized = matches!(scenario.policy, Policy::RandomNonOverlapping { .. });
        // Materialize a layout up front: deterministic policies keep it
        // for every replication; for randomizing policies this is a
        // feasibility probe so errors surface before threads spawn.
        let mut layout_rng = Pcg64::new(substream(seed, LAYOUT_STREAM));
        let probe = scenario.policy.layout(n, &mut layout_rng)?;
        let fixed_sim = if randomized {
            None
        } else {
            Some(
                JobSimulator::new(probe, scenario.tau.clone())
                    .with_failures(scenario.failures),
            )
        };

        let threads = self.effective_threads();
        outcomes.clear();
        outcomes.resize(self.reps, JobOutcome::Failed);

        let sample_one = |rep: usize| -> JobOutcome {
            let mut rng = Pcg64::new(substream(seed, rep as u64));
            match &fixed_sim {
                Some(sim) => sim.sample(&mut rng),
                None => {
                    let layout = scenario
                        .policy
                        .layout(n, &mut rng)
                        .expect("feasibility probed before replication");
                    JobSimulator::new(layout, scenario.tau.clone())
                        .with_failures(scenario.failures)
                        .sample(&mut rng)
                }
            }
        };

        if threads <= 1 {
            for (rep, slot) in outcomes.iter_mut().enumerate() {
                *slot = sample_one(rep);
            }
        } else {
            let chunk = self.reps.div_ceil(threads);
            std::thread::scope(|scope| {
                for (ci, slice) in outcomes.chunks_mut(chunk).enumerate() {
                    let sample_one = &sample_one;
                    scope.spawn(move || {
                        for (i, slot) in slice.iter_mut().enumerate() {
                            *slot = sample_one(ci * chunk + i);
                        }
                    });
                }
            });
        }

        // Serial reduction in replication order: float accumulation is
        // independent of the thread partition above.
        let mut summary = Summary::new();
        let mut failed = 0usize;
        for outcome in outcomes.iter() {
            match outcome {
                JobOutcome::Done(t) => summary.record(*t),
                JobOutcome::Failed => failed += 1,
            }
        }
        let completed = self.reps - failed;
        let provenance = Provenance::MonteCarlo { reps: self.reps, seed, threads };
        if completed == 0 {
            // Every replication failed coverage: there is no completion
            // time to summarize. Report that explicitly instead of
            // leaking NaNs out of an empty Summary.
            return Ok(Estimate {
                mean: f64::NAN,
                ci95: f64::NAN,
                cov: f64::NAN,
                p50: f64::NAN,
                p95: f64::NAN,
                p99: f64::NAN,
                failure_rate: 1.0,
                replications: self.reps,
                completed: 0,
                provenance,
            });
        }
        Ok(Estimate {
            mean: summary.mean(),
            ci95: summary.ci95(),
            cov: summary.cov(),
            p50: summary.quantile(0.50),
            p95: summary.quantile(0.95),
            p99: summary.quantile(0.99),
            failure_rate: failed as f64 / self.reps as f64,
            replications: self.reps,
            completed,
            provenance,
        })
    }
}

impl Default for MonteCarlo {
    fn default() -> MonteCarlo {
        MonteCarlo::new(crate::eval::DEFAULT_REPS, 0xD15EA5E)
    }
}

impl Estimator for MonteCarlo {
    fn evaluate(&self, scenario: &Scenario) -> Result<Estimate> {
        self.run(scenario, self.seed, &mut Vec::new())
    }

    fn evaluate_at(&self, scenario: &Scenario, index: u64) -> Result<Estimate> {
        self.run(scenario, substream(self.seed, index), &mut Vec::new())
    }

    fn evaluate_many(&self, scenarios: &[Scenario]) -> Result<Vec<Estimate>> {
        // One replication buffer amortized across the whole batch.
        let mut outcomes = Vec::with_capacity(self.reps);
        let mut estimates = Vec::with_capacity(scenarios.len());
        for (i, scenario) in scenarios.iter().enumerate() {
            estimates.push(self.run(
                scenario,
                substream(self.seed, i as u64),
                &mut outcomes,
            )?);
        }
        Ok(estimates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::closed_form;
    use crate::dist::ServiceDist;
    use crate::sim::job::FailureModel;

    #[test]
    fn matches_closed_form_within_ci() {
        let tau = ServiceDist::shifted_exp(0.05, 1.0);
        for b in [1usize, 4, 20] {
            let est = MonteCarlo::new(30_000, 42)
                .evaluate(&Scenario::balanced(20, b, tau.clone()))
                .unwrap();
            let want = closed_form::sexp_mean(20, b, 0.05, 1.0);
            assert!(
                (est.mean - want).abs() < 4.0 * est.ci95.max(1e-3),
                "B={b}: {} vs {want} (ci {})",
                est.mean,
                est.ci95
            );
            assert_eq!(est.failure_rate, 0.0);
            assert_eq!(est.completed, 30_000);
            assert!(est.p50 <= est.p95 && est.p95 <= est.p99);
        }
    }

    #[test]
    fn bit_identical_across_thread_counts() {
        let tau = ServiceDist::pareto(1.0, 2.5);
        let scenario = Scenario::balanced(20, 4, tau);
        let serial = MonteCarlo::serial(5_000, 7).evaluate(&scenario).unwrap();
        for threads in [2usize, 3, 4, 8] {
            let par = MonteCarlo { reps: 5_000, seed: 7, threads }
                .evaluate(&scenario)
                .unwrap();
            assert_eq!(serial.mean.to_bits(), par.mean.to_bits(), "{threads} threads");
            assert_eq!(serial.cov.to_bits(), par.cov.to_bits());
            assert_eq!(serial.p99.to_bits(), par.p99.to_bits());
            assert_eq!(serial.failure_rate, par.failure_rate);
        }
    }

    #[test]
    fn randomized_layouts_are_thread_invariant_too() {
        let scenario = Scenario::new(
            20,
            Policy::RandomNonOverlapping { batches: 5 },
            ServiceDist::exp(1.0),
        );
        let a = MonteCarlo::serial(4_000, 3).evaluate(&scenario).unwrap();
        let b = MonteCarlo { reps: 4_000, seed: 3, threads: 4 }
            .evaluate(&scenario)
            .unwrap();
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.failure_rate, b.failure_rate);
        assert!(a.failure_rate > 0.0, "random B=5 on N=20 should fail sometimes");
    }

    #[test]
    fn distinct_seeds_give_distinct_estimates() {
        let scenario = Scenario::balanced(10, 2, ServiceDist::exp(1.0));
        let a = MonteCarlo::new(1_000, 7).evaluate(&scenario).unwrap();
        let b = MonteCarlo::new(1_000, 7).evaluate(&scenario).unwrap();
        let c = MonteCarlo::new(1_000, 8).evaluate(&scenario).unwrap();
        assert_eq!(a.mean, b.mean);
        assert_ne!(a.mean, c.mean);
    }

    #[test]
    fn all_replications_failing_is_explicit() {
        // every worker crashes: no replication can complete
        let scenario = Scenario::balanced(8, 2, ServiceDist::exp(1.0))
            .with_failures(FailureModel::Crash { p: 1.0 });
        let est = MonteCarlo::new(500, 1).evaluate(&scenario).unwrap();
        assert!(est.all_failed());
        assert_eq!(est.completed, 0);
        assert_eq!(est.failure_rate, 1.0);
        assert!(est.mean.is_nan() && est.ci95.is_nan() && est.cov.is_nan());
        assert!(est.p50.is_nan() && est.p99.is_nan());
    }

    #[test]
    fn evaluate_many_matches_evaluate_at() {
        let mc = MonteCarlo::new(2_000, 11);
        let scenarios: Vec<Scenario> = [1usize, 2, 5]
            .iter()
            .map(|&b| Scenario::balanced(10, b, ServiceDist::exp(1.0)))
            .collect();
        let batch = mc.evaluate_many(&scenarios).unwrap();
        for (i, s) in scenarios.iter().enumerate() {
            let single = mc.evaluate_at(s, i as u64).unwrap();
            assert_eq!(batch[i].mean.to_bits(), single.mean.to_bits(), "item {i}");
        }
        // different items run on different substreams
        assert_ne!(batch[0].provenance, batch[1].provenance);
    }

    #[test]
    fn infeasible_scenario_is_error() {
        let s = Scenario::balanced(10, 3, ServiceDist::exp(1.0));
        assert!(MonteCarlo::new(10, 0).evaluate(&s).is_err());
        let s = Scenario::balanced(10, 2, ServiceDist::exp(1.0));
        assert!(MonteCarlo::new(0, 0).evaluate(&s).is_err());
    }
}
