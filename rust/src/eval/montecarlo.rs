//! Monte-Carlo backend: pooled two-level replication with
//! counter-based RNG streams.
//!
//! Execution shape: every entry point funnels into [`MonteCarlo::run_batch`],
//! which prepares each scenario once (layout probe, compiled sampler),
//! carves the whole batch into scenario×replication-chunk units, and
//! fans those units across the persistent [`WorkerPool`] — so a
//! 200-point sweep keeps every core busy instead of serializing
//! scenario-by-scenario with a thread spawn/join per scenario.

use crate::batching::Policy;
use crate::dist::Sampler;
use crate::eval::{substream, Estimate, Estimator, Provenance, Scenario};
use crate::metrics::{CostAccumulator, Summary};
use crate::sim::job::{
    fast_disjoint_layout, FailureModel, JobOutcome, JobSimulator, ServiceModel, SimScratch,
    SimView,
};
use crate::sim::policy::ReplicationPolicy;
use crate::sim::pool::WorkerPool;
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;
use std::sync::Mutex;

/// Substream index reserved for layout materialization (replication
/// streams use indices `0..reps`, far below this).
const LAYOUT_STREAM: u64 = u64::MAX;

/// Don't split a scenario into units smaller than this many
/// replications — below that, queue traffic beats the parallelism win.
const MIN_UNIT_REPS: usize = 256;

/// Upper bound on outcome slots held live at once (≈ 64 MiB of
/// `JobOutcome`): very large batches are processed in waves of this
/// many slots so memory stays bounded by the wave, not the sweep.
const MAX_WAVE_SLOTS: usize = 1 << 22;

/// First wave size for precision-targeted ([`MonteCarlo::until_ci95`])
/// evaluation. Waves double from here and each wave recomputes from
/// replication 0, so total work is at most 2× the realized count and
/// the returned estimate is exactly the fixed-reps estimate at that
/// count — shard- and position-independent by construction.
const AUTO_WAVE_START: usize = 64;

/// The Monte-Carlo estimator.
///
/// Every replication draws from its own counter-based RNG stream
/// (`substream(seed, rep)`) into its own output slot, and results are
/// reduced serially in replication order — so for a fixed seed the
/// estimate is **bit-identical regardless of `threads`**, and
/// [`Estimator::evaluate_many`] item `i` is bit-identical to
/// [`Estimator::evaluate_at`] with index `i`. Layout-randomizing
/// policies (random assignment) re-draw their assignment per
/// replication from that same stream; deterministic policies
/// materialize one layout up front and share it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MonteCarlo {
    /// Number of independent replications.
    pub reps: usize,
    /// Base seed; batch entry points derive per-item streams from it
    /// via [`substream`].
    pub seed: u64,
    /// Per-scenario fan-out cap: a scenario's replications are split
    /// into at most this many concurrent units. `0` defers entirely to
    /// the [`WorkerPool::global`] width; `1` forces fully inline serial
    /// execution (no pool). Batch entry points additionally run
    /// scenarios in parallel across the pool regardless of this cap.
    pub threads: usize,
}

impl MonteCarlo {
    /// Estimator with the given replication budget, using the full
    /// worker pool.
    pub fn new(reps: usize, seed: u64) -> MonteCarlo {
        MonteCarlo { reps, seed, threads: 0 }
    }

    /// Restrict (or widen) the per-scenario fan-out. `0` = pool width.
    pub fn with_threads(mut self, threads: usize) -> MonteCarlo {
        self.threads = threads;
        self
    }

    /// Single-threaded variant (useful for micro-benchmark baselines).
    pub fn serial(reps: usize, seed: u64) -> MonteCarlo {
        MonteCarlo { reps, seed, threads: 1 }
    }

    /// Core driver: evaluate each `(scenario, stream seed)` item with
    /// `reps` replications, sharing one outcome buffer and one pool
    /// scope per wave. Item order is the reduction order; results are
    /// bit-identical for any thread count, pool width, or wave split
    /// (each item's replications depend only on its own stream seed).
    pub(crate) fn run_batch(&self, items: &[(&Scenario, u64)]) -> Result<Vec<Estimate>> {
        if self.reps == 0 {
            return Err(Error::Config("MonteCarlo needs reps >= 1".into()));
        }
        if items.is_empty() {
            return Ok(Vec::new());
        }
        let window = (MAX_WAVE_SLOTS / self.reps).max(1);
        if items.len() <= window {
            return self.run_wave(items);
        }
        let mut estimates = Vec::with_capacity(items.len());
        for wave in items.chunks(window) {
            estimates.extend(self.run_wave(wave)?);
        }
        Ok(estimates)
    }

    /// One wave of `run_batch`: prepare, fan out, reduce.
    fn run_wave(&self, items: &[(&Scenario, u64)]) -> Result<Vec<Estimate>> {
        let (outcomes, costs, threads) = self.run_wave_raw(items)?;
        let mut estimates = Vec::with_capacity(items.len());
        for (i, (_, seed)) in items.iter().enumerate() {
            let slots = &outcomes[i * self.reps..(i + 1) * self.reps];
            let cost_slots = &costs[i * self.reps..(i + 1) * self.reps];
            estimates.push(self.reduce(slots, cost_slots, *seed, threads));
        }
        Ok(estimates)
    }

    /// The fan-out core of a wave: prepare each item, run every
    /// replication into its pre-assigned slot, and hand back the raw
    /// outcome/cost buffers (scenario `i` owns slots
    /// `[i·reps, (i+1)·reps)`) plus the resolved thread count.
    fn run_wave_raw(
        &self,
        items: &[(&Scenario, u64)],
    ) -> Result<(Vec<JobOutcome>, Vec<f64>, usize)> {
        // Prepare serially: feasibility problems surface here, lowest
        // item first, before any unit is queued.
        let preps = items
            .iter()
            .map(|(scenario, seed)| prepare(scenario, *seed))
            .collect::<Result<Vec<_>>>()?;
        let n_scen = preps.len();

        // One exact-size outcome buffer for the whole batch; scenario i
        // owns slots [i·reps, (i+1)·reps). Costs ride in a parallel
        // buffer with the same ownership map (NaN = cost untracked).
        let mut outcomes: Vec<JobOutcome> = Vec::with_capacity(n_scen * self.reps);
        outcomes.resize(n_scen * self.reps, JobOutcome::Failed);
        let mut costs: Vec<f64> = Vec::with_capacity(n_scen * self.reps);
        costs.resize(n_scen * self.reps, f64::NAN);

        // A randomized per-replication draw can fail even though the
        // up-front probe succeeded; keep the first error in
        // (scenario, replication) order so the reported error does not
        // depend on scheduling.
        let first_error: Mutex<Option<(usize, usize, Error)>> = Mutex::new(None);

        let threads = if self.threads == 0 {
            WorkerPool::global().threads()
        } else {
            self.threads
        };
        if threads <= 1 {
            let mut scratch = RepScratch::default();
            for (i, prep) in preps.iter().enumerate() {
                let slots = &mut outcomes[i * self.reps..(i + 1) * self.reps];
                let cost_slots = &mut costs[i * self.reps..(i + 1) * self.reps];
                run_unit(prep, slots, cost_slots, i, 0, &mut scratch, &first_error);
            }
        } else {
            let chunk_len = self.reps.div_ceil(chunks_per_scenario(
                threads, n_scen, self.reps,
            ));
            let errors = &first_error;
            WorkerPool::global().scope(|scope| {
                let slices =
                    outcomes.chunks_mut(self.reps).zip(costs.chunks_mut(self.reps));
                for (i, (prep, (slice, cost_slice))) in
                    preps.iter().zip(slices).enumerate()
                {
                    let mut lo = 0usize;
                    for (slots, cost_slots) in
                        slice.chunks_mut(chunk_len).zip(cost_slice.chunks_mut(chunk_len))
                    {
                        let len = slots.len();
                        scope.submit(move || {
                            let mut scratch = RepScratch::default();
                            run_unit(prep, slots, cost_slots, i, lo, &mut scratch, errors);
                        });
                        lo += len;
                    }
                }
            });
        }

        let first_error =
            first_error.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some((_, _, error)) = first_error {
            return Err(error);
        }
        Ok((outcomes, costs, threads))
    }

    /// Like [`MonteCarlo::run_batch`], but additionally return each
    /// item's per-replication completion times in replication order
    /// (NaN = failed replication) — the raw material for
    /// paired-difference (common-random-numbers) estimation in
    /// `planner::PairedSpectrum`. Pass every item the **same** stream
    /// seed and replication `r` of every item consumes the same
    /// `substream(seed, r)` draw stream.
    pub(crate) fn run_batch_retained(
        &self,
        items: &[(&Scenario, u64)],
    ) -> Result<Vec<(Estimate, Vec<f64>)>> {
        if self.reps == 0 {
            return Err(Error::Config("MonteCarlo needs reps >= 1".into()));
        }
        let window = (MAX_WAVE_SLOTS / self.reps).max(1);
        let mut out = Vec::with_capacity(items.len());
        for wave in items.chunks(window) {
            let (outcomes, costs, threads) = self.run_wave_raw(wave)?;
            for (i, (_, seed)) in wave.iter().enumerate() {
                let slots = &outcomes[i * self.reps..(i + 1) * self.reps];
                let cost_slots = &costs[i * self.reps..(i + 1) * self.reps];
                let est = self.reduce(slots, cost_slots, *seed, threads);
                let mut times = Vec::with_capacity(self.reps);
                for outcome in slots {
                    times.push(match outcome {
                        JobOutcome::Done(t) => *t,
                        JobOutcome::Failed => f64::NAN,
                    });
                }
                out.push((est, times));
            }
        }
        Ok(out)
    }

    /// Precision-targeted evaluation: double the replication count in
    /// waves (starting at [`AUTO_WAVE_START`]) until the estimate's
    /// ci95 half-width drops to `eps` or the count reaches `max`, and
    /// return that estimate. Each wave recomputes from replication 0 on
    /// `substream(stream_seed, rep)`, so the result is **exactly** the
    /// fixed-reps estimate at the realized count
    /// (`Estimate::replications`) — byte-identical across thread
    /// counts, shards, and resume, with total work bounded by 2× the
    /// realized count.
    ///
    /// The stopping rule is a function of the accumulated estimate
    /// only — never wall-clock — and a NaN ci95 (fewer than two
    /// completed replications) never satisfies the target, so sparse
    /// coverage keeps doubling until `max`.
    pub fn until_ci95(
        &self,
        scenario: &Scenario,
        stream_seed: u64,
        eps: f64,
        max: usize,
    ) -> Result<Estimate> {
        if !(eps.is_finite() && eps > 0.0) {
            return Err(Error::Config(format!(
                "auto-reps eps must be finite and > 0, got {eps}"
            )));
        }
        if max == 0 {
            return Err(Error::Config("auto-reps max must be >= 1".into()));
        }
        let mut reps = AUTO_WAVE_START.min(max);
        loop {
            let wave = MonteCarlo { reps, seed: self.seed, threads: self.threads };
            let mut batch = wave.run_batch(&[(scenario, stream_seed)])?;
            let est = batch.pop().ok_or_else(|| {
                Error::Internal("one item in, zero estimates out".into())
            })?;
            if est.ci95 <= eps || reps == max {
                return Ok(est);
            }
            reps = reps.saturating_mul(2).min(max);
        }
    }

    /// Serial reduction in replication order: float accumulation is
    /// independent of how units were scheduled above.
    fn reduce(
        &self,
        outcomes: &[JobOutcome],
        costs: &[f64],
        seed: u64,
        threads: usize,
    ) -> Estimate {
        let mut summary = Summary::new();
        let mut cost = CostAccumulator::new();
        let mut failed = 0usize;
        for (outcome, c) in outcomes.iter().zip(costs.iter()) {
            match outcome {
                JobOutcome::Done(t) => {
                    summary.record(*t);
                    cost.record(*c);
                }
                JobOutcome::Failed => failed += 1,
            }
        }
        let completed = self.reps - failed;
        let provenance = Provenance::MonteCarlo { reps: self.reps, seed, threads };
        if completed == 0 {
            // Every replication failed coverage: there is no completion
            // time to summarize. Report that explicitly instead of
            // leaking NaNs out of an empty Summary.
            return Estimate {
                mean: f64::NAN,
                ci95: f64::NAN,
                cov: f64::NAN,
                p50: f64::NAN,
                p95: f64::NAN,
                p99: f64::NAN,
                cost: f64::NAN,
                failure_rate: 1.0,
                replications: self.reps,
                completed: 0,
                provenance,
            };
        }
        Estimate {
            mean: summary.mean(),
            ci95: summary.ci95(),
            cov: summary.cov(),
            p50: summary.quantile(0.50),
            p95: summary.quantile(0.95),
            p99: summary.quantile(0.99),
            cost: cost.mean(),
            failure_rate: failed as f64 / self.reps as f64,
            replications: self.reps,
            completed,
            provenance,
        }
    }
}

/// Two-level unit shaping: enough chunks per scenario to saturate
/// `threads` workers when the batch is small, dropping to one chunk per
/// scenario once the batch itself provides the parallelism.
fn chunks_per_scenario(threads: usize, scenarios: usize, reps: usize) -> usize {
    let want = (threads * 2).div_ceil(scenarios).max(1);
    let max_by_reps = reps.div_ceil(MIN_UNIT_REPS).max(1);
    want.min(threads).min(max_by_reps).max(1)
}

/// One unit of pool work: run replications `lo..lo + slots.len()` of a
/// prepared scenario into their output slots, reusing one scratch
/// arena. On a replication error the unit stops early (the batch is
/// aborted by the caller) after recording the error.
fn run_unit(
    prep: &Prepared<'_>,
    slots: &mut [JobOutcome],
    costs: &mut [f64],
    scen: usize,
    lo: usize,
    scratch: &mut RepScratch,
    first_error: &Mutex<Option<(usize, usize, Error)>>,
) {
    // An error anywhere aborts the whole batch, so skip units that
    // cannot record a lower-ordered error than the one already seen —
    // every error this unit could find has key >= (scen, lo), so the
    // final minimum (and thus the reported error) is unchanged and
    // stays independent of scheduling. One lock per unit, amortized
    // over >= MIN_UNIT_REPS replications.
    {
        let seen =
            first_error.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some((s, r, _)) = seen.as_ref() {
            if (*s, *r) < (scen, lo) {
                return;
            }
        }
    }
    for (k, (slot, cost)) in slots.iter_mut().zip(costs.iter_mut()).enumerate() {
        match prep.sample_rep(lo + k, scratch) {
            Ok((outcome, c)) => {
                *slot = outcome;
                *cost = c;
            }
            Err(error) => {
                record_error(first_error, scen, lo + k, error);
                return;
            }
        }
    }
}

/// Keep the error of the lowest `(scenario, replication)` pair so the
/// reported failure is deterministic under any scheduling.
fn record_error(
    slot: &Mutex<Option<(usize, usize, Error)>>,
    scen: usize,
    rep: usize,
    error: Error,
) {
    let mut guard = slot.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let replace = match guard.as_ref() {
        None => true,
        Some((s, r, _)) => (scen, rep) < (*s, *r),
    };
    if replace {
        *guard = Some((scen, rep, error));
    }
}

/// Replication strategy compiled once per scenario.
enum RepPath {
    /// Deterministic policy: one materialized layout + simulator shared
    /// by every replication.
    Fixed(JobSimulator),
    /// Randomizing policy without failures: re-draw each worker's batch
    /// pick per replication and fold per-batch minima directly — no
    /// layout materialization, no `tau` clone, nothing allocated past
    /// the per-unit scratch.
    RandomPicks { batches: usize, batch_size: usize, sampler: Sampler },
    /// Randomizing policy with failure injection: materialize a fresh
    /// layout per replication (allocates, but failure paths are not the
    /// throughput-critical ones) and simulate it by borrow — still no
    /// `tau` clone.
    RandomMaterialize { sampler: Sampler },
}

struct Prepared<'s> {
    scenario: &'s Scenario,
    seed: u64,
    path: RepPath,
}

/// Compile one scenario: probe the layout (errors surface before any
/// unit is queued) and pick the replication path.
fn prepare<'s>(scenario: &'s Scenario, seed: u64) -> Result<Prepared<'s>> {
    let n = scenario.workers;
    let randomized = matches!(scenario.policy, Policy::RandomNonOverlapping { .. });
    let mut layout_rng = Pcg64::new(substream(seed, LAYOUT_STREAM));
    let probe = scenario.policy.layout(n, &mut layout_rng)?;
    if !scenario.replication.is_upfront() {
        // Timed replication is only defined on the disjoint fast path:
        // a fixed layout of disjoint equal-size batches with no failure
        // injection. Reject everything else here, before any unit is
        // queued, instead of silently reporting all-failed.
        if randomized {
            return Err(Error::Config(format!(
                "replication policy {} needs a deterministic layout, \
                 not a randomized assignment",
                scenario.replication.label()
            )));
        }
        if scenario.failures != FailureModel::None {
            return Err(Error::Config(format!(
                "replication policy {} does not support failure injection",
                scenario.replication.label()
            )));
        }
        if !fast_disjoint_layout(&probe) {
            return Err(Error::Config(format!(
                "replication policy {} needs disjoint equal-size batches",
                scenario.replication.label()
            )));
        }
    }
    let path = if !randomized {
        RepPath::Fixed(
            JobSimulator::new(probe, scenario.tau.as_ref())
                .with_failures(scenario.failures)
                .with_replication(scenario.replication),
        )
    } else if scenario.failures == FailureModel::None {
        RepPath::RandomPicks {
            batches: probe.batches.len(),
            batch_size: probe.batch_size(),
            sampler: scenario.tau.sampler(),
        }
    } else {
        RepPath::RandomMaterialize { sampler: scenario.tau.sampler() }
    };
    Ok(Prepared { scenario, seed, path })
}

/// Per-unit scratch: simulator buffers plus the per-batch minima used
/// by the pick path. Allocated once per unit and reused across its
/// replications.
#[derive(Default)]
struct RepScratch {
    sim: SimScratch,
    batch_min: Vec<f64>,
    batch_count: Vec<u32>,
}

impl Prepared<'_> {
    fn sample_rep(
        &self,
        rep: usize,
        scratch: &mut RepScratch,
    ) -> Result<(JobOutcome, f64)> {
        let mut rng = Pcg64::new(substream(self.seed, rep as u64));
        match &self.path {
            RepPath::Fixed(sim) => Ok(sim.sample_with_cost(&mut rng, &mut scratch.sim)),
            RepPath::RandomPicks { batches, batch_size, sampler } => {
                Ok(sample_random_picks(
                    self.scenario.workers,
                    *batches,
                    *batch_size,
                    sampler,
                    &mut rng,
                    &mut scratch.batch_min,
                    &mut scratch.batch_count,
                ))
            }
            RepPath::RandomMaterialize { sampler } => {
                let layout =
                    self.scenario.policy.layout(self.scenario.workers, &mut rng)?;
                let view = SimView {
                    layout: &layout,
                    sampler,
                    model: ServiceModel::SizeDependentPerWorker,
                    failure: self.scenario.failures,
                    // this path only runs with failure injection, which
                    // always takes the event-driven route — the fast
                    // flag would be dead, so skip the O(N) verification
                    fast_disjoint: false,
                    // prepare() rejects timed policies off the fast
                    // path, so only up-front reaches here
                    replication: ReplicationPolicy::Upfront,
                };
                Ok((view.sample_into(&mut rng, &mut scratch.sim), f64::NAN))
            }
        }
    }
}

/// One replication of the random-assignment policy without
/// materializing a layout: every worker picks a batch uniformly (the
/// same `below(B)` draw the layout builder makes) and its size-scaled
/// service time folds into that batch's minimum in a single pass. The
/// job fails iff some batch attracted no worker (Lemma 1 coverage),
/// otherwise `T = max_b min_{w∈b} S_w` with up-front cost
/// `Σ_b count_b · min_b` (every picker of batch `b` runs until its
/// first finisher).
fn sample_random_picks(
    workers: usize,
    batches: usize,
    batch_size: usize,
    sampler: &Sampler,
    rng: &mut Pcg64,
    batch_min: &mut Vec<f64>,
    batch_count: &mut Vec<u32>,
) -> (JobOutcome, f64) {
    batch_min.clear();
    batch_min.resize(batches, f64::INFINITY);
    batch_count.clear();
    batch_count.resize(batches, 0u32);
    let size = batch_size as f64;
    for _ in 0..workers {
        let pick = rng.below(batches as u64) as usize;
        batch_count[pick] += 1;
        let s = size * sampler.sample_one(rng);
        if s < batch_min[pick] {
            batch_min[pick] = s;
        }
    }
    let mut t_job: f64 = 0.0;
    let mut cost = 0.0;
    for (&m, &c) in batch_min.iter().zip(batch_count.iter()) {
        if m == f64::INFINITY {
            return (JobOutcome::Failed, f64::NAN); // uncovered batch
        }
        if m > t_job {
            t_job = m;
        }
        cost += c as f64 * m;
    }
    (JobOutcome::Done(t_job), cost)
}

impl Default for MonteCarlo {
    fn default() -> MonteCarlo {
        MonteCarlo::new(crate::eval::DEFAULT_REPS, 0xD15EA5E)
    }
}

impl Estimator for MonteCarlo {
    fn evaluate(&self, scenario: &Scenario) -> Result<Estimate> {
        let mut batch = self.run_batch(&[(scenario, self.seed)])?;
        batch
            .pop()
            .ok_or_else(|| Error::Internal("one item in, zero estimates out".into()))
    }

    fn evaluate_at(&self, scenario: &Scenario, index: u64) -> Result<Estimate> {
        let mut batch = self.run_batch(&[(scenario, substream(self.seed, index))])?;
        batch
            .pop()
            .ok_or_else(|| Error::Internal("one item in, zero estimates out".into()))
    }

    fn evaluate_many(&self, scenarios: &[Scenario]) -> Result<Vec<Estimate>> {
        let items: Vec<(&Scenario, u64)> = scenarios
            .iter()
            .enumerate()
            .map(|(i, s)| (s, substream(self.seed, i as u64)))
            .collect();
        self.run_batch(&items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::closed_form;
    use crate::dist::ServiceDist;
    use crate::sim::job::FailureModel;

    #[test]
    fn matches_closed_form_within_ci() {
        let tau = ServiceDist::shifted_exp(0.05, 1.0);
        for b in [1usize, 4, 20] {
            let est = MonteCarlo::new(30_000, 42)
                .evaluate(&Scenario::balanced(20, b, tau.clone()))
                .unwrap();
            let want = closed_form::sexp_mean(20, b, 0.05, 1.0);
            assert!(
                (est.mean - want).abs() < 4.0 * est.ci95.max(1e-3),
                "B={b}: {} vs {want} (ci {})",
                est.mean,
                est.ci95
            );
            assert_eq!(est.failure_rate, 0.0);
            assert_eq!(est.completed, 30_000);
            assert!(est.p50 <= est.p95 && est.p95 <= est.p99);
        }
    }

    #[test]
    fn bit_identical_across_thread_counts() {
        let tau = ServiceDist::pareto(1.0, 2.5);
        let scenario = Scenario::balanced(20, 4, tau);
        let serial = MonteCarlo::serial(5_000, 7).evaluate(&scenario).unwrap();
        for threads in [2usize, 3, 4, 8] {
            let par = MonteCarlo { reps: 5_000, seed: 7, threads }
                .evaluate(&scenario)
                .unwrap();
            assert_eq!(serial.mean.to_bits(), par.mean.to_bits(), "{threads} threads");
            assert_eq!(serial.cov.to_bits(), par.cov.to_bits());
            assert_eq!(serial.p99.to_bits(), par.p99.to_bits());
            assert_eq!(serial.failure_rate, par.failure_rate);
        }
    }

    #[test]
    fn randomized_layouts_are_thread_invariant_too() {
        let scenario = Scenario::new(
            20,
            Policy::RandomNonOverlapping { batches: 5 },
            ServiceDist::exp(1.0),
        );
        let a = MonteCarlo::serial(4_000, 3).evaluate(&scenario).unwrap();
        let b = MonteCarlo { reps: 4_000, seed: 3, threads: 4 }
            .evaluate(&scenario)
            .unwrap();
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.failure_rate, b.failure_rate);
        assert!(a.failure_rate > 0.0, "random B=5 on N=20 should fail sometimes");
    }

    #[test]
    fn randomized_coverage_matches_lemma_1() {
        // the pick path must reproduce the exact coverage probability
        let (n, b) = (20usize, 10usize);
        let scenario = Scenario::new(
            n,
            Policy::RandomNonOverlapping { batches: b },
            ServiceDist::exp(1.0),
        );
        let est = MonteCarlo::new(40_000, 9).evaluate(&scenario).unwrap();
        let want = 1.0 - crate::analysis::coverage::coverage_probability(n, b);
        assert!(
            (est.failure_rate - want).abs() < 0.01,
            "{} vs {want}",
            est.failure_rate
        );
    }

    #[test]
    fn randomized_with_failures_still_thread_invariant() {
        // exercises the per-replication layout materialization path
        let scenario = Scenario::new(
            12,
            Policy::RandomNonOverlapping { batches: 3 },
            ServiceDist::exp(1.0),
        )
        .with_failures(FailureModel::Crash { p: 0.2 });
        let a = MonteCarlo::serial(2_000, 5).evaluate(&scenario).unwrap();
        let b = MonteCarlo { reps: 2_000, seed: 5, threads: 4 }
            .evaluate(&scenario)
            .unwrap();
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.failure_rate, b.failure_rate);
    }

    #[test]
    fn distinct_seeds_give_distinct_estimates() {
        let scenario = Scenario::balanced(10, 2, ServiceDist::exp(1.0));
        let a = MonteCarlo::new(1_000, 7).evaluate(&scenario).unwrap();
        let b = MonteCarlo::new(1_000, 7).evaluate(&scenario).unwrap();
        let c = MonteCarlo::new(1_000, 8).evaluate(&scenario).unwrap();
        assert_eq!(a.mean, b.mean);
        assert_ne!(a.mean, c.mean);
    }

    #[test]
    fn all_replications_failing_is_explicit() {
        // every worker crashes: no replication can complete
        let scenario = Scenario::balanced(8, 2, ServiceDist::exp(1.0))
            .with_failures(FailureModel::Crash { p: 1.0 });
        let est = MonteCarlo::new(500, 1).evaluate(&scenario).unwrap();
        assert!(est.all_failed());
        assert_eq!(est.completed, 0);
        assert_eq!(est.failure_rate, 1.0);
        assert!(est.mean.is_nan() && est.ci95.is_nan() && est.cov.is_nan());
        assert!(est.p50.is_nan() && est.p99.is_nan());
        assert!(est.cost.is_nan());
    }

    #[test]
    fn upfront_cost_matches_closed_form() {
        // balanced N=20, B=4: r = k = 5, each worker serves an Exp(1/5)
        // stretch, the batch runs 5 workers until its min — expected
        // cost per batch is 5·E[min of 5 Exp(0.2)] = 5, total n/mu = 20.
        let est = MonteCarlo::new(30_000, 13)
            .evaluate(&Scenario::balanced(20, 4, ServiceDist::exp(1.0)))
            .unwrap();
        assert!((est.cost - 20.0).abs() < 0.5, "cost {}", est.cost);
        // the pick path tracks cost too (random assignment, no failures)
        let random = Scenario::new(
            20,
            Policy::RandomNonOverlapping { batches: 2 },
            ServiceDist::exp(1.0),
        );
        let est = MonteCarlo::new(20_000, 13).evaluate(&random).unwrap();
        assert!(est.cost.is_finite() && est.cost > 0.0, "cost {}", est.cost);
    }

    #[test]
    fn speculative_policy_flows_through_with_lower_cost() {
        let tau = ServiceDist::pareto(1.0, 2.0);
        let upfront = Scenario::balanced(12, 3, tau.clone());
        let spec = Scenario::balanced(12, 3, tau)
            .with_replication(ReplicationPolicy::SpeculativeAt { t: 8.0 });
        let mc = MonteCarlo::new(20_000, 21);
        let eu = mc.evaluate(&upfront).unwrap();
        let es = mc.evaluate(&spec).unwrap();
        // speculation pays latency to save worker-seconds
        assert!(es.mean >= eu.mean, "{} vs {}", es.mean, eu.mean);
        assert!(es.cost < 0.7 * eu.cost, "{} vs {}", es.cost, eu.cost);
        // and the cost column is thread-invariant like everything else
        let serial = MonteCarlo::serial(5_000, 21).evaluate(&spec).unwrap();
        let par = MonteCarlo { reps: 5_000, seed: 21, threads: 4 }
            .evaluate(&spec)
            .unwrap();
        assert_eq!(serial.mean.to_bits(), par.mean.to_bits());
        assert_eq!(serial.cost.to_bits(), par.cost.to_bits());
    }

    #[test]
    fn upfront_estimates_are_pool_width_invariant() {
        // the policy refactor must not perturb the up-front path: the
        // same bits at 1, 2, 4, and 8 evaluation lanes, and an explicit
        // `Upfront` annotation changes nothing vs the plain
        // (pre-refactor-shaped) scenario at any width
        let tau = ServiceDist::shifted_exp(0.05, 1.0);
        let plain = Scenario::balanced(16, 4, tau.clone());
        let annotated =
            Scenario::balanced(16, 4, tau).with_replication(ReplicationPolicy::Upfront);
        let golden = MonteCarlo { reps: 4_000, seed: 17, threads: 1 }
            .evaluate(&plain)
            .unwrap();
        for threads in [1usize, 2, 4, 8] {
            for s in [&plain, &annotated] {
                let est = MonteCarlo { reps: 4_000, seed: 17, threads }.evaluate(s).unwrap();
                assert_eq!(golden.mean.to_bits(), est.mean.to_bits(), "{threads} lanes");
                assert_eq!(golden.cov.to_bits(), est.cov.to_bits(), "{threads} lanes");
                assert_eq!(golden.p50.to_bits(), est.p50.to_bits(), "{threads} lanes");
                assert_eq!(golden.p99.to_bits(), est.p99.to_bits(), "{threads} lanes");
                assert_eq!(golden.cost.to_bits(), est.cost.to_bits(), "{threads} lanes");
            }
        }
    }

    #[test]
    fn timed_policies_reject_unsupported_combinations() {
        let spec = ReplicationPolicy::SpeculativeAt { t: 1.0 };
        // failure injection
        let s = Scenario::balanced(8, 2, ServiceDist::exp(1.0))
            .with_failures(FailureModel::Crash { p: 0.1 })
            .with_replication(spec);
        assert!(MonteCarlo::new(10, 0).evaluate(&s).is_err());
        // randomized assignment
        let s = Scenario::new(
            8,
            Policy::RandomNonOverlapping { batches: 2 },
            ServiceDist::exp(1.0),
        )
        .with_replication(spec);
        assert!(MonteCarlo::new(10, 0).evaluate(&s).is_err());
        // overlapping (non-disjoint) layout
        let s = Scenario::new(
            8,
            Policy::CyclicOverlapping { batches: 4 },
            ServiceDist::exp(1.0),
        )
        .with_replication(spec);
        assert!(MonteCarlo::new(10, 0).evaluate(&s).is_err());
    }

    #[test]
    fn evaluate_many_matches_evaluate_at() {
        let mc = MonteCarlo::new(2_000, 11);
        let scenarios: Vec<Scenario> = [1usize, 2, 5]
            .iter()
            .map(|&b| Scenario::balanced(10, b, ServiceDist::exp(1.0)))
            .collect();
        let batch = mc.evaluate_many(&scenarios).unwrap();
        for (i, s) in scenarios.iter().enumerate() {
            let single = mc.evaluate_at(s, i as u64).unwrap();
            assert_eq!(batch[i].mean.to_bits(), single.mean.to_bits(), "item {i}");
        }
        // different items run on different substreams
        assert_ne!(batch[0].provenance, batch[1].provenance);
    }

    #[test]
    fn infeasible_scenario_is_error() {
        let s = Scenario::balanced(10, 3, ServiceDist::exp(1.0));
        assert!(MonteCarlo::new(10, 0).evaluate(&s).is_err());
        let s = Scenario::balanced(10, 2, ServiceDist::exp(1.0));
        assert!(MonteCarlo::new(0, 0).evaluate(&s).is_err());
    }

    #[test]
    fn infeasible_item_fails_the_whole_batch_deterministically() {
        let scenarios = vec![
            Scenario::balanced(10, 2, ServiceDist::exp(1.0)),
            Scenario::balanced(10, 3, ServiceDist::exp(1.0)), // infeasible
            Scenario::balanced(10, 7, ServiceDist::exp(1.0)), // infeasible
        ];
        let err = MonteCarlo::new(100, 0).evaluate_many(&scenarios).unwrap_err();
        // the first infeasible item (B=3) is the one reported
        assert!(format!("{err}").contains("B=3"), "{err}");
    }

    #[test]
    fn until_ci95_is_the_fixed_reps_estimate_at_the_realized_count() {
        let scenario = Scenario::balanced(12, 3, ServiceDist::exp(1.0));
        let mc = MonteCarlo::new(1, 0); // reps field is ignored by auto
        let auto = mc.until_ci95(&scenario, 77, 0.05, 1 << 14).unwrap();
        assert!(auto.ci95 <= 0.05, "ci95 {}", auto.ci95);
        assert!(auto.replications >= AUTO_WAVE_START);
        let fixed = MonteCarlo::new(auto.replications, 0)
            .run_batch(&[(&scenario, 77)])
            .unwrap()
            .pop()
            .unwrap();
        assert_eq!(auto.mean.to_bits(), fixed.mean.to_bits());
        assert_eq!(auto.ci95.to_bits(), fixed.ci95.to_bits());
        assert_eq!(auto.cost.to_bits(), fixed.cost.to_bits());
        assert_eq!(auto.provenance, fixed.provenance);
    }

    #[test]
    fn until_ci95_respects_max_and_thread_invariance() {
        let scenario = Scenario::balanced(12, 3, ServiceDist::pareto(1.0, 2.5));
        // unreachable eps: stops exactly at max
        let capped =
            MonteCarlo::serial(1, 5).until_ci95(&scenario, 9, 1e-12, 1000).unwrap();
        assert_eq!(capped.replications, 1000);
        // loose eps: stops at the first wave
        let first = MonteCarlo::new(1, 5).until_ci95(&scenario, 9, 1e9, 1000).unwrap();
        assert_eq!(first.replications, AUTO_WAVE_START);
        // realized count and bits are thread-invariant
        let wide = MonteCarlo { reps: 1, seed: 5, threads: 4 }
            .until_ci95(&scenario, 9, 1e-12, 1000)
            .unwrap();
        assert_eq!(capped.mean.to_bits(), wide.mean.to_bits());
        assert_eq!(capped.replications, wide.replications);
    }

    #[test]
    fn until_ci95_rejects_bad_targets() {
        let scenario = Scenario::balanced(4, 2, ServiceDist::exp(1.0));
        let mc = MonteCarlo::new(1, 0);
        assert!(mc.until_ci95(&scenario, 0, f64::NAN, 100).is_err());
        assert!(mc.until_ci95(&scenario, 0, 0.0, 100).is_err());
        assert!(mc.until_ci95(&scenario, 0, -1.0, 100).is_err());
        assert!(mc.until_ci95(&scenario, 0, f64::INFINITY, 100).is_err());
        assert!(mc.until_ci95(&scenario, 0, 0.1, 0).is_err());
    }

    #[test]
    fn retained_times_reproduce_the_estimate() {
        let scenario = Scenario::balanced(10, 2, ServiceDist::exp(1.0));
        let mc = MonteCarlo::new(500, 3);
        let mut retained = mc.run_batch_retained(&[(&scenario, 42)]).unwrap();
        let (est, times) = retained.pop().unwrap();
        assert_eq!(times.len(), 500);
        let plain = mc.run_batch(&[(&scenario, 42)]).unwrap().pop().unwrap();
        assert_eq!(est.mean.to_bits(), plain.mean.to_bits());
        // replication-order mean of the retained times is the estimate
        let mut s = Summary::new();
        for &t in &times {
            if !t.is_nan() {
                s.record(t);
            }
        }
        assert_eq!(s.mean().to_bits(), est.mean.to_bits());
        assert_eq!(s.ci95().to_bits(), est.ci95.to_bits());
    }

    #[test]
    fn retained_times_mark_failures_as_nan() {
        let scenario = Scenario::balanced(8, 2, ServiceDist::exp(1.0))
            .with_failures(FailureModel::Crash { p: 0.5 });
        let mut retained = MonteCarlo::new(400, 1)
            .run_batch_retained(&[(&scenario, 7)])
            .unwrap();
        let (est, times) = retained.pop().unwrap();
        let mut failed = 0;
        for &t in &times {
            if t.is_nan() {
                failed += 1;
            }
        }
        assert_eq!(failed, 400 - est.completed);
        assert!(failed > 0, "crash p=0.5 should fail some replications");
    }

    #[test]
    fn unit_shaping_is_sane() {
        // single scenario: fan out across threads
        assert_eq!(chunks_per_scenario(8, 1, 30_000), 8);
        // large batch: one unit per scenario
        assert_eq!(chunks_per_scenario(8, 200, 30_000), 1);
        // tiny rep budgets never split below the unit floor
        assert_eq!(chunks_per_scenario(8, 1, 100), 1);
        assert_eq!(chunks_per_scenario(8, 1, 600), 3);
        // never zero
        assert_eq!(chunks_per_scenario(1, 1, 1), 1);
    }
}
