//! Service-time distributions τ — the stochastic substrate of the paper.
//!
//! Every layer above sits on this module: the closed forms of
//! [`crate::analysis`] pattern-match the analytic families, the
//! simulator ([`crate::sim`]) draws from them, the numeric integrator
//! inverts their CDFs, and the trace pipeline ([`crate::traces`]) fits
//! them to observed samples.
//!
//! * [`ServiceDist`] — the family catalogue: `Exp(μ)` (§IV/§VI, eqs. 18
//!   and 26), `ShiftedExp(Δ, μ)` (§VI-B, eqs. 19/21, Theorems 5–7),
//!   `Pareto(σ, α)` (§VI-C, eqs. 22/24, Theorems 8–10), `Weibull` and
//!   `Gamma` (the §IV closing remark's open problem — stochastically
//!   concave for shape > 1), `Bimodal` fast/slow stragglers, and
//!   `Empirical` trace bootstrap (§VII). All families are closed under
//!   positive scaling ([`ServiceDist::scaled`]), which is what makes the
//!   size-dependent batch model `T_batch = (N/B)·τ` of §VI representable
//!   without leaving the enum.
//! * [`Empirical`] — exact order-statistics ECDF (no binning), the
//!   distribution `traces::analyze` builds per job for Figs. 11–13.
//! * [`TailFit`] / [`TailClass`] — the §VII tail classifier: decide
//!   whether observed service times have an exponential or a heavy
//!   (power-law) tail and fit the winning family, feeding the planner's
//!   trace-driven path ([`crate::planner::plan_from_samples`]).
//!
//! Sampling is inverse-CDF wherever a closed form exists, so
//! `sample`/`cdf`/`ccdf`/`quantile` are mutually consistent — the
//! property [`crate::eval::Analytic`] relies on for exact p50/p95/p99.

//!
//! Hot-path sampling: [`ServiceDist::sample`] is the scalar per-draw
//! entry point; simulations that draw millions of times compile a
//! [`Sampler`] once ([`ServiceDist::sampler`]) and batch-fill slices —
//! see [`sampler`] and [`alias`] for the contract.

pub mod alias;
mod empirical;
pub mod sampler;
mod service;
mod tailfit;

pub use alias::AliasTable;
pub use empirical::Empirical;
pub use sampler::{FillMode, Sampler};
pub use service::ServiceDist;
pub use tailfit::{TailClass, TailFit};
