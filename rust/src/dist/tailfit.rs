//! Tail classification of observed service times (§VII).
//!
//! The paper's trace pipeline (Fig. 11) splits jobs into two families by
//! the shape of their task service-time tail: exponential
//! (log-survival linear in `t` — jobs 1–4) and heavy / power-law
//! (log-survival linear in `ln t` — jobs 6–10). The classifier here
//! uses two shift- and scale-invariant statistics of that log-survival
//! slope structure:
//!
//! * **excess CoV** — the coefficient of variation of `X − min(X)`.
//!   For shifted-exponential data the excess is Exp(μ), so the CoV
//!   concentrates at 1 regardless of Δ and μ; Pareto-tailed data with
//!   the paper's α ∈ [1.1, 2.5] pushes it well above 1.
//! * **Hill tail index** — the Hill estimator
//!   `α̂ = k / Σ ln(x_(n−i) / x_(n−k))` over the top-k order statistics,
//!   i.e. the inverse slope of the empirical log-survival against
//!   `ln t`. Power-law tails give small `α̂` (the paper's jobs: ≤ 2);
//!   exponential tails give large `α̂` (≈ μ · threshold).
//!
//! A sample is classified [`TailClass::HeavyTail`] only when **both**
//! statistics agree, which keeps each family's false-positive modes
//! (e.g. an unshifted Exp fooling the Hill statistic, or one outlier
//! inflating the CoV) from flipping the label. The winning family is
//! fitted by maximum likelihood — `SExp(min, 1/(mean − min))` or
//! `Pareto(min, n / Σ ln(xᵢ/min))` — and returned by [`TailFit::best`]
//! for the planner's trace-driven path (§VII, Figs. 12–13).

use crate::dist::ServiceDist;

/// Excess-CoV threshold: exponential-family data concentrates at 1.
const HEAVY_EXCESS_COV: f64 = 1.35;
/// Hill-index threshold: the paper's heavy-tail jobs have α ≤ 2.
const HEAVY_TAIL_ALPHA: f64 = 4.0;
/// Fraction of the sample the Hill estimator treats as "the tail".
const HILL_FRACTION: f64 = 0.2;

/// Which tail family a sample belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TailClass {
    /// Log-survival linear in `t` (exponential-family tail).
    ExponentialTail,
    /// Log-survival linear in `ln t` (power-law tail).
    HeavyTail,
}

/// The result of classifying one sample of service times.
#[derive(Clone, Debug, PartialEq)]
pub struct TailFit {
    /// The winning tail family.
    pub class: TailClass,
    /// CoV of the excess over the sample minimum (≈ 1 for SExp data).
    pub excess_cov: f64,
    /// Hill estimator of the tail index on the top order statistics.
    pub tail_alpha: f64,
    /// Fitted shifted-exponential candidate `(delta, mu)`.
    pub sexp: (f64, f64),
    /// Fitted Pareto candidate `(sigma, alpha)` (full-sample MLE).
    pub pareto: (f64, f64),
    /// Number of (finite) samples the fit was computed from.
    pub n: usize,
}

impl TailFit {
    /// Classify a sample of service times and fit both candidate
    /// families. Degenerate inputs (fewer than 3 finite samples, or all
    /// samples equal) fall back to an exponential classification.
    pub fn classify(samples: &[f64]) -> TailFit {
        let mut xs: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
        xs.sort_by(f64::total_cmp);
        let n = xs.len();
        if n < 3 {
            let min = xs.first().copied().unwrap_or(0.0).max(0.0);
            return TailFit {
                class: TailClass::ExponentialTail,
                excess_cov: 0.0,
                tail_alpha: f64::INFINITY,
                sexp: (min, 1.0),
                pareto: (min.max(1e-12), 1.0),
                n,
            };
        }

        let min = xs[0];
        let mean = xs.iter().sum::<f64>() / n as f64;
        let mean_excess = (mean - min).max(0.0);
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let excess_cov = if mean_excess > 0.0 {
            var.sqrt() / mean_excess
        } else {
            0.0
        };

        // Hill estimator over the top-k order statistics.
        let k = ((n as f64 * HILL_FRACTION) as usize).clamp(1, n - 1);
        let x_k = xs[n - 1 - k].max(1e-300);
        let hill_sum: f64 = xs[n - k..].iter().map(|x| (x / x_k).ln().max(0.0)).sum();
        let tail_alpha = if hill_sum > 0.0 {
            k as f64 / hill_sum
        } else {
            f64::INFINITY
        };

        // Candidate fits (both MLE given their family). The rate clamp
        // keeps near-constant samples from producing an infinite μ.
        let mu = if mean_excess > 0.0 {
            (1.0 / mean_excess).min(1e12)
        } else {
            1e9
        };
        let sexp = (min.max(0.0), mu);
        let sigma = min.max(1e-300);
        let mle_sum: f64 = xs.iter().map(|x| (x / sigma).ln().max(0.0)).sum();
        let alpha_mle = if mle_sum > 0.0 {
            n as f64 / mle_sum
        } else {
            1e6
        };
        let pareto = (sigma, alpha_mle.clamp(0.05, 1e6));

        let class = if excess_cov > HEAVY_EXCESS_COV && tail_alpha < HEAVY_TAIL_ALPHA {
            TailClass::HeavyTail
        } else {
            TailClass::ExponentialTail
        };
        TailFit { class, excess_cov, tail_alpha, sexp, pareto, n }
    }

    /// The fitted distribution of the winning family — what the planner
    /// optimizes against in the §VII flow.
    pub fn best(&self) -> ServiceDist {
        match self.class {
            TailClass::HeavyTail => ServiceDist::pareto(self.pareto.0, self.pareto.1),
            TailClass::ExponentialTail => ServiceDist::shifted_exp(self.sexp.0, self.sexp.1),
        }
    }

    /// Convenience: is this a heavy (power-law) tail?
    pub fn is_heavy(&self) -> bool {
        self.class == TailClass::HeavyTail
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn draw(d: &ServiceDist, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| d.sample(&mut rng)).collect()
    }

    #[test]
    fn sexp_samples_classify_exponential_and_refit() {
        // the paper's jobs 1–4 parameter range, including the
        // kilo-second shift of job 4
        for (delta, mu) in [(10.0, 0.8), (12.0, 0.5), (9.0, 1.2), (1000.0, 0.05)] {
            let fit = TailFit::classify(&draw(&ServiceDist::shifted_exp(delta, mu), 5_000, 7));
            assert_eq!(fit.class, TailClass::ExponentialTail, "SExp({delta}, {mu}): {fit:?}");
            assert!(fit.excess_cov < 1.2, "SExp({delta}, {mu}): cov {}", fit.excess_cov);
            match fit.best() {
                ServiceDist::ShiftedExp { delta: d, mu: m } => {
                    assert!((d - delta).abs() / delta < 0.05, "delta {d} vs {delta}");
                    assert!((m - mu).abs() / mu < 0.10, "mu {m} vs {mu}");
                }
                other => panic!("expected SExp fit, got {}", other.label()),
            }
        }
    }

    #[test]
    fn pareto_samples_classify_heavy_and_refit() {
        // the paper's jobs 6–10 parameter range
        for (sigma, alpha) in [(8.0, 1.6), (20.0, 1.2), (10.0, 1.5), (15.0, 1.8)] {
            let fit = TailFit::classify(&draw(&ServiceDist::pareto(sigma, alpha), 5_000, 11));
            assert_eq!(fit.class, TailClass::HeavyTail, "Pareto({sigma}, {alpha}): {fit:?}");
            assert!(fit.is_heavy());
            assert!(fit.tail_alpha < 4.0, "hill {}", fit.tail_alpha);
            match fit.best() {
                ServiceDist::Pareto { sigma: s, alpha: a } => {
                    assert!((s - sigma).abs() / sigma < 0.02, "sigma {s} vs {sigma}");
                    assert!((a - alpha).abs() / alpha < 0.15, "alpha {a} vs {alpha}");
                }
                other => panic!("expected Pareto fit, got {}", other.label()),
            }
        }
    }

    #[test]
    fn statistics_are_scale_invariant() {
        let base = draw(&ServiceDist::pareto(1.0, 1.5), 4_000, 3);
        let scaled: Vec<f64> = base.iter().map(|x| 50.0 * x).collect();
        let a = TailFit::classify(&base);
        let b = TailFit::classify(&scaled);
        assert_eq!(a.class, b.class);
        assert!((a.excess_cov - b.excess_cov).abs() < 1e-9);
        assert!((a.tail_alpha - b.tail_alpha).abs() < 1e-9);
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        let empty = TailFit::classify(&[]);
        assert_eq!(empty.class, TailClass::ExponentialTail);
        assert_eq!(empty.n, 0);
        let tiny = TailFit::classify(&[1.0, 2.0]);
        assert_eq!(tiny.class, TailClass::ExponentialTail);
        let constant = TailFit::classify(&[3.0; 100]);
        assert_eq!(constant.class, TailClass::ExponentialTail);
        assert_eq!(constant.excess_cov, 0.0);
        // best() is still a valid distribution in every case
        let _ = empty.best();
        let _ = tiny.best();
        let _ = constant.best();
    }

    #[test]
    fn non_finite_samples_are_ignored() {
        let mut xs = draw(&ServiceDist::pareto(8.0, 1.6), 3_000, 5);
        xs.push(f64::NAN);
        xs.push(f64::INFINITY);
        let fit = TailFit::classify(&xs);
        assert_eq!(fit.class, TailClass::HeavyTail);
        assert_eq!(fit.n, 3_000);
    }
}
