//! Walker alias tables: O(1) sampling from finite discrete
//! distributions.
//!
//! Built once (O(n)), sampled forever after with a single uniform draw
//! and two array reads — no binary search, no rejection loop. This is
//! the engine behind the batched [`super::Sampler`] for the `Bimodal`
//! mixture (2 cells) and the `Empirical` bootstrap (n cells), replacing
//! per-draw branching in the Monte-Carlo hot loop.

use crate::util::rng::Pcg64;

/// A compiled Walker alias table over outcomes `0..n`.
///
/// `sample` draws index `i` with probability `w_i / Σ w_j` for the
/// weights the table was built from. Construction uses the standard
/// two-worklist (small/large) pairing, which is numerically robust:
/// leftover cells are clamped to acceptance probability 1, so rounding
/// error never produces an out-of-range alias.
#[derive(Clone, Debug, PartialEq)]
pub struct AliasTable {
    /// Acceptance probability of each cell (in units of one cell).
    prob: Vec<f64>,
    /// Donor outcome used when the cell rejects.
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from non-negative weights (not necessarily normalized).
    ///
    /// Panics on empty input, non-finite or negative weights, or an
    /// all-zero weight vector.
    pub fn new(weights: &[f64]) -> AliasTable {
        let n = weights.len();
        assert!(n > 0, "AliasTable needs at least one outcome");
        assert!(
            n <= u32::MAX as usize,
            "AliasTable supports at most u32::MAX outcomes"
        );
        assert!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "AliasTable weights must be finite and non-negative"
        );
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "AliasTable needs at least one positive weight");

        // Scale so the average cell holds exactly 1.0, then pair each
        // underfull cell with an overfull donor.
        let scale = n as f64 / total;
        let mut prob: Vec<f64> = weights.iter().map(|w| w * scale).collect();
        let mut alias: Vec<u32> = (0..n as u32).collect();
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            alias[s] = l as u32;
            // donate (1 − prob[s]) from cell l to top up cell s; l
            // stays a donor until it dips below one cell of mass
            let remaining = prob[l] + prob[s] - 1.0;
            prob[l] = remaining;
            if remaining < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Rounding can strand cells in either list with prob ≈ 1; their
        // alias is identity or a donor, so clamping to "always accept"
        // is exact.
        for i in small.into_iter().chain(large) {
            prob[i] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// The uniform distribution over `0..n` (used by the `Empirical`
    /// bootstrap: every cell accepts, the alias is never consulted).
    pub fn uniform(n: usize) -> AliasTable {
        assert!(n > 0 && n <= u32::MAX as usize);
        AliasTable { prob: vec![1.0; n], alias: (0..n as u32).collect() }
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one outcome index. Consumes exactly one uniform draw.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let n = self.prob.len();
        let x = rng.uniform() * n as f64;
        // u < 1.0 guarantees x < n mathematically; the clamp guards the
        // one-ULP rounding case for very large n.
        let mut i = x as usize;
        if i >= n {
            i = n - 1;
        }
        let frac = x - i as f64;
        if frac < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frequencies(table: &AliasTable, draws: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed);
        let mut counts = vec![0usize; table.len()];
        for _ in 0..draws {
            counts[table.sample(&mut rng)] += 1;
        }
        counts.into_iter().map(|c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn matches_weights_in_frequency() {
        let weights = [1.0, 2.0, 3.0, 4.0];
        let table = AliasTable::new(&weights);
        let freq = frequencies(&table, 200_000, 7);
        for (i, &w) in weights.iter().enumerate() {
            let want = w / 10.0;
            assert!((freq[i] - want).abs() < 0.01, "cell {i}: {} vs {want}", freq[i]);
        }
    }

    #[test]
    fn uniform_table_is_uniform() {
        let table = AliasTable::uniform(8);
        assert_eq!(table.len(), 8);
        let freq = frequencies(&table, 160_000, 3);
        for (i, f) in freq.iter().enumerate() {
            assert!((f - 0.125).abs() < 0.01, "cell {i}: {f}");
        }
    }

    #[test]
    fn zero_weights_are_never_drawn() {
        let table = AliasTable::new(&[1.0, 0.0, 3.0, 0.0]);
        let freq = frequencies(&table, 100_000, 11);
        assert_eq!(freq[1], 0.0);
        assert_eq!(freq[3], 0.0);
        assert!((freq[0] - 0.25).abs() < 0.01);
        assert!((freq[2] - 0.75).abs() < 0.01);
    }

    #[test]
    fn degenerate_single_outcome() {
        let table = AliasTable::new(&[5.0]);
        let mut rng = Pcg64::new(1);
        for _ in 0..100 {
            assert_eq!(table.sample(&mut rng), 0);
        }
        // two cells, all mass on one of them
        let table = AliasTable::new(&[1.0, 0.0]);
        let mut rng = Pcg64::new(2);
        for _ in 0..100 {
            assert_eq!(table.sample(&mut rng), 0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let table = AliasTable::new(&[0.3, 0.5, 0.2]);
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..200 {
            assert_eq!(table.sample(&mut a), table.sample(&mut b));
        }
    }

    #[test]
    #[should_panic]
    fn empty_rejected() {
        AliasTable::new(&[]);
    }

    #[test]
    #[should_panic]
    fn all_zero_rejected() {
        AliasTable::new(&[0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn negative_rejected() {
        AliasTable::new(&[1.0, -0.5]);
    }
}
