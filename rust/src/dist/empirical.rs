//! Exact empirical (ECDF) distribution — the §VII trace bootstrap.

use crate::util::rng::Pcg64;

/// The empirical distribution of a set of observed samples.
///
/// Samples are stored sorted; every query is an exact order-statistics
/// computation (no binning), as `traces::analyze` expects for the
/// Fig. 11 CCDF series. `sample` draws uniformly with replacement — the
/// bootstrap the paper's trace-driven sweeps (Figs. 12–13) use.
#[derive(Clone, Debug, PartialEq)]
pub struct Empirical {
    sorted: Vec<f64>,
}

impl Empirical {
    /// Build from raw samples. Panics on empty or non-finite input.
    pub fn new(mut samples: Vec<f64>) -> Empirical {
        assert!(!samples.is_empty(), "Empirical needs at least one sample");
        assert!(samples.iter().all(|x| x.is_finite()), "Empirical samples must be finite");
        samples.sort_by(f64::total_cmp);
        Empirical { sorted: samples }
    }

    /// The samples, ascending.
    pub fn data(&self) -> &[f64] {
        &self.sorted
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn min(&self) -> f64 {
        self.sorted[0]
    }

    pub fn max(&self) -> f64 {
        self.sorted[self.sorted.len() - 1]
    }

    /// Draw one sample uniformly with replacement (bootstrap).
    ///
    /// Hot loops should prefer the compiled
    /// [`crate::dist::Sampler`], which bootstraps through a uniform
    /// alias table (one uniform per draw, no rejection loop).
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.sorted[rng.below(self.sorted.len() as u64) as usize]
    }

    pub fn mean(&self) -> f64 {
        self.sorted.iter().sum::<f64>() / self.sorted.len() as f64
    }

    /// Population variance of the sample.
    pub fn variance(&self) -> f64 {
        let m = self.mean();
        let ss: f64 = self.sorted.iter().map(|x| (x - m) * (x - m)).sum();
        ss / self.sorted.len() as f64
    }

    /// Exact ECDF: the fraction of samples `≤ t`.
    pub fn cdf(&self, t: f64) -> f64 {
        self.sorted.partition_point(|x| *x <= t) as f64 / self.sorted.len() as f64
    }

    /// Exact empirical survival `Pr{X > t}`.
    pub fn ccdf(&self, t: f64) -> f64 {
        1.0 - self.cdf(t)
    }

    /// Exact order-statistics quantile: the smallest sample `x` with
    /// `ECDF(x) ≥ q`, so `quantile(i/n)` is the i-th order statistic.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile needs q in [0, 1], got {q}");
        let n = self.sorted.len();
        let scaled = q * n as f64;
        // Snap to the nearest integer when within a few ULP: `q = i/n`
        // step points must land on the i-th order statistic exactly even
        // though `q * n` can round a hair above `i` (the error grows
        // with `i`, so the tolerance is relative, not absolute).
        let nearest = scaled.round();
        let idx = if (scaled - nearest).abs() <= scaled * 4.0 * f64::EPSILON {
            nearest as usize
        } else {
            scaled.ceil() as usize
        };
        self.sorted[idx.saturating_sub(1).min(n - 1)]
    }

    /// The empirical distribution of `c · X` (see [`super::ServiceDist::scaled`]).
    pub(crate) fn scaled(&self, c: f64) -> Empirical {
        Empirical { sorted: self.sorted.iter().map(|x| c * x).collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ecdf_1_to_4() -> Empirical {
        Empirical::new(vec![3.0, 1.0, 4.0, 2.0])
    }

    #[test]
    fn sorts_and_exposes_order_statistics() {
        let e = ecdf_1_to_4();
        assert_eq!(e.data(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(e.len(), 4);
        assert!(!e.is_empty());
        assert_eq!((e.min(), e.max()), (1.0, 4.0));
        assert_eq!(e.mean(), 2.5);
        assert_eq!(e.variance(), 1.25);
    }

    #[test]
    fn cdf_is_exact_step_function() {
        let e = ecdf_1_to_4();
        assert_eq!(e.cdf(0.5), 0.0);
        assert_eq!(e.cdf(1.0), 0.25);
        assert_eq!(e.cdf(2.5), 0.5);
        assert_eq!(e.cdf(4.0), 1.0);
        assert_eq!(e.ccdf(2.0), 0.5);
    }

    #[test]
    fn quantile_hits_order_statistics_exactly() {
        let e = ecdf_1_to_4();
        for (i, &x) in e.data().iter().enumerate() {
            let q = (i + 1) as f64 / 4.0;
            assert_eq!(e.quantile(q), x, "q={q}");
        }
        assert_eq!(e.quantile(0.0), 1.0);
        assert_eq!(e.quantile(1.0), 4.0);
        // just past a step: next order statistic
        assert_eq!(e.quantile(0.26), 2.0);
    }

    #[test]
    fn bootstrap_sampling_is_deterministic_and_in_support() {
        let e = ecdf_1_to_4();
        let mut a = Pcg64::new(3);
        let mut b = Pcg64::new(3);
        for _ in 0..100 {
            let x = e.sample(&mut a);
            assert_eq!(x, e.sample(&mut b));
            assert!(e.data().contains(&x));
        }
    }

    #[test]
    fn scaled_multiplies_samples() {
        let e = ecdf_1_to_4().scaled(2.0);
        assert_eq!(e.data(), &[2.0, 4.0, 6.0, 8.0]);
        assert_eq!(e.mean(), 5.0);
    }

    #[test]
    #[should_panic]
    fn empty_rejected() {
        Empirical::new(Vec::new());
    }

    #[test]
    #[should_panic]
    fn non_finite_rejected() {
        Empirical::new(vec![1.0, f64::NAN]);
    }
}
