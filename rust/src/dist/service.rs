//! The service-time family catalogue.
//!
//! Paper references (Behrouzi-Far & Soljanin 2020):
//!
//! * `Exp(μ)` — §IV/§VI; E\[T\] eq. (26), CoV eq. (18), Theorems 3–4.
//! * `ShiftedExp(Δ, μ)` — §VI-B; eqs. (19)/(21), Theorems 5–7.
//! * `Pareto(σ, α)` — §VI-C; eqs. (22)/(24), Theorems 8–10. Survival
//!   `S(t) = (σ/t)^α` for `t ≥ σ`; the mean is infinite for `α ≤ 1`.
//! * `Weibull(k, λ)` / `Gamma(k, θ)` — the §IV closing remark's open
//!   problem (stochastically concave for shape > 1), explored in
//!   `experiments::open_problem`.
//! * `Bimodal` — fast/slow mixture of shifted exponentials (two-class
//!   stragglers, the §VII motivation).
//! * `Empirical` — trace bootstrap (§VII, Figs. 11–13).

use crate::dist::sampler::{exp_draw, gamma_draw, pareto_draw, weibull_draw};
use crate::dist::{Empirical, Sampler};
use crate::util::math::{
    bisect, gamma, gammainc_lower_regularized, gammainc_upper_regularized,
};
use crate::util::rng::Pcg64;

/// A task service-time distribution τ.
///
/// All families are supported on `[0, ∞)`. Sampling is inverse-CDF
/// wherever a closed form exists, so `sample`, [`ServiceDist::cdf`],
/// [`ServiceDist::ccdf`] and [`ServiceDist::quantile`] are mutually
/// consistent — [`crate::eval::Analytic`] inverts the exact CDF for its
/// p50/p95/p99, and the numeric integrator in
/// [`crate::analysis::closed_form`] integrates the exact survival.
#[derive(Clone, Debug, PartialEq)]
pub enum ServiceDist {
    /// Exponential with rate `mu` (mean `1/μ`).
    Exp { mu: f64 },
    /// The paper's SExp(Δ, μ): a deterministic shift `delta` plus an
    /// `Exp(mu)` tail.
    ShiftedExp { delta: f64, mu: f64 },
    /// Pareto with scale `sigma` and tail index `alpha`.
    Pareto { sigma: f64, alpha: f64 },
    /// Weibull with shape `shape` and scale `scale`:
    /// `S(t) = exp(−(t/λ)^k)`.
    Weibull { shape: f64, scale: f64 },
    /// Gamma with shape `shape` and scale `scale` (mean `k·θ`).
    Gamma { shape: f64, scale: f64 },
    /// Fast/slow straggler mixture: with probability `p_slow` the task
    /// is drawn from `SExp(slow.0, slow.1)`, otherwise from
    /// `SExp(fast.0, fast.1)`.
    Bimodal { p_slow: f64, fast: (f64, f64), slow: (f64, f64) },
    /// Empirical distribution of observed samples (exact ECDF).
    Empirical(Empirical),
}

/// CDF of `SExp(delta, mu)` at `t`.
fn sexp_cdf(delta: f64, mu: f64, t: f64) -> f64 {
    if t <= delta {
        0.0
    } else {
        1.0 - (-mu * (t - delta)).exp()
    }
}

/// Survival of `SExp(delta, mu)` at `t`.
fn sexp_ccdf(delta: f64, mu: f64, t: f64) -> f64 {
    if t <= delta {
        1.0
    } else {
        (-mu * (t - delta)).exp()
    }
}

impl ServiceDist {
    // ------------------------------------------------------ constructors

    /// Exponential with rate `mu` (mean `1/μ`).
    pub fn exp(mu: f64) -> ServiceDist {
        assert!(mu > 0.0 && mu.is_finite(), "Exp rate must be > 0, got {mu}");
        ServiceDist::Exp { mu }
    }

    /// Shifted exponential SExp(Δ, μ) — eq. (19)'s service model.
    pub fn shifted_exp(delta: f64, mu: f64) -> ServiceDist {
        assert!(delta >= 0.0 && delta.is_finite(), "SExp shift must be >= 0, got {delta}");
        assert!(mu > 0.0 && mu.is_finite(), "SExp rate must be > 0, got {mu}");
        ServiceDist::ShiftedExp { delta, mu }
    }

    /// Pareto(σ, α) — eq. (22)'s service model.
    pub fn pareto(sigma: f64, alpha: f64) -> ServiceDist {
        assert!(sigma > 0.0 && sigma.is_finite(), "Pareto scale must be > 0, got {sigma}");
        assert!(alpha > 0.0 && alpha.is_finite(), "Pareto index must be > 0, got {alpha}");
        ServiceDist::Pareto { sigma, alpha }
    }

    /// Weibull with shape `k` and scale `λ`.
    pub fn weibull(shape: f64, scale: f64) -> ServiceDist {
        assert!(shape > 0.0 && shape.is_finite(), "Weibull shape must be > 0, got {shape}");
        assert!(scale > 0.0 && scale.is_finite(), "Weibull scale must be > 0, got {scale}");
        ServiceDist::Weibull { shape, scale }
    }

    /// Gamma with shape `k` and scale `θ` (named `gamma_dist` to avoid
    /// clashing with the Γ special function).
    pub fn gamma_dist(shape: f64, scale: f64) -> ServiceDist {
        assert!(shape > 0.0 && shape.is_finite(), "Gamma shape must be > 0, got {shape}");
        assert!(scale > 0.0 && scale.is_finite(), "Gamma scale must be > 0, got {scale}");
        ServiceDist::Gamma { shape, scale }
    }

    /// Fast/slow mixture of shifted exponentials; each component is a
    /// `(delta, mu)` pair and `p_slow` is the straggler probability.
    pub fn bimodal(p_slow: f64, fast: (f64, f64), slow: (f64, f64)) -> ServiceDist {
        assert!((0.0..=1.0).contains(&p_slow), "p_slow must be in [0, 1], got {p_slow}");
        for (delta, mu) in [fast, slow] {
            assert!(delta >= 0.0 && delta.is_finite(), "component shift must be >= 0");
            assert!(mu > 0.0 && mu.is_finite(), "component rate must be > 0");
        }
        ServiceDist::Bimodal { p_slow, fast, slow }
    }

    /// Empirical distribution of observed samples (§VII bootstrap).
    pub fn empirical(samples: Vec<f64>) -> ServiceDist {
        ServiceDist::Empirical(Empirical::new(samples))
    }

    /// The distribution of `c · τ` — the batch-level service time of the
    /// size-dependent model `T_batch = (N/B)·τ` (§VI). Every family is
    /// closed under positive scaling, so the result stays in the enum,
    /// and a scaled distribution consumes the same RNG stream as its
    /// base (its draws are exactly `c ×` the base draws).
    pub fn scaled(c: f64, tau: ServiceDist) -> ServiceDist {
        assert!(c > 0.0 && c.is_finite(), "scale factor must be > 0, got {c}");
        match tau {
            ServiceDist::Exp { mu } => ServiceDist::Exp { mu: mu / c },
            ServiceDist::ShiftedExp { delta, mu } => {
                ServiceDist::ShiftedExp { delta: c * delta, mu: mu / c }
            }
            ServiceDist::Pareto { sigma, alpha } => {
                ServiceDist::Pareto { sigma: c * sigma, alpha }
            }
            ServiceDist::Weibull { shape, scale } => {
                ServiceDist::Weibull { shape, scale: c * scale }
            }
            ServiceDist::Gamma { shape, scale } => {
                ServiceDist::Gamma { shape, scale: c * scale }
            }
            ServiceDist::Bimodal { p_slow, fast, slow } => ServiceDist::Bimodal {
                p_slow,
                fast: (c * fast.0, fast.1 / c),
                slow: (c * slow.0, slow.1 / c),
            },
            ServiceDist::Empirical(e) => ServiceDist::Empirical(e.scaled(c)),
        }
    }

    // ----------------------------------------------------------- queries

    /// Draw one service time — a thin per-draw wrapper over the scalar
    /// kernels shared with the batched [`Sampler`]. Hot loops drawing
    /// many samples should compile a [`ServiceDist::sampler`] once and
    /// use [`Sampler::fill`] instead.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match self {
            ServiceDist::Exp { mu } => exp_draw(rng, *mu),
            ServiceDist::ShiftedExp { delta, mu } => delta + exp_draw(rng, *mu),
            ServiceDist::Pareto { sigma, alpha } => pareto_draw(rng, *sigma, *alpha),
            ServiceDist::Weibull { shape, scale } => weibull_draw(rng, *shape, *scale),
            ServiceDist::Gamma { shape, scale } => scale * gamma_draw(rng, *shape),
            ServiceDist::Bimodal { p_slow, fast, slow } => {
                let (delta, mu) = if rng.uniform() < *p_slow {
                    *slow
                } else {
                    *fast
                };
                delta + exp_draw(rng, mu)
            }
            ServiceDist::Empirical(e) => e.sample(rng),
        }
    }

    /// Compile the batched [`Sampler`] for this distribution (see
    /// [`crate::dist::sampler`] for the contract: identical bits for
    /// the closed-form families, identical distribution for
    /// Bimodal/Empirical).
    pub fn sampler(&self) -> Sampler {
        Sampler::compile(self)
    }

    /// E\[τ\]. Infinite for Pareto with `α ≤ 1`.
    pub fn mean(&self) -> f64 {
        match self {
            ServiceDist::Exp { mu } => 1.0 / mu,
            ServiceDist::ShiftedExp { delta, mu } => delta + 1.0 / mu,
            ServiceDist::Pareto { sigma, alpha } => {
                if *alpha > 1.0 {
                    alpha * sigma / (alpha - 1.0)
                } else {
                    f64::INFINITY
                }
            }
            ServiceDist::Weibull { shape, scale } => scale * gamma(1.0 + 1.0 / shape),
            ServiceDist::Gamma { shape, scale } => shape * scale,
            ServiceDist::Bimodal { p_slow, fast, slow } => {
                let m_fast = fast.0 + 1.0 / fast.1;
                let m_slow = slow.0 + 1.0 / slow.1;
                (1.0 - p_slow) * m_fast + p_slow * m_slow
            }
            ServiceDist::Empirical(e) => e.mean(),
        }
    }

    /// Var\[τ\]. Infinite for Pareto with `α ≤ 2`.
    pub fn variance(&self) -> f64 {
        match self {
            ServiceDist::Exp { mu } | ServiceDist::ShiftedExp { mu, .. } => 1.0 / (mu * mu),
            ServiceDist::Pareto { sigma, alpha } => {
                if *alpha > 2.0 {
                    sigma * sigma * alpha / ((alpha - 1.0) * (alpha - 1.0) * (alpha - 2.0))
                } else {
                    f64::INFINITY
                }
            }
            ServiceDist::Weibull { shape, scale } => {
                let g1 = gamma(1.0 + 1.0 / shape);
                let g2 = gamma(1.0 + 2.0 / shape);
                scale * scale * (g2 - g1 * g1)
            }
            ServiceDist::Gamma { shape, scale } => shape * scale * scale,
            ServiceDist::Bimodal { p_slow, fast, slow } => {
                // mixture: E[X²] = Σ wᵢ (varᵢ + meanᵢ²)
                let m_fast = fast.0 + 1.0 / fast.1;
                let m_slow = slow.0 + 1.0 / slow.1;
                let e2_fast = 1.0 / (fast.1 * fast.1) + m_fast * m_fast;
                let e2_slow = 1.0 / (slow.1 * slow.1) + m_slow * m_slow;
                let m = (1.0 - p_slow) * m_fast + p_slow * m_slow;
                (1.0 - p_slow) * e2_fast + p_slow * e2_slow - m * m
            }
            ServiceDist::Empirical(e) => e.variance(),
        }
    }

    /// `Pr{τ ≤ t}` (exact closed form except Gamma, which uses the
    /// regularized incomplete gamma).
    pub fn cdf(&self, t: f64) -> f64 {
        match self {
            ServiceDist::Exp { mu } => sexp_cdf(0.0, *mu, t),
            ServiceDist::ShiftedExp { delta, mu } => sexp_cdf(*delta, *mu, t),
            ServiceDist::Pareto { sigma, alpha } => {
                if t <= *sigma {
                    0.0
                } else {
                    1.0 - (sigma / t).powf(*alpha)
                }
            }
            ServiceDist::Weibull { shape, scale } => {
                if t <= 0.0 {
                    0.0
                } else {
                    1.0 - (-(t / scale).powf(*shape)).exp()
                }
            }
            ServiceDist::Gamma { shape, scale } => {
                if t <= 0.0 {
                    0.0
                } else {
                    gammainc_lower_regularized(*shape, t / scale)
                }
            }
            ServiceDist::Bimodal { p_slow, fast, slow } => {
                (1.0 - p_slow) * sexp_cdf(fast.0, fast.1, t)
                    + p_slow * sexp_cdf(slow.0, slow.1, t)
            }
            ServiceDist::Empirical(e) => e.cdf(t),
        }
    }

    /// Survival `Pr{τ > t}`, computed directly (not as `1 − cdf`) so the
    /// deep tail keeps full relative precision — the order-statistics
    /// integrator raises this to the replication power `S(t)^r`.
    pub fn ccdf(&self, t: f64) -> f64 {
        match self {
            ServiceDist::Exp { mu } => sexp_ccdf(0.0, *mu, t),
            ServiceDist::ShiftedExp { delta, mu } => sexp_ccdf(*delta, *mu, t),
            ServiceDist::Pareto { sigma, alpha } => {
                if t <= *sigma {
                    1.0
                } else {
                    (sigma / t).powf(*alpha)
                }
            }
            ServiceDist::Weibull { shape, scale } => {
                if t <= 0.0 {
                    1.0
                } else {
                    (-(t / scale).powf(*shape)).exp()
                }
            }
            ServiceDist::Gamma { shape, scale } => {
                if t <= 0.0 {
                    1.0
                } else {
                    gammainc_upper_regularized(*shape, t / scale)
                }
            }
            ServiceDist::Bimodal { p_slow, fast, slow } => {
                (1.0 - p_slow) * sexp_ccdf(fast.0, fast.1, t)
                    + p_slow * sexp_ccdf(slow.0, slow.1, t)
            }
            ServiceDist::Empirical(e) => e.ccdf(t),
        }
    }

    /// Quantile function `F⁻¹(q)` — exact inversion where a closed form
    /// exists (Exp/SExp/Pareto/Weibull), order statistics for Empirical,
    /// monotone bisection of the CDF for Gamma and Bimodal.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile needs q in [0, 1], got {q}");
        match self {
            ServiceDist::Exp { mu } => -(1.0 - q).ln() / mu,
            ServiceDist::ShiftedExp { delta, mu } => delta - (1.0 - q).ln() / mu,
            ServiceDist::Pareto { sigma, alpha } => sigma * (1.0 - q).powf(-1.0 / alpha),
            ServiceDist::Weibull { shape, scale } => {
                scale * (-(1.0 - q).ln()).powf(1.0 / shape)
            }
            ServiceDist::Gamma { .. } | ServiceDist::Bimodal { .. } => {
                self.quantile_by_bisection(q)
            }
            ServiceDist::Empirical(e) => e.quantile(q),
        }
    }

    /// Numeric quantile for families without a closed-form inverse:
    /// expand an upper bracket geometrically, then bisect the CDF.
    fn quantile_by_bisection(&self, q: f64) -> f64 {
        if q <= 0.0 {
            return 0.0;
        }
        if q >= 1.0 {
            return f64::INFINITY;
        }
        let mut hi = self.mean();
        if !hi.is_finite() || hi <= 0.0 {
            hi = 1.0;
        }
        let mut guard = 0;
        while self.cdf(hi) < q && guard < 2_000 {
            hi *= 2.0;
            guard += 1;
        }
        bisect(|t| self.cdf(t) - q, 0.0, hi, 1e-12 * hi.max(1.0)).unwrap_or(hi)
    }

    /// The distribution of the minimum of `k` i.i.d. copies, for the
    /// families closed under minima (`S_min = S^k`): Exp, SExp, Pareto
    /// and Weibull. `k = 1` is the distribution itself for every family;
    /// Gamma, Bimodal and Empirical are not closed for `k ≥ 2` — `None`.
    pub fn min_of(&self, k: usize) -> Option<ServiceDist> {
        assert!(k >= 1, "min_of needs k >= 1");
        if k == 1 {
            return Some(self.clone());
        }
        let kf = k as f64;
        match self {
            ServiceDist::Exp { mu } => Some(ServiceDist::Exp { mu: kf * mu }),
            ServiceDist::ShiftedExp { delta, mu } => {
                Some(ServiceDist::ShiftedExp { delta: *delta, mu: kf * mu })
            }
            ServiceDist::Pareto { sigma, alpha } => {
                Some(ServiceDist::Pareto { sigma: *sigma, alpha: kf * alpha })
            }
            ServiceDist::Weibull { shape, scale } => Some(ServiceDist::Weibull {
                shape: *shape,
                scale: scale * kf.powf(-1.0 / shape),
            }),
            _ => None,
        }
    }

    /// Short human-readable description for tables and error messages.
    pub fn label(&self) -> String {
        match self {
            ServiceDist::Exp { mu } => format!("Exp({mu})"),
            ServiceDist::ShiftedExp { delta, mu } => format!("SExp({delta}, {mu})"),
            ServiceDist::Pareto { sigma, alpha } => format!("Pareto({sigma}, {alpha})"),
            ServiceDist::Weibull { shape, scale } => format!("Weibull({shape}, {scale})"),
            ServiceDist::Gamma { shape, scale } => format!("Gamma({shape}, {scale})"),
            ServiceDist::Bimodal { p_slow, fast, slow } => {
                format!("Bimodal(p_slow={p_slow}, fast=SExp{fast:?}, slow=SExp{slow:?})")
            }
            ServiceDist::Empirical(e) => format!("Empirical(n={})", e.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc_moments(d: &ServiceDist, n: usize, seed: u64) -> (f64, f64) {
        let mut rng = Pcg64::new(seed);
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = d.sample(&mut rng);
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        (mean, s2 / n as f64 - mean * mean)
    }

    fn close_rel(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() / b.abs().max(1e-12) < tol, "{a} vs {b}");
    }

    #[test]
    fn closed_form_moments_known_values() {
        close_rel(ServiceDist::exp(2.0).mean(), 0.5, 1e-12);
        close_rel(ServiceDist::exp(2.0).variance(), 0.25, 1e-12);
        close_rel(ServiceDist::shifted_exp(0.05, 1.0).mean(), 1.05, 1e-12);
        close_rel(ServiceDist::pareto(1.0, 3.0).mean(), 1.5, 1e-12);
        close_rel(ServiceDist::pareto(1.0, 3.0).variance(), 0.75, 1e-12);
        // Weibull(1, λ) is Exp(1/λ)
        close_rel(ServiceDist::weibull(1.0, 2.0).mean(), 2.0, 1e-10);
        close_rel(ServiceDist::weibull(1.0, 2.0).variance(), 4.0, 1e-9);
        close_rel(ServiceDist::gamma_dist(2.5, 0.8).mean(), 2.0, 1e-12);
        close_rel(ServiceDist::gamma_dist(2.5, 0.8).variance(), 1.6, 1e-12);
        // Gamma(1, θ) is Exp(1/θ)
        close_rel(ServiceDist::gamma_dist(1.0, 0.5).variance(), 0.25, 1e-12);
    }

    #[test]
    fn pareto_heavy_tails_report_infinite_moments() {
        assert!(ServiceDist::pareto(1.0, 0.9).mean().is_infinite());
        assert!(ServiceDist::pareto(1.0, 1.5).mean().is_finite());
        assert!(ServiceDist::pareto(1.0, 1.5).variance().is_infinite());
        assert!(ServiceDist::pareto(1.0, 2.5).variance().is_finite());
    }

    #[test]
    fn cdf_ccdf_boundaries_and_complement() {
        let dists = [
            ServiceDist::exp(1.0),
            ServiceDist::shifted_exp(0.5, 2.0),
            ServiceDist::pareto(1.0, 2.0),
            ServiceDist::weibull(0.7, 1.0),
            ServiceDist::gamma_dist(2.0, 1.0),
            ServiceDist::bimodal(0.1, (0.1, 10.0), (5.0, 1.0)),
            ServiceDist::empirical(vec![1.0, 2.0, 3.0]),
        ];
        for d in &dists {
            assert_eq!(d.cdf(-1.0), 0.0, "{}", d.label());
            assert_eq!(d.ccdf(-1.0), 1.0, "{}", d.label());
            for t in [0.1, 0.5, 1.0, 2.0, 10.0] {
                let (f, s) = (d.cdf(t), d.ccdf(t));
                assert!((0.0..=1.0).contains(&f), "{} t={t}", d.label());
                assert!((f + s - 1.0).abs() < 1e-12, "{} t={t}: {f} + {s}", d.label());
            }
        }
    }

    #[test]
    fn shift_and_support_lower_bounds() {
        let sexp = ServiceDist::shifted_exp(0.5, 2.0);
        assert_eq!(sexp.cdf(0.5), 0.0);
        assert!(sexp.cdf(0.6) > 0.0);
        assert_eq!(sexp.quantile(0.0), 0.5);
        let par = ServiceDist::pareto(2.0, 1.5);
        assert_eq!(par.cdf(2.0), 0.0);
        assert_eq!(par.quantile(0.0), 2.0);
        let mut rng = Pcg64::new(1);
        for _ in 0..1_000 {
            assert!(sexp.sample(&mut rng) >= 0.5);
            assert!(par.sample(&mut rng) >= 2.0);
        }
    }

    #[test]
    fn numeric_quantiles_invert_the_cdf() {
        let dists = [
            ServiceDist::gamma_dist(2.0, 1.5),
            ServiceDist::gamma_dist(0.7, 1.0),
            ServiceDist::bimodal(0.1, (0.1, 10.0), (5.0, 1.0)),
        ];
        for d in &dists {
            for q in [0.05, 0.25, 0.5, 0.9, 0.99, 0.9999] {
                let t = d.quantile(q);
                assert!((d.cdf(t) - q).abs() < 1e-6, "{} q={q} t={t}", d.label());
            }
            assert_eq!(d.quantile(0.0), 0.0);
        }
    }

    #[test]
    fn scaled_is_exactly_c_times_the_base_stream() {
        let c = 3.5;
        let dists = [
            ServiceDist::exp(1.3),
            ServiceDist::shifted_exp(0.5, 2.0),
            ServiceDist::pareto(1.0, 3.0),
            ServiceDist::weibull(0.7, 1.0),
            ServiceDist::gamma_dist(2.0, 1.0),
            ServiceDist::bimodal(0.3, (0.1, 10.0), (5.0, 1.0)),
            ServiceDist::empirical(vec![1.0, 2.0, 3.0, 5.0]),
        ];
        for d in &dists {
            let s = ServiceDist::scaled(c, d.clone());
            close_rel(s.mean(), c * d.mean(), 1e-12);
            close_rel(s.variance(), c * c * d.variance(), 1e-12);
            let mut ra = Pcg64::new(9);
            let mut rb = Pcg64::new(9);
            for _ in 0..200 {
                close_rel(s.sample(&mut ra), c * d.sample(&mut rb), 1e-12);
            }
            // distribution-level identity: F_s(c·t) = F_d(t)
            for q in [0.1, 0.5, 0.9] {
                close_rel(s.quantile(q), c * d.quantile(q), 1e-6);
            }
        }
    }

    #[test]
    fn min_of_matches_survival_powers_exactly() {
        let dists = [
            ServiceDist::exp(1.3),
            ServiceDist::shifted_exp(0.5, 2.0),
            ServiceDist::pareto(1.0, 2.0),
            ServiceDist::weibull(0.7, 1.0),
        ];
        for d in &dists {
            let m = d.min_of(4).expect("closed under minima");
            for t in [0.2, 0.7, 1.5, 4.0] {
                close_rel(m.ccdf(t).max(1e-300), d.ccdf(t).powi(4).max(1e-300), 1e-9);
            }
        }
        assert!(ServiceDist::gamma_dist(2.0, 1.0).min_of(3).is_none());
        assert!(ServiceDist::bimodal(0.1, (0.1, 10.0), (5.0, 1.0)).min_of(3).is_none());
        assert!(ServiceDist::empirical(vec![1.0]).min_of(3).is_none());
        // min of one copy is the distribution itself, for every family
        let g = ServiceDist::gamma_dist(2.0, 1.0);
        assert_eq!(g.min_of(1), Some(g.clone()));
        let e = ServiceDist::exp(1.3);
        assert_eq!(e.min_of(1), Some(e.clone()));
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let d = ServiceDist::gamma_dist(0.7, 1.0);
        let a: Vec<f64> = {
            let mut rng = Pcg64::new(5);
            (0..50).map(|_| d.sample(&mut rng)).collect()
        };
        let b: Vec<f64> = {
            let mut rng = Pcg64::new(5);
            (0..50).map(|_| d.sample(&mut rng)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn bimodal_degenerate_weights_collapse_to_components() {
        let fast = (0.1, 10.0);
        let slow = (5.0, 1.0);
        let all_fast = ServiceDist::bimodal(0.0, fast, slow);
        close_rel(all_fast.mean(), 0.1 + 0.1, 1e-12);
        let all_slow = ServiceDist::bimodal(1.0, fast, slow);
        close_rel(all_slow.mean(), 6.0, 1e-12);
        close_rel(all_slow.variance(), 1.0, 1e-12);
    }

    #[test]
    fn gamma_sampler_moments_both_branches() {
        // shape > 1 (Marsaglia–Tsang) and shape < 1 (Boost boost)
        for (shape, scale) in [(2.5, 0.8), (0.7, 1.5)] {
            let d = ServiceDist::gamma_dist(shape, scale);
            let (m, v) = mc_moments(&d, 200_000, 42);
            close_rel(m, d.mean(), 0.02);
            close_rel(v, d.variance(), 0.05);
        }
    }

    #[test]
    fn labels_name_the_family() {
        assert_eq!(ServiceDist::exp(1.0).label(), "Exp(1)");
        assert_eq!(ServiceDist::shifted_exp(0.05, 1.0).label(), "SExp(0.05, 1)");
        assert!(ServiceDist::gamma_dist(2.0, 1.0).label().contains("Gamma"));
        assert!(ServiceDist::empirical(vec![1.0, 2.0]).label().contains("n=2"));
    }

    #[test]
    #[should_panic]
    fn invalid_rate_rejected() {
        ServiceDist::exp(0.0);
    }

    #[test]
    #[should_panic]
    fn invalid_scale_factor_rejected() {
        ServiceDist::scaled(0.0, ServiceDist::exp(1.0));
    }
}
