//! Compiled batch samplers — the Monte-Carlo hot path's draw engine.
//!
//! [`crate::dist::ServiceDist::sample`] is the right call for a single
//! draw, but the simulator draws millions of service times per sweep,
//! and paying an enum match (plus, for `Bimodal`/`Empirical`, per-draw
//! branching) on every draw is measurable. A [`Sampler`] is compiled
//! once per simulation from a [`ServiceDist`] and then:
//!
//! * [`Sampler::fill`] fills a caller-owned `&mut [f64]` slice with one
//!   family-specialized tight loop — the enum dispatch is hoisted out
//!   of the per-draw path entirely;
//! * `Bimodal` and `Empirical` draw through Walker
//!   [`AliasTable`]s (O(1) per draw, one uniform), replacing the
//!   per-draw mixture branch and the bootstrap index rejection loop.
//!
//! The scalar per-draw kernels (`exp_draw`, `gamma_draw`, …) live here
//! and are shared with `ServiceDist::sample`, which stays as a thin
//! per-draw wrapper over the same arithmetic — so for the closed-form
//! families a `Sampler` consumes the RNG stream draw-for-draw exactly
//! like the scalar path. `Bimodal`/`Empirical` use the alias path
//! instead, which is identical **in distribution** (property-tested in
//! `tests/sampler_properties.rs`) but consumes the stream differently.

use crate::dist::alias::AliasTable;
use crate::dist::ServiceDist;
use crate::util::rng::Pcg64;

// ------------------------------------------------------ scalar kernels

/// One exponential draw by inversion, `−ln U / μ` with `U ∈ (0, 1]`.
#[inline]
pub(crate) fn exp_draw(rng: &mut Pcg64, mu: f64) -> f64 {
    -rng.uniform_pos().ln() / mu
}

/// One Pareto(σ, α) draw by inversion.
#[inline]
pub(crate) fn pareto_draw(rng: &mut Pcg64, sigma: f64, alpha: f64) -> f64 {
    sigma * rng.uniform_pos().powf(-1.0 / alpha)
}

/// One Weibull(k, λ) draw by inversion.
#[inline]
pub(crate) fn weibull_draw(rng: &mut Pcg64, shape: f64, scale: f64) -> f64 {
    scale * (-rng.uniform_pos().ln()).powf(1.0 / shape)
}

/// Marsaglia–Tsang Gamma(shape, 1) sampler; Boost trick for shape < 1.
pub(crate) fn gamma_draw(rng: &mut Pcg64, shape: f64) -> f64 {
    if shape < 1.0 {
        let x = gamma_draw(rng, shape + 1.0);
        return x * rng.uniform_pos().powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let z = rng.normal();
        let v = (1.0 + c * z).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.uniform_pos();
        if u.ln() < 0.5 * z * z + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

// ---------------------------------------------------- compiled sampler

/// A service-time sampler compiled from one [`ServiceDist`].
///
/// Build once per simulation ([`ServiceDist::sampler`]), then call
/// [`Sampler::fill`] from the replication loop. Compilation is O(1)
/// except for `Empirical`, which clones the sample vector and builds
/// its alias table in O(n) — amortized over every replication of the
/// scenario.
#[derive(Clone, Debug)]
pub enum Sampler {
    Exp {
        mu: f64,
    },
    ShiftedExp {
        delta: f64,
        mu: f64,
    },
    Pareto {
        sigma: f64,
        alpha: f64,
    },
    Weibull {
        shape: f64,
        scale: f64,
    },
    Gamma {
        shape: f64,
        scale: f64,
    },
    /// Component picked by a 2-cell alias table (0 = fast, 1 = slow),
    /// then `delta + Exp(mu)`.
    Bimodal {
        comps: [(f64, f64); 2],
        alias: AliasTable,
    },
    /// Bootstrap over the sorted sample values via a uniform alias
    /// table (one uniform per draw; no Lemire rejection loop).
    Empirical {
        values: Vec<f64>,
        alias: AliasTable,
    },
}

impl Sampler {
    /// Compile the batch sampler for a distribution.
    pub fn compile(dist: &ServiceDist) -> Sampler {
        match dist {
            ServiceDist::Exp { mu } => Sampler::Exp { mu: *mu },
            ServiceDist::ShiftedExp { delta, mu } => {
                Sampler::ShiftedExp { delta: *delta, mu: *mu }
            }
            ServiceDist::Pareto { sigma, alpha } => {
                Sampler::Pareto { sigma: *sigma, alpha: *alpha }
            }
            ServiceDist::Weibull { shape, scale } => {
                Sampler::Weibull { shape: *shape, scale: *scale }
            }
            ServiceDist::Gamma { shape, scale } => {
                Sampler::Gamma { shape: *shape, scale: *scale }
            }
            ServiceDist::Bimodal { p_slow, fast, slow } => Sampler::Bimodal {
                comps: [*fast, *slow],
                alias: AliasTable::new(&[1.0 - p_slow, *p_slow]),
            },
            ServiceDist::Empirical(e) => Sampler::Empirical {
                values: e.data().to_vec(),
                alias: AliasTable::uniform(e.len()),
            },
        }
    }

    /// Draw one service time.
    #[inline]
    pub fn sample_one(&self, rng: &mut Pcg64) -> f64 {
        match self {
            Sampler::Exp { mu } => exp_draw(rng, *mu),
            Sampler::ShiftedExp { delta, mu } => delta + exp_draw(rng, *mu),
            Sampler::Pareto { sigma, alpha } => pareto_draw(rng, *sigma, *alpha),
            Sampler::Weibull { shape, scale } => weibull_draw(rng, *shape, *scale),
            Sampler::Gamma { shape, scale } => scale * gamma_draw(rng, *shape),
            Sampler::Bimodal { comps, alias } => {
                let (delta, mu) = comps[alias.sample(rng)];
                delta + exp_draw(rng, mu)
            }
            Sampler::Empirical { values, alias } => values[alias.sample(rng)],
        }
    }

    /// Fill `out` with independent draws — one tight per-family loop,
    /// no per-draw dispatch.
    pub fn fill(&self, rng: &mut Pcg64, out: &mut [f64]) {
        match self {
            Sampler::Exp { mu } => {
                for x in out.iter_mut() {
                    *x = -rng.uniform_pos().ln() / mu;
                }
            }
            Sampler::ShiftedExp { delta, mu } => {
                for x in out.iter_mut() {
                    *x = delta - rng.uniform_pos().ln() / mu;
                }
            }
            Sampler::Pareto { sigma, alpha } => {
                let exponent = -1.0 / alpha;
                for x in out.iter_mut() {
                    *x = sigma * rng.uniform_pos().powf(exponent);
                }
            }
            Sampler::Weibull { shape, scale } => {
                let exponent = 1.0 / shape;
                for x in out.iter_mut() {
                    *x = scale * (-rng.uniform_pos().ln()).powf(exponent);
                }
            }
            Sampler::Gamma { shape, scale } => {
                for x in out.iter_mut() {
                    *x = scale * gamma_draw(rng, *shape);
                }
            }
            Sampler::Bimodal { comps, alias } => {
                for x in out.iter_mut() {
                    let (delta, mu) = comps[alias.sample(rng)];
                    *x = delta - rng.uniform_pos().ln() / mu;
                }
            }
            Sampler::Empirical { values, alias } => {
                for x in out.iter_mut() {
                    *x = values[alias.sample(rng)];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_families() -> Vec<ServiceDist> {
        vec![
            ServiceDist::exp(1.3),
            ServiceDist::shifted_exp(0.5, 2.0),
            ServiceDist::pareto(1.0, 3.0),
            ServiceDist::weibull(0.7, 1.5),
            ServiceDist::gamma_dist(2.5, 0.8),
            ServiceDist::bimodal(0.15, (0.1, 10.0), (5.0, 1.0)),
            ServiceDist::empirical(vec![1.0, 2.0, 3.0, 5.0, 8.0]),
        ]
    }

    #[test]
    fn closed_form_families_match_scalar_path_bitwise() {
        // Exp/SExp/Pareto/Weibull/Gamma: the compiled sampler and
        // ServiceDist::sample share the same kernels, so equal seeds
        // give equal bits draw-for-draw.
        for dist in [
            ServiceDist::exp(1.3),
            ServiceDist::shifted_exp(0.5, 2.0),
            ServiceDist::pareto(1.0, 3.0),
            ServiceDist::weibull(0.7, 1.5),
            ServiceDist::gamma_dist(2.5, 0.8),
        ] {
            let sampler = Sampler::compile(&dist);
            let mut a = Pcg64::new(17);
            let mut b = Pcg64::new(17);
            for i in 0..500 {
                let x = sampler.sample_one(&mut a);
                let y = dist.sample(&mut b);
                assert_eq!(x.to_bits(), y.to_bits(), "{} draw {i}", dist.label());
            }
        }
    }

    #[test]
    fn fill_matches_sample_one_bitwise() {
        // same seed, same sequence: the batched loop is the scalar loop
        // with the dispatch hoisted
        for dist in all_families() {
            let sampler = Sampler::compile(&dist);
            let mut a = Pcg64::new(23);
            let mut b = Pcg64::new(23);
            let mut buf = vec![0.0; 300];
            sampler.fill(&mut a, &mut buf);
            for (i, &x) in buf.iter().enumerate() {
                let y = sampler.sample_one(&mut b);
                assert_eq!(x.to_bits(), y.to_bits(), "{} draw {i}", dist.label());
            }
        }
    }

    #[test]
    fn moments_match_the_distribution() {
        for dist in all_families() {
            let sampler = Sampler::compile(&dist);
            let mut rng = Pcg64::new(41);
            let mut buf = vec![0.0; 4_000];
            let (mut s, mut s2) = (0.0, 0.0);
            let blocks = 50;
            for _ in 0..blocks {
                sampler.fill(&mut rng, &mut buf);
                for &x in &buf {
                    s += x;
                    s2 += x * x;
                }
            }
            let n = (blocks * buf.len()) as f64;
            let mean = s / n;
            let var = s2 / n - mean * mean;
            assert!(
                (mean - dist.mean()).abs() / dist.mean() < 0.02,
                "{}: mean {mean} vs {}",
                dist.label(),
                dist.mean()
            );
            assert!(
                (var - dist.variance()).abs() / dist.variance() < 0.06,
                "{}: var {var} vs {}",
                dist.label(),
                dist.variance()
            );
        }
    }

    #[test]
    fn samples_stay_in_support() {
        let dist = ServiceDist::empirical(vec![2.0, 4.0, 6.0]);
        let sampler = Sampler::compile(&dist);
        let mut rng = Pcg64::new(5);
        let mut buf = vec![0.0; 1_000];
        sampler.fill(&mut rng, &mut buf);
        for &x in &buf {
            assert!(x == 2.0 || x == 4.0 || x == 6.0, "{x}");
        }
        let dist = ServiceDist::shifted_exp(0.5, 1.0);
        let sampler = Sampler::compile(&dist);
        sampler.fill(&mut rng, &mut buf);
        assert!(buf.iter().all(|&x| x >= 0.5));
    }

    #[test]
    fn bimodal_degenerate_weights_collapse() {
        let fast = (0.1, 10.0);
        let slow = (5.0, 1.0);
        let all_fast = Sampler::compile(&ServiceDist::bimodal(0.0, fast, slow));
        let mut rng = Pcg64::new(9);
        let mut buf = vec![0.0; 2_000];
        all_fast.fill(&mut rng, &mut buf);
        // fast component is SExp(0.1, 10): mean 0.2, support >= 0.1
        assert!(buf.iter().all(|&x| x >= 0.1));
        let mean = buf.iter().sum::<f64>() / buf.len() as f64;
        assert!((mean - 0.2).abs() < 0.02, "{mean}");
    }
}
