//! Compiled batch samplers — the Monte-Carlo hot path's draw engine.
//!
//! [`crate::dist::ServiceDist::sample`] is the right call for a single
//! draw, but the simulator draws millions of service times per sweep,
//! and paying an enum match (plus, for `Bimodal`/`Empirical`, per-draw
//! branching) on every draw is measurable. A [`Sampler`] is compiled
//! once per simulation from a [`ServiceDist`] and then:
//!
//! * [`Sampler::fill`] fills a caller-owned `&mut [f64]` slice with one
//!   family-specialized tight loop — the enum dispatch is hoisted out
//!   of the per-draw path entirely;
//! * `Bimodal` and `Empirical` draw through Walker
//!   [`AliasTable`]s (O(1) per draw, one uniform), replacing the
//!   per-draw mixture branch and the bootstrap index rejection loop.
//!
//! The scalar per-draw kernels (`exp_draw`, `gamma_draw`, …) live here
//! and are shared with `ServiceDist::sample`, which stays as a thin
//! per-draw wrapper over the same arithmetic — so for the closed-form
//! families a `Sampler` consumes the RNG stream draw-for-draw exactly
//! like the scalar path. `Bimodal`/`Empirical` use the alias path
//! instead, which is identical **in distribution** (property-tested in
//! `tests/sampler_properties.rs`) but consumes the stream differently.
//!
//! # Variance-reduced fills
//!
//! Two extra fill strategies exist for the single-uniform inverse-CDF
//! families (Exp, SExp, Pareto, Weibull):
//!
//! * [`Sampler::fill_antithetic`] — u/1−u pairing: adjacent slots share
//!   one uniform and its complement. The per-draw marginal is exact, so
//!   any *mean over draws* (E\[τ\], E\[h(τ)\] for monotone h) stays
//!   unbiased while its variance drops.
//! * [`Sampler::fill_stratified`] — one draw per equal-probability
//!   stratum of the batch: slot `i` of an n-slot fill lands in CDF cell
//!   `[i/n, (i+1)/n)`. Again exact marginals, near-zero quantile noise.
//!
//! Both are for estimating **expectations that are symmetric (or
//! linear) in the batch**. They are deliberately *not* wired into the
//! job simulator's per-replication fills: a replication's completion
//! time `T = max_b min_w τ_w` is a nonlinear function of the joint
//! draw vector, and draws that are dependent *within one replication*
//! (an antithetic pair, a stratified grid) would bias E\[T\]. The
//! simulator's variance reduction is common random numbers across the
//! B-spectrum instead (see `planner::PairedSpectrum`).
//!
//! Families without a single-uniform inverse CDF (Gamma's rejection
//! loop, the alias-table-backed Bimodal/Empirical) fall back to the
//! plain [`Sampler::fill`]; the returned [`FillMode`] records which
//! strategy actually ran so callers can carry it into provenance.

use crate::dist::alias::AliasTable;
use crate::dist::ServiceDist;
use crate::util::rng::Pcg64;

// ------------------------------------------------------ scalar kernels

/// One exponential draw by inversion, `−ln U / μ` with `U ∈ (0, 1]`.
#[inline]
pub(crate) fn exp_draw(rng: &mut Pcg64, mu: f64) -> f64 {
    -rng.uniform_pos().ln() / mu
}

/// One Pareto(σ, α) draw by inversion.
#[inline]
pub(crate) fn pareto_draw(rng: &mut Pcg64, sigma: f64, alpha: f64) -> f64 {
    sigma * rng.uniform_pos().powf(-1.0 / alpha)
}

/// One Weibull(k, λ) draw by inversion.
#[inline]
pub(crate) fn weibull_draw(rng: &mut Pcg64, shape: f64, scale: f64) -> f64 {
    scale * (-rng.uniform_pos().ln()).powf(1.0 / shape)
}

/// Marsaglia–Tsang Gamma(shape, 1) sampler; Boost trick for shape < 1.
pub(crate) fn gamma_draw(rng: &mut Pcg64, shape: f64) -> f64 {
    if shape < 1.0 {
        let x = gamma_draw(rng, shape + 1.0);
        return x * rng.uniform_pos().powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let z = rng.normal();
        let v = (1.0 + c * z).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.uniform_pos();
        if u.ln() < 0.5 * z * z + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

// ------------------------------------------------ variance-reduced fills

/// The smallest value `Pcg64::uniform_pos` can return (2⁻⁵³); clamping
/// a derived uniform to this floor keeps it inside the kernels' (0, 1]
/// domain so `ln` never sees zero.
const U_MIN: f64 = 1.0 / 9_007_199_254_740_992.0;

/// The fill strategy that actually ran for a variance-reduced fill
/// request — `Plain` when the family forced a fallback.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FillMode {
    /// u/1−u pairing in adjacent slots (closed-form inverse-CDF only).
    Antithetic,
    /// One draw per equal-probability CDF stratum of the batch.
    Stratified,
    /// Independent draws — the fallback for Gamma (rejection loop) and
    /// the alias-table families (Bimodal, Empirical).
    Plain,
}

impl FillMode {
    /// Stable lowercase label for provenance records and CLI output.
    pub fn label(&self) -> &'static str {
        match self {
            FillMode::Antithetic => "antithetic",
            FillMode::Stratified => "stratified",
            FillMode::Plain => "plain",
        }
    }
}

// ---------------------------------------------------- compiled sampler

/// A service-time sampler compiled from one [`ServiceDist`].
///
/// Build once per simulation ([`ServiceDist::sampler`]), then call
/// [`Sampler::fill`] from the replication loop. Compilation is O(1)
/// except for `Empirical`, which clones the sample vector and builds
/// its alias table in O(n) — amortized over every replication of the
/// scenario.
#[derive(Clone, Debug)]
pub enum Sampler {
    Exp {
        mu: f64,
    },
    ShiftedExp {
        delta: f64,
        mu: f64,
    },
    Pareto {
        sigma: f64,
        alpha: f64,
    },
    Weibull {
        shape: f64,
        scale: f64,
    },
    Gamma {
        shape: f64,
        scale: f64,
    },
    /// Component picked by a 2-cell alias table (0 = fast, 1 = slow),
    /// then `delta + Exp(mu)`.
    Bimodal {
        comps: [(f64, f64); 2],
        alias: AliasTable,
    },
    /// Bootstrap over the sorted sample values via a uniform alias
    /// table (one uniform per draw; no Lemire rejection loop).
    Empirical {
        values: Vec<f64>,
        alias: AliasTable,
    },
}

impl Sampler {
    /// Compile the batch sampler for a distribution.
    pub fn compile(dist: &ServiceDist) -> Sampler {
        match dist {
            ServiceDist::Exp { mu } => Sampler::Exp { mu: *mu },
            ServiceDist::ShiftedExp { delta, mu } => {
                Sampler::ShiftedExp { delta: *delta, mu: *mu }
            }
            ServiceDist::Pareto { sigma, alpha } => {
                Sampler::Pareto { sigma: *sigma, alpha: *alpha }
            }
            ServiceDist::Weibull { shape, scale } => {
                Sampler::Weibull { shape: *shape, scale: *scale }
            }
            ServiceDist::Gamma { shape, scale } => {
                Sampler::Gamma { shape: *shape, scale: *scale }
            }
            ServiceDist::Bimodal { p_slow, fast, slow } => Sampler::Bimodal {
                comps: [*fast, *slow],
                alias: AliasTable::new(&[1.0 - p_slow, *p_slow]),
            },
            ServiceDist::Empirical(e) => Sampler::Empirical {
                values: e.data().to_vec(),
                alias: AliasTable::uniform(e.len()),
            },
        }
    }

    /// Draw one service time.
    #[inline]
    pub fn sample_one(&self, rng: &mut Pcg64) -> f64 {
        match self {
            Sampler::Exp { mu } => exp_draw(rng, *mu),
            Sampler::ShiftedExp { delta, mu } => delta + exp_draw(rng, *mu),
            Sampler::Pareto { sigma, alpha } => pareto_draw(rng, *sigma, *alpha),
            Sampler::Weibull { shape, scale } => weibull_draw(rng, *shape, *scale),
            Sampler::Gamma { shape, scale } => scale * gamma_draw(rng, *shape),
            Sampler::Bimodal { comps, alias } => {
                let (delta, mu) = comps[alias.sample(rng)];
                delta + exp_draw(rng, mu)
            }
            Sampler::Empirical { values, alias } => values[alias.sample(rng)],
        }
    }

    /// Fill `out` with independent draws — one tight per-family loop,
    /// no per-draw dispatch.
    pub fn fill(&self, rng: &mut Pcg64, out: &mut [f64]) {
        match self {
            Sampler::Exp { mu } => {
                for x in out.iter_mut() {
                    *x = -rng.uniform_pos().ln() / mu;
                }
            }
            Sampler::ShiftedExp { delta, mu } => {
                for x in out.iter_mut() {
                    *x = delta - rng.uniform_pos().ln() / mu;
                }
            }
            Sampler::Pareto { sigma, alpha } => {
                let exponent = -1.0 / alpha;
                for x in out.iter_mut() {
                    *x = sigma * rng.uniform_pos().powf(exponent);
                }
            }
            Sampler::Weibull { shape, scale } => {
                let exponent = 1.0 / shape;
                for x in out.iter_mut() {
                    *x = scale * (-rng.uniform_pos().ln()).powf(exponent);
                }
            }
            Sampler::Gamma { shape, scale } => {
                for x in out.iter_mut() {
                    *x = scale * gamma_draw(rng, *shape);
                }
            }
            Sampler::Bimodal { comps, alias } => {
                for x in out.iter_mut() {
                    let (delta, mu) = comps[alias.sample(rng)];
                    *x = delta - rng.uniform_pos().ln() / mu;
                }
            }
            Sampler::Empirical { values, alias } => {
                for x in out.iter_mut() {
                    *x = values[alias.sample(rng)];
                }
            }
        }
    }

    /// True when this family draws through a single-uniform inverse-CDF
    /// kernel, so the variance-reduced fills apply without fallback.
    pub fn supports_inverse_cdf(&self) -> bool {
        matches!(
            self,
            Sampler::Exp { .. }
                | Sampler::ShiftedExp { .. }
                | Sampler::Pareto { .. }
                | Sampler::Weibull { .. }
        )
    }

    /// Map one uniform `u ∈ (0, 1]` through the family's inverse-CDF
    /// kernel — the same arithmetic [`Sampler::fill`] applies to
    /// `rng.uniform_pos()`, so feeding the RNG's own uniform through
    /// here reproduces the plain draw bit-for-bit.
    ///
    /// Only meaningful for the [`Sampler::supports_inverse_cdf`]
    /// families; for the rest it returns NaN (total, never panics).
    #[inline]
    fn from_uniform(&self, u: f64) -> f64 {
        match self {
            Sampler::Exp { mu } => -u.ln() / mu,
            Sampler::ShiftedExp { delta, mu } => delta - u.ln() / mu,
            Sampler::Pareto { sigma, alpha } => sigma * u.powf(-1.0 / alpha),
            Sampler::Weibull { shape, scale } => {
                scale * (-u.ln()).powf(1.0 / shape)
            }
            Sampler::Gamma { .. }
            | Sampler::Bimodal { .. }
            | Sampler::Empirical { .. } => f64::NAN,
        }
    }

    /// Fill `out` with antithetic pairs: slot `2k` draws `u`, slot
    /// `2k+1` reuses its complement `1 − u` (clamped into (0, 1]), both
    /// through the family's inverse-CDF kernel. A trailing odd slot
    /// gets an independent draw. Families without a single-uniform
    /// inverse CDF fall back to [`Sampler::fill`].
    ///
    /// Returns the strategy that actually ran so callers can record
    /// fallbacks in provenance.
    pub fn fill_antithetic(&self, rng: &mut Pcg64, out: &mut [f64]) -> FillMode {
        if !self.supports_inverse_cdf() {
            self.fill(rng, out);
            return FillMode::Plain;
        }
        let mut i = 0;
        while i + 1 < out.len() {
            let u = rng.uniform_pos();
            out[i] = self.from_uniform(u);
            out[i + 1] = self.from_uniform((1.0 - u).max(U_MIN));
            i += 2;
        }
        if i < out.len() {
            out[i] = self.from_uniform(rng.uniform_pos());
        }
        FillMode::Antithetic
    }

    /// Fill `out` with one draw per equal-probability stratum: slot `i`
    /// of an n-slot fill uses `u = 1 − (i + V)/n` with `V ∈ [0, 1)`, so
    /// its CDF value lands in `[i/n, (i+1)/n)`. One uniform is consumed
    /// per slot, exactly like the plain fill. Families without a
    /// single-uniform inverse CDF fall back to [`Sampler::fill`].
    ///
    /// Returns the strategy that actually ran so callers can record
    /// fallbacks in provenance.
    pub fn fill_stratified(&self, rng: &mut Pcg64, out: &mut [f64]) -> FillMode {
        if !self.supports_inverse_cdf() || out.is_empty() {
            self.fill(rng, out);
            return FillMode::Plain;
        }
        let n = out.len() as f64;
        for (i, x) in out.iter_mut().enumerate() {
            let u = 1.0 - (i as f64 + rng.uniform()) / n;
            *x = self.from_uniform(u.max(U_MIN));
        }
        FillMode::Stratified
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_families() -> Vec<ServiceDist> {
        vec![
            ServiceDist::exp(1.3),
            ServiceDist::shifted_exp(0.5, 2.0),
            ServiceDist::pareto(1.0, 3.0),
            ServiceDist::weibull(0.7, 1.5),
            ServiceDist::gamma_dist(2.5, 0.8),
            ServiceDist::bimodal(0.15, (0.1, 10.0), (5.0, 1.0)),
            ServiceDist::empirical(vec![1.0, 2.0, 3.0, 5.0, 8.0]),
        ]
    }

    #[test]
    fn closed_form_families_match_scalar_path_bitwise() {
        // Exp/SExp/Pareto/Weibull/Gamma: the compiled sampler and
        // ServiceDist::sample share the same kernels, so equal seeds
        // give equal bits draw-for-draw.
        for dist in [
            ServiceDist::exp(1.3),
            ServiceDist::shifted_exp(0.5, 2.0),
            ServiceDist::pareto(1.0, 3.0),
            ServiceDist::weibull(0.7, 1.5),
            ServiceDist::gamma_dist(2.5, 0.8),
        ] {
            let sampler = Sampler::compile(&dist);
            let mut a = Pcg64::new(17);
            let mut b = Pcg64::new(17);
            for i in 0..500 {
                let x = sampler.sample_one(&mut a);
                let y = dist.sample(&mut b);
                assert_eq!(x.to_bits(), y.to_bits(), "{} draw {i}", dist.label());
            }
        }
    }

    #[test]
    fn fill_matches_sample_one_bitwise() {
        // same seed, same sequence: the batched loop is the scalar loop
        // with the dispatch hoisted
        for dist in all_families() {
            let sampler = Sampler::compile(&dist);
            let mut a = Pcg64::new(23);
            let mut b = Pcg64::new(23);
            let mut buf = vec![0.0; 300];
            sampler.fill(&mut a, &mut buf);
            for (i, &x) in buf.iter().enumerate() {
                let y = sampler.sample_one(&mut b);
                assert_eq!(x.to_bits(), y.to_bits(), "{} draw {i}", dist.label());
            }
        }
    }

    #[test]
    fn moments_match_the_distribution() {
        for dist in all_families() {
            let sampler = Sampler::compile(&dist);
            let mut rng = Pcg64::new(41);
            let mut buf = vec![0.0; 4_000];
            let (mut s, mut s2) = (0.0, 0.0);
            let blocks = 50;
            for _ in 0..blocks {
                sampler.fill(&mut rng, &mut buf);
                for &x in &buf {
                    s += x;
                    s2 += x * x;
                }
            }
            let n = (blocks * buf.len()) as f64;
            let mean = s / n;
            let var = s2 / n - mean * mean;
            assert!(
                (mean - dist.mean()).abs() / dist.mean() < 0.02,
                "{}: mean {mean} vs {}",
                dist.label(),
                dist.mean()
            );
            assert!(
                (var - dist.variance()).abs() / dist.variance() < 0.06,
                "{}: var {var} vs {}",
                dist.label(),
                dist.variance()
            );
        }
    }

    #[test]
    fn samples_stay_in_support() {
        let dist = ServiceDist::empirical(vec![2.0, 4.0, 6.0]);
        let sampler = Sampler::compile(&dist);
        let mut rng = Pcg64::new(5);
        let mut buf = vec![0.0; 1_000];
        sampler.fill(&mut rng, &mut buf);
        for &x in &buf {
            assert!(x == 2.0 || x == 4.0 || x == 6.0, "{x}");
        }
        let dist = ServiceDist::shifted_exp(0.5, 1.0);
        let sampler = Sampler::compile(&dist);
        sampler.fill(&mut rng, &mut buf);
        assert!(buf.iter().all(|&x| x >= 0.5));
    }

    #[test]
    fn antithetic_pairs_are_complements() {
        // For Exp(μ) the survival function S(x) = exp(−μx) recovers the
        // uniform that produced x, so each pair's survival values must
        // sum to exactly 1.
        let sampler = Sampler::compile(&ServiceDist::exp(1.3));
        let mut rng = Pcg64::new(7);
        let mut buf = vec![0.0; 64];
        let mode = sampler.fill_antithetic(&mut rng, &mut buf);
        assert_eq!(mode, FillMode::Antithetic);
        for pair in buf.chunks_exact(2) {
            let u0 = (-1.3 * pair[0]).exp();
            let u1 = (-1.3 * pair[1]).exp();
            assert!((u0 + u1 - 1.0).abs() < 1e-12, "{u0} + {u1}");
        }
    }

    #[test]
    fn antithetic_handles_odd_lengths() {
        let sampler = Sampler::compile(&ServiceDist::weibull(0.7, 1.5));
        let mut rng = Pcg64::new(11);
        let mut buf = vec![0.0; 7];
        let mode = sampler.fill_antithetic(&mut rng, &mut buf);
        assert_eq!(mode, FillMode::Antithetic);
        assert!(buf.iter().all(|x| x.is_finite() && *x > 0.0));
    }

    #[test]
    fn fallback_families_match_plain_fill_bitwise() {
        // Gamma (rejection loop) and the alias-table families must fall
        // back to the plain fill, draw-for-draw identical.
        for dist in [
            ServiceDist::gamma_dist(2.5, 0.8),
            ServiceDist::bimodal(0.15, (0.1, 10.0), (5.0, 1.0)),
            ServiceDist::empirical(vec![1.0, 2.0, 3.0, 5.0]),
        ] {
            let sampler = Sampler::compile(&dist);
            let mut plain = vec![0.0; 100];
            let mut reduced = vec![0.0; 100];
            let mut rng = Pcg64::new(13);
            sampler.fill(&mut rng, &mut plain);

            let mut rng = Pcg64::new(13);
            let mode = sampler.fill_antithetic(&mut rng, &mut reduced);
            assert_eq!(mode, FillMode::Plain, "{}", dist.label());
            for (a, b) in plain.iter().zip(&reduced) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", dist.label());
            }

            let mut rng = Pcg64::new(13);
            let mode = sampler.fill_stratified(&mut rng, &mut reduced);
            assert_eq!(mode, FillMode::Plain, "{}", dist.label());
            for (a, b) in plain.iter().zip(&reduced) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", dist.label());
            }
        }
    }

    #[test]
    fn stratified_slots_land_in_their_strata() {
        // Pareto CDF F(x) = 1 − (σ/x)^α recovers the stratum position:
        // slot i of an n-slot fill must have F(x_i) ∈ [i/n, (i+1)/n).
        let (sigma, alpha) = (1.0, 3.0);
        let sampler = Sampler::compile(&ServiceDist::pareto(sigma, alpha));
        let mut rng = Pcg64::new(19);
        let mut buf = vec![0.0; 128];
        let mode = sampler.fill_stratified(&mut rng, &mut buf);
        assert_eq!(mode, FillMode::Stratified);
        let n = buf.len() as f64;
        for (i, &x) in buf.iter().enumerate() {
            let f = 1.0 - (sigma / x).powf(alpha);
            let (lo, hi) = (i as f64 / n, (i as f64 + 1.0) / n);
            assert!(f >= lo - 1e-12 && f < hi + 1e-12, "slot {i}: {f}");
        }
    }

    #[test]
    fn antithetic_reduces_variance_of_the_mean() {
        // Mean-of-Exp estimation: antithetic pairs are negatively
        // correlated, so block means must spread less than independent
        // block means. Deterministic seeds; generous margin.
        let sampler = Sampler::compile(&ServiceDist::exp(1.0));
        let spread = |fill_antithetic: bool| {
            let mut rng = Pcg64::new(101);
            let mut buf = vec![0.0; 512];
            let mut means = Vec::new();
            for _ in 0..200 {
                if fill_antithetic {
                    sampler.fill_antithetic(&mut rng, &mut buf);
                } else {
                    sampler.fill(&mut rng, &mut buf);
                }
                let mut s = 0.0;
                for &x in &buf {
                    s += x;
                }
                means.push(s / buf.len() as f64);
            }
            let m = means.iter().sum::<f64>() / means.len() as f64;
            means.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
                / (means.len() - 1) as f64
        };
        let (v_plain, v_anti) = (spread(false), spread(true));
        assert!(
            v_anti < 0.7 * v_plain,
            "antithetic {v_anti} vs plain {v_plain}"
        );
    }
        let fast = (0.1, 10.0);
        let slow = (5.0, 1.0);
        let all_fast = Sampler::compile(&ServiceDist::bimodal(0.0, fast, slow));
        let mut rng = Pcg64::new(9);
        let mut buf = vec![0.0; 2_000];
        all_fast.fill(&mut rng, &mut buf);
        // fast component is SExp(0.1, 10): mean 0.2, support >= 0.1
        assert!(buf.iter().all(|&x| x >= 0.1));
        let mean = buf.iter().sum::<f64>() / buf.len() as f64;
        assert!((mean - 0.2).abs() < 0.02, "{mean}");
    }
}
