//! Sharded, resumable trace-sweep engine — the §VII experiment as a
//! batch system.
//!
//! The paper's empirical pipeline (extract per-job service times from a
//! cluster trace, bootstrap an empirical τ, sweep the redundancy level,
//! read off the optimum) is a *workload*, not a figure: planner
//! searches, regression gates, and cluster-scale what-if studies all
//! ask the same grid of questions. This module turns that grid into an
//! engine:
//!
//! * [`SweepSpec`] ([`spec`]) — a JSON spec naming the workload (trace
//!   file or generator parameters) and the axes: jobs × batch counts ×
//!   crash levels × offered loads (the optional open-system `arrivals`
//!   axis) × replication policies × backends.
//! * [`ScenarioSet`] ([`grid`]) — the deterministic expansion of a spec
//!   into content-addressed cases: each case's key is a stable hash of
//!   scenario + estimator config + seed, and doubles as its cache
//!   address and RNG stream selector, so **an estimate depends only on
//!   what is asked, never on grid position or sharding**.
//! * [`run`] / [`run_spec`] ([`runner`]) — shard the grid into bounded
//!   units, fan each shard's Monte-Carlo cases across the persistent
//!   [`crate::sim::pool::WorkerPool`] in one batched call, stream
//!   records to a JSONL [`store`] and an on-disk estimate cache.
//!   A killed run resumes exactly where it stopped (the store validates
//!   its prefix and truncates at most one partial line) and re-runs are
//!   incremental (cache hits are never re-evaluated); resumed output is
//!   **byte-identical** to an uninterrupted run.
//! * [`merge`](mod@merge) — the multi-process path: `--shard K/M` runs
//!   write per-shard stores (own file, own cache, sweep-identity
//!   header), and [`merge()`](fn@merge) reassembles the canonical
//!   grid-ordered store **byte-identical to a single-process run** —
//!   possible because each case's RNG stream derives from its content
//!   key, never from where or when it ran. Long-lived caches are
//!   compacted with [`store::EstimateCache::gc`], and
//!   `sweep-merge --allow-partial` ([`merge_partial`]) publishes the
//!   covered prefix of a still-running sweep plus a machine-readable
//!   list of the uncovered ranges.
//! * [`report`] — the replication-gain report: per-job optimal
//!   redundancy, speedup over the B = N baseline, and the
//!   E\[T\]-vs-predictability (and, on the policy axis, cost)
//!   trade-off, with tail classes from [`crate::dist::TailFit`].
//!   [`gain_report_from_records`] builds the same rows straight from
//!   parsed store lines (`sweep-merge --report-only`), with no spec
//!   re-expansion or trace re-generation.
//!
//! `experiments::traces_exp` (Figs. 11–13), the `replica sweep --spec`
//! CLI command (plus `replica sweep-merge`), and CI's regression
//! artifacts — including the `sweep-shard-determinism` job that
//! byte-compares a 4-process run against a single-process one — are
//! all thin layers over this one engine.

pub mod grid;
pub mod merge;
pub mod report;
pub mod runner;
pub mod spec;
pub mod store;

pub use grid::{case_key, case_key_auto, case_key_open, shard_range, ScenarioSet, SweepCase};
pub use merge::{
    merge, merge_partial, merge_shards, shard_path, MergeReport, MissingRange,
    PartialMergeReport,
};
pub use report::{
    gain_report, gain_report_from_records, gain_table, headline_speedup, parse_report_line,
    GainRow, RecordRow,
};
pub use runner::{evaluate_cases, run, run_spec, CaseResult, RunConfig};
pub use spec::{
    ArrivalsSpec, AutoReps, Backend, SweepSpec, Workload, DEFAULT_SHARD_SIZE,
    DEFAULT_SWEEP_REPS,
};
pub use store::{CacheGc, CaseOutcome, EstimateCache, StoredEstimate};
