//! The sharded, resumable sweep engine.
//!
//! Execution shape: the grid is processed in shards of
//! [`SweepSpec::shard_size`](crate::sweep::SweepSpec) cases. Each
//! shard's uncached Monte-Carlo-bound cases go through **one** pooled
//! `MonteCarlo::run_batch` call (the same two-level
//! scenario×replication-chunk fan-out every batch entry point uses), so
//! the persistent worker pool stays saturated across the whole shard;
//! closed-form cases are answered inline. Finished outcomes are
//! appended to the estimate cache, then the shard's records are
//! appended to the result store in grid order and both files are
//! flushed — the durability checkpoint a kill can interrupt by at most
//! one partial line.
//!
//! Because every case's estimate depends only on its content key (its
//! RNG stream is `substream(spec.seed, key)`), shard boundaries,
//! resume points, pool width, and cache hits can change *when* a value
//! is computed but never *what* it is — so an interrupted-and-resumed
//! run writes byte-identical output to an uninterrupted one.
//!
//! The same property scales past one process: with
//! [`RunConfig::shard`] set to `(k, m)`, a process evaluates only the
//! k-th contiguous slice of the grid and streams it to a private
//! per-shard store (+ per-shard cache), so m machines can split a
//! sweep with no shared files and no coordination beyond agreeing on
//! the spec. [`crate::sweep::merge()`](fn@crate::sweep::merge) then
//! reassembles the canonical store, byte-identical to a
//! single-process run.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::eval::{Analytic, Estimator, MonteCarlo, OpenSystem, Scenario};
use crate::sweep::grid::{ScenarioSet, SweepCase};
use crate::sweep::merge::shard_path;
use crate::sweep::spec::{Backend, SweepSpec, DEFAULT_SHARD_SIZE};
use crate::sweep::store::{
    render_record, CaseOutcome, EstimateCache, ResultStore, ShardHeader, StoredEstimate,
};
use crate::traces::Trace;
use crate::util::error::{Error, Result};

/// Engine configuration (everything that is *not* part of a case's
/// content: where to persist, how to shard, how wide to fan out).
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Result store path (`None` = in-memory run, nothing persisted).
    /// For process-sharded runs this is the *canonical* path; the
    /// process actually writes [`shard_path`]`(out, k, m)`.
    pub out: Option<PathBuf>,
    /// Estimate-cache path (`None` = in-memory cache).
    pub cache: Option<PathBuf>,
    /// Cases per shard (one pooled batch + one store flush each).
    pub shard_size: usize,
    /// Stop after this many shards (budgeted/partial runs; resume picks
    /// up where the run stopped).
    pub limit_shards: Option<usize>,
    /// Per-scenario Monte-Carlo fan-out cap (0 = pool width).
    pub threads: usize,
    /// Process-level shard selector `(k, m)`: evaluate only the k-th of
    /// m contiguous grid slices and persist to a per-shard store with a
    /// sweep-identity header, so m processes can run one sweep with no
    /// shared files. Merge the shard stores back into the canonical
    /// store with [`crate::sweep::merge()`](fn@crate::sweep::merge).
    /// `None` = the whole grid.
    pub shard: Option<(usize, usize)>,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            out: None,
            cache: None,
            shard_size: DEFAULT_SHARD_SIZE,
            limit_shards: None,
            threads: 0,
            shard: None,
        }
    }
}

impl RunConfig {
    /// Persisted run: results to `out`, cache derived as
    /// `<out>.cache.jsonl` unless set explicitly.
    pub fn persisted(out: PathBuf) -> RunConfig {
        let cache = PathBuf::from(format!("{}.cache.jsonl", out.display()));
        RunConfig { out: Some(out), cache: Some(cache), ..RunConfig::default() }
    }

    /// Persisted single-shard run `k` of `m`: the store is the
    /// per-shard file derived from the canonical `out` path, and the
    /// cache sits next to it (per-shard too, so concurrent shard
    /// processes never share a writable file).
    pub fn sharded(out: PathBuf, k: usize, m: usize) -> RunConfig {
        let store = shard_path(&out, k, m);
        let cache = PathBuf::from(format!("{}.cache.jsonl", store.display()));
        RunConfig {
            out: Some(out),
            cache: Some(cache),
            shard: Some((k, m)),
            ..RunConfig::default()
        }
    }
}

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub case: SweepCase,
    pub outcome: CaseOutcome,
}

/// Run (or resume) a sweep. Returns the results of every case
/// evaluated so far in grid order — the full grid (or, for a
/// process-sharded run, the full process slice) unless `limit_shards`
/// stopped the run early.
pub fn run(set: &ScenarioSet, cfg: &RunConfig) -> Result<Vec<CaseResult>> {
    let cases: &[SweepCase] = match cfg.shard {
        Some((k, m)) => set.shard(k, m)?,
        None => &set.cases,
    };
    let expected: Vec<u64> = cases.iter().map(|case| case.key).collect();
    let (mut store, prefix) = match &cfg.out {
        Some(path) => {
            let (store, prefix) = match cfg.shard {
                Some((k, m)) => {
                    let header = ShardHeader {
                        shard: k,
                        of: m,
                        cases: cases.len(),
                        sweep_key: set.sweep_key(),
                    };
                    ResultStore::open_shard(&shard_path(path, k, m), header, &expected)?
                }
                None => ResultStore::open(path, &expected)?,
            };
            (Some(store), prefix)
        }
        None => (None, Vec::new()),
    };
    let mut cache = match &cfg.cache {
        Some(path) => EstimateCache::open(path)?,
        None => EstimateCache::in_memory(),
    };
    let mut results: Vec<CaseResult> = cases
        .iter()
        .zip(prefix)
        .map(|(case, outcome)| CaseResult { case: case.clone(), outcome })
        .collect();

    let mut shards_done = 0usize;
    while results.len() < cases.len() {
        if cfg.limit_shards.is_some_and(|limit| shards_done >= limit) {
            break;
        }
        let lo = results.len();
        let hi = (lo + cfg.shard_size.max(1)).min(cases.len());
        let shard = &cases[lo..hi];
        let outcomes = evaluate_cases(shard, &mut cache, cfg.threads)?;
        for (case, outcome) in shard.iter().zip(&outcomes) {
            if let Some(store) = &mut store {
                store.append(&render_record(case, outcome))?;
            }
        }
        cache.flush()?;
        if let Some(store) = &mut store {
            store.flush()?;
        }
        results.extend(
            shard
                .iter()
                .zip(outcomes)
                .map(|(case, outcome)| CaseResult { case: case.clone(), outcome }),
        );
        shards_done += 1;
    }
    Ok(results)
}

/// Convenience: materialize the spec's workload, expand the grid, run.
/// Returns the trace alongside the results so reports can classify
/// tails without re-deriving it.
pub fn run_spec(spec: &SweepSpec, cfg: &RunConfig) -> Result<(Trace, Vec<CaseResult>)> {
    let trace = spec.load_trace()?;
    let set = ScenarioSet::from_trace(&trace, spec)?;
    let results = run(&set, cfg)?;
    Ok((trace, results))
}

/// Evaluate a contiguous run of cases: cache hits are reused,
/// closed-form cases are answered inline, and every Monte-Carlo-bound
/// case goes through one pooled batch. Per-case problems (no closed
/// form, an infeasible hand-built scenario) become
/// [`CaseOutcome::Error`] records instead of poisoning the batch;
/// all-failed estimates likewise surface per scenario via their
/// `all_failed` flag.
///
/// This is the single evaluation path shared by the in-process engine
/// ([`run`], per shard) and the cluster worker
/// ([`crate::cluster::client`], per leased slice) — both produce
/// outcomes that depend only on each case's content key.
pub fn evaluate_cases(
    shard: &[SweepCase],
    cache: &mut EstimateCache,
    threads: usize,
) -> Result<Vec<CaseOutcome>> {
    let mut outcomes: Vec<Option<CaseOutcome>> = vec![None; shard.len()];
    let mut fresh: Vec<usize> = Vec::new();
    // mc-bound case indices, grouped by replication budget (a single
    // spec yields one group; hand-built sets may mix)
    let mut mc_groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, case) in shard.iter().enumerate() {
        if let Some(hit) = cache.get(case.key) {
            outcomes[i] = Some(hit.clone());
            continue;
        }
        fresh.push(i);
        if case.arrivals.is_some() {
            // Open-system cases fan their replications across the pool
            // inside the estimator, so each case is one pooled call —
            // same saturation shape as the closed-system batch below.
            outcomes[i] = Some(open_outcome(case, threads));
            continue;
        }
        let analytic = case.backend == Backend::Analytic
            || (case.backend == Backend::Auto && Analytic::supports(&case.scenario));
        if analytic {
            outcomes[i] = Some(analytic_outcome(&case.scenario));
        } else if case.auto.is_some() {
            // Precision-targeted cases stop at their own realized
            // counts, so each runs its private doubling loop (every
            // wave is still one pooled call).
            outcomes[i] = Some(auto_outcome(case, threads));
        } else {
            mc_groups.entry(case.reps.max(1)).or_default().push(i);
        }
    }
    for (reps, idxs) in mc_groups {
        let mc = MonteCarlo { reps, seed: 0, threads };
        let items: Vec<(&Scenario, u64)> =
            idxs.iter().map(|&i| (&shard[i].scenario, shard[i].stream_seed)).collect();
        match mc.run_batch(&items) {
            Ok(estimates) => {
                for (&i, est) in idxs.iter().zip(&estimates) {
                    outcomes[i] = Some(CaseOutcome::Ok(StoredEstimate::of(
                        est,
                        shard[i].scenario.replication,
                    )));
                }
            }
            Err(_) => {
                // One bad case (e.g. an infeasible hand-built scenario)
                // aborted the batch. Isolate each case so the error
                // lands on the scenario that owns it — every item's
                // stream depends only on its own key, so the healthy
                // cases' estimates are unchanged by the re-run.
                for &i in &idxs {
                    let item = [(&shard[i].scenario, shard[i].stream_seed)];
                    outcomes[i] = Some(match mc.run_batch(&item) {
                        Ok(mut v) => match v.pop() {
                            Some(est) => CaseOutcome::Ok(StoredEstimate::of(
                                &est,
                                shard[i].scenario.replication,
                            )),
                            None => CaseOutcome::Error(
                                "one item in, zero estimates out".to_string(),
                            ),
                        },
                        Err(e) => CaseOutcome::Error(e.to_string()),
                    });
                }
            }
        }
    }
    for &i in &fresh {
        let outcome = outcomes[i].clone().ok_or_else(|| {
            Error::Internal(format!("fresh case {i} was never evaluated"))
        })?;
        cache.insert(shard[i].key, outcome)?;
    }
    outcomes
        .into_iter()
        .enumerate()
        .map(|(i, o)| {
            o.ok_or_else(|| Error::Internal(format!("case {i} was never evaluated")))
        })
        .collect()
}

fn analytic_outcome(scenario: &Scenario) -> CaseOutcome {
    match Analytic.evaluate(scenario) {
        Ok(est) => CaseOutcome::Ok(StoredEstimate::of(&est, scenario.replication)),
        Err(e) => CaseOutcome::Error(e.to_string()),
    }
}

/// Evaluate one precision-targeted closed-system case
/// (`reps: {"auto": ...}`): double the replication count until the ci95
/// half-width reaches the case's `eps` or its `max` ceiling. The
/// realized count lands in the record's `replications` field.
fn auto_outcome(case: &SweepCase, threads: usize) -> CaseOutcome {
    let Some(auto) = case.auto else {
        return CaseOutcome::Error("auto_outcome needs a 'reps: auto' target".into());
    };
    let mc = MonteCarlo { reps: auto.max, seed: 0, threads };
    match mc.until_ci95(&case.scenario, case.stream_seed, auto.eps, auto.max) {
        Ok(est) => CaseOutcome::Ok(StoredEstimate::of(&est, case.scenario.replication)),
        Err(e) => CaseOutcome::Error(e.to_string()),
    }
}

/// Evaluate one open-system case. The RNG stream comes from the case's
/// content key (`stream_seed`), exactly like the closed-system batch
/// path, so open estimates are equally independent of grid position,
/// sharding, and pool width.
fn open_outcome(case: &SweepCase, threads: usize) -> CaseOutcome {
    let Some(open) = case.arrivals else {
        return CaseOutcome::Error("open_outcome needs an 'arrivals' operating point".into());
    };
    let os = OpenSystem { reps: case.reps.max(1), seed: 0, threads, open };
    let evaluated = match case.auto {
        Some(auto) => os.until_ci95(&case.scenario, case.stream_seed, auto.eps, auto.max),
        None => os.evaluate_open_seeded(&case.scenario, case.stream_seed),
    };
    match evaluated {
        Ok(oe) => CaseOutcome::Ok(StoredEstimate::of_open(&oe, case.scenario.replication)),
        Err(e) => CaseOutcome::Error(e.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::grid::ScenarioSet;
    use crate::traces::GeneratorConfig;

    fn small_set(reps: usize) -> (Trace, ScenarioSet) {
        let trace = GeneratorConfig::paper_workload(12, 3).generate();
        let mut spec = SweepSpec::for_trace();
        spec.reps = reps;
        spec.seed = 5;
        spec.jobs = Some(vec![1, 6]);
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        (trace, set)
    }

    #[test]
    fn in_memory_run_covers_the_grid() {
        let (_, set) = small_set(300);
        let results = run(&set, &RunConfig::default()).unwrap();
        assert_eq!(results.len(), set.len());
        for r in &results {
            match &r.outcome {
                CaseOutcome::Ok(e) => {
                    assert_eq!(e.via, "monte-carlo");
                    assert_eq!(e.replications, 300);
                    assert!(e.mean.is_finite());
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn results_are_independent_of_shard_size() {
        let (_, set) = small_set(200);
        let a = run(&set, &RunConfig { shard_size: 1, ..RunConfig::default() }).unwrap();
        let b = run(&set, &RunConfig { shard_size: 7, ..RunConfig::default() }).unwrap();
        let c = run(&set, &RunConfig { shard_size: 1000, ..RunConfig::default() }).unwrap();
        for ((x, y), z) in a.iter().zip(&b).zip(&c) {
            let (CaseOutcome::Ok(x), CaseOutcome::Ok(y), CaseOutcome::Ok(z)) =
                (&x.outcome, &y.outcome, &z.outcome)
            else {
                panic!("unexpected error outcome");
            };
            assert_eq!(x.mean.to_bits(), y.mean.to_bits());
            assert_eq!(y.mean.to_bits(), z.mean.to_bits());
            assert_eq!(x.p99.to_bits(), z.p99.to_bits());
        }
    }

    #[test]
    fn limit_shards_stops_early() {
        let (_, set) = small_set(100);
        let cfg = RunConfig { shard_size: 5, limit_shards: Some(1), ..RunConfig::default() };
        let partial = run(&set, &cfg).unwrap();
        assert_eq!(partial.len(), 5);
    }

    #[test]
    fn analytic_error_does_not_poison_the_shard() {
        // empirical τ has no closed form: the analytic backend yields
        // per-case Error records while mc cases in the same shard
        // succeed
        let trace = GeneratorConfig::paper_workload(12, 3).generate();
        let mut spec = SweepSpec::for_trace();
        spec.reps = 100;
        spec.jobs = Some(vec![1]);
        spec.backends = vec![Backend::Analytic, Backend::MonteCarlo];
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        let results = run(&set, &RunConfig::default()).unwrap();
        assert_eq!(results.len(), 12);
        for r in &results {
            match (r.case.backend, &r.outcome) {
                (Backend::Analytic, CaseOutcome::Error(msg)) => {
                    assert!(msg.contains("no closed form"), "{msg}");
                }
                (Backend::MonteCarlo, CaseOutcome::Ok(e)) => {
                    assert!(e.mean.is_finite());
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn auto_routes_like_the_auto_estimator() {
        // closed-form τ: auto answers analytically (replications = 0)
        let trace = GeneratorConfig::paper_workload(12, 3).generate();
        let mut spec = SweepSpec::for_trace();
        spec.reps = 100;
        spec.jobs = Some(vec![2]);
        spec.backends = vec![Backend::Auto];
        let mut set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        // swap the empirical τ for a closed-form family, keeping keys
        // consistent is irrelevant here (in-memory, no cache reuse)
        for case in &mut set.cases {
            case.scenario.tau = crate::dist::ServiceDist::exp(1.0).into();
        }
        let results = run(&set, &RunConfig::default()).unwrap();
        for r in &results {
            match &r.outcome {
                CaseOutcome::Ok(e) => {
                    assert_eq!(e.via, "analytic");
                    assert_eq!(e.replications, 0);
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn process_shard_runs_cover_their_slice_identically() {
        let (_, set) = small_set(150);
        let full = run(&set, &RunConfig::default()).unwrap();
        let mut sharded = Vec::new();
        for k in 0..3 {
            let cfg = RunConfig { shard: Some((k, 3)), ..RunConfig::default() };
            sharded.extend(run(&set, &cfg).unwrap());
        }
        // concatenated shard slices = the whole grid, bit-identical
        assert_eq!(sharded.len(), full.len());
        for (a, b) in full.iter().zip(&sharded) {
            assert_eq!(a.case.key, b.case.key);
            let (CaseOutcome::Ok(a), CaseOutcome::Ok(b)) = (&a.outcome, &b.outcome) else {
                panic!("unexpected error outcome");
            };
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.p99.to_bits(), b.p99.to_bits());
        }
        let bad = RunConfig { shard: Some((3, 3)), ..RunConfig::default() };
        assert!(run(&set, &bad).is_err());
    }

    #[test]
    fn timed_policy_cases_flow_through_the_engine() {
        use crate::sim::policy::ReplicationPolicy;
        let trace = GeneratorConfig::paper_workload(12, 3).generate();
        let mut spec = SweepSpec::for_trace();
        spec.reps = 200;
        spec.seed = 5;
        spec.jobs = Some(vec![1]);
        spec.batches = Some(vec![3]);
        spec.policies = vec![
            ReplicationPolicy::Upfront,
            ReplicationPolicy::SpeculativeAt { t: 2.0 },
            ReplicationPolicy::RelaunchAt { t: 2.0 },
        ];
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        let results = run(&set, &RunConfig::default()).unwrap();
        assert_eq!(results.len(), 3);
        for r in &results {
            let CaseOutcome::Ok(e) = &r.outcome else { panic!("{:?}", r.outcome) };
            assert!(e.mean.is_finite());
            assert_eq!(e.policy, r.case.scenario.replication);
            if e.policy.is_upfront() {
                assert!(e.cost.is_nan(), "up-front records never persist cost");
            } else {
                assert!(e.cost.is_finite() && e.cost > 0.0);
            }
            // the persisted line reproduces the in-memory record
            let line = render_record(&r.case, &r.outcome);
            let (key, back) = crate::sweep::store::parse_record(&line).unwrap();
            assert_eq!(key, r.case.key);
            assert_eq!(render_record(&r.case, &back), line);
        }
        // shard-size independence holds on the policy axis too
        let again =
            run(&set, &RunConfig { shard_size: 1, ..RunConfig::default() }).unwrap();
        for (a, b) in results.iter().zip(&again) {
            let (CaseOutcome::Ok(a), CaseOutcome::Ok(b)) = (&a.outcome, &b.outcome) else {
                panic!("unexpected error outcome");
            };
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.cost.to_bits(), b.cost.to_bits());
        }
    }

    #[test]
    fn open_system_cases_flow_through_the_engine() {
        use crate::sweep::spec::ArrivalsSpec;
        let trace = GeneratorConfig::paper_workload(12, 3).generate();
        let mut spec = SweepSpec::for_trace();
        spec.reps = 40;
        spec.seed = 5;
        spec.jobs = Some(vec![1]);
        spec.batches = Some(vec![1, 12]);
        spec.arrivals = Some(ArrivalsSpec { rho: vec![0.3], jobs: 40, warmup: 10 });
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        let results = run(&set, &RunConfig::default()).unwrap();
        assert_eq!(results.len(), 2);
        for r in &results {
            let CaseOutcome::Ok(e) = &r.outcome else { panic!("{:?}", r.outcome) };
            assert!(e.mean.is_finite() && e.mean > 0.0);
            assert!(e.utilization > 0.0 && e.utilization <= 1.0);
            assert!(e.cost.is_finite() && e.cost > 0.0, "open records track cost");
            // the persisted line carries the operating point and
            // reproduces the in-memory record exactly
            let line = render_record(&r.case, &r.outcome);
            assert!(line.contains("\"rho\":0.3"), "{line}");
            assert!(line.contains("\"utilization\":"), "{line}");
            let (key, back) = crate::sweep::store::parse_record(&line).unwrap();
            assert_eq!(key, r.case.key);
            assert_eq!(render_record(&r.case, &back), line);
        }
        // shard-size independence holds on the open axis too
        let again =
            run(&set, &RunConfig { shard_size: 1, ..RunConfig::default() }).unwrap();
        for (a, b) in results.iter().zip(&again) {
            let (CaseOutcome::Ok(a), CaseOutcome::Ok(b)) = (&a.outcome, &b.outcome) else {
                panic!("unexpected error outcome");
            };
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        }
    }

    #[test]
    fn auto_reps_cases_stop_early_and_stay_deterministic() {
        use crate::sweep::spec::AutoReps;
        let trace = GeneratorConfig::paper_workload(12, 3).generate();
        let mut spec = SweepSpec::for_trace();
        spec.jobs = Some(vec![1]);
        spec.seed = 5;
        spec.reps = 4096;
        spec.auto_reps = Some(AutoReps { eps: 0.2, max: 4096 });
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        let results = run(&set, &RunConfig::default()).unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            let CaseOutcome::Ok(e) = &r.outcome else { panic!("{:?}", r.outcome) };
            // the realized count is persisted, honors the ceiling, and
            // only stops short of it once the target is met
            assert!(e.replications >= 1 && e.replications <= 4096);
            assert!(e.ci95 <= 0.2 || e.replications == 4096, "{e:?}");
            // exactly the fixed-budget estimate at the realized count
            let fixed = MonteCarlo { reps: e.replications, seed: 0, threads: 0 }
                .run_batch(&[(&r.case.scenario, r.case.stream_seed)])
                .unwrap()
                .pop()
                .unwrap();
            assert_eq!(e.mean.to_bits(), fixed.mean.to_bits());
            assert_eq!(e.ci95.to_bits(), fixed.ci95.to_bits());
        }
        // the target must bite somewhere, or this test is vacuous
        assert!(results.iter().any(
            |r| matches!(&r.outcome, CaseOutcome::Ok(e) if e.replications < 4096)
        ));
        // realized counts and estimates are independent of shard size
        // and pool width
        let again = run(
            &set,
            &RunConfig { shard_size: 2, threads: 4, ..RunConfig::default() },
        )
        .unwrap();
        for (a, b) in results.iter().zip(&again) {
            let (CaseOutcome::Ok(a), CaseOutcome::Ok(b)) = (&a.outcome, &b.outcome) else {
                panic!("unexpected error outcome");
            };
            assert_eq!(a.replications, b.replications);
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.ci95.to_bits(), b.ci95.to_bits());
        }
    }

    #[test]
    fn auto_reps_open_cases_flow_through_the_engine() {
        use crate::sweep::spec::{ArrivalsSpec, AutoReps};
        let trace = GeneratorConfig::paper_workload(12, 3).generate();
        let mut spec = SweepSpec::for_trace();
        spec.jobs = Some(vec![1]);
        spec.batches = Some(vec![1, 12]);
        spec.seed = 5;
        spec.reps = 64;
        spec.auto_reps = Some(AutoReps { eps: 0.5, max: 64 });
        spec.arrivals = Some(ArrivalsSpec { rho: vec![0.3], jobs: 40, warmup: 10 });
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        let results = run(&set, &RunConfig::default()).unwrap();
        assert_eq!(results.len(), 2);
        for r in &results {
            let CaseOutcome::Ok(e) = &r.outcome else { panic!("{:?}", r.outcome) };
            assert!(e.replications >= 1 && e.replications <= 64);
            assert!(e.ci95 <= 0.5 || e.replications == 64, "{e:?}");
            assert!(e.utilization > 0.0, "open auto records keep utilization");
            // exactly the fixed-budget open estimate at that count
            let os = OpenSystem {
                reps: e.replications,
                seed: 0,
                threads: 0,
                open: r.case.arrivals.unwrap(),
            };
            let fixed =
                os.evaluate_open_seeded(&r.case.scenario, r.case.stream_seed).unwrap();
            assert_eq!(e.mean.to_bits(), fixed.estimate.mean.to_bits());
            assert_eq!(e.utilization.to_bits(), fixed.utilization.to_bits());
        }
    }

    #[test]
    fn cache_hits_skip_reevaluation() {
        let (_, set) = small_set(150);
        let dir = std::env::temp_dir().join("replica_sweep_runner_cache");
        std::fs::create_dir_all(&dir).unwrap();
        let cache_path = dir.join("cache.jsonl");
        std::fs::remove_file(&cache_path).ok();
        let cfg = RunConfig {
            cache: Some(cache_path.clone()),
            shard_size: 4,
            ..RunConfig::default()
        };
        let a = run(&set, &cfg).unwrap();
        let lines_after_first = std::fs::read_to_string(&cache_path).unwrap();
        let b = run(&set, &cfg).unwrap();
        let lines_after_second = std::fs::read_to_string(&cache_path).unwrap();
        assert_eq!(
            lines_after_first, lines_after_second,
            "second run must be served entirely from cache"
        );
        for (x, y) in a.iter().zip(&b) {
            let (CaseOutcome::Ok(x), CaseOutcome::Ok(y)) = (&x.outcome, &y.outcome) else {
                panic!("unexpected error outcome");
            };
            assert_eq!(x.mean.to_bits(), y.mean.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
