//! Grid expansion and content addressing.
//!
//! A [`ScenarioSet`] is the deterministic expansion of a [`SweepSpec`]
//! over a trace: `jobs × batch counts × crash levels × offered loads ×
//! replication policies × backends`, in that nesting order (a
//! single-policy `["upfront"]` axis with no `arrivals` axis reproduces
//! the pre-policy order exactly). Each
//! case carries a **content key** — a stable
//! 64-bit hash of everything that determines its estimate (scenario,
//! estimator configuration, spec seed) — which is simultaneously:
//!
//! * the cache address (same key ⇒ same estimate, by the determinism
//!   contract of [`crate::eval::MonteCarlo`]),
//! * the resume checkpoint identity (the result store validates its
//!   prefix against the expected key sequence),
//! * the RNG stream selector (`stream_seed = substream(spec.seed, key)`),
//!   so an estimate depends only on *what* is asked, never on where the
//!   case sits in the grid or how the grid is sharded.

use std::ops::Range;
use std::sync::Arc;

use crate::batching::{operating_points, Policy};
use crate::dist::ServiceDist;
use crate::eval::{substream, OpenConfig, Scenario};
use crate::sim::job::FailureModel;
use crate::sim::policy::ReplicationPolicy;
use crate::sweep::spec::{AutoReps, Backend, SweepSpec};
use crate::traces::{JobAnalysis, Trace};
use crate::util::error::{Error, Result};

/// One point of the sweep grid.
#[derive(Clone, Debug)]
pub struct SweepCase {
    /// Position in the grid (also the result-store record index).
    pub index: usize,
    /// Trace job this scenario models.
    pub job_id: u64,
    /// The evaluation question (workers = the job's task count, batch
    /// count from the spec axis, τ = the job's empirical bootstrap).
    pub scenario: Scenario,
    /// Requested estimator backend.
    pub backend: Backend,
    /// Monte-Carlo replication budget (0 for the analytic backend).
    pub reps: usize,
    /// Content address of `(scenario, estimator config, spec seed)`.
    pub key: u64,
    /// RNG stream seed derived from the content key.
    pub stream_seed: u64,
    /// Open-system operating point (offered load + measurement window);
    /// `None` for closed-system cases. Part of the content address when
    /// present.
    pub arrivals: Option<OpenConfig>,
    /// Precision target (`reps: auto` specs): stop doubling at ci95
    /// half-width ≤ `eps` or at `max` (= `reps`) replications. `None`
    /// for fixed budgets and for analytic cases, which are exact. Part
    /// of the content address when present.
    pub auto: Option<AutoReps>,
}

impl SweepCase {
    /// Batch count of this case's (always balanced) scenario.
    pub fn batches(&self) -> usize {
        match self.scenario.policy {
            Policy::BalancedNonOverlapping { batches } => batches,
            _ => self.scenario.policy.batch_count(self.scenario.workers),
        }
    }

    /// Crash probability of the failure axis (0 = none).
    pub fn crash(&self) -> f64 {
        match self.scenario.failures {
            FailureModel::None => 0.0,
            FailureModel::Crash { p } => p,
            FailureModel::CrashRestart { p, .. } => p,
        }
    }

    /// The content key as the fixed-width hex string used in store
    /// records (u64 does not survive a JSON `Num` round trip intact).
    pub fn key_hex(&self) -> String {
        format!("{:016x}", self.key)
    }

    /// Offered load ρ of the open-system axis (`None` = closed system).
    pub fn rho(&self) -> Option<f64> {
        self.arrivals.map(|a| a.rho)
    }
}

/// The expanded, content-addressed scenario grid.
#[derive(Clone, Debug)]
pub struct ScenarioSet {
    pub cases: Vec<SweepCase>,
}

impl ScenarioSet {
    /// Expand `spec` over `trace`. Deterministic: same spec + same
    /// trace ⇒ the same cases with the same keys in the same order.
    pub fn from_trace(trace: &Trace, spec: &SweepSpec) -> Result<ScenarioSet> {
        let job_ids = match &spec.jobs {
            Some(ids) => ids.clone(),
            None => trace.job_ids(),
        };
        if job_ids.is_empty() {
            return Err(Error::Config("sweep grid has no jobs".into()));
        }
        // Open-system sweeps are Monte-Carlo only: the analytic backend
        // has no queueing model. The spec parser enforces this for JSON
        // specs; re-check here for programmatically built ones.
        if spec.arrivals.is_some() && spec.backends.iter().any(|&bk| bk != Backend::MonteCarlo)
        {
            return Err(Error::Config(
                "an 'arrivals' axis requires backends = [\"mc\"]".into(),
            ));
        }
        // The ρ axis: one closed-system pseudo-point when absent, so the
        // loop below stays uniform and closed grids expand unchanged.
        let rhos: Vec<Option<OpenConfig>> = match &spec.arrivals {
            None => vec![None],
            Some(a) => a
                .rho
                .iter()
                .map(|&rho| Some(OpenConfig { rho, jobs: a.jobs, warmup: a.warmup }))
                .collect(),
        };
        let mut cases = Vec::new();
        for &job_id in &job_ids {
            let analysis = JobAnalysis::of(trace, job_id).ok_or_else(|| {
                Error::Config(format!("job {job_id} has no completed tasks in the trace"))
            })?;
            let n = analysis.n_tasks;
            // One τ allocation per job, shared by every case via `Arc`:
            // an empirical bootstrap carries the job's full sample set
            // (~8 KB at 1000 tasks), and the job expands into
            // batches × crash × backends cases.
            let tau = Arc::new(analysis.service_dist());
            let batches: Vec<usize> = match &spec.batches {
                Some(bs) => {
                    for &b in bs {
                        if n % b != 0 {
                            return Err(Error::Config(format!(
                                "batch count {b} does not divide job {job_id}'s N={n}"
                            )));
                        }
                    }
                    bs.clone()
                }
                None => operating_points(n).into_iter().map(|op| op.batches).collect(),
            };
            for &b in &batches {
                for &p in &spec.crash {
                    let failures = if p == 0.0 {
                        FailureModel::None
                    } else {
                        FailureModel::Crash { p }
                    };
                    for arrivals in &rhos {
                        for &replication in &spec.policies {
                            if !replication.is_upfront() && p > 0.0 {
                                return Err(Error::Config(format!(
                                    "policy '{}' cannot be combined with failure \
                                     injection (crash={p}); timed policies are only \
                                     simulated without failures",
                                    replication.label()
                                )));
                            }
                            for &backend in &spec.backends {
                                let scenario = Scenario::balanced(n, b, Arc::clone(&tau))
                                    .with_failures(failures)
                                    .with_replication(replication);
                                let reps =
                                    if backend == Backend::Analytic { 0 } else { spec.reps };
                                // The analytic backend is exact, so a
                                // precision target neither changes its
                                // estimate nor belongs in its address.
                                let auto = if backend == Backend::Analytic {
                                    None
                                } else {
                                    spec.auto_reps
                                };
                                let key = case_key_auto(
                                    &scenario,
                                    backend,
                                    reps,
                                    spec.seed,
                                    arrivals.as_ref(),
                                    auto.as_ref(),
                                );
                                cases.push(SweepCase {
                                    index: cases.len(),
                                    job_id,
                                    scenario,
                                    backend,
                                    reps,
                                    key,
                                    stream_seed: substream(spec.seed, key),
                                    arrivals: *arrivals,
                                    auto,
                                });
                            }
                        }
                    }
                }
            }
        }
        Ok(ScenarioSet { cases })
    }

    /// Expand the divisor spectrum of one workload: a balanced
    /// Monte-Carlo case per feasible B, every case sharing `tau`
    /// through the same `Arc`. This is the grid
    /// [`crate::planner::plan_from_samples`] runs, and the engine-level
    /// equivalent of [`crate::eval::Estimator::sweep`].
    pub fn spectrum(
        job_id: u64,
        n: usize,
        tau: Arc<ServiceDist>,
        reps: usize,
        seed: u64,
    ) -> Result<ScenarioSet> {
        if n == 0 {
            return Err(Error::Config("spectrum needs a worker budget >= 1".into()));
        }
        if reps == 0 {
            return Err(Error::Config("spectrum needs reps >= 1".into()));
        }
        let mut cases = Vec::new();
        for op in operating_points(n) {
            let scenario = Scenario::balanced(n, op.batches, Arc::clone(&tau));
            let key = case_key(&scenario, Backend::MonteCarlo, reps, seed);
            cases.push(SweepCase {
                index: cases.len(),
                job_id,
                scenario,
                backend: Backend::MonteCarlo,
                reps,
                key,
                stream_seed: substream(seed, key),
                arrivals: None,
                auto: None,
            });
        }
        Ok(ScenarioSet { cases })
    }

    pub fn len(&self) -> usize {
        self.cases.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cases.is_empty()
    }

    /// The expected record-key sequence of a complete run.
    pub fn expected_keys(&self) -> Vec<u64> {
        self.cases.iter().map(|c| c.key).collect()
    }

    /// The cases of process-shard `k` of `m`: contiguous balanced
    /// blocks over the grid, sizes differing by at most one case.
    /// Deterministic, so independent processes agree on the partition
    /// without coordination.
    pub fn shard(&self, k: usize, m: usize) -> Result<&[SweepCase]> {
        if m == 0 || k >= m {
            return Err(Error::Config(format!(
                "invalid shard {k}/{m}: need M >= 1 and 0 <= K < M"
            )));
        }
        Ok(&self.cases[shard_range(self.cases.len(), k, m)])
    }

    /// Identity of the whole sweep: a stable hash over the case-key
    /// sequence. Two specs produce the same sweep key iff they expand
    /// to the same grid (and would write the same store); per-shard
    /// store files carry it in their header so a merge can refuse a
    /// shard that belongs to a different sweep.
    pub fn sweep_key(&self) -> u64 {
        let mut h = Fnv::new();
        h.write(b"replica-sweep-set-v1");
        h.write_u64(self.cases.len() as u64);
        for case in &self.cases {
            h.write_u64(case.key);
        }
        h.finish()
    }
}

/// Case-index range of contiguous shard `k` of `m` over `total` cases.
pub fn shard_range(total: usize, k: usize, m: usize) -> Range<usize> {
    (k * total / m)..((k + 1) * total / m)
}

/// Content-address one case: a stable FNV-1a hash over a canonical
/// encoding of the scenario (workers, policy, τ including every
/// empirical sample bit, failure model, replication policy), the
/// estimator configuration (backend, replication budget), and the spec
/// seed. Not a cryptographic hash — it only needs to separate the
/// cases of overlapping sweep specs.
pub fn case_key(scenario: &Scenario, backend: Backend, reps: usize, seed: u64) -> u64 {
    case_key_open(scenario, backend, reps, seed, None)
}

/// [`case_key`] extended with the open-system axis. Closed-system cases
/// (`open: None`) hash to exactly the old addresses; an operating point
/// extends the encoding only when present, following the same
/// append-only convention as the timed-replication bytes.
pub fn case_key_open(
    scenario: &Scenario,
    backend: Backend,
    reps: usize,
    seed: u64,
    open: Option<&OpenConfig>,
) -> u64 {
    case_key_auto(scenario, backend, reps, seed, open, None)
}

/// [`case_key_open`] extended with the precision-target axis. Fixed-reps
/// cases (`auto: None`) hash to exactly the old addresses; a target
/// extends the encoding only when present, following the same
/// append-only convention as the timed-replication and open-system
/// bytes.
pub fn case_key_auto(
    scenario: &Scenario,
    backend: Backend,
    reps: usize,
    seed: u64,
    open: Option<&OpenConfig>,
    auto: Option<&AutoReps>,
) -> u64 {
    let mut h = Fnv::new();
    h.write(b"replica-sweep-v1");
    h.write_u64(scenario.workers as u64);
    hash_policy(&mut h, &scenario.policy);
    hash_dist(&mut h, &scenario.tau);
    hash_failures(&mut h, scenario.failures);
    h.write(backend.name().as_bytes());
    h.write_u64(reps as u64);
    h.write_u64(seed);
    // The replication policy extends the encoding only when timed:
    // every pre-policy store addressed its (implicitly up-front) cases
    // without these bytes, and those addresses must not move.
    if !scenario.replication.is_upfront() {
        h.write(scenario.replication.name().as_bytes());
        if let Some(t) = scenario.replication.t() {
            h.write_f64(t);
        }
    }
    if let Some(open) = open {
        h.write(b"open");
        h.write_f64(open.rho);
        h.write_u64(open.jobs as u64);
        h.write_u64(open.warmup as u64);
    }
    if let Some(auto) = auto {
        h.write(b"auto");
        h.write_f64(auto.eps);
        h.write_u64(auto.max as u64);
    }
    h.finish()
}

/// FNV-1a, 64-bit: tiny, dependency-free, and stable across platforms
/// and releases (unlike `DefaultHasher`, whose algorithm is unspecified).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xCBF2_9CE4_8422_2325)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }

    fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }

    fn write_f64(&mut self, x: f64) {
        self.write_u64(x.to_bits());
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

fn hash_policy(h: &mut Fnv, policy: &Policy) {
    h.write(policy.name().as_bytes());
    match policy {
        Policy::BalancedNonOverlapping { batches }
        | Policy::RandomNonOverlapping { batches }
        | Policy::CyclicOverlapping { batches }
        | Policy::HybridOverlapping { batches } => h.write_u64(*batches as u64),
        Policy::UnbalancedNonOverlapping { assignment } => {
            h.write_u64(assignment.len() as u64);
            for &a in assignment {
                h.write_u64(a as u64);
            }
        }
    }
}

fn hash_dist(h: &mut Fnv, tau: &ServiceDist) {
    match tau {
        ServiceDist::Exp { mu } => {
            h.write(b"exp");
            h.write_f64(*mu);
        }
        ServiceDist::ShiftedExp { delta, mu } => {
            h.write(b"sexp");
            h.write_f64(*delta);
            h.write_f64(*mu);
        }
        ServiceDist::Pareto { sigma, alpha } => {
            h.write(b"pareto");
            h.write_f64(*sigma);
            h.write_f64(*alpha);
        }
        ServiceDist::Weibull { shape, scale } => {
            h.write(b"weibull");
            h.write_f64(*shape);
            h.write_f64(*scale);
        }
        ServiceDist::Gamma { shape, scale } => {
            h.write(b"gamma");
            h.write_f64(*shape);
            h.write_f64(*scale);
        }
        ServiceDist::Bimodal { p_slow, fast, slow } => {
            h.write(b"bimodal");
            h.write_f64(*p_slow);
            for (d, m) in [fast, slow] {
                h.write_f64(*d);
                h.write_f64(*m);
            }
        }
        ServiceDist::Empirical(e) => {
            h.write(b"empirical");
            h.write_u64(e.len() as u64);
            for &x in e.data() {
                h.write_f64(x);
            }
        }
    }
}

fn hash_failures(h: &mut Fnv, failures: FailureModel) {
    match failures {
        FailureModel::None => h.write(b"none"),
        FailureModel::Crash { p } => {
            h.write(b"crash");
            h.write_f64(p);
        }
        FailureModel::CrashRestart { p, delay } => {
            h.write(b"crash-restart");
            h.write_f64(p);
            h.write_f64(delay);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::spec::ArrivalsSpec;
    use crate::traces::GeneratorConfig;

    fn small_trace() -> Trace {
        GeneratorConfig::paper_workload(12, 3).generate()
    }

    fn spec() -> SweepSpec {
        let mut s = SweepSpec::for_trace();
        s.reps = 200;
        s.seed = 5;
        s
    }

    #[test]
    fn grid_expansion_is_deterministic_and_ordered() {
        let trace = small_trace();
        let a = ScenarioSet::from_trace(&trace, &spec()).unwrap();
        let b = ScenarioSet::from_trace(&trace, &spec()).unwrap();
        // 10 jobs x 6 divisors of 12 x 1 crash x 1 backend
        assert_eq!(a.len(), 60);
        assert_eq!(a.expected_keys(), b.expected_keys());
        for (i, c) in a.cases.iter().enumerate() {
            assert_eq!(c.index, i);
            assert_eq!(c.scenario.workers, 12);
        }
        // nesting order: job-major, then batches ascending
        assert_eq!(a.cases[0].job_id, 1);
        assert_eq!(a.cases[0].batches(), 1);
        assert_eq!(a.cases[5].batches(), 12);
        assert_eq!(a.cases[6].job_id, 2);
    }

    #[test]
    fn keys_are_content_addresses() {
        let trace = small_trace();
        let set = ScenarioSet::from_trace(&trace, &spec()).unwrap();
        // all distinct within a run
        let mut keys = set.expected_keys();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), set.len());
        // changing the estimator config changes every key
        let mut spec2 = spec();
        spec2.reps = 400;
        let set2 = ScenarioSet::from_trace(&trace, &spec2).unwrap();
        for (a, b) in set.cases.iter().zip(&set2.cases) {
            assert_ne!(a.key, b.key);
        }
        // changing the seed changes keys and streams
        let mut spec3 = spec();
        spec3.seed = 6;
        let set3 = ScenarioSet::from_trace(&trace, &spec3).unwrap();
        for (a, b) in set.cases.iter().zip(&set3.cases) {
            assert_ne!(a.key, b.key);
            assert_ne!(a.stream_seed, b.stream_seed);
        }
        // same spec ⇒ keys independent of grid position (subset sweep)
        let mut narrowed = spec();
        narrowed.jobs = Some(vec![7]);
        let sub = ScenarioSet::from_trace(&trace, &narrowed).unwrap();
        let full_job7: Vec<&SweepCase> =
            set.cases.iter().filter(|c| c.job_id == 7).collect();
        assert_eq!(sub.len(), full_job7.len());
        for (a, b) in sub.cases.iter().zip(full_job7) {
            assert_eq!(a.key, b.key, "keys must not depend on grid position");
        }
    }

    #[test]
    fn axes_multiply() {
        let trace = small_trace();
        let mut s = spec();
        s.jobs = Some(vec![1, 6]);
        s.batches = Some(vec![1, 4]);
        s.crash = vec![0.0, 0.3];
        s.backends = vec![Backend::MonteCarlo, Backend::Auto];
        let set = ScenarioSet::from_trace(&trace, &s).unwrap();
        assert_eq!(set.len(), 2 * 2 * 2 * 2);
        let c = &set.cases[3];
        assert_eq!((c.job_id, c.batches()), (1, 1));
        assert_eq!(c.crash(), 0.3);
        assert_eq!(c.backend, Backend::Auto);
        assert_eq!(c.key_hex().len(), 16);
    }

    #[test]
    fn policy_axis_multiplies_and_preserves_upfront_keys() {
        let trace = small_trace();
        let base = ScenarioSet::from_trace(&trace, &spec()).unwrap();
        let mut s = spec();
        s.policies = vec![
            ReplicationPolicy::Upfront,
            ReplicationPolicy::SpeculativeAt { t: 1.0 },
            ReplicationPolicy::RelaunchAt { t: 1.0 },
        ];
        let set = ScenarioSet::from_trace(&trace, &s).unwrap();
        assert_eq!(set.len(), base.len() * 3);
        // the up-front slice of the widened grid keeps the exact keys
        // of the single-policy grid: old stores stay addressable
        let upfront: Vec<u64> = set
            .cases
            .iter()
            .filter(|c| c.scenario.replication.is_upfront())
            .map(|c| c.key)
            .collect();
        assert_eq!(upfront, base.expected_keys());
        // timed policies with different t (and different policies at
        // the same t) address different estimates
        let mut keys = set.expected_keys();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), set.len());
        let mut s2 = spec();
        s2.policies = vec![ReplicationPolicy::SpeculativeAt { t: 2.0 }];
        let set2 = ScenarioSet::from_trace(&trace, &s2).unwrap();
        let spec1: Vec<&SweepCase> = set
            .cases
            .iter()
            .filter(|c| !c.scenario.replication.is_upfront())
            .collect();
        for (a, b) in spec1.iter().zip(&set2.cases) {
            assert_ne!(a.key, b.key, "t must be part of the content address");
        }
    }

    #[test]
    fn arrivals_axis_multiplies_and_preserves_closed_keys() {
        let trace = small_trace();
        let base = ScenarioSet::from_trace(&trace, &spec()).unwrap();
        let mut s = spec();
        s.arrivals =
            Some(ArrivalsSpec { rho: vec![0.2, 0.8], jobs: 100, warmup: 20 });
        let set = ScenarioSet::from_trace(&trace, &s).unwrap();
        assert_eq!(set.len(), base.len() * 2);
        // nesting: ρ varies fastest above policies, so consecutive
        // cases of one (job, B, crash) cell hold its two loads
        assert_eq!(set.cases[0].rho(), Some(0.2));
        assert_eq!(set.cases[1].rho(), Some(0.8));
        assert_eq!(base.cases[0].rho(), None);
        // open keys are distinct from each other AND from every
        // closed-system key: old stores stay addressable, new cells
        // never collide with them
        let mut keys = set.expected_keys();
        keys.extend(base.expected_keys());
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), set.len() + base.len());
        // the measurement window is part of the content address too
        let mut s2 = spec();
        s2.arrivals =
            Some(ArrivalsSpec { rho: vec![0.2, 0.8], jobs: 100, warmup: 21 });
        let set2 = ScenarioSet::from_trace(&trace, &s2).unwrap();
        for (a, b) in set.cases.iter().zip(&set2.cases) {
            assert_ne!(a.key, b.key, "warmup must be part of the address");
        }
    }

    #[test]
    fn arrivals_axis_rejects_non_mc_backends() {
        let trace = small_trace();
        let mut s = spec();
        s.arrivals = Some(ArrivalsSpec { rho: vec![0.5], jobs: 50, warmup: 10 });
        s.backends = vec![Backend::MonteCarlo, Backend::Auto];
        let err = ScenarioSet::from_trace(&trace, &s).unwrap_err();
        assert!(err.to_string().contains("arrivals"), "{err}");
        s.backends = vec![Backend::MonteCarlo];
        assert!(ScenarioSet::from_trace(&trace, &s).is_ok());
    }

    #[test]
    fn auto_reps_rekeys_mc_cases_but_not_analytic_ones() {
        let trace = small_trace();
        let mut s = spec();
        s.backends = vec![Backend::MonteCarlo, Backend::Analytic, Backend::Auto];
        let base = ScenarioSet::from_trace(&trace, &s).unwrap();
        let mut s2 = s.clone();
        s2.reps = 200; // == base ceiling, so only the auto bytes differ
        s2.auto_reps = Some(AutoReps { eps: 0.05, max: 200 });
        let set = ScenarioSet::from_trace(&trace, &s2).unwrap();
        assert_eq!(set.len(), base.len());
        for (a, b) in base.cases.iter().zip(&set.cases) {
            if b.backend == Backend::Analytic {
                // exact estimates: a precision target must not move
                // analytic addresses (their cache entries stay valid)
                assert_eq!(a.key, b.key);
                assert_eq!(b.auto, None);
            } else {
                assert_ne!(a.key, b.key, "eps/max must be part of the address");
                assert_ne!(a.stream_seed, b.stream_seed);
                assert_eq!(b.auto, Some(AutoReps { eps: 0.05, max: 200 }));
            }
        }
        // a different target addresses different estimates
        let mut s3 = s2.clone();
        s3.auto_reps = Some(AutoReps { eps: 0.1, max: 200 });
        let set3 = ScenarioSet::from_trace(&trace, &s3).unwrap();
        for (a, b) in set.cases.iter().zip(&set3.cases) {
            if a.backend != Backend::Analytic {
                assert_ne!(a.key, b.key);
            }
        }
    }

    #[test]
    fn timed_policies_reject_the_crash_axis() {
        let trace = small_trace();
        let mut s = spec();
        s.crash = vec![0.0, 0.3];
        s.policies = vec![ReplicationPolicy::SpeculativeAt { t: 1.0 }];
        let err = ScenarioSet::from_trace(&trace, &s).unwrap_err();
        assert!(err.to_string().contains("failure injection"), "{err}");
        // crash = [0] is fine for the same policy
        s.crash = vec![0.0];
        assert!(ScenarioSet::from_trace(&trace, &s).is_ok());
    }

    #[test]
    fn bad_grids_error() {
        let trace = small_trace();
        let mut s = spec();
        s.jobs = Some(vec![99]);
        assert!(ScenarioSet::from_trace(&trace, &s).is_err());
        let mut s = spec();
        s.batches = Some(vec![5]); // does not divide 12
        assert!(ScenarioSet::from_trace(&trace, &s).is_err());
    }

    #[test]
    fn analytic_backend_zeroes_reps() {
        let trace = small_trace();
        let mut s = spec();
        s.backends = vec![Backend::Analytic];
        let set = ScenarioSet::from_trace(&trace, &s).unwrap();
        assert!(set.cases.iter().all(|c| c.reps == 0));
    }

    #[test]
    fn cases_share_one_tau_allocation_per_job() {
        // the acceptance criterion of the Arc refactor: expanding a job
        // into batches x crash cases must not clone its empirical τ
        let trace = small_trace();
        let mut s = spec();
        s.crash = vec![0.0, 0.3];
        let set = ScenarioSet::from_trace(&trace, &s).unwrap();
        for job in [1u64, 5, 10] {
            let cases: Vec<&SweepCase> =
                set.cases.iter().filter(|c| c.job_id == job).collect();
            assert_eq!(cases.len(), 12); // 6 divisors x 2 crash levels
            for c in &cases[1..] {
                assert!(
                    Arc::ptr_eq(&cases[0].scenario.tau, &c.scenario.tau),
                    "job {job}: per-case τ clone detected"
                );
            }
            assert!(
                Arc::strong_count(&cases[0].scenario.tau) >= cases.len(),
                "job {job}: τ Arc not shared by all {} cases",
                cases.len()
            );
        }
        // distinct jobs have distinct allocations
        let (a, b) = (&set.cases[0], set.cases.last().unwrap());
        assert!(!Arc::ptr_eq(&a.scenario.tau, &b.scenario.tau));
    }

    #[test]
    fn shard_ranges_partition_the_grid() {
        let trace = small_trace();
        let set = ScenarioSet::from_trace(&trace, &spec()).unwrap();
        for m in [1usize, 2, 3, 4, 7, 59, 60, 61] {
            let mut covered = 0usize;
            for k in 0..m {
                let range = shard_range(set.len(), k, m);
                assert_eq!(range.start, covered, "m={m} k={k}: gap or overlap");
                covered = range.end;
                let slice = set.shard(k, m).unwrap();
                assert_eq!(slice.len(), range.len());
                // balanced: sizes differ by at most one
                assert!(slice.len() >= set.len() / m && slice.len() <= set.len() / m + 1);
            }
            assert_eq!(covered, set.len(), "m={m}: shards must cover the grid");
        }
        assert!(set.shard(0, 0).is_err());
        assert!(set.shard(2, 2).is_err());
    }

    #[test]
    fn sweep_key_identifies_the_grid() {
        let trace = small_trace();
        let a = ScenarioSet::from_trace(&trace, &spec()).unwrap();
        let b = ScenarioSet::from_trace(&trace, &spec()).unwrap();
        assert_eq!(a.sweep_key(), b.sweep_key());
        let mut other = spec();
        other.seed = 6;
        let c = ScenarioSet::from_trace(&trace, &other).unwrap();
        assert_ne!(a.sweep_key(), c.sweep_key());
        let mut narrowed = spec();
        narrowed.jobs = Some(vec![1]);
        let d = ScenarioSet::from_trace(&trace, &narrowed).unwrap();
        assert_ne!(a.sweep_key(), d.sweep_key(), "a sub-grid is a different sweep");
    }

    #[test]
    fn spectrum_expands_divisors_over_one_shared_tau() {
        let tau = Arc::new(ServiceDist::exp(1.0));
        let set = ScenarioSet::spectrum(3, 12, Arc::clone(&tau), 100, 9).unwrap();
        assert_eq!(set.len(), 6); // divisors of 12
        for (i, c) in set.cases.iter().enumerate() {
            assert_eq!(c.index, i);
            assert_eq!(c.job_id, 3);
            assert_eq!(c.backend, Backend::MonteCarlo);
            assert_eq!(c.reps, 100);
            assert!(Arc::ptr_eq(&c.scenario.tau, &tau));
        }
        // keys match what a trace-driven grid would assign to the same
        // scenarios (content addressing is constructor-independent)
        let again = ScenarioSet::spectrum(3, 12, tau, 100, 9).unwrap();
        assert_eq!(set.expected_keys(), again.expected_keys());
        assert!(ScenarioSet::spectrum(0, 0, Arc::new(ServiceDist::exp(1.0)), 1, 0).is_err());
        assert!(ScenarioSet::spectrum(0, 4, Arc::new(ServiceDist::exp(1.0)), 0, 0).is_err());
    }
}
