//! JSONL persistence: the result store and the estimate cache.
//!
//! Both files hold one compact JSON object per line. Keys inside a
//! record are emitted in sorted order (the codec's `Obj` is a
//! `BTreeMap`) and floats render shortest-roundtrip, so **rendering is
//! a pure function of the record's content** — the property the
//! resume-equals-rerun byte-identity guarantee rests on.
//!
//! * The **result store** (`results.jsonl`) is written strictly in grid
//!   order. On open it validates the existing file against the expected
//!   key sequence, truncates everything from the first invalid or
//!   out-of-order line (a kill can leave at most one partial line), and
//!   resumes after the surviving prefix.
//! * A **per-shard store** (`results.shard-K-of-M.jsonl`, opened via
//!   [`ResultStore::open_shard`]) is the same format prefixed by one
//!   identity header line naming the shard slice and the sweep key —
//!   so M processes can each own a file with no coordination, a
//!   foreign shard file is refused instead of overwritten, and
//!   [`crate::sweep::merge`](mod@crate::sweep::merge) can stitch the
//!   shards back into the canonical store.
//! * The **estimate cache** keys finished estimates by content address,
//!   so a re-run — same spec, a widened spec, or a run whose result
//!   file was lost — never re-evaluates a scenario it has already paid
//!   for. Lines are unordered; corrupt tails are truncated on load,
//!   and [`EstimateCache::gc`] compacts away keys the current grid no
//!   longer asks about.
//!
//! Undefined statistics (an all-failed Monte-Carlo estimate is all-NaN
//! by construction) are stored as JSON `null` and flagged
//! `"all_failed": true`, keeping the line parseable instead of
//! poisoning the file with bare `NaN` tokens.

use std::collections::{BTreeMap, BTreeSet};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::eval::{Estimate, OpenEstimate};
use crate::sim::policy::ReplicationPolicy;
use crate::sweep::grid::SweepCase;
use crate::util::error::{Error, Result};
use crate::util::json::{parse, Json};

/// The persisted slice of an [`Estimate`].
#[derive(Clone, Debug)]
pub struct StoredEstimate {
    /// Backend that actually answered (`analytic` | `monte-carlo`) —
    /// distinct from the requested backend when `auto` routes.
    pub via: String,
    pub mean: f64,
    pub ci95: f64,
    pub cov: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// Expected total worker-seconds. NaN for up-front records — they
    /// never persist a cost field (the pre-policy line format), so a
    /// freshly evaluated record and one reconstituted from disk carry
    /// the same value.
    pub cost: f64,
    pub failure_rate: f64,
    pub replications: usize,
    pub completed: usize,
    /// Mean fraction of worker-time busy (open-system records only).
    /// NaN for closed-system records, which omit the field on disk —
    /// the same only-when-present convention as the policy fields.
    pub utilization: f64,
    /// Replication policy the estimate was computed under. Up-front
    /// records omit the field on disk and parse back to `Upfront`.
    pub policy: ReplicationPolicy,
}

impl StoredEstimate {
    pub fn of(est: &Estimate, policy: ReplicationPolicy) -> StoredEstimate {
        StoredEstimate {
            via: est.provenance.backend().to_string(),
            mean: est.mean,
            ci95: est.ci95,
            cov: est.cov,
            p50: est.p50,
            p95: est.p95,
            p99: est.p99,
            // up-front lines don't persist cost; storing it would make
            // fresh records differ from cache/store round trips
            cost: if policy.is_upfront() { f64::NAN } else { est.cost },
            failure_rate: est.failure_rate,
            replications: est.replications,
            completed: est.completed,
            utilization: f64::NAN,
            policy,
        }
    }

    /// The persisted slice of an open-system estimate. Unlike closed
    /// up-front records, open records always carry cost (worker-seconds
    /// per job is a primary axis of the B*-vs-load story) and
    /// utilization; neither collides with the pre-open line format
    /// because closed records store both as NaN and never render them.
    pub fn of_open(oe: &OpenEstimate, policy: ReplicationPolicy) -> StoredEstimate {
        let est = &oe.estimate;
        StoredEstimate {
            via: est.provenance.backend().to_string(),
            mean: est.mean,
            ci95: est.ci95,
            cov: est.cov,
            p50: est.p50,
            p95: est.p95,
            p99: est.p99,
            cost: est.cost,
            failure_rate: est.failure_rate,
            replications: est.replications,
            completed: est.completed,
            utilization: oe.utilization,
            policy,
        }
    }

    /// Mirrors [`Estimate::all_failed`].
    pub fn all_failed(&self) -> bool {
        self.replications > 0 && self.completed == 0
    }
}

/// What the engine has to say about one case: an estimate, or a
/// deterministic per-case error (e.g. "no closed form") that must not
/// take the rest of its shard down with it.
#[derive(Clone, Debug)]
pub enum CaseOutcome {
    Ok(StoredEstimate),
    Error(String),
}

/// Render one result-store line (no trailing newline) for `case`.
/// Pure: fresh estimates and cache-reconstituted ones render
/// byte-identically.
pub fn render_record(case: &SweepCase, outcome: &CaseOutcome) -> String {
    let mut pairs = vec![
        ("b", Json::Num(case.batches() as f64)),
        ("backend", Json::Str(case.backend.name().to_string())),
        ("crash", Json::Num(case.crash())),
        ("job", Json::Num(case.job_id as f64)),
        ("key", Json::Str(case.key_hex())),
        ("n", Json::Num(case.scenario.workers as f64)),
    ];
    // Open-system cases name their operating point; closed cases keep
    // the pre-open line format byte-for-byte.
    if let Some(rho) = case.rho() {
        pairs.push(("rho", Json::Num(rho)));
    }
    pairs.extend(outcome_fields(outcome));
    Json::obj(pairs).to_string_compact()
}

/// Render one cache line (no trailing newline): the outcome keyed by
/// content address only.
fn render_cache_line(key: u64, outcome: &CaseOutcome) -> String {
    let mut pairs = vec![("key", Json::Str(format!("{key:016x}")))];
    pairs.extend(outcome_fields(outcome));
    Json::obj(pairs).to_string_compact()
}

fn outcome_fields(outcome: &CaseOutcome) -> Vec<(&'static str, Json)> {
    match outcome {
        CaseOutcome::Error(msg) => vec![("error", Json::Str(msg.clone()))],
        CaseOutcome::Ok(e) => {
            let mut fields = vec![
                ("all_failed", Json::Bool(e.all_failed())),
                ("ci95", Json::num_or_null(e.ci95)),
                ("completed", Json::Num(e.completed as f64)),
                ("cov", Json::num_or_null(e.cov)),
                ("failure_rate", Json::num_or_null(e.failure_rate)),
                ("mean", Json::num_or_null(e.mean)),
                ("p50", Json::num_or_null(e.p50)),
                ("p95", Json::num_or_null(e.p95)),
                ("p99", Json::num_or_null(e.p99)),
                ("replications", Json::Num(e.replications as f64)),
                ("via", Json::Str(e.via.clone())),
            ];
            // Up-front records keep the exact pre-policy line format:
            // policy/t/cost appear only for timed policies, so every
            // byte of an existing store is reproduced unchanged.
            if !e.policy.is_upfront() {
                fields.push(("cost", Json::num_or_null(e.cost)));
                fields.push(("policy", Json::Str(e.policy.name().to_string())));
                if let Some(t) = e.policy.t() {
                    fields.push(("t", Json::Num(t)));
                }
            } else if e.cost.is_finite() {
                // Open-system up-front records do track cost (closed
                // up-front ones store NaN, so old lines are unchanged).
                fields.push(("cost", Json::num_or_null(e.cost)));
            }
            if e.utilization.is_finite() {
                fields.push(("utilization", Json::num_or_null(e.utilization)));
            }
            fields
        }
    }
}

/// Identity header of a per-shard store file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardHeader {
    /// Shard index (0-based).
    pub shard: usize,
    /// Total shard count.
    pub of: usize,
    /// Number of cases this shard covers.
    pub cases: usize,
    /// [`crate::sweep::ScenarioSet::sweep_key`] of the whole grid.
    pub sweep_key: u64,
}

/// Render a shard store's first line (no trailing newline). Pure, like
/// every other store rendering — resuming a shard re-derives the exact
/// header bytes.
pub fn render_shard_header(header: ShardHeader) -> String {
    Json::obj(vec![
        ("cases", Json::Num(header.cases as f64)),
        ("of", Json::Num(header.of as f64)),
        ("shard", Json::Num(header.shard as f64)),
        ("sweep", Json::Str(format!("{:016x}", header.sweep_key))),
    ])
    .to_string_compact()
}

/// Parse a shard header line; `None` when the line is not a header
/// (e.g. an ordinary record, or a canonical store handed to the merge
/// by mistake).
pub fn parse_shard_header(line: &str) -> Option<ShardHeader> {
    let doc = parse(line).ok()?;
    let sweep_key = u64::from_str_radix(doc.get("sweep")?.as_str()?, 16).ok()?;
    Some(ShardHeader {
        shard: doc.get("shard")?.as_usize()?,
        of: doc.get("of")?.as_usize()?,
        cases: doc.get("cases")?.as_usize()?,
        sweep_key,
    })
}

/// Parse any store/cache line back into `(key, outcome)`.
pub fn parse_record(line: &str) -> Result<(u64, CaseOutcome)> {
    let doc = parse(line)?;
    let key_hex = doc
        .get("key")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Parse("record has no 'key'".into()))?;
    let key = u64::from_str_radix(key_hex, 16)
        .map_err(|e| Error::Parse(format!("bad record key '{key_hex}': {e}")))?;
    if let Some(msg) = doc.get("error").and_then(Json::as_str) {
        return Ok((key, CaseOutcome::Error(msg.to_string())));
    }
    let field = |name: &str| doc.get(name).map_or(f64::NAN, Json::as_f64_or_nan);
    let count = |name: &str| -> Result<usize> {
        doc.get(name)
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Parse(format!("record missing count '{name}'")))
    };
    let via = doc
        .get("via")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Parse("record has no 'via'".into()))?
        .to_string();
    // Pre-policy records have no "policy" field: they were all written
    // under up-front replication, so that is what they parse back to
    // (and their untracked cost is NaN).
    let policy = match doc.get("policy").and_then(Json::as_str) {
        None => ReplicationPolicy::Upfront,
        Some(name) => {
            let t = doc.get("t").map(Json::as_f64_or_nan);
            ReplicationPolicy::parse(name, t)
                .map_err(|e| Error::Parse(format!("bad record policy: {e}")))?
        }
    };
    Ok((
        key,
        CaseOutcome::Ok(StoredEstimate {
            via,
            mean: field("mean"),
            ci95: field("ci95"),
            cov: field("cov"),
            p50: field("p50"),
            p95: field("p95"),
            p99: field("p99"),
            cost: field("cost"),
            failure_rate: field("failure_rate"),
            replications: count("replications")?,
            completed: count("completed")?,
            utilization: field("utilization"),
            policy,
        }),
    ))
}

/// Split `text` into complete (newline-terminated) lines, reporting the
/// byte length of the surviving prefix as lines are accepted.
fn complete_lines(text: &str) -> impl Iterator<Item = &str> {
    // `split_inclusive` keeps the terminator, so a trailing partial
    // line (no '\n') is naturally excluded by the filter.
    text.split_inclusive('\n')
        .filter(|l| l.ends_with('\n'))
        .map(|l| &l[..l.len() - 1])
}

/// Read the file's longest valid-UTF-8 prefix. A kill can tear a write
/// mid multi-byte character; `read_to_string` would hard-error on that
/// forever, whereas the torn bytes are exactly the corrupt tail the
/// truncate-and-resume logic is meant to discard. Byte offsets into
/// the returned string equal file offsets (no lossy replacement).
fn read_valid_prefix(file: &mut File) -> Result<String> {
    let mut bytes = Vec::new();
    file.read_to_end(&mut bytes)?;
    let valid = match std::str::from_utf8(&bytes) {
        Ok(_) => bytes.len(),
        Err(e) => e.valid_up_to(),
    };
    bytes.truncate(valid);
    String::from_utf8(bytes)
        .map_err(|e| Error::Internal(format!("validated UTF-8 prefix rejected: {e}")))
}

/// The grid-ordered JSONL result store.
pub struct ResultStore {
    file: File,
}

impl ResultStore {
    /// Open (or create) the store and validate it against the expected
    /// key sequence. Returns the store, positioned to append, plus the
    /// outcomes of the valid resume prefix (record `i` matched
    /// `expected[i]`). Everything after the first invalid, partial, or
    /// out-of-order line is truncated — but a file whose *first*
    /// complete record already mismatches is a different sweep's
    /// output (a kill can only tear the last line), and truncating it
    /// would destroy healthy data; that is an error instead.
    pub fn open(path: &Path, expected: &[u64]) -> Result<(ResultStore, Vec<CaseOutcome>)> {
        let mut file =
            OpenOptions::new().read(true).write(true).create(true).truncate(false).open(path)?;
        let text = read_valid_prefix(&mut file)?;
        let mut outcomes = Vec::new();
        let mut good_bytes = 0u64;
        let mut complete = 0usize;
        for line in complete_lines(&text) {
            complete += 1;
            if outcomes.len() >= expected.len() {
                break; // spec shrank: drop surplus records
            }
            match parse_record(line) {
                Ok((key, outcome)) if key == expected[outcomes.len()] => {
                    outcomes.push(outcome);
                    good_bytes += line.len() as u64 + 1;
                }
                _ => break,
            }
        }
        if outcomes.is_empty() && complete > 0 {
            return Err(Error::Config(format!(
                "existing results file {} does not match this sweep's scenario grid \
                 (different spec, seed, or reps?); refusing to overwrite it — delete \
                 the file or pass a different output path",
                path.display()
            )));
        }
        file.set_len(good_bytes)?;
        file.seek(SeekFrom::End(0))?;
        Ok((ResultStore { file }, outcomes))
    }

    /// Open (or create) the per-shard store of one process in a
    /// multi-process sweep. The first line is the shard's identity
    /// header ([`render_shard_header`]); records follow in grid order
    /// exactly like the canonical store and resume the same way. A file
    /// whose header names a different sweep, slice, or shard count is
    /// another run's output and is refused, never truncated; only a
    /// torn header line (a kill before the first flush) is rebuilt.
    pub fn open_shard(
        path: &Path,
        header: ShardHeader,
        expected: &[u64],
    ) -> Result<(ResultStore, Vec<CaseOutcome>)> {
        let mut file =
            OpenOptions::new().read(true).write(true).create(true).truncate(false).open(path)?;
        let text = read_valid_prefix(&mut file)?;
        let header_line = render_shard_header(header);
        let mut lines = complete_lines(&text);
        match lines.next() {
            None => {
                // fresh file, or one torn line from a kill before the
                // header was flushed: start over with the header
                file.set_len(0)?;
                file.seek(SeekFrom::Start(0))?;
                file.write_all(header_line.as_bytes())?;
                file.write_all(b"\n")?;
                return Ok((ResultStore { file }, Vec::new()));
            }
            Some(line) if line == header_line => {}
            Some(line) => {
                let found = match parse_shard_header(line) {
                    Some(h) => format!(
                        "shard {}/{} of sweep {:016x}",
                        h.shard, h.of, h.sweep_key
                    ),
                    None => "no shard header".to_string(),
                };
                return Err(Error::Config(format!(
                    "existing shard file {} does not belong to this sweep slice \
                     (found {found}, expected shard {}/{} of sweep {:016x}); \
                     refusing to overwrite it — delete the file or pass a \
                     different output path",
                    path.display(),
                    header.shard,
                    header.of,
                    header.sweep_key
                )));
            }
        }
        let mut outcomes = Vec::new();
        let mut good_bytes = header_line.len() as u64 + 1;
        for line in lines {
            if outcomes.len() >= expected.len() {
                break;
            }
            match parse_record(line) {
                Ok((key, outcome)) if key == expected[outcomes.len()] => {
                    outcomes.push(outcome);
                    good_bytes += line.len() as u64 + 1;
                }
                _ => break,
            }
        }
        file.set_len(good_bytes)?;
        file.seek(SeekFrom::End(0))?;
        Ok((ResultStore { file }, outcomes))
    }

    /// Append one record line (newline added here).
    pub fn append(&mut self, line: &str) -> Result<()> {
        self.file.write_all(line.as_bytes())?;
        self.file.write_all(b"\n")?;
        Ok(())
    }

    /// Flush buffered records to disk (called once per shard).
    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// The content-addressed estimate cache.
pub struct EstimateCache {
    file: Option<File>,
    map: BTreeMap<u64, CaseOutcome>,
}

impl EstimateCache {
    /// A cache with no backing file (the in-memory engine path).
    pub fn in_memory() -> EstimateCache {
        EstimateCache { file: None, map: BTreeMap::new() }
    }

    /// Open (or create) a cache file, loading every valid line. The
    /// file is truncated at the first corrupt line (at most the last
    /// one after a kill).
    pub fn open(path: &Path) -> Result<EstimateCache> {
        let mut file =
            OpenOptions::new().read(true).write(true).create(true).truncate(false).open(path)?;
        let text = read_valid_prefix(&mut file)?;
        let mut map = BTreeMap::new();
        let mut good_bytes = 0u64;
        for line in complete_lines(&text) {
            match parse_record(line) {
                Ok((key, outcome)) => {
                    map.insert(key, outcome);
                    good_bytes += line.len() as u64 + 1;
                }
                Err(_) => break,
            }
        }
        file.set_len(good_bytes)?;
        file.seek(SeekFrom::End(0))?;
        Ok(EstimateCache { file: Some(file), map })
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn get(&self, key: u64) -> Option<&CaseOutcome> {
        self.map.get(&key)
    }

    /// Record one outcome (appended to the backing file if any).
    pub fn insert(&mut self, key: u64, outcome: CaseOutcome) -> Result<()> {
        if let Some(file) = &mut self.file {
            file.write_all(render_cache_line(key, &outcome).as_bytes())?;
            file.write_all(b"\n")?;
        }
        self.map.insert(key, outcome);
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(file) = &mut self.file {
            file.flush()?;
        }
        Ok(())
    }

    /// Compact the cache: drop every key not in `live` and rewrite the
    /// backing file to hold exactly the survivors. Long-lived caches
    /// accumulate dead keys as specs change (every reps/seed/axis edit
    /// re-keys its scenarios); GC reclaims that space without touching
    /// any estimate the current grid still asks about.
    ///
    /// The rewrite is in place (truncate + rewrite + flush), so a kill
    /// mid-GC can lose cache entries — acceptable for a cache, whose
    /// loss only costs re-evaluation, never correctness.
    pub fn gc(&mut self, live: &BTreeSet<u64>) -> Result<CacheGc> {
        let before = self.map.len();
        self.map.retain(|key, _| live.contains(key));
        let kept = self.map.len();
        let mut reclaimed_bytes = 0u64;
        if let Some(file) = &mut self.file {
            let old_len = file.metadata()?.len();
            let mut text = String::new();
            for (key, outcome) in &self.map {
                text.push_str(&render_cache_line(*key, outcome));
                text.push('\n');
            }
            file.set_len(0)?;
            file.seek(SeekFrom::Start(0))?;
            file.write_all(text.as_bytes())?;
            file.flush()?;
            reclaimed_bytes = old_len.saturating_sub(text.len() as u64);
        }
        Ok(CacheGc { live: kept, dead: before - kept, reclaimed_bytes })
    }
}

/// What one [`EstimateCache::gc`] pass found and freed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheGc {
    /// Keys the current grid still asks about (kept).
    pub live: usize,
    /// Keys absent from the current grid (dropped).
    pub dead: usize,
    /// Bytes the backing file shrank by (0 for in-memory caches).
    pub reclaimed_bytes: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Provenance;

    fn est(mean: f64, completed: usize) -> StoredEstimate {
        StoredEstimate {
            via: "monte-carlo".into(),
            mean,
            ci95: 0.1,
            cov: 0.5,
            p50: mean,
            p95: mean * 2.0,
            p99: mean * 3.0,
            cost: f64::NAN,
            failure_rate: 0.0,
            replications: 100,
            completed,
            utilization: f64::NAN,
            policy: ReplicationPolicy::Upfront,
        }
    }

    #[test]
    fn record_roundtrip_is_exact() {
        let line = render_cache_line(0xDEAD_BEEF_0000_0001, &CaseOutcome::Ok(est(1.2345, 100)));
        let (key, outcome) = parse_record(&line).unwrap();
        assert_eq!(key, 0xDEAD_BEEF_0000_0001);
        // re-rendering the parsed outcome reproduces the exact bytes
        assert_eq!(render_cache_line(key, &outcome), line);
    }

    #[test]
    fn all_failed_record_stays_parseable() {
        let mut e = est(f64::NAN, 0);
        e.ci95 = f64::NAN;
        e.cov = f64::NAN;
        e.p50 = f64::NAN;
        e.p95 = f64::NAN;
        e.p99 = f64::NAN;
        e.failure_rate = 1.0;
        let line = render_cache_line(7, &CaseOutcome::Ok(e));
        assert!(line.contains("\"all_failed\":true"));
        assert!(line.contains("\"mean\":null"));
        assert!(!line.contains("NaN"));
        let (_, back) = parse_record(&line).unwrap();
        match back {
            CaseOutcome::Ok(e) => {
                assert!(e.all_failed());
                assert!(e.mean.is_nan());
                assert_eq!(e.failure_rate, 1.0);
            }
            other => panic!("{other:?}"),
        }
        // and the exact-bytes property holds through the null round trip
        let (key, outcome) = parse_record(&line).unwrap();
        assert_eq!(render_cache_line(key, &outcome), line);
    }

    #[test]
    fn error_outcome_roundtrip() {
        let line = render_cache_line(3, &CaseOutcome::Error("no closed form".into()));
        let (key, outcome) = parse_record(&line).unwrap();
        assert_eq!(key, 3);
        assert!(matches!(outcome, CaseOutcome::Error(ref m) if m == "no closed form"));
        assert_eq!(render_cache_line(key, &outcome), line);
    }

    #[test]
    fn stored_estimate_mirrors_estimate() {
        let e = Estimate {
            mean: 2.0,
            ci95: 0.1,
            cov: 0.4,
            p50: 1.9,
            p95: 3.0,
            p99: 3.5,
            cost: 42.0,
            failure_rate: 0.25,
            replications: 400,
            completed: 300,
            provenance: Provenance::MonteCarlo { reps: 400, seed: 1, threads: 2 },
        };
        let s = StoredEstimate::of(&e, ReplicationPolicy::Upfront);
        assert_eq!(s.via, "monte-carlo");
        assert_eq!(s.completed, 300);
        assert!(!s.all_failed());
        // up-front lines never persist cost, so the in-memory record
        // drops it too (fresh == reconstituted)
        assert!(s.cost.is_nan());
        let t = StoredEstimate::of(&e, ReplicationPolicy::SpeculativeAt { t: 0.5 });
        assert_eq!(t.cost, 42.0);
        assert_eq!(t.policy, ReplicationPolicy::SpeculativeAt { t: 0.5 });
    }

    #[test]
    fn upfront_lines_keep_the_pre_policy_format() {
        // an up-front record renders without any of the new fields...
        let line = render_cache_line(9, &CaseOutcome::Ok(est(1.5, 100)));
        for field in ["cost", "policy", "\"t\""] {
            assert!(!line.contains(field), "{field} leaked into {line}");
        }
        // ...and a literal pre-policy line (as written by older code)
        // parses to an up-front record with untracked cost
        let old = "{\"all_failed\":false,\"ci95\":0.1,\"completed\":100,\"cov\":0.5,\
                   \"failure_rate\":0,\"key\":\"0000000000000009\",\"mean\":1.5,\
                   \"p50\":1.5,\"p95\":3,\"p99\":4.5,\"replications\":100,\
                   \"via\":\"monte-carlo\"}";
        let (key, outcome) = parse_record(old).unwrap();
        assert_eq!(key, 9);
        match outcome {
            CaseOutcome::Ok(e) => {
                assert!(e.policy.is_upfront());
                assert!(e.cost.is_nan());
                assert_eq!(e.mean, 1.5);
                // and it re-renders to the exact same bytes as a fresh
                // up-front record — the byte-identity contract
                assert_eq!(render_cache_line(9, &CaseOutcome::Ok(e)), old);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn timed_policy_records_roundtrip_exactly() {
        let mut e = est(2.0, 100);
        e.cost = 17.25;
        e.policy = ReplicationPolicy::SpeculativeAt { t: 0.75 };
        let line = render_cache_line(11, &CaseOutcome::Ok(e));
        assert!(line.contains("\"policy\":\"speculative\""));
        assert!(line.contains("\"t\":0.75"));
        assert!(line.contains("\"cost\":17.25"));
        let (key, back) = parse_record(&line).unwrap();
        assert_eq!(key, 11);
        match &back {
            CaseOutcome::Ok(b) => {
                assert_eq!(b.policy, ReplicationPolicy::SpeculativeAt { t: 0.75 });
                assert_eq!(b.cost, 17.25);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(render_cache_line(key, &back), line);
        // relaunch records roundtrip the same way
        let mut r = est(3.0, 100);
        r.cost = 9.5;
        r.policy = ReplicationPolicy::RelaunchAt { t: 1.5 };
        let line = render_cache_line(12, &CaseOutcome::Ok(r));
        assert!(line.contains("\"policy\":\"relaunch\""));
        let (key, back) = parse_record(&line).unwrap();
        assert_eq!(render_cache_line(key, &back), line);
    }

    #[test]
    fn open_records_roundtrip_with_cost_and_utilization() {
        let mut e = est(2.5, 100);
        e.cost = 4.5;
        e.utilization = 0.625;
        let line = render_cache_line(13, &CaseOutcome::Ok(e));
        assert!(line.contains("\"cost\":4.5"));
        assert!(line.contains("\"utilization\":0.625"));
        assert!(!line.contains("policy"), "up-front open records omit policy");
        let (key, back) = parse_record(&line).unwrap();
        match &back {
            CaseOutcome::Ok(b) => {
                assert!(b.policy.is_upfront());
                assert_eq!(b.cost, 4.5);
                assert_eq!(b.utilization, 0.625);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(render_cache_line(key, &back), line);
    }

    #[test]
    fn of_open_keeps_cost_for_upfront_records() {
        let est = Estimate {
            mean: 2.0,
            ci95: 0.1,
            cov: 0.4,
            p50: 1.9,
            p95: 3.0,
            p99: 3.5,
            cost: 6.0,
            failure_rate: 0.0,
            replications: 64,
            completed: 64,
            provenance: Provenance::MonteCarlo { reps: 64, seed: 1, threads: 2 },
        };
        let oe = OpenEstimate { estimate: est, utilization: 0.5, lambda: 0.8 };
        let s = StoredEstimate::of_open(&oe, ReplicationPolicy::Upfront);
        assert_eq!(s.cost, 6.0, "open records persist cost under every policy");
        assert_eq!(s.utilization, 0.5);
        assert_eq!(s.via, "monte-carlo");
    }

    #[test]
    fn cache_survives_corrupt_tail() {
        let dir = std::env::temp_dir().join("replica_sweep_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.jsonl");
        {
            let mut cache = EstimateCache::open(&path).unwrap();
            cache.insert(1, CaseOutcome::Ok(est(1.0, 100))).unwrap();
            cache.insert(2, CaseOutcome::Ok(est(2.0, 100))).unwrap();
            cache.flush().unwrap();
        }
        // simulate a kill mid-write: append half a line
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"key\":\"zz-partial");
        std::fs::write(&path, &text).unwrap();
        let cache = EstimateCache::open(&path).unwrap();
        assert_eq!(cache.len(), 2);
        assert!(cache.get(1).is_some() && cache.get(2).is_some());
        // the corrupt tail was truncated away
        let clean = std::fs::read_to_string(&path).unwrap();
        assert!(clean.ends_with('\n') && !clean.contains("zz-partial"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_utf8_tail_is_truncated_not_fatal() {
        let dir = std::env::temp_dir().join("replica_sweep_torn_utf8");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.jsonl");
        {
            let mut cache = EstimateCache::open(&path).unwrap();
            cache.insert(1, CaseOutcome::Error("policy needs B \u{2264} N".into())).unwrap();
            cache.flush().unwrap();
        }
        // tear the next record mid multi-byte character (first byte of
        // a 3-byte UTF-8 sequence)
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"{\"key\":\"02\",\"error\":\"B \xE2");
        std::fs::write(&path, &bytes).unwrap();
        let cache = EstimateCache::open(&path).unwrap();
        assert_eq!(cache.len(), 1);
        assert!(matches!(cache.get(1), Some(CaseOutcome::Error(m)) if m.contains('\u{2264}')));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn result_store_validates_prefix() {
        let dir = std::env::temp_dir().join("replica_sweep_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("results.jsonl");
        let expected = [10u64, 11, 12];
        // write records 10, 11, then an out-of-order 99
        {
            let (mut store, prefix) = ResultStore::open(&path, &expected).unwrap();
            assert!(prefix.is_empty());
            for key in [10u64, 11, 99] {
                store.append(&render_cache_line(key, &CaseOutcome::Ok(est(1.0, 10)))).unwrap();
            }
            store.flush().unwrap();
        }
        let (_, prefix) = ResultStore::open(&path, &expected).unwrap();
        assert_eq!(prefix.len(), 2, "key 99 must not validate against expected 12");
        // reopening after truncation keeps only the valid prefix bytes
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn result_store_refuses_to_wipe_a_foreign_file() {
        let dir = std::env::temp_dir().join("replica_sweep_store_foreign");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("results.jsonl");
        {
            let (mut store, _) = ResultStore::open(&path, &[10]).unwrap();
            store.append(&render_cache_line(10, &CaseOutcome::Ok(est(1.0, 10)))).unwrap();
            store.flush().unwrap();
        }
        // same path, different grid: the healthy file must survive
        let err = ResultStore::open(&path, &[20]).unwrap_err();
        assert!(err.to_string().contains("refusing to overwrite"), "{err}");
        assert_eq!(std::fs::read_to_string(&path).unwrap().lines().count(), 1);
        // a file holding only a torn partial line is fair game
        std::fs::write(&path, "{\"key\":\"tor").unwrap();
        let (_, prefix) = ResultStore::open(&path, &[20]).unwrap();
        assert!(prefix.is_empty());
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_header_roundtrip_is_exact() {
        let h = ShardHeader { shard: 2, of: 4, cases: 17, sweep_key: 0xFEED_F00D_1234_5678 };
        let line = render_shard_header(h);
        assert_eq!(parse_shard_header(&line), Some(h));
        // a header re-rendered from its parse reproduces the bytes
        assert_eq!(render_shard_header(parse_shard_header(&line).unwrap()), line);
        // ordinary records are not headers
        let record = render_cache_line(1, &CaseOutcome::Ok(est(1.0, 10)));
        assert_eq!(parse_shard_header(&record), None);
        assert_eq!(parse_shard_header("not json"), None);
    }

    #[test]
    fn shard_store_resumes_and_refuses_foreign_headers() {
        let dir = std::env::temp_dir().join("replica_sweep_shard_store");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("results.shard-1-of-2.jsonl");
        let header = ShardHeader { shard: 1, of: 2, cases: 2, sweep_key: 0xAB };
        let expected = [7u64, 8];
        {
            let (mut store, prefix) =
                ResultStore::open_shard(&path, header, &expected).unwrap();
            assert!(prefix.is_empty());
            store.append(&render_cache_line(7, &CaseOutcome::Ok(est(1.0, 10)))).unwrap();
            store.flush().unwrap();
        }
        // resume: header validated, one record survives
        let (_, prefix) = ResultStore::open_shard(&path, header, &expected).unwrap();
        assert_eq!(prefix.len(), 1);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2, "header + one record");
        assert_eq!(text.lines().next().unwrap(), render_shard_header(header));
        // a different sweep key is refused, file untouched
        let foreign = ShardHeader { sweep_key: 0xCD, ..header };
        let err = ResultStore::open_shard(&path, foreign, &expected).unwrap_err();
        assert!(err.to_string().contains("refusing to overwrite"), "{err}");
        assert_eq!(std::fs::read_to_string(&path).unwrap(), text);
        // so is a different slice of the same sweep
        let wrong_slice = ShardHeader { shard: 0, ..header };
        assert!(ResultStore::open_shard(&path, wrong_slice, &expected).is_err());
        // a torn header line (kill before first flush) is rebuilt
        std::fs::write(&path, "{\"cases\":2,\"of").unwrap();
        let (_, prefix) = ResultStore::open_shard(&path, header, &expected).unwrap();
        assert!(prefix.is_empty());
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, format!("{}\n", render_shard_header(header)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cache_gc_compacts_the_backing_file() {
        let dir = std::env::temp_dir().join("replica_sweep_cache_gc");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.jsonl");
        let mut cache = EstimateCache::open(&path).unwrap();
        for key in 1u64..=6 {
            cache.insert(key, CaseOutcome::Ok(est(key as f64, 100))).unwrap();
        }
        cache.flush().unwrap();
        let before = std::fs::metadata(&path).unwrap().len();
        let live: BTreeSet<u64> = [2u64, 4, 6].into_iter().collect();
        let stats = cache.gc(&live).unwrap();
        assert_eq!((stats.live, stats.dead), (3, 3));
        assert!(stats.reclaimed_bytes > 0);
        let after = std::fs::metadata(&path).unwrap().len();
        assert_eq!(before - after, stats.reclaimed_bytes);
        assert!(cache.get(2).is_some() && cache.get(3).is_none());
        // the rewritten file reloads to exactly the survivors
        drop(cache);
        let reloaded = EstimateCache::open(&path).unwrap();
        assert_eq!(reloaded.len(), 3);
        assert!(reloaded.get(4).is_some());
        // a second GC with the same live set is a no-op
        let mut reloaded = reloaded;
        let again = reloaded.gc(&live).unwrap();
        assert_eq!((again.live, again.dead, again.reclaimed_bytes), (3, 0, 0));
        // in-memory caches GC without a file
        let mut mem = EstimateCache::in_memory();
        mem.insert(1, CaseOutcome::Ok(est(1.0, 10))).unwrap();
        let stats = mem.gc(&BTreeSet::new()).unwrap();
        assert_eq!((stats.live, stats.dead, stats.reclaimed_bytes), (0, 1, 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn result_store_drops_surplus_records() {
        let dir = std::env::temp_dir().join("replica_sweep_store_surplus");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("results.jsonl");
        {
            let (mut store, _) = ResultStore::open(&path, &[5, 6]).unwrap();
            for key in [5u64, 6] {
                store.append(&render_cache_line(key, &CaseOutcome::Ok(est(1.0, 10)))).unwrap();
            }
            store.flush().unwrap();
        }
        // the spec shrank to one case: the second record is dropped
        let (_, prefix) = ResultStore::open(&path, &[5]).unwrap();
        assert_eq!(prefix.len(), 1);
        assert_eq!(std::fs::read_to_string(&path).unwrap().lines().count(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
