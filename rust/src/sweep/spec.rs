//! Sweep specification: the declarative input of the sweep engine.
//!
//! A spec names a workload (a trace file or generator parameters), the
//! grid axes (jobs × batch counts × failure levels × replication
//! policies × backends), and the estimator budget. Specs are plain
//! JSON so they can be committed, diffed, and fed to
//! `replica sweep --spec` from CI:
//!
//! ```json
//! {
//!   "workload": {"generate": {"jobs": 100, "tasks_per_job": 1000, "seed": 7}},
//!   "jobs": [1, 2, 3],
//!   "batches": [1, 10, 100],
//!   "backends": ["mc"],
//!   "reps": 2000,
//!   "seed": 42,
//!   "crash": [0, 0.05],
//!   "policies": ["upfront", {"speculative": 1.5}, {"relaunch": 2.0}],
//!   "shard_size": 64
//! }
//! ```
//!
//! Every field except `workload` is optional: `jobs` defaults to every
//! job in the trace, `batches` to the full divisor spectrum of each
//! job's task count, `backends` to `["mc"]`, `crash` to `[0]` (no
//! failure injection), `policies` to `["upfront"]` (the pre-policy
//! grid, so existing specs re-key nothing), `reps` to
//! [`DEFAULT_SWEEP_REPS`], `seed` to 0, and `shard_size` to
//! [`DEFAULT_SHARD_SIZE`]. A `policies` entry is either the string
//! `"upfront"` or a one-key object `{"speculative": T}` /
//! `{"relaunch": T}` naming the policy's trigger time.
//!
//! The optional `arrivals` field switches the sweep into *open-system*
//! mode (see [`crate::eval::OpenSystem`]): each case simulates a
//! Poisson job stream instead of one job on an idle cluster, and the
//! offered loads become one more grid axis:
//!
//! ```json
//! "arrivals": {"rho": [0.2, 0.5, 0.8], "jobs": 200, "warmup": 50}
//! ```
//!
//! `rho` is required (non-empty, each in `(0, 4]`); `jobs` and `warmup`
//! default to the [`crate::eval::OpenSystem`] window defaults. Open
//! sweeps are Monte-Carlo only — there is no closed form under
//! queueing — so `backends` must be `["mc"]`. Specs without `arrivals`
//! expand exactly as before and re-key nothing.
//!
//! The `reps` field also accepts the precision-targeted form
//!
//! ```json
//! "reps": {"auto": {"eps": 0.05, "max": 4096}}
//! ```
//!
//! which replaces the fixed per-case budget with adaptive stopping:
//! each Monte-Carlo case doubles its replication count in waves until
//! its ci95 half-width drops to `eps` or the count reaches `max` (see
//! `MonteCarlo::until_ci95`). Both keys are required. The realized
//! count lands in each store record's `replications` field, and the
//! stopping rule is a function of the accumulated estimate only —
//! never wall-clock — so shard, cluster, and resume runs stay
//! byte-identical. Analytic cases are exact and ignore the target;
//! `auto`-backend cases apply it only where they fall back to
//! Monte-Carlo.

use std::path::{Path, PathBuf};

use crate::eval::{DEFAULT_OPEN_JOBS, DEFAULT_OPEN_WARMUP};
use crate::sim::policy::ReplicationPolicy;
use crate::traces::{load_trace, GeneratorConfig, Trace};
use crate::util::error::{Error, Result};
use crate::util::json::{parse, Json};

/// Default Monte-Carlo replications per scenario. Cluster-scale sweeps
/// evaluate thousands of scenarios, so the default budget is leaner
/// than the single-scenario [`crate::eval::DEFAULT_REPS`].
pub const DEFAULT_SWEEP_REPS: usize = 2_000;

/// Default scenarios per shard (one shard = one pooled
/// `evaluate_many`-style batch and one store flush).
pub const DEFAULT_SHARD_SIZE: usize = 64;

/// Which estimator backend a grid axis point asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    MonteCarlo,
    Analytic,
    Auto,
}

impl Backend {
    /// Spec-file spelling (also the `backend` field of result records).
    pub fn name(&self) -> &'static str {
        match self {
            Backend::MonteCarlo => "mc",
            Backend::Analytic => "analytic",
            Backend::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "mc" | "monte-carlo" => Ok(Backend::MonteCarlo),
            "analytic" => Ok(Backend::Analytic),
            "auto" => Ok(Backend::Auto),
            other => {
                Err(Error::Config(format!("unknown backend '{other}' (mc | analytic | auto)")))
            }
        }
    }
}

/// The open-system `arrivals` axis: offered loads plus the measurement
/// window shared by every load point.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrivalsSpec {
    /// Offered loads ρ to sweep (each in `(0, 4]`).
    pub rho: Vec<f64>,
    /// Measured jobs per replication.
    pub jobs: usize,
    /// Warmup jobs excluded from statistics.
    pub warmup: usize,
}

/// Precision-targeted replication budget, the
/// `reps: {"auto": {"eps": E, "max": M}}` spec form: stop doubling a
/// case's replication count once its ci95 half-width reaches `eps`, or
/// at `max` replications.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoReps {
    /// Target ci95 half-width (finite, > 0).
    pub eps: f64,
    /// Replication-count ceiling (>= 1).
    pub max: usize,
}

/// Where the trace comes from.
#[derive(Clone, Debug, PartialEq)]
pub enum Workload {
    /// Synthesize a cluster-scale trace via
    /// [`GeneratorConfig::scaled_workload`].
    Generate { jobs: usize, tasks_per_job: usize, seed: u64 },
    /// Load a trace CSV (real or previously generated).
    TraceFile(PathBuf),
}

/// A parsed sweep specification.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepSpec {
    /// Trace source. `None` means the caller supplies the [`Trace`]
    /// directly (the in-memory path used by `experiments::traces_exp`).
    pub workload: Option<Workload>,
    /// Job-id filter; `None` = every job present in the trace.
    pub jobs: Option<Vec<u64>>,
    /// Batch counts to evaluate; `None` = all divisors of each job's
    /// task count (the full diversity–parallelism spectrum).
    pub batches: Option<Vec<usize>>,
    /// Estimator backends (one grid axis).
    pub backends: Vec<Backend>,
    /// Monte-Carlo replications per scenario. Under `reps: auto` this
    /// holds the ceiling (`auto_reps.max`), so shard math and existing
    /// validation see a concrete count.
    pub reps: usize,
    /// Precision-targeted stopping (`reps: {"auto": ...}`); `None` (the
    /// default) keeps fixed budgets — and every existing content key —
    /// unchanged.
    pub auto_reps: Option<AutoReps>,
    /// Base seed; every scenario derives its own stream from it and its
    /// content key.
    pub seed: u64,
    /// Worker crash probabilities (one grid axis); `0` = no failures.
    pub crash: Vec<f64>,
    /// Replication policies (one grid axis).
    pub policies: Vec<ReplicationPolicy>,
    /// Scenarios per shard.
    pub shard_size: usize,
    /// Open-system mode: offered-load axis and measurement window.
    /// `None` (the default) keeps the closed-system grid — and every
    /// existing content key — unchanged.
    pub arrivals: Option<ArrivalsSpec>,
}

impl SweepSpec {
    /// Spec with default axes for a caller-supplied trace.
    pub fn for_trace() -> SweepSpec {
        SweepSpec {
            workload: None,
            jobs: None,
            batches: None,
            backends: vec![Backend::MonteCarlo],
            reps: DEFAULT_SWEEP_REPS,
            auto_reps: None,
            seed: 0,
            crash: vec![0.0],
            policies: vec![ReplicationPolicy::Upfront],
            shard_size: DEFAULT_SHARD_SIZE,
            arrivals: None,
        }
    }

    /// Parse a JSON spec document. Strict about keys: a misspelled
    /// field would otherwise silently fall back to its default (and
    /// re-key every scenario), so unknown keys are hard errors.
    pub fn from_json(text: &str) -> Result<SweepSpec> {
        let doc = parse(text)?;
        const KNOWN: [&str; 10] = [
            "workload",
            "jobs",
            "batches",
            "backends",
            "reps",
            "seed",
            "crash",
            "policies",
            "shard_size",
            "arrivals",
        ];
        if let Json::Obj(map) = &doc {
            for key in map.keys() {
                if !KNOWN.contains(&key.as_str()) {
                    return Err(Error::Config(format!(
                        "unknown spec field '{key}' (known: {})",
                        KNOWN.join(", ")
                    )));
                }
            }
        } else {
            return Err(Error::Config("sweep spec must be a JSON object".into()));
        }
        let workload = match doc.get("workload") {
            None => return Err(Error::Config("sweep spec needs a 'workload' field".into())),
            Some(w) => Some(parse_workload(w)?),
        };
        let jobs = match doc.get("jobs") {
            None => None,
            Some(v) => Some(
                expect_arr(v, "jobs")?
                    .iter()
                    .map(|x| expect_index(x, "jobs entry"))
                    .collect::<Result<Vec<u64>>>()?,
            ),
        };
        let batches = match doc.get("batches") {
            None => None,
            Some(Json::Str(s)) if s == "divisors" => None,
            Some(v) => {
                let bs = expect_arr(v, "batches")?
                    .iter()
                    .map(|x| expect_index(x, "batches entry").map(|n| n as usize))
                    .collect::<Result<Vec<usize>>>()?;
                if bs.is_empty() || bs.iter().any(|&b| b == 0) {
                    return Err(Error::Config("'batches' must be non-empty and positive".into()));
                }
                Some(bs)
            }
        };
        let backends = match doc.get("backends") {
            None => vec![Backend::MonteCarlo],
            Some(v) => {
                let names = expect_arr(v, "backends")?;
                if names.is_empty() {
                    return Err(Error::Config("'backends' must be non-empty".into()));
                }
                names
                    .iter()
                    .map(|x| {
                        Backend::parse(
                            x.as_str().ok_or_else(|| {
                                Error::Config("'backends' entries must be strings".into())
                            })?,
                        )
                    })
                    .collect::<Result<Vec<Backend>>>()?
            }
        };
        let (reps, auto_reps) = parse_reps(&doc)?;
        let seed = get_usize(&doc, "seed", 0)? as u64;
        let crash = match doc.get("crash") {
            None => vec![0.0],
            Some(v) => {
                let ps = expect_arr(v, "crash")?
                    .iter()
                    .map(|x| expect_num(x, "crash entry"))
                    .collect::<Result<Vec<f64>>>()?;
                if ps.is_empty() || ps.iter().any(|p| !(0.0..=1.0).contains(p)) {
                    return Err(Error::Config(
                        "'crash' must be non-empty probabilities in [0, 1]".into(),
                    ));
                }
                ps
            }
        };
        let policies = match doc.get("policies") {
            None => vec![ReplicationPolicy::Upfront],
            Some(v) => {
                let entries = expect_arr(v, "policies")?;
                if entries.is_empty() {
                    return Err(Error::Config("'policies' must be non-empty".into()));
                }
                entries
                    .iter()
                    .map(parse_policy_entry)
                    .collect::<Result<Vec<ReplicationPolicy>>>()?
            }
        };
        let shard_size = get_usize(&doc, "shard_size", DEFAULT_SHARD_SIZE)?;
        if shard_size == 0 {
            return Err(Error::Config("'shard_size' must be >= 1".into()));
        }
        let arrivals = match doc.get("arrivals") {
            None => None,
            Some(v) => Some(parse_arrivals(v)?),
        };
        if arrivals.is_some() && backends.iter().any(|b| *b != Backend::MonteCarlo) {
            return Err(Error::Config(
                "open-system sweeps ('arrivals') support only the 'mc' backend — \
                 there is no closed form under queueing"
                    .into(),
            ));
        }
        Ok(SweepSpec {
            workload,
            jobs,
            batches,
            backends,
            reps,
            auto_reps,
            seed,
            crash,
            policies,
            shard_size,
            arrivals,
        })
    }

    /// Parse a spec file.
    pub fn from_file(path: &Path) -> Result<SweepSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read spec {}: {e}", path.display())))?;
        SweepSpec::from_json(&text)
    }

    /// Materialize the workload's trace (generate or load).
    pub fn load_trace(&self) -> Result<Trace> {
        match &self.workload {
            None => Err(Error::Config(
                "spec has no workload; pass the trace directly (ScenarioSet::from_trace)".into(),
            )),
            Some(Workload::Generate { jobs, tasks_per_job, seed }) => {
                Ok(GeneratorConfig::scaled_workload(*jobs, *tasks_per_job, *seed).generate())
            }
            Some(Workload::TraceFile(path)) => load_trace(path),
        }
    }
}

fn parse_workload(w: &Json) -> Result<Workload> {
    let Json::Obj(top) = w else {
        return Err(Error::Config(
            "'workload' must be {\"trace\": PATH} or {\"generate\": {...}}".into(),
        ));
    };
    for key in top.keys() {
        if !["trace", "generate"].contains(&key.as_str()) {
            return Err(Error::Config(format!(
                "unknown 'workload' field '{key}' (known: trace, generate)"
            )));
        }
    }
    match (top.get("trace"), top.get("generate")) {
        (Some(_), Some(_)) => Err(Error::Config(
            "'workload' cannot name both 'trace' and 'generate'".into(),
        )),
        (Some(t), None) => {
            let path = t.as_str().ok_or_else(|| {
                Error::Config("'workload.trace' must be a path string".into())
            })?;
            Ok(Workload::TraceFile(PathBuf::from(path)))
        }
        (None, Some(g)) => {
            let Json::Obj(map) = g else {
                return Err(Error::Config("'generate' must be an object".into()));
            };
            for key in map.keys() {
                if !["jobs", "tasks_per_job", "seed"].contains(&key.as_str()) {
                    return Err(Error::Config(format!(
                        "unknown 'generate' field '{key}' (known: jobs, tasks_per_job, seed)"
                    )));
                }
            }
            let jobs = get_usize(g, "jobs", 10)?;
            let tasks = get_usize(g, "tasks_per_job", 100)?;
            if jobs == 0 || tasks == 0 {
                return Err(Error::Config(
                    "'generate' needs jobs >= 1 and tasks_per_job >= 1".into(),
                ));
            }
            let seed = get_usize(g, "seed", 42)? as u64;
            Ok(Workload::Generate { jobs, tasks_per_job: tasks, seed })
        }
        (None, None) => Err(Error::Config(
            "'workload' must be {\"trace\": PATH} or {\"generate\": {...}}".into(),
        )),
    }
}

/// The `arrivals` object: `{"rho": [..], "jobs": N?, "warmup": N?}`.
fn parse_arrivals(v: &Json) -> Result<ArrivalsSpec> {
    let Json::Obj(map) = v else {
        return Err(Error::Config(
            "'arrivals' must be {\"rho\": [..], \"jobs\": N, \"warmup\": N}".into(),
        ));
    };
    for key in map.keys() {
        if !["rho", "jobs", "warmup"].contains(&key.as_str()) {
            return Err(Error::Config(format!(
                "unknown 'arrivals' field '{key}' (known: rho, jobs, warmup)"
            )));
        }
    }
    let rho = match map.get("rho") {
        None => return Err(Error::Config("'arrivals' needs a 'rho' array".into())),
        Some(v) => expect_arr(v, "arrivals.rho")?
            .iter()
            .map(|x| expect_num(x, "arrivals.rho entry"))
            .collect::<Result<Vec<f64>>>()?,
    };
    if rho.is_empty() || rho.iter().any(|r| !r.is_finite() || *r <= 0.0 || *r > 4.0) {
        return Err(Error::Config(
            "'arrivals.rho' must be non-empty offered loads in (0, 4]".into(),
        ));
    }
    let jobs = get_usize(v, "jobs", DEFAULT_OPEN_JOBS)?;
    let warmup = get_usize(v, "warmup", DEFAULT_OPEN_WARMUP)?;
    if jobs == 0 {
        return Err(Error::Config("'arrivals.jobs' must be >= 1".into()));
    }
    Ok(ArrivalsSpec { rho, jobs, warmup })
}

/// The `reps` field: a fixed count, or the precision-targeted form
/// `{"auto": {"eps": E, "max": M}}`. Auto resolves `reps` to the
/// ceiling so downstream shard math needs no special case.
fn parse_reps(doc: &Json) -> Result<(usize, Option<AutoReps>)> {
    match doc.get("reps") {
        None => Ok((DEFAULT_SWEEP_REPS, None)),
        Some(Json::Obj(map)) => {
            for key in map.keys() {
                if key != "auto" {
                    return Err(Error::Config(format!(
                        "unknown 'reps' field '{key}' (object form is {{\"auto\": \
                         {{\"eps\": E, \"max\": M}}}})"
                    )));
                }
            }
            let Some(auto) = map.get("auto") else {
                return Err(Error::Config(
                    "'reps' object form needs an 'auto' key: \
                     {\"auto\": {\"eps\": E, \"max\": M}}"
                        .into(),
                ));
            };
            let Json::Obj(inner) = auto else {
                return Err(Error::Config(
                    "'reps.auto' must be an object {\"eps\": E, \"max\": M}".into(),
                ));
            };
            for key in inner.keys() {
                if !["eps", "max"].contains(&key.as_str()) {
                    return Err(Error::Config(format!(
                        "unknown 'reps.auto' field '{key}' (known: eps, max)"
                    )));
                }
            }
            let eps = match inner.get("eps") {
                None => return Err(Error::Config("'reps.auto' needs an 'eps' target".into())),
                Some(v) => expect_num(v, "reps.auto.eps")?,
            };
            if !eps.is_finite() || eps <= 0.0 {
                return Err(Error::Config("'reps.auto.eps' must be finite and > 0".into()));
            }
            if inner.get("max").is_none() {
                return Err(Error::Config("'reps.auto' needs a 'max' ceiling".into()));
            }
            let max = get_usize(auto, "max", 0)?;
            if max == 0 {
                return Err(Error::Config("'reps.auto.max' must be >= 1".into()));
            }
            Ok((max, Some(AutoReps { eps, max })))
        }
        Some(_) => {
            let reps = get_usize(doc, "reps", DEFAULT_SWEEP_REPS)?;
            if reps == 0 {
                return Err(Error::Config("'reps' must be >= 1".into()));
            }
            Ok((reps, None))
        }
    }
}

/// One `policies` entry: `"upfront"`, `{"speculative": T}`, or
/// `{"relaunch": T}`.
fn parse_policy_entry(v: &Json) -> Result<ReplicationPolicy> {
    match v {
        Json::Str(s) => ReplicationPolicy::parse(s, None),
        Json::Obj(map) => {
            if map.len() != 1 {
                return Err(Error::Config(
                    "'policies' object entries must have exactly one key, \
                     {\"speculative\": T} or {\"relaunch\": T}"
                        .into(),
                ));
            }
            let (name, t) = map
                .iter()
                .next()
                .ok_or_else(|| Error::Internal("one-entry map yielded nothing".into()))?;
            ReplicationPolicy::parse(name, Some(expect_num(t, "policies entry t")?))
        }
        _ => Err(Error::Config(
            "'policies' entries must be \"upfront\" or {\"speculative\"|\"relaunch\": T}".into(),
        )),
    }
}

fn expect_arr<'j>(v: &'j Json, what: &str) -> Result<&'j [Json]> {
    v.as_arr().ok_or_else(|| Error::Config(format!("'{what}' must be an array")))
}

fn expect_num(v: &Json, what: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| Error::Config(format!("'{what}' must be a number")))
}

/// A non-negative integer array entry; fractional or negative values
/// would otherwise truncate silently and re-key scenarios.
fn expect_index(v: &Json, what: &str) -> Result<u64> {
    let x = expect_num(v, what)?;
    if x < 0.0 || x.fract() != 0.0 {
        return Err(Error::Config(format!("'{what}' must be a non-negative integer, got {x}")));
    }
    Ok(x as u64)
}

fn get_usize(doc: &Json, key: &str, default: usize) -> Result<usize> {
    match doc.get(key) {
        None => Ok(default),
        Some(v) => {
            let x = expect_num(v, key)?;
            if x < 0.0 || x.fract() != 0.0 {
                return Err(Error::Config(format!("'{key}' must be a non-negative integer")));
            }
            Ok(x as usize)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_spec_defaults() {
        let spec = SweepSpec::from_json(
            r#"{"workload": {"generate": {"jobs": 3, "tasks_per_job": 12, "seed": 1}}}"#,
        )
        .unwrap();
        assert_eq!(
            spec.workload,
            Some(Workload::Generate { jobs: 3, tasks_per_job: 12, seed: 1 })
        );
        assert_eq!(spec.jobs, None);
        assert_eq!(spec.batches, None);
        assert_eq!(spec.backends, vec![Backend::MonteCarlo]);
        assert_eq!(spec.reps, DEFAULT_SWEEP_REPS);
        assert_eq!(spec.auto_reps, None);
        assert_eq!(spec.crash, vec![0.0]);
        assert_eq!(spec.policies, vec![ReplicationPolicy::Upfront]);
        assert_eq!(spec.shard_size, DEFAULT_SHARD_SIZE);
        assert_eq!(spec.arrivals, None);
    }

    #[test]
    fn arrivals_axis_parses_with_defaults() {
        let spec = SweepSpec::from_json(
            r#"{"workload": {"trace": "t"}, "arrivals": {"rho": [0.2, 0.8]}}"#,
        )
        .unwrap();
        let arrivals = spec.arrivals.unwrap();
        assert_eq!(arrivals.rho, vec![0.2, 0.8]);
        assert_eq!(arrivals.jobs, DEFAULT_OPEN_JOBS);
        assert_eq!(arrivals.warmup, DEFAULT_OPEN_WARMUP);

        let spec = SweepSpec::from_json(
            r#"{"workload": {"trace": "t"},
                "arrivals": {"rho": [0.5], "jobs": 120, "warmup": 30}}"#,
        )
        .unwrap();
        let arrivals = spec.arrivals.unwrap();
        assert_eq!((arrivals.jobs, arrivals.warmup), (120, 30));
    }

    #[test]
    fn invalid_arrivals_are_rejected() {
        for bad in [
            r#"{"workload": {"trace": "t"}, "arrivals": {}}"#,
            r#"{"workload": {"trace": "t"}, "arrivals": {"rho": []}}"#,
            r#"{"workload": {"trace": "t"}, "arrivals": {"rho": [0]}}"#,
            r#"{"workload": {"trace": "t"}, "arrivals": {"rho": [-0.2]}}"#,
            r#"{"workload": {"trace": "t"}, "arrivals": {"rho": [9.0]}}"#,
            r#"{"workload": {"trace": "t"}, "arrivals": {"rho": [0.2], "jobs": 0}}"#,
            r#"{"workload": {"trace": "t"}, "arrivals": {"rho": [0.2], "nope": 1}}"#,
            r#"{"workload": {"trace": "t"}, "arrivals": [0.2]}"#,
            r#"{"workload": {"trace": "t"}, "arrivals": {"rho": [0.2]},
                "backends": ["analytic"]}"#,
            r#"{"workload": {"trace": "t"}, "arrivals": {"rho": [0.2]},
                "backends": ["mc", "auto"]}"#,
        ] {
            assert!(SweepSpec::from_json(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn full_spec_round() {
        let spec = SweepSpec::from_json(
            r#"{
              "workload": {"trace": "t.csv"},
              "jobs": [2, 4],
              "batches": [1, 2, 6],
              "backends": ["mc", "auto", "analytic"],
              "reps": 500,
              "seed": 9,
              "crash": [0, 0.5],
              "policies": ["upfront", {"speculative": 1.5}, {"relaunch": 2}],
              "shard_size": 8
            }"#,
        )
        .unwrap();
        assert_eq!(spec.workload, Some(Workload::TraceFile(PathBuf::from("t.csv"))));
        assert_eq!(spec.jobs, Some(vec![2, 4]));
        assert_eq!(spec.batches, Some(vec![1, 2, 6]));
        assert_eq!(
            spec.backends,
            vec![Backend::MonteCarlo, Backend::Auto, Backend::Analytic]
        );
        assert_eq!((spec.reps, spec.seed, spec.shard_size), (500, 9, 8));
        assert_eq!(spec.crash, vec![0.0, 0.5]);
        assert_eq!(
            spec.policies,
            vec![
                ReplicationPolicy::Upfront,
                ReplicationPolicy::SpeculativeAt { t: 1.5 },
                ReplicationPolicy::RelaunchAt { t: 2.0 },
            ]
        );
    }

    #[test]
    fn invalid_specs_are_rejected() {
        for bad in [
            r#"{}"#,
            r#"{"workload": {"nope": 1}}"#,
            r#"{"workload": {"trace": "t"}, "reps": 0}"#,
            r#"{"workload": {"trace": "t"}, "batches": []}"#,
            r#"{"workload": {"trace": "t"}, "batches": [0]}"#,
            r#"{"workload": {"trace": "t"}, "backends": []}"#,
            r#"{"workload": {"trace": "t"}, "backends": ["gpu"]}"#,
            r#"{"workload": {"trace": "t"}, "crash": [1.5]}"#,
            r#"{"workload": {"trace": "t"}, "shard_size": 0}"#,
            r#"{"workload": {"generate": {"jobs": 0}}}"#,
            r#"{"workload": {"trace": "t"}, "reps": 1.5}"#,
            r#"{"workload": {"trace": "t"}, "rep": 500}"#,
            r#"{"workload": {"generate": {"job": 5}}}"#,
            r#"{"workload": {"generate": "100x1000"}}"#,
            r#"{"workload": {"trace": "t", "generate": {"jobs": 2}}}"#,
            r#"{"workload": {"trace": "t", "tasks_per_job": 10}}"#,
            r#"{"workload": {"trace": 123}}"#,
            r#"{"workload": {"trace": "t"}, "jobs": [1.9]}"#,
            r#"{"workload": {"trace": "t"}, "jobs": [-1]}"#,
            r#"{"workload": {"trace": "t"}, "batches": [2.5]}"#,
            r#"{"workload": {"trace": "t"}, "policies": []}"#,
            r#"{"workload": {"trace": "t"}, "policies": ["eager"]}"#,
            r#"{"workload": {"trace": "t"}, "policies": [{"speculative": -1}]}"#,
            r#"{"workload": {"trace": "t"}, "policies": [{"upfront": 1}]}"#,
            r#"{"workload": {"trace": "t"}, "policies": [{"speculative": 1, "relaunch": 2}]}"#,
            r#"{"workload": {"trace": "t"}, "policies": [7]}"#,
            r#"[1, 2]"#,
        ] {
            assert!(SweepSpec::from_json(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn auto_reps_parses_and_pins_reps_to_the_ceiling() {
        let spec = SweepSpec::from_json(
            r#"{"workload": {"trace": "t"},
                "reps": {"auto": {"eps": 0.05, "max": 4096}}}"#,
        )
        .unwrap();
        assert_eq!(spec.reps, 4096);
        assert_eq!(spec.auto_reps, Some(AutoReps { eps: 0.05, max: 4096 }));
        // fixed-number form still parses and leaves auto off
        let spec = SweepSpec::from_json(r#"{"workload": {"trace": "t"}, "reps": 32}"#).unwrap();
        assert_eq!((spec.reps, spec.auto_reps), (32, None));
    }

    #[test]
    fn malformed_auto_reps_are_rejected() {
        for bad in [
            r#"{"workload": {"trace": "t"}, "reps": {}}"#,
            r#"{"workload": {"trace": "t"}, "reps": {"eps": 0.05, "max": 10}}"#,
            r#"{"workload": {"trace": "t"}, "reps": {"auto": 100}}"#,
            r#"{"workload": {"trace": "t"}, "reps": {"auto": {}}}"#,
            r#"{"workload": {"trace": "t"}, "reps": {"auto": {"eps": 0.05}}}"#,
            r#"{"workload": {"trace": "t"}, "reps": {"auto": {"max": 100}}}"#,
            r#"{"workload": {"trace": "t"}, "reps": {"auto": {"eps": 0, "max": 100}}}"#,
            r#"{"workload": {"trace": "t"}, "reps": {"auto": {"eps": -0.1, "max": 100}}}"#,
            r#"{"workload": {"trace": "t"}, "reps": {"auto": {"eps": 0.05, "max": 0}}}"#,
            r#"{"workload": {"trace": "t"}, "reps": {"auto": {"eps": 0.05, "max": 1.5}}}"#,
            r#"{"workload": {"trace": "t"}, "reps": {"auto": {"eps": 0.05, "max": 10, "min": 2}}}"#,
            r#"{"workload": {"trace": "t"}, "reps": {"auto": {"eps": 0.05, "max": 10}, "x": 1}}"#,
        ] {
            assert!(SweepSpec::from_json(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn batches_divisors_keyword() {
        let spec = SweepSpec::from_json(
            r#"{"workload": {"trace": "t"}, "batches": "divisors"}"#,
        )
        .unwrap();
        assert_eq!(spec.batches, None);
    }

    #[test]
    fn missing_workload_trace_load_errors() {
        assert!(SweepSpec::for_trace().load_trace().is_err());
    }
}
