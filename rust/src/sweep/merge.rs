//! Deterministic merge of per-shard sweep stores.
//!
//! A multi-process sweep runs `replica sweep --spec FILE --shard K/M`
//! once per shard: each process owns a contiguous slice of the grid
//! ([`crate::sweep::grid::shard_range`]) and a private store file
//! ([`shard_path`]) headed by the sweep's identity key, so M writers
//! never contend for one file. [`merge`] stitches those shard files
//! back into the canonical grid-ordered store.
//!
//! The merged output is **byte-identical to a single-process run** of
//! the same spec. That falls out of two properties the engine already
//! guarantees: every case's estimate depends only on its content key
//! (its RNG stream is `substream(spec.seed, key)`, independent of shard
//! boundaries), and record rendering is a pure function of case +
//! outcome (sorted keys, shortest-roundtrip floats). The merge
//! therefore re-renders each record from the expanded grid and the
//! shard-recorded outcome, in grid order — the exact bytes a lone
//! process would have streamed. CI's `sweep-shard-determinism` job
//! `cmp`s the two files on every run.
//!
//! Failure handling is conservative: a shard file from a different
//! sweep (mismatched sweep key) is refused, missing shard files and
//! incomplete shards abort with the unfinished cases named (resume the
//! shard and re-merge), and overlapping shards are tolerated only if
//! their duplicate records agree byte-for-byte — a disagreement means
//! the determinism contract broke, which must never be papered over.
//!
//! [`merge_partial`] relaxes exactly one of those refusals: an
//! *incomplete* grid. It writes the longest contiguous covered prefix
//! (a valid, resumable store — the same shape a killed single-process
//! run leaves behind) and reports every uncovered index range instead
//! of erroring, so an operator can see what is left while shards (or
//! cluster workers) are still running. All other refusals — foreign
//! sweeps, corrupt records, byte-level disagreement — stay hard errors.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::sweep::grid::{ScenarioSet, SweepCase};
use crate::sweep::store::{parse_record, parse_shard_header, render_record, CaseOutcome};
use crate::util::error::{Error, Result};

/// Summary of one merge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MergeReport {
    /// Shard files read.
    pub shards: usize,
    /// Cases written to the canonical store (= the full grid).
    pub cases: usize,
    /// Records seen more than once across shard files (overlapping
    /// shard ranges); each duplicate was verified byte-identical.
    pub duplicates: usize,
}

/// One contiguous run of grid indices no shard file covered.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MissingRange {
    /// First uncovered grid index (inclusive).
    pub lo: usize,
    /// One past the last uncovered grid index.
    pub hi: usize,
    /// Content key of the first uncovered case — the stable name to
    /// look the range up by, independent of grid re-expansion.
    pub first_key: u64,
}

impl MissingRange {
    /// Number of uncovered cases in this range.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    /// A `MissingRange` always holds at least one case.
    pub fn is_empty(&self) -> bool {
        false
    }
}

/// Summary of one [`merge_partial`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartialMergeReport {
    /// Shard files read.
    pub shards: usize,
    /// Cases in the full grid.
    pub cases: usize,
    /// Cases written to the store: the longest contiguous covered
    /// prefix of the grid.
    pub merged: usize,
    /// Cases covered *somewhere* in the inputs (prefix + islands past
    /// the first gap; the islands stay in their shard files and are
    /// picked up by a later merge).
    pub covered: usize,
    /// Byte-verified duplicate records across overlapping shards.
    pub duplicates: usize,
    /// Every uncovered index range, in grid order. Empty means the
    /// grid is complete and the output equals a strict [`merge`].
    pub missing: Vec<MissingRange>,
}

/// Conventional per-shard store path for canonical output `out`:
/// `results.jsonl` → `results.shard-K-of-M.jsonl` (a missing `.jsonl`
/// extension is simply appended to).
pub fn shard_path(out: &Path, k: usize, m: usize) -> PathBuf {
    let full = out.to_string_lossy();
    let stem = full.strip_suffix(".jsonl").unwrap_or(&full);
    PathBuf::from(format!("{stem}.shard-{k}-of-{m}.jsonl"))
}

/// Merge the `m` conventionally-named shard files of `out` (as written
/// by `m` processes running `--shard 0/m .. --shard m-1/m`) into the
/// canonical store at `out`.
pub fn merge_shards(
    set: &ScenarioSet,
    out: &Path,
    m: usize,
) -> Result<(MergeReport, Vec<CaseOutcome>)> {
    if m == 0 {
        return Err(Error::Config("merge needs a shard count >= 1".into()));
    }
    let files: Vec<PathBuf> = (0..m).map(|k| shard_path(out, k, m)).collect();
    merge(set, &files, out)
}

/// Merge explicit shard files into the canonical store at `out`.
/// Shard files may come from different shardings of the same sweep
/// (e.g. a 2-way and a 4-way run) and may overlap; together they must
/// cover the whole grid. Returns the report plus every case's outcome
/// in grid order, so callers can build gain reports without re-reading
/// the store they just wrote.
pub fn merge(
    set: &ScenarioSet,
    shard_files: &[PathBuf],
    out: &Path,
) -> Result<(MergeReport, Vec<CaseOutcome>)> {
    let (duplicates, outcomes) = load_outcomes(set, shard_files)?;
    let missing = outcomes.iter().filter(|outcome| outcome.is_none()).count();
    let first_gap = set
        .cases
        .iter()
        .zip(&outcomes)
        .find(|(_, outcome)| outcome.is_none())
        .map(|(case, _)| case);
    if let Some(first) = first_gap {
        return Err(Error::Config(format!(
            "merge is missing {missing} of {} cases (first: {} — job {}, B={}); \
             run the unfinished shard(s) to completion and re-merge \
             (or pass --allow-partial for the covered prefix)",
            set.cases.len(),
            first.key_hex(),
            first.job_id,
            first.batches()
        )));
    }
    // every slot is Some: `first_gap` above found no gap
    let outcomes: Vec<CaseOutcome> = outcomes.into_iter().flatten().collect();
    write_store(set.cases.iter().zip(&outcomes), out)?;
    let report =
        MergeReport { shards: shard_files.len(), cases: set.cases.len(), duplicates };
    Ok((report, outcomes))
}

/// Merge what the shard files hold *so far*: write the longest
/// contiguous covered prefix of the grid to `out` (a valid store that
/// any later run, merge, or `cluster-serve` restart resumes from) and
/// report every uncovered range instead of refusing. Covered islands
/// past the first gap are not written — they stay in their shard files
/// and cost nothing to re-merge later.
pub fn merge_partial(
    set: &ScenarioSet,
    shard_files: &[PathBuf],
    out: &Path,
) -> Result<PartialMergeReport> {
    let (duplicates, outcomes) = load_outcomes(set, shard_files)?;
    let merged = outcomes.iter().take_while(|outcome| outcome.is_some()).count();
    let covered = outcomes.iter().filter(|outcome| outcome.is_some()).count();
    write_store(
        set.cases
            .iter()
            .zip(&outcomes)
            .take(merged)
            .filter_map(|(case, outcome)| outcome.as_ref().map(|o| (case, o))),
        out,
    )?;
    let mut missing = Vec::new();
    let mut i = 0;
    while i < outcomes.len() {
        if outcomes[i].is_some() {
            i += 1;
            continue;
        }
        let lo = i;
        while i < outcomes.len() && outcomes[i].is_none() {
            i += 1;
        }
        missing.push(MissingRange { lo, hi: i, first_key: set.cases[lo].key });
    }
    Ok(PartialMergeReport {
        shards: shard_files.len(),
        cases: set.cases.len(),
        merged,
        covered,
        duplicates,
        missing,
    })
}

/// Render the given `(case, outcome)` records in order and publish them
/// at `out` via write-then-rename: a kill mid-merge never leaves a torn
/// canonical store (and an existing store is replaced atomically).
fn write_store<'a>(
    records: impl Iterator<Item = (&'a SweepCase, &'a CaseOutcome)>,
    out: &Path,
) -> Result<()> {
    let mut text = String::new();
    for (case, outcome) in records {
        text.push_str(&render_record(case, outcome));
        text.push('\n');
    }
    let tmp = PathBuf::from(format!("{}.tmp", out.display()));
    std::fs::write(&tmp, &text)?;
    std::fs::rename(&tmp, out)?;
    Ok(())
}

/// The shared loading pass: read every shard file, validate headers
/// against this sweep's identity, place each record at its grid index,
/// and byte-verify overlaps. Returns the duplicate count and the
/// per-index outcomes (`None` = no shard covered that case).
fn load_outcomes(
    set: &ScenarioSet,
    shard_files: &[PathBuf],
) -> Result<(usize, Vec<Option<CaseOutcome>>)> {
    if shard_files.is_empty() {
        return Err(Error::Config("merge needs at least one shard file".into()));
    }
    let sweep_key = set.sweep_key();
    let index_of: BTreeMap<u64, usize> =
        set.cases.iter().map(|case| (case.key, case.index)).collect();
    let mut outcomes: Vec<Option<CaseOutcome>> = vec![None; set.cases.len()];
    let mut duplicates = 0usize;
    for path in shard_files {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!(
                "cannot read shard file {}: {e} (was that shard run?)",
                path.display()
            ))
        })?;
        let mut lines = text.split_inclusive('\n').filter_map(|line| {
            // ignore a torn trailing line (the shard was killed after
            // its last flush); the cases it held simply stay missing
            line.strip_suffix('\n')
        });
        let header = lines.next().and_then(parse_shard_header).ok_or_else(|| {
            Error::Config(format!(
                "{} is not a shard store (first line is not a shard header); \
                 merge inputs must be files written by `sweep --shard K/M`",
                path.display()
            ))
        })?;
        if header.sweep_key != sweep_key {
            return Err(Error::Config(format!(
                "shard file {} belongs to a different sweep \
                 (sweep key {:016x}, this spec expands to {sweep_key:016x}); \
                 refusing to merge — check the spec, seed, and reps match the run",
                path.display(),
                header.sweep_key
            )));
        }
        for line in lines {
            let (key, outcome) = parse_record(line).map_err(|e| {
                Error::Parse(format!("corrupt record in {}: {e}", path.display()))
            })?;
            let Some(&index) = index_of.get(&key) else {
                return Err(Error::Config(format!(
                    "shard file {} holds record {key:016x}, which is not in this grid \
                     despite a matching sweep key — the file is corrupt",
                    path.display()
                )));
            };
            match &outcomes[index] {
                None => outcomes[index] = Some(outcome),
                Some(existing) => {
                    duplicates += 1;
                    let case = &set.cases[index];
                    if render_record(case, existing) != render_record(case, &outcome) {
                        return Err(Error::Config(format!(
                            "shard files disagree on case {key:016x} (job {}, B={}): \
                             the determinism contract is broken; refusing to merge",
                            case.job_id,
                            case.batches()
                        )));
                    }
                }
            }
        }
    }
    Ok((duplicates, outcomes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_path_convention() {
        assert_eq!(
            shard_path(Path::new("results.jsonl"), 0, 4),
            PathBuf::from("results.shard-0-of-4.jsonl")
        );
        assert_eq!(
            shard_path(Path::new("/tmp/x/r.jsonl"), 3, 4),
            PathBuf::from("/tmp/x/r.shard-3-of-4.jsonl")
        );
        // no .jsonl suffix: the shard tag is appended
        assert_eq!(
            shard_path(Path::new("store"), 1, 2),
            PathBuf::from("store.shard-1-of-2.jsonl")
        );
    }

    #[test]
    fn merge_refuses_empty_inputs() {
        let set = ScenarioSet { cases: Vec::new() };
        assert!(merge(&set, &[], Path::new("/tmp/never.jsonl")).is_err());
        assert!(merge_shards(&set, Path::new("/tmp/never.jsonl"), 0).is_err());
    }
    // end-to-end merge behavior (byte identity, overlap, refusal,
    // resume) is covered by tests/sweep_merge.rs
}
