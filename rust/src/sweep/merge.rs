//! Deterministic merge of per-shard sweep stores.
//!
//! A multi-process sweep runs `replica sweep --spec FILE --shard K/M`
//! once per shard: each process owns a contiguous slice of the grid
//! ([`crate::sweep::grid::shard_range`]) and a private store file
//! ([`shard_path`]) headed by the sweep's identity key, so M writers
//! never contend for one file. [`merge`] stitches those shard files
//! back into the canonical grid-ordered store.
//!
//! The merged output is **byte-identical to a single-process run** of
//! the same spec. That falls out of two properties the engine already
//! guarantees: every case's estimate depends only on its content key
//! (its RNG stream is `substream(spec.seed, key)`, independent of shard
//! boundaries), and record rendering is a pure function of case +
//! outcome (sorted keys, shortest-roundtrip floats). The merge
//! therefore re-renders each record from the expanded grid and the
//! shard-recorded outcome, in grid order — the exact bytes a lone
//! process would have streamed. CI's `sweep-shard-determinism` job
//! `cmp`s the two files on every run.
//!
//! Failure handling is conservative: a shard file from a different
//! sweep (mismatched sweep key) is refused, missing shard files and
//! incomplete shards abort with the unfinished cases named (resume the
//! shard and re-merge), and overlapping shards are tolerated only if
//! their duplicate records agree byte-for-byte — a disagreement means
//! the determinism contract broke, which must never be papered over.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::sweep::grid::ScenarioSet;
use crate::sweep::store::{parse_record, parse_shard_header, render_record, CaseOutcome};
use crate::util::error::{Error, Result};

/// Summary of one merge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MergeReport {
    /// Shard files read.
    pub shards: usize,
    /// Cases written to the canonical store (= the full grid).
    pub cases: usize,
    /// Records seen more than once across shard files (overlapping
    /// shard ranges); each duplicate was verified byte-identical.
    pub duplicates: usize,
}

/// Conventional per-shard store path for canonical output `out`:
/// `results.jsonl` → `results.shard-K-of-M.jsonl` (a missing `.jsonl`
/// extension is simply appended to).
pub fn shard_path(out: &Path, k: usize, m: usize) -> PathBuf {
    let full = out.to_string_lossy();
    let stem = full.strip_suffix(".jsonl").unwrap_or(&full);
    PathBuf::from(format!("{stem}.shard-{k}-of-{m}.jsonl"))
}

/// Merge the `m` conventionally-named shard files of `out` (as written
/// by `m` processes running `--shard 0/m .. --shard m-1/m`) into the
/// canonical store at `out`.
pub fn merge_shards(
    set: &ScenarioSet,
    out: &Path,
    m: usize,
) -> Result<(MergeReport, Vec<CaseOutcome>)> {
    if m == 0 {
        return Err(Error::Config("merge needs a shard count >= 1".into()));
    }
    let files: Vec<PathBuf> = (0..m).map(|k| shard_path(out, k, m)).collect();
    merge(set, &files, out)
}

/// Merge explicit shard files into the canonical store at `out`.
/// Shard files may come from different shardings of the same sweep
/// (e.g. a 2-way and a 4-way run) and may overlap; together they must
/// cover the whole grid. Returns the report plus every case's outcome
/// in grid order, so callers can build gain reports without re-reading
/// the store they just wrote.
pub fn merge(
    set: &ScenarioSet,
    shard_files: &[PathBuf],
    out: &Path,
) -> Result<(MergeReport, Vec<CaseOutcome>)> {
    if shard_files.is_empty() {
        return Err(Error::Config("merge needs at least one shard file".into()));
    }
    let sweep_key = set.sweep_key();
    let index_of: BTreeMap<u64, usize> =
        set.cases.iter().map(|case| (case.key, case.index)).collect();
    let mut outcomes: Vec<Option<CaseOutcome>> = vec![None; set.cases.len()];
    let mut duplicates = 0usize;
    for path in shard_files {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!(
                "cannot read shard file {}: {e} (was that shard run?)",
                path.display()
            ))
        })?;
        let mut lines = text.split_inclusive('\n').filter_map(|line| {
            // ignore a torn trailing line (the shard was killed after
            // its last flush); the cases it held simply stay missing
            line.strip_suffix('\n')
        });
        let header = lines.next().and_then(parse_shard_header).ok_or_else(|| {
            Error::Config(format!(
                "{} is not a shard store (first line is not a shard header); \
                 merge inputs must be files written by `sweep --shard K/M`",
                path.display()
            ))
        })?;
        if header.sweep_key != sweep_key {
            return Err(Error::Config(format!(
                "shard file {} belongs to a different sweep \
                 (sweep key {:016x}, this spec expands to {sweep_key:016x}); \
                 refusing to merge — check the spec, seed, and reps match the run",
                path.display(),
                header.sweep_key
            )));
        }
        for line in lines {
            let (key, outcome) = parse_record(line).map_err(|e| {
                Error::Parse(format!("corrupt record in {}: {e}", path.display()))
            })?;
            let Some(&index) = index_of.get(&key) else {
                return Err(Error::Config(format!(
                    "shard file {} holds record {key:016x}, which is not in this grid \
                     despite a matching sweep key — the file is corrupt",
                    path.display()
                )));
            };
            match &outcomes[index] {
                None => outcomes[index] = Some(outcome),
                Some(existing) => {
                    duplicates += 1;
                    let case = &set.cases[index];
                    if render_record(case, existing) != render_record(case, &outcome) {
                        return Err(Error::Config(format!(
                            "shard files disagree on case {key:016x} (job {}, B={}): \
                             the determinism contract is broken; refusing to merge",
                            case.job_id,
                            case.batches()
                        )));
                    }
                }
            }
        }
    }
    let missing = outcomes.iter().filter(|outcome| outcome.is_none()).count();
    let first_gap = set
        .cases
        .iter()
        .zip(&outcomes)
        .find(|(_, outcome)| outcome.is_none())
        .map(|(case, _)| case);
    if let Some(first) = first_gap {
        return Err(Error::Config(format!(
            "merge is missing {missing} of {} cases (first: {} — job {}, B={}); \
             run the unfinished shard(s) to completion and re-merge",
            set.cases.len(),
            first.key_hex(),
            first.job_id,
            first.batches()
        )));
    }
    // every slot is Some: `first_gap` above found no gap
    let outcomes: Vec<CaseOutcome> = outcomes.into_iter().flatten().collect();
    let mut text = String::new();
    for (case, outcome) in set.cases.iter().zip(&outcomes) {
        text.push_str(&render_record(case, outcome));
        text.push('\n');
    }
    // write-then-rename: a kill mid-merge never leaves a torn canonical
    // store (and an existing store is replaced atomically)
    let tmp = PathBuf::from(format!("{}.tmp", out.display()));
    std::fs::write(&tmp, &text)?;
    std::fs::rename(&tmp, out)?;
    let report =
        MergeReport { shards: shard_files.len(), cases: set.cases.len(), duplicates };
    Ok((report, outcomes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_path_convention() {
        assert_eq!(
            shard_path(Path::new("results.jsonl"), 0, 4),
            PathBuf::from("results.shard-0-of-4.jsonl")
        );
        assert_eq!(
            shard_path(Path::new("/tmp/x/r.jsonl"), 3, 4),
            PathBuf::from("/tmp/x/r.shard-3-of-4.jsonl")
        );
        // no .jsonl suffix: the shard tag is appended
        assert_eq!(
            shard_path(Path::new("store"), 1, 2),
            PathBuf::from("store.shard-1-of-2.jsonl")
        );
    }

    #[test]
    fn merge_refuses_empty_inputs() {
        let set = ScenarioSet { cases: Vec::new() };
        assert!(merge(&set, &[], Path::new("/tmp/never.jsonl")).is_err());
        assert!(merge_shards(&set, Path::new("/tmp/never.jsonl"), 0).is_err());
    }
    // end-to-end merge behavior (byte identity, overlap, refusal,
    // resume) is covered by tests/sweep_merge.rs
}
