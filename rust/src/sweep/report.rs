//! The §VII replication-gain report.
//!
//! Condenses sweep results into the paper's headline question: per
//! job, which redundancy level minimizes the objective, how much does
//! it buy over the no-redundancy baseline (B = N), and what does it
//! cost in predictability? Tail classes come from the same
//! [`TailFit`] classifier the trace pipeline uses, so the report reads
//! like Fig. 12/13 plus the abstract's order-of-magnitude claim.

use std::collections::BTreeMap;

use crate::dist::{TailClass, TailFit};
use crate::metrics::{fnum, Table};
use crate::planner::{choose, Objective, SweepPoint};
use crate::sim::policy::ReplicationPolicy;
use crate::sweep::runner::CaseResult;
use crate::sweep::spec::Backend;
use crate::sweep::store::{parse_record, CaseOutcome};
use crate::traces::Trace;
use crate::util::error::{Error, Result};
use crate::util::json::{parse, Json};

/// One job's replication gain at one (backend, crash, policy) axis
/// point.
#[derive(Clone, Debug)]
pub struct GainRow {
    pub job_id: u64,
    /// Worker budget (= the job's task count).
    pub n: usize,
    /// Requested backend name.
    pub backend: &'static str,
    /// Crash probability of the failure axis (0 = none).
    pub crash: f64,
    /// Replication policy of the policy axis.
    pub policy: ReplicationPolicy,
    /// Tail class of the job's service times (when a trace was given).
    pub tail: Option<TailClass>,
    /// Optimal batch count under the objective (`None` when every
    /// point was all-failed or errored).
    pub optimum: Option<SweepPoint>,
    /// The no-redundancy baseline: the largest B in the grid (= N when
    /// the grid covers the full spectrum). `None` when that exact
    /// point was all-failed or errored — a smaller B must not stand in
    /// for it, or the speedup column would stop measuring
    /// speedup-over-no-redundancy.
    pub baseline: Option<SweepPoint>,
    /// Points whose every Monte-Carlo replication failed coverage.
    pub all_failed_points: usize,
    /// Points that produced per-case errors.
    pub error_points: usize,
}

impl GainRow {
    /// E\[T\](baseline) / E\[T\](B*) — the paper's speedup metric.
    pub fn speedup(&self) -> f64 {
        match (&self.baseline, &self.optimum) {
            (Some(base), Some(opt)) => base.mean / opt.mean,
            _ => f64::NAN,
        }
    }
}

/// Everything the gain report needs from one result-store line. The
/// streaming `sweep-merge --report-only` path parses these straight
/// out of the merged store, so the §VII report never re-expands the
/// spec or re-generates the trace. (Tail classes do need the trace
/// and are reported as `-` on that path.)
#[derive(Clone, Debug)]
pub struct RecordRow {
    pub job_id: u64,
    /// Worker budget (the record's `n` field).
    pub n: usize,
    /// Batch count (the record's `b` field).
    pub batches: usize,
    /// Requested backend (the record's `backend` field).
    pub backend: Backend,
    /// Crash probability (the record's `crash` field).
    pub crash: f64,
    pub outcome: CaseOutcome,
}

/// Parse one result-store line into a [`RecordRow`]. Cache lines are
/// rejected — they key outcomes by content address only and carry no
/// case fields to report on.
pub fn parse_report_line(line: &str) -> Result<RecordRow> {
    let (_, outcome) = parse_record(line)?;
    let doc = parse(line)?;
    let idx = |name: &str| -> Result<usize> {
        doc.get(name).and_then(Json::as_usize).ok_or_else(|| {
            Error::Parse(format!(
                "store record missing '{name}' — cache lines carry no case \
                 fields; report from the merged result store"
            ))
        })
    };
    let backend = doc
        .get("backend")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Parse("store record missing 'backend'".into()))?;
    let crash = doc
        .get("crash")
        .and_then(Json::as_f64)
        .ok_or_else(|| Error::Parse("store record missing 'crash'".into()))?;
    Ok(RecordRow {
        job_id: idx("job")? as u64,
        n: idx("n")?,
        batches: idx("b")?,
        backend: Backend::parse(backend)?,
        crash,
        outcome,
    })
}

/// One case, reduced to the fields the grouping logic needs —
/// constructible both from in-memory [`CaseResult`]s and from parsed
/// store records.
struct CaseView<'a> {
    job_id: u64,
    n: usize,
    batches: usize,
    backend: &'static str,
    crash: f64,
    policy: ReplicationPolicy,
    outcome: &'a CaseOutcome,
}

/// Build the per-job gain rows from sweep results, scoring operating
/// points with the planner's objective rule. Rows come out sorted by
/// (job, backend, crash, policy).
pub fn gain_report(
    results: &[CaseResult],
    trace: Option<&Trace>,
    objective: Objective,
) -> Vec<GainRow> {
    let views: Vec<CaseView> = results
        .iter()
        .map(|r| CaseView {
            job_id: r.case.job_id,
            n: r.case.scenario.workers,
            batches: r.case.batches(),
            backend: r.case.backend.name(),
            crash: r.case.crash(),
            policy: r.case.scenario.replication,
            outcome: &r.outcome,
        })
        .collect();
    let mut tails: BTreeMap<u64, TailClass> = BTreeMap::new();
    gain_rows(
        &views,
        |job_id| {
            trace.map(|t| {
                *tails
                    .entry(job_id)
                    .or_insert_with(|| TailFit::classify(&t.service_times(job_id)).class)
            })
        },
        objective,
    )
}

/// [`gain_report`] over parsed store records — the streaming
/// report-only path. Error records carry no policy field on disk, so
/// they group (and are counted) under the up-front row of their
/// (job, backend, crash) axis point.
pub fn gain_report_from_records(records: &[RecordRow], objective: Objective) -> Vec<GainRow> {
    let views: Vec<CaseView> = records
        .iter()
        .map(|r| CaseView {
            job_id: r.job_id,
            n: r.n,
            batches: r.batches,
            backend: r.backend.name(),
            crash: r.crash,
            policy: match &r.outcome {
                CaseOutcome::Ok(e) => e.policy,
                CaseOutcome::Error(_) => ReplicationPolicy::Upfront,
            },
            outcome: &r.outcome,
        })
        .collect();
    gain_rows(&views, |_| None, objective)
}

fn gain_rows(
    views: &[CaseView],
    mut tail_of: impl FnMut(u64) -> Option<TailClass>,
    objective: Objective,
) -> Vec<GainRow> {
    // group by (job, backend, crash-bits, policy name, t-bits);
    // BTreeMap for stable order (the policy itself carries an f64, so
    // the key holds its canonical name + trigger-time bits instead)
    type GroupKey = (u64, &'static str, u64, &'static str, u64);
    let mut groups: BTreeMap<GroupKey, Vec<&CaseView>> = BTreeMap::new();
    for v in views {
        groups
            .entry((
                v.job_id,
                v.backend,
                v.crash.to_bits(),
                v.policy.name(),
                v.policy.t().unwrap_or(0.0).to_bits(),
            ))
            .or_default()
            .push(v);
    }
    let mut rows = Vec::with_capacity(groups.len());
    for ((job_id, backend, crash_bits, _, _), group) in groups {
        let mut points = Vec::new();
        let mut all_failed_points = 0usize;
        let mut error_points = 0usize;
        for v in &group {
            match v.outcome {
                CaseOutcome::Error(_) => error_points += 1,
                CaseOutcome::Ok(e) if e.all_failed() => all_failed_points += 1,
                CaseOutcome::Ok(e) => points.push(SweepPoint {
                    batches: v.batches,
                    mean: e.mean,
                    cov: e.cov,
                    cost: e.cost,
                    ci95: e.ci95,
                }),
            }
        }
        let optimum = choose(&points, objective);
        // the baseline is the group's largest-B point itself, not the
        // largest B that happened to survive
        let max_b = group.iter().map(|v| v.batches).max().unwrap_or(0);
        let baseline =
            points.iter().find(|p| p.batches == max_b && p.mean.is_finite()).copied();
        rows.push(GainRow {
            job_id,
            n: group[0].n,
            backend,
            crash: f64::from_bits(crash_bits),
            policy: group[0].policy,
            tail: tail_of(job_id),
            optimum,
            baseline,
            all_failed_points,
            error_points,
        });
    }
    rows
}

/// The headline number: the best speedup across all rows (the
/// abstract's "order of magnitude" claim comes from the heavy-tail
/// jobs' rows).
pub fn headline_speedup(rows: &[GainRow]) -> f64 {
    rows.iter().map(GainRow::speedup).filter(|s| s.is_finite()).fold(f64::NAN, f64::max)
}

/// Printable report table.
pub fn gain_table(title: &str, rows: &[GainRow]) -> Table {
    let mut t = Table::new(
        title,
        vec![
            "job", "N", "backend", "crash", "policy", "tail", "B*", "E[T]*", "CoV*",
            "cost*", "E[T] B=N", "CoV B=N", "speedup", "degraded",
        ],
    );
    for row in rows {
        let tail = match row.tail {
            Some(TailClass::HeavyTail) => "heavy",
            Some(TailClass::ExponentialTail) => "exp",
            None => "-",
        };
        let (b_star, mean_star, cov_star, cost_star) = match &row.optimum {
            Some(p) => (
                p.batches.to_string(),
                fnum(p.mean),
                fnum(p.cov),
                if p.cost.is_finite() { fnum(p.cost) } else { "-".into() },
            ),
            None => ("-".into(), "-".into(), "-".into(), "-".into()),
        };
        let (mean_base, cov_base) = match &row.baseline {
            Some(p) => (fnum(p.mean), fnum(p.cov)),
            None => ("-".into(), "-".into()),
        };
        let speedup = row.speedup();
        let speedup_cell = if speedup.is_finite() {
            format!("{}x", fnum(speedup))
        } else {
            "-".into()
        };
        let degraded = if row.all_failed_points + row.error_points > 0 {
            format!("{} failed / {} error", row.all_failed_points, row.error_points)
        } else {
            String::new()
        };
        t.row(vec![
            row.job_id.to_string(),
            row.n.to_string(),
            row.backend.to_string(),
            fnum(row.crash),
            row.policy.label(),
            tail.to_string(),
            b_star,
            mean_star,
            cov_star,
            cost_star,
            mean_base,
            cov_base,
            speedup_cell,
            degraded,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::runner::{run, RunConfig};
    use crate::sweep::spec::SweepSpec;
    use crate::sweep::ScenarioSet;
    use crate::traces::GeneratorConfig;

    #[test]
    fn report_finds_interior_optimum_for_heavy_tail() {
        let trace = GeneratorConfig::paper_workload(100, 7).generate();
        let mut spec = SweepSpec::for_trace();
        spec.reps = 2_000;
        spec.seed = 9;
        spec.jobs = Some(vec![4, 7]); // job 4: big shift; job 7: heavy
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        let results = run(&set, &RunConfig::default()).unwrap();
        let rows = gain_report(&results, Some(&trace), Objective::MeanCompletion);
        assert_eq!(rows.len(), 2);
        let job4 = &rows[0];
        let job7 = &rows[1];
        assert_eq!((job4.job_id, job7.job_id), (4, 7));
        assert_eq!(job4.tail, Some(TailClass::ExponentialTail));
        assert_eq!(job7.tail, Some(TailClass::HeavyTail));
        // baseline is B = N
        assert_eq!(job7.baseline.unwrap().batches, 100);
        // heavy tail: redundancy helps a lot
        assert!(job7.optimum.unwrap().batches < 100);
        assert!(job7.speedup() > 1.5, "speedup {}", job7.speedup());
        let headline = headline_speedup(&rows);
        assert!(headline >= job7.speedup());
        let table = gain_table("gains", &rows);
        assert!(table.render().contains("heavy"));
    }

    #[test]
    fn policy_axis_groups_into_separate_rows() {
        let trace = GeneratorConfig::paper_workload(12, 3).generate();
        let mut spec = SweepSpec::for_trace();
        spec.reps = 200;
        spec.seed = 3;
        spec.jobs = Some(vec![1]);
        spec.policies = vec![
            ReplicationPolicy::Upfront,
            ReplicationPolicy::SpeculativeAt { t: 2.0 },
            ReplicationPolicy::SpeculativeAt { t: 4.0 },
        ];
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        let results = run(&set, &RunConfig::default()).unwrap();
        let rows = gain_report(&results, Some(&trace), Objective::MeanCompletion);
        // one row per policy axis point, each over the full B spectrum
        assert_eq!(rows.len(), 3);
        let mut labels: Vec<String> = rows.iter().map(|r| r.policy.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 3, "distinct t must not collapse into one row");
        for row in &rows {
            let opt = row.optimum.as_ref().unwrap();
            if row.policy.is_upfront() {
                assert!(opt.cost.is_nan(), "up-front store records carry no cost");
            } else {
                assert!(opt.cost.is_finite() && opt.cost > 0.0);
            }
        }
        let rendered = gain_table("gains", &rows).render();
        assert!(rendered.contains("policy"));
        assert!(rendered.contains("speculative(t=2)"));
    }

    #[test]
    fn record_level_report_matches_the_in_memory_report() {
        use crate::sweep::store::render_record;
        let trace = GeneratorConfig::paper_workload(12, 3).generate();
        let mut spec = SweepSpec::for_trace();
        spec.reps = 150;
        spec.seed = 11;
        spec.jobs = Some(vec![1, 6]);
        spec.policies = vec![
            ReplicationPolicy::Upfront,
            ReplicationPolicy::SpeculativeAt { t: 2.0 },
        ];
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        let results = run(&set, &RunConfig::default()).unwrap();
        // re-parse what the store would hold, as --report-only does
        let records: Vec<RecordRow> = results
            .iter()
            .map(|r| parse_report_line(&render_record(&r.case, &r.outcome)).unwrap())
            .collect();
        let from_memory = gain_report(&results, None, Objective::MeanCompletion);
        let from_records = gain_report_from_records(&records, Objective::MeanCompletion);
        assert_eq!(from_memory.len(), from_records.len());
        for (a, b) in from_memory.iter().zip(&from_records) {
            assert_eq!((a.job_id, a.backend, a.n), (b.job_id, b.backend, b.n));
            assert_eq!(a.policy, b.policy);
            assert_eq!(a.tail, b.tail, "no trace on either path: both None");
            let (ao, bo) = (a.optimum.as_ref().unwrap(), b.optimum.as_ref().unwrap());
            assert_eq!(ao.batches, bo.batches);
            assert_eq!(ao.mean.to_bits(), bo.mean.to_bits());
            assert_eq!(ao.cost.to_bits(), bo.cost.to_bits());
            assert_eq!(a.speedup().to_bits(), b.speedup().to_bits());
        }
        // cache lines are not reportable
        let cache_like = r#"{"key":"00000000000000aa","error":"x"}"#;
        assert!(parse_report_line(cache_like).is_err());
    }

    #[test]
    fn degraded_points_are_counted_not_fatal() {
        let trace = GeneratorConfig::paper_workload(12, 3).generate();
        let mut spec = SweepSpec::for_trace();
        spec.reps = 50;
        spec.jobs = Some(vec![1]);
        spec.crash = vec![1.0]; // every worker crashes: all points all-failed
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        let results = run(&set, &RunConfig::default()).unwrap();
        let rows = gain_report(&results, Some(&trace), Objective::MeanCompletion);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].all_failed_points, 6); // all 6 divisors of 12
        assert!(rows[0].optimum.is_none());
        assert!(rows[0].baseline.is_none(), "a failed B=N point must not be substituted");
        assert!(rows[0].speedup().is_nan());
        assert!(headline_speedup(&rows).is_nan());
        let rendered = gain_table("gains", &rows).render();
        assert!(rendered.contains("6 failed"));
    }
}
