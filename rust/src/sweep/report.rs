//! The §VII replication-gain report.
//!
//! Condenses sweep results into the paper's headline question: per
//! job, which redundancy level minimizes the objective, how much does
//! it buy over the no-redundancy baseline (B = N), and what does it
//! cost in predictability? Tail classes come from the same
//! [`TailFit`] classifier the trace pipeline uses, so the report reads
//! like Fig. 12/13 plus the abstract's order-of-magnitude claim.

use std::collections::BTreeMap;

use crate::dist::{TailClass, TailFit};
use crate::metrics::{fnum, Table};
use crate::planner::{choose, Objective, SweepPoint};
use crate::sweep::runner::CaseResult;
use crate::sweep::store::CaseOutcome;
use crate::traces::Trace;

/// One job's replication gain at one (backend, crash) axis point.
#[derive(Clone, Debug)]
pub struct GainRow {
    pub job_id: u64,
    /// Worker budget (= the job's task count).
    pub n: usize,
    /// Requested backend name.
    pub backend: &'static str,
    /// Crash probability of the failure axis (0 = none).
    pub crash: f64,
    /// Tail class of the job's service times (when a trace was given).
    pub tail: Option<TailClass>,
    /// Optimal batch count under the objective (`None` when every
    /// point was all-failed or errored).
    pub optimum: Option<SweepPoint>,
    /// The no-redundancy baseline: the largest B in the grid (= N when
    /// the grid covers the full spectrum). `None` when that exact
    /// point was all-failed or errored — a smaller B must not stand in
    /// for it, or the speedup column would stop measuring
    /// speedup-over-no-redundancy.
    pub baseline: Option<SweepPoint>,
    /// Points whose every Monte-Carlo replication failed coverage.
    pub all_failed_points: usize,
    /// Points that produced per-case errors.
    pub error_points: usize,
}

impl GainRow {
    /// E\[T\](baseline) / E\[T\](B*) — the paper's speedup metric.
    pub fn speedup(&self) -> f64 {
        match (&self.baseline, &self.optimum) {
            (Some(base), Some(opt)) => base.mean / opt.mean,
            _ => f64::NAN,
        }
    }
}

/// Build the per-job gain rows from sweep results, scoring operating
/// points with the planner's objective rule. Rows come out sorted by
/// (job, backend, crash).
pub fn gain_report(
    results: &[CaseResult],
    trace: Option<&Trace>,
    objective: Objective,
) -> Vec<GainRow> {
    // group by (job, backend, crash-bits); BTreeMap for stable order
    let mut groups: BTreeMap<(u64, &'static str, u64), Vec<&CaseResult>> = BTreeMap::new();
    for r in results {
        groups
            .entry((r.case.job_id, r.case.backend.name(), r.case.crash().to_bits()))
            .or_default()
            .push(r);
    }
    let mut tails: BTreeMap<u64, TailClass> = BTreeMap::new();
    let mut rows = Vec::with_capacity(groups.len());
    for ((job_id, backend, crash_bits), group) in groups {
        let mut points = Vec::new();
        let mut all_failed_points = 0usize;
        let mut error_points = 0usize;
        for r in &group {
            match &r.outcome {
                CaseOutcome::Error(_) => error_points += 1,
                CaseOutcome::Ok(e) if e.all_failed() => all_failed_points += 1,
                CaseOutcome::Ok(e) => points.push(SweepPoint {
                    batches: r.case.batches(),
                    mean: e.mean,
                    cov: e.cov,
                }),
            }
        }
        let optimum = choose(&points, objective);
        // the baseline is the group's largest-B point itself, not the
        // largest B that happened to survive
        let max_b = group.iter().map(|r| r.case.batches()).max().unwrap_or(0);
        let baseline =
            points.iter().find(|p| p.batches == max_b && p.mean.is_finite()).copied();
        let tail = trace.map(|t| {
            *tails
                .entry(job_id)
                .or_insert_with(|| TailFit::classify(&t.service_times(job_id)).class)
        });
        rows.push(GainRow {
            job_id,
            n: group[0].case.scenario.workers,
            backend,
            crash: f64::from_bits(crash_bits),
            tail,
            optimum,
            baseline,
            all_failed_points,
            error_points,
        });
    }
    rows
}

/// The headline number: the best speedup across all rows (the
/// abstract's "order of magnitude" claim comes from the heavy-tail
/// jobs' rows).
pub fn headline_speedup(rows: &[GainRow]) -> f64 {
    rows.iter().map(GainRow::speedup).filter(|s| s.is_finite()).fold(f64::NAN, f64::max)
}

/// Printable report table.
pub fn gain_table(title: &str, rows: &[GainRow]) -> Table {
    let mut t = Table::new(
        title,
        vec![
            "job", "N", "backend", "crash", "tail", "B*", "E[T]*", "CoV*", "E[T] B=N",
            "CoV B=N", "speedup", "degraded",
        ],
    );
    for row in rows {
        let tail = match row.tail {
            Some(TailClass::HeavyTail) => "heavy",
            Some(TailClass::ExponentialTail) => "exp",
            None => "-",
        };
        let (b_star, mean_star, cov_star) = match &row.optimum {
            Some(p) => (p.batches.to_string(), fnum(p.mean), fnum(p.cov)),
            None => ("-".into(), "-".into(), "-".into()),
        };
        let (mean_base, cov_base) = match &row.baseline {
            Some(p) => (fnum(p.mean), fnum(p.cov)),
            None => ("-".into(), "-".into()),
        };
        let speedup = row.speedup();
        let speedup_cell = if speedup.is_finite() {
            format!("{}x", fnum(speedup))
        } else {
            "-".into()
        };
        let degraded = if row.all_failed_points + row.error_points > 0 {
            format!("{} failed / {} error", row.all_failed_points, row.error_points)
        } else {
            String::new()
        };
        t.row(vec![
            row.job_id.to_string(),
            row.n.to_string(),
            row.backend.to_string(),
            fnum(row.crash),
            tail.to_string(),
            b_star,
            mean_star,
            cov_star,
            mean_base,
            cov_base,
            speedup_cell,
            degraded,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::runner::{run, RunConfig};
    use crate::sweep::spec::SweepSpec;
    use crate::sweep::ScenarioSet;
    use crate::traces::GeneratorConfig;

    #[test]
    fn report_finds_interior_optimum_for_heavy_tail() {
        let trace = GeneratorConfig::paper_workload(100, 7).generate();
        let mut spec = SweepSpec::for_trace();
        spec.reps = 2_000;
        spec.seed = 9;
        spec.jobs = Some(vec![4, 7]); // job 4: big shift; job 7: heavy
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        let results = run(&set, &RunConfig::default()).unwrap();
        let rows = gain_report(&results, Some(&trace), Objective::MeanCompletion);
        assert_eq!(rows.len(), 2);
        let job4 = &rows[0];
        let job7 = &rows[1];
        assert_eq!((job4.job_id, job7.job_id), (4, 7));
        assert_eq!(job4.tail, Some(TailClass::ExponentialTail));
        assert_eq!(job7.tail, Some(TailClass::HeavyTail));
        // baseline is B = N
        assert_eq!(job7.baseline.unwrap().batches, 100);
        // heavy tail: redundancy helps a lot
        assert!(job7.optimum.unwrap().batches < 100);
        assert!(job7.speedup() > 1.5, "speedup {}", job7.speedup());
        let headline = headline_speedup(&rows);
        assert!(headline >= job7.speedup());
        let table = gain_table("gains", &rows);
        assert!(table.render().contains("heavy"));
    }

    #[test]
    fn degraded_points_are_counted_not_fatal() {
        let trace = GeneratorConfig::paper_workload(12, 3).generate();
        let mut spec = SweepSpec::for_trace();
        spec.reps = 50;
        spec.jobs = Some(vec![1]);
        spec.crash = vec![1.0]; // every worker crashes: all points all-failed
        let set = ScenarioSet::from_trace(&trace, &spec).unwrap();
        let results = run(&set, &RunConfig::default()).unwrap();
        let rows = gain_report(&results, Some(&trace), Objective::MeanCompletion);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].all_failed_points, 6); // all 6 divisors of 12
        assert!(rows[0].optimum.is_none());
        assert!(rows[0].baseline.is_none(), "a failed B=N point must not be substituted");
        assert!(rows[0].speedup().is_nan());
        assert!(headline_speedup(&rows).is_nan());
        let rendered = gain_table("gains", &rows).render();
        assert!(rendered.contains("6 failed"));
    }
}
