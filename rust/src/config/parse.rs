//! Flat TOML-subset parser: `[section]` headers + `key = value` pairs.
//!
//! Values: integers, floats, booleans, double-quoted strings. Keys are
//! exposed as `"section.key"`. Comments (`#`) and blank lines ignored.
//! This covers every config file the crate ships; it is *not* a general
//! TOML implementation.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl TomlValue {
    pub fn as_int(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => Err(Error::Parse(format!("expected integer, got {other:?}"))),
        }
    }

    pub fn as_float(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => Err(Error::Parse(format!("expected float, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(Error::Parse(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => Err(Error::Parse(format!("expected string, got {other:?}"))),
        }
    }
}

/// A parsed document: dotted-path -> value.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, dotted: &str) -> Option<&TomlValue> {
        self.values.get(dotted)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| Error::Parse(format!("line {}: unclosed [section]", lineno + 1)))?;
            section = name.trim().to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| Error::Parse(format!("line {}: expected key = value", lineno + 1)))?;
        let key = line[..eq].trim();
        let value = line[eq + 1..].trim();
        if key.is_empty() {
            return Err(Error::Parse(format!("line {}: empty key", lineno + 1)));
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.values.insert(full_key, parse_value(value, lineno + 1)?);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue> {
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| Error::Parse(format!("line {lineno}: unterminated string")))?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(Error::Parse(format!("line {lineno}: cannot parse value '{s}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_document() {
        let doc = parse_toml(
            r#"
            top = 1
            [a]
            x = 1.5        # trailing comment
            y = "hi # not a comment"
            flag = true
            big = 1_000_000
            [b]
            x = -3
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("top").unwrap(), &TomlValue::Int(1));
        assert_eq!(doc.get("a.x").unwrap(), &TomlValue::Float(1.5));
        assert_eq!(
            doc.get("a.y").unwrap(),
            &TomlValue::Str("hi # not a comment".into())
        );
        assert_eq!(doc.get("a.flag").unwrap(), &TomlValue::Bool(true));
        assert_eq!(doc.get("a.big").unwrap(), &TomlValue::Int(1_000_000));
        assert_eq!(doc.get("b.x").unwrap(), &TomlValue::Int(-3));
        assert!(doc.get("b.y").is_none());
    }

    #[test]
    fn scientific_floats() {
        let doc = parse_toml("x = 1e-3\ny = 2.5E2\n").unwrap();
        assert_eq!(doc.get("x").unwrap().as_float().unwrap(), 1e-3);
        assert_eq!(doc.get("y").unwrap().as_float().unwrap(), 250.0);
    }

    #[test]
    fn errors() {
        assert!(parse_toml("[unclosed\n").is_err());
        assert!(parse_toml("novalue\n").is_err());
        assert!(parse_toml("x = \"open\n").is_err());
        assert!(parse_toml("x = what\n").is_err());
        assert!(parse_toml(" = 3\n").is_err());
    }

    #[test]
    fn type_coercions() {
        let doc = parse_toml("i = 3\nf = 1.5\n").unwrap();
        assert_eq!(doc.get("i").unwrap().as_float().unwrap(), 3.0);
        assert!(doc.get("f").unwrap().as_int().is_err());
    }
}
