//! Cluster runtime configuration.
//!
//! All socket/timing knobs of the [`crate::cluster`] runtime live
//! here: lease deadlines, heartbeat cadence, lease sizing, and the
//! worker's reconnect backoff. None of these affect sweep *results* —
//! the store is fixed by the content-keyed RNG — only scheduling, so
//! the CLI may tune them freely without re-keying anything.

use crate::util::error::{Error, Result};

/// Timing and sizing knobs for `cluster-serve` / `cluster-work`.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// A lease not renewed within this window is considered dead (its
    /// worker crashed or is straggling) and its slice is reassigned.
    pub lease_timeout_ms: u64,
    /// Target heartbeat cadence; shipped to workers in the welcome
    /// frame so both sides agree. Must be well under
    /// `lease_timeout_ms`.
    pub heartbeat_ms: u64,
    /// Coordinator housekeeping period (lease-expiry sweeps) and the
    /// retry hint sent to workers when no slice is currently leasable.
    pub poll_ms: u64,
    /// Smallest lease (cases) — the tail-end work-stealing granularity.
    pub min_lease: usize,
    /// Largest lease (cases) handed out while the grid is full.
    pub max_lease: usize,
    /// Cases a worker evaluates between heartbeats.
    pub chunk: usize,
    /// First reconnect delay after a dropped connection.
    pub reconnect_base_ms: u64,
    /// Backoff cap for reconnect delays (doubling up to this).
    pub reconnect_max_ms: u64,
    /// Consecutive failed connection attempts before a worker gives up.
    pub max_reconnects: u32,
    /// How long a finished coordinator keeps answering `done` so
    /// trailing workers learn the sweep is over before it exits.
    pub linger_ms: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            lease_timeout_ms: 10_000,
            heartbeat_ms: 2_000,
            poll_ms: 250,
            min_lease: 2,
            max_lease: 64,
            chunk: 8,
            reconnect_base_ms: 200,
            reconnect_max_ms: 5_000,
            max_reconnects: 25,
            linger_ms: 2_000,
        }
    }
}

impl ClusterConfig {
    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.lease_timeout_ms == 0 || self.heartbeat_ms == 0 || self.poll_ms == 0 {
            return Err(Error::Config(
                "cluster timeouts must all be >= 1ms".into(),
            ));
        }
        if self.heartbeat_ms * 2 > self.lease_timeout_ms {
            return Err(Error::Config(format!(
                "heartbeat ({} ms) must be at most half the lease timeout ({} ms), \
                 or every lease would expire between renewals",
                self.heartbeat_ms, self.lease_timeout_ms
            )));
        }
        if self.min_lease == 0 || self.max_lease < self.min_lease {
            return Err(Error::Config(format!(
                "lease sizes must satisfy 1 <= min ({}) <= max ({})",
                self.min_lease, self.max_lease
            )));
        }
        if self.chunk == 0 {
            return Err(Error::Config("chunk must be >= 1 case".into()));
        }
        if self.reconnect_base_ms == 0 || self.reconnect_max_ms < self.reconnect_base_ms {
            return Err(Error::Config(format!(
                "reconnect backoff must satisfy 1 <= base ({}) <= max ({})",
                self.reconnect_base_ms, self.reconnect_max_ms
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ClusterConfig::default().validate().unwrap();
    }

    #[test]
    fn heartbeat_must_fit_in_lease_window() {
        let cfg = ClusterConfig {
            heartbeat_ms: 6_000,
            lease_timeout_ms: 10_000,
            ..ClusterConfig::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("half the lease timeout"), "{err}");
    }

    #[test]
    fn lease_sizes_are_ordered() {
        let cfg = ClusterConfig { min_lease: 10, max_lease: 5, ..ClusterConfig::default() };
        assert!(cfg.validate().is_err());
        let cfg = ClusterConfig { min_lease: 0, ..ClusterConfig::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn reconnect_backoff_is_ordered() {
        let cfg = ClusterConfig {
            reconnect_base_ms: 1_000,
            reconnect_max_ms: 100,
            ..ClusterConfig::default()
        };
        assert!(cfg.validate().is_err());
    }
}
