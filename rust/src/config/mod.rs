//! Typed configuration + a small TOML-subset parser.
//!
//! The CLI and examples read experiment/system settings from
//! `replica.toml`-style files (flat `key = value` pairs under
//! `[section]` headers — the subset we need; no serde offline).

mod cluster;
mod parse;

pub use cluster::ClusterConfig;
pub use parse::{parse_toml, TomlValue};

use crate::dist::ServiceDist;
use crate::util::error::{Error, Result};

/// System-level configuration for the coordinator / simulator.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    /// Worker budget N.
    pub workers: usize,
    /// Batch count B (None = let the planner choose).
    pub batches: Option<usize>,
    /// Task service-time model.
    pub service: ServiceDist,
    /// RNG seed.
    pub seed: u64,
    /// Monte-Carlo replications for simulated estimates.
    pub replications: usize,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            workers: 100,
            batches: None,
            service: ServiceDist::shifted_exp(0.05, 1.0),
            seed: 0,
            replications: 10_000,
        }
    }
}

impl SystemConfig {
    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::Config("workers must be >= 1".into()));
        }
        if let Some(b) = self.batches {
            if b == 0 || self.workers % b != 0 {
                return Err(Error::Config(format!(
                    "batches B={b} must divide workers N={}",
                    self.workers
                )));
            }
        }
        if self.replications == 0 {
            return Err(Error::Config("replications must be >= 1".into()));
        }
        Ok(())
    }

    /// Build from a parsed TOML document. Recognized keys (all optional,
    /// defaults above):
    ///
    /// ```toml
    /// [system]
    /// workers = 100
    /// batches = 10           # omit to auto-plan
    /// seed = 42
    /// replications = 20000
    ///
    /// [service]
    /// family = "sexp"        # exp | sexp | pareto | weibull | bimodal
    /// mu = 1.0
    /// delta = 0.05
    /// sigma = 1.0
    /// alpha = 2.0
    /// shape = 0.8
    /// scale = 1.0
    /// p_slow = 0.1
    /// ```
    pub fn from_toml(text: &str) -> Result<SystemConfig> {
        let doc = parse_toml(text)?;
        let mut cfg = SystemConfig::default();
        if let Some(v) = doc.get("system.workers") {
            cfg.workers = v.as_int()? as usize;
        }
        if let Some(v) = doc.get("system.batches") {
            cfg.batches = Some(v.as_int()? as usize);
        }
        if let Some(v) = doc.get("system.seed") {
            cfg.seed = v.as_int()? as u64;
        }
        if let Some(v) = doc.get("system.replications") {
            cfg.replications = v.as_int()? as usize;
        }
        let get_f = |key: &str, default: f64| -> Result<f64> {
            match doc.get(key) {
                Some(v) => v.as_float(),
                None => Ok(default),
            }
        };
        if let Some(family) = doc.get("service.family") {
            let fam = family.as_str()?;
            cfg.service = match fam {
                "exp" => ServiceDist::exp(get_f("service.mu", 1.0)?),
                "sexp" => ServiceDist::shifted_exp(
                    get_f("service.delta", 0.05)?,
                    get_f("service.mu", 1.0)?,
                ),
                "pareto" => ServiceDist::pareto(
                    get_f("service.sigma", 1.0)?,
                    get_f("service.alpha", 2.0)?,
                ),
                "weibull" => ServiceDist::weibull(
                    get_f("service.shape", 0.8)?,
                    get_f("service.scale", 1.0)?,
                ),
                "bimodal" => ServiceDist::bimodal(
                    get_f("service.p_slow", 0.1)?,
                    (get_f("service.fast_delta", 0.1)?, get_f("service.fast_mu", 10.0)?),
                    (get_f("service.slow_delta", 5.0)?, get_f("service.slow_mu", 1.0)?),
                ),
                other => {
                    return Err(Error::Config(format!("unknown service family '{other}'")))
                }
            };
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        SystemConfig::default().validate().unwrap();
    }

    #[test]
    fn from_toml_full() {
        let cfg = SystemConfig::from_toml(
            r#"
            # comment
            [system]
            workers = 50
            batches = 10
            seed = 7
            replications = 500

            [service]
            family = "pareto"
            sigma = 2.0
            alpha = 1.5
            "#,
        )
        .unwrap();
        assert_eq!(cfg.workers, 50);
        assert_eq!(cfg.batches, Some(10));
        assert_eq!(cfg.seed, 7);
        match cfg.service {
            ServiceDist::Pareto { sigma, alpha } => {
                assert_eq!((sigma, alpha), (2.0, 1.5));
            }
            _ => panic!("wrong family"),
        }
    }

    #[test]
    fn invalid_batches_rejected() {
        let err = SystemConfig::from_toml(
            "[system]\nworkers = 10\nbatches = 3\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("divide"));
    }

    #[test]
    fn unknown_family_rejected() {
        let err =
            SystemConfig::from_toml("[service]\nfamily = \"zipf\"\n").unwrap_err();
        assert!(err.to_string().contains("zipf"));
    }

    #[test]
    fn empty_toml_gives_defaults() {
        let cfg = SystemConfig::from_toml("").unwrap();
        assert_eq!(cfg.workers, 100);
        assert!(cfg.batches.is_none());
    }
}
