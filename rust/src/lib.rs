//! # replica — efficient replication for straggler mitigation
//!
//! A production-style reproduction of *"Efficient Replication for
//! Straggler Mitigation in Distributed Computing"* (Behrouzi-Far &
//! Soljanin, 2020).
//!
//! The crate is organized around one question — *what do job compute
//! times look like for a `(N, policy, τ)` scenario?* — asked through
//! one interface:
//!
//! * [`eval`] — the unified evaluation API. An [`eval::Scenario`] names
//!   the question (including *when* replicas launch, via
//!   [`sim::policy::ReplicationPolicy`]), an [`eval::Estimate`] is the
//!   rich answer (mean ± CI, CoV, p50/p95/p99, expected worker-seconds
//!   cost, failure rate, provenance), and the [`eval::Estimator`] trait
//!   abstracts the backend: exact closed forms ([`eval::Analytic`]), a
//!   thread-parallel seed-stable simulator ([`eval::MonteCarlo`]), or
//!   analytic-with-MC-fallback ([`eval::Auto`]). Everything above —
//!   planner, experiments, CLI, benches — consumes this trait.
//!
//! The substrates underneath:
//!
//! * [`dist`] — service-time distributions (Exponential,
//!   Shifted-Exponential, Pareto, Weibull, Gamma, Bimodal, Empirical),
//!   the [`dist::TailFit`] trace classifier (§VII), and the
//!   size-dependent batch model `T_batch = (N/B)·τ` of §VI (via
//!   [`dist::ServiceDist::scaled`] — every family is closed under
//!   positive scaling).
//! * [`batching`] — the paper's §III task-replication policies:
//!   balanced/unbalanced non-overlapping batches, random
//!   (coupon-collector) assignment, cyclic and hybrid overlapping
//!   schemes.
//! * [`analysis`] — closed forms for E\[T\] and CoV\[T\] (eqs. 18, 19,
//!   21, 22, 24, 26), Stirling-number coverage probabilities (Lemma 1),
//!   majorization (Lemmas 2–3), and the discrete optimizers + regime
//!   classification of Theorems 5–10. The [`eval::Analytic`] backend is
//!   the supported way in.
//! * [`sim`] — the job-level discrete-event simulator that
//!   [`eval::MonteCarlo`] replicates over (with failure injection);
//!   [`sim::policy`] — the replication *timing* family (up-front,
//!   speculative-at-`t`, relaunch-at-`t`) with a completion-time and
//!   worker-seconds cost semantics per member; and [`sim::queue`] —
//!   the open-system serving kernel: Poisson/trace arrivals,
//!   per-worker FIFO queues, batch-replicated placement, and
//!   kill-on-batch-complete, evaluated through [`eval::OpenSystem`]
//!   into sojourn-time percentiles, utilization, and worker-seconds
//!   per job vs offered load ρ (the B*-vs-load curve; `replica
//!   opensys`).
//! * [`planner`] — the redundancy planner: given N and a service-time
//!   model (analytic or fitted from traces), chooses the batch count B
//!   minimizing mean compute time, CoV, a weighted trade-off, or a
//!   cost–latency blend ([`planner::Objective::CostLatency`], searched
//!   jointly over `(B, t)` by [`planner::Planner::plan_joint`]). One
//!   code path ([`planner::Planner::plan_with`]) parameterized by any
//!   [`eval::Estimator`].
//! * [`coordinator`] — a live master–worker engine (threads + channels)
//!   that applies a replication plan to real gradient computations
//!   executed through [`runtime`] (PJRT/XLA artifacts compiled AOT from
//!   JAX+Pallas; Python never runs at serve time).
//! * [`traces`] — a Google-cluster-trace-shaped workload generator,
//!   loader, and tail analyzer (§VII).
//! * [`sweep`] — the sharded, resumable trace-sweep engine: a JSON
//!   spec expands into a content-addressed scenario grid, shards fan
//!   out over the worker pool, results stream to a JSONL store with an
//!   on-disk estimate cache (kill-and-resume is byte-identical,
//!   re-runs are incremental, `--cache-gc` compacts stale keys), and a
//!   replication-gain report summarizes per-job optima per policy
//!   (`replica sweep --spec`, re-printable from a store alone via
//!   `replica sweep-merge --report-only`). Multi-process runs split the
//!   grid with `--shard K/M` into per-shard stores that
//!   `replica sweep-merge` reassembles byte-identically to a
//!   single-process run, and `--cache-import DIR` warms a new run from
//!   earlier caches without touching them. An optional `arrivals` axis
//!   of offered loads routes cases through [`eval::OpenSystem`] for
//!   open-system sweeps.
//! * [`cluster`] — the fault-tolerant multi-process sweep runtime:
//!   `replica cluster-serve` leases grid slices to `replica
//!   cluster-work` processes over a socket protocol with heartbeats,
//!   dead-lease reassignment, and shrinking (work-stealing) leases;
//!   the assembled store stays byte-identical to a single-process
//!   sweep under worker kills and coordinator restarts.
//! * [`experiments`] — one module per paper figure/table; the bench
//!   harness and CLI call into these.
//!
//! `docs/ARCHITECTURE.md` (repo root) is the paper-to-code map: which
//! section/theorem/figure each module reproduces, the end-to-end data
//! flow from spec to published store, the determinism contract, and
//! the `detlint` rules that enforce it at the source level.
//!
//! ## Quickstart
//!
//! ```no_run
//! use replica::dist::ServiceDist;
//! use replica::eval::{Auto, Estimator, Scenario};
//! use replica::planner::{Objective, Planner};
//!
//! // N = 100 workers, task service times ~ SExp(Δ=0.05, μ=1.0)
//! let dist = ServiceDist::shifted_exp(0.05, 1.0);
//!
//! // 1. Ask the planner for the optimal number of batches.
//! let plan = Planner::new(100, dist.clone()).plan(Objective::MeanCompletion);
//! println!("optimal number of batches B = {}", plan.batches);
//!
//! // 2. Evaluate any scenario through the unified estimator API.
//! //    Auto answers with closed forms when exact and falls back to
//! //    seed-stable multi-threaded Monte-Carlo otherwise.
//! let est = Auto::default()
//!     .evaluate(&Scenario::balanced(100, plan.batches, dist))
//!     .unwrap();
//! println!(
//!     "E[T] = {:.4} (p99 {:.4}, via {})",
//!     est.mean,
//!     est.p99,
//!     est.provenance.backend()
//! );
//! ```
//!
//! See `examples/estimator_backends.rs` for the three backends compared
//! side by side on one scenario.
//!
//! ## Performance
//!
//! The Monte-Carlo hot path is built around three mechanisms (see
//! `README.md` under `rust/` for the full notes and bench
//! instructions):
//!
//! * **Persistent worker pool** ([`sim::pool::WorkerPool`]) — one set
//!   of OS threads for the process, shared by every evaluation.
//!   [`eval::MonteCarlo`] carves batches into scenario×replication-chunk
//!   units, so `evaluate_many`/`sweep` keep all cores busy across the
//!   whole batch instead of spawn/joining per scenario. Size it with
//!   `--pool-threads`, `REPLICA_POOL_THREADS`, or
//!   [`sim::pool::WorkerPool::configure_global`].
//! * **Batched sampling** ([`dist::Sampler`], [`dist::AliasTable`]) —
//!   a per-family sampler compiled once per scenario fills slices of
//!   draws with the enum dispatch hoisted out of the loop;
//!   Bimodal/Empirical draw through Walker alias tables in O(1).
//! * **Allocation-free replication loops** — [`sim::SimScratch`]
//!   buffers are reused across a unit's replications
//!   ([`sim::JobSimulator::sample_into`]), disjoint layouts take an
//!   exact-verified `max–min` fast path, and the randomized-assignment
//!   policy simulates straight from batch picks without materializing
//!   layouts.
//!
//! **Determinism contract:** every replication draws from its own
//! counter-based stream ([`eval::substream`]) into its own output
//! slot, and reduction is serial in replication order — estimates are
//! bit-identical for a fixed seed across any thread count, pool width,
//! and between `evaluate_many` item `i` and `evaluate_at(·, i)`.
//! Benches: `cargo bench --bench bench_eval` (add `-- --smoke` for the
//! CI short run; `scripts/bench_snapshot.sh` writes `BENCH_eval.json`).

pub mod analysis;
pub mod batching;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dist;
pub mod eval;
pub mod experiments;
pub mod metrics;
pub mod planner;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod traces;
pub mod util;

pub use util::error::{Error, Result};
