//! # replica — efficient replication for straggler mitigation
//!
//! A production-style reproduction of *"Efficient Replication for
//! Straggler Mitigation in Distributed Computing"* (Behrouzi-Far &
//! Soljanin, 2020).
//!
//! The crate implements the paper's full system and every substrate it
//! depends on:
//!
//! * [`dist`] — service-time distributions (Exponential,
//!   Shifted-Exponential, Pareto, Weibull, Bimodal, Empirical) plus the
//!   size-dependent batch model `T_batch = (N/B)·τ` of Gardner et al.
//! * [`batching`] — the paper's §III task-replication policies:
//!   balanced/unbalanced non-overlapping batches, random
//!   (coupon-collector) assignment, cyclic and hybrid overlapping
//!   schemes.
//! * [`analysis`] — closed forms for E\[T\] and CoV\[T\] (eqs. 18, 19,
//!   21, 22, 24, 26), Stirling-number coverage probabilities (Lemma 1),
//!   majorization (Lemmas 2–3), and the discrete optimizers + regime
//!   classification of Theorems 5–10.
//! * [`sim`] — a discrete-event Monte-Carlo simulator for job compute
//!   time under any policy/distribution pair.
//! * [`planner`] — the redundancy planner: given N and a service-time
//!   model (analytic or fitted from traces), chooses the batch count B
//!   minimizing mean compute time, CoV, or a weighted trade-off.
//! * [`coordinator`] — a live master–worker engine (threads + channels)
//!   that applies a replication plan to real gradient computations
//!   executed through [`runtime`] (PJRT/XLA artifacts compiled AOT from
//!   JAX+Pallas; Python never runs at serve time).
//! * [`traces`] — a Google-cluster-trace-shaped workload generator,
//!   loader, and tail analyzer (§VII).
//! * [`experiments`] — one module per paper figure/table; the bench
//!   harness and CLI call into these.
//!
//! ## Quickstart
//!
//! ```no_run
//! use replica::dist::ServiceDist;
//! use replica::planner::{Planner, Objective};
//!
//! // N = 100 workers, task service times ~ SExp(Δ=0.05, μ=1.0)
//! let dist = ServiceDist::shifted_exp(0.05, 1.0);
//! let plan = Planner::new(100, dist).plan(Objective::MeanCompletion);
//! println!("optimal number of batches B = {}", plan.batches);
//! ```

pub mod analysis;
pub mod batching;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dist;
pub mod experiments;
pub mod metrics;
pub mod planner;
pub mod runtime;
pub mod sim;
pub mod traces;
pub mod util;

pub use util::error::{Error, Result};
