//! CLI subcommand implementations.

use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::cli::args::Args;
use crate::config::{ClusterConfig, SystemConfig};
use crate::coordinator::{Coordinator, Dataset, GdConfig, NativeBackend, PjrtBackend};
use crate::dist::ServiceDist;
use crate::eval::{Analytic, Auto, Estimator, MonteCarlo, Scenario};
use crate::experiments::{self, DEFAULT_REPS};
use crate::metrics::{export_csv, fnum, Table};
use crate::planner::{Objective, Planner, SweepPoint};
use crate::runtime::{artifacts_dir, GradientOps, RuntimeService};
use crate::sim::policy::ReplicationPolicy;
use crate::traces::{load_trace, write_trace, GeneratorConfig, JobAnalysis};
use crate::util::error::{Error, Result};

/// Resolve the service distribution from flags or `--config`.
fn service_from(args: &mut Args) -> Result<ServiceDist> {
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(&path)?;
        return Ok(SystemConfig::from_toml(&text)?.service);
    }
    let family = args.get("family").unwrap_or_else(|| "sexp".to_string());
    Ok(match family.as_str() {
        "exp" => ServiceDist::exp(args.get_f64("mu", 1.0)?),
        "sexp" => {
            ServiceDist::shifted_exp(args.get_f64("delta", 0.05)?, args.get_f64("mu", 1.0)?)
        }
        "pareto" => {
            ServiceDist::pareto(args.get_f64("sigma", 1.0)?, args.get_f64("alpha", 2.0)?)
        }
        "weibull" => {
            ServiceDist::weibull(args.get_f64("shape", 0.8)?, args.get_f64("scale", 1.0)?)
        }
        "gamma" => ServiceDist::gamma_dist(
            args.get_f64("shape", 2.0)?,
            args.get_f64("scale", 1.0)?,
        ),
        "bimodal" => ServiceDist::bimodal(
            args.get_f64("p_slow", 0.1)?,
            (args.get_f64("fast_delta", 0.1)?, args.get_f64("fast_mu", 10.0)?),
            (args.get_f64("slow_delta", 5.0)?, args.get_f64("slow_mu", 1.0)?),
        ),
        other => return Err(Error::Config(format!("unknown family '{other}'"))),
    })
}

fn objective_from(args: &mut Args) -> Result<Objective> {
    match args.get("objective").as_deref() {
        None | Some("mean") => Ok(Objective::MeanCompletion),
        Some("cov") => Ok(Objective::Predictability),
        Some(o) if o.starts_with("tradeoff=") => {
            let w = o["tradeoff=".len()..]
                .parse::<f64>()
                .map_err(|e| Error::Config(format!("bad tradeoff weight: {e}")))?;
            Ok(Objective::Tradeoff(w))
        }
        Some(o) if o.starts_with("cost=") => {
            let w = o["cost=".len()..]
                .parse::<f64>()
                .map_err(|e| Error::Config(format!("bad cost weight: {e}")))?;
            Ok(Objective::CostLatency(w))
        }
        Some(other) => Err(Error::Config(format!("unknown objective '{other}'"))),
    }
}

/// Resolve the replication policy from `--policy NAME` + `--spec-t T`.
/// Absent flags mean the paper's up-front policy.
fn replication_from(args: &mut Args) -> Result<ReplicationPolicy> {
    let name = args.get("policy");
    let t = match args.get("spec-t") {
        None => None,
        Some(v) => Some(
            v.parse::<f64>().map_err(|e| Error::Config(format!("--spec-t {v}: {e}")))?,
        ),
    };
    match name.as_deref() {
        None if t.is_none() => Ok(ReplicationPolicy::Upfront),
        None => Err(Error::Config(
            "--spec-t needs --policy speculative|relaunch".into(),
        )),
        Some(name) => ReplicationPolicy::parse(name, t),
    }
}

/// Format a cost cell: expected total worker-seconds, or `-` when the
/// evaluation path does not track cost.
fn cost_cell(cost: f64) -> String {
    if cost.is_nan() {
        "-".into()
    } else {
        fnum(cost)
    }
}

pub fn plan(args: &mut Args) -> Result<()> {
    let n = args.get_usize("workers", 100)?;
    let tau = service_from(args)?;
    let objective = objective_from(args)?;
    // the cost objective only separates candidates when the launch time
    // is part of the search, so it implies the joint (B, t) planner
    let joint = args.get_bool("joint") || matches!(objective, Objective::CostLatency(_));
    let planner = Planner::new(n, tau.clone());
    let plan = if joint {
        let reps = args.get_usize("reps", DEFAULT_REPS)?;
        let seed = args.get_u64("seed", 0)?;
        planner.plan_joint(objective, reps, seed)?
    } else {
        planner.plan(objective)
    };
    let mut t = Table::new(
        &format!("Redundancy plan: N={n}, tau ~ {}", tau.label()),
        vec!["field", "value"],
    );
    t.row(vec!["batches B*".into(), plan.batches.to_string()]);
    t.row(vec!["batch size".into(), plan.batch_size.to_string()]);
    t.row(vec!["replication".into(), plan.replication.to_string()]);
    t.row(vec!["policy".into(), plan.policy.name().into()]);
    t.row(vec!["replication policy".into(), plan.replication_policy.label()]);
    t.row(vec!["predicted E[T]".into(), fnum(plan.predicted_mean)]);
    t.row(vec!["predicted CoV".into(), fnum(plan.predicted_cov)]);
    t.row(vec!["predicted cost".into(), cost_cell(plan.predicted_cost)]);
    t.row(vec![
        "speedup vs B=N".into(),
        format!("{}x", fnum(plan.speedup_vs_no_redundancy)),
    ]);
    if let Some(r) = plan.regime {
        t.row(vec!["regime".into(), format!("{r:?}")]);
    }
    t.print();
    Ok(())
}

/// Resolve the estimator backend from `--backend mc|analytic|auto`
/// (plus `--reps/--seed/--threads` for the stochastic ones).
///
/// Threading note: `--threads` only caps the per-scenario fan-out of
/// one evaluation; the OS threads themselves come from the persistent
/// process-wide pool sized by `--pool-threads` (handled in
/// [`crate::cli::run`] before dispatch).
fn estimator_from(args: &mut Args) -> Result<Box<dyn Estimator>> {
    let reps = args.get_usize("reps", DEFAULT_REPS)?;
    let seed = args.get_u64("seed", 0)?;
    let threads = args.get_usize("threads", 0)?;
    match args.get("backend").as_deref().unwrap_or("mc") {
        "mc" | "monte-carlo" => {
            Ok(Box::new(MonteCarlo { reps, seed, threads }))
        }
        "analytic" => Ok(Box::new(Analytic)),
        "auto" => Ok(Box::new(Auto {
            fallback: MonteCarlo { reps, seed, threads },
        })),
        other => Err(Error::Config(format!(
            "unknown backend '{other}' (mc | analytic | auto)"
        ))),
    }
}

pub fn simulate(args: &mut Args) -> Result<()> {
    let n = args.get_usize("workers", 100)?;
    let b = args.get_usize("batches", n)?;
    let tau = service_from(args)?;
    let replication = replication_from(args)?;
    let estimator = estimator_from(args)?;
    let scenario = Scenario::balanced(n, b, tau.clone()).with_replication(replication);
    let est = estimator.evaluate(&scenario)?;
    let mut t = Table::new(
        &format!("Evaluation: N={n}, B={b}, tau ~ {}", tau.label()),
        vec!["metric", "value"],
    );
    t.row(vec!["backend".into(), est.provenance.backend().into()]);
    t.row(vec!["replication policy".into(), replication.label()]);
    if est.replications > 0 {
        t.row(vec![
            "replications".into(),
            format!("{} ({} completed)", est.replications, est.completed),
        ]);
    }
    t.row(vec!["mean".into(), format!("{} ± {}", fnum(est.mean), fnum(est.ci95))]);
    t.row(vec!["CoV".into(), fnum(est.cov)]);
    t.row(vec!["p50".into(), fnum(est.p50)]);
    t.row(vec!["p95".into(), fnum(est.p95)]);
    t.row(vec!["p99".into(), fnum(est.p99)]);
    t.row(vec!["cost".into(), cost_cell(est.cost)]);
    t.row(vec!["failure rate".into(), fnum(est.failure_rate)]);
    t.print();
    if est.all_failed() {
        println!("warning: every replication failed coverage; statistics are undefined");
    }
    Ok(())
}

pub fn sweep(args: &mut Args) -> Result<()> {
    if let Some(spec_path) = args.get("spec") {
        return sweep_from_spec(args, &spec_path);
    }
    if args.get_bool("paired") {
        return sweep_paired_cmd(args);
    }
    let n = args.get_usize("workers", 100)?;
    let tau = service_from(args)?;
    let replication = replication_from(args)?;
    let planner = Planner::new(n, tau.clone());
    let sweep = if replication.is_upfront() {
        planner.sweep()
    } else {
        // timed policies have no closed forms: evaluate every feasible
        // operating point by Monte-Carlo on per-point substreams
        let reps = args.get_usize("reps", DEFAULT_REPS)?;
        let seed = args.get_u64("seed", 0)?;
        let bs = crate::analysis::optimizer::feasible_b(n);
        let scenarios: Vec<Scenario> = bs
            .iter()
            .map(|&b| Scenario::balanced(n, b, tau.clone()).with_replication(replication))
            .collect();
        let estimates = MonteCarlo::new(reps, seed).evaluate_many(&scenarios)?;
        bs.iter()
            .zip(estimates.iter())
            .map(|(&b, e)| SweepPoint {
                batches: b,
                mean: e.mean,
                cov: e.cov,
                cost: e.cost,
                ci95: e.ci95,
            })
            .collect()
    };
    let mut t = Table::new(
        &format!(
            "Spectrum sweep: N={n}, tau ~ {}, policy {}",
            tau.label(),
            replication.label()
        ),
        vec!["B", "batch size", "E[T]", "CoV[T]", "cost", "speedup vs B=N"],
    );
    let baseline = sweep
        .last()
        .ok_or_else(|| Error::Internal("sweep produced no points".into()))?
        .mean;
    for p in &sweep {
        t.row(vec![
            p.batches.to_string(),
            (n / p.batches).to_string(),
            fnum(p.mean),
            fnum(p.cov),
            cost_cell(p.cost),
            format!("{}x", fnum(baseline / p.mean)),
        ]);
    }
    t.print();
    Ok(())
}

/// `replica sweep --paired`: the common-random-numbers spectrum. Every
/// B consumes the same per-replication service draws, so the table
/// reports the ci95 of each point's *difference* from the best B —
/// usually far tighter than the per-point ci95 — and `--eps E`
/// replaces `--reps` with adaptive doubling that stops once every
/// difference is resolved to ±E (ceiling `--max-reps`).
fn sweep_paired_cmd(args: &mut Args) -> Result<()> {
    let n = args.get_usize("workers", 100)?;
    let tau = service_from(args)?;
    if !replication_from(args)?.is_upfront() {
        return Err(Error::Config(
            "--paired sweeps the up-front spectrum; timed policies are not supported"
                .into(),
        ));
    }
    let seed = args.get_u64("seed", 0)?;
    let planner = Planner::new(n, tau.clone());
    let spectrum = match args.get("eps") {
        Some(v) => {
            let eps =
                v.parse::<f64>().map_err(|e| Error::Config(format!("--eps {v}: {e}")))?;
            let max = args.get_usize("max-reps", 1 << 16)?;
            planner.sweep_paired_until(eps, max, seed)?
        }
        None => {
            let reps = args.get_usize("reps", DEFAULT_REPS)?;
            planner.sweep_paired(reps, seed)?
        }
    };
    let mut t = Table::new(
        &format!(
            "Paired (CRN) spectrum: N={n}, tau ~ {}, {} replications",
            tau.label(),
            spectrum.replications
        ),
        vec!["B", "batch size", "E[T]", "ci95", "dE[T] vs best", "ci95(diff)", "paired"],
    );
    for (i, p) in spectrum.points.iter().enumerate() {
        let (diff, diff_ci, paired) = if i == spectrum.reference {
            ("best".into(), "-".into(), "-".into())
        } else {
            (fnum(p.diff_mean), fnum(p.diff_ci95), p.paired.to_string())
        };
        t.row(vec![
            p.point.batches.to_string(),
            (n / p.point.batches).to_string(),
            fnum(p.point.mean),
            fnum(p.point.ci95),
            diff,
            diff_ci,
            paired,
        ]);
    }
    t.print();
    Ok(())
}

/// `replica crn-bench`: measure how many replications the paired
/// (common-random-numbers) spectrum needs to resolve every B's
/// difference from the best to ±eps, versus independent per-scenario
/// streams reaching the same target (difference CIs combined by
/// quadrature). Both arms use the same doubling schedule, so the
/// printed ratio is the variance-efficiency gain CI gates on
/// (scripts/check_variance_floor.sh). Deterministic: both arms derive
/// every stream from `--seed`.
pub fn crn_bench(args: &mut Args) -> Result<()> {
    let (n, tau) = match args.get("spec") {
        Some(spec_path) => {
            let spec = crate::sweep::SweepSpec::from_file(Path::new(&spec_path))?;
            let trace = spec.load_trace()?;
            let job = match args.get("job") {
                Some(v) => {
                    v.parse::<u64>().map_err(|e| Error::Config(format!("--job {v}: {e}")))?
                }
                None => *trace.job_ids().first().ok_or_else(|| {
                    Error::Config("crn-bench: the spec's trace has no jobs".into())
                })?,
            };
            let analysis = JobAnalysis::of(&trace, job).ok_or_else(|| {
                Error::Config(format!("job {job} has no completed tasks in the trace"))
            })?;
            (analysis.n_tasks, analysis.service_dist())
        }
        None => (args.get_usize("workers", 100)?, service_from(args)?),
    };
    let seed = args.get_u64("seed", 0)?;
    let max = args.get_usize("max-reps", 1 << 15)?;
    if max == 0 {
        return Err(Error::Config("--max-reps must be >= 1".into()));
    }
    let planner = Planner::new(n, tau.clone());
    // the target: --eps absolute, or --eps-rel (default 2%) of the
    // best arm's mean from a small pilot
    let eps = match args.get("eps") {
        Some(v) => v.parse::<f64>().map_err(|e| Error::Config(format!("--eps {v}: {e}")))?,
        None => {
            let rel = args.get_f64("eps-rel", 0.02)?;
            if !rel.is_finite() || rel <= 0.0 {
                return Err(Error::Config("--eps-rel must be finite and > 0".into()));
            }
            let pilot = planner.sweep_paired(64.min(max), seed)?;
            let reference = pilot.points.get(pilot.reference).ok_or_else(|| {
                Error::Internal("paired pilot produced no reference point".into())
            })?;
            rel * reference.point.mean
        }
    };
    let paired = planner.sweep_paired_until(eps, max, seed)?;
    // independent arm: the same spectrum on per-scenario substreams
    // (evaluate_many), doubling until every quadrature diff CI <= eps
    let bs = crate::analysis::optimizer::feasible_b(n);
    let scenarios: Vec<Scenario> =
        bs.iter().map(|&b| Scenario::balanced(n, b, tau.clone())).collect();
    let mut reps = 64usize.min(max);
    let independent = loop {
        let ests = MonteCarlo::new(reps, seed).evaluate_many(&scenarios)?;
        let mut reference = 0usize;
        for (i, e) in ests.iter().enumerate() {
            if e.mean.is_finite()
                && (!ests[reference].mean.is_finite() || e.mean < ests[reference].mean)
            {
                reference = i;
            }
        }
        let mut worst = 0.0f64;
        for (i, e) in ests.iter().enumerate() {
            if i == reference {
                continue;
            }
            let d = (e.ci95 * e.ci95 + ests[reference].ci95 * ests[reference].ci95).sqrt();
            if d.is_nan() {
                worst = f64::INFINITY;
            } else if d > worst {
                worst = d;
            }
        }
        if worst <= eps || reps == max {
            break reps;
        }
        reps = reps.saturating_mul(2).min(max);
    };
    let ratio = independent as f64 / paired.replications.max(1) as f64;
    println!(
        "{{\"workers\":{n},\"points\":{},\"eps\":{eps},\"paired_reps\":{},\
         \"independent_reps\":{independent},\"ratio\":{ratio}}}",
        bs.len(),
        paired.replications
    );
    Ok(())
}

/// Parse `--shard K/M` (0-based K, M >= 1, K < M).
fn parse_shard(s: &str) -> Result<(usize, usize)> {
    let bad =
        || Error::Config(format!("--shard {s}: expected K/M with 0 <= K < M (e.g. 0/4)"));
    let Some((k, m)) = s.split_once('/') else {
        return Err(bad());
    };
    let k = k.trim().parse::<usize>().map_err(|_| bad())?;
    let m = m.trim().parse::<usize>().map_err(|_| bad())?;
    if m == 0 || k >= m {
        return Err(bad());
    }
    Ok((k, m))
}

/// Parse the spec named by a `sweep`/`sweep-merge` invocation and apply
/// the estimator-budget flag overrides (`--reps`, `--seed`) that re-key
/// the grid — both commands must resolve the same keys or a merge
/// would refuse its own shards.
fn spec_with_overrides(args: &mut Args, spec_path: &str) -> Result<crate::sweep::SweepSpec> {
    let mut spec = crate::sweep::SweepSpec::from_file(Path::new(spec_path))?;
    // flags override the spec's estimator budget, not its grid; the
    // override must honor the same validation as the spec parser
    spec.reps = args.get_usize("reps", spec.reps)?;
    if spec.reps == 0 {
        return Err(Error::Config("--reps must be >= 1".into()));
    }
    // under `reps: auto` the ceiling rides the reps budget, so a --reps
    // override moves both and every command resolves the same keys
    if let Some(auto) = &mut spec.auto_reps {
        auto.max = spec.reps;
    }
    // --eps E turns any spec into a precision-targeted one (ceiling =
    // the resolved reps budget), re-keying the grid exactly as the
    // spec's own `reps: {"auto": ...}` form would
    if let Some(v) = args.get("eps") {
        let eps = v.parse::<f64>().map_err(|e| Error::Config(format!("--eps {v}: {e}")))?;
        if !eps.is_finite() || eps <= 0.0 {
            return Err(Error::Config("--eps must be finite and > 0".into()));
        }
        spec.auto_reps = Some(crate::sweep::AutoReps { eps, max: spec.reps });
    }
    spec.seed = args.get_u64("seed", spec.seed)?;
    Ok(spec)
}

/// After a sweep, optionally compact the estimate cache against the
/// current grid (`--cache-gc`): keys no earlier spec revision asks
/// about anymore are dropped and the reclaimed space reported.
fn maybe_cache_gc(
    cache_gc: bool,
    cache: Option<&Path>,
    set: &crate::sweep::ScenarioSet,
) -> Result<()> {
    if !cache_gc {
        return Ok(());
    }
    let Some(cache) = cache else {
        return Ok(());
    };
    let live: std::collections::BTreeSet<u64> = set.expected_keys().into_iter().collect();
    let mut store = crate::sweep::EstimateCache::open(cache)?;
    let stats = store.gc(&live)?;
    println!(
        "cache gc {}: {} live kept, {} dead dropped, {} bytes reclaimed",
        cache.display(),
        stats.live,
        stats.dead,
        stats.reclaimed_bytes
    );
    Ok(())
}

/// `--cache-import DIR`: adopt estimates from the `*.cache.jsonl`
/// files of earlier runs into this run's cache, so a new sweep (or a
/// re-sharded one) starts warm. DIR is read-only — imported files are
/// never modified. Entries already in the run's own cache win; across
/// imported files, the lexicographically first file wins. Cache keys
/// are content-addressed, so a foreign entry the current grid never
/// asks about is dead weight at worst (and `--cache-gc` reclaims it).
fn import_cache(dir: &Path, cache: Option<&Path>) -> Result<usize> {
    let Some(cache) = cache else {
        return Err(Error::Config(
            "--cache-import needs a persisted run to import into".into(),
        ));
    };
    let mut files: Vec<PathBuf> = Vec::new();
    for entry in std::fs::read_dir(dir)
        .map_err(|e| Error::Config(format!("--cache-import {}: {e}", dir.display())))?
    {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.ends_with(".cache.jsonl") && path.as_path() != cache {
            files.push(path);
        }
    }
    files.sort();
    if files.is_empty() {
        return Err(Error::Config(format!(
            "--cache-import {}: no *.cache.jsonl files found",
            dir.display()
        )));
    }
    let mut dest = crate::sweep::EstimateCache::open(cache)?;
    let mut adopted = 0usize;
    for file in &files {
        let text = std::fs::read_to_string(file)?;
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            // a torn tail (killed writer) ends the file, exactly as
            // EstimateCache::open treats its own backing file
            let Ok((key, outcome)) = crate::sweep::store::parse_record(line) else {
                break;
            };
            if dest.get(key).is_none() {
                dest.insert(key, outcome)?;
                adopted += 1;
            }
        }
    }
    dest.flush()?;
    Ok(adopted)
}

/// `replica sweep --spec FILE`: the sharded, resumable trace-sweep
/// engine. Results stream to a JSONL store (`--out`, default
/// `sweep_results.jsonl`) with an on-disk estimate cache (`--cache`,
/// default `<out>.cache.jsonl`); re-running the same command resumes a
/// killed run exactly where it stopped and prints the §VII
/// replication-gain report at the end. With `--shard K/M` the process
/// evaluates only its slice of the grid into a per-shard store (see
/// `replica sweep-merge`); with `--cache-gc` the estimate cache is
/// compacted against the current grid after the run; with
/// `--cache-import DIR` estimates from earlier runs' caches are
/// adopted first (DIR is read-only — nothing in it is modified).
fn sweep_from_spec(args: &mut Args, spec_path: &str) -> Result<()> {
    let spec = spec_with_overrides(args, spec_path)?;
    let out = PathBuf::from(args.get("out").unwrap_or_else(|| "sweep_results.jsonl".into()));
    let shard = match args.get("shard") {
        None => None,
        Some(s) => Some(parse_shard(&s)?),
    };
    let limit = args.get_usize("limit-shards", 0)?;
    let mut cfg = match shard {
        Some((k, m)) => crate::sweep::RunConfig::sharded(out.clone(), k, m),
        None => crate::sweep::RunConfig::persisted(out.clone()),
    };
    if let Some(cache) = args.get("cache") {
        if shard.is_some() {
            // the cache format is single-writer (truncate-on-open +
            // positioned writes); M concurrent shard processes sharing
            // one override path would corrupt it
            return Err(Error::Config(
                "--cache cannot be combined with --shard: each shard process keeps \
                 a private cache next to its shard store (<store>.cache.jsonl)"
                    .into(),
            ));
        }
        cfg.cache = Some(PathBuf::from(cache));
    }
    cfg.shard_size = spec.shard_size;
    cfg.limit_shards = if limit == 0 { None } else { Some(limit) };
    cfg.threads = args.get_usize("threads", 0)?;
    let cache_gc = args.get_bool("cache-gc");
    let objective = objective_from(args)?;
    if let Some(dir) = args.get("cache-import") {
        let adopted = import_cache(Path::new(&dir), cfg.cache.as_deref())?;
        println!("cache import {dir}: {adopted} entries adopted");
    }
    let trace = spec.load_trace()?;
    let set = crate::sweep::ScenarioSet::from_trace(&trace, &spec)?;
    let results = crate::sweep::run(&set, &cfg)?;
    let total = match shard {
        Some((k, m)) => set.shard(k, m)?.len(),
        None => set.len(),
    };
    if let Some((k, m)) = shard {
        // a shard sees only its slice: the gain report would be
        // misleading, so point at the merge step instead
        println!(
            "shard {k}/{m}: {} of {total} cases -> {}",
            results.len(),
            crate::sweep::shard_path(&out, k, m).display()
        );
        // repeat the resolved estimator budget in the hint: the merge
        // re-expands the grid, and a different reps/seed would re-key
        // every case and make it refuse this run's own shards
        println!(
            "when all shards finish: replica sweep-merge --spec {spec_path} --out {} \
             --shards {m} --reps {} --seed {}",
            out.display(),
            spec.reps,
            spec.seed
        );
    } else {
        let rows = crate::sweep::gain_report(&results, Some(&trace), objective);
        crate::sweep::gain_table(
            &format!("Replication gains — {spec_path} ({} scenarios)", results.len()),
            &rows,
        )
        .print();
        let headline = crate::sweep::headline_speedup(&rows);
        if headline.is_finite() {
            println!("headline speedup (best job): {}x", fnum(headline));
        }
        println!("results: {}", out.display());
    }
    if results.len() < total {
        println!(
            "partial run ({} of {total} scenarios evaluated); rerun to resume",
            results.len()
        );
    }
    maybe_cache_gc(cache_gc, cfg.cache.as_deref(), &set)?;
    Ok(())
}

/// `replica opensys --spec FILE`: the open-system serving sweep. Every
/// case of the spec's grid (which must carry an `arrivals` axis) is
/// evaluated through the same engine path as `sweep --spec`
/// ([`crate::sweep::evaluate_cases`]), then two tables are printed:
/// per-cell latency percentiles + utilization + worker-seconds per job,
/// and the headline **B\*-vs-load curve** — the batch count that wins
/// each (job, ρ) cell under `--objective`. Output is byte-identical
/// across `--pool-threads` settings (each replication's RNG stream is
/// fixed by the case's content key).
pub fn opensys(args: &mut Args) -> Result<()> {
    let spec_path = args
        .get("spec")
        .ok_or_else(|| Error::Config("opensys needs --spec FILE".into()))?;
    let spec = spec_with_overrides(args, &spec_path)?;
    if spec.arrivals.is_none() {
        return Err(Error::Config(format!(
            "spec {spec_path} has no 'arrivals' axis; opensys sweeps the open \
             system — add \"arrivals\": {{\"rho\": [...]}} to the spec, or use \
             `replica sweep --spec` for the closed-system grid"
        )));
    }
    let threads = args.get_usize("threads", 0)?;
    let objective = objective_from(args)?;
    let trace = spec.load_trace()?;
    let set = crate::sweep::ScenarioSet::from_trace(&trace, &spec)?;
    let mut cache = crate::sweep::EstimateCache::in_memory();
    let outcomes = crate::sweep::evaluate_cases(&set.cases, &mut cache, threads)?;

    struct OpenRow {
        job: u64,
        rho: f64,
        n: usize,
        b: usize,
        policy: ReplicationPolicy,
        est: crate::sweep::StoredEstimate,
    }
    let mut rows: Vec<OpenRow> = Vec::new();
    let mut t = Table::new(
        &format!("Open-system sweep — {spec_path} ({} cases)", set.len()),
        vec![
            "job", "rho", "B", "policy", "E[T]", "ci95", "p50", "p95", "p99", "util",
            "cost/job",
        ],
    );
    for (case, outcome) in set.cases.iter().zip(&outcomes) {
        let rho = case.rho().unwrap_or(f64::NAN);
        let cells = |tail: Vec<String>| {
            let mut row = vec![
                case.job_id.to_string(),
                fnum(rho),
                case.batches().to_string(),
                case.scenario.replication.label(),
            ];
            row.extend(tail);
            row
        };
        match outcome {
            crate::sweep::CaseOutcome::Error(msg) => {
                t.row(cells(vec![format!("error: {msg}"), String::new(), String::new(),
                    String::new(), String::new(), String::new(), String::new()]));
            }
            crate::sweep::CaseOutcome::Ok(est) => {
                t.row(cells(vec![
                    fnum(est.mean),
                    fnum(est.ci95),
                    fnum(est.p50),
                    fnum(est.p95),
                    fnum(est.p99),
                    fnum(est.utilization),
                    cost_cell(est.cost),
                ]));
                rows.push(OpenRow {
                    job: case.job_id,
                    rho,
                    n: case.scenario.workers,
                    b: case.batches(),
                    policy: case.scenario.replication,
                    est: est.clone(),
                });
            }
        }
    }
    t.print();

    // B* per (job, ρ): the operating point `--objective` picks from
    // each load level's spectrum — the redundancy-collapse curve.
    let mut curve = Table::new(
        "B* vs load",
        vec!["job", "rho", "B*", "r", "policy", "E[T]", "util", "vs B=N"],
    );
    let mut cells: Vec<(u64, u64)> = rows.iter().map(|r| (r.job, r.rho.to_bits())).collect();
    cells.sort_unstable();
    cells.dedup();
    for (job, rho_bits) in cells {
        let group: Vec<&OpenRow> = rows
            .iter()
            .filter(|r| r.job == job && r.rho.to_bits() == rho_bits)
            .collect();
        let points: Vec<SweepPoint> = group
            .iter()
            .map(|r| SweepPoint {
                batches: r.b,
                mean: r.est.mean,
                cov: r.est.cov,
                cost: r.est.cost,
                ci95: r.est.ci95,
            })
            .collect();
        let Some(best) = crate::planner::choose(&points, objective) else {
            continue;
        };
        // `choose` returns the winning point; recover its row (first
        // match — policy ties can only arise from duplicate cells)
        let Some(win) = group.iter().find(|r| {
            r.b == best.batches && r.est.mean.to_bits() == best.mean.to_bits()
        }) else {
            continue;
        };
        let baseline = group
            .iter()
            .filter(|r| r.b == r.n)
            .map(|r| r.est.mean)
            .fold(f64::NAN, f64::min);
        let vs = if baseline.is_finite() && win.est.mean > 0.0 {
            format!("{}x", fnum(baseline / win.est.mean))
        } else {
            "-".into()
        };
        curve.row(vec![
            job.to_string(),
            fnum(f64::from_bits(rho_bits)),
            win.b.to_string(),
            (win.n / win.b).to_string(),
            win.policy.label(),
            fnum(win.est.mean),
            fnum(win.est.utilization),
            vs,
        ]);
    }
    curve.print();
    Ok(())
}

/// `replica sweep-merge --spec FILE --out OUT --shards M`: merge the
/// per-shard stores of a multi-process sweep into the canonical
/// grid-ordered store, byte-identical to a single-process run. Shard
/// files are located by the `--shard K/M` naming convention; explicit
/// shard-file paths may be passed as positionals instead (they may
/// overlap, e.g. shards from different shardings of the same sweep).
///
/// With `--allow-partial` an incomplete grid is tolerated: the covered
/// prefix is written and every missing index range is printed as one
/// JSON line (machine-readable progress for a sweep still in flight).
/// With `--report-only` the merge (and the spec) are skipped entirely:
/// the gain report streams straight from the `--out` store's records.
pub fn sweep_merge(args: &mut Args) -> Result<()> {
    if args.get_bool("report-only") {
        return report_only(args);
    }
    let spec_path = args
        .get("spec")
        .ok_or_else(|| Error::Config("sweep-merge needs --spec FILE".into()))?;
    let spec = spec_with_overrides(args, &spec_path)?;
    let out = PathBuf::from(args.get("out").unwrap_or_else(|| "sweep_results.jsonl".into()));
    let shards = args.get_usize("shards", 0)?;
    let files: Vec<PathBuf> = (1..)
        .map_while(|i| args.positional(i).map(PathBuf::from))
        .collect();
    let trace = spec.load_trace()?;
    let set = crate::sweep::ScenarioSet::from_trace(&trace, &spec)?;
    let shard_files: Vec<PathBuf> = if !files.is_empty() {
        files
    } else if shards > 0 {
        (0..shards).map(|k| crate::sweep::shard_path(&out, k, shards)).collect()
    } else {
        return Err(Error::Config(
            "sweep-merge needs --shards M or explicit shard-file positionals".into(),
        ));
    };
    if args.get_bool("allow-partial") {
        return merge_partial_cmd(&set, &shard_files, &out);
    }
    let (report, outcomes) = crate::sweep::merge(&set, &shard_files, &out)?;
    println!(
        "merged {} shard files -> {} ({} cases, {} overlapping records verified)",
        report.shards,
        out.display(),
        report.cases,
        report.duplicates
    );
    // the merged store is a complete run: print the gain report from
    // the outcomes the merge already holds
    let objective = objective_from(args)?;
    let results: Vec<crate::sweep::CaseResult> = set
        .cases
        .iter()
        .zip(outcomes)
        .map(|(case, outcome)| crate::sweep::CaseResult { case: case.clone(), outcome })
        .collect();
    let rows = crate::sweep::gain_report(&results, Some(&trace), objective);
    crate::sweep::gain_table(
        &format!("Replication gains — {spec_path} ({} scenarios, merged)", results.len()),
        &rows,
    )
    .print();
    let headline = crate::sweep::headline_speedup(&rows);
    if headline.is_finite() {
        println!("headline speedup (best job): {}x", fnum(headline));
    }
    if args.get_bool("cache-gc") {
        // every shard store keeps its cache next to it; GC each in place
        for file in &shard_files {
            let cache = PathBuf::from(format!("{}.cache.jsonl", file.display()));
            if cache.exists() {
                maybe_cache_gc(true, Some(cache.as_path()), &set)?;
            }
        }
    }
    Ok(())
}

/// The `--allow-partial` arm of [`sweep_merge`]: publish the covered
/// prefix and print one compact JSON line per missing range, so a
/// watcher script can track a distributed sweep without parsing prose.
/// Shard files not written yet are tolerated — their slices simply
/// show up as missing ranges.
fn merge_partial_cmd(
    set: &crate::sweep::ScenarioSet,
    shard_files: &[PathBuf],
    out: &Path,
) -> Result<()> {
    let present: Vec<PathBuf> =
        shard_files.iter().filter(|f| f.exists()).cloned().collect();
    for absent in shard_files.iter().filter(|f| !f.exists()) {
        println!(
            "shard file {} not written yet; its slice counts as missing",
            absent.display()
        );
    }
    if present.is_empty() {
        return Err(Error::Config(
            "--allow-partial: none of the shard files exist yet — nothing to merge"
                .into(),
        ));
    }
    let report = crate::sweep::merge_partial(set, &present, out)?;
    println!(
        "partial merge: {} of {} cases written to {} ({} covered across {} shard \
         files, {} overlapping records verified)",
        report.merged,
        report.cases,
        out.display(),
        report.covered,
        report.shards,
        report.duplicates
    );
    for range in &report.missing {
        // one machine-readable line per gap; `first_key` matches the
        // store's key rendering, so the range survives re-expansion
        println!(
            "{{\"missing\":{{\"lo\":{},\"hi\":{},\"cases\":{},\"first_key\":\"{:016x}\"}}}}",
            range.lo,
            range.hi,
            range.len(),
            range.first_key
        );
    }
    if report.missing.is_empty() {
        println!("grid complete: the partial merge equals a strict merge");
    }
    Ok(())
}

/// `replica sweep-merge --report-only --out FILE`: the §VII gain report
/// straight from an existing result store — no spec re-parse, no trace
/// re-generation, no grid expansion. Every store record carries its
/// full case description (job, N, B, backend, crash, policy), so the
/// rows stream from the records alone; only the trace-derived tail
/// class is unavailable and its column stays empty.
fn report_only(args: &mut Args) -> Result<()> {
    let out = PathBuf::from(args.get("out").unwrap_or_else(|| "sweep_results.jsonl".into()));
    let objective = objective_from(args)?;
    let file = std::fs::File::open(&out)
        .map_err(|e| Error::Config(format!("--report-only {}: {e}", out.display())))?;
    let mut records = Vec::new();
    for (i, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        if i == 0 && crate::sweep::store::parse_shard_header(&line).is_some() {
            return Err(Error::Config(format!(
                "{} is a per-shard store; run sweep-merge without --report-only first",
                out.display()
            )));
        }
        let row = crate::sweep::parse_report_line(&line)
            .map_err(|e| Error::Parse(format!("{}:{}: {e}", out.display(), i + 1)))?;
        records.push(row);
    }
    let rows = crate::sweep::gain_report_from_records(&records, objective);
    crate::sweep::gain_table(
        &format!("Replication gains — {} ({} records)", out.display(), records.len()),
        &rows,
    )
    .print();
    let headline = crate::sweep::headline_speedup(&rows);
    if headline.is_finite() {
        println!("headline speedup (best job): {}x", fnum(headline));
    }
    Ok(())
}

/// Map the cluster timing/sizing flags onto a [`ClusterConfig`],
/// starting from the defaults; cross-field invariants are validated
/// here so a bad combination fails before any socket is opened.
fn cluster_config_from(args: &mut Args) -> Result<ClusterConfig> {
    let defaults = ClusterConfig::default();
    let cfg = ClusterConfig {
        lease_timeout_ms: args.get_u64("lease-timeout-ms", defaults.lease_timeout_ms)?,
        heartbeat_ms: args.get_u64("heartbeat-ms", defaults.heartbeat_ms)?,
        poll_ms: args.get_u64("poll-ms", defaults.poll_ms)?,
        min_lease: args.get_usize("min-lease", defaults.min_lease)?,
        max_lease: args.get_usize("max-lease", defaults.max_lease)?,
        chunk: args.get_usize("chunk", defaults.chunk)?,
        reconnect_base_ms: args.get_u64("reconnect-base-ms", defaults.reconnect_base_ms)?,
        reconnect_max_ms: args.get_u64("reconnect-max-ms", defaults.reconnect_max_ms)?,
        max_reconnects: u32::try_from(
            args.get_usize("max-reconnects", defaults.max_reconnects as usize)?,
        )
        .map_err(|_| Error::Config("--max-reconnects is too large".into()))?,
        linger_ms: args.get_u64("linger-ms", defaults.linger_ms)?,
    };
    cfg.validate()?;
    Ok(cfg)
}

/// `replica cluster-serve --spec FILE --out OUT [--listen ADDR]`: run
/// the fault-tolerant sweep coordinator until the grid is complete.
/// The finished store is byte-identical to a single-process
/// `replica sweep --spec FILE --out OUT`; a restarted coordinator
/// resumes from the store prefix plus the estimate cache and leases
/// only what is still uncovered.
pub fn cluster_serve(args: &mut Args) -> Result<()> {
    let spec_path = args
        .get("spec")
        .ok_or_else(|| Error::Config("cluster-serve needs --spec FILE".into()))?;
    let spec_text = std::fs::read_to_string(&spec_path)
        .map_err(|e| Error::Config(format!("--spec {spec_path}: {e}")))?;
    let out = PathBuf::from(args.get("out").unwrap_or_else(|| "sweep_results.jsonl".into()));
    let listen = args.get("listen").unwrap_or_else(|| "127.0.0.1:7700".into());
    let reps_override = match args.get("reps") {
        None => None,
        Some(v) => Some(
            v.parse::<usize>().map_err(|e| Error::Config(format!("--reps {v}: {e}")))?,
        ),
    };
    let seed_override = match args.get("seed") {
        None => None,
        Some(v) => Some(
            v.parse::<u64>().map_err(|e| Error::Config(format!("--seed {v}: {e}")))?,
        ),
    };
    let cfg = cluster_config_from(args)?;
    let opts = crate::cluster::ServeOptions {
        spec_text,
        reps_override,
        seed_override,
        out: out.clone(),
        listen: listen.clone(),
        cfg,
    };
    println!("cluster-serve: listening on {listen}, store {}", out.display());
    let clock: Arc<dyn crate::util::clock::Clock> =
        Arc::new(crate::util::clock::MonotonicClock::new());
    let report = crate::cluster::serve(&opts, clock)?;
    println!(
        "cluster sweep complete: {} cases ({} resumed from disk) via {} workers; \
         {} expired leases reassigned, {} duplicate lines byte-verified",
        report.cases,
        report.resumed,
        report.workers,
        report.expired_leases,
        report.duplicate_lines
    );
    println!("results: {}", out.display());
    Ok(())
}

/// `replica cluster-work --connect ADDR [--worker NAME]`: run one sweep
/// worker against a coordinator until the sweep completes. Survives
/// coordinator restarts (exponential-backoff reconnect) and lease
/// expiry under straggling (the slice is abandoned and re-leased;
/// recomputation is cache-warm).
pub fn cluster_work(args: &mut Args) -> Result<()> {
    let connect = args
        .get("connect")
        .ok_or_else(|| Error::Config("cluster-work needs --connect ADDR".into()))?;
    let worker =
        args.get("worker").unwrap_or_else(|| format!("w-{}", std::process::id()));
    let threads = args.get_usize("threads", 0)?;
    let cfg = cluster_config_from(args)?;
    let opts = crate::cluster::WorkOptions {
        connect: connect.clone(),
        worker: worker.clone(),
        threads,
        cfg,
    };
    let clock = crate::util::clock::MonotonicClock::new();
    let report = crate::cluster::work(&opts, &clock)?;
    println!(
        "worker {worker} done: {} cases over {} leases \
         ({} abandoned after expiry, {} reconnects)",
        report.cases, report.leases, report.abandoned, report.reconnects
    );
    Ok(())
}

pub fn trace(args: &mut Args) -> Result<()> {
    match args.positional(1) {
        Some("gen") => {
            let out = PathBuf::from(
                args.get("out").unwrap_or_else(|| "trace.csv".to_string()),
            );
            let tasks = args.get_usize("tasks", 100)?;
            let seed = args.get_u64("seed", 42)?;
            let trace = GeneratorConfig::paper_workload(tasks, seed).generate();
            write_trace(&out, &trace)?;
            println!(
                "wrote {} events ({} jobs x {tasks} tasks) to {}",
                trace.events.len(),
                trace.job_ids().len(),
                out.display()
            );
            Ok(())
        }
        Some("analyze") => {
            let path = PathBuf::from(args.get("trace").ok_or_else(|| {
                Error::Config("trace analyze needs --trace FILE".into())
            })?);
            let trace = load_trace(&path)?;
            let mut t = Table::new(
                &format!("Trace analysis: {}", path.display()),
                vec!["job", "tasks", "mean", "min", "p99", "tail", "fitted"],
            );
            for a in JobAnalysis::all(&trace) {
                t.row(vec![
                    a.job_id.to_string(),
                    a.n_tasks.to_string(),
                    fnum(a.mean),
                    fnum(a.min),
                    fnum(a.p99),
                    if a.is_heavy_tail() { "heavy" } else { "exp" }.to_string(),
                    a.fit.best().label(),
                ]);
            }
            t.print();
            Ok(())
        }
        other => Err(Error::Config(format!(
            "trace needs a subcommand gen|analyze, got {other:?}"
        ))),
    }
}

pub fn experiment(args: &mut Args) -> Result<()> {
    let which = args.positional(1).unwrap_or("all").to_string();
    let reps = args.get_usize("reps", DEFAULT_REPS)?;
    let seed = args.get_u64("seed", 42)?;
    let out = args.get("out").map(PathBuf::from);
    let run_one = |id: &str| -> Result<()> {
        match id {
            "fig3" => {
                experiments::fig3::table(&experiments::fig3::PAPER_NS).print();
                if let Some(dir) = &out {
                    std::fs::create_dir_all(dir)?;
                    export_csv(
                        &dir.join("fig3.csv"),
                        &experiments::fig3::run(&experiments::fig3::PAPER_NS),
                    )?;
                }
            }
            "fig6" => {
                let rows =
                    experiments::fig6::run(&[0.25, 0.5, 1.0, 2.0, 4.0], reps, seed)?;
                experiments::fig6::table(&rows).print();
                if let Some(dir) = &out {
                    std::fs::create_dir_all(dir)?;
                    export_csv(&dir.join("fig6.csv"), &experiments::fig6::series(&rows))?;
                }
            }
            "fig7_8" => {
                experiments::fig7_8::table(&experiments::fig7_8::PAPER_MUS).print();
                if let Some(dir) = &out {
                    std::fs::create_dir_all(dir)?;
                    export_csv(
                        &dir.join("fig7.csv"),
                        &experiments::fig7_8::fig7_series(&experiments::fig7_8::PAPER_MUS),
                    )?;
                    export_csv(
                        &dir.join("fig8.csv"),
                        &experiments::fig7_8::fig8_series(&experiments::fig7_8::PAPER_MUS),
                    )?;
                }
            }
            "fig9_10" => {
                experiments::fig9_10::table(&experiments::fig9_10::PAPER_ALPHAS).print();
                if let Some(dir) = &out {
                    std::fs::create_dir_all(dir)?;
                    export_csv(
                        &dir.join("fig9.csv"),
                        &experiments::fig9_10::fig9_series(&experiments::fig9_10::PAPER_ALPHAS),
                    )?;
                    export_csv(
                        &dir.join("fig10.csv"),
                        &experiments::fig9_10::fig10_series(&experiments::fig9_10::PAPER_ALPHAS),
                    )?;
                }
            }
            "regimes" => {
                experiments::regimes::sexp_mean_table(
                    100,
                    0.05,
                    &[0.1, 0.5, 1.0, 2.0, 5.0, 14.0, 20.0],
                )
                .print();
                experiments::regimes::sexp_cov_table(100, 0.05, &[0.2, 0.5, 3.0, 40.0])
                    .print();
                experiments::regimes::pareto_table(100, 1.0, &[1.5, 2.5, 3.5, 5.0, 7.0])
                    .print();
                experiments::regimes::tradeoff_table(100).print();
            }
            "assignment" => {
                for tau in [
                    ServiceDist::exp(1.0),
                    ServiceDist::shifted_exp(0.1, 1.0),
                    ServiceDist::pareto(1.0, 2.5),
                ] {
                    let rows = experiments::assignment::run(8, 2, &tau, reps, seed)?;
                    experiments::assignment::table(8, 2, &tau, &rows).print();
                }
            }
            "open-problem" => {
                experiments::open_problem::table(8, 2)?.print();
                experiments::open_problem::table(12, 3)?.print();
            }
            "traces" => {
                let trace = experiments::traces_exp::standard_trace(seed);
                experiments::traces_exp::table(
                    "Fig 12: normalized E[T] vs B — exponential-tail jobs",
                    &trace,
                    &experiments::traces_exp::EXP_TAIL_JOBS,
                    reps,
                    seed,
                )?
                .print();
                experiments::traces_exp::table(
                    "Fig 13: normalized E[T] vs B — heavy-tail jobs",
                    &trace,
                    &experiments::traces_exp::HEAVY_TAIL_JOBS,
                    reps,
                    seed,
                )?
                .print();
                let headline =
                    experiments::traces_exp::headline_speedup(&trace, reps, seed)?;
                println!("headline speedup (best heavy-tail job): {}x", fnum(headline));
                if let Some(dir) = &out {
                    std::fs::create_dir_all(dir)?;
                    export_csv(
                        &dir.join("fig11.csv"),
                        &experiments::traces_exp::fig11_series(&trace),
                    )?;
                }
            }
            other => return Err(Error::Config(format!("unknown experiment '{other}'"))),
        }
        Ok(())
    };
    if which == "all" {
        for id in
            ["fig3", "fig6", "fig7_8", "fig9_10", "regimes", "assignment", "open-problem", "traces"]
        {
            run_one(id)?;
            println!();
        }
        Ok(())
    } else {
        run_one(&which)
    }
}

pub fn gd_train(args: &mut Args) -> Result<()> {
    let workers = args.get_usize("workers", 16)?;
    let batches = args.get_usize("batches", 4)?;
    let rounds = args.get_usize("rounds", 100)?;
    let lr = args.get_f32("lr", 0.1)?;
    let seed = args.get_u64("seed", 0)?;
    let time_scale = args.get_f64("time-scale", 1e-3)?;
    let backend_kind = args.get("backend").unwrap_or_else(|| "pjrt".to_string());
    let tau = service_from(args)?;

    // keep the RuntimeService alive for the whole run
    let mut _service_keepalive = None;
    let (backend, m, d): (Arc<dyn crate::coordinator::ComputeBackend>, usize, usize) =
        match backend_kind.as_str() {
            "native" => {
                let (m, d) = (args.get_usize("m", 64)?, args.get_usize("d", 16)?);
                (Arc::new(NativeBackend::new(m, d)), m, d)
            }
            "pjrt" => {
                let service = RuntimeService::start(&artifacts_dir())?;
                let manifest = service.handle().manifest().clone();
                let ops = GradientOps::new(service.handle(), manifest.m)?;
                let (m, d) = (ops.m, ops.d);
                let backend = Arc::new(PjrtBackend::new(ops));
                _service_keepalive = Some(service);
                (backend, m, d)
            }
            other => return Err(Error::Config(format!("unknown backend '{other}'"))),
        };

    let dataset = Dataset::synthetic(workers, m, d, 0.1, seed ^ 0xD5);
    let cfg = GdConfig { workers, batches, rounds, lr, straggler: tau, time_scale, seed };
    let mut coord = Coordinator::new(cfg, dataset, backend)?;
    let report = coord.run()?;

    let title =
        format!("Distributed GD: N={workers} B={batches} rounds={rounds} backend={backend_kind}");
    let mut t = Table::new(&title, vec!["round", "loss", "latency_ms"]);
    let stride = (rounds / 10).max(1);
    for (i, r) in report.rounds.iter().enumerate() {
        if i % stride == 0 || i + 1 == rounds {
            t.row(vec![i.to_string(), fnum(r.loss), fnum(r.latency * 1e3)]);
        }
    }
    t.print();
    println!("final global loss: {}", fnum(report.final_global_loss));
    println!("mean round latency: {} ms", fnum(report.mean_latency() * 1e3));
    println!("late replicas discarded: {}", report.total_discarded);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect()).unwrap()
    }

    #[test]
    fn service_from_flags() {
        let mut a = args("plan --family pareto --sigma 2 --alpha 1.5");
        match service_from(&mut a).unwrap() {
            ServiceDist::Pareto { sigma, alpha } => assert_eq!((sigma, alpha), (2.0, 1.5)),
            other => panic!("{}", other.label()),
        }
        let mut a = args("plan");
        assert!(matches!(service_from(&mut a).unwrap(), ServiceDist::ShiftedExp { .. }));
        let mut a = args("plan --family nope");
        assert!(service_from(&mut a).is_err());
    }

    #[test]
    fn objective_parsing() {
        let mut a = args("plan");
        assert_eq!(objective_from(&mut a).unwrap(), Objective::MeanCompletion);
        let mut a = args("plan --objective cov");
        assert_eq!(objective_from(&mut a).unwrap(), Objective::Predictability);
        let mut a = args("plan --objective tradeoff=0.3");
        assert_eq!(objective_from(&mut a).unwrap(), Objective::Tradeoff(0.3));
        let mut a = args("plan --objective cost=0.5");
        assert_eq!(objective_from(&mut a).unwrap(), Objective::CostLatency(0.5));
        let mut a = args("plan --objective cost=lots");
        assert!(objective_from(&mut a).is_err());
        let mut a = args("plan --objective speed");
        assert!(objective_from(&mut a).is_err());
    }

    #[test]
    fn replication_policy_parsing() {
        let mut a = args("simulate");
        assert_eq!(replication_from(&mut a).unwrap(), ReplicationPolicy::Upfront);
        let mut a = args("simulate --policy upfront");
        assert_eq!(replication_from(&mut a).unwrap(), ReplicationPolicy::Upfront);
        let mut a = args("simulate --policy speculative --spec-t 2.5");
        assert_eq!(
            replication_from(&mut a).unwrap(),
            ReplicationPolicy::SpeculativeAt { t: 2.5 }
        );
        let mut a = args("simulate --policy relaunch --spec-t 1");
        assert_eq!(
            replication_from(&mut a).unwrap(),
            ReplicationPolicy::RelaunchAt { t: 1.0 }
        );
        // timed policies need a timeout; a timeout needs a policy;
        // up-front takes none
        assert!(replication_from(&mut args("simulate --policy speculative")).is_err());
        assert!(replication_from(&mut args("simulate --spec-t 2")).is_err());
        assert!(replication_from(&mut args("simulate --policy upfront --spec-t 2")).is_err());
        assert!(replication_from(&mut args("simulate --policy lazy --spec-t 2")).is_err());
        assert!(replication_from(&mut args("simulate --policy relaunch --spec-t -1")).is_err());
        assert!(replication_from(&mut args("simulate --policy relaunch --spec-t x")).is_err());
    }

    #[test]
    fn plan_and_sweep_run() {
        plan(&mut args("plan --workers 20 --family exp --mu 1")).unwrap();
        sweep(&mut args("sweep --workers 20 --family exp --mu 1")).unwrap();
        simulate(&mut args("simulate --workers 12 --batches 3 --family exp --reps 500"))
            .unwrap();
    }

    #[test]
    fn timed_policies_flow_through_simulate_and_sweep() {
        simulate(&mut args(
            "simulate --workers 12 --batches 3 --family exp --reps 400 \
             --policy speculative --spec-t 2",
        ))
        .unwrap();
        sweep(&mut args(
            "sweep --workers 12 --family exp --reps 300 --policy relaunch --spec-t 2",
        ))
        .unwrap();
        // the analytic backend has closed forms only for the up-front
        // policy; a timed policy must be refused, not silently ignored
        assert!(simulate(&mut args(
            "simulate --workers 12 --batches 3 --family exp --backend analytic \
             --policy speculative --spec-t 1",
        ))
        .is_err());
    }

    #[test]
    fn opensys_runs_a_tiny_spec_and_refuses_closed_specs() {
        let dir = std::env::temp_dir().join("replica_cli_opensys");
        std::fs::create_dir_all(&dir).unwrap();
        let spec = dir.join("open.json");
        std::fs::write(
            &spec,
            r#"{
              "workload": {"generate": {"jobs": 1, "tasks_per_job": 4, "seed": 1}},
              "batches": [1, 4],
              "arrivals": {"rho": [0.3], "jobs": 20, "warmup": 5},
              "backends": ["mc"],
              "reps": 20,
              "seed": 3
            }"#,
        )
        .unwrap();
        opensys(&mut args(&format!("opensys --spec {}", spec.display()))).unwrap();
        // a closed-system spec is refused with a pointer at `sweep`
        let closed = dir.join("closed.json");
        std::fs::write(
            &closed,
            r#"{"workload": {"generate": {"jobs": 1, "tasks_per_job": 4, "seed": 1}}}"#,
        )
        .unwrap();
        let err =
            opensys(&mut args(&format!("opensys --spec {}", closed.display()))).unwrap_err();
        assert!(err.to_string().contains("arrivals"), "{err}");
        // --spec is required
        assert!(opensys(&mut args("opensys")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cost_objective_plans_jointly() {
        // heavy-tail service: replicas are mostly idle insurance, so a
        // speculative launch should be on the table; either way the
        // joint plan must come back with a finite cost prediction
        plan(&mut args(
            "plan --workers 12 --family pareto --sigma 1 --alpha 1.2 \
             --objective cost=0.5 --reps 400 --seed 7",
        ))
        .unwrap();
        plan(&mut args("plan --workers 12 --family exp --joint=true --reps 400")).unwrap();
    }

    #[test]
    fn simulate_backend_selection() {
        simulate(&mut args(
            "simulate --workers 12 --batches 3 --family exp --backend analytic",
        ))
        .unwrap();
        simulate(&mut args(
            "simulate --workers 12 --batches 3 --family exp --backend auto --reps 500",
        ))
        .unwrap();
        simulate(&mut args(
            "simulate --workers 12 --batches 3 --family exp --backend mc --reps 500 \
             --threads 2",
        ))
        .unwrap();
        // analytic backend has no closed form for weibull
        assert!(simulate(&mut args(
            "simulate --workers 12 --batches 3 --family weibull --backend analytic",
        ))
        .is_err());
        assert!(simulate(&mut args(
            "simulate --workers 12 --batches 3 --family exp --backend nope",
        ))
        .is_err());
    }

    #[test]
    fn pool_threads_flag_is_accepted() {
        // parsed in cli::run before dispatch; best-effort if the global
        // pool already exists (e.g. another test initialized it)
        crate::cli::run(
            "simulate --workers 12 --batches 3 --family exp --reps 500 \
             --pool-threads 2"
                .split_whitespace()
                .map(String::from)
                .collect(),
        )
        .unwrap();
    }

    #[test]
    fn trace_gen_and_analyze_roundtrip() {
        let dir = std::env::temp_dir().join("replica_cli_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        trace(&mut args(&format!(
            "trace gen --out {} --tasks 30 --seed 5",
            path.display()
        )))
        .unwrap();
        trace(&mut args(&format!("trace analyze --trace {}", path.display()))).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_spec_runs_and_resumes() {
        let dir = std::env::temp_dir().join("replica_cli_sweep_spec");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let spec = dir.join("spec.json");
        std::fs::write(
            &spec,
            r#"{"workload": {"generate": {"jobs": 2, "tasks_per_job": 12, "seed": 3}},
                "reps": 100, "seed": 1, "shard_size": 4}"#,
        )
        .unwrap();
        let out = dir.join("results.jsonl");
        // budgeted partial run: one shard of 4 scenarios
        sweep(&mut args(&format!(
            "sweep --spec {} --out {} --limit-shards 1",
            spec.display(),
            out.display()
        )))
        .unwrap();
        let partial = std::fs::read_to_string(&out).unwrap();
        assert_eq!(partial.lines().count(), 4);
        // rerun without the budget: resumes and completes 2 jobs x 6 B
        sweep(&mut args(&format!(
            "sweep --spec {} --out {}",
            spec.display(),
            out.display()
        )))
        .unwrap();
        let full = std::fs::read_to_string(&out).unwrap();
        assert_eq!(full.lines().count(), 12);
        assert!(full.starts_with(&partial), "resume must extend the partial prefix");
        assert!(std::fs::metadata(dir.join("results.jsonl.cache.jsonl")).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_spec_missing_file_is_error() {
        assert!(sweep(&mut args("sweep --spec /nonexistent/spec.json")).is_err());
    }

    #[test]
    fn shard_flag_parsing() {
        assert_eq!(parse_shard("0/4").unwrap(), (0, 4));
        assert_eq!(parse_shard("3/4").unwrap(), (3, 4));
        assert_eq!(parse_shard("0/1").unwrap(), (0, 1));
        for bad in ["4/4", "5/4", "0/0", "a/4", "0/b", "04", "-1/4", "1/4/2"] {
            assert!(parse_shard(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn sharded_sweep_plus_merge_matches_single_process() {
        let dir = std::env::temp_dir().join("replica_cli_sweep_shard");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let spec = dir.join("spec.json");
        std::fs::write(
            &spec,
            r#"{"workload": {"generate": {"jobs": 2, "tasks_per_job": 12, "seed": 3}},
                "reps": 100, "seed": 1, "shard_size": 4}"#,
        )
        .unwrap();
        // single-process reference
        let single = dir.join("single.jsonl");
        sweep(&mut args(&format!(
            "sweep --spec {} --out {}",
            spec.display(),
            single.display()
        )))
        .unwrap();
        // two shard processes (run sequentially here; the engine makes
        // no distinction) + merge
        let merged = dir.join("merged.jsonl");
        for k in 0..2 {
            sweep(&mut args(&format!(
                "sweep --spec {} --out {} --shard {k}/2",
                spec.display(),
                merged.display()
            )))
            .unwrap();
        }
        // an explicit --cache would be shared by concurrent shard
        // processes (single-writer format): refused up front
        assert!(sweep(&mut args(&format!(
            "sweep --spec {} --out {} --shard 0/2 --cache {}",
            spec.display(),
            merged.display(),
            dir.join("shared_cache.jsonl").display()
        )))
        .is_err());
        // merge must refuse while using the wrong shard count
        assert!(sweep_merge(&mut args(&format!(
            "sweep-merge --spec {} --out {} --shards 3",
            spec.display(),
            merged.display()
        )))
        .is_err());
        sweep_merge(&mut args(&format!(
            "sweep-merge --spec {} --out {} --shards 2",
            spec.display(),
            merged.display()
        )))
        .unwrap();
        let a = std::fs::read_to_string(&single).unwrap();
        let b = std::fs::read_to_string(&merged).unwrap();
        assert_eq!(a, b, "merged distributed run must be byte-identical");
        // per-shard stores and caches exist under the naming convention
        assert!(dir.join("merged.shard-0-of-2.jsonl").exists());
        assert!(dir.join("merged.shard-1-of-2.jsonl.cache.jsonl").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_merge_report_only_reads_the_store_alone() {
        let dir = std::env::temp_dir().join("replica_cli_report_only");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let spec = dir.join("spec.json");
        std::fs::write(
            &spec,
            r#"{"workload": {"generate": {"jobs": 2, "tasks_per_job": 12, "seed": 3}},
                "policies": ["upfront", {"speculative": 2.0}], "reps": 100, "seed": 1}"#,
        )
        .unwrap();
        let out = dir.join("results.jsonl");
        sweep(&mut args(&format!("sweep --spec {} --out {}", spec.display(), out.display())))
            .unwrap();
        // the report needs only the store: no --spec, no trace
        sweep_merge(&mut args(&format!(
            "sweep-merge --report-only --out {}",
            out.display()
        )))
        .unwrap();
        // a per-shard store is not a complete run: refuse with a hint
        let shard_out = dir.join("sharded.jsonl");
        sweep(&mut args(&format!(
            "sweep --spec {} --out {} --shard 0/2",
            spec.display(),
            shard_out.display()
        )))
        .unwrap();
        assert!(sweep_merge(&mut args(&format!(
            "sweep-merge --report-only --out {}",
            dir.join("sharded.shard-0-of-2.jsonl").display()
        )))
        .is_err());
        // and so is a missing or malformed store
        assert!(sweep_merge(&mut args(&format!(
            "sweep-merge --report-only --out {}",
            dir.join("nope.jsonl").display()
        )))
        .is_err());
        let garbled = dir.join("garbled.jsonl");
        std::fs::write(&garbled, "{\"key\":\"00aa\",\"error\":\"x\"}\n").unwrap();
        assert!(sweep_merge(&mut args(&format!(
            "sweep-merge --report-only --out {}",
            garbled.display()
        )))
        .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cache_import_warms_a_fresh_run() {
        let dir = std::env::temp_dir().join("replica_cli_cache_import");
        std::fs::remove_dir_all(&dir).ok();
        let (warm, cold) = (dir.join("warm"), dir.join("cold"));
        std::fs::create_dir_all(&warm).unwrap();
        std::fs::create_dir_all(&cold).unwrap();
        let spec = dir.join("spec.json");
        std::fs::write(
            &spec,
            r#"{"workload": {"generate": {"jobs": 2, "tasks_per_job": 12, "seed": 3}},
                "reps": 100, "seed": 1}"#,
        )
        .unwrap();
        let first = warm.join("results.jsonl");
        sweep(&mut args(&format!("sweep --spec {} --out {}", spec.display(), first.display())))
            .unwrap();
        // fresh store, fresh cache, warmed from the first run's cache
        // directory: every case is a hit, so the new cache gains no
        // appended lines beyond the 12 imported ones
        let second = cold.join("results.jsonl");
        sweep(&mut args(&format!(
            "sweep --spec {} --out {} --cache-import {}",
            spec.display(),
            second.display(),
            warm.display()
        )))
        .unwrap();
        let a = std::fs::read_to_string(&first).unwrap();
        let b = std::fs::read_to_string(&second).unwrap();
        assert_eq!(a, b, "a cache-warmed run must reproduce the original bytes");
        let imported =
            std::fs::read_to_string(cold.join("results.jsonl.cache.jsonl")).unwrap();
        assert_eq!(imported.lines().count(), 12, "all 12 estimates come from the import");
        // the source cache is untouched
        let source =
            std::fs::read_to_string(warm.join("results.jsonl.cache.jsonl")).unwrap();
        assert_eq!(source.lines().count(), 12);
        // a directory with no caches (or none at all) is a config error
        assert!(sweep(&mut args(&format!(
            "sweep --spec {} --out {} --cache-import {}",
            spec.display(),
            cold.join("again.jsonl").display(),
            dir.join("empty").display()
        )))
        .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_cache_gc_flag_reports_and_compacts() {
        let dir = std::env::temp_dir().join("replica_cli_cache_gc");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let wide = dir.join("wide.json");
        std::fs::write(
            &wide,
            r#"{"workload": {"generate": {"jobs": 2, "tasks_per_job": 12, "seed": 3}},
                "reps": 80, "seed": 1}"#,
        )
        .unwrap();
        let narrow = dir.join("narrow.json");
        std::fs::write(
            &narrow,
            r#"{"workload": {"generate": {"jobs": 2, "tasks_per_job": 12, "seed": 3}},
                "jobs": [1], "reps": 80, "seed": 1}"#,
        )
        .unwrap();
        let cache = dir.join("cache.jsonl");
        // wide run fills the cache with both jobs
        sweep(&mut args(&format!(
            "sweep --spec {} --out {} --cache {}",
            wide.display(),
            dir.join("wide.jsonl").display(),
            cache.display()
        )))
        .unwrap();
        let full = std::fs::read_to_string(&cache).unwrap().lines().count();
        assert_eq!(full, 12);
        // narrow run with --cache-gc drops job 2's now-dead keys
        sweep(&mut args(&format!(
            "sweep --spec {} --out {} --cache {} --cache-gc",
            narrow.display(),
            dir.join("narrow.jsonl").display(),
            cache.display()
        )))
        .unwrap();
        let compacted = std::fs::read_to_string(&cache).unwrap().lines().count();
        assert_eq!(compacted, 6, "job 2's dead keys must be gone");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_merge_without_inputs_is_error() {
        let dir = std::env::temp_dir().join("replica_cli_merge_noinput");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let spec = dir.join("spec.json");
        std::fs::write(
            &spec,
            r#"{"workload": {"generate": {"jobs": 1, "tasks_per_job": 12, "seed": 3}},
                "reps": 50}"#,
        )
        .unwrap();
        assert!(sweep_merge(&mut args("sweep-merge")).is_err(), "--spec is required");
        assert!(sweep_merge(&mut args(&format!("sweep-merge --spec {}", spec.display())))
            .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_merge_allow_partial_publishes_prefix() {
        let dir = std::env::temp_dir().join("replica_cli_merge_partial");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let spec = dir.join("spec.json");
        std::fs::write(
            &spec,
            r#"{"workload": {"generate": {"jobs": 2, "tasks_per_job": 12, "seed": 3}},
                "reps": 100, "seed": 1, "shard_size": 4}"#,
        )
        .unwrap();
        let out = dir.join("merged.jsonl");
        // only the *second* half of the grid ran: the prefix is empty,
        // shard 0's file does not even exist yet
        sweep(&mut args(&format!(
            "sweep --spec {} --out {} --shard 1/2",
            spec.display(),
            out.display()
        )))
        .unwrap();
        assert!(sweep_merge(&mut args(&format!(
            "sweep-merge --spec {} --out {} --shards 2",
            spec.display(),
            out.display()
        )))
        .is_err());
        sweep_merge(&mut args(&format!(
            "sweep-merge --spec {} --out {} --shards 2 --allow-partial=true",
            spec.display(),
            out.display()
        )))
        .unwrap();
        assert_eq!(std::fs::read_to_string(&out).unwrap(), "", "empty covered prefix");
        // completing shard 0 makes the partial merge total
        sweep(&mut args(&format!(
            "sweep --spec {} --out {} --shard 0/2",
            spec.display(),
            out.display()
        )))
        .unwrap();
        sweep_merge(&mut args(&format!(
            "sweep-merge --spec {} --out {} --shards 2 --allow-partial=true",
            spec.display(),
            out.display()
        )))
        .unwrap();
        assert_eq!(std::fs::read_to_string(&out).unwrap().lines().count(), 12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cluster_flags_map_onto_config() {
        let mut a = args(
            "cluster-work --lease-timeout-ms 9000 --heartbeat-ms 1500 --min-lease 4 \
             --max-lease 16 --chunk 3",
        );
        let cfg = cluster_config_from(&mut a).unwrap();
        assert_eq!(cfg.lease_timeout_ms, 9000);
        assert_eq!(cfg.heartbeat_ms, 1500);
        assert_eq!((cfg.min_lease, cfg.max_lease, cfg.chunk), (4, 16, 3));
        // defaults survive for flags not given
        assert_eq!(cfg.poll_ms, ClusterConfig::default().poll_ms);
        // invalid combinations are rejected before any socket opens
        let mut a = args("cluster-work --heartbeat-ms 8000 --lease-timeout-ms 9000");
        assert!(cluster_config_from(&mut a).is_err());
        let mut a = args("cluster-serve --min-lease 8 --max-lease 2");
        assert!(cluster_config_from(&mut a).is_err());
    }

    #[test]
    fn cluster_commands_validate_required_flags() {
        assert!(cluster_serve(&mut args("cluster-serve")).is_err(), "--spec required");
        assert!(
            cluster_serve(&mut args("cluster-serve --spec /nonexistent/spec.json"))
                .is_err()
        );
        assert!(cluster_work(&mut args("cluster-work")).is_err(), "--connect required");
    }

    #[test]
    fn gd_train_native_backend() {
        gd_train(&mut args(
            "gd-train --workers 4 --batches 2 --rounds 5 --backend native --m 8 --d 3 \
             --family sexp --delta 0.01 --mu 10 --time-scale 0.0001",
        ))
        .unwrap();
    }

    #[test]
    fn unknown_experiment_is_error() {
        assert!(experiment(&mut args("experiment fig99")).is_err());
    }

    #[test]
    fn paired_sweep_runs_fixed_and_precision_modes() {
        sweep(&mut args(
            "sweep --workers 12 --family exp --paired=true --reps 300 --seed 3",
        ))
        .unwrap();
        sweep(&mut args(
            "sweep --workers 12 --family exp --paired=true --eps 0.5 --max-reps 256",
        ))
        .unwrap();
        // the paired spectrum couples the up-front policy's draws; a
        // timed policy is refused, not silently un-paired
        assert!(sweep(&mut args(
            "sweep --workers 12 --family exp --paired=true --policy relaunch --spec-t 2",
        ))
        .is_err());
        // the precision target is validated before any wave runs
        assert!(sweep(&mut args(
            "sweep --workers 12 --family exp --paired=true --eps 0",
        ))
        .is_err());
        assert!(sweep(&mut args(
            "sweep --workers 12 --family exp --paired=true --eps lots",
        ))
        .is_err());
    }

    #[test]
    fn crn_bench_prints_the_efficiency_line() {
        crn_bench(&mut args(
            "crn-bench --workers 12 --family exp --eps-rel 0.05 --max-reps 1024 --seed 7",
        ))
        .unwrap();
        crn_bench(&mut args("crn-bench --workers 12 --family exp --eps 0.5 --max-reps 256"))
            .unwrap();
        assert!(crn_bench(&mut args("crn-bench --workers 12 --eps-rel 0")).is_err());
        assert!(crn_bench(&mut args("crn-bench --workers 12 --max-reps 0")).is_err());
    }

    #[test]
    fn crn_bench_resolves_the_arm_from_a_spec() {
        let dir = std::env::temp_dir().join("replica_cli_crn_spec");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let spec = dir.join("spec.json");
        std::fs::write(
            &spec,
            r#"{"workload": {"generate": {"jobs": 2, "tasks_per_job": 12, "seed": 3}},
                "reps": 100, "seed": 1}"#,
        )
        .unwrap();
        crn_bench(&mut args(&format!(
            "crn-bench --spec {} --eps 0.5 --max-reps 256 --seed 5",
            spec.display()
        )))
        .unwrap();
        // a job id absent from the trace is a config error
        assert!(crn_bench(&mut args(&format!(
            "crn-bench --spec {} --job 999 --eps 0.5",
            spec.display()
        )))
        .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_spec_eps_override_targets_precision_and_resumes() {
        let dir = std::env::temp_dir().join("replica_cli_sweep_eps");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let spec = dir.join("spec.json");
        std::fs::write(
            &spec,
            r#"{"workload": {"generate": {"jobs": 2, "tasks_per_job": 12, "seed": 3}},
                "reps": 512, "seed": 1, "shard_size": 4}"#,
        )
        .unwrap();
        let out = dir.join("results.jsonl");
        let cmd = format!(
            "sweep --spec {} --out {} --eps 0.3",
            spec.display(),
            out.display()
        );
        sweep(&mut args(&cmd)).unwrap();
        let first = std::fs::read_to_string(&out).unwrap();
        assert_eq!(first.lines().count(), 12);
        assert!(first.contains("\"replications\":"), "realized counts must be stored");
        // the same precision target resolves the same content keys, so
        // a rerun is a pure resume: byte-identical store
        sweep(&mut args(&cmd)).unwrap();
        assert_eq!(std::fs::read_to_string(&out).unwrap(), first);
        std::fs::remove_dir_all(&dir).ok();
    }
}
