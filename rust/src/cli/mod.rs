//! Command-line interface (hand-rolled; no clap offline).
//!
//! ```text
//! replica plan       --workers 100 --family pareto --alpha 1.5
//!                    [--objective mean|cov|tradeoff=0.5|cost=0.5] [--joint]
//! replica simulate   --workers 100 --batches 10 --family sexp --delta 0.05 --mu 1
//!                    [--backend mc|analytic|auto] [--reps 20000] [--pool-threads 0]
//!                    [--policy upfront|speculative|relaunch --spec-t T]
//! replica sweep      --workers 100 --family sexp --delta 0.05 --mu 1
//!                    [--policy upfront|speculative|relaunch --spec-t T]
//!                    [--paired [--eps E --max-reps N]]
//! replica sweep      --spec sweep.json [--out results.jsonl] [--cache cache.jsonl]
//!                    [--limit-shards K] [--shard K/M] [--cache-gc] [--eps E]
//!                    [--cache-import DIR] [--objective mean|cov|tradeoff=0.5|cost=0.5]
//! replica crn-bench  [--spec sweep.json | --workers N --family F ...]
//!                    [--eps E | --eps-rel R] [--max-reps N] [--seed N]
//! replica opensys    --spec open_system.json [--pool-threads 0] [--threads 0]
//!                    [--objective mean|cov|tradeoff=0.5|cost=0.5]
//! replica sweep-merge --spec sweep.json --out results.jsonl --shards M
//!                    [--allow-partial]
//! replica sweep-merge --report-only --out results.jsonl
//! replica cluster-serve --spec sweep.json --out results.jsonl
//!                    [--listen 127.0.0.1:7700] [--lease-timeout-ms N]
//!                    [--heartbeat-ms N] [--min-lease N] [--max-lease N]
//! replica cluster-work  --connect 127.0.0.1:7700 [--worker NAME] [--threads N]
//! replica trace gen      --out trace.csv [--tasks 100] [--seed 42]
//! replica trace analyze  --trace trace.csv
//! replica experiment <fig3|fig6|fig7_8|fig9_10|regimes|assignment|traces|all> [--reps N] [--out dir]
//! replica gd-train   --workers 16 --batches 4 --rounds 100 [--backend pjrt|native]
//! ```

mod args;
mod commands;

pub use args::Args;

use crate::util::error::{Error, Result};

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn run(argv: Vec<String>) -> Result<()> {
    crate::util::logging::init();
    // The parser treats `--flag word` as a flag with a value, so a bare
    // boolean flag written before a positional (e.g. `sweep-merge
    // --cache-gc a.shard-0-of-2.jsonl ...`) would swallow the
    // positional as its value. Normalize known boolean flags to their
    // explicit `=true` spelling before parsing.
    let argv: Vec<String> = argv
        .into_iter()
        .map(|tok| match tok.as_str() {
            "--cache-gc" | "--report-only" | "--joint" | "--allow-partial" | "--paired" => {
                format!("{tok}=true")
            }
            _ => tok,
        })
        .collect();
    let mut args = Args::parse(argv)?;
    // Size the process-wide simulation pool before any command touches
    // it (`0`/absent = one worker per core). This replaces per-call
    // thread spawning: every Monte-Carlo evaluation in the process
    // shares these workers.
    let pool_threads = args.get_usize("pool-threads", 0)?;
    if pool_threads > 0 {
        crate::sim::pool::WorkerPool::configure_global(pool_threads);
    }
    let cmd = args.positional(0).map(String::from);
    match cmd.as_deref() {
        Some("plan") => commands::plan(&mut args),
        Some("simulate") => commands::simulate(&mut args),
        Some("sweep") => commands::sweep(&mut args),
        Some("crn-bench") => commands::crn_bench(&mut args),
        Some("opensys") => commands::opensys(&mut args),
        Some("sweep-merge") => commands::sweep_merge(&mut args),
        Some("cluster-serve") => commands::cluster_serve(&mut args),
        Some("cluster-work") => commands::cluster_work(&mut args),
        Some("trace") => commands::trace(&mut args),
        Some("experiment") => commands::experiment(&mut args),
        Some("gd-train") => commands::gd_train(&mut args),
        Some("help") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => {
            Err(Error::Config(format!("unknown command '{other}' (try `replica help`)")))
        }
    }
}

pub const HELP: &str = "\
replica — efficient replication for straggler mitigation (paper reproduction)

USAGE:
  replica <command> [flags]

COMMANDS:
  plan        choose the optimal redundancy level for a service-time model
  simulate    estimate job compute time at one operating point through a
              pluggable backend (Monte-Carlo, analytic closed forms, or auto)
  sweep       E[T] and CoV across the full diversity-parallelism spectrum;
              with --spec FILE: the sharded, resumable trace-sweep engine
              (scenario grid -> JSONL store + estimate cache + gain report;
              rerunning the same command resumes a killed run); with
              --shard K/M: one process of an M-way distributed sweep;
              with --paired: the common-random-numbers spectrum (every B
              shares one draw stream; the table adds difference CIs)
  crn-bench   replications needed by the paired (CRN) spectrum vs
              independent streams for the same ±eps difference
              resolution; prints one JSON line (the CI variance floor)
  opensys     the open-system serving sweep: jobs arrive as a stream
              (spec needs an \"arrivals\" axis of offered loads rho),
              each case reports sojourn-time percentiles, worker
              utilization, and worker-seconds per job, and the B*-vs-load
              table shows where redundancy stops paying as load grows
  sweep-merge merge the per-shard stores of a --shard K/M sweep into the
              canonical store (byte-identical to a single-process run);
              with --allow-partial: publish the covered prefix of a
              still-running sweep and list the missing ranges; with
              --report-only: print the gain report straight from an
              existing merged store, no spec or trace needed
  cluster-serve  run the fault-tolerant sweep coordinator: lease grid
              slices to cluster-work processes over TCP, with
              heartbeats, dead-lease reassignment, and shrinking
              leases; the finished store is byte-identical to a
              single-process `sweep --spec` run, and a restarted
              coordinator resumes from the store + cache
  cluster-work   run one sweep worker against a coordinator; survives
              coordinator restarts via exponential-backoff reconnect
  trace       gen | analyze Google-cluster-shaped traces
  experiment  regenerate a paper figure (fig3, fig6, fig7_8, fig9_10,
              regimes, assignment, traces, all)
  gd-train    run live distributed GD through the coordinator (+PJRT)
  help        this text

COMMON FLAGS:
  --workers N           worker budget (default 100)
  --batches B           batch count (must divide N)
  --family F            exp | sexp | pareto | weibull | bimodal
  --mu X --delta X --alpha X --sigma X --shape X --scale X
  --objective O         mean | cov | tradeoff=W | cost=W (cost=W scores
                        w*E[T] + (1-w)*expected worker-seconds; plan
                        then searches (B, t) jointly)
  --policy P            when replicas launch: upfront (default, the
                        paper's policy) | speculative | relaunch
                        (timed policies need --spec-t)
  --spec-t T            timeout for speculative/relaunch policies
  --joint               (plan) search batch counts and speculative
                        timeouts jointly by Monte-Carlo (implied by
                        --objective cost=W)
  --backend B           mc | analytic | auto (simulate; default mc)
  --reps N              Monte-Carlo replications
  --paired              (sweep) evaluate the spectrum on one shared draw
                        stream (common random numbers) and report the
                        ci95 of each point's difference from the best B
  --eps E               precision target: with --paired (or crn-bench),
                        double replications until every difference CI
                        <= E; with --spec, rewrite the spec's budget to
                        reps: {"auto": {"eps": E, "max": reps}}
  --max-reps N          replication ceiling for --eps / crn-bench
  --eps-rel R           (crn-bench) derive eps as R x the best arm's
                        pilot mean (default 0.02)
  --seed N              RNG seed
  --pool-threads N      size of the persistent simulation worker pool,
                        shared by every evaluation (0 = all cores)
  --threads N           per-scenario Monte-Carlo fan-out cap
                        (0 = pool width, 1 = force serial)
  --config FILE         load [system]/[service] sections from TOML

SWEEP-ENGINE FLAGS (sweep --spec FILE / sweep-merge):
  --spec FILE           JSON sweep spec (workload + grid axes; see
                        rust/README.md for the format)
  --out FILE            JSONL result store (default sweep_results.jsonl)
  --cache FILE          estimate cache (default <out>.cache.jsonl; not
                        valid with --shard, whose processes each keep a
                        private <shard store>.cache.jsonl)
  --limit-shards K      stop after K shards (resume later by rerunning)
  --shard K/M           evaluate only the K-th of M contiguous grid
                        slices into <out>.shard-K-of-M.jsonl (0-based;
                        run all M, then sweep-merge; rerun = resume)
  --shards M            (sweep-merge) how many shard files to merge
  --cache-gc            after the run, drop cache keys the current grid
                        no longer asks about and report space reclaimed
  --cache-import DIR    before the run, adopt estimates from DIR's
                        *.cache.jsonl files into this run's cache
                        (DIR itself is never written)
  --allow-partial       (sweep-merge) tolerate an incomplete grid: write
                        the covered prefix and print one JSON line per
                        missing index range instead of refusing
  --report-only         (sweep-merge) skip the merge and print the gain
                        report from the --out store's records alone

CLUSTER FLAGS (cluster-serve / cluster-work):
  --listen ADDR         (serve) TCP address to accept workers on
                        (default 127.0.0.1:7700)
  --connect ADDR        (work) coordinator address to connect to
  --worker NAME         (work) worker name in leases and logs
                        (default w-<pid>)
  --lease-timeout-ms N  lease deadline; a lease not heartbeat-renewed
                        within N ms is reassigned (default 10000)
  --heartbeat-ms N      worker heartbeat interval hint (default 2000;
                        must be <= half the lease timeout)
  --min-lease N         smallest lease, in cases (default 2)
  --max-lease N         largest lease, in cases (default 64; actual
                        size shrinks with the remaining grid)
  --chunk N             (work) cases evaluated between heartbeats
                        (default 8)
";
