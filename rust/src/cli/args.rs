//! Tiny argv parser: positional words + `--flag value` pairs
//! (`--flag=value` also accepted; bare `--flag` is a boolean).

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags that were read at least once (unknown-flag detection).
    consumed: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parse argv (without the program name).
    pub fn parse(argv: Vec<String>) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    a.flags.insert(flag.to_string(), v);
                } else {
                    a.flags.insert(flag.to_string(), "true".to_string());
                }
            } else {
                a.positionals.push(tok);
            }
        }
        Ok(a)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    /// String flag.
    pub fn get(&mut self, key: &str) -> Option<String> {
        self.consumed.insert(key.to_string());
        self.flags.get(key).cloned()
    }

    /// Typed flag with default.
    pub fn get_usize(&mut self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|e| Error::Config(format!("--{key} {v}: {e}"))),
        }
    }

    pub fn get_u64(&mut self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse::<u64>().map_err(|e| Error::Config(format!("--{key} {v}: {e}")))
            }
        }
    }

    pub fn get_f64(&mut self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse::<f64>().map_err(|e| Error::Config(format!("--{key} {v}: {e}")))
            }
        }
    }

    pub fn get_f32(&mut self, key: &str, default: f32) -> Result<f32> {
        Ok(self.get_f64(key, default as f64)? as f32)
    }

    pub fn get_bool(&mut self, key: &str) -> bool {
        self.get(key).is_some_and(|v| v == "true" || v == "1" || v == "yes")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect()).unwrap()
    }

    #[test]
    fn positionals_and_flags() {
        let mut a = parse("experiment fig3 --reps 500 --out=/tmp/x --verbose");
        assert_eq!(a.positional(0), Some("experiment"));
        assert_eq!(a.positional(1), Some("fig3"));
        assert_eq!(a.get_usize("reps", 1).unwrap(), 500);
        assert_eq!(a.get("out").unwrap(), "/tmp/x");
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn negative_number_values() {
        let mut a = parse("plan --mu 1.5 --delta 0.05");
        assert_eq!(a.get_f64("mu", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_f64("delta", 0.0).unwrap(), 0.05);
    }

    #[test]
    fn bad_typed_value_is_error() {
        let mut a = parse("x --reps many");
        assert!(a.get_usize("reps", 1).is_err());
    }

    #[test]
    fn boolean_at_end() {
        let mut a = parse("cmd --flag");
        assert!(a.get_bool("flag"));
    }
}
