//! The redundancy planner — the paper's actionable output.
//!
//! Given a worker budget N and a task service-time model τ (analytic
//! family or fitted from trace samples), choose the batch count B —
//! i.e. the operating point on the diversity–parallelism spectrum —
//! optimizing:
//!
//! * [`Objective::MeanCompletion`] — minimize E\[T\] (Theorems 3, 5, 8),
//! * [`Objective::Predictability`] — minimize CoV\[T\] (Theorems 4, 7, 10),
//! * [`Objective::Tradeoff`] — a weighted blend (the "system
//!   administrator's middle point" of §VI-A),
//! * [`Objective::CostLatency`] — a weighted blend of E\[T\] and
//!   expected total worker-seconds, for clusters that pay for
//!   replication rather than getting it free.
//!
//! Beyond choosing B, [`Planner::plan_joint`] searches the joint
//! (B, t) space: every feasible batch count crossed with the up-front
//! policy and speculative launch timeouts derived from the service
//! distribution's quantiles (see
//! [`crate::sim::policy::ReplicationPolicy`]).
//!
//! All planning flows through one code path, [`Planner::plan_with`],
//! parameterized by an [`Estimator`] backend: [`Planner::plan`] uses
//! [`Auto`] (closed forms where exact, Monte-Carlo otherwise), while
//! [`Planner::plan_simulated`] forces [`MonteCarlo`] — useful when you
//! want simulation-grade numbers even where closed forms exist.
//!
//! Sweeps go through the batched [`Estimator::evaluate_many`] entry
//! point, so a simulated spectrum runs all operating points in
//! parallel on the persistent worker pool
//! ([`crate::sim::pool::WorkerPool`]) instead of point-by-point.
//!
//! Trace-driven planning consumes the sweep engine directly:
//! [`plan_from_samples`] evaluates the empirical τ across the spectrum
//! through [`crate::sweep`] and picks B from the result records via
//! [`plan_from_records`] — no analytic refit in the decision loop
//! (the old refit-and-plan path survives as
//! [`plan_from_samples_refit`]).
//!
//! Every closed-system objective above scores a job against **idle**
//! workers. In the open-system regime ([`crate::sim::queue`],
//! [`crate::eval::OpenSystem`]) jobs arrive as a stream and replication
//! adds offered load, so the same [`choose`] call over per-load
//! [`SweepPoint`] spectra yields a *load-dependent* B\*: high
//! redundancy wins while the system is lightly loaded and collapses
//! toward B = N as utilization climbs (`replica opensys` prints this
//! B\*-vs-ρ curve).

use std::sync::Arc;

use crate::analysis::optimizer::{self, Regime};
use crate::batching::Policy;
use crate::dist::{ServiceDist, TailFit};
use crate::eval::{Auto, Estimator, MonteCarlo, Scenario};
use crate::metrics::Summary;
use crate::sim::policy::ReplicationPolicy;
use crate::sweep::{self, CaseOutcome, CaseResult, ScenarioSet};
use crate::util::error::{Error, Result};

/// Planning objective.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Minimize expected job compute time.
    MeanCompletion,
    /// Minimize the coefficient of variation (maximize predictability).
    Predictability,
    /// Minimize `w·E[T]/E* + (1−w)·CoV/CoV*` for `w ∈ [0,1]`.
    Tradeoff(f64),
    /// Minimize `w·E[T]/E* + (1−w)·cost/cost*` for `w ∈ [0,1]`, where
    /// cost is expected total worker-seconds. Points without a tracked
    /// cost score +∞ under this objective.
    CostLatency(f64),
}

/// A redundancy plan: the chosen operating point plus predictions.
#[derive(Clone, Debug)]
pub struct Plan {
    pub workers: usize,
    pub batches: usize,
    pub batch_size: usize,
    pub replication: usize,
    /// The policy to deploy (always balanced non-overlapping — the
    /// provably optimal family, Theorems 1–2 and §V).
    pub policy: Policy,
    /// When the batch's replicas launch: up-front (the paper's policy,
    /// and the default everywhere except [`Planner::plan_joint`]) or a
    /// timed policy with its chosen timeout.
    pub replication_policy: ReplicationPolicy,
    /// Predicted E[T] at the chosen point.
    pub predicted_mean: f64,
    /// Predicted CoV[T] at the chosen point.
    pub predicted_cov: f64,
    /// Predicted expected total worker-seconds at the chosen point
    /// (NaN when the evaluation path does not track cost).
    pub predicted_cost: f64,
    /// Speedup of E[T] vs the no-redundancy baseline (B = N).
    pub speedup_vs_no_redundancy: f64,
    /// Regime classification when the family has one.
    pub regime: Option<Regime>,
}

/// One row of a spectrum sweep. `cost` is expected total
/// worker-seconds (NaN when the evaluation path does not track it —
/// NaN costs only matter under [`Objective::CostLatency`]).
///
/// `ci95` is the half-width of the point's mean estimate: `0.0` for
/// exact (analytic) points, finite for Monte-Carlo points with at
/// least two completed replications, and NaN for a single-completed-
/// replication estimate (see `eval::Estimate`). A NaN ci95 marks a
/// mean that carries **no** uncertainty information, so
/// [`score_point`] makes such candidates lose deterministically
/// rather than letting a one-sample fluke win the sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    pub batches: usize,
    pub mean: f64,
    pub cov: f64,
    pub cost: f64,
    pub ci95: f64,
}

/// Score one operating point under `objective`, given the sweep-wide
/// normalization anchors (the minimum mean, CoV, and cost over the
/// spectrum — only the blended objectives use them). Lower is better;
/// NaN points (e.g. all-failed Monte-Carlo estimates, or missing cost
/// under the cost objective) score +∞ so they can never win, and so
/// does a NaN `ci95` — a single-completed-replication estimate whose
/// mean is a lone sample with no attached uncertainty (`reps: auto`
/// with a small `max` under heavy failure injection produces these).
pub fn score_point(
    p: &SweepPoint,
    objective: Objective,
    min_mean: f64,
    min_cov: f64,
    min_cost: f64,
) -> f64 {
    if p.ci95.is_nan() {
        return f64::INFINITY;
    }
    let score = match objective {
        Objective::MeanCompletion => p.mean,
        Objective::Predictability => p.cov,
        Objective::Tradeoff(w) => {
            w * p.mean / min_mean.max(1e-300) + (1.0 - w) * p.cov / min_cov.max(1e-300)
        }
        Objective::CostLatency(w) => {
            w * p.mean / min_mean.max(1e-300) + (1.0 - w) * p.cost / min_cost.max(1e-300)
        }
    };
    if score.is_nan() {
        f64::INFINITY
    } else {
        score
    }
}

/// Pick the best operating point of a sweep under `objective` — the one
/// selection rule shared by [`Planner::plan_with`] and the trace-sweep
/// replication-gain report ([`crate::sweep::report`]). Returns `None`
/// for an empty sweep or one with no finite point.
pub fn choose(sweep: &[SweepPoint], objective: Objective) -> Option<SweepPoint> {
    let min_mean = sweep.iter().map(|p| p.mean).fold(f64::INFINITY, f64::min);
    let min_cov = sweep.iter().map(|p| p.cov).fold(f64::INFINITY, f64::min);
    // f64::min skips NaN, so an all-NaN cost column leaves the anchor
    // at +∞ — harmless for the objectives that ignore cost.
    let min_cost = sweep.iter().map(|p| p.cost).fold(f64::INFINITY, f64::min);
    let mut best: Option<(SweepPoint, f64)> = None;
    for p in sweep {
        let score = score_point(p, objective, min_mean, min_cov, min_cost);
        if score.is_finite() && best.as_ref().is_none_or(|(_, s)| score < *s) {
            best = Some((*p, score));
        }
    }
    best.map(|(p, _)| p)
}

/// Redundancy planner for a fixed `(N, τ)`.
#[derive(Clone, Debug)]
pub struct Planner {
    n: usize,
    tau: Arc<ServiceDist>,
}

impl Planner {
    /// Accepts an owned [`ServiceDist`] or a shared `Arc<ServiceDist>`
    /// (cloning a planner, or the scenarios it builds, then shares one
    /// τ allocation).
    pub fn new(n: usize, tau: impl Into<Arc<ServiceDist>>) -> Planner {
        assert!(n >= 1);
        Planner { n, tau: tau.into() }
    }

    pub fn workers(&self) -> usize {
        self.n
    }

    pub fn service(&self) -> &ServiceDist {
        &self.tau
    }

    /// Default plan: closed forms where the family has them, transparent
    /// Monte-Carlo (default budget) otherwise.
    pub fn plan(&self, objective: Objective) -> Plan {
        self.plan_with(objective, &Auto::default())
            .expect("Auto evaluation cannot fail on the feasible spectrum")
    }

    /// Monte-Carlo plan: exhaustive search over the feasible spectrum by
    /// simulation, with per-point substreams derived from `seed`.
    pub fn plan_simulated(
        &self,
        objective: Objective,
        reps: usize,
        seed: u64,
    ) -> Result<Plan> {
        self.plan_with(objective, &MonteCarlo::new(reps, seed))
    }

    /// The one planning code path: sweep the spectrum with `estimator`,
    /// score every operating point under `objective`, materialize the
    /// winner.
    pub fn plan_with<E: Estimator + ?Sized>(
        &self,
        objective: Objective,
        estimator: &E,
    ) -> Result<Plan> {
        let sweep = self.sweep_with(estimator)?;
        let chosen = choose(&sweep, objective).ok_or_else(|| {
            Error::Config("no operating point produced a finite estimate".into())
        })?;
        // last point is B = N (no redundancy)
        let baseline = sweep
            .last()
            .ok_or_else(|| Error::Internal("sweep produced no points".into()))?
            .mean;
        Ok(Plan {
            workers: self.n,
            batches: chosen.batches,
            batch_size: self.n / chosen.batches,
            replication: self.n / chosen.batches,
            policy: Policy::BalancedNonOverlapping { batches: chosen.batches },
            replication_policy: ReplicationPolicy::Upfront,
            predicted_mean: chosen.mean,
            predicted_cov: chosen.cov,
            predicted_cost: chosen.cost,
            speedup_vs_no_redundancy: baseline / chosen.mean,
            regime: self.regime(objective),
        })
    }

    /// Materialize the plan at a specific operating point B.
    pub fn plan_at(&self, b: usize, objective: Objective) -> Plan {
        assert!(self.n % b == 0, "B must divide N");
        // one batched call: the chosen point and the B = N baseline run
        // on independent substreams and share the worker pool
        let scenarios = [
            Scenario::balanced(self.n, b, self.tau.clone()),
            Scenario::balanced(self.n, self.n, self.tau.clone()),
        ];
        let mut estimates = Auto::default()
            .evaluate_many(&scenarios)
            .expect("Auto evaluation cannot fail for feasible B");
        let baseline = estimates.pop().expect("two estimates");
        let est = estimates.pop().expect("two estimates");
        Plan {
            workers: self.n,
            batches: b,
            batch_size: self.n / b,
            replication: self.n / b,
            policy: Policy::BalancedNonOverlapping { batches: b },
            replication_policy: ReplicationPolicy::Upfront,
            predicted_mean: est.mean,
            predicted_cov: est.cov,
            predicted_cost: est.cost,
            speedup_vs_no_redundancy: baseline.mean / est.mean,
            regime: self.regime(objective),
        }
    }

    /// The theorem-level regime classification for the family, if any.
    pub fn regime(&self, objective: Objective) -> Option<Regime> {
        match (self.tau.as_ref(), objective) {
            (ServiceDist::Exp { .. }, Objective::MeanCompletion) => {
                Some(Regime::FullDiversity) // Theorem 3
            }
            (ServiceDist::Exp { .. }, Objective::Predictability) => {
                Some(Regime::FullParallelism) // Theorem 4
            }
            (ServiceDist::ShiftedExp { delta, mu }, Objective::MeanCompletion) => {
                Some(optimizer::sexp_mean_regime(self.n, *delta, *mu)) // Theorem 6
            }
            (ServiceDist::ShiftedExp { delta, mu }, Objective::Predictability)
                if self.n > 4 =>
            {
                Some(optimizer::sexp_cov_regime(self.n, *delta, *mu)) // Theorem 7
            }
            (ServiceDist::Pareto { alpha, .. }, Objective::MeanCompletion)
                if *alpha > 1.0 =>
            {
                Some(optimizer::pareto_mean_regime(self.n, *alpha)) // Theorem 9
            }
            (ServiceDist::Pareto { .. }, Objective::Predictability) => {
                Some(optimizer::pareto_cov_regime()) // Theorem 10
            }
            _ => None,
        }
    }

    /// Default spectrum sweep: (B, E[T], CoV) at every feasible B via
    /// the [`Auto`] backend.
    pub fn sweep(&self) -> Vec<SweepPoint> {
        self.sweep_with(&Auto::default())
            .expect("Auto evaluation cannot fail on the feasible spectrum")
    }

    /// Simulated spectrum sweep (forces Monte-Carlo everywhere).
    pub fn sweep_simulated(&self, reps: usize, seed: u64) -> Result<Vec<SweepPoint>> {
        self.sweep_with(&MonteCarlo::new(reps, seed))
    }

    /// Spectrum sweep through any estimator backend.
    pub fn sweep_with<E: Estimator + ?Sized>(
        &self,
        estimator: &E,
    ) -> Result<Vec<SweepPoint>> {
        Ok(estimator
            .sweep(self.n, &self.tau)?
            .into_iter()
            .map(|(op, est)| SweepPoint {
                batches: op.batches,
                mean: est.mean,
                cov: est.cov,
                cost: est.cost,
                ci95: est.ci95,
            })
            .collect())
    }

    /// Pareto-efficient frontier of (E\[T\], CoV, cost): points not
    /// dominated in all tracked metrics — the menu a system
    /// administrator picks from. Cost compares as equal when either
    /// side is NaN, so sweeps without a cost column degrade to the old
    /// two-axis front.
    pub fn tradeoff_front(&self) -> Vec<SweepPoint> {
        let sweep = self.sweep();
        sweep
            .iter()
            .filter(|p| !sweep.iter().any(|q| dominates(q, p)))
            .copied()
            .collect()
    }

    /// Joint (B, t) plan: sweep every feasible batch count crossed with
    /// the up-front policy and speculative timeouts derived from the
    /// batch-level service quantiles (`t = (N/B)·Q_τ(q)` for
    /// `q ∈` [`JOINT_T_QUANTILES`]), score all candidates under
    /// `objective`, and return the winner. The up-front points are
    /// always in the candidate set, so the joint plan is never worse
    /// (in score) than the pure-B plan on the same sweep.
    ///
    /// All candidates — including those with closed forms — are
    /// evaluated by Monte-Carlo on **one shared draw stream** (common
    /// random numbers): replication `r` of every (B, t) candidate
    /// consumes the same `substream(seed, r)` service draws, so
    /// candidate scores compare paired samples instead of stacking two
    /// independent noise floors on every difference. Timed policies
    /// drain unused draws, so the per-replication stream layout is
    /// identical across the whole candidate set.
    pub fn plan_joint(
        &self,
        objective: Objective,
        reps: usize,
        seed: u64,
    ) -> Result<Plan> {
        let mut scenarios = Vec::new();
        let mut tags: Vec<(usize, ReplicationPolicy)> = Vec::new();
        for b in optimizer::feasible_b(self.n) {
            let k = (self.n / b) as f64;
            scenarios.push(Scenario::balanced(self.n, b, self.tau.clone()));
            tags.push((b, ReplicationPolicy::Upfront));
            if self.n / b < 2 {
                continue; // r = 1: no replicas to time, identical to up-front
            }
            for q in JOINT_T_QUANTILES {
                let t = k * self.tau.quantile(q);
                if !t.is_finite() || t <= 0.0 {
                    continue;
                }
                let policy = ReplicationPolicy::SpeculativeAt { t };
                let scenario = Scenario::balanced(self.n, b, self.tau.clone())
                    .with_replication(policy);
                scenarios.push(scenario);
                tags.push((b, policy));
            }
        }
        let items: Vec<(&Scenario, u64)> = scenarios.iter().map(|s| (s, seed)).collect();
        let estimates = MonteCarlo::new(reps, seed).run_batch(&items)?;
        let points: Vec<SweepPoint> = tags
            .iter()
            .zip(estimates.iter())
            .map(|((b, _), est)| SweepPoint {
                batches: *b,
                mean: est.mean,
                cov: est.cov,
                cost: est.cost,
                ci95: est.ci95,
            })
            .collect();
        let min_mean = points.iter().map(|p| p.mean).fold(f64::INFINITY, f64::min);
        let min_cov = points.iter().map(|p| p.cov).fold(f64::INFINITY, f64::min);
        let min_cost = points.iter().map(|p| p.cost).fold(f64::INFINITY, f64::min);
        let mut best: Option<(usize, f64)> = None;
        for (i, p) in points.iter().enumerate() {
            let score = score_point(p, objective, min_mean, min_cov, min_cost);
            if score.is_finite() && best.is_none_or(|(_, s)| score < s) {
                best = Some((i, score));
            }
        }
        let (idx, _) = best.ok_or_else(|| {
            Error::Config("no (B, t) candidate produced a finite estimate".into())
        })?;
        let (b, policy) = tags[idx];
        let chosen = &points[idx];
        // baseline: the up-front B = N point (always a candidate)
        let baseline = tags
            .iter()
            .zip(points.iter())
            .find(|((bb, pp), _)| *bb == self.n && pp.is_upfront())
            .map(|(_, p)| p.mean)
            .ok_or_else(|| Error::Internal("joint sweep lost its baseline".into()))?;
        Ok(Plan {
            workers: self.n,
            batches: b,
            batch_size: self.n / b,
            replication: self.n / b,
            policy: Policy::BalancedNonOverlapping { batches: b },
            replication_policy: policy,
            predicted_mean: chosen.mean,
            predicted_cov: chosen.cov,
            predicted_cost: chosen.cost,
            speedup_vs_no_redundancy: baseline / chosen.mean,
            regime: None, // theorem regimes only classify up-front plans
        })
    }

    /// Paired spectrum sweep with common random numbers: every feasible
    /// B consumes the **same** per-replication task-service draws
    /// (`substream(seed, rep)` keyed by replication index, not by
    /// operating point), and each row reports the CI of the paired
    /// *difference* against the best-mean reference row. Differences of
    /// monotone-coupled completion times are far less noisy than the
    /// points themselves, so the spectrum resolves B-vs-B comparisons
    /// in a small fraction of the replications independent streams
    /// need.
    ///
    /// Each row's own estimate is bit-identical to
    /// `MonteCarlo::new(reps, seed).evaluate(scenario_b)` — the paired
    /// mode changes which streams are *shared*, never what any single
    /// point computes.
    pub fn sweep_paired(&self, reps: usize, seed: u64) -> Result<PairedSpectrum> {
        self.sweep_paired_mc(&MonteCarlo::new(reps, seed))
    }

    /// [`Planner::sweep_paired`] with an explicit estimator config
    /// (thread caps for tests, a custom seed): `mc.seed` is the shared
    /// stream seed.
    pub fn sweep_paired_mc(&self, mc: &MonteCarlo) -> Result<PairedSpectrum> {
        let feasible = optimizer::feasible_b(self.n);
        let scenarios: Vec<Scenario> = feasible
            .iter()
            .map(|&b| Scenario::balanced(self.n, b, self.tau.clone()))
            .collect();
        // CRN: every item gets the same stream seed.
        let items: Vec<(&Scenario, u64)> =
            scenarios.iter().map(|s| (s, mc.seed)).collect();
        let retained = mc.run_batch_retained(&items)?;
        pair_spectrum(&feasible, &retained, mc.reps)
    }

    /// Precision-targeted paired spectrum: double the replication count
    /// in waves (from [`PAIRED_WAVE_START`]) until every non-reference
    /// row's paired-difference ci95 half-width drops to `eps`, or the
    /// count reaches `max`. The stopping rule is a function of the
    /// accumulated estimates only (never wall-clock), and each wave
    /// recomputes from replication 0, so the result is exactly
    /// [`Planner::sweep_paired`] at the realized count
    /// (`PairedSpectrum::replications`).
    pub fn sweep_paired_until(
        &self,
        eps: f64,
        max: usize,
        seed: u64,
    ) -> Result<PairedSpectrum> {
        if !(eps.is_finite() && eps > 0.0) {
            return Err(Error::Config(format!(
                "paired-spectrum eps must be finite and > 0, got {eps}"
            )));
        }
        if max == 0 {
            return Err(Error::Config("paired-spectrum max must be >= 1".into()));
        }
        let mut reps = PAIRED_WAVE_START.min(max);
        loop {
            let spectrum = self.sweep_paired(reps, seed)?;
            let worst = spectrum.max_diff_ci95();
            if worst <= eps || reps == max {
                return Ok(spectrum);
            }
            reps = reps.saturating_mul(2).min(max);
        }
    }
}

/// First wave size for [`Planner::sweep_paired_until`]; waves double
/// from here, so total work stays within 2× the realized count.
const PAIRED_WAVE_START: usize = 64;

/// One row of a paired (common-random-numbers) spectrum: the usual
/// sweep columns plus the paired-difference statistics against the
/// spectrum's reference row.
#[derive(Clone, Copy, Debug)]
pub struct PairedPoint {
    /// The operating point's own estimate columns, scoreable by
    /// [`choose`] like any independent sweep row.
    pub point: SweepPoint,
    /// Mean of the per-replication difference `T_B(r) − T_ref(r)` over
    /// replications where both completed (0 for the reference row).
    pub diff_mean: f64,
    /// ci95 half-width of that paired difference — the quantity the
    /// paper's B-vs-B comparisons actually need. 0 for the reference
    /// row; NaN when fewer than two replications paired up.
    pub diff_ci95: f64,
    /// Replications entering the paired difference (both sides
    /// completed).
    pub paired: usize,
}

/// A spectrum evaluated under common random numbers — see
/// [`Planner::sweep_paired`]. Rows are in feasible-B order; `reference`
/// indexes the row every difference is taken against.
#[derive(Clone, Debug)]
pub struct PairedSpectrum {
    pub points: Vec<PairedPoint>,
    /// Index of the reference row: the best (smallest) finite mean,
    /// ties broken toward the lower B.
    pub reference: usize,
    /// Replications each row consumed (realized count under
    /// [`Planner::sweep_paired_until`]).
    pub replications: usize,
}

impl PairedSpectrum {
    /// The rows as plain sweep points, for [`choose`],
    /// [`score_point`], and report code that is agnostic to pairing.
    pub fn sweep_points(&self) -> Vec<SweepPoint> {
        let mut points = Vec::with_capacity(self.points.len());
        for p in &self.points {
            points.push(p.point);
        }
        points
    }

    /// Pick the best row under `objective` (same rule as [`choose`]).
    pub fn choose(&self, objective: Objective) -> Option<SweepPoint> {
        choose(&self.sweep_points(), objective)
    }

    /// Worst (largest) paired-difference ci95 over the non-reference
    /// rows — the quantity [`Planner::sweep_paired_until`] drives below
    /// ε. NaN rows (nothing paired yet) count as +∞ so they keep the
    /// wave loop running; an empty or single-row spectrum reports 0.
    pub fn max_diff_ci95(&self) -> f64 {
        let mut worst = 0.0_f64;
        for (i, p) in self.points.iter().enumerate() {
            if i == self.reference {
                continue;
            }
            if p.diff_ci95.is_nan() {
                return f64::INFINITY;
            }
            if p.diff_ci95 > worst {
                worst = p.diff_ci95;
            }
        }
        worst
    }
}

/// Build the paired spectrum from retained per-replication completion
/// times (NaN = failed replication). The reference row is the best
/// finite mean (ties toward the lower B); every other row's difference
/// summary runs over the replications where both rows completed, in
/// replication order.
fn pair_spectrum(
    batches: &[usize],
    retained: &[(crate::eval::Estimate, Vec<f64>)],
    reps: usize,
) -> Result<PairedSpectrum> {
    let mut reference: Option<usize> = None;
    for (i, (est, _)) in retained.iter().enumerate() {
        let better = match reference {
            None => est.mean.is_finite(),
            Some(r) => est.mean.is_finite() && est.mean < retained[r].0.mean,
        };
        if better {
            reference = Some(i);
        }
    }
    let reference = reference.ok_or_else(|| {
        Error::Config("no paired spectrum point produced a finite estimate".into())
    })?;
    let ref_times = &retained[reference].1;
    let mut points = Vec::with_capacity(retained.len());
    for (i, (est, times)) in retained.iter().enumerate() {
        let point = SweepPoint {
            batches: batches[i],
            mean: est.mean,
            cov: est.cov,
            cost: est.cost,
            ci95: est.ci95,
        };
        if i == reference {
            points.push(PairedPoint {
                point,
                diff_mean: 0.0,
                diff_ci95: 0.0,
                paired: est.completed,
            });
            continue;
        }
        let mut diff = Summary::moments_only();
        for (t, r) in times.iter().zip(ref_times.iter()) {
            let d = t - r;
            if !d.is_nan() {
                diff.record(d);
            }
        }
        points.push(PairedPoint {
            point,
            diff_mean: diff.mean(),
            diff_ci95: diff.ci95(),
            paired: diff.count() as usize,
        });
    }
    Ok(PairedSpectrum { points, reference, replications: reps })
}

/// Quantiles of τ whose batch-level values (`(N/B)·Q_τ(q)`) serve as
/// speculative-timeout candidates in [`Planner::plan_joint`].
pub const JOINT_T_QUANTILES: [f64; 3] = [0.5, 0.75, 0.9];

/// Three-axis Pareto dominance for [`Planner::tradeoff_front`]:
/// `q` dominates `p` when it is no worse on every tracked metric and
/// strictly better on at least one. NaN cost on either side makes the
/// cost axis a tie.
fn dominates(q: &SweepPoint, p: &SweepPoint) -> bool {
    let cost_tracked = !(q.cost.is_nan() || p.cost.is_nan());
    let no_worse = q.mean <= p.mean
        && q.cov <= p.cov
        && (!cost_tracked || q.cost <= p.cost);
    let better = q.mean < p.mean
        || q.cov < p.cov
        || (cost_tracked && q.cost < p.cost);
    no_worse && better
}

/// Monte-Carlo budget of [`plan_from_samples`]'s spectrum sweep. Leaner
/// than [`crate::eval::DEFAULT_REPS`]: the objective is shallow near B*
/// and the sweep evaluates every feasible operating point.
pub const SAMPLE_PLAN_REPS: usize = 4_000;

/// Fixed seed of [`plan_from_samples`]'s spectrum sweep, so the
/// sample-driven plan is a deterministic function of `(n, samples,
/// objective)`.
pub const SAMPLE_PLAN_SEED: u64 = 0x5A3D_F00D;

/// Plan directly from observed service-time samples (the §VII flow):
/// classify the tail for reporting, evaluate the **empirical** τ itself
/// across the divisor spectrum on the sweep engine, and choose B from
/// those result records.
///
/// This consumes the engine's records instead of refitting an analytic
/// family and planning on the fit (the old behavior, kept as
/// [`plan_from_samples_refit`]): the fitted family is a two-parameter
/// summary, and on real traces its closed-form optimum can drift from
/// the optimum of the data itself. The returned plan's `regime` is
/// still classified via the fitted family (the empirical distribution
/// has no theorem-level regime).
pub fn plan_from_samples(
    n: usize,
    samples: &[f64],
    objective: Objective,
) -> (Plan, TailFit) {
    let fit = TailFit::classify(samples);
    let tau = Arc::new(ServiceDist::empirical(samples.to_vec()));
    let set = ScenarioSet::spectrum(0, n, tau, SAMPLE_PLAN_REPS, SAMPLE_PLAN_SEED)
        .expect("divisor spectrum of n >= 1 is non-empty");
    let results = sweep::run(&set, &sweep::RunConfig::default())
        .expect("balanced Monte-Carlo spectrum evaluation cannot fail");
    let mut plan = plan_from_records(&results, objective)
        .expect("a failure-free spectrum sweep always has a finite baseline");
    plan.regime = Planner::new(n, fit.best()).regime(objective);
    (plan, fit)
}

/// The pre-engine path: fit the classified family to the samples and
/// plan analytically on the fit. Kept for comparison against
/// [`plan_from_samples`] (see the agreement test) and for callers that
/// want a closed-form plan with no simulation budget.
pub fn plan_from_samples_refit(
    n: usize,
    samples: &[f64],
    objective: Objective,
) -> (Plan, TailFit) {
    let fit = TailFit::classify(samples);
    let planner = Planner::new(n, fit.best());
    (planner.plan(objective), fit)
}

/// Build a plan for one job directly from sweep-engine result records
/// — no refit, no re-evaluation: the records *are* the sweep. Expects
/// one job's grid (every record the same N); error/all-failed records
/// are skipped the same way [`crate::sweep::gain_report`] skips them.
/// The baseline is the largest B present in the records (= N when the
/// grid covers the full spectrum); a missing or degenerate baseline is
/// an error rather than a silently-substituted smaller B.
pub fn plan_from_records(results: &[CaseResult], objective: Objective) -> Result<Plan> {
    let first = results
        .first()
        .ok_or_else(|| Error::Config("plan_from_records needs a non-empty sweep".into()))?;
    let n = first.case.scenario.workers;
    if results.iter().any(|r| r.case.scenario.workers != n) {
        return Err(Error::Config(
            "plan_from_records needs a single job's grid (records mix worker budgets)"
                .into(),
        ));
    }
    let points: Vec<SweepPoint> = results
        .iter()
        .filter_map(|r| match &r.outcome {
            CaseOutcome::Ok(e) => Some(SweepPoint {
                batches: r.case.batches(),
                mean: e.mean,
                cov: e.cov,
                cost: e.cost,
                ci95: e.ci95,
            }),
            CaseOutcome::Error(_) => None,
        })
        .collect();
    let chosen = choose(&points, objective).ok_or_else(|| {
        Error::Config("no record in the sweep produced a finite estimate".into())
    })?;
    let max_b = results.iter().map(|r| r.case.batches()).max().unwrap_or(0);
    let baseline = points
        .iter()
        .find(|p| p.batches == max_b && p.mean.is_finite())
        .ok_or_else(|| {
            Error::Config(format!(
                "sweep records lack a finite B={max_b} baseline point"
            ))
        })?;
    let regime =
        Planner::new(n, Arc::clone(&first.case.scenario.tau)).regime(objective);
    Ok(Plan {
        workers: n,
        batches: chosen.batches,
        batch_size: n / chosen.batches,
        replication: n / chosen.batches,
        policy: Policy::BalancedNonOverlapping { batches: chosen.batches },
        replication_policy: ReplicationPolicy::Upfront,
        predicted_mean: chosen.mean,
        predicted_cov: chosen.cov,
        predicted_cost: chosen.cost,
        speedup_vs_no_redundancy: baseline.mean / chosen.mean,
        regime,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::closed_form;
    use crate::eval::Analytic;
    use crate::util::rng::Pcg64;

    #[test]
    fn exp_plans_match_theorems_3_and_4() {
        let p = Planner::new(100, ServiceDist::exp(1.0));
        let mean_plan = p.plan(Objective::MeanCompletion);
        assert_eq!(mean_plan.batches, 1);
        assert_eq!(mean_plan.regime, Some(Regime::FullDiversity));
        let cov_plan = p.plan(Objective::Predictability);
        assert_eq!(cov_plan.batches, 100);
        assert_eq!(cov_plan.regime, Some(Regime::FullParallelism));
    }

    #[test]
    fn plan_fields_consistent() {
        let p = Planner::new(100, ServiceDist::shifted_exp(0.05, 1.0));
        let plan = p.plan(Objective::MeanCompletion);
        assert_eq!(plan.batches * plan.batch_size, 100);
        assert_eq!(plan.replication, plan.batch_size);
        assert!(plan.predicted_mean > 0.0);
        assert!(plan.speedup_vs_no_redundancy > 0.0);
        // pure-B planning always deploys the paper's up-front policy,
        // with the closed-form cost prediction attached
        assert!(plan.replication_policy.is_upfront());
        assert!(plan.predicted_cost.is_finite() && plan.predicted_cost > 0.0);
        match plan.policy {
            Policy::BalancedNonOverlapping { batches } => assert_eq!(batches, plan.batches),
            _ => panic!("planner must emit the balanced policy"),
        }
    }

    #[test]
    fn plan_matches_closed_form_optimizer() {
        // the estimator-driven sweep must agree with the direct argmin
        // over the closed forms for every family that has them
        for tau in [
            ServiceDist::exp(1.0),
            ServiceDist::shifted_exp(0.05, 1.0),
            ServiceDist::pareto(1.0, 2.5),
        ] {
            let p = Planner::new(100, tau.clone());
            let plan = p.plan(Objective::MeanCompletion);
            let (b_star, val) = crate::analysis::optimizer::optimal_b_mean(100, &tau);
            assert_eq!(plan.batches, b_star, "{}", tau.label());
            assert!((plan.predicted_mean - val).abs() < 1e-12);
        }
    }

    #[test]
    fn sexp_middle_regime_is_interior() {
        let p = Planner::new(100, ServiceDist::shifted_exp(0.05, 1.0));
        let plan = p.plan(Objective::MeanCompletion);
        assert_eq!(plan.regime, Some(Regime::Middle));
        assert!(plan.batches > 1 && plan.batches < 100, "B={}", plan.batches);
    }

    #[test]
    fn choose_flips_b_star_across_open_system_loads() {
        // `choose` is load-agnostic: B* vs ρ comes from handing it one
        // spectrum per load level, as the end-to-end open-system sweep
        // does. Feed it the simulated spectra of sexp(0.1, 1), N = 4.
        use crate::eval::{OpenConfig, OpenSystem};
        let tau = Arc::new(ServiceDist::shifted_exp(0.1, 1.0));
        let spectrum_at = |rho: f64| -> Vec<SweepPoint> {
            [1usize, 4]
                .iter()
                .map(|&b| {
                    let scenario = Scenario::balanced(4, b, Arc::clone(&tau));
                    let os = OpenSystem {
                        reps: 96,
                        seed: 17,
                        threads: 1,
                        open: OpenConfig { rho, jobs: 80, warmup: 20 },
                    };
                    let oe = os.evaluate_open(&scenario).unwrap();
                    SweepPoint {
                        batches: b,
                        mean: oe.estimate.mean,
                        cov: oe.estimate.cov,
                        cost: oe.estimate.cost,
                        ci95: oe.estimate.ci95,
                    }
                })
                .collect()
        };
        // near-idle: full diversity (B = 1) wins the mean, exactly as
        // in the closed system (4·(δ + 1/(4μ)) < δ + H₄/μ)
        let light = choose(&spectrum_at(0.05), Objective::MeanCompletion).unwrap();
        assert_eq!(light.batches, 1, "light load must favor replication");
        // heavy load: B = 1's 4x worker-seconds overload the queue and
        // B* collapses to full parallelism
        let heavy = choose(&spectrum_at(0.9), Objective::MeanCompletion).unwrap();
        assert_eq!(heavy.batches, 4, "heavy load must favor parallelism");
    }

    #[test]
    fn simulated_plan_close_to_analytic() {
        let p = Planner::new(20, ServiceDist::shifted_exp(0.05, 1.0));
        let analytic = p.plan(Objective::MeanCompletion);
        let simulated = p.plan_simulated(Objective::MeanCompletion, 8_000, 11).unwrap();
        // objective is shallow near the optimum: require the simulated
        // choice to be within 5% of the analytic optimum's value
        let sim_val =
            closed_form::mean_t(20, simulated.batches, &ServiceDist::shifted_exp(0.05, 1.0));
        assert!(
            (sim_val - analytic.predicted_mean) / analytic.predicted_mean < 0.05,
            "sim B={} val {sim_val} vs analytic B={} val {}",
            simulated.batches,
            analytic.batches,
            analytic.predicted_mean
        );
    }

    #[test]
    fn plan_with_takes_any_backend() {
        let p = Planner::new(20, ServiceDist::exp(1.0));
        let exact = p.plan_with(Objective::MeanCompletion, &Analytic).unwrap();
        let auto = p.plan_with(Objective::MeanCompletion, &Auto::default()).unwrap();
        assert_eq!(exact.batches, auto.batches);
        assert_eq!(exact.predicted_mean, auto.predicted_mean);
    }

    #[test]
    fn empirical_tau_plans_via_simulation() {
        // heavy-tail sample → planner should pick an interior/low B
        let d = ServiceDist::pareto(1.0, 1.5);
        let mut rng = Pcg64::new(3);
        let samples: Vec<f64> = (0..20_000).map(|_| d.sample(&mut rng)).collect();
        let p = Planner::new(20, ServiceDist::empirical(samples));
        let plan = p.plan_simulated(Objective::MeanCompletion, 4_000, 5).unwrap();
        assert!(plan.batches < 20, "B={}", plan.batches);
        assert!(plan.speedup_vs_no_redundancy > 1.0);
    }

    #[test]
    fn tradeoff_front_is_pareto_efficient() {
        let p = Planner::new(100, ServiceDist::shifted_exp(0.05, 1.0));
        let front = p.tradeoff_front();
        assert!(!front.is_empty());
        for a in &front {
            for b in &front {
                if a.batches != b.batches {
                    assert!(!dominates(b, a), "{:?} dominated by {:?}", a, b);
                }
            }
        }
        // the analytic sweep carries a cost column, so front points do too
        assert!(front.iter().all(|p| p.cost.is_finite() && p.cost > 0.0));
    }

    #[test]
    fn nan_cost_makes_the_cost_axis_a_tie() {
        let a = SweepPoint { batches: 1, mean: 1.0, cov: 0.5, cost: f64::NAN, ci95: 0.0 };
        let b = SweepPoint { batches: 2, mean: 2.0, cov: 0.5, cost: 1.0, ci95: 0.0 };
        // b is worse on mean; its tracked cost cannot rescue it, and
        // a's untracked cost cannot count against it
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        // equal tracked metrics + NaN cost on one side: no domination
        let c = SweepPoint { batches: 4, mean: 1.0, cov: 0.5, cost: 0.1, ci95: 0.0 };
        assert!(!dominates(&a, &c) && !dominates(&c, &a));
        // with cost tracked on both sides it breaks the tie
        let d = SweepPoint { batches: 5, mean: 1.0, cov: 0.5, cost: 0.2, ci95: 0.0 };
        assert!(dominates(&c, &d) && !dominates(&d, &c));
    }

    #[test]
    fn speculative_beats_upfront_on_cost_at_better_mean_for_heavy_tails() {
        // The acceptance scenario for timed replication: under a heavy
        // tail, up-front full diversity (B = 1) pays N·k worker-seconds
        // of insurance and its mean still carries the k = N scaling,
        // while a speculative point at modest B gets the straggler
        // insurance almost free — primaries usually beat the timeout.
        // spec(B=3, t = 4·Q(0.9)) vs upfront(B=1), N=12, Pareto(1, 2):
        // analytically mean ≈ 10.1 vs 12.5 and cost ≈ 25 vs 150.
        let tau = ServiceDist::pareto(1.0, 2.0);
        let mc = MonteCarlo::new(20_000, 33);
        let up = mc.evaluate(&Scenario::balanced(12, 1, tau.clone())).unwrap();
        let t = 4.0 * tau.quantile(0.9);
        let spec = Scenario::balanced(12, 3, tau)
            .with_replication(ReplicationPolicy::SpeculativeAt { t });
        let sp = mc.evaluate(&spec).unwrap();
        assert!(sp.mean <= up.mean, "mean {} vs {}", sp.mean, up.mean);
        assert!(sp.cost < 0.5 * up.cost, "cost {} vs {}", sp.cost, up.cost);
    }

    #[test]
    fn joint_plan_picks_a_timed_policy_when_cost_dominates() {
        // Pareto(1, 1.5), N=12: every up-front point costs ≥ 36
        // worker-seconds while speculative candidates at interior B run
        // near primary-only cost (≈ 28) — so a cost-heavy objective
        // must land on a timed policy.
        let p = Planner::new(12, ServiceDist::pareto(1.0, 1.5));
        let plan = p.plan_joint(Objective::CostLatency(0.1), 20_000, 7).unwrap();
        assert!(
            !plan.replication_policy.is_upfront(),
            "joint plan chose {:?}",
            plan.replication_policy
        );
        assert!(plan.predicted_cost.is_finite() && plan.predicted_cost > 0.0);
        assert_eq!(12 % plan.batches, 0);
        assert_eq!(plan.batch_size, 12 / plan.batches);
        assert!(plan.regime.is_none());
        // deterministic: same seed, same plan
        let again = p.plan_joint(Objective::CostLatency(0.1), 20_000, 7).unwrap();
        assert_eq!(plan.batches, again.batches);
        assert_eq!(plan.replication_policy, again.replication_policy);
        assert_eq!(plan.predicted_cost.to_bits(), again.predicted_cost.to_bits());
        // under the pure mean objective the joint search still returns
        // a coherent plan (possibly up-front — that candidate set is
        // always included)
        let joint = p.plan_joint(Objective::MeanCompletion, 4_000, 7).unwrap();
        assert!(joint.predicted_mean.is_finite() && joint.predicted_mean > 0.0);
        assert_eq!(12 % joint.batches, 0);
    }

    #[test]
    fn plan_from_samples_classifies_and_plans() {
        let d = ServiceDist::pareto(1.0, 1.8);
        let mut rng = Pcg64::new(9);
        let samples: Vec<f64> = (0..30_000).map(|_| d.sample(&mut rng)).collect();
        let (plan, fit) = plan_from_samples(100, &samples, Objective::MeanCompletion);
        assert_eq!(fit.class, crate::dist::TailClass::HeavyTail);
        // heavy tails benefit from interior redundancy (Theorem 9, α < α*)
        assert!(plan.batches < 100, "B={}", plan.batches);
        assert!(plan.speedup_vs_no_redundancy > 1.0);
        // deterministic: the record-driven path has a fixed seed
        let (again, _) = plan_from_samples(100, &samples, Objective::MeanCompletion);
        assert_eq!(plan.batches, again.batches);
        assert_eq!(plan.predicted_mean.to_bits(), again.predicted_mean.to_bits());
    }

    #[test]
    fn record_driven_plan_agrees_with_the_refit_path() {
        // tame tail: both paths must pick operating points of nearly
        // equal value under the fitted family's closed form (the
        // objective is shallow near B*, so the chosen B itself may
        // differ by a step)
        let d = ServiceDist::shifted_exp(0.05, 1.0);
        let mut rng = Pcg64::new(17);
        let samples: Vec<f64> = (0..30_000).map(|_| d.sample(&mut rng)).collect();
        let (direct, fit) = plan_from_samples(20, &samples, Objective::MeanCompletion);
        let (refit, fit2) =
            plan_from_samples_refit(20, &samples, Objective::MeanCompletion);
        assert_eq!(fit.class, fit2.class);
        let family = fit.best();
        let v_direct = closed_form::mean_t(20, direct.batches, &family);
        let v_refit = closed_form::mean_t(20, refit.batches, &family);
        assert!(
            (v_direct - v_refit).abs() / v_refit < 0.05,
            "record-driven B={} ({v_direct}) vs refit B={} ({v_refit})",
            direct.batches,
            refit.batches
        );
        // both regime classifications come from the same fitted family
        assert_eq!(direct.regime, refit.regime);
    }

    #[test]
    fn plan_from_records_consumes_engine_records() {
        let tau = Arc::new(ServiceDist::shifted_exp(0.05, 1.0));
        let set = ScenarioSet::spectrum(1, 20, Arc::clone(&tau), 3_000, 7).unwrap();
        let results = sweep::run(&set, &sweep::RunConfig::default()).unwrap();
        let plan = plan_from_records(&results, Objective::MeanCompletion).unwrap();
        assert_eq!(plan.workers, 20);
        assert_eq!(plan.batches * plan.batch_size, 20);
        assert!(plan.predicted_mean.is_finite() && plan.predicted_mean > 0.0);
        assert!(plan.speedup_vs_no_redundancy > 0.0);
        // the records carry the τ family, so the regime survives
        assert!(plan.regime.is_some());
        assert!(plan_from_records(&[], Objective::MeanCompletion).is_err());
    }

    #[test]
    fn choose_skips_nan_points_and_matches_plan() {
        let pts = vec![
            SweepPoint {
                batches: 1,
                mean: f64::NAN,
                cov: f64::NAN,
                cost: f64::NAN,
                ci95: f64::NAN,
            },
            SweepPoint { batches: 2, mean: 3.0, cov: 0.5, cost: 10.0, ci95: 0.1 },
            SweepPoint { batches: 4, mean: 2.0, cov: 0.9, cost: 30.0, ci95: 0.1 },
        ];
        let best = choose(&pts, Objective::MeanCompletion).unwrap();
        assert_eq!(best.batches, 4);
        let best = choose(&pts, Objective::Predictability).unwrap();
        assert_eq!(best.batches, 2);
        // cost-dominant blend prefers the cheap point; mean-dominant the fast one
        let best = choose(&pts, Objective::CostLatency(0.1)).unwrap();
        assert_eq!(best.batches, 2);
        let best = choose(&pts, Objective::CostLatency(0.9)).unwrap();
        assert_eq!(best.batches, 4);
        assert!(choose(&[], Objective::MeanCompletion).is_none());
        let all_nan = vec![SweepPoint {
            batches: 1,
            mean: f64::NAN,
            cov: f64::NAN,
            cost: f64::NAN,
            ci95: f64::NAN,
        }];
        assert!(choose(&all_nan, Objective::MeanCompletion).is_none());
        // a NaN cost can never win the cost objective, even when every
        // competitor is more expensive on the tracked axes
        let missing_cost = vec![
            SweepPoint { batches: 1, mean: 1.0, cov: 0.1, cost: f64::NAN, ci95: 0.0 },
            SweepPoint { batches: 2, mean: 5.0, cov: 0.5, cost: 10.0, ci95: 0.0 },
        ];
        let best = choose(&missing_cost, Objective::CostLatency(0.5)).unwrap();
        assert_eq!(best.batches, 2);
        // the extracted scorer drives plan_with: same winner either way
        let p = Planner::new(100, ServiceDist::shifted_exp(0.05, 1.0));
        let plan = p.plan(Objective::MeanCompletion);
        let direct = choose(&p.sweep(), Objective::MeanCompletion).unwrap();
        assert_eq!(plan.batches, direct.batches);
    }

    #[test]
    fn nan_ci95_candidates_lose_deterministically() {
        // Regression: a single-completed-replication estimate carries a
        // finite (lone-sample) mean but a NaN ci95. Before the guard it
        // could win `choose` on that fluke mean; now it must lose under
        // every objective.
        let pts = vec![
            SweepPoint { batches: 1, mean: 0.5, cov: 0.1, cost: 1.0, ci95: f64::NAN },
            SweepPoint { batches: 2, mean: 3.0, cov: 0.5, cost: 10.0, ci95: 0.2 },
        ];
        for objective in [
            Objective::MeanCompletion,
            Objective::Predictability,
            Objective::Tradeoff(0.5),
            Objective::CostLatency(0.5),
        ] {
            let best = choose(&pts, objective).unwrap();
            assert_eq!(best.batches, 2, "{objective:?}");
            assert!(
                score_point(&pts[0], objective, 0.5, 0.1, 1.0).is_infinite(),
                "{objective:?}"
            );
        }
        // every candidate degenerate: no winner, not an arbitrary one
        let all_lone = vec![SweepPoint {
            batches: 1,
            mean: 0.5,
            cov: 0.1,
            cost: 1.0,
            ci95: f64::NAN,
        }];
        assert!(choose(&all_lone, Objective::MeanCompletion).is_none());
        // and an end-to-end producer of such estimates: reps=1 Monte
        // Carlo gives ci95 = NaN, which plan_from_records now rejects
        let e = MonteCarlo::new(1, 3)
            .evaluate(&Scenario::balanced(4, 2, ServiceDist::exp(1.0)))
            .unwrap();
        assert_eq!(e.completed, 1);
        assert!(e.ci95.is_nan());
    }

    #[test]
    fn paired_spectrum_rows_match_independent_evaluation_bitwise() {
        // CRN changes which streams are shared, never what any single
        // point computes: row B must equal MonteCarlo::evaluate on the
        // same stream seed, bit for bit.
        let tau = ServiceDist::shifted_exp(0.05, 1.0);
        let p = Planner::new(12, tau.clone());
        let spectrum = p.sweep_paired(2_000, 77).unwrap();
        assert_eq!(spectrum.points.len(), 6); // divisors of 12
        assert_eq!(spectrum.replications, 2_000);
        let mc = MonteCarlo::new(2_000, 77);
        for row in &spectrum.points {
            let single = mc
                .evaluate(&Scenario::balanced(12, row.point.batches, tau.clone()))
                .unwrap();
            assert_eq!(row.point.mean.to_bits(), single.mean.to_bits());
            assert_eq!(row.point.cov.to_bits(), single.cov.to_bits());
            assert_eq!(row.point.cost.to_bits(), single.cost.to_bits());
            assert_eq!(row.point.ci95.to_bits(), single.ci95.to_bits());
        }
        // reference row: best mean, zero self-difference
        let r = &spectrum.points[spectrum.reference];
        assert!(spectrum
            .points
            .iter()
            .all(|q| !q.point.mean.is_finite() || q.point.mean >= r.point.mean));
        assert_eq!(r.diff_mean, 0.0);
        assert_eq!(r.diff_ci95, 0.0);
    }

    #[test]
    fn paired_differences_beat_independent_differences() {
        // The point of CRN: the paired-difference CI must be much
        // tighter than the two independent CIs stacked. SExp couples
        // strongly across B (shared exponential draws).
        let p = Planner::new(12, ServiceDist::shifted_exp(0.05, 1.0));
        let spectrum = p.sweep_paired(2_000, 21).unwrap();
        for (i, row) in spectrum.points.iter().enumerate() {
            if i == spectrum.reference {
                continue;
            }
            let independent = (row.point.ci95.powi(2)
                + spectrum.points[spectrum.reference].point.ci95.powi(2))
            .sqrt();
            assert!(
                row.diff_ci95 < independent,
                "B={}: paired {} vs independent {}",
                row.point.batches,
                row.diff_ci95,
                independent
            );
            assert!(row.paired > 0 && row.paired <= 2_000);
            assert!(row.diff_mean >= 0.0, "reference is the best mean");
        }
    }

    #[test]
    fn paired_spectrum_is_thread_and_entrypoint_invariant() {
        let tau = ServiceDist::pareto(1.0, 2.5);
        let p = Planner::new(8, tau);
        let golden = p
            .sweep_paired_mc(&MonteCarlo { reps: 1_500, seed: 9, threads: 1 })
            .unwrap();
        for threads in [2usize, 4, 8] {
            let wide = p
                .sweep_paired_mc(&MonteCarlo { reps: 1_500, seed: 9, threads })
                .unwrap();
            assert_eq!(golden.reference, wide.reference, "{threads} threads");
            for (a, b) in golden.points.iter().zip(wide.points.iter()) {
                assert_eq!(a.point.mean.to_bits(), b.point.mean.to_bits());
                assert_eq!(a.diff_mean.to_bits(), b.diff_mean.to_bits());
                assert_eq!(a.diff_ci95.to_bits(), b.diff_ci95.to_bits());
                assert_eq!(a.paired, b.paired);
            }
        }
    }

    #[test]
    fn sweep_paired_until_stops_at_the_fixed_reps_spectrum() {
        let p = Planner::new(12, ServiceDist::shifted_exp(0.05, 1.0));
        let auto = p.sweep_paired_until(0.02, 1 << 14, 5).unwrap();
        assert!(auto.max_diff_ci95() <= 0.02, "{}", auto.max_diff_ci95());
        let fixed = p.sweep_paired(auto.replications, 5).unwrap();
        for (a, b) in auto.points.iter().zip(fixed.points.iter()) {
            assert_eq!(a.point.mean.to_bits(), b.point.mean.to_bits());
            assert_eq!(a.diff_ci95.to_bits(), b.diff_ci95.to_bits());
        }
        // unreachable target stops at max
        let capped = p.sweep_paired_until(1e-12, 128, 5).unwrap();
        assert_eq!(capped.replications, 128);
        // bad targets rejected
        assert!(p.sweep_paired_until(0.0, 128, 5).is_err());
        assert!(p.sweep_paired_until(f64::NAN, 128, 5).is_err());
        assert!(p.sweep_paired_until(0.02, 0, 5).is_err());
        // choose() on the paired spectrum agrees with choose() on its
        // flattened rows
        let via_method = auto.choose(Objective::MeanCompletion).unwrap();
        let via_points = choose(&auto.sweep_points(), Objective::MeanCompletion).unwrap();
        assert_eq!(via_method.batches, via_points.batches);
    }

    #[test]
    fn sweep_covers_spectrum_monotonically_for_exp() {
        let p = Planner::new(12, ServiceDist::exp(1.0));
        let sweep = p.sweep();
        assert_eq!(sweep.len(), 6); // divisors of 12
        // Theorem 3: mean increasing in B; Theorem 4: CoV decreasing
        for w in sweep.windows(2) {
            assert!(w[1].mean > w[0].mean);
            assert!(w[1].cov < w[0].cov);
        }
    }
}
