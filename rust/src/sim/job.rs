//! Job-level simulator: one job execution = one sampled compute time.
//!
//! Semantics (paper §II + §IV generalized to arbitrary overlap):
//! every worker `w` draws a service time `S_w` for its whole batch
//! (size-dependent model: `S = |batch| · τ` with per-task i.i.d. τ, or
//! batch-level i.i.d. draws). A task is *recovered* at the earliest
//! finish among workers hosting it; the job completes when all tasks
//! are recovered: `T = max_t min_{w ∋ t} S_w` (eqs. (8)–(9)).
//!
//! Failure injection: a failed worker never reports. If failures break
//! coverage the job is [`JobOutcome::Failed`] — the availability story
//! of §VI's opening.
//!
//! Hot-path shape: service times are drawn through a compiled
//! [`Sampler`] (built once in [`JobSimulator::new`]) into caller-owned
//! [`SimScratch`] buffers via [`JobSimulator::sample_into`], so the
//! replication loop does no per-draw enum dispatch and no per-sample
//! allocation. [`JobSimulator::sample`] stays as the allocating
//! convenience wrapper.
//!
//! Replication timing: [`JobSimulator::with_replication`] selects a
//! [`ReplicationPolicy`] — up-front (the paper's, and the default),
//! speculative-at-`t`, or relaunch-at-`t`. The timed policies reuse the
//! disjoint-layout fast path's draw discipline verbatim (one batched
//! fill of `n_workers` draws, consumed in `batch_workers` order), so
//! the up-front policy's output is bit-identical to the pre-policy
//! kernel, and every policy shares one stream layout per replication.
//! [`JobSimulator::sample_with_cost`] additionally reports the
//! execution's **cost** in worker-seconds (kill-at-batch-completion;
//! NaN on paths that do not track cost — overlap, failures, Failed
//! outcomes).

use crate::batching::Layout;
use crate::dist::{Sampler, ServiceDist};
use crate::sim::event::EventQueue;
use crate::sim::policy::ReplicationPolicy;
use crate::util::rng::Pcg64;

/// Worker failure model for a single job execution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FailureModel {
    /// No failures.
    None,
    /// Each worker independently fails (never reports) with probability
    /// `p`.
    Crash { p: f64 },
    /// Each worker fails with probability `p` but restarts after a fixed
    /// `delay`, then serves a fresh service time (delayed-relaunch
    /// mitigation, \[29\]).
    CrashRestart { p: f64, delay: f64 },
}

/// Outcome of one simulated job execution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum JobOutcome {
    /// Completed at the given virtual time.
    Done(f64),
    /// Coverage impossible: some task's every replica failed.
    Failed,
}

impl JobOutcome {
    pub fn time(&self) -> Option<f64> {
        match self {
            JobOutcome::Done(t) => Some(*t),
            JobOutcome::Failed => None,
        }
    }
}

/// How batch service times are drawn.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServiceModel {
    /// `S_w = |batch| · τ` with one τ per worker (the size-dependent
    /// model of §VI — the default).
    SizeDependentPerWorker,
    /// `S_w` drawn directly from the distribution, ignoring batch size
    /// (the batch-level i.i.d. model of §IV).
    PerBatchDirect,
}

/// Reusable per-thread scratch buffers for the replication loop.
///
/// One `SimScratch` per worker thread (or replication chunk) keeps the
/// no-failure sampling paths allocation-free: buffers grow to the
/// largest scenario seen and are then reused verbatim.
#[derive(Clone, Debug, Default)]
pub struct SimScratch {
    /// One service time per worker (batch-filled by the [`Sampler`]).
    services: Vec<f64>,
    /// Earliest recovery time per task (general path only).
    earliest: Vec<f64>,
}

impl SimScratch {
    pub fn new() -> SimScratch {
        SimScratch::default()
    }
}

/// Verify the disjoint-layout fast-path preconditions exactly:
///
/// 1. batches are pairwise disjoint and jointly cover every task, and
/// 2. `batch_workers` partitions the workers, each listed worker
///    executing exactly its batch.
///
/// Checked with bitsets, not size sums — a layout with one duplicated
/// and one missing task keeps the sums equal while violating coverage,
/// which the sum-based detection this replaces silently accepted
/// (reporting completion for jobs whose missing task makes them
/// unfinishable).
pub(crate) fn fast_disjoint_layout(layout: &Layout) -> bool {
    let mut task_seen = vec![false; layout.n_tasks];
    for tasks in &layout.batches {
        for &t in tasks {
            if t >= layout.n_tasks || task_seen[t] {
                return false;
            }
            task_seen[t] = true;
        }
    }
    if !task_seen.iter().all(|&seen| seen) {
        return false;
    }
    let n_workers = layout.n_workers();
    let mut worker_seen = vec![false; n_workers];
    for (b, workers) in layout.batch_workers.iter().enumerate() {
        for &w in workers {
            if w >= n_workers || worker_seen[w] {
                return false;
            }
            worker_seen[w] = true;
            if layout.worker_tasks[w] != layout.batches[b] {
                return false;
            }
        }
    }
    worker_seen.iter().all(|&seen| seen)
}

/// Borrowed view of everything one replication needs — the actual
/// sampling engine. [`JobSimulator`] wraps it over owned data; the
/// Monte-Carlo randomized-layout path builds one per freshly drawn
/// layout without cloning the service distribution.
#[derive(Clone, Copy, Debug)]
pub(crate) struct SimView<'a> {
    pub(crate) layout: &'a Layout,
    pub(crate) sampler: &'a Sampler,
    pub(crate) model: ServiceModel,
    pub(crate) failure: FailureModel,
    pub(crate) fast_disjoint: bool,
    pub(crate) replication: ReplicationPolicy,
}

impl SimView<'_> {
    /// Draw the service time of one worker.
    fn draw_service(&self, w: usize, rng: &mut Pcg64) -> f64 {
        match self.model {
            ServiceModel::SizeDependentPerWorker => {
                self.layout.worker_tasks[w].len() as f64 * self.sampler.sample_one(rng)
            }
            ServiceModel::PerBatchDirect => self.sampler.sample_one(rng),
        }
    }

    /// Sample one job execution into caller-owned scratch.
    pub(crate) fn sample_into(
        &self,
        rng: &mut Pcg64,
        scratch: &mut SimScratch,
    ) -> JobOutcome {
        self.sample_with_cost(rng, scratch).0
    }

    /// Sample one job execution, returning `(outcome, cost)` where cost
    /// is total worker-seconds under kill-at-batch-completion. Cost is
    /// NaN on the overlap/failure paths (which do not track it) and for
    /// Failed outcomes.
    pub(crate) fn sample_with_cost(
        &self,
        rng: &mut Pcg64,
        scratch: &mut SimScratch,
    ) -> (JobOutcome, f64) {
        match self.replication {
            ReplicationPolicy::Upfront => match self.failure {
                FailureModel::None if self.fast_disjoint => self.sample_fast(rng, scratch),
                FailureModel::None => (self.sample_general(rng, scratch), f64::NAN),
                _ => (self.sample_with_events(rng), f64::NAN),
            },
            ReplicationPolicy::SpeculativeAt { t } => self.sample_speculative(t, rng, scratch),
            ReplicationPolicy::RelaunchAt { t } => self.sample_relaunch(t, rng, scratch),
        }
    }

    /// Disjoint-batch fast path: `T = max_b min_{w∈b} S_w`, one batched
    /// fill, no per-task bookkeeping. Cost: every replica of batch `b`
    /// runs `[0, D_b]`, so the batch adds `r_b · D_b` worker-seconds.
    fn sample_fast(&self, rng: &mut Pcg64, scratch: &mut SimScratch) -> (JobOutcome, f64) {
        let n_draws = self.layout.n_workers();
        scratch.services.resize(n_draws, 0.0);
        self.sampler.fill(rng, &mut scratch.services);
        let mut next = 0usize;
        let mut t_job: f64 = 0.0;
        let mut cost: f64 = 0.0;
        for (b, workers) in self.layout.batch_workers.iter().enumerate() {
            if workers.is_empty() {
                return (JobOutcome::Failed, f64::NAN); // uncovered batch (random assignment)
            }
            let size = self.layout.batches[b].len() as f64;
            let mut min_s = f64::INFINITY;
            for _ in 0..workers.len() {
                let tau = scratch.services[next];
                next += 1;
                let s = match self.model {
                    ServiceModel::SizeDependentPerWorker => size * tau,
                    ServiceModel::PerBatchDirect => tau,
                };
                if s < min_s {
                    min_s = s;
                }
            }
            cost += workers.len() as f64 * min_s;
            if min_s > t_job {
                t_job = min_s;
            }
        }
        (JobOutcome::Done(t_job), cost)
    }

    /// Speculative-at-`t` on the disjoint fast path. Same single fill
    /// and draw order as [`SimView::sample_fast`] — the first draw of a
    /// batch is its primary, the rest are the backups launched at `t`.
    /// Preconditions (disjoint layout, no failure injection) are
    /// enforced by the eval layer; this path degrades to `Failed`
    /// rather than panicking if they are violated.
    fn sample_speculative(
        &self,
        t: f64,
        rng: &mut Pcg64,
        scratch: &mut SimScratch,
    ) -> (JobOutcome, f64) {
        if !self.fast_disjoint || self.failure != FailureModel::None {
            return (JobOutcome::Failed, f64::NAN);
        }
        let n_draws = self.layout.n_workers();
        scratch.services.resize(n_draws, 0.0);
        self.sampler.fill(rng, &mut scratch.services);
        let mut next = 0usize;
        let mut t_job: f64 = 0.0;
        let mut cost: f64 = 0.0;
        for (b, workers) in self.layout.batch_workers.iter().enumerate() {
            if workers.is_empty() {
                return (JobOutcome::Failed, f64::NAN);
            }
            let size = self.layout.batches[b].len() as f64;
            let scale = |tau: f64| match self.model {
                ServiceModel::SizeDependentPerWorker => size * tau,
                ServiceModel::PerBatchDirect => tau,
            };
            let primary = scale(scratch.services[next]);
            next += 1;
            let r = workers.len();
            let (done, batch_cost) = if r == 1 || primary <= t {
                // backups never launch; their draws are still consumed
                // so every policy shares one stream layout
                next += r - 1;
                (primary, primary)
            } else {
                let mut backup_min = f64::INFINITY;
                let backup_lo = next;
                for _ in 1..r {
                    let s = scale(scratch.services[next]);
                    next += 1;
                    if s < backup_min {
                        backup_min = s;
                    }
                }
                let done = primary.min(t + backup_min);
                // primary runs [0, done]; backup i runs [t, min(t+s_i, done)]
                let mut c = done;
                for &tau in &scratch.services[backup_lo..next] {
                    c += scale(tau).min(done - t);
                }
                (done, c)
            };
            cost += batch_cost;
            if done > t_job {
                t_job = done;
            }
        }
        (JobOutcome::Done(t_job), cost)
    }

    /// Relaunch-at-`t` on the disjoint fast path: the batch's `r`
    /// assigned workers become sequential attempts; attempt `i` starts
    /// at `(i−1)·t` and is cancelled at its own deadline unless it is
    /// the last. Exactly one worker is busy at a time, so cost = D.
    fn sample_relaunch(
        &self,
        t: f64,
        rng: &mut Pcg64,
        scratch: &mut SimScratch,
    ) -> (JobOutcome, f64) {
        if !self.fast_disjoint || self.failure != FailureModel::None {
            return (JobOutcome::Failed, f64::NAN);
        }
        let n_draws = self.layout.n_workers();
        scratch.services.resize(n_draws, 0.0);
        self.sampler.fill(rng, &mut scratch.services);
        let mut next = 0usize;
        let mut t_job: f64 = 0.0;
        let mut cost: f64 = 0.0;
        for (b, workers) in self.layout.batch_workers.iter().enumerate() {
            if workers.is_empty() {
                return (JobOutcome::Failed, f64::NAN);
            }
            let size = self.layout.batches[b].len() as f64;
            let r = workers.len();
            let mut done = f64::NAN;
            for i in 0..r {
                let tau = scratch.services[next];
                next += 1;
                if !done.is_nan() {
                    continue; // finished earlier; drain the batch's draws
                }
                let s = match self.model {
                    ServiceModel::SizeDependentPerWorker => size * tau,
                    ServiceModel::PerBatchDirect => tau,
                };
                if s <= t || i == r - 1 {
                    done = i as f64 * t + s;
                }
            }
            cost += done;
            if done > t_job {
                t_job = done;
            }
        }
        (JobOutcome::Done(t_job), cost)
    }

    /// General overlap path: per-task earliest-recovery scan.
    fn sample_general(&self, rng: &mut Pcg64, scratch: &mut SimScratch) -> JobOutcome {
        let n_workers = self.layout.n_workers();
        scratch.services.resize(n_workers, 0.0);
        self.sampler.fill(rng, &mut scratch.services);
        if self.model == ServiceModel::SizeDependentPerWorker {
            for (w, s) in scratch.services.iter_mut().enumerate() {
                *s *= self.layout.worker_tasks[w].len() as f64;
            }
        }
        scratch.earliest.clear();
        scratch.earliest.resize(self.layout.n_tasks, f64::INFINITY);
        for (w, tasks) in self.layout.worker_tasks.iter().enumerate() {
            let s = scratch.services[w];
            for &t in tasks {
                if s < scratch.earliest[t] {
                    scratch.earliest[t] = s;
                }
            }
        }
        let mut t_job: f64 = 0.0;
        for &e in &scratch.earliest {
            if e == f64::INFINITY {
                return JobOutcome::Failed; // uncovered task
            }
            if e > t_job {
                t_job = e;
            }
        }
        JobOutcome::Done(t_job)
    }

    /// Event-driven execution path (used when failures are modeled):
    /// workers start at t=0; completion events update task coverage; the
    /// job finishes when coverage is total.
    fn sample_with_events(&self, rng: &mut Pcg64) -> JobOutcome {
        #[derive(PartialEq, Debug, Clone, Copy)]
        enum Ev {
            Finish(usize),
            Restart(usize),
        }
        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut alive_replicas = vec![0usize; self.layout.n_tasks];
        for (w, tasks) in self.layout.worker_tasks.iter().enumerate() {
            let failed = match self.failure {
                FailureModel::None => false,
                FailureModel::Crash { p } | FailureModel::CrashRestart { p, .. } => {
                    rng.uniform() < p
                }
            };
            if failed {
                match self.failure {
                    FailureModel::CrashRestart { delay, .. } => {
                        if q.schedule(delay, Ev::Restart(w)).is_err() {
                            return JobOutcome::Failed; // non-finite restart delay
                        }
                    }
                    _ => continue, // permanently dead; not counted alive
                }
            } else if q.schedule(self.draw_service(w, rng), Ev::Finish(w)).is_err() {
                return JobOutcome::Failed; // non-finite service draw
            }
            for &t in tasks {
                alive_replicas[t] += 1;
            }
        }
        if alive_replicas.iter().any(|&c| c == 0) {
            return JobOutcome::Failed;
        }
        let mut remaining: usize = self.layout.n_tasks;
        let mut covered = vec![false; self.layout.n_tasks];
        while let Some(ev) = q.pop() {
            match ev.payload {
                Ev::Finish(w) => {
                    for &t in &self.layout.worker_tasks[w] {
                        if !covered[t] {
                            covered[t] = true;
                            remaining -= 1;
                        }
                    }
                    if remaining == 0 {
                        return JobOutcome::Done(ev.time);
                    }
                }
                Ev::Restart(w) => {
                    let s = self.draw_service(w, rng);
                    if q.schedule_in(s, Ev::Finish(w)).is_err() {
                        return JobOutcome::Failed; // non-finite service draw
                    }
                }
            }
        }
        JobOutcome::Failed
    }
}

/// Simulator for a fixed layout + service-time model.
#[derive(Clone, Debug)]
pub struct JobSimulator {
    layout: Layout,
    /// Compiled once from the service distribution; every replication
    /// draws through it.
    sampler: Sampler,
    model: ServiceModel,
    failure: FailureModel,
    /// Perf fast path (EXPERIMENTS.md §Perf): when batches are pairwise
    /// disjoint and jointly cover the task set, and the batch→worker map
    /// partitions the workers, `T = max_b min_{w∈b} S_w` — O(N) with no
    /// allocation, instead of the general O(N · batch_size) per-task
    /// scan. All non-overlapping policies qualify; overlapping layouts
    /// fall back to the general path. Verified exactly (bitsets), not
    /// by size sums — see [`fast_disjoint_layout`].
    fast_disjoint: bool,
    /// When replicas launch (up-front by default; timed policies run on
    /// the disjoint fast path only — see [`ReplicationPolicy`]).
    replication: ReplicationPolicy,
}

impl JobSimulator {
    /// Build a simulator for `layout` with service times drawn from
    /// `tau`. Takes the distribution by [`Borrow`](std::borrow::Borrow)
    /// — an owned [`ServiceDist`], a reference, or a shared
    /// `Arc<ServiceDist>` all work without cloning the distribution
    /// (only its compiled [`Sampler`] is kept).
    pub fn new(layout: Layout, tau: impl std::borrow::Borrow<ServiceDist>) -> JobSimulator {
        let fast_disjoint = fast_disjoint_layout(&layout);
        JobSimulator {
            layout,
            sampler: tau.borrow().sampler(),
            model: ServiceModel::SizeDependentPerWorker,
            failure: FailureModel::None,
            fast_disjoint,
            replication: ReplicationPolicy::Upfront,
        }
    }

    pub fn with_service_model(mut self, model: ServiceModel) -> Self {
        self.model = model;
        self
    }

    pub fn with_failures(mut self, failure: FailureModel) -> Self {
        self.failure = failure;
        self
    }

    /// Select the replication timing policy. Timed policies
    /// (speculative/relaunch) require a disjoint layout and no failure
    /// injection; violating combinations yield `Failed` outcomes —
    /// [`crate::eval::MonteCarlo`] rejects them with a config error
    /// before any sampling starts.
    pub fn with_replication(mut self, replication: ReplicationPolicy) -> Self {
        self.replication = replication;
        self
    }

    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// The borrowed replication view over this simulator's data.
    pub(crate) fn view(&self) -> SimView<'_> {
        SimView {
            layout: &self.layout,
            sampler: &self.sampler,
            model: self.model,
            failure: self.failure,
            fast_disjoint: self.fast_disjoint,
            replication: self.replication,
        }
    }

    /// Sample one job execution (allocating convenience wrapper around
    /// [`JobSimulator::sample_into`]).
    pub fn sample(&self, rng: &mut Pcg64) -> JobOutcome {
        let mut scratch = SimScratch::new();
        self.sample_into(rng, &mut scratch)
    }

    /// Sample one job execution into caller-owned scratch buffers —
    /// the allocation-free entry point replication loops should use.
    pub fn sample_into(&self, rng: &mut Pcg64, scratch: &mut SimScratch) -> JobOutcome {
        self.view().sample_into(rng, scratch)
    }

    /// Sample one execution and its cost in worker-seconds (see
    /// [`ReplicationPolicy`] for the per-policy cost semantics; NaN on
    /// paths that do not track cost).
    pub fn sample_with_cost(
        &self,
        rng: &mut Pcg64,
        scratch: &mut SimScratch,
    ) -> (JobOutcome, f64) {
        self.view().sample_with_cost(rng, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::closed_form;
    use crate::batching::Policy;
    use crate::metrics::Summary;

    fn mc_mean(sim: &JobSimulator, reps: usize, seed: u64) -> (f64, f64, f64) {
        let mut rng = Pcg64::new(seed);
        let mut scratch = SimScratch::new();
        let mut s = Summary::moments_only();
        let mut fails = 0usize;
        for _ in 0..reps {
            match sim.sample_into(&mut rng, &mut scratch) {
                JobOutcome::Done(t) => s.record(t),
                JobOutcome::Failed => fails += 1,
            }
        }
        (s.mean(), s.cov(), fails as f64 / reps as f64)
    }

    #[test]
    fn matches_exp_closed_form() {
        // Theorem 3 setting: E[T] = H_B / μ for any B | N
        let n = 12;
        let mut rng = Pcg64::new(1);
        for b in [1usize, 2, 3, 4, 6, 12] {
            let layout =
                Policy::BalancedNonOverlapping { batches: b }.layout(n, &mut rng).unwrap();
            let sim = JobSimulator::new(layout, ServiceDist::exp(1.0));
            let (mean, _, fr) = mc_mean(&sim, 40_000, 100 + b as u64);
            let want = closed_form::exp_mean(b, 1.0);
            assert_eq!(fr, 0.0);
            assert!(
                (mean - want).abs() / want < 0.03,
                "B={b}: sim {mean} vs closed {want}"
            );
        }
    }

    #[test]
    fn matches_sexp_closed_form() {
        let n = 20;
        let (d, mu) = (0.05, 1.0);
        let mut rng = Pcg64::new(2);
        for b in [1usize, 2, 4, 5, 10, 20] {
            let layout =
                Policy::BalancedNonOverlapping { batches: b }.layout(n, &mut rng).unwrap();
            let sim = JobSimulator::new(layout, ServiceDist::shifted_exp(d, mu));
            let (mean, cov, _) = mc_mean(&sim, 40_000, 200 + b as u64);
            let want = closed_form::sexp_mean(n, b, d, mu);
            let want_cov = closed_form::sexp_cov(n, b, d, mu);
            assert!((mean - want).abs() / want < 0.03, "B={b}: {mean} vs {want}");
            assert!(
                (cov - want_cov).abs() / want_cov < 0.08,
                "B={b}: cov {cov} vs {want_cov}"
            );
        }
    }

    #[test]
    fn matches_pareto_closed_form_including_corrected_cov() {
        let n = 20;
        let (sigma, alpha) = (1.0, 3.0);
        let mut rng = Pcg64::new(3);
        for b in [1usize, 4, 10] {
            let layout =
                Policy::BalancedNonOverlapping { batches: b }.layout(n, &mut rng).unwrap();
            let sim = JobSimulator::new(layout, ServiceDist::pareto(sigma, alpha));
            let (mean, cov, _) = mc_mean(&sim, 60_000, 300 + b as u64);
            let want = closed_form::pareto_mean(n, b, sigma, alpha);
            assert!((mean - want).abs() / want < 0.03, "B={b}: {mean} vs {want}");
            // the *corrected* CoV formula must match simulation
            let want_cov = closed_form::pareto_cov(n, b, alpha);
            assert!(
                (cov - want_cov).abs() / want_cov < 0.15,
                "B={b}: cov {cov} vs corrected {want_cov}"
            );
        }
    }

    #[test]
    fn per_batch_direct_model_first_order_stats() {
        // §IV model: batch times i.i.d. Exp(μ) regardless of size; with
        // balanced assignment T_i ~ Exp((N/B)μ) and T = max of B.
        let n = 12;
        let b = 3;
        let mut rng = Pcg64::new(4);
        let layout = Policy::BalancedNonOverlapping { batches: b }.layout(n, &mut rng).unwrap();
        let sim = JobSimulator::new(layout, ServiceDist::exp(1.0))
            .with_service_model(ServiceModel::PerBatchDirect);
        let (mean, _, _) = mc_mean(&sim, 60_000, 5);
        // E[max of B Exp(rμ)] = H_B / (rμ), r = N/B = 4
        let want = closed_form::exp_mean(b, 4.0);
        assert!((mean - want).abs() / want < 0.03, "{mean} vs {want}");
    }

    #[test]
    fn crash_failures_leave_jobs_unfinished_without_redundancy() {
        // full parallelism + crashes: any crash kills the job
        let n = 10;
        let mut rng = Pcg64::new(5);
        let layout = Policy::BalancedNonOverlapping { batches: n }.layout(n, &mut rng).unwrap();
        let sim = JobSimulator::new(layout, ServiceDist::exp(1.0))
            .with_failures(FailureModel::Crash { p: 0.1 });
        let (_, _, fail_rate) = mc_mean(&sim, 20_000, 6);
        // Pr{job fails} = 1 − (1−p)^10 ≈ 0.651
        let want = 1.0 - 0.9f64.powi(10);
        assert!((fail_rate - want).abs() < 0.02, "{fail_rate} vs {want}");
    }

    #[test]
    fn replication_restores_availability() {
        // B=1 (full diversity): job fails only if ALL workers crash
        let n = 10;
        let mut rng = Pcg64::new(7);
        let layout = Policy::BalancedNonOverlapping { batches: 1 }.layout(n, &mut rng).unwrap();
        let sim = JobSimulator::new(layout, ServiceDist::exp(1.0))
            .with_failures(FailureModel::Crash { p: 0.1 });
        let (_, _, fail_rate) = mc_mean(&sim, 20_000, 8);
        assert!(fail_rate < 1e-3, "{fail_rate}");
    }

    #[test]
    fn crash_restart_always_completes_but_slower() {
        let n = 8;
        let mut rng = Pcg64::new(9);
        let layout = Policy::BalancedNonOverlapping { batches: 8 }.layout(n, &mut rng).unwrap();
        let clean = JobSimulator::new(layout.clone(), ServiceDist::exp(1.0));
        let faulty = JobSimulator::new(layout, ServiceDist::exp(1.0))
            .with_failures(FailureModel::CrashRestart { p: 0.3, delay: 5.0 });
        let (m_clean, _, fr_clean) = mc_mean(&clean, 20_000, 10);
        let (m_faulty, _, fr_faulty) = mc_mean(&faulty, 20_000, 11);
        assert_eq!(fr_clean, 0.0);
        assert_eq!(fr_faulty, 0.0);
        assert!(m_faulty > m_clean + 1.0, "{m_faulty} vs {m_clean}");
    }

    #[test]
    fn event_path_matches_fast_path_statistically() {
        // CrashRestart with p=0 must reproduce the no-failure estimate
        let n = 12;
        let mut rng = Pcg64::new(12);
        let layout = Policy::BalancedNonOverlapping { batches: 4 }.layout(n, &mut rng).unwrap();
        let fast = JobSimulator::new(layout.clone(), ServiceDist::shifted_exp(0.1, 2.0));
        let slow = JobSimulator::new(layout, ServiceDist::shifted_exp(0.1, 2.0))
            .with_failures(FailureModel::CrashRestart { p: 0.0, delay: 1.0 });
        let (m_fast, _, _) = mc_mean(&fast, 30_000, 13);
        let (m_slow, _, _) = mc_mean(&slow, 30_000, 14);
        assert!((m_fast - m_slow).abs() / m_fast < 0.03, "{m_fast} vs {m_slow}");
    }

    #[test]
    fn random_assignment_fails_on_uncovered_batches() {
        // With B close to N, random assignment frequently leaves batches
        // uncovered → Failed outcomes (the Lemma 1 pathology).
        let n = 20;
        let b = 10;
        let mut rng = Pcg64::new(15);
        let mut fails = 0usize;
        let trials = 5_000;
        for _ in 0..trials {
            let layout =
                Policy::RandomNonOverlapping { batches: b }.layout(n, &mut rng).unwrap();
            let sim = JobSimulator::new(layout, ServiceDist::exp(1.0));
            if matches!(sim.sample(&mut rng), JobOutcome::Failed) {
                fails += 1;
            }
        }
        let p_fail = fails as f64 / trials as f64;
        let want = 1.0 - crate::analysis::coverage::coverage_probability(n, b);
        assert!((p_fail - want).abs() < 0.03, "{p_fail} vs {want}");
    }

    #[test]
    fn duplicated_plus_missing_task_defeats_sum_based_detection() {
        // Regression: batch sizes sum to n_tasks (task 1 duplicated,
        // task 3 missing) and the workers partition cleanly, so the old
        // sum-based fast_disjoint detection took the fast path and
        // reported a completion time for a job that can never finish.
        let layout = Layout {
            n_tasks: 4,
            worker_tasks: vec![vec![0, 1], vec![0, 1], vec![1, 2], vec![1, 2]],
            batches: vec![vec![0, 1], vec![1, 2]],
            batch_workers: vec![vec![0, 1], vec![2, 3]],
        };
        assert!(!fast_disjoint_layout(&layout));
        let sim = JobSimulator::new(layout, ServiceDist::exp(1.0));
        let mut rng = Pcg64::new(77);
        for _ in 0..50 {
            assert_eq!(sim.sample(&mut rng), JobOutcome::Failed);
        }
    }

    #[test]
    fn fast_disjoint_detection_accepts_and_rejects_correctly() {
        let mut rng = Pcg64::new(21);
        // all non-overlapping policies qualify
        for policy in [
            Policy::BalancedNonOverlapping { batches: 4 },
            Policy::UnbalancedNonOverlapping { assignment: vec![5, 1, 1, 1] },
            Policy::RandomNonOverlapping { batches: 4 },
        ] {
            let layout = policy.layout(8, &mut rng).unwrap();
            assert!(fast_disjoint_layout(&layout), "{}", policy.name());
        }
        // overlapping layouts do not
        let layout = Policy::CyclicOverlapping { batches: 4 }.layout(8, &mut rng).unwrap();
        assert!(!fast_disjoint_layout(&layout));
        // a worker listed under two batches is rejected even when sums
        // look consistent
        let layout = Layout {
            n_tasks: 2,
            worker_tasks: vec![vec![0], vec![1]],
            batches: vec![vec![0], vec![1]],
            batch_workers: vec![vec![0], vec![0]],
        };
        assert!(!fast_disjoint_layout(&layout));
    }

    #[test]
    fn speculative_at_zero_matches_upfront() {
        // t = 0: backups launch immediately → identical completion
        // times (bitwise: same fill, same consumption order) and the
        // same cost up to summation order
        let mut rng = Pcg64::new(40);
        for b in [1usize, 2, 3, 4, 6, 12] {
            let layout =
                Policy::BalancedNonOverlapping { batches: b }.layout(12, &mut rng).unwrap();
            let upfront = JobSimulator::new(layout.clone(), ServiceDist::pareto(1.0, 2.0));
            let spec = JobSimulator::new(layout, ServiceDist::pareto(1.0, 2.0))
                .with_replication(ReplicationPolicy::SpeculativeAt { t: 0.0 });
            let mut scratch = SimScratch::new();
            for rep in 0..200u64 {
                let mut a = Pcg64::new(1_000 + rep);
                let mut c = Pcg64::new(1_000 + rep);
                let (out_u, cost_u) = upfront.sample_with_cost(&mut a, &mut scratch);
                let (out_s, cost_s) = spec.sample_with_cost(&mut c, &mut scratch);
                let (Some(tu), Some(ts)) = (out_u.time(), out_s.time()) else {
                    panic!("balanced layouts never fail");
                };
                assert_eq!(tu.to_bits(), ts.to_bits(), "B={b}");
                assert!((cost_u - cost_s).abs() / cost_u < 1e-12, "B={b}");
            }
        }
    }

    #[test]
    fn upfront_cost_is_replicas_times_completion() {
        // B=1: every one of the N workers runs exactly [0, T]
        let n = 8;
        let mut rng = Pcg64::new(41);
        let layout = Policy::BalancedNonOverlapping { batches: 1 }.layout(n, &mut rng).unwrap();
        let sim = JobSimulator::new(layout, ServiceDist::exp(1.0));
        let mut scratch = SimScratch::new();
        for _ in 0..100 {
            let (out, cost) = sim.sample_with_cost(&mut rng, &mut scratch);
            let t = out.time().unwrap();
            assert_eq!(cost.to_bits(), (n as f64 * t).to_bits());
        }
    }

    #[test]
    fn huge_timeout_reduces_both_timed_policies_to_primary_only() {
        // t far above any service time: the primary always beats the
        // deadline, so speculative and relaunch agree bitwise — D = s_1
        // per batch and cost = completion work only
        let mut rng = Pcg64::new(42);
        let layout = Policy::BalancedNonOverlapping { batches: 3 }.layout(12, &mut rng).unwrap();
        let tau = ServiceDist::shifted_exp(0.05, 1.0);
        let spec = JobSimulator::new(layout.clone(), tau.clone())
            .with_replication(ReplicationPolicy::SpeculativeAt { t: 1e12 });
        let relaunch = JobSimulator::new(layout, tau)
            .with_replication(ReplicationPolicy::RelaunchAt { t: 1e12 });
        let mut scratch = SimScratch::new();
        for rep in 0..200u64 {
            let mut a = Pcg64::new(2_000 + rep);
            let mut b = Pcg64::new(2_000 + rep);
            let (out_s, cost_s) = spec.sample_with_cost(&mut a, &mut scratch);
            let (out_r, cost_r) = relaunch.sample_with_cost(&mut b, &mut scratch);
            assert_eq!(out_s.time().unwrap().to_bits(), out_r.time().unwrap().to_bits());
            assert_eq!(cost_s.to_bits(), cost_r.to_bits());
        }
    }

    #[test]
    fn relaunch_cost_equals_sum_of_batch_completions() {
        // one worker busy at a time → the job's cost is Σ_b D_b; with
        // B=1 that is exactly T
        let mut rng = Pcg64::new(43);
        let layout = Policy::BalancedNonOverlapping { batches: 1 }.layout(6, &mut rng).unwrap();
        let sim = JobSimulator::new(layout, ServiceDist::exp(1.0))
            .with_replication(ReplicationPolicy::RelaunchAt { t: 0.4 });
        let mut scratch = SimScratch::new();
        for _ in 0..200 {
            let (out, cost) = sim.sample_with_cost(&mut rng, &mut scratch);
            assert_eq!(cost.to_bits(), out.time().unwrap().to_bits());
        }
    }

    #[test]
    fn speculative_trades_latency_for_cost_on_average() {
        // a positive timeout can only delay completion, and for a
        // heavy-tail τ it saves real worker-seconds: up-front pays
        // r·E[min_r] ≈ r·σ while speculation usually pays one draw
        let n = 12;
        let b = 3;
        let mut rng = Pcg64::new(44);
        let layout = Policy::BalancedNonOverlapping { batches: b }.layout(n, &mut rng).unwrap();
        let tau = ServiceDist::pareto(1.0, 2.0);
        let upfront = JobSimulator::new(layout.clone(), tau.clone());
        let spec = JobSimulator::new(layout, tau)
            .with_replication(ReplicationPolicy::SpeculativeAt { t: 8.0 });
        let mut scratch = SimScratch::new();
        let reps = 20_000u64;
        let (mut t_u, mut c_u, mut t_s, mut c_s) = (0.0, 0.0, 0.0, 0.0);
        for rep in 0..reps {
            let mut a = Pcg64::new(3_000 + rep);
            let mut c = Pcg64::new(3_000 + rep);
            let (out, cost) = upfront.sample_with_cost(&mut a, &mut scratch);
            let (out2, cost2) = spec.sample_with_cost(&mut c, &mut scratch);
            let (ta, ts) = (out.time().unwrap(), out2.time().unwrap());
            assert!(ts >= ta, "speculation cannot beat upfront on the same draws");
            t_u += ta;
            c_u += cost;
            t_s += ts;
            c_s += cost2;
        }
        assert!(t_s >= t_u);
        assert!(
            c_s < 0.7 * c_u,
            "expected a large cost saving: spec {} vs upfront {}",
            c_s / reps as f64,
            c_u / reps as f64
        );
    }

    #[test]
    fn timed_policies_degrade_to_failed_off_the_fast_path() {
        // overlapping layout: the timed kernels refuse (no panic)
        let mut rng = Pcg64::new(45);
        let layout = Policy::CyclicOverlapping { batches: 4 }.layout(12, &mut rng).unwrap();
        let sim = JobSimulator::new(layout, ServiceDist::exp(1.0))
            .with_replication(ReplicationPolicy::SpeculativeAt { t: 0.5 });
        let mut scratch = SimScratch::new();
        let (out, cost) = sim.sample_with_cost(&mut rng, &mut scratch);
        assert_eq!(out, JobOutcome::Failed);
        assert!(cost.is_nan());
    }

    #[test]
    fn sample_into_matches_sample_bitwise() {
        let mut rng = Pcg64::new(31);
        let layout = Policy::CyclicOverlapping { batches: 4 }.layout(12, &mut rng).unwrap();
        let sim = JobSimulator::new(layout, ServiceDist::pareto(1.0, 2.5));
        let mut a = Pcg64::new(8);
        let mut b = Pcg64::new(8);
        let mut scratch = SimScratch::new();
        for _ in 0..200 {
            let x = sim.sample(&mut a);
            let y = sim.sample_into(&mut b, &mut scratch);
            assert_eq!(x, y);
        }
    }
}
