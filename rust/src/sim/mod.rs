//! Discrete-event Monte-Carlo simulation of job compute time.
//!
//! Two granularities:
//!
//! * [`event`] — a generic discrete-event engine (time-ordered queue)
//!   used to replay worker lifecycles with failures/restarts.
//! * [`job`] — the job-level simulator: given a [`Layout`] and a task
//!   service-time model, sample the job compute time
//!   `T = max_task min_{worker ∋ task} S_worker` (first-copy-wins per
//!   batch, all batches required — eqs. (8)–(9) generalized to
//!   arbitrary overlap), with optional worker failure injection.
//! * [`montecarlo`] — the legacy replication shim; the maintained
//!   driver is [`crate::eval::MonteCarlo`] behind the
//!   [`crate::eval::Estimator`] trait.
//! * [`pool`] — the persistent scoped worker pool the maintained
//!   driver fans scenario×replication-chunk units across (no per-call
//!   thread spawn/join).
//! * [`policy`] — replication *timing* policies
//!   ([`policy::ReplicationPolicy`]): up-front (the paper's),
//!   speculative-at-`t`, and relaunch-at-`t`, each with a
//!   worker-seconds cost semantics alongside completion time.
//! * [`queue`] — the *open-system* cluster simulator: a stream of jobs
//!   (Poisson or trace-driven) queueing FIFO per worker, with
//!   batch-replicated placement, kill-on-batch-complete cancellation,
//!   and crash faults. Driven by [`crate::eval::OpenSystem`].
//!
//! [`Layout`]: crate::batching::Layout

pub mod event;
pub mod job;
pub mod montecarlo;
pub mod policy;
pub mod pool;
pub mod queue;

pub use event::{Event, EventQueue};
pub use job::{FailureModel, JobOutcome, JobSimulator, SimScratch};
pub use policy::ReplicationPolicy;
pub use queue::{Arrivals, OpenRun, OpenSim};
#[allow(deprecated)]
pub use montecarlo::{simulate_policy, McEstimate};
pub use pool::WorkerPool;
