//! A minimal discrete-event engine: a virtual clock plus a time-ordered
//! event queue. The job simulator and the coordinator's fault-injection
//! tests drive it.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at a virtual time.
#[derive(Clone, Debug, PartialEq)]
pub struct Event<P> {
    pub time: f64,
    /// Tie-break sequence number (FIFO among equal times).
    pub seq: u64,
    pub payload: P,
}

impl<P> Eq for Event<P> where P: PartialEq {}

impl<P: PartialEq> Ord for Event<P> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on (time, seq): BinaryHeap is a max-heap, so reverse.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<P: PartialEq> PartialOrd for Event<P> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Time-ordered event queue with a virtual clock.
#[derive(Debug)]
pub struct EventQueue<P: PartialEq> {
    heap: BinaryHeap<Event<P>>,
    now: f64,
    seq: u64,
}

impl<P: PartialEq> Default for EventQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

impl<P: PartialEq> EventQueue<P> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
    }

    /// Current virtual time (the time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` at absolute time `time` (must be ≥ now).
    pub fn schedule(&mut self, time: f64, payload: P) {
        debug_assert!(time >= self.now, "cannot schedule in the past");
        self.heap.push(Event { time, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule after a delay relative to now.
    pub fn schedule_in(&mut self, delay: f64, payload: P) {
        self.schedule(self.now + delay, payload);
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<Event<P>> {
        let e = self.heap.pop()?;
        self.now = e.time;
        Some(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.now(), 1.0);
        assert_eq!(q.pop().unwrap().payload, "b");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn fifo_among_ties() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!(q.pop().unwrap().payload, 1);
        assert_eq!(q.pop().unwrap().payload, 2);
        assert_eq!(q.pop().unwrap().payload, 3);
    }

    #[test]
    fn relative_scheduling() {
        let mut q = EventQueue::new();
        q.schedule(5.0, "later");
        q.pop();
        q.schedule_in(2.0, "relative");
        let e = q.pop().unwrap();
        assert_eq!(e.time, 7.0);
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<u8> = EventQueue::new();
        assert!(q.is_empty());
        q.schedule(1.0, 0);
        assert_eq!(q.len(), 1);
    }
}
