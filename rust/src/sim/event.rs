//! A minimal discrete-event engine: a virtual clock plus a time-ordered
//! event queue. The job simulator, the open-system cluster simulator
//! ([`crate::sim::queue`]), and the coordinator's fault-injection tests
//! drive it.
//!
//! Ordering is total even for pathological inputs: events compare by
//! [`f64::total_cmp`], and [`EventQueue::schedule`] rejects non-finite
//! times outright, so a NaN produced upstream surfaces as an error
//! instead of silently corrupting the heap invariant.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::error::{Error, Result};

/// An event scheduled at a virtual time.
#[derive(Clone, Debug, PartialEq)]
pub struct Event<P> {
    pub time: f64,
    /// Tie-break sequence number (FIFO among equal times).
    pub seq: u64,
    pub payload: P,
}

impl<P> Eq for Event<P> where P: PartialEq {}

impl<P: PartialEq> Ord for Event<P> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on (time, seq): BinaryHeap is a max-heap, so reverse.
        // total_cmp keeps the order total even if a NaN slips through
        // (schedule rejects them, but Ord must not depend on that).
        other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<P: PartialEq> PartialOrd for Event<P> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Time-ordered event queue with a virtual clock.
#[derive(Debug)]
pub struct EventQueue<P: PartialEq> {
    heap: BinaryHeap<Event<P>>,
    now: f64,
    seq: u64,
}

impl<P: PartialEq> Default for EventQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

impl<P: PartialEq> EventQueue<P> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
    }

    /// Current virtual time (the time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` at absolute time `time` (must be finite and ≥ now).
    ///
    /// A non-finite time (NaN or ±∞) is rejected with an error rather
    /// than pushed: a NaN key would otherwise poison every subsequent
    /// heap comparison it participates in.
    pub fn schedule(&mut self, time: f64, payload: P) -> Result<()> {
        if !time.is_finite() {
            return Err(Error::Internal(format!(
                "cannot schedule an event at non-finite time {time}"
            )));
        }
        debug_assert!(time >= self.now, "cannot schedule in the past");
        self.heap.push(Event { time, seq: self.seq, payload });
        self.seq += 1;
        Ok(())
    }

    /// Schedule after a delay relative to now (the resulting absolute
    /// time must be finite).
    pub fn schedule_in(&mut self, delay: f64, payload: P) -> Result<()> {
        self.schedule(self.now + delay, payload)
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<Event<P>> {
        let e = self.heap.pop()?;
        self.now = e.time;
        Some(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c").unwrap();
        q.schedule(1.0, "a").unwrap();
        q.schedule(2.0, "b").unwrap();
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.now(), 1.0);
        assert_eq!(q.pop().unwrap().payload, "b");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn fifo_among_ties() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1).unwrap();
        q.schedule(1.0, 2).unwrap();
        q.schedule(1.0, 3).unwrap();
        assert_eq!(q.pop().unwrap().payload, 1);
        assert_eq!(q.pop().unwrap().payload, 2);
        assert_eq!(q.pop().unwrap().payload, 3);
    }

    #[test]
    fn relative_scheduling() {
        let mut q = EventQueue::new();
        q.schedule(5.0, "later").unwrap();
        q.pop();
        q.schedule_in(2.0, "relative").unwrap();
        let e = q.pop().unwrap();
        assert_eq!(e.time, 7.0);
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<u8> = EventQueue::new();
        assert!(q.is_empty());
        q.schedule(1.0, 0).unwrap();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn rejects_non_finite_times() {
        // Regression: a NaN event time used to be pushed with
        // partial_cmp(..).unwrap_or(Equal), silently corrupting heap
        // order. schedule now refuses it and the queue stays intact.
        let mut q = EventQueue::new();
        q.schedule(1.0, "a").unwrap();
        assert!(q.schedule(f64::NAN, "nan").is_err());
        assert!(q.schedule(f64::INFINITY, "inf").is_err());
        assert!(q.schedule(f64::NEG_INFINITY, "ninf").is_err());
        assert!(q.schedule_in(f64::NAN, "rel-nan").is_err());
        // The rejected events were not enqueued and ordering still holds.
        q.schedule(0.5, "first").unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().payload, "first");
        assert_eq!(q.pop().unwrap().payload, "a");
        assert!(q.pop().is_none());
    }

    #[test]
    fn event_ord_is_total_with_nan() {
        // Even if a NaN Event is constructed directly (bypassing
        // schedule), Ord stays a total order: comparisons are
        // antisymmetric rather than collapsing to Equal.
        let nan = Event { time: f64::NAN, seq: 0, payload: () };
        let one = Event { time: 1.0, seq: 1, payload: () };
        assert_eq!(nan.cmp(&one).reverse(), one.cmp(&nan));
        assert_eq!(nan.cmp(&nan), Ordering::Equal);
    }
}
