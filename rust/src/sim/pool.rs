//! Persistent scoped worker pool — the execution substrate of the
//! Monte-Carlo hot path.
//!
//! [`crate::eval::MonteCarlo`] used to spawn and join a fresh
//! `thread::scope` per scenario, so a 200-point planner sweep paid 200
//! spawn/join rounds and serialized scenario-by-scenario. A
//! [`WorkerPool`] is created once (usually [`WorkerPool::global`]),
//! keeps its OS threads parked on a condvar between calls, and executes
//! borrowed closures through [`WorkerPool::scope`] — the same
//! structured-concurrency shape as [`std::thread::scope`], but without
//! the per-call thread churn, and shared by every scenario of a batch
//! so scenario×replication-chunk units from the whole sweep interleave
//! across all cores.
//!
//! Determinism is unaffected by the pool: callers partition work into
//! units that write disjoint, index-addressed output slots and derive
//! per-unit RNG streams from [`crate::eval::substream`]; which pool
//! thread runs a unit (or whether the caller thread runs it while
//! waiting) cannot change any result bit.
//!
//! The caller thread is not idle during [`WorkerPool::scope`]: while
//! waiting for its tasks it pops and runs queued tasks itself
//! ("help-first" join), which both uses the extra core and makes nested
//! scopes deadlock-free — a worker blocked in an inner scope drains the
//! queue instead of sleeping.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

/// Type-erased unit of work. Tasks are erased to `'static` when queued;
/// the [`WorkerPool::scope`] join discipline is what makes that sound
/// (see the `SAFETY` comment in [`PoolScope::submit`]).
type Task = Box<dyn FnOnce() + Send + 'static>;

/// State shared between the pool handle and its worker threads.
struct Shared {
    queue: Mutex<VecDeque<Task>>,
    /// Signaled when a task is pushed (workers wait here while idle).
    ready: Condvar,
    /// Set by `Drop`: workers exit once the queue is drained.
    shutdown: AtomicBool,
}

/// Bookkeeping for one [`WorkerPool::scope`] call.
struct ScopeState {
    /// Tasks submitted but not yet finished.
    pending: Mutex<usize>,
    /// Signaled when `pending` reaches zero.
    done: Condvar,
    /// First panic payload from any task, re-raised at scope exit.
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
}

impl ScopeState {
    fn new() -> ScopeState {
        ScopeState {
            pending: Mutex::new(0),
            done: Condvar::new(),
            panic: Mutex::new(None),
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut queue = shared.queue.lock().unwrap();
            loop {
                if let Some(task) = queue.pop_front() {
                    break task;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                queue = shared.ready.wait(queue).unwrap();
            }
        };
        // The task wrapper (built in `submit`) catches panics itself,
        // so a failing unit never takes a worker thread down.
        task();
    }
}

/// A pool of persistent OS worker threads executing scoped tasks.
///
/// Cheap to share (`&WorkerPool`); idle workers cost nothing but
/// parked threads. Dropping a pool shuts its workers down after the
/// queue drains; the [`WorkerPool::global`] instance lives for the
/// process.
pub struct WorkerPool {
    shared: Arc<Shared>,
    threads: usize,
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
static GLOBAL_CONFIG: Mutex<Option<usize>> = Mutex::new(None);

impl WorkerPool {
    /// Spawn a pool with `threads` workers; `0` means one per
    /// available core.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = if threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        for i in 0..threads {
            let shared = Arc::clone(&shared);
            thread::Builder::new()
                .name(format!("replica-pool-{i}"))
                .spawn(move || worker_loop(shared))
                .expect("failed to spawn worker-pool thread");
        }
        WorkerPool { shared, threads }
    }

    /// The process-wide pool. Created lazily on first use, sized by (in
    /// precedence order) [`WorkerPool::configure_global`], the
    /// `REPLICA_POOL_THREADS` environment variable, or the number of
    /// available cores.
    pub fn global() -> &'static WorkerPool {
        GLOBAL.get_or_init(|| {
            let configured = GLOBAL_CONFIG.lock().unwrap().take();
            let threads = configured
                .or_else(|| {
                    std::env::var("REPLICA_POOL_THREADS")
                        .ok()
                        .and_then(|v| v.parse().ok())
                })
                .unwrap_or(0);
            WorkerPool::new(threads)
        })
    }

    /// Set the size of the global pool before its first use (the CLI's
    /// `--pool-threads` knob; `0` = one per core). Returns `false` —
    /// and changes nothing — if the global pool already exists.
    pub fn configure_global(threads: usize) -> bool {
        let mut config = GLOBAL_CONFIG.lock().unwrap();
        if GLOBAL.get().is_some() {
            return false;
        }
        *config = Some(threads);
        true
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f`, letting it [`PoolScope::submit`] borrowed closures to
    /// the pool. Returns only after every submitted task has finished
    /// — also on panic (the first task panic, or a panic in `f`
    /// itself, is re-raised after the join). This join-before-return
    /// discipline is what lets tasks borrow from the caller's stack,
    /// exactly like [`std::thread::scope`].
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: for<'scope> FnOnce(&'scope PoolScope<'scope, 'env>) -> R,
    {
        let scope = PoolScope {
            pool: self,
            state: Arc::new(ScopeState::new()),
            _env: PhantomData,
        };
        let result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        // Join unconditionally — the `'env` erasure in `submit` is
        // sound only because no path returns before pending == 0.
        self.wait_all(&scope.state);
        match result {
            Err(payload) => resume_unwind(payload),
            Ok(value) => {
                if let Some(payload) = scope.state.panic.lock().unwrap().take() {
                    resume_unwind(payload);
                }
                value
            }
        }
    }

    /// Help-first join: run queued tasks on this thread until the
    /// scope's pending count drains, sleeping only when the queue is
    /// momentarily empty.
    fn wait_all(&self, state: &ScopeState) {
        loop {
            if *state.pending.lock().unwrap() == 0 {
                return;
            }
            let task = self.shared.queue.lock().unwrap().pop_front();
            if let Some(task) = task {
                // May belong to a different concurrent scope — that
                // scope's own join still waits for it, so running it
                // here is always safe and never wasted.
                task();
                continue;
            }
            // Queue momentarily empty: our remaining tasks are running
            // on other threads; sleep until the last one notifies.
            let mut pending = state.pending.lock().unwrap();
            while *pending > 0 {
                pending = state.done.wait(pending).unwrap();
            }
            return;
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Hold the queue mutex while raising the flag: a worker is then
        // either before its lock (sees the flag on its check) or already
        // in `wait` (receives the notify). Without the lock, a worker
        // between its shutdown check and the wait would miss the
        // notification and park forever.
        let _queue = self.shared.queue.lock().unwrap();
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.ready.notify_all();
    }
}

/// Handle for submitting tasks inside one [`WorkerPool::scope`] call.
pub struct PoolScope<'scope, 'env> {
    pool: &'scope WorkerPool,
    state: Arc<ScopeState>,
    /// Invariant over `'env`, like [`std::thread::scope`], so borrows
    /// smuggled into tasks cannot be shortened.
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'scope, 'env> PoolScope<'scope, 'env> {
    /// Queue `f` for execution on the pool. The closure may borrow
    /// anything that outlives the enclosing [`WorkerPool::scope`] call;
    /// it runs exactly once, on whichever thread (worker or waiting
    /// caller) pops it first.
    pub fn submit<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        *self.state.pending.lock().unwrap() += 1;
        let state = Arc::clone(&self.state);
        let wrapper: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(f)) {
                let mut slot = state.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            // Decrement strictly after the panic (if any) is recorded,
            // so the joining scope observes it.
            let mut pending = state.pending.lock().unwrap();
            *pending -= 1;
            if *pending == 0 {
                state.done.notify_all();
            }
        });
        // SAFETY: the queue stores `'static` tasks, but `wrapper` only
        // borrows data alive for `'env`. `WorkerPool::scope` cannot
        // return (normally or by unwind) until this task has run to
        // completion — `wait_all` blocks on the pending counter this
        // wrapper decrements as its final action — so every `'env`
        // borrow strictly outlives the task. This is the same lifetime
        // argument `std::thread::scope` makes for its spawned threads.
        let task: Task = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Task>(wrapper)
        };
        self.pool.shared.queue.lock().unwrap().push_back(task);
        self.pool.shared.ready.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_submitted_task() {
        let pool = WorkerPool::new(4);
        let counter = AtomicUsize::new(0);
        pool.scope(|scope| {
            for _ in 0..100 {
                scope.submit(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn tasks_can_mutate_disjoint_borrowed_slices() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u64; 1000];
        pool.scope(|scope| {
            for (i, chunk) in data.chunks_mut(100).enumerate() {
                scope.submit(move || {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        *slot = (i * 100 + j) as u64;
                    }
                });
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
    }

    #[test]
    fn scope_returns_the_closure_value() {
        let pool = WorkerPool::new(2);
        let sum = pool.scope(|scope| {
            let partials = Arc::new(Mutex::new(0u64));
            for k in 0..10u64 {
                let partials = Arc::clone(&partials);
                scope.submit(move || {
                    *partials.lock().unwrap() += k;
                });
            }
            partials
        });
        // scope() has joined: all adds are visible
        assert_eq!(*sum.lock().unwrap(), 45);
    }

    #[test]
    fn task_panic_propagates_after_join() {
        let pool = WorkerPool::new(2);
        let finished = Arc::new(AtomicUsize::new(0));
        let fin = Arc::clone(&finished);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|scope| {
                for i in 0..8 {
                    let fin = Arc::clone(&fin);
                    scope.submit(move || {
                        if i == 3 {
                            panic!("unit failure");
                        }
                        fin.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        }));
        assert!(result.is_err(), "panic must cross the scope boundary");
        // the join still completed the other 7 tasks
        assert_eq!(finished.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn sequential_scopes_reuse_the_same_pool() {
        let pool = WorkerPool::new(2);
        for round in 0..20 {
            let counter = AtomicUsize::new(0);
            pool.scope(|scope| {
                for _ in 0..10 {
                    scope.submit(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(counter.load(Ordering::Relaxed), 10, "round {round}");
        }
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        // single worker + caller: the inner scope's join must help
        // drain the queue instead of sleeping
        let pool = WorkerPool::new(1);
        let counter = Arc::new(AtomicUsize::new(0));
        let outer = Arc::clone(&counter);
        pool.scope(|scope| {
            let inner_pool = &pool;
            let outer = Arc::clone(&outer);
            scope.submit(move || {
                inner_pool.scope(|inner| {
                    for _ in 0..5 {
                        let c = Arc::clone(&outer);
                        inner.submit(move || {
                            c.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
                outer.fetch_add(100, Ordering::Relaxed);
            });
        });
        assert_eq!(counter.load(Ordering::Relaxed), 105);
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = WorkerPool::global() as *const WorkerPool;
        let b = WorkerPool::global() as *const WorkerPool;
        assert_eq!(a, b);
        assert!(WorkerPool::global().threads() >= 1);
        // once the global pool exists, reconfiguration is refused
        assert!(!WorkerPool::configure_global(2));
    }
}
