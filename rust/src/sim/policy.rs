//! Replication *timing* policies — when a batch's replicas launch.
//!
//! The paper replicates every batch up front: all `r = N/B` workers of
//! a batch start at time 0 and the first finisher wins. Real clusters
//! rarely pay for that: speculative execution launches backups only for
//! batches still unfinished at a straggler timeout `t` (Wang, Joshi &
//! Wornell, arXiv 1503.03128), and relaunch-style mitigation cancels a
//! straggling attempt and resubmits it instead of adding a replica.
//! [`ReplicationPolicy`] names these three members of the family; the
//! job kernel ([`crate::sim::job`]) gives each a completion-time *and*
//! a **cost** semantics, where cost is total worker-seconds consumed
//! (replicas are killed the moment their batch completes).
//!
//! Semantics of `t` (per batch, service times `s_1..s_r` in worker
//! order, first listed worker = the primary):
//!
//! * [`Upfront`](ReplicationPolicy::Upfront) — all `r` replicas start
//!   at 0: `D = min_i s_i`, `cost = r·D`. Today's behavior, and the
//!   `t = 0` limit of speculation.
//! * [`SpeculativeAt { t }`](ReplicationPolicy::SpeculativeAt) — the
//!   primary starts alone; if it has not finished by `t`, the batch's
//!   remaining `r − 1` workers launch at `t`:
//!   `D = min(s_1, t + min_{i≥2} s_i)`,
//!   `cost = D + Σ_{i≥2} min(s_i, D − t)` (zero extra cost when the
//!   primary beats the timeout).
//! * [`RelaunchAt { t }`](ReplicationPolicy::RelaunchAt) — one attempt
//!   at a time: attempt `i` starts at `(i−1)·t` and is cancelled at its
//!   own `t`-deadline unless it is the last (`i = r`), which runs to
//!   completion. `D = (i*−1)·t + s_{i*}` for the first attempt that
//!   beats its deadline (or the last), and `cost = D` — exactly one
//!   worker is ever busy.
//!
//! A job's completion time is still `T = max_b D_b` and its cost the
//! sum of batch costs. Only the up-front policy has closed forms; the
//! timed policies are evaluated by Monte-Carlo on the disjoint-layout
//! fast path (no failure injection, no overlapping/random layouts —
//! the eval layer rejects those combinations up front).

use crate::util::error::{Error, Result};

/// When a batch's replicas launch (see the module docs for the exact
/// completion-time and worker-seconds semantics of each member).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum ReplicationPolicy {
    /// All replicas start at time 0 — the paper's policy.
    #[default]
    Upfront,
    /// Backups launch at time `t` for batches the primary has not
    /// finished by then (speculative execution).
    SpeculativeAt {
        /// Straggler timeout (same unit as service times).
        t: f64,
    },
    /// Cancel-and-resubmit: each attempt gets `t` seconds before it is
    /// replaced; the final attempt runs to completion.
    RelaunchAt {
        /// Per-attempt deadline (same unit as service times).
        t: f64,
    },
}

impl ReplicationPolicy {
    /// Stable short name: `upfront`, `speculative`, or `relaunch`.
    /// Part of the sweep-store record format — do not repurpose.
    pub fn name(&self) -> &'static str {
        match self {
            ReplicationPolicy::Upfront => "upfront",
            ReplicationPolicy::SpeculativeAt { .. } => "speculative",
            ReplicationPolicy::RelaunchAt { .. } => "relaunch",
        }
    }

    /// The timeout parameter, when the policy has one.
    pub fn t(&self) -> Option<f64> {
        match self {
            ReplicationPolicy::Upfront => None,
            ReplicationPolicy::SpeculativeAt { t } | ReplicationPolicy::RelaunchAt { t } => {
                Some(*t)
            }
        }
    }

    /// `true` for the paper's up-front policy (the compatibility
    /// default everywhere: old stores, specs without a `policies` axis,
    /// CLI without `--policy`).
    pub fn is_upfront(&self) -> bool {
        matches!(self, ReplicationPolicy::Upfront)
    }

    /// Human-readable label, e.g. `speculative(t=0.5)`.
    pub fn label(&self) -> String {
        match self {
            ReplicationPolicy::Upfront => "upfront".to_string(),
            ReplicationPolicy::SpeculativeAt { t } => format!("speculative(t={t})"),
            ReplicationPolicy::RelaunchAt { t } => format!("relaunch(t={t})"),
        }
    }

    /// Build a policy from its stable name and optional timeout —
    /// the one parser the CLI, spec files, and store records share.
    /// Timed policies require a finite `t ≥ 0`; `upfront` rejects one.
    pub fn parse(name: &str, t: Option<f64>) -> Result<ReplicationPolicy> {
        match (name, t) {
            ("upfront", None) => Ok(ReplicationPolicy::Upfront),
            ("upfront", Some(_)) => {
                Err(Error::Config("policy 'upfront' takes no timeout t".into()))
            }
            ("speculative" | "relaunch", Some(t)) if !(t.is_finite() && t >= 0.0) => Err(
                Error::Config(format!("policy '{name}' needs a finite t >= 0, got {t}")),
            ),
            ("speculative", Some(t)) => Ok(ReplicationPolicy::SpeculativeAt { t }),
            ("relaunch", Some(t)) => Ok(ReplicationPolicy::RelaunchAt { t }),
            ("speculative" | "relaunch", None) => Err(Error::Config(format!(
                "policy '{name}' needs a timeout (--spec-t T or {{\"{name}\": T}})"
            ))),
            (other, _) => Err(Error::Config(format!(
                "unknown replication policy '{other}' \
                 (expected upfront | speculative | relaunch)"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_t_roundtrip_through_parse() {
        for policy in [
            ReplicationPolicy::Upfront,
            ReplicationPolicy::SpeculativeAt { t: 0.5 },
            ReplicationPolicy::RelaunchAt { t: 2.0 },
        ] {
            let back = ReplicationPolicy::parse(policy.name(), policy.t()).unwrap();
            assert_eq!(back, policy);
        }
    }

    #[test]
    fn default_is_upfront() {
        assert!(ReplicationPolicy::default().is_upfront());
        assert_eq!(ReplicationPolicy::default().t(), None);
    }

    #[test]
    fn labels_are_distinct_and_carry_t() {
        assert_eq!(ReplicationPolicy::Upfront.label(), "upfront");
        assert_eq!(
            ReplicationPolicy::SpeculativeAt { t: 0.25 }.label(),
            "speculative(t=0.25)"
        );
        assert_eq!(ReplicationPolicy::RelaunchAt { t: 1.0 }.label(), "relaunch(t=1)");
    }

    #[test]
    fn parse_rejects_bad_inputs() {
        assert!(ReplicationPolicy::parse("upfront", Some(1.0)).is_err());
        assert!(ReplicationPolicy::parse("speculative", None).is_err());
        assert!(ReplicationPolicy::parse("relaunch", Some(-1.0)).is_err());
        assert!(ReplicationPolicy::parse("speculative", Some(f64::NAN)).is_err());
        assert!(ReplicationPolicy::parse("speculative", Some(f64::INFINITY)).is_err());
        assert!(ReplicationPolicy::parse("eager", None).is_err());
        // t = 0 is legal (speculation at 0 ≡ upfront, a tested identity)
        assert_eq!(
            ReplicationPolicy::parse("speculative", Some(0.0)).unwrap(),
            ReplicationPolicy::SpeculativeAt { t: 0.0 }
        );
    }
}
