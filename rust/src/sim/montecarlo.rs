//! Monte-Carlo replication driver — legacy entry point.
//!
//! The actual driver now lives in [`crate::eval::MonteCarlo`];
//! [`simulate_policy`] survives as a thin shim for old call sites and
//! will be removed once nothing links against it.

use crate::batching::Policy;
use crate::dist::ServiceDist;
use crate::eval::{Estimate, Estimator, MonteCarlo, Scenario};
use crate::util::error::Result;

/// Monte-Carlo estimate of job compute-time statistics.
///
/// When every replication fails coverage (`completed == 0`), `mean`,
/// `ci95`, `cov` and the percentiles are all `NaN` and `failure_rate`
/// is exactly 1.0 — see [`McEstimate::all_failed`].
#[derive(Clone, Debug)]
pub struct McEstimate {
    pub replications: usize,
    pub completed: usize,
    /// Mean completion time over completed jobs.
    pub mean: f64,
    /// 95% CI half-width of the mean.
    pub ci95: f64,
    /// Coefficient of variation of completion time.
    pub cov: f64,
    /// Fraction of replications where coverage failed.
    pub failure_rate: f64,
    /// Percentiles p50/p95/p99 of completion time.
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl McEstimate {
    /// True when zero replications completed: all statistics are `NaN`
    /// and only `failure_rate` is meaningful.
    pub fn all_failed(&self) -> bool {
        self.replications > 0 && self.completed == 0
    }
}

impl From<Estimate> for McEstimate {
    fn from(e: Estimate) -> McEstimate {
        McEstimate {
            replications: e.replications,
            completed: e.completed,
            mean: e.mean,
            ci95: e.ci95,
            cov: e.cov,
            failure_rate: e.failure_rate,
            p50: e.p50,
            p95: e.p95,
            p99: e.p99,
        }
    }
}

/// Estimate compute-time statistics of a `(policy, τ)` pair on `n`
/// workers with `reps` independent replications (single-threaded).
#[deprecated(
    note = "use eval::MonteCarlo (or eval::Auto) through the eval::Estimator trait"
)]
pub fn simulate_policy(
    n: usize,
    policy: &Policy,
    tau: &ServiceDist,
    reps: usize,
    seed: u64,
) -> Result<McEstimate> {
    MonteCarlo::serial(reps, seed)
        .evaluate(&Scenario::new(n, policy.clone(), tau.clone()))
        .map(McEstimate::from)
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::analysis::closed_form;

    #[test]
    fn shim_matches_closed_form_with_ci() {
        let n = 20;
        let tau = ServiceDist::shifted_exp(0.05, 1.0);
        for b in [1usize, 4, 20] {
            let est = simulate_policy(
                n,
                &Policy::BalancedNonOverlapping { batches: b },
                &tau,
                30_000,
                42,
            )
            .unwrap();
            let want = closed_form::sexp_mean(n, b, 0.05, 1.0);
            assert!(
                (est.mean - want).abs() < 4.0 * est.ci95.max(1e-3),
                "B={b}: {} vs {want} (ci {})",
                est.mean,
                est.ci95
            );
            assert_eq!(est.failure_rate, 0.0);
            assert!(est.p50 <= est.p95 && est.p95 <= est.p99);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let tau = ServiceDist::exp(1.0);
        let p = Policy::BalancedNonOverlapping { batches: 2 };
        let a = simulate_policy(10, &p, &tau, 1000, 7).unwrap();
        let b = simulate_policy(10, &p, &tau, 1000, 7).unwrap();
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.p99, b.p99);
        let c = simulate_policy(10, &p, &tau, 1000, 8).unwrap();
        assert_ne!(a.mean, c.mean);
    }

    #[test]
    fn shim_agrees_with_eval_backend_exactly() {
        let tau = ServiceDist::exp(1.0);
        let p = Policy::BalancedNonOverlapping { batches: 5 };
        let shim = simulate_policy(10, &p, &tau, 2_000, 3).unwrap();
        let direct = MonteCarlo::serial(2_000, 3)
            .evaluate(&Scenario::new(10, p, tau))
            .unwrap();
        assert_eq!(shim.mean.to_bits(), direct.mean.to_bits());
        assert_eq!(shim.p95.to_bits(), direct.p95.to_bits());
    }

    #[test]
    fn random_policy_reports_failures() {
        let est = simulate_policy(
            20,
            &Policy::RandomNonOverlapping { batches: 10 },
            &ServiceDist::exp(1.0),
            5_000,
            1,
        )
        .unwrap();
        assert!(est.failure_rate > 0.3, "rate {}", est.failure_rate);
        assert!(est.completed > 0);
        assert!(!est.all_failed());
    }

    #[test]
    fn infeasible_policy_is_error() {
        assert!(simulate_policy(
            10,
            &Policy::BalancedNonOverlapping { batches: 3 },
            &ServiceDist::exp(1.0),
            10,
            0,
        )
        .is_err());
    }
}
