//! Monte-Carlo replication driver.

use crate::batching::Policy;
use crate::dist::ServiceDist;
use crate::metrics::Summary;
use crate::sim::job::{JobOutcome, JobSimulator};
use crate::util::error::Result;
use crate::util::rng::Pcg64;

/// Monte-Carlo estimate of job compute-time statistics.
#[derive(Clone, Debug)]
pub struct McEstimate {
    pub replications: usize,
    pub completed: usize,
    /// Mean completion time over completed jobs.
    pub mean: f64,
    /// 95% CI half-width of the mean.
    pub ci95: f64,
    /// Coefficient of variation of completion time.
    pub cov: f64,
    /// Fraction of replications where coverage failed.
    pub failure_rate: f64,
    /// Percentiles p50/p95/p99 of completion time.
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Estimate compute-time statistics of a `(policy, τ)` pair on `n`
/// workers with `reps` independent replications.
///
/// Layout-randomizing policies (random assignment) get a fresh layout
/// per replication; deterministic policies reuse one layout.
pub fn simulate_policy(
    n: usize,
    policy: &Policy,
    tau: &ServiceDist,
    reps: usize,
    seed: u64,
) -> Result<McEstimate> {
    let mut rng = Pcg64::new(seed);
    let mut summary = Summary::new();
    let mut failed = 0usize;

    let randomized = matches!(policy, Policy::RandomNonOverlapping { .. });
    let fixed_sim = if randomized {
        None
    } else {
        Some(JobSimulator::new(policy.layout(n, &mut rng)?, tau.clone()))
    };

    for _ in 0..reps {
        let outcome = match &fixed_sim {
            Some(sim) => sim.sample(&mut rng),
            None => {
                let layout = policy.layout(n, &mut rng)?;
                JobSimulator::new(layout, tau.clone()).sample(&mut rng)
            }
        };
        match outcome {
            JobOutcome::Done(t) => summary.record(t),
            JobOutcome::Failed => failed += 1,
        }
    }

    let completed = reps - failed;
    Ok(McEstimate {
        replications: reps,
        completed,
        mean: summary.mean(),
        ci95: summary.ci95(),
        cov: summary.cov(),
        failure_rate: failed as f64 / reps as f64,
        p50: if completed > 0 { summary.quantile(0.50) } else { f64::NAN },
        p95: if completed > 0 { summary.quantile(0.95) } else { f64::NAN },
        p99: if completed > 0 { summary.quantile(0.99) } else { f64::NAN },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::closed_form;

    #[test]
    fn estimate_matches_closed_form_with_ci() {
        let n = 20;
        let tau = ServiceDist::shifted_exp(0.05, 1.0);
        for b in [1usize, 4, 20] {
            let est = simulate_policy(
                n,
                &Policy::BalancedNonOverlapping { batches: b },
                &tau,
                30_000,
                42,
            )
            .unwrap();
            let want = closed_form::sexp_mean(n, b, 0.05, 1.0);
            assert!(
                (est.mean - want).abs() < 4.0 * est.ci95.max(1e-3),
                "B={b}: {} vs {want} (ci {})",
                est.mean,
                est.ci95
            );
            assert_eq!(est.failure_rate, 0.0);
            assert!(est.p50 <= est.p95 && est.p95 <= est.p99);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let tau = ServiceDist::exp(1.0);
        let p = Policy::BalancedNonOverlapping { batches: 2 };
        let a = simulate_policy(10, &p, &tau, 1000, 7).unwrap();
        let b = simulate_policy(10, &p, &tau, 1000, 7).unwrap();
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.p99, b.p99);
        let c = simulate_policy(10, &p, &tau, 1000, 8).unwrap();
        assert_ne!(a.mean, c.mean);
    }

    #[test]
    fn random_policy_reports_failures() {
        let est = simulate_policy(
            20,
            &Policy::RandomNonOverlapping { batches: 10 },
            &ServiceDist::exp(1.0),
            5_000,
            1,
        )
        .unwrap();
        assert!(est.failure_rate > 0.3, "rate {}", est.failure_rate);
        assert!(est.completed > 0);
    }

    #[test]
    fn infeasible_policy_is_error() {
        assert!(simulate_policy(
            10,
            &Policy::BalancedNonOverlapping { batches: 3 },
            &ServiceDist::exp(1.0),
            10,
            0,
        )
        .is_err());
    }
}
