//! Open-system cluster simulator: a *stream* of jobs arriving at a
//! finite cluster, queueing per worker, and competing for capacity.
//!
//! Everything else in `sim/` is closed-system — one job, workers always
//! idle at t=0, exactly the regime of the paper's Theorems 1–3. This
//! module models the serving regime of Aktaş & Soljanin
//! (arXiv 1906.05345): jobs arrive over time (Poisson or trace-driven),
//! every batch is replicated onto its `r = N/B` workers per the
//! [`ReplicationPolicy`], copies wait in per-worker FIFO queues, and
//! redundancy now *adds load* — the work burned by extra copies
//! lengthens everyone else's queues, so the optimal batch count B
//! shifts with the offered load ρ.
//!
//! ## Model
//!
//! * `N` workers, jobs of `N` tasks split into `B` balanced batches of
//!   `N/B` tasks (the balanced non-overlapping policy; batch `b` owns
//!   workers `b·r .. (b+1)·r`, `r = N/B`).
//! * A copy of batch `b` on any of its workers serves the whole batch:
//!   service time `(N/B)·τ` with `τ` drawn fresh per copy (the same
//!   size-dependent model as the closed-system simulator).
//! * **Kill-on-batch-complete:** the instant one copy of a batch
//!   finishes, its sibling copies are cancelled — running copies are
//!   killed (freeing their workers immediately), queued copies are
//!   dropped lazily when they reach the head of a queue.
//! * **Replication timing** ([`ReplicationPolicy`]): up-front enqueues
//!   all `r` copies at arrival; `speculative(t)` enqueues the primary at
//!   arrival and the `r−1` backups at `arrival+t` if the batch is still
//!   incomplete; `relaunch(t)` cancels attempt `k` and enqueues attempt
//!   `k+1` on the batch's next worker at `arrival+(k+1)·t` (the last
//!   attempt runs to completion). Deadlines are measured from *job
//!   arrival* — the natural open-system generalization of the
//!   closed-system policies, where arrival and service start coincide.
//! * **Crash faults** ([`FailureModel`]): a copy crashes with
//!   probability `p`, consuming its full service time but reporting
//!   nothing. Under `Crash` a batch whose `r` copies all crash can never
//!   finish — the job is counted failed and its surviving copies are
//!   cancelled. Under `CrashRestart` the copy re-enqueues on the same
//!   worker after `delay`. As in the closed system, failure injection
//!   combines only with the up-front policy.
//!
//! The simulator reports per-job sojourn times (arrival → last batch
//! complete), job failures, total busy worker-seconds, and the horizon,
//! from which callers derive utilization `busy/(N·horizon)`.
//!
//! ## Determinism
//!
//! One replication = one serial event loop over the total-ordered
//! [`EventQueue`] (time, then FIFO sequence), drawing from a single
//! caller-provided [`Pcg64`] in event order. The kernel never seeds an
//! RNG itself; [`crate::eval::OpenSystem`] derives one substream per
//! replication, which is what keeps estimates bit-identical across pool
//! widths.

use std::collections::VecDeque;

use crate::dist::Sampler;
use crate::sim::event::EventQueue;
use crate::sim::job::FailureModel;
use crate::sim::policy::ReplicationPolicy;
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;

/// Job arrival process for the open system.
#[derive(Clone, Copy, Debug)]
pub enum Arrivals<'a> {
    /// Poisson arrivals: iid exponential interarrival times at `rate`
    /// jobs per unit time.
    Poisson { rate: f64 },
    /// Trace-driven arrivals: explicit non-decreasing arrival times,
    /// one per job (must cover every simulated job, warmup included).
    Trace(&'a [f64]),
}

/// One open-system replication: the job stream to simulate.
///
/// `warmup` jobs are simulated but excluded from the statistics (the
/// queue starts empty, so early jobs see an unrepresentatively idle
/// cluster); the following `jobs` jobs are measured.
#[derive(Clone, Copy, Debug)]
pub struct OpenSim<'a> {
    /// Worker budget N (= task count, the paper's model).
    pub workers: usize,
    /// Batch count B (must divide N); `r = N/B` copies per batch.
    pub batches: usize,
    /// Compiled service-time sampler for τ.
    pub sampler: &'a Sampler,
    /// When each batch's replicas launch.
    pub replication: ReplicationPolicy,
    /// Per-copy crash model.
    pub failures: FailureModel,
    /// Job arrival process.
    pub arrivals: Arrivals<'a>,
    /// Leading jobs excluded from statistics.
    pub warmup: usize,
    /// Measured jobs (after warmup).
    pub jobs: usize,
}

/// Result of one open-system replication.
#[derive(Clone, Debug)]
pub struct OpenRun {
    /// Sojourn times of measured jobs that completed, in arrival order
    /// (independent of completion order, for deterministic reduction).
    pub sojourns: Vec<f64>,
    /// Measured jobs that failed (a batch lost all its copies to
    /// crashes).
    pub failed: usize,
    /// Total busy worker-seconds over the whole run (warmup included),
    /// counting killed and crashed copies up to the instant they stop.
    pub busy: f64,
    /// Virtual time at which the last job resolved.
    pub horizon: f64,
}

/// A queued copy: batch `batch` of job `job`, launch generation `gen`
/// (the relaunch policy bumps the live generation to cancel a queued
/// attempt without scanning the queue).
#[derive(Clone, Copy, Debug)]
struct QueuedCopy {
    job: u32,
    batch: u32,
    gen: u32,
}

#[derive(Clone, Copy, Debug)]
struct RunningCopy {
    job: u32,
    batch: u32,
    start: f64,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Ev {
    /// Next job arrives (the job index is the arrival counter).
    Arrive,
    /// A running copy on `worker` finishes service; stale once the
    /// worker's epoch moves past `epoch` (the copy was killed).
    Finish { worker: u32, epoch: u64, crashed: bool },
    /// Speculative backups for `job` launch if batches are incomplete.
    Backup { job: u32 },
    /// Relaunch deadline: cancel attempt `attempt − 1` of each
    /// incomplete batch of `job`, launch attempt `attempt`.
    Relaunch { job: u32, attempt: u32 },
    /// Crash-restart: re-enqueue batch `batch` of `job` on `worker`.
    Requeue { worker: u32, job: u32, batch: u32 },
}

struct Sim<'a, 'r> {
    spec: &'a OpenSim<'a>,
    rng: &'r mut Pcg64,
    q: EventQueue<Ev>,
    /// N/B as f64: service = copies · τ (size-dependent batches).
    batch_size: f64,
    /// r = N/B copies (= workers) per batch.
    copies: usize,
    total_jobs: usize,
    next_arrival: usize,
    resolved: usize,

    // Per-worker state.
    queues: Vec<VecDeque<QueuedCopy>>,
    running: Vec<Option<RunningCopy>>,
    /// Bumped whenever a worker's running copy changes; invalidates
    /// in-flight Finish events of killed copies.
    epochs: Vec<u64>,

    // Per-job state.
    arrival_time: Vec<f64>,
    batches_left: Vec<u32>,
    job_dead: Vec<bool>,

    // Per-(job, batch) state, flat-indexed job·B + batch.
    batch_done: Vec<bool>,
    batch_gen: Vec<u32>,
    crashed_copies: Vec<u32>,

    // Outputs.
    sojourn: Vec<f64>,
    job_failed: Vec<bool>,
    busy: f64,
}

impl OpenSim<'_> {
    /// Validate the configuration and run one replication, drawing all
    /// randomness (arrivals, services, crashes) from `rng` in event
    /// order.
    pub fn run(&self, rng: &mut Pcg64) -> Result<OpenRun> {
        self.check()?;
        let b = self.batches;
        let total = self.warmup + self.jobs;
        let mut sim = Sim {
            spec: self,
            rng,
            q: EventQueue::new(),
            batch_size: (self.workers / b) as f64,
            copies: self.workers / b,
            total_jobs: total,
            next_arrival: 0,
            resolved: 0,
            queues: vec![VecDeque::new(); self.workers],
            running: vec![None; self.workers],
            epochs: vec![0; self.workers],
            arrival_time: vec![0.0; total],
            batches_left: vec![b as u32; total],
            job_dead: vec![false; total],
            batch_done: vec![false; total * b],
            batch_gen: vec![0; total * b],
            crashed_copies: vec![0; total * b],
            sojourn: vec![f64::NAN; total],
            job_failed: vec![false; total],
            busy: 0.0,
        };
        sim.run()
    }

    /// Validate the configuration without running it. `run` calls this
    /// itself; drivers fanning replications across a pool call it once
    /// up front so configuration errors surface before any unit queues.
    pub fn check(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::Config("open system needs at least one worker".into()));
        }
        if self.batches == 0 || self.workers % self.batches != 0 {
            return Err(Error::Config(format!(
                "batch count {} must divide the worker count {}",
                self.batches, self.workers
            )));
        }
        if self.jobs == 0 {
            return Err(Error::Config("open system needs at least one measured job".into()));
        }
        if !self.replication.is_upfront() && self.failures != FailureModel::None {
            return Err(Error::Config(format!(
                "the {} policy does not support failure injection \
                 (parity with the closed-system simulator)",
                self.replication.name()
            )));
        }
        match self.arrivals {
            Arrivals::Poisson { rate } => {
                if !rate.is_finite() || rate <= 0.0 {
                    return Err(Error::Config(format!(
                        "Poisson arrival rate must be finite and positive, got {rate}"
                    )));
                }
            }
            Arrivals::Trace(times) => {
                let needed = self.warmup + self.jobs;
                if times.len() < needed {
                    return Err(Error::Config(format!(
                        "arrival trace has {} times but the run needs {needed}",
                        times.len()
                    )));
                }
                let mut prev = 0.0_f64;
                for &t in &times[..needed] {
                    if !t.is_finite() || t < prev {
                        return Err(Error::Config(format!(
                            "arrival trace must be finite and non-decreasing \
                             (offending time {t})"
                        )));
                    }
                    prev = t;
                }
            }
        }
        Ok(())
    }
}

impl Sim<'_, '_> {
    fn run(mut self) -> Result<OpenRun> {
        let first = match self.spec.arrivals {
            Arrivals::Poisson { .. } => 0.0,
            Arrivals::Trace(times) => times[0],
        };
        self.q.schedule(first, Ev::Arrive)?;
        while self.resolved < self.total_jobs {
            let ev = match self.q.pop() {
                Some(ev) => ev,
                // Unreachable for a valid configuration: every job either
                // completes or fails, and each resolution is preceded by
                // a scheduled event. Surface it rather than spin.
                None => {
                    return Err(Error::Internal(
                        "open-system event queue drained before all jobs resolved".into(),
                    ))
                }
            };
            match ev.payload {
                Ev::Arrive => self.on_arrive()?,
                Ev::Finish { worker, epoch, crashed } => {
                    self.on_finish(worker as usize, epoch, crashed)?
                }
                Ev::Backup { job } => self.on_backup(job as usize)?,
                Ev::Relaunch { job, attempt } => {
                    self.on_relaunch(job as usize, attempt as usize)?
                }
                Ev::Requeue { worker, job, batch } => {
                    self.on_requeue(worker as usize, job, batch)?
                }
            }
        }
        let horizon = self.q.now();
        let mut sojourns = Vec::with_capacity(self.spec.jobs);
        let mut failed = 0usize;
        for j in self.spec.warmup..self.total_jobs {
            if self.job_failed[j] {
                failed += 1;
            } else {
                sojourns.push(self.sojourn[j]);
            }
        }
        Ok(OpenRun { sojourns, failed, busy: self.busy, horizon })
    }

    fn on_arrive(&mut self) -> Result<()> {
        let job = self.next_arrival;
        self.next_arrival += 1;
        let now = self.q.now();
        self.arrival_time[job] = now;

        // Launch per the replication timing policy.
        let r = self.copies;
        match self.spec.replication {
            ReplicationPolicy::Upfront => {
                for b in 0..self.spec.batches {
                    for c in 0..r {
                        self.enqueue(b * r + c, job as u32, b as u32, 0)?;
                    }
                }
            }
            ReplicationPolicy::SpeculativeAt { t } => {
                for b in 0..self.spec.batches {
                    self.enqueue(b * r, job as u32, b as u32, 0)?;
                }
                if r > 1 {
                    self.q.schedule_in(t, Ev::Backup { job: job as u32 })?;
                }
            }
            ReplicationPolicy::RelaunchAt { t } => {
                for b in 0..self.spec.batches {
                    self.enqueue(b * r, job as u32, b as u32, 0)?;
                }
                if r > 1 {
                    self.q.schedule_in(t, Ev::Relaunch { job: job as u32, attempt: 1 })?;
                }
            }
        }

        // Schedule the next arrival.
        if self.next_arrival < self.total_jobs {
            match self.spec.arrivals {
                Arrivals::Poisson { rate } => {
                    let dt = -self.rng.uniform_pos().ln() / rate;
                    self.q.schedule_in(dt, Ev::Arrive)?;
                }
                Arrivals::Trace(times) => {
                    self.q.schedule(times[self.next_arrival], Ev::Arrive)?;
                }
            }
        }
        Ok(())
    }

    /// Push a copy onto worker `w`'s FIFO queue, starting it
    /// immediately if the worker is idle.
    fn enqueue(&mut self, w: usize, job: u32, batch: u32, gen: u32) -> Result<()> {
        self.queues[w].push_back(QueuedCopy { job, batch, gen });
        if self.running[w].is_none() {
            self.start_next(w)?;
        }
        Ok(())
    }

    /// Pop the next live copy (skipping cancelled ones) and start
    /// serving it: draw the service time and the crash outcome, bump the
    /// worker epoch, and schedule the Finish event.
    fn start_next(&mut self, w: usize) -> Result<()> {
        while let Some(copy) = self.queues[w].pop_front() {
            let jb = copy.job as usize * self.spec.batches + copy.batch as usize;
            let cancelled = self.batch_done[jb]
                || self.job_dead[copy.job as usize]
                || copy.gen != self.batch_gen[jb];
            if cancelled {
                continue;
            }
            let service = self.batch_size * self.spec.sampler.sample_one(self.rng);
            let crashed = match self.spec.failures {
                FailureModel::None => false,
                FailureModel::Crash { p } | FailureModel::CrashRestart { p, .. } => {
                    self.rng.uniform() < p
                }
            };
            let now = self.q.now();
            self.epochs[w] += 1;
            self.running[w] =
                Some(RunningCopy { job: copy.job, batch: copy.batch, start: now });
            self.q.schedule(
                now + service,
                Ev::Finish { worker: w as u32, epoch: self.epochs[w], crashed },
            )?;
            return Ok(());
        }
        Ok(())
    }

    /// Stop the copy running on `w` (kill or normal completion),
    /// crediting its busy time, and start the worker's next copy.
    fn release(&mut self, w: usize) -> Result<()> {
        if let Some(rc) = self.running[w].take() {
            self.busy += self.q.now() - rc.start;
            self.epochs[w] += 1; // invalidate the in-flight Finish
        }
        self.start_next(w)
    }

    fn on_finish(&mut self, w: usize, epoch: u64, crashed: bool) -> Result<()> {
        if self.epochs[w] != epoch {
            return Ok(()); // stale: this copy was killed earlier
        }
        let rc = match self.running[w].take() {
            Some(rc) => rc,
            None => return Ok(()), // defensive: epoch matched an idle worker
        };
        self.busy += self.q.now() - rc.start;
        if crashed {
            self.start_next(w)?;
            return self.on_crash(w, rc);
        }
        let jb = rc.job as usize * self.spec.batches + rc.batch as usize;
        if !self.batch_done[jb] && !self.job_dead[rc.job as usize] {
            self.batch_done[jb] = true;
            self.kill_batch_copies(rc.job, rc.batch)?;
            self.batches_left[rc.job as usize] -= 1;
            if self.batches_left[rc.job as usize] == 0 {
                self.resolve(rc.job as usize, false);
            }
        }
        self.start_next(w)
    }

    /// Kill-on-batch-complete: running sibling copies of a finished
    /// batch are stopped immediately (queued siblings are dropped lazily
    /// by `start_next`).
    fn kill_batch_copies(&mut self, job: u32, batch: u32) -> Result<()> {
        let r = self.copies;
        let base = batch as usize * r;
        for w in base..base + r {
            if let Some(rc) = self.running[w] {
                if rc.job == job && rc.batch == batch {
                    self.release(w)?;
                }
            }
        }
        Ok(())
    }

    fn on_crash(&mut self, w: usize, rc: RunningCopy) -> Result<()> {
        let job = rc.job as usize;
        let jb = job * self.spec.batches + rc.batch as usize;
        if self.batch_done[jb] || self.job_dead[job] {
            return Ok(());
        }
        match self.spec.failures {
            FailureModel::CrashRestart { delay, .. } => {
                // The copy retries on the worker it ran on after the
                // restart delay; the batch stays recoverable.
                self.q.schedule_in(
                    delay,
                    Ev::Requeue { worker: w as u32, job: rc.job, batch: rc.batch },
                )
            }
            FailureModel::Crash { .. } => {
                self.crashed_copies[jb] += 1;
                if self.crashed_copies[jb] >= self.copies as u32 {
                    // Every copy of this batch crashed: the job can
                    // never complete. Cancel its surviving work.
                    self.job_dead[job] = true;
                    for w in 0..self.spec.workers {
                        if let Some(run) = self.running[w] {
                            if run.job == rc.job {
                                self.release(w)?;
                            }
                        }
                    }
                    self.resolve(job, true);
                }
                Ok(())
            }
            FailureModel::None => Ok(()),
        }
    }

    fn on_backup(&mut self, job: usize) -> Result<()> {
        if self.job_dead[job] {
            return Ok(());
        }
        let r = self.copies;
        for b in 0..self.spec.batches {
            let jb = job * self.spec.batches + b;
            if self.batch_done[jb] {
                continue;
            }
            for c in 1..r {
                self.enqueue(b * r + c, job as u32, b as u32, 0)?;
            }
        }
        Ok(())
    }

    fn on_relaunch(&mut self, job: usize, attempt: usize) -> Result<()> {
        if self.job_dead[job] {
            return Ok(());
        }
        let t = match self.spec.replication {
            ReplicationPolicy::RelaunchAt { t } => t,
            // Relaunch events are only ever scheduled under this policy.
            _ => return Ok(()),
        };
        let r = self.copies;
        let mut any_open = false;
        for b in 0..self.spec.batches {
            let jb = job * self.spec.batches + b;
            if self.batch_done[jb] {
                continue;
            }
            any_open = true;
            // Cancel attempt−1: kill it if running, otherwise bump the
            // live generation so the queued copy is dropped at pop time.
            let prev_worker = b * r + (attempt - 1);
            self.batch_gen[jb] = attempt as u32;
            match self.running[prev_worker] {
                Some(rc) if rc.job as usize == job && rc.batch as usize == b => {
                    self.release(prev_worker)?;
                }
                _ => {}
            }
            self.enqueue(b * r + attempt, job as u32, b as u32, attempt as u32)?;
        }
        if any_open && attempt + 1 < r {
            let deadline = self.arrival_time[job] + (attempt as f64 + 1.0) * t;
            // Guard against t = 0 rounding: never schedule in the past.
            let at = if deadline < self.q.now() { self.q.now() } else { deadline };
            self.q
                .schedule(at, Ev::Relaunch { job: job as u32, attempt: attempt as u32 + 1 })?;
        }
        Ok(())
    }

    fn on_requeue(&mut self, w: usize, job: u32, batch: u32) -> Result<()> {
        let jb = job as usize * self.spec.batches + batch as usize;
        if self.batch_done[jb] || self.job_dead[job as usize] {
            return Ok(());
        }
        self.enqueue(w, job, batch, self.batch_gen[jb])
    }

    fn resolve(&mut self, job: usize, failed: bool) {
        self.resolved += 1;
        self.job_failed[job] = failed;
        if !failed {
            self.sojourn[job] = self.q.now() - self.arrival_time[job];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ServiceDist;

    fn spec<'a>(sampler: &'a Sampler, arrivals: Arrivals<'a>) -> OpenSim<'a> {
        OpenSim {
            workers: 4,
            batches: 2,
            sampler,
            replication: ReplicationPolicy::Upfront,
            failures: FailureModel::None,
            arrivals,
            warmup: 5,
            jobs: 20,
        }
    }

    #[test]
    fn completes_all_jobs_and_accounts_busy_time() {
        let sampler = ServiceDist::exp(1.0).sampler();
        let mut rng = Pcg64::new(11);
        let run = spec(&sampler, Arrivals::Poisson { rate: 0.05 }).run(&mut rng).unwrap();
        assert_eq!(run.sojourns.len(), 20);
        assert_eq!(run.failed, 0);
        assert!(run.sojourns.iter().all(|&s| s.is_finite() && s > 0.0));
        assert!(run.busy > 0.0);
        // Busy worker-seconds can never exceed cluster capacity.
        assert!(run.busy <= 4.0 * run.horizon * (1.0 + 1e-12));
    }

    #[test]
    fn is_deterministic_for_a_fixed_rng_stream() {
        let sampler = ServiceDist::exp(1.0).sampler();
        let s = spec(&sampler, Arrivals::Poisson { rate: 0.5 });
        let a = s.run(&mut Pcg64::new(7)).unwrap();
        let b = s.run(&mut Pcg64::new(7)).unwrap();
        assert_eq!(a.sojourns, b.sojourns);
        assert_eq!(a.busy.to_bits(), b.busy.to_bits());
        assert_eq!(a.horizon.to_bits(), b.horizon.to_bits());
        let c = s.run(&mut Pcg64::new(8)).unwrap();
        assert_ne!(a.sojourns, c.sojourns);
    }

    #[test]
    fn trace_arrivals_far_apart_match_the_closed_system_shape() {
        // Jobs spaced far beyond any plausible sojourn: each sees an
        // idle cluster, so sojourns are iid closed-system samples —
        // strictly positive and unaffected by earlier jobs.
        let sampler = ServiceDist::exp(1.0).sampler();
        let times: Vec<f64> = (0..8).map(|i| i as f64 * 1e6).collect();
        let mut s = spec(&sampler, Arrivals::Trace(&times));
        s.warmup = 2;
        s.jobs = 6;
        let run = s.run(&mut Pcg64::new(3)).unwrap();
        assert_eq!(run.sojourns.len(), 6);
        // No queueing: every sojourn is far below the interarrival gap.
        assert!(run.sojourns.iter().all(|&x| x < 1e5));
    }

    #[test]
    fn rejects_invalid_configurations() {
        let sampler = ServiceDist::exp(1.0).sampler();
        let base = spec(&sampler, Arrivals::Poisson { rate: 0.1 });
        let mut s = base;
        s.batches = 3; // does not divide 4
        assert!(s.run(&mut Pcg64::new(1)).is_err());
        let mut s = base;
        s.workers = 0;
        assert!(s.run(&mut Pcg64::new(1)).is_err());
        let mut s = base;
        s.jobs = 0;
        assert!(s.run(&mut Pcg64::new(1)).is_err());
        let mut s = base;
        s.arrivals = Arrivals::Poisson { rate: 0.0 };
        assert!(s.run(&mut Pcg64::new(1)).is_err());
        let mut s = base;
        s.arrivals = Arrivals::Poisson { rate: f64::NAN };
        assert!(s.run(&mut Pcg64::new(1)).is_err());
        let short = [0.0, 1.0];
        let mut s = base;
        s.arrivals = Arrivals::Trace(&short);
        assert!(s.run(&mut Pcg64::new(1)).is_err());
        let mut non_monotone: Vec<f64> = (0..30).map(f64::from).collect();
        non_monotone[3] = 0.5;
        let mut s = base;
        s.arrivals = Arrivals::Trace(&non_monotone);
        assert!(s.run(&mut Pcg64::new(1)).is_err());
        let mut s = base;
        s.replication = ReplicationPolicy::SpeculativeAt { t: 1.0 };
        s.failures = FailureModel::Crash { p: 0.1 };
        assert!(s.run(&mut Pcg64::new(1)).is_err());
    }

    #[test]
    fn crash_without_restart_can_fail_jobs() {
        let sampler = ServiceDist::exp(1.0).sampler();
        let mut s = spec(&sampler, Arrivals::Poisson { rate: 0.1 });
        s.failures = FailureModel::Crash { p: 1.0 };
        let run = s.run(&mut Pcg64::new(5)).unwrap();
        assert_eq!(run.failed, 20);
        assert!(run.sojourns.is_empty());
        // Crashed copies still burned worker time.
        assert!(run.busy > 0.0);
    }

    #[test]
    fn crash_restart_recovers_every_job() {
        let sampler = ServiceDist::exp(1.0).sampler();
        let mut s = spec(&sampler, Arrivals::Poisson { rate: 0.05 });
        s.failures = FailureModel::CrashRestart { p: 0.5, delay: 0.25 };
        let run = s.run(&mut Pcg64::new(6)).unwrap();
        assert_eq!(run.failed, 0);
        assert_eq!(run.sojourns.len(), 20);
    }

    #[test]
    fn timed_policies_complete_their_jobs() {
        let sampler = ServiceDist::exp(1.0).sampler();
        for replication in [
            ReplicationPolicy::SpeculativeAt { t: 0.5 },
            ReplicationPolicy::SpeculativeAt { t: 0.0 },
            ReplicationPolicy::RelaunchAt { t: 0.5 },
            ReplicationPolicy::RelaunchAt { t: 0.0 },
        ] {
            let mut s = spec(&sampler, Arrivals::Poisson { rate: 0.2 });
            s.replication = replication;
            let run = s.run(&mut Pcg64::new(9)).unwrap();
            assert_eq!(run.failed, 0, "{replication:?}");
            assert_eq!(run.sojourns.len(), 20, "{replication:?}");
            assert!(run.sojourns.iter().all(|&x| x.is_finite() && x > 0.0));
        }
    }

    #[test]
    fn speculation_burns_no_more_than_upfront() {
        // With a huge speculation deadline the backups never launch:
        // strictly less redundant work than up-front replication of the
        // same stream, and never more than one copy's service per batch
        // is *useful*. Compare total busy time under identical seeds.
        let sampler = ServiceDist::exp(1.0).sampler();
        let mut lazy = spec(&sampler, Arrivals::Poisson { rate: 0.05 });
        lazy.replication = ReplicationPolicy::SpeculativeAt { t: 1e9 };
        let lazy_run = lazy.run(&mut Pcg64::new(13)).unwrap();
        assert_eq!(lazy_run.failed, 0);
        assert_eq!(lazy_run.sojourns.len(), 20);
    }
}
