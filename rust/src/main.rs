//! `replica` — CLI entrypoint for the straggler-mitigation framework.
//!
//! See `replica help` (or [`replica::cli::HELP`]) for usage.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = replica::cli::run(argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
