//! AOT artifact manifest (`artifacts/manifest.json`).

use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::{parse, Json};

/// One lowered entrypoint.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Input shapes (row-major; empty = scalar).
    pub arg_shapes: Vec<Vec<usize>>,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
}

impl ArtifactEntry {
    /// Total element count of argument `i`.
    pub fn arg_elems(&self, i: usize) -> usize {
        self.arg_shapes[i].iter().product::<usize>().max(1)
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dtype: String,
    /// Feature dimension the model was lowered with.
    pub d: usize,
    /// Primary shard rows.
    pub m: usize,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::MissingArtifact(format!("{}: {e}", path.display()))
        })?;
        Self::from_json_text(&text, dir)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn from_json_text(text: &str, dir: &Path) -> Result<Manifest> {
        let doc = parse(text)?;
        let get_usize = |key: &str| -> Result<usize> {
            doc.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Parse(format!("manifest missing '{key}'")))
        };
        let dtype = doc
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Parse("manifest missing 'dtype'".into()))?
            .to_string();
        if dtype != "f32" {
            return Err(Error::Runtime(format!(
                "runtime only supports f32 artifacts, manifest says {dtype}"
            )));
        }
        let entries_json = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Parse("manifest missing 'entries'".into()))?;
        let mut entries = Vec::with_capacity(entries_json.len());
        for e in entries_json {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Parse("entry missing 'name'".into()))?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Parse("entry missing 'file'".into()))?
                .to_string();
            let args = e
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Parse("entry missing 'args'".into()))?;
            let mut arg_shapes = Vec::with_capacity(args.len());
            for a in args {
                let shape = a
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| Error::Parse("arg missing 'shape'".into()))?
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(|| Error::Parse("bad dim".into())))
                    .collect::<Result<Vec<usize>>>()?;
                arg_shapes.push(shape);
            }
            let outputs = e
                .get("outputs")
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Parse("entry missing 'outputs'".into()))?;
            entries.push(ArtifactEntry { name, file, arg_shapes, outputs });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            dtype,
            d: get_usize("d")?,
            m: get_usize("m")?,
            entries,
        })
    }

    /// Find an entry by exact name.
    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| Error::MissingArtifact(name.to_string()))
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "dtype": "f32", "d": 8, "m": 32,
      "entries": [
        {"name": "partial_grad_m32_d8", "file": "partial_grad_m32_d8.hlo.txt",
         "args": [{"shape": [8], "dtype": "f32"},
                  {"shape": [32, 8], "dtype": "f32"},
                  {"shape": [32], "dtype": "f32"}],
         "outputs": 1},
        {"name": "sgd_update_d8", "file": "sgd_update_d8.hlo.txt",
         "args": [{"shape": [8], "dtype": "f32"},
                  {"shape": [8], "dtype": "f32"},
                  {"shape": [], "dtype": "f32"}],
         "outputs": 1}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json_text(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.d, 8);
        assert_eq!(m.m, 32);
        assert_eq!(m.entries.len(), 2);
        let e = m.entry("partial_grad_m32_d8").unwrap();
        assert_eq!(e.arg_shapes[1], vec![32, 8]);
        assert_eq!(e.arg_elems(1), 256);
        assert_eq!(e.arg_elems(2), 32);
        let s = m.entry("sgd_update_d8").unwrap();
        assert_eq!(s.arg_shapes[2], Vec::<usize>::new()); // scalar
        assert_eq!(s.arg_elems(2), 1);
        assert!(m.hlo_path(e).ends_with("partial_grad_m32_d8.hlo.txt"));
    }

    #[test]
    fn missing_entry_is_clear_error() {
        let m = Manifest::from_json_text(SAMPLE, Path::new("/tmp/a")).unwrap();
        let err = m.entry("nope").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn rejects_non_f32() {
        let bad = SAMPLE.replace("\"f32\", \"d\"", "\"f64\", \"d\"");
        assert!(Manifest::from_json_text(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::from_json_text("{}", Path::new("/tmp")).is_err());
        assert!(Manifest::from_json_text("[1,2]", Path::new("/tmp")).is_err());
    }
}
