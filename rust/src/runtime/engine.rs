//! The PJRT engine: compile HLO-text artifacts, execute with f32
//! tensors. `!Send` — lives on the runtime service thread.

use std::collections::HashMap;

use crate::runtime::manifest::Manifest;
use crate::util::error::{Error, Result};

/// A flat f32 tensor (row-major). `shape = []` means scalar.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>().max(1));
        Tensor { data, shape }
    }

    pub fn scalar(x: f32) -> Tensor {
        Tensor { data: vec![x], shape: vec![] }
    }

    pub fn vec(data: Vec<f32>) -> Tensor {
        let n = data.len();
        Tensor { data, shape: vec![n] }
    }

    pub fn matrix(data: Vec<f32>, rows: usize, cols: usize) -> Tensor {
        debug_assert_eq!(data.len(), rows * cols);
        Tensor { data, shape: vec![rows, cols] }
    }
}

/// One input to a cached execution: either fresh host data (uploaded
/// every call) or a device-resident buffer cached under a caller-chosen
/// key (uploaded on first use only). Cached inputs are for *immutable*
/// data — the coordinator's dataset shards, which never change between
/// rounds; the caller owns key uniqueness.
pub enum Arg {
    Fresh(Tensor),
    Cached { key: u64, tensor: Tensor },
}

/// Compiled-executable cache over one PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Device-resident buffers for immutable inputs (see [`Arg::Cached`]).
    buffers: HashMap<u64, xla::PjRtBuffer>,
}

impl Engine {
    /// Create a CPU engine for a manifest. Compilation is lazy per
    /// entry (first call compiles, later calls hit the cache).
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT engine up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine { client, manifest, compiled: HashMap::new(), buffers: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Eagerly compile every artifact in the manifest (startup warm-up,
    /// so the serving hot path never pays compile latency).
    pub fn warm_up(&mut self) -> Result<()> {
        let names: Vec<String> =
            self.manifest.entries.iter().map(|e| e.name.clone()).collect();
        for name in names {
            self.ensure_compiled(&name)?;
        }
        Ok(())
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let entry = self.manifest.entry(name)?.clone();
            let path = self.manifest.hlo_path(&entry);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            log::debug!("compiled artifact '{name}' from {}", path.display());
            self.compiled.insert(name.to_string(), exe);
        }
        self.compiled.get(name).ok_or_else(|| {
            Error::Internal(format!("artifact '{name}' vanished after compilation"))
        })
    }

    /// Execute an entrypoint with plain (fresh) inputs.
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let args: Vec<Arg> = inputs.iter().map(|t| Arg::Fresh(t.clone())).collect();
        self.execute_args(name, args)
    }

    /// Execute an entrypoint with a mix of fresh and device-cached
    /// inputs (§Perf: avoids re-uploading immutable shard data every
    /// round). Input count/shapes are validated against the manifest;
    /// outputs come back as flat tensors.
    pub fn execute_args(&mut self, name: &str, args: Vec<Arg>) -> Result<Vec<Tensor>> {
        let entry = self.manifest.entry(name)?.clone();
        if args.len() != entry.arg_shapes.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                entry.arg_shapes.len(),
                args.len()
            )));
        }
        for (i, (a, want)) in args.iter().zip(&entry.arg_shapes).enumerate() {
            let t = match a {
                Arg::Fresh(t) | Arg::Cached { tensor: t, .. } => t,
            };
            if &t.shape != want {
                return Err(Error::Runtime(format!(
                    "{name}: input {i} has shape {:?}, artifact wants {:?}",
                    t.shape, want
                )));
            }
        }
        self.ensure_compiled(name)?; // lazy compile before borrowing buffers
        // Pass 1: make sure every buffer exists on device. Fresh inputs
        // are uploaded into `scratch`; cached ones go to (or come from)
        // the persistent cache.
        let mut scratch: Vec<(usize, xla::PjRtBuffer)> = Vec::new();
        for (i, a) in args.iter().enumerate() {
            match a {
                Arg::Fresh(t) => {
                    let dims: Vec<usize> = t.shape.clone();
                    let buf = self
                        .client
                        .buffer_from_host_buffer::<f32>(&t.data, &dims, None)?;
                    scratch.push((i, buf));
                }
                Arg::Cached { key, tensor } => {
                    if !self.buffers.contains_key(key) {
                        let dims: Vec<usize> = tensor.shape.clone();
                        let buf = self
                            .client
                            .buffer_from_host_buffer::<f32>(&tensor.data, &dims, None)?;
                        self.buffers.insert(*key, buf);
                    }
                }
            }
        }
        // Pass 2: assemble the argument list by reference.
        let mut buf_refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        let mut scratch_iter = scratch.iter();
        for (i, a) in args.iter().enumerate() {
            match a {
                Arg::Fresh(_) => {
                    let (idx, buf) = scratch_iter.next().ok_or_else(|| {
                        Error::Internal(format!("{name}: no scratch buffer for input {i}"))
                    })?;
                    debug_assert_eq!(*idx, i);
                    buf_refs.push(buf);
                }
                Arg::Cached { key, .. } => {
                    buf_refs.push(self.buffers.get(key).ok_or_else(|| {
                        Error::Internal(format!(
                            "{name}: input {i} missing from the device cache"
                        ))
                    })?);
                }
            }
        }
        let exe = self
            .compiled
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("{name}: not compiled (warm_up?)")))?;
        let result = exe.execute_b::<&xla::PjRtBuffer>(&buf_refs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result.to_tuple()?;
        if parts.len() != entry.outputs {
            return Err(Error::Runtime(format!(
                "{name}: artifact returned {} outputs, manifest says {}",
                parts.len(),
                entry.outputs
            )));
        }
        parts
            .into_iter()
            .map(|lit| {
                let data = lit.to_vec::<f32>()?;
                let n = data.len();
                Ok(Tensor { data, shape: vec![n] })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_constructors() {
        let s = Tensor::scalar(2.5);
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(s.data, vec![2.5]);
        let v = Tensor::vec(vec![1.0, 2.0]);
        assert_eq!(v.shape, vec![2]);
        let m = Tensor::matrix(vec![1.0; 6], 2, 3);
        assert_eq!(m.shape, vec![2, 3]);
    }

    // Engine execution itself is covered by rust/tests/integration_runtime.rs
    // (needs `make artifacts` + the PJRT shared library).
}
