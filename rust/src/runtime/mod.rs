//! PJRT/XLA runtime: load AOT artifacts and execute them from Rust.
//!
//! The Python side (`python/compile/aot.py`) lowers the JAX+Pallas model
//! ONCE to HLO-text artifacts + `manifest.json`; this module loads them
//! with `HloModuleProto::from_text_file`, compiles on the PJRT CPU
//! client, and serves executions to the coordinator's worker threads.
//!
//! PJRT wrapper types hold raw pointers (`!Send`), so the engine lives
//! on a dedicated runtime thread ([`RuntimeService`]); worker threads
//! talk to it through a cloneable, `Send` [`RuntimeHandle`]. Python
//! never runs at serve time.

mod engine;
mod gradient;
mod manifest;
mod service;

pub use engine::{Arg, Engine, Tensor};
pub use gradient::GradientOps;
pub use manifest::{ArtifactEntry, Manifest};
pub use service::{RuntimeHandle, RuntimeService};

use std::path::PathBuf;

/// Resolve the artifacts directory: `$REPLICA_ARTIFACTS` or
/// `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("REPLICA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Are AOT artifacts available? (Used by tests/examples to degrade
/// gracefully with a clear "run `make artifacts`" message.)
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
