//! The runtime service thread: owns the (`!Send`) PJRT engine and
//! serves execution requests from any number of worker threads through
//! a cloneable [`RuntimeHandle`].

use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::runtime::engine::{Arg, Engine, Tensor};
use crate::runtime::manifest::Manifest;
use crate::util::error::{Error, Result};

enum Request {
    Execute {
        entry: String,
        args: Vec<Arg>,
        reply: Sender<Result<Vec<Tensor>>>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the runtime thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Request>,
    /// Copy of the manifest for shape lookups (cheap, immutable).
    manifest: Manifest,
}

impl RuntimeHandle {
    /// Execute an artifact entrypoint; blocks until the result arrives.
    pub fn execute(&self, entry: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        self.execute_args(entry, inputs.into_iter().map(Arg::Fresh).collect())
    }

    /// Execute with a mix of fresh and device-cached inputs (§Perf:
    /// immutable shard data is uploaded once and kept device-resident).
    pub fn execute_args(&self, entry: &str, args: Vec<Arg>) -> Result<Vec<Tensor>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request::Execute { entry: entry.to_string(), args, reply: reply_tx })
            .map_err(|_| Error::Runtime("runtime thread is gone".into()))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Runtime("runtime thread dropped the reply".into()))?
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

/// The runtime service: spawns the engine thread on construction.
pub struct RuntimeService {
    tx: Sender<Request>,
    manifest: Manifest,
    join: Option<JoinHandle<()>>,
}

impl RuntimeService {
    /// Start a service for the artifacts in `dir` (pre-compiling every
    /// entry before accepting work).
    pub fn start(dir: &Path) -> Result<RuntimeService> {
        let manifest = Manifest::load(dir)?;
        let (tx, rx) = channel::<Request>();
        let thread_manifest = manifest.clone();
        // Engine construction happens ON the runtime thread (PJRT types
        // are !Send), so failures are reported through a one-shot channel.
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("replica-runtime".into())
            .spawn(move || runtime_loop(thread_manifest, rx, ready_tx))
            .map_err(|e| Error::Runtime(format!("spawn runtime thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("runtime thread died during startup".into()))??;
        Ok(RuntimeService { tx, manifest, join: Some(join) })
    }

    /// Get a handle for worker threads.
    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle { tx: self.tx.clone(), manifest: self.manifest.clone() }
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn runtime_loop(manifest: Manifest, rx: Receiver<Request>, ready: Sender<Result<()>>) {
    let mut engine = match Engine::new(manifest).and_then(|mut e| {
        e.warm_up()?;
        Ok(e)
    }) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::Execute { entry, args, reply } => {
                let result = engine.execute_args(&entry, args);
                // receiver may have given up; ignore send failures
                let _ = reply.send(result);
            }
            Request::Shutdown => break,
        }
    }
}

// Execution is covered by rust/tests/integration_runtime.rs (requires
// artifacts); manifest/channel plumbing is unit-tested via the
// coordinator's native-backend tests.
