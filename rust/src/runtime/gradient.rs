//! Typed gradient operations over a [`RuntimeHandle`]: the coordinator's
//! view of the L2 model.

use crate::runtime::engine::{Arg, Tensor};
use crate::runtime::service::RuntimeHandle;
use crate::util::error::{Error, Result};

/// Typed wrappers around the AOT entrypoints for one `(m, d)` shape.
#[derive(Clone)]
pub struct GradientOps {
    handle: RuntimeHandle,
    /// Shard rows this instance serves.
    pub m: usize,
    /// Feature dimension.
    pub d: usize,
    /// Unique id namespacing this instance's device-cache keys —
    /// different `GradientOps` sharing one runtime service must never
    /// collide on cached shard buffers.
    instance: u64,
    grad_loss_entry: String,
    full_step_entry: String,
    update_entry: String,
}

static INSTANCE_COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

impl GradientOps {
    /// Bind to the artifacts for shard size `m` (must exist in the
    /// manifest; `aot.py` emits the primary m and m/2).
    pub fn new(handle: RuntimeHandle, m: usize) -> Result<GradientOps> {
        let d = handle.manifest().d;
        let grad_loss_entry = format!("partial_grad_loss_m{m}_d{d}");
        let full_step_entry = format!("full_step_m{m}_d{d}");
        let update_entry = format!("sgd_update_d{d}");
        // fail fast if the artifacts are missing
        handle.manifest().entry(&grad_loss_entry)?;
        handle.manifest().entry(&full_step_entry)?;
        handle.manifest().entry(&update_entry)?;
        let instance = INSTANCE_COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(GradientOps { handle, m, d, instance, grad_loss_entry, full_step_entry, update_entry })
    }

    /// Per-worker task: mean gradient + mean loss over a shard.
    /// `x` is row-major `(m, d)`, `y` is `(m,)`.
    pub fn partial_grad_loss(
        &self,
        beta: &[f32],
        x: &[f32],
        y: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        self.check_shapes(beta, x, y)?;
        let out = self.handle.execute(
            &self.grad_loss_entry,
            vec![
                Tensor::vec(beta.to_vec()),
                Tensor::matrix(x.to_vec(), self.m, self.d),
                Tensor::vec(y.to_vec()),
            ],
        )?;
        let grad = out[0].data.clone();
        let loss = out[1].data[0];
        Ok((grad, loss))
    }

    /// Like [`Self::partial_grad_loss`] but with the shard's `x`/`y`
    /// cached device-side under `shard_key` — uploads the (immutable)
    /// shard once, then only β crosses the host/device boundary each
    /// round (§Perf). The caller must keep `shard_key` ↔ data stable.
    pub fn partial_grad_loss_cached(
        &self,
        beta: &[f32],
        shard_key: u64,
        x: &[f32],
        y: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        self.check_shapes(beta, x, y)?;
        // key layout: [instance | shard | x-vs-y] — instances never share
        // cache entries, and x/y of one shard get adjacent keys
        let kx = (self.instance << 32) | (shard_key << 1);
        let ky = kx | 1;
        let out = self.handle.execute_args(
            &self.grad_loss_entry,
            vec![
                Arg::Fresh(Tensor::vec(beta.to_vec())),
                Arg::Cached { key: kx, tensor: Tensor::matrix(x.to_vec(), self.m, self.d) },
                Arg::Cached { key: ky, tensor: Tensor::vec(y.to_vec()) },
            ],
        )?;
        Ok((out[0].data.clone(), out[1].data[0]))
    }

    /// Master update: `beta - lr * g`.
    pub fn sgd_update(&self, beta: &[f32], grad: &[f32], lr: f32) -> Result<Vec<f32>> {
        let out = self.handle.execute(
            &self.update_entry,
            vec![
                Tensor::vec(beta.to_vec()),
                Tensor::vec(grad.to_vec()),
                Tensor::scalar(lr),
            ],
        )?;
        Ok(out[0].data.clone())
    }

    /// Fused single-worker step: `(beta', loss)`.
    pub fn full_step(
        &self,
        beta: &[f32],
        x: &[f32],
        y: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        self.check_shapes(beta, x, y)?;
        let out = self.handle.execute(
            &self.full_step_entry,
            vec![
                Tensor::vec(beta.to_vec()),
                Tensor::matrix(x.to_vec(), self.m, self.d),
                Tensor::vec(y.to_vec()),
                Tensor::scalar(lr),
            ],
        )?;
        Ok((out[0].data.clone(), out[1].data[0]))
    }

    fn check_shapes(&self, beta: &[f32], x: &[f32], y: &[f32]) -> Result<()> {
        if beta.len() != self.d || x.len() != self.m * self.d || y.len() != self.m {
            return Err(Error::Runtime(format!(
                "shape mismatch: beta {} (want {}), x {} (want {}), y {} (want {})",
                beta.len(),
                self.d,
                x.len(),
                self.m * self.d,
                y.len(),
                self.m
            )));
        }
        Ok(())
    }
}
