//! Synthetic Google-cluster-shaped trace generator.
//!
//! Reproduces the §VII workload: 10 jobs whose task service times fall
//! into two families, matching Fig. 11:
//!
//! * jobs 1–4 — exponential tail, large shift (the paper reports shift
//!   ≈ 10 s for jobs 1–3 and ≈ 1000 s for job 4);
//! * job 5 — borderline (exponential-ish CCDF but optimum at B = 50 in
//!   Fig. 12, i.e. mild heavy-tail behaviour);
//! * jobs 6–10 — heavy tail (Pareto α ∈ [1.1, 2.0]).

use crate::dist::ServiceDist;
use crate::traces::schema::{EventKind, Trace, TraceEvent};
use crate::util::rng::Pcg64;

/// Specification of one synthetic job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub job_id: u64,
    pub tasks: usize,
    pub service: ServiceDist,
}

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct GeneratorConfig {
    pub jobs: Vec<JobSpec>,
    pub seed: u64,
    /// Mean gap between task schedule times within a job (seconds);
    /// schedules are jittered so timestamps look trace-like.
    pub schedule_jitter: f64,
}

impl GeneratorConfig {
    /// The paper's §VII workload: 10 jobs / 2 tail families / 100 tasks
    /// each (divisible by the Fig. 12–13 sweep points).
    pub fn paper_workload(tasks_per_job: usize, seed: u64) -> GeneratorConfig {
        let jobs = vec![
            // exponential tail, shift ~10 s (jobs 1–3)
            JobSpec {
                job_id: 1,
                tasks: tasks_per_job,
                service: ServiceDist::shifted_exp(10.0, 0.8),
            },
            JobSpec {
                job_id: 2,
                tasks: tasks_per_job,
                service: ServiceDist::shifted_exp(12.0, 0.5),
            },
            JobSpec {
                job_id: 3,
                tasks: tasks_per_job,
                service: ServiceDist::shifted_exp(9.0, 1.2),
            },
            // job 4: shift ~1000 s
            JobSpec {
                job_id: 4,
                tasks: tasks_per_job,
                service: ServiceDist::shifted_exp(1000.0, 0.05),
            },
            // job 5: borderline — modest shift, heavier randomness
            JobSpec { job_id: 5, tasks: tasks_per_job, service: ServiceDist::pareto(5.0, 2.5) },
            // jobs 6–10: heavy tail
            JobSpec { job_id: 6, tasks: tasks_per_job, service: ServiceDist::pareto(8.0, 1.6) },
            JobSpec { job_id: 7, tasks: tasks_per_job, service: ServiceDist::pareto(20.0, 1.2) },
            JobSpec { job_id: 8, tasks: tasks_per_job, service: ServiceDist::pareto(10.0, 1.5) },
            JobSpec { job_id: 9, tasks: tasks_per_job, service: ServiceDist::pareto(6.0, 1.4) },
            JobSpec { job_id: 10, tasks: tasks_per_job, service: ServiceDist::pareto(15.0, 1.8) },
        ];
        GeneratorConfig { jobs, seed, schedule_jitter: 1.0 }
    }

    /// A cluster-scale workload in the same two §VII tail families:
    /// `jobs` jobs of `tasks_per_job` tasks each, cycling
    /// exponential-tail (shifted-exponential, 2 of every 5 jobs) and
    /// heavy-tail (Pareto, α ∈ \[1.1, 2.0\]) specs with per-job
    /// parameters drawn deterministically from `seed`. This is the
    /// workload behind the sweep engine's `generate` spec — ≥ 100 jobs
    /// × 1000 tasks is the intended scale, while `paper_workload`
    /// stays the exact 10-job Fig. 11 reproduction.
    pub fn scaled_workload(jobs: usize, tasks_per_job: usize, seed: u64) -> GeneratorConfig {
        let mut rng = Pcg64::new(seed ^ 0x5CA1_AB1E);
        let specs = (0..jobs)
            .map(|j| {
                let service = if j % 5 < 2 {
                    // exponential tail: shift 5–20 s, rate 0.3–1.5
                    ServiceDist::shifted_exp(
                        5.0 + 15.0 * rng.uniform(),
                        0.3 + 1.2 * rng.uniform(),
                    )
                } else {
                    // heavy tail: scale 5–20 s, index 1.1–2.0
                    ServiceDist::pareto(5.0 + 15.0 * rng.uniform(), 1.1 + 0.9 * rng.uniform())
                };
                JobSpec { job_id: (j + 1) as u64, tasks: tasks_per_job, service }
            })
            .collect();
        GeneratorConfig { jobs: specs, seed, schedule_jitter: 1.0 }
    }

    /// Generate the trace.
    pub fn generate(&self) -> Trace {
        let mut rng = Pcg64::new(self.seed);
        let mut events = Vec::new();
        for job in &self.jobs {
            let mut t_sched = 0.0f64;
            for task in 0..job.tasks {
                t_sched += self.schedule_jitter * rng.uniform();
                let service = job.service.sample(&mut rng);
                let machine = rng.below(1000) + 1;
                events.push(TraceEvent {
                    timestamp_us: (t_sched * 1e6) as u64,
                    job_id: job.job_id,
                    task_index: task as u32,
                    machine_id: machine,
                    kind: EventKind::Schedule,
                });
                events.push(TraceEvent {
                    timestamp_us: ((t_sched + service) * 1e6) as u64,
                    job_id: job.job_id,
                    task_index: task as u32,
                    machine_id: machine,
                    kind: EventKind::Finish,
                });
            }
        }
        events.sort_by_key(|e| e.timestamp_us);
        Trace { events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{TailClass, TailFit};

    #[test]
    fn paper_workload_has_ten_jobs() {
        let trace = GeneratorConfig::paper_workload(100, 1).generate();
        assert_eq!(trace.job_ids(), (1..=10).collect::<Vec<u64>>());
        for j in 1..=10 {
            assert_eq!(trace.service_times(j).len(), 100, "job {j}");
        }
        // 10 jobs × 100 tasks × 2 events
        assert_eq!(trace.events.len(), 2000);
    }

    #[test]
    fn events_are_time_sorted() {
        let trace = GeneratorConfig::paper_workload(50, 2).generate();
        assert!(trace.events.windows(2).all(|w| w[0].timestamp_us <= w[1].timestamp_us));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = GeneratorConfig::paper_workload(20, 3).generate();
        let b = GeneratorConfig::paper_workload(20, 3).generate();
        assert_eq!(a.service_times(7), b.service_times(7));
        let c = GeneratorConfig::paper_workload(20, 4).generate();
        assert_ne!(a.service_times(7), c.service_times(7));
    }

    #[test]
    fn tail_families_classify_as_designed() {
        // larger sample so the classifier has a real tail to look at
        let trace = GeneratorConfig::paper_workload(3000, 5).generate();
        for j in [1u64, 2, 3, 4] {
            let fit = TailFit::classify(&trace.service_times(j));
            assert_eq!(fit.class, TailClass::ExponentialTail, "job {j}: {fit:?}");
        }
        for j in [6u64, 7, 8, 9, 10] {
            let fit = TailFit::classify(&trace.service_times(j));
            assert_eq!(fit.class, TailClass::HeavyTail, "job {j}: {fit:?}");
        }
    }

    #[test]
    fn scaled_workload_covers_both_families_at_scale() {
        let cfg = GeneratorConfig::scaled_workload(100, 40, 11);
        assert_eq!(cfg.jobs.len(), 100);
        let exp = cfg
            .jobs
            .iter()
            .filter(|j| matches!(j.service, ServiceDist::ShiftedExp { .. }))
            .count();
        let heavy = cfg
            .jobs
            .iter()
            .filter(|j| matches!(j.service, ServiceDist::Pareto { .. }))
            .count();
        assert_eq!(exp, 40);
        assert_eq!(heavy, 60);
        let trace = cfg.generate();
        assert_eq!(trace.job_ids().len(), 100);
        assert_eq!(trace.events.len(), 100 * 40 * 2);
        for j in [1u64, 50, 100] {
            assert_eq!(trace.service_times(j).len(), 40, "job {j}");
        }
        // deterministic in the seed, distinct across seeds
        let a = GeneratorConfig::scaled_workload(100, 40, 11).generate();
        assert_eq!(a.service_times(33), trace.service_times(33));
        let b = GeneratorConfig::scaled_workload(100, 40, 12).generate();
        assert_ne!(b.service_times(33), trace.service_times(33));
    }

    #[test]
    fn job4_has_kilo_second_shift() {
        let trace = GeneratorConfig::paper_workload(200, 6).generate();
        let st = trace.service_times(4);
        assert!(st.iter().all(|&t| t >= 1000.0));
    }
}
