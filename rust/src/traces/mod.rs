//! Cluster-trace workloads (paper §VII).
//!
//! The paper extracts per-task service times (finish − schedule) from
//! the 2011 Google cluster traces \[91\] and observes two families of
//! jobs: exponential-tail (jobs 1–4 of Fig. 11) and heavy-tail (jobs
//! 5–10). That dataset is not available offline, so [`generator`]
//! synthesizes a trace *in the same schema* with the same two tail
//! families (documented substitution — DESIGN.md §Substitutions); the
//! analysis pipeline ([`loader`], [`analyze`]) is identical for real
//! and synthetic traces.

mod analyze;
mod generator;
mod loader;
mod schema;

pub use analyze::{job_ccdf, JobAnalysis};
pub use generator::{GeneratorConfig, JobSpec};
pub use loader::{load_trace, write_trace};
pub use schema::{Trace, TraceEvent};
