//! Trace schema, modeled after the Google cluster `task_events` table:
//! one SCHEDULE and one FINISH event per task, with microsecond
//! timestamps.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

/// Event types present in the subset of the schema we use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Schedule,
    Finish,
}

impl EventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::Schedule => "SCHEDULE",
            EventKind::Finish => "FINISH",
        }
    }

    pub fn parse(s: &str) -> Result<EventKind> {
        match s {
            "SCHEDULE" => Ok(EventKind::Schedule),
            "FINISH" => Ok(EventKind::Finish),
            other => Err(Error::Parse(format!("unknown event kind '{other}'"))),
        }
    }
}

/// One trace event row.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Timestamp in microseconds since trace start.
    pub timestamp_us: u64,
    pub job_id: u64,
    pub task_index: u32,
    pub machine_id: u64,
    pub kind: EventKind,
}

/// A parsed trace: a flat list of events.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Job ids present, sorted.
    pub fn job_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.events.iter().map(|e| e.job_id).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Per-task service times of one job (seconds), via the paper's
    /// method: `finish_timestamp − schedule_timestamp` per task index.
    /// Tasks missing either endpoint are skipped (as in any real trace).
    pub fn service_times(&self, job_id: u64) -> Vec<f64> {
        let mut schedule: BTreeMap<u32, u64> = BTreeMap::new();
        let mut finish: BTreeMap<u32, u64> = BTreeMap::new();
        for e in self.events.iter().filter(|e| e.job_id == job_id) {
            match e.kind {
                EventKind::Schedule => {
                    schedule.insert(e.task_index, e.timestamp_us);
                }
                EventKind::Finish => {
                    finish.insert(e.task_index, e.timestamp_us);
                }
            }
        }
        let mut out = Vec::new();
        for (task, s) in schedule {
            if let Some(&f) = finish.get(&task) {
                if f > s {
                    out.push((f - s) as f64 / 1e6);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u64, job: u64, task: u32, kind: EventKind) -> TraceEvent {
        TraceEvent { timestamp_us: t, job_id: job, task_index: task, machine_id: 1, kind }
    }

    #[test]
    fn service_time_extraction() {
        let trace = Trace {
            events: vec![
                ev(0, 1, 0, EventKind::Schedule),
                ev(2_000_000, 1, 0, EventKind::Finish),
                ev(500_000, 1, 1, EventKind::Schedule),
                ev(1_500_000, 1, 1, EventKind::Finish),
                ev(0, 2, 0, EventKind::Schedule), // job 2: never finishes
            ],
        };
        assert_eq!(trace.job_ids(), vec![1, 2]);
        let st = trace.service_times(1);
        assert_eq!(st, vec![2.0, 1.0]);
        assert!(trace.service_times(2).is_empty());
        assert!(trace.service_times(99).is_empty());
    }

    #[test]
    fn kind_roundtrip() {
        assert_eq!(EventKind::parse("SCHEDULE").unwrap(), EventKind::Schedule);
        assert_eq!(EventKind::parse(EventKind::Finish.as_str()).unwrap(), EventKind::Finish);
        assert!(EventKind::parse("EVICT").is_err());
    }
}
