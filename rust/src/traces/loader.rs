//! Trace CSV persistence (same column layout for synthetic and real
//! traces): `timestamp_us,job_id,task_index,machine_id,event`.

use std::path::Path;

use crate::traces::schema::{EventKind, Trace, TraceEvent};
use crate::util::csv::Table;
use crate::util::error::{Error, Result};

/// Write a trace to CSV.
pub fn write_trace(path: &Path, trace: &Trace) -> Result<()> {
    let mut t = Table::new(vec!["timestamp_us", "job_id", "task_index", "machine_id", "event"]);
    for e in &trace.events {
        t.push_row(vec![
            e.timestamp_us.to_string(),
            e.job_id.to_string(),
            e.task_index.to_string(),
            e.machine_id.to_string(),
            e.kind.as_str().to_string(),
        ]);
    }
    t.write_to(path)
}

/// Load a trace from CSV.
pub fn load_trace(path: &Path) -> Result<Trace> {
    let t = Table::read_from(path)?;
    let c_ts = t.col("timestamp_us")?;
    let c_job = t.col("job_id")?;
    let c_task = t.col("task_index")?;
    let c_machine = t.col("machine_id")?;
    let c_event = t.col("event")?;
    let mut events = Vec::with_capacity(t.rows.len());
    for (i, row) in t.rows.iter().enumerate() {
        let parse_u64 = |s: &str, what: &str| -> Result<u64> {
            s.parse::<u64>()
                .map_err(|e| Error::Parse(format!("row {i}: bad {what} '{s}': {e}")))
        };
        events.push(TraceEvent {
            timestamp_us: parse_u64(&row[c_ts], "timestamp")?,
            job_id: parse_u64(&row[c_job], "job id")?,
            task_index: parse_u64(&row[c_task], "task index")? as u32,
            machine_id: parse_u64(&row[c_machine], "machine id")?,
            kind: EventKind::parse(&row[c_event])?,
        });
    }
    Ok(Trace { events })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::generator::GeneratorConfig;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("replica_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        let trace = GeneratorConfig::paper_workload(25, 9).generate();
        write_trace(&path, &trace).unwrap();
        let back = load_trace(&path).unwrap();
        assert_eq!(back.events.len(), trace.events.len());
        for j in trace.job_ids() {
            assert_eq!(back.service_times(j), trace.service_times(j), "job {j}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_rows_are_reported() {
        let dir = std::env::temp_dir().join("replica_trace_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(
            &path,
            "timestamp_us,job_id,task_index,machine_id,event\nxyz,1,0,1,SCHEDULE\n",
        )
        .unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::write(
            &path,
            "timestamp_us,job_id,task_index,machine_id,event\n1,1,0,1,EVICT\n",
        )
        .unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
