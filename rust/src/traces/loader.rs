//! Trace CSV persistence (same column layout for synthetic and real
//! traces): `timestamp_us,job_id,task_index,machine_id,event`.
//!
//! Loading is strict: malformed cells, unknown event kinds, ragged
//! rows (rejected by the CSV layer), and duplicate `(job, task, kind)`
//! events are all hard errors naming the offending row — a duplicate
//! FINISH would otherwise silently overwrite a service time and skew
//! every downstream tail fit.

use std::collections::BTreeSet;
use std::path::Path;

use crate::traces::schema::{EventKind, Trace, TraceEvent};
use crate::util::csv::Table;
use crate::util::error::{Error, Result};

/// Write a trace to CSV.
pub fn write_trace(path: &Path, trace: &Trace) -> Result<()> {
    let mut t = Table::new(vec!["timestamp_us", "job_id", "task_index", "machine_id", "event"]);
    for e in &trace.events {
        t.push_row(vec![
            e.timestamp_us.to_string(),
            e.job_id.to_string(),
            e.task_index.to_string(),
            e.machine_id.to_string(),
            e.kind.as_str().to_string(),
        ]);
    }
    t.write_to(path)
}

/// Load a trace from CSV.
pub fn load_trace(path: &Path) -> Result<Trace> {
    let t = Table::read_from(path)?;
    let c_ts = t.col("timestamp_us")?;
    let c_job = t.col("job_id")?;
    let c_task = t.col("task_index")?;
    let c_machine = t.col("machine_id")?;
    let c_event = t.col("event")?;
    let mut events = Vec::with_capacity(t.rows.len());
    let mut seen: BTreeSet<(u64, u32, bool)> = BTreeSet::new();
    for (i, row) in t.rows.iter().enumerate() {
        let parse_u64 = |s: &str, what: &str| -> Result<u64> {
            s.parse::<u64>()
                .map_err(|e| Error::Parse(format!("row {i}: bad {what} '{s}': {e}")))
        };
        let event = TraceEvent {
            timestamp_us: parse_u64(&row[c_ts], "timestamp")?,
            job_id: parse_u64(&row[c_job], "job id")?,
            task_index: parse_u64(&row[c_task], "task index")? as u32,
            machine_id: parse_u64(&row[c_machine], "machine id")?,
            kind: EventKind::parse(&row[c_event])?,
        };
        if !seen.insert((event.job_id, event.task_index, event.kind == EventKind::Finish)) {
            return Err(Error::Parse(format!(
                "row {i}: duplicate {} event for job {} task {}",
                event.kind.as_str(),
                event.job_id,
                event.task_index
            )));
        }
        events.push(event);
    }
    Ok(Trace { events })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::generator::GeneratorConfig;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("replica_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        let trace = GeneratorConfig::paper_workload(25, 9).generate();
        write_trace(&path, &trace).unwrap();
        let back = load_trace(&path).unwrap();
        assert_eq!(back.events.len(), trace.events.len());
        for j in trace.job_ids() {
            assert_eq!(back.service_times(j), trace.service_times(j), "job {j}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_rows_are_reported() {
        let dir = std::env::temp_dir().join("replica_trace_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(
            &path,
            "timestamp_us,job_id,task_index,machine_id,event\nxyz,1,0,1,SCHEDULE\n",
        )
        .unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::write(
            &path,
            "timestamp_us,job_id,task_index,machine_id,event\n1,1,0,1,EVICT\n",
        )
        .unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn duplicate_events_are_rejected_with_row_context() {
        let dir = std::env::temp_dir().join("replica_trace_dup");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dup.csv");
        // duplicate FINISH for job 1 task 0 (a schedule+finish pair for
        // the same task is fine; the same kind twice is not)
        std::fs::write(
            &path,
            "timestamp_us,job_id,task_index,machine_id,event\n\
             0,1,0,1,SCHEDULE\n\
             5,1,0,1,FINISH\n\
             9,1,0,2,FINISH\n",
        )
        .unwrap();
        let err = load_trace(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("row 2") && msg.contains("duplicate FINISH"), "{msg}");
        assert!(msg.contains("job 1") && msg.contains("task 0"), "{msg}");
        // same task on a different job is not a duplicate
        std::fs::write(
            &path,
            "timestamp_us,job_id,task_index,machine_id,event\n\
             0,1,0,1,SCHEDULE\n\
             0,2,0,1,SCHEDULE\n\
             5,1,0,1,FINISH\n\
             6,2,0,1,FINISH\n",
        )
        .unwrap();
        let trace = load_trace(&path).unwrap();
        assert_eq!(trace.job_ids(), vec![1, 2]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn structurally_malformed_traces_are_rejected() {
        let dir = std::env::temp_dir().join("replica_trace_malformed");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        // ragged row (field count mismatch)
        std::fs::write(
            &path,
            "timestamp_us,job_id,task_index,machine_id,event\n1,1,0\n",
        )
        .unwrap();
        assert!(load_trace(&path).is_err());
        // missing required column
        std::fs::write(&path, "timestamp_us,job_id,task_index,machine_id\n1,1,0,1\n")
            .unwrap();
        assert!(load_trace(&path).is_err());
        // empty file
        std::fs::write(&path, "").unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
