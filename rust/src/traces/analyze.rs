//! Trace analysis: per-job tail classification and CCDF extraction —
//! the §VII pipeline (Fig. 11 + the inputs to Figs. 12–13).

use crate::dist::{Empirical, ServiceDist, TailClass, TailFit};
use crate::traces::schema::Trace;

/// Analysis of one job's task service times.
#[derive(Clone, Debug)]
pub struct JobAnalysis {
    pub job_id: u64,
    pub n_tasks: usize,
    pub mean: f64,
    pub min: f64,
    pub p99: f64,
    pub fit: TailFit,
    /// The empirical distribution (for trace-driven simulation).
    pub empirical: Empirical,
}

impl JobAnalysis {
    /// Analyze one job of a trace. Returns None if it has no completed
    /// tasks.
    pub fn of(trace: &Trace, job_id: u64) -> Option<JobAnalysis> {
        let st = trace.service_times(job_id);
        if st.is_empty() {
            return None;
        }
        let fit = TailFit::classify(&st);
        let empirical = Empirical::new(st.clone());
        let mut sorted = st;
        sorted.sort_by(f64::total_cmp);
        let p99 = sorted[((sorted.len() - 1) as f64 * 0.99) as usize];
        Some(JobAnalysis {
            job_id,
            n_tasks: sorted.len(),
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            min: sorted[0],
            p99,
            fit,
            empirical,
        })
    }

    /// Analyze every job in the trace.
    pub fn all(trace: &Trace) -> Vec<JobAnalysis> {
        trace.job_ids().into_iter().filter_map(|j| JobAnalysis::of(trace, j)).collect()
    }

    pub fn is_heavy_tail(&self) -> bool {
        self.fit.class == TailClass::HeavyTail
    }

    /// The service distribution to drive simulations with: the raw
    /// empirical distribution (bootstrap), exactly like the paper's
    /// trace experiments.
    pub fn service_dist(&self) -> ServiceDist {
        ServiceDist::Empirical(self.empirical.clone())
    }
}

/// The Fig. 11 series: `(t, Pr{τ > t})` CCDF points of one job, at the
/// sample's own order statistics (exact ECDF, no binning).
pub fn job_ccdf(trace: &Trace, job_id: u64, max_points: usize) -> Vec<(f64, f64)> {
    let mut st = trace.service_times(job_id);
    if st.is_empty() {
        return Vec::new();
    }
    st.sort_by(f64::total_cmp);
    let n = st.len();
    let stride = (n / max_points.max(1)).max(1);
    let mut pts = Vec::new();
    for i in (0..n).step_by(stride) {
        pts.push((st[i], (n - i) as f64 / n as f64));
    }
    pts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::generator::GeneratorConfig;

    #[test]
    fn analysis_covers_all_jobs() {
        let trace = GeneratorConfig::paper_workload(300, 11).generate();
        let all = JobAnalysis::all(&trace);
        assert_eq!(all.len(), 10);
        let heavy: Vec<u64> =
            all.iter().filter(|a| a.is_heavy_tail()).map(|a| a.job_id).collect();
        // jobs 6–10 are heavy by construction (5 is borderline)
        for j in [6u64, 7, 8, 9, 10] {
            assert!(heavy.contains(&j), "job {j} should classify heavy: {heavy:?}");
        }
        for a in &all {
            assert_eq!(a.n_tasks, 300);
            assert!(a.min <= a.mean && a.mean <= a.p99);
        }
    }

    #[test]
    fn ccdf_shape() {
        let trace = GeneratorConfig::paper_workload(500, 12).generate();
        let pts = job_ccdf(&trace, 7, 100);
        assert!(pts.len() <= 101 && pts.len() >= 90);
        assert!((pts[0].1 - 1.0).abs() < 0.01);
        for w in pts.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 <= w[0].1);
        }
    }

    #[test]
    fn missing_job_is_none() {
        let trace = GeneratorConfig::paper_workload(10, 13).generate();
        assert!(JobAnalysis::of(&trace, 999).is_none());
        assert!(job_ccdf(&trace, 999, 10).is_empty());
    }
}
