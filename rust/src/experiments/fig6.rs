//! Fig. 6 + eq. (17) — overlapping vs non-overlapping batches (§V).
//!
//! The paper's N=6, B=3 case: scheme 1 (cyclic overlap), scheme 2
//! (hybrid), scheme 3 (balanced non-overlap). The claim:
//! `E[T³] < E[T²] < E[T¹]`.

use crate::batching::Policy;
use crate::dist::ServiceDist;
use crate::eval::{Estimator, MonteCarlo, Scenario};
use crate::metrics::{fnum, SeriesExport, Table};
use crate::util::error::Result;

/// Mean compute time of the three Fig. 5 schemes at one service rate.
#[derive(Clone, Copy, Debug)]
pub struct SchemeComparison {
    pub mu: f64,
    pub cyclic: f64,
    pub hybrid: f64,
    pub nonoverlap: f64,
}

/// Run the comparison over a μ sweep with `Exp(μ)` batch service times
/// (the Fig. 6 x-axis), N=6, B=3.
pub fn run(mus: &[f64], reps: usize, seed: u64) -> Result<Vec<SchemeComparison>> {
    let n = 6;
    let b = 3;
    let mc = MonteCarlo::new(reps, seed);
    mus.iter()
        .map(|&mu| {
            let tau = ServiceDist::exp(mu);
            // one batched evaluation per μ: each scheme gets its own
            // substream, the replication buffer is shared
            let scenarios = [
                Scenario::new(n, Policy::CyclicOverlapping { batches: b }, tau.clone()),
                Scenario::new(n, Policy::HybridOverlapping { batches: b }, tau.clone()),
                Scenario::new(n, Policy::BalancedNonOverlapping { batches: b }, tau),
            ];
            let ests = mc.evaluate_many(&scenarios)?;
            Ok(SchemeComparison {
                mu,
                cyclic: ests[0].mean,
                hybrid: ests[1].mean,
                nonoverlap: ests[2].mean,
            })
        })
        .collect()
}

/// Export curves (one per scheme).
pub fn series(rows: &[SchemeComparison]) -> Vec<SeriesExport> {
    let mut cyc = SeriesExport::new("scheme1_cyclic", "mu", vec!["mean_T"]);
    let mut hyb = SeriesExport::new("scheme2_hybrid", "mu", vec!["mean_T"]);
    let mut non = SeriesExport::new("scheme3_nonoverlap", "mu", vec!["mean_T"]);
    for r in rows {
        cyc.push(r.mu, vec![r.cyclic]);
        hyb.push(r.mu, vec![r.hybrid]);
        non.push(r.mu, vec![r.nonoverlap]);
    }
    vec![cyc, hyb, non]
}

/// Printable table.
pub fn table(rows: &[SchemeComparison]) -> Table {
    let mut t = Table::new(
        "Fig 6 / eq 17: E[T] of overlap schemes (N=6, B=3, Exp(mu) service)",
        vec!["mu", "scheme1 cyclic", "scheme2 hybrid", "scheme3 non-overlap", "eq17 holds"],
    );
    for r in rows {
        let ok = r.nonoverlap < r.hybrid && r.hybrid < r.cyclic;
        t.row(vec![
            fnum(r.mu),
            fnum(r.cyclic),
            fnum(r.hybrid),
            fnum(r.nonoverlap),
            if ok { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq17_ordering_holds() {
        // E[T3] < E[T2] < E[T1] across service rates
        let rows = run(&[0.5, 1.0, 2.0], 60_000, 7).unwrap();
        for r in &rows {
            assert!(
                r.nonoverlap < r.hybrid,
                "mu={}: nonoverlap {} !< hybrid {}",
                r.mu,
                r.nonoverlap,
                r.hybrid
            );
            assert!(
                r.hybrid < r.cyclic,
                "mu={}: hybrid {} !< cyclic {}",
                r.mu,
                r.hybrid,
                r.cyclic
            );
        }
    }

    #[test]
    fn means_scale_inversely_with_mu() {
        let rows = run(&[1.0, 2.0], 30_000, 9).unwrap();
        // Exp service: doubling μ halves all means
        assert!((rows[0].nonoverlap / rows[1].nonoverlap - 2.0).abs() < 0.15);
    }

    #[test]
    fn series_and_table_shapes() {
        let rows = run(&[1.0], 5_000, 1).unwrap();
        let s = series(&rows);
        assert_eq!(s.len(), 3);
        assert_eq!(s[0].points.len(), 1);
        let t = table(&rows);
        assert!(t.render().contains("yes"));
    }
}
