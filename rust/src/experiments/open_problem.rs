//! Extension: the paper's open problem (§IV closing remark).
//!
//! "The case of concave random variables, e.g. weibull and gamma with
//! shape parameters α > 1, is left as an open problem."
//!
//! We explore it numerically: does the balanced assignment still
//! minimize E\[T\] when the batch service time is stochastically
//! *concave*? Lemma 2's Schur-convexity argument needs convexity, so
//! the ordering could in principle reverse. The experiment compares
//! every assignment shape under Weibull/Gamma with shape > 1 via
//! numeric integration + Monte-Carlo.

use crate::analysis::closed_form::numeric_mean_var_assignment;
use crate::analysis::majorization::{all_assignments, balanced};
use crate::dist::ServiceDist;
use crate::metrics::{fnum, Table};
use crate::util::error::Result;

/// One exploration row: assignment and its numeric E\[T\].
#[derive(Clone, Debug)]
pub struct ConcaveRow {
    pub assignment: Vec<usize>,
    pub mean: f64,
}

/// Numeric E\[T\] of every assignment shape for a concave batch
/// service distribution, ascending by mean.
pub fn explore(n: usize, b: usize, tau: &ServiceDist) -> Result<Vec<ConcaveRow>> {
    assert!(n % b == 0);
    let batch = ServiceDist::scaled((n / b) as f64, tau.clone());
    let mut rows: Vec<ConcaveRow> = all_assignments(n, b)
        .into_iter()
        .map(|a| {
            let (mean, _) = numeric_mean_var_assignment(&a, &batch);
            ConcaveRow { assignment: a, mean }
        })
        .collect();
    rows.sort_by(|x, y| x.mean.total_cmp(&y.mean));
    Ok(rows)
}

/// Is the balanced assignment still optimal for this concave family?
pub fn balanced_still_optimal(n: usize, b: usize, tau: &ServiceDist) -> Result<bool> {
    let rows = explore(n, b, tau)?;
    Ok(rows[0].assignment == balanced(n, b))
}

/// Printable exploration table across concave families.
pub fn table(n: usize, b: usize) -> Result<Table> {
    let mut t = Table::new(
        &format!("Open problem: balanced optimality under CONCAVE service (N={n}, B={b})"),
        vec!["family", "balanced optimal?", "best assignment", "worst/best ratio"],
    );
    for tau in [
        ServiceDist::weibull(2.0, 1.0),
        ServiceDist::weibull(4.0, 1.0),
        ServiceDist::gamma_dist(2.0, 1.0),
        ServiceDist::gamma_dist(8.0, 0.25),
        // convex control rows
        ServiceDist::exp(1.0),
        ServiceDist::weibull(0.6, 1.0),
    ] {
        let rows = explore(n, b, &tau)?;
        let best = &rows[0];
        let worst = rows.last().unwrap_or(best);
        let optimal = best.assignment == balanced(n, b);
        t.row(vec![
            tau.label(),
            if optimal { "yes" } else { "NO" }.to_string(),
            format!("{:?}", best.assignment),
            fnum(worst.mean / best.mean),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn convex_families_confirm_lemma2() {
        assert!(balanced_still_optimal(8, 2, &ServiceDist::exp(1.0)).unwrap());
        assert!(balanced_still_optimal(8, 2, &ServiceDist::weibull(0.6, 1.0)).unwrap());
    }

    #[test]
    fn concave_families_explored() {
        // Empirical finding (documented in EXPERIMENTS.md): balanced
        // remains optimal for the concave families we test too — the
        // paper's open question, answered affirmatively in these cases.
        for tau in [ServiceDist::weibull(2.0, 1.0), ServiceDist::gamma_dist(2.0, 1.0)] {
            let rows = explore(8, 2, &tau).unwrap();
            assert_eq!(rows[0].assignment, vec![4, 4], "{}", tau.label());
        }
    }

    #[test]
    fn table_renders() {
        let t = table(6, 2).unwrap();
        assert!(t.render().contains("Gamma"));
    }

    #[test]
    fn monte_carlo_agrees_with_numeric_for_gamma() {
        use crate::batching::Policy;
        use crate::eval::{Estimator, MonteCarlo, Scenario};
        let tau = ServiceDist::gamma_dist(2.0, 1.0);
        let rows = explore(8, 2, &tau).unwrap();
        for r in rows.iter().take(2) {
            let est = MonteCarlo::new(30_000, 3)
                .evaluate(&Scenario::new(
                    8,
                    Policy::UnbalancedNonOverlapping { assignment: r.assignment.clone() },
                    tau.clone(),
                ))
                .unwrap();
            assert!(
                (est.mean - r.mean).abs() / r.mean < 0.03,
                "{:?}: mc {} vs numeric {}",
                r.assignment,
                est.mean,
                r.mean
            );
        }
    }
}
