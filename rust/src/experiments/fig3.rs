//! Fig. 3 — probability of covering B batches with N workers under
//! random batch-to-worker assignment (Lemma 1).

use crate::analysis::coverage::coverage_probability;
use crate::metrics::{fnum, SeriesExport, Table};

/// The paper's Fig. 3 worker budgets.
pub const PAPER_NS: [usize; 4] = [20, 50, 100, 200];

/// One curve per N: coverage probability at B = 1..=N.
pub fn run(ns: &[usize]) -> Vec<SeriesExport> {
    ns.iter()
        .map(|&n| {
            let mut s = SeriesExport::new(&format!("N={n}"), "B", vec!["coverage_prob"]);
            for b in 1..=n {
                s.push(b as f64, vec![coverage_probability(n, b)]);
            }
            s
        })
        .collect()
}

/// Printable summary: for each N, the largest B still covered with
/// ≥ 99% / ≥ 50% probability (the paper's headline reading: N=100
/// covers only B ≈ 10 reliably).
pub fn table(ns: &[usize]) -> Table {
    let mut t = Table::new(
        "Fig 3: batch coverage under random assignment (Lemma 1)",
        vec!["N", "max B @ 99%", "max B @ 50%", "P(cover B=N/10)", "P(cover B=N/2)"],
    );
    for &n in ns {
        let max_b = |target: f64| {
            (1..=n).rev().find(|&b| coverage_probability(n, b) >= target).unwrap_or(0)
        };
        t.row(vec![
            n.to_string(),
            max_b(0.99).to_string(),
            max_b(0.50).to_string(),
            fnum(coverage_probability(n, n / 10)),
            fnum(coverage_probability(n, n / 2)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curves_have_paper_shape() {
        let series = run(&PAPER_NS);
        assert_eq!(series.len(), 4);
        for s in &series {
            // starts at 1 (B=1 always covered), decreasing in B
            assert!((s.points[0].1[0] - 1.0).abs() < 1e-12);
            for w in s.points.windows(2) {
                assert!(w[1].1[0] <= w[0].1[0] + 1e-12);
            }
        }
        // paper: N=100 covers B=10 w.h.p., larger B drops fast
        let n100 = &series[2];
        assert!(n100.points[9].1[0] > 0.99); // B=10
        assert!(n100.points[29].1[0] < 0.6); // B=30
    }

    #[test]
    fn table_rows_match_ns() {
        let t = table(&PAPER_NS);
        assert_eq!(t.n_rows(), 4);
        let rendered = t.render();
        assert!(rendered.contains("N"));
    }
}
