//! Lemma 2/3 experiments — balanced vs unbalanced assignment under
//! majorization, for all three stochastically-convex families.

use crate::analysis::closed_form::numeric_mean_var_assignment;
use crate::analysis::majorization::{all_assignments, balanced, majorizes};
use crate::batching::Policy;
use crate::dist::ServiceDist;
use crate::eval::{Estimator, MonteCarlo, Scenario};
use crate::metrics::{fnum, Table};
use crate::util::error::Result;

/// One assignment-comparison row.
#[derive(Clone, Debug)]
pub struct AssignmentRow {
    pub assignment: Vec<usize>,
    pub majorizes_balanced: bool,
    /// Numeric-integration E\[T\].
    pub mean_numeric: f64,
    /// Monte-Carlo E\[T\].
    pub mean_mc: f64,
}

/// Compare every assignment shape of N workers to B batches under a
/// batch service distribution `(N/B)·τ`.
pub fn run(
    n: usize,
    b: usize,
    tau: &ServiceDist,
    reps: usize,
    seed: u64,
) -> Result<Vec<AssignmentRow>> {
    assert!(n % b == 0);
    let batch = ServiceDist::scaled((n / b) as f64, tau.clone());
    let bal = balanced(n, b);
    let assignments = all_assignments(n, b);
    // batched evaluation: one substream per assignment shape, one
    // shared replication buffer
    let scenarios: Vec<Scenario> = assignments
        .iter()
        .map(|a| {
            Scenario::new(
                n,
                Policy::UnbalancedNonOverlapping { assignment: a.clone() },
                tau.clone(),
            )
        })
        .collect();
    let ests = MonteCarlo::new(reps, seed).evaluate_many(&scenarios)?;
    let mut rows: Vec<AssignmentRow> = assignments
        .into_iter()
        .zip(ests)
        .map(|(a, est)| {
            let (mean_numeric, _) = numeric_mean_var_assignment(&a, &batch);
            AssignmentRow {
                majorizes_balanced: majorizes(&a, &bal) && a != bal,
                assignment: a,
                mean_numeric,
                mean_mc: est.mean,
            }
        })
        .collect();
    // sort by numeric mean so the table reads best-to-worst
    rows.sort_by(|x, y| x.mean_numeric.total_cmp(&y.mean_numeric));
    Ok(rows)
}

/// Printable table.
pub fn table(n: usize, b: usize, tau: &ServiceDist, rows: &[AssignmentRow]) -> Table {
    let mut t = Table::new(
        &format!("Lemma 2/3: assignment shapes, N={n}, B={b}, tau ~ {}", tau.label()),
        vec!["assignment", "E[T] numeric", "E[T] MC", "majorizes balanced"],
    );
    for r in rows {
        let mark = if r.majorizes_balanced {
            "yes"
        } else {
            "(balanced)"
        };
        t.row(vec![
            format!("{:?}", r.assignment),
            fnum(r.mean_numeric),
            fnum(r.mean_mc),
            mark.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_assignment_wins_for_all_families() {
        for tau in [
            ServiceDist::exp(1.0),
            ServiceDist::shifted_exp(0.1, 1.0),
            ServiceDist::pareto(1.0, 2.5),
        ] {
            let rows = run(8, 2, &tau, 20_000, 3).unwrap();
            // best row (numeric) must be the balanced (4,4)
            assert_eq!(rows[0].assignment, vec![4, 4], "{}", tau.label());
            // MC agrees within noise: balanced strictly better than the
            // most extreme (7,1)
            let extreme = rows.iter().find(|r| r.assignment == vec![7, 1]).unwrap();
            assert!(
                rows[0].mean_mc < extreme.mean_mc,
                "{}: {} !< {}",
                tau.label(),
                rows[0].mean_mc,
                extreme.mean_mc
            );
        }
    }

    #[test]
    fn majorization_order_implies_mean_order_numeric() {
        // Lemma 2 exactly: a ⪰ a' ⇒ E[T(a)] ≥ E[T(a')]
        let tau = ServiceDist::exp(1.0);
        let rows = run(12, 3, &tau, 1_000, 5).unwrap();
        for x in &rows {
            for y in &rows {
                if majorizes(&x.assignment, &y.assignment) {
                    assert!(
                        x.mean_numeric >= y.mean_numeric - 1e-9,
                        "{:?} ⪰ {:?} but {} < {}",
                        x.assignment,
                        y.assignment,
                        x.mean_numeric,
                        y.mean_numeric
                    );
                }
            }
        }
    }

    #[test]
    fn table_marks_balanced() {
        let tau = ServiceDist::exp(1.0);
        let rows = run(6, 3, &tau, 2_000, 7).unwrap();
        let t = table(6, 3, &tau, &rows);
        assert!(t.render().contains("(balanced)"));
    }
}
