//! Figs. 7–8 — E\[T\] and CoV\[T\] vs B for shifted-exponential task
//! service times (N=100, Δ=0.05, μ sweep), analytic closed forms with
//! optional Monte-Carlo cross-check.

use crate::analysis::closed_form::{sexp_cov, sexp_mean};
use crate::analysis::optimizer::feasible_b;
use crate::dist::ServiceDist;
use crate::eval::{Estimator, MonteCarlo};
use crate::metrics::{fnum, SeriesExport, Table};
use crate::util::error::Result;

/// Paper parameters.
pub const N: usize = 100;
pub const DELTA: f64 = 0.05;
pub const PAPER_MUS: [f64; 4] = [0.1, 1.0, 5.0, 15.0];

/// One figure row: (B, E\[T\], CoV\[T\]) for a given μ.
pub fn sweep(n: usize, delta: f64, mu: f64) -> Vec<(usize, f64, f64)> {
    feasible_b(n)
        .into_iter()
        .map(|b| (b, sexp_mean(n, b, delta, mu), sexp_cov(n, b, delta, mu)))
        .collect()
}

/// Fig. 7 curves (one per μ): E\[T\] vs B.
pub fn fig7_series(mus: &[f64]) -> Vec<SeriesExport> {
    mus.iter()
        .map(|&mu| {
            let mut s = SeriesExport::new(&format!("mu={mu}"), "B", vec!["mean_T"]);
            for (b, mean, _) in sweep(N, DELTA, mu) {
                s.push(b as f64, vec![mean]);
            }
            s
        })
        .collect()
}

/// Fig. 8 curves (one per μ): CoV\[T\] vs B.
pub fn fig8_series(mus: &[f64]) -> Vec<SeriesExport> {
    mus.iter()
        .map(|&mu| {
            let mut s = SeriesExport::new(&format!("mu={mu}"), "B", vec!["cov_T"]);
            for (b, _, cov) in sweep(N, DELTA, mu) {
                s.push(b as f64, vec![cov]);
            }
            s
        })
        .collect()
}

/// Printable Fig. 7 table (rows = B, one column pair per μ) with the
/// argmin marked.
pub fn table(mus: &[f64]) -> Table {
    let mut header: Vec<String> = vec!["B".into()];
    for &mu in mus {
        header.push(format!("E[T] mu={mu}"));
        header.push(format!("CoV mu={mu}"));
    }
    let mut t = Table::new(
        "Figs 7-8: E[T] and CoV[T] vs B, tau ~ SExp(0.05, mu), N=100",
        header.iter().map(|s| s.as_str()).collect(),
    );
    let sweeps: Vec<Vec<(usize, f64, f64)>> =
        mus.iter().map(|&mu| sweep(N, DELTA, mu)).collect();
    let argmins: Vec<usize> = sweeps
        .iter()
        .map(|sw| {
            sw.iter().min_by(|a, b| a.1.total_cmp(&b.1)).map_or(0, |(b, _, _)| *b)
        })
        .collect();
    for (i, b) in feasible_b(N).into_iter().enumerate() {
        let mut row = vec![b.to_string()];
        for (j, sw) in sweeps.iter().enumerate() {
            let star = if argmins[j] == b { "*" } else { "" };
            row.push(format!("{}{star}", fnum(sw[i].1)));
            row.push(fnum(sw[i].2));
        }
        t.row(row);
    }
    t
}

/// Monte-Carlo cross-check of one μ curve: returns
/// `(B, analytic, simulated, ci95)` rows.
pub fn mc_crosscheck(
    mu: f64,
    reps: usize,
    seed: u64,
) -> Result<Vec<(usize, f64, f64, f64)>> {
    let tau = ServiceDist::shifted_exp(DELTA, mu);
    let sweep = MonteCarlo::new(reps, seed).sweep(N, &tau)?;
    Ok(sweep
        .into_iter()
        .map(|(op, est)| {
            (op.batches, sexp_mean(N, op.batches, DELTA, mu), est.mean, est.ci95)
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_minima_move_right_with_mu() {
        // paper: the minimum of E[T] moves toward full parallelism as μ grows
        let argmin = |mu: f64| {
            sweep(N, DELTA, mu)
                .into_iter()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
                .0
        };
        let b_01 = argmin(0.1);
        let b_1 = argmin(1.0);
        let b_5 = argmin(5.0);
        let b_15 = argmin(15.0);
        assert_eq!(b_01, 1, "mu=0.1 → full diversity");
        assert!(b_1 > 1 && b_1 < N, "mu=1 interior, got {b_1}");
        assert!(b_5 >= b_1, "{b_5} >= {b_1}");
        assert_eq!(b_15, N, "mu=15 → full parallelism");
    }

    #[test]
    fn fig8_cov_optimum_flips_near_mu_06() {
        // Evaluating eq. (21) directly: the CoV optimum is at FULL
        // PARALLELISM for small μ and FULL DIVERSITY for large μ, with
        // the crossover at NΔμ ≈ 3.1 → μ ≈ 0.62 for N=100, Δ=0.05.
        //
        // NOTE: the paper's Fig. 8 prose states the opposite direction
        // ("μ < 0.8 full diversity ... μ > 0.8 full parallelism"), which
        // contradicts the paper's own eq. (21) and Theorem 7 (small Δμ →
        // full parallelism). We follow eq. (21)/Theorem 7; see
        // EXPERIMENTS.md.
        let argmin = |mu: f64| {
            sweep(N, DELTA, mu)
                .into_iter()
                .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
                .unwrap()
                .0
        };
        assert_eq!(argmin(0.4), N); // small Δμ → full parallelism
        assert_eq!(argmin(5.0), 1); // large Δμ → full diversity
        // crossover bracket
        assert_eq!(argmin(0.55), N);
        assert_eq!(argmin(0.70), 1);
    }

    #[test]
    fn mc_crosscheck_agrees() {
        let rows = mc_crosscheck(1.0, 8_000, 3).unwrap();
        for (b, analytic, simulated, ci) in rows {
            assert!(
                (analytic - simulated).abs() < (4.0 * ci).max(0.02 * analytic),
                "B={b}: analytic {analytic} vs sim {simulated} (ci {ci})"
            );
        }
    }

    #[test]
    fn table_has_star_markers() {
        let t = table(&[0.1, 1.0]);
        assert!(t.render().contains('*'));
        assert_eq!(t.n_rows(), feasible_b(N).len());
    }
}
