//! Regime tables — Theorems 6, 7, 9 and Corollaries 2–3: where the
//! optimum sits in the diversity–parallelism spectrum as the service
//! parameters move.

use crate::analysis::optimizer::{
    feasible_b, optimal_b_cov, optimal_b_mean, pareto_alpha_star, sexp_cov_optimal_end,
    sexp_cov_regime, sexp_mean_regime, Regime,
};
use crate::dist::ServiceDist;
use crate::metrics::{fnum, Table};

fn regime_str(r: Regime) -> &'static str {
    match r {
        Regime::FullDiversity => "full-diversity",
        Regime::Middle => "middle",
        Regime::FullParallelism => "full-parallelism",
        Regime::EitherEnd => "either-end",
    }
}

/// Theorem 6 table: SExp mean-optimal regime across μ (N, Δ fixed).
pub fn sexp_mean_table(n: usize, delta: f64, mus: &[f64]) -> Table {
    let mut t = Table::new(
        &format!("Theorem 6: E[T]-optimal regime, tau ~ SExp({delta}, mu), N={n}"),
        vec!["mu", "delta*mu", "regime (Thm 6)", "B* (search)", "E[T](B*)"],
    );
    for &mu in mus {
        let tau = ServiceDist::shifted_exp(delta, mu);
        let (b_star, val) = optimal_b_mean(n, &tau);
        t.row(vec![
            fnum(mu),
            fnum(delta * mu),
            regime_str(sexp_mean_regime(n, delta, mu)).to_string(),
            b_star.to_string(),
            fnum(val),
        ]);
    }
    t
}

/// Theorem 7 / Corollary 3 table: SExp CoV-optimal regime.
pub fn sexp_cov_table(n: usize, delta: f64, mus: &[f64]) -> Table {
    let mut t = Table::new(
        &format!("Theorem 7 / Cor 3: CoV-optimal regime, tau ~ SExp({delta}, mu), N={n}"),
        vec!["mu", "delta*mu", "regime (Thm 7)", "resolved end", "B* (search)"],
    );
    for &mu in mus {
        let tau = ServiceDist::shifted_exp(delta, mu);
        let (b_star, _) = optimal_b_cov(n, &tau);
        let regime = sexp_cov_regime(n, delta, mu);
        let resolved = match regime {
            Regime::EitherEnd => regime_str(sexp_cov_optimal_end(n, delta, mu)),
            r => regime_str(r),
        };
        t.row(vec![
            fnum(mu),
            fnum(delta * mu),
            regime_str(regime).to_string(),
            resolved.to_string(),
            b_star.to_string(),
        ]);
    }
    t
}

/// Theorem 9 table: Pareto mean-optimal regime across α, with α*.
pub fn pareto_table(n: usize, sigma: f64, alphas: &[f64]) -> Table {
    let a_star = pareto_alpha_star(n);
    let title = format!(
        "Theorem 9: E[T]-optimal regime, tau ~ Pareto({sigma}, alpha), N={n}, alpha*={a_star:.2}"
    );
    let mut t = Table::new(
        &title,
        vec!["alpha", "predicted", "B* (search)", "E[T](B*)", "CoV B* (Thm 10)"],
    );
    for &alpha in alphas {
        let tau = ServiceDist::pareto(sigma, alpha);
        let (b_star, val) = optimal_b_mean(n, &tau);
        let (b_cov, _) = optimal_b_cov(n, &tau);
        let predicted = if alpha >= a_star {
            "full-parallelism"
        } else {
            "middle"
        };
        t.row(vec![
            fnum(alpha),
            predicted.to_string(),
            b_star.to_string(),
            fnum(val),
            b_cov.to_string(),
        ]);
    }
    t
}

/// The headline trade-off table: for each family, the mean-optimal and
/// CoV-optimal operating points side by side (§VI discussion: they can
/// sit at opposite ends of the spectrum).
pub fn tradeoff_table(n: usize) -> Table {
    let mut t = Table::new(
        &format!("Mean-vs-predictability trade-off (N={n})"),
        vec!["service dist", "B* mean", "B* CoV", "opposite ends"],
    );
    let cases = vec![
        ServiceDist::exp(1.0),
        ServiceDist::shifted_exp(0.05, 0.1),
        ServiceDist::shifted_exp(0.05, 1.0),
        ServiceDist::shifted_exp(0.05, 20.0),
        ServiceDist::pareto(1.0, 2.5),
        ServiceDist::pareto(1.0, 7.0),
    ];
    for tau in cases {
        let (bm, _) = optimal_b_mean(n, &tau);
        let (bc, _) = optimal_b_cov(n, &tau);
        let opposite = (bm == 1 && bc == n) || (bm == n && bc == 1);
        t.row(vec![
            tau.label(),
            bm.to_string(),
            bc.to_string(),
            if opposite { "YES" } else { "no" }.to_string(),
        ]);
    }
    t
}

/// All feasible B for quick display.
pub fn spectrum_row(n: usize) -> String {
    feasible_b(n).iter().map(|b| b.to_string()).collect::<Vec<_>>().join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_with_expected_rows() {
        let t = sexp_mean_table(100, 0.05, &[0.1, 1.0, 15.0]);
        assert_eq!(t.n_rows(), 3);
        let r = t.render();
        assert!(r.contains("full-diversity"));
        assert!(r.contains("middle"));
        assert!(r.contains("full-parallelism"));

        let t = sexp_cov_table(100, 0.05, &[0.2, 3.0, 40.0]);
        assert_eq!(t.n_rows(), 3);

        let t = pareto_table(100, 1.0, &[1.5, 3.0, 7.0]);
        let r = t.render();
        assert!(r.contains("alpha*=4.7") || r.contains("alpha*=4.6") || r.contains("alpha*=4.8"));
    }

    #[test]
    fn exp_family_is_opposite_ends() {
        let t = tradeoff_table(100);
        let r = t.render();
        assert!(r.contains("YES"));
    }

    #[test]
    fn spectrum_row_lists_divisors() {
        assert_eq!(spectrum_row(6), "1, 2, 3, 6");
    }
}
