//! §VII trace experiments — Figs. 11, 12, 13.
//!
//! Fig. 11: CCDF of task service times for 10 jobs.
//! Fig. 12: normalized E\[T\] vs B for exponential-tail jobs (1–5).
//! Fig. 13: normalized E\[T\] vs B for heavy-tail jobs (6–10).
//!
//! The jobs come from a Google-trace-shaped synthetic workload (see
//! `traces::generator`); the pipeline — extract service times, build
//! the empirical distribution, sweep the redundancy level by
//! trace-driven simulation — is the paper's, executed on the
//! [`crate::sweep`] engine (in-memory: figure reproduction needs no
//! store), so the figures and the cluster-scale `replica sweep` command
//! share one grid-expansion and evaluation path.

use crate::metrics::{fnum, SeriesExport, Table};
use crate::sweep::{self, CaseOutcome, RunConfig, ScenarioSet, SweepSpec};
use crate::traces::{job_ccdf, GeneratorConfig, Trace};
use crate::util::error::{Error, Result};

/// Jobs shown in Fig. 12 (exponential tail + the borderline job 5).
pub const EXP_TAIL_JOBS: [u64; 5] = [1, 2, 3, 4, 5];
/// Jobs shown in Fig. 13 (heavy tail).
pub const HEAVY_TAIL_JOBS: [u64; 5] = [6, 7, 8, 9, 10];

/// Generate the standard workload: 100 tasks per job (so the B sweep
/// matches the paper's N=100 spectrum), fixed seed.
pub fn standard_trace(seed: u64) -> Trace {
    GeneratorConfig::paper_workload(100, seed).generate()
}

/// Fig. 11 series: one CCDF curve per job.
pub fn fig11_series(trace: &Trace) -> Vec<SeriesExport> {
    trace
        .job_ids()
        .into_iter()
        .map(|j| {
            let mut s = SeriesExport::new(&format!("job{j}"), "t_seconds", vec!["ccdf"]);
            for (t, p) in job_ccdf(trace, j, 200) {
                s.push(t, vec![p]);
            }
            s
        })
        .collect()
}

/// One job's redundancy sweep: normalized E\[T\](B) / E\[T\](B=N),
/// trace-driven (empirical τ resampled bootstrap-style).
pub fn job_sweep(
    trace: &Trace,
    job_id: u64,
    reps: usize,
    seed: u64,
) -> Result<Vec<(usize, f64)>> {
    let mut spec = SweepSpec::for_trace();
    spec.jobs = Some(vec![job_id]);
    spec.reps = reps;
    spec.seed = seed;
    let set = ScenarioSet::from_trace(trace, &spec)?;
    let results = sweep::run(&set, &RunConfig::default())?;
    let rows: Vec<(usize, f64)> = results
        .iter()
        .map(|r| match &r.outcome {
            CaseOutcome::Ok(e) => Ok((r.case.batches(), e.mean)),
            CaseOutcome::Error(msg) => {
                Err(Error::Config(format!("job {job_id} B={}: {msg}", r.case.batches())))
            }
        })
        .collect::<Result<_>>()?;
    // last row is B = N (no redundancy)
    let baseline = rows
        .last()
        .ok_or_else(|| Error::Internal(format!("job {job_id}: sweep has no rows")))?
        .1;
    Ok(rows.into_iter().map(|(b, m)| (b, m / baseline)).collect())
}

/// Figs. 12/13 series for a set of jobs.
pub fn sweep_series(
    trace: &Trace,
    jobs: &[u64],
    reps: usize,
    seed: u64,
) -> Result<Vec<SeriesExport>> {
    jobs.iter()
        .map(|&j| {
            let mut s =
                SeriesExport::new(&format!("job{j}"), "B", vec!["normalized_mean_T"]);
            for (b, m) in job_sweep(trace, j, reps, seed)? {
                s.push(b as f64, vec![m]);
            }
            Ok(s)
        })
        .collect()
}

/// Printable table for one figure: rows = B, columns = jobs, argmin
/// starred; last row reports the speedup at the optimum.
pub fn table(
    title: &str,
    trace: &Trace,
    jobs: &[u64],
    reps: usize,
    seed: u64,
) -> Result<Table> {
    let sweeps: Vec<Vec<(usize, f64)>> =
        jobs.iter().map(|&j| job_sweep(trace, j, reps, seed)).collect::<Result<_>>()?;
    let mut header = vec!["B".to_string()];
    header.extend(jobs.iter().map(|j| format!("job {j}")));
    let mut t = Table::new(title, header.iter().map(|s| s.as_str()).collect());
    let argmins: Vec<usize> = sweeps
        .iter()
        .map(|sw| {
            sw.iter().min_by(|a, b| a.1.total_cmp(&b.1)).map_or(0, |(b, _)| *b)
        })
        .collect();
    let bs: Vec<usize> = sweeps[0].iter().map(|(b, _)| *b).collect();
    for (i, b) in bs.iter().enumerate() {
        let mut row = vec![b.to_string()];
        for (j, sw) in sweeps.iter().enumerate() {
            let star = if argmins[j] == *b { "*" } else { "" };
            row.push(format!("{}{star}", fnum(sw[i].1)));
        }
        t.row(row);
    }
    // speedup row: 1 / normalized-mean at the optimum
    let mut row = vec!["speedup".to_string()];
    for sw in &sweeps {
        let best = sw.iter().map(|(_, m)| *m).fold(f64::INFINITY, f64::min);
        row.push(format!("{}x", fnum(1.0 / best)));
    }
    t.row(row);
    Ok(t)
}

/// The paper's headline: max speedup across the heavy-tail jobs.
pub fn headline_speedup(trace: &Trace, reps: usize, seed: u64) -> Result<f64> {
    let mut best = 1.0f64;
    for &j in &HEAVY_TAIL_JOBS {
        let sweep = job_sweep(trace, j, reps, seed)?;
        let min = sweep.iter().map(|(_, m)| *m).fold(f64::INFINITY, f64::min);
        best = best.max(1.0 / min);
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_has_ten_curves() {
        let trace = standard_trace(1);
        let series = fig11_series(&trace);
        assert_eq!(series.len(), 10);
        for s in &series {
            assert!(!s.points.is_empty());
        }
    }

    #[test]
    fn fig12_exp_tail_jobs_prefer_high_parallelism() {
        // Jobs with large shift (1–4): optimum at/near full parallelism.
        let trace = standard_trace(2);
        for &j in &[1u64, 4] {
            let sweep = job_sweep(&trace, j, 4_000, 3).unwrap();
            let (b_star, _) =
                *sweep.iter().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
            assert!(b_star >= 50, "job {j}: B*={b_star}");
        }
    }

    #[test]
    fn fig13_heavy_tail_jobs_prefer_interior_redundancy() {
        let trace = standard_trace(4);
        for &j in &[7u64, 9] {
            let sweep = job_sweep(&trace, j, 4_000, 5).unwrap();
            let (b_star, norm) =
                *sweep.iter().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
            assert!(b_star < 100, "job {j}: B*={b_star}");
            assert!(norm < 0.8, "job {j}: redundancy should speed up ≥1.25x, got {norm}");
        }
    }

    #[test]
    fn headline_order_of_magnitude_speedup() {
        // the paper's abstract: "speed up the computing job by an order
        // of magnitude" on heavy-tail jobs
        let trace = standard_trace(6);
        let s = headline_speedup(&trace, 4_000, 7).unwrap();
        assert!(s > 5.0, "headline speedup {s}");
    }

    #[test]
    fn sweeps_are_normalized() {
        let trace = standard_trace(8);
        let sweep = job_sweep(&trace, 6, 2_000, 9).unwrap();
        assert!((sweep.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn table_includes_speedup_row() {
        let trace = standard_trace(10);
        let t = table("fig13", &trace, &[6, 7], 1_000, 11).unwrap();
        assert!(t.render().contains("speedup"));
    }
}
