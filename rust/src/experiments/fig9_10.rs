//! Figs. 9–10 — E\[T\] and CoV\[T\] vs B for Pareto task service times
//! (N=100, σ=1, α sweep).

use crate::analysis::closed_form::{pareto_cov, pareto_mean};
use crate::analysis::optimizer::{feasible_b, pareto_alpha_star};
use crate::dist::ServiceDist;
use crate::eval::{Estimator, MonteCarlo};
use crate::metrics::{fnum, SeriesExport, Table};
use crate::util::error::Result;

pub const N: usize = 100;
pub const SIGMA: f64 = 1.0;
pub const PAPER_ALPHAS: [f64; 5] = [1.5, 2.5, 3.5, 5.0, 7.0];

/// (B, E\[T\], CoV\[T\]) sweep for one α.
pub fn sweep(n: usize, sigma: f64, alpha: f64) -> Vec<(usize, f64, f64)> {
    feasible_b(n)
        .into_iter()
        .map(|b| (b, pareto_mean(n, b, sigma, alpha), pareto_cov(n, b, alpha)))
        .collect()
}

/// Fig. 9 curves: E\[T\] vs B per α.
pub fn fig9_series(alphas: &[f64]) -> Vec<SeriesExport> {
    alphas
        .iter()
        .map(|&alpha| {
            let mut s = SeriesExport::new(&format!("alpha={alpha}"), "B", vec!["mean_T"]);
            for (b, mean, _) in sweep(N, SIGMA, alpha) {
                s.push(b as f64, vec![mean]);
            }
            s
        })
        .collect()
}

/// Fig. 10 curves: CoV\[T\] vs B per α (α > 2 for finite variance).
pub fn fig10_series(alphas: &[f64]) -> Vec<SeriesExport> {
    alphas
        .iter()
        .filter(|&&a| a > 2.0)
        .map(|&alpha| {
            let mut s = SeriesExport::new(&format!("alpha={alpha}"), "B", vec!["cov_T"]);
            for (b, _, cov) in sweep(N, SIGMA, alpha) {
                s.push(b as f64, vec![cov]);
            }
            s
        })
        .collect()
}

/// Printable table with argmin markers and the α* boundary.
pub fn table(alphas: &[f64]) -> Table {
    let a_star = pareto_alpha_star(N);
    let mut header: Vec<String> = vec!["B".into()];
    for &a in alphas {
        header.push(format!("E[T] a={a}"));
        header.push(format!("CoV a={a}"));
    }
    let mut t = Table::new(
        &format!(
            "Figs 9-10: E[T], CoV[T] vs B, tau ~ Pareto(1, alpha), N=100 (alpha* = {:.2})",
            a_star
        ),
        header.iter().map(|s| s.as_str()).collect(),
    );
    let sweeps: Vec<Vec<(usize, f64, f64)>> =
        alphas.iter().map(|&a| sweep(N, SIGMA, a)).collect();
    let argmins: Vec<usize> = sweeps
        .iter()
        .map(|sw| {
            sw.iter().min_by(|a, b| a.1.total_cmp(&b.1)).map_or(0, |(b, _, _)| *b)
        })
        .collect();
    for (i, b) in feasible_b(N).into_iter().enumerate() {
        let mut row = vec![b.to_string()];
        for (j, sw) in sweeps.iter().enumerate() {
            let star = if argmins[j] == b { "*" } else { "" };
            row.push(format!("{}{star}", fnum(sw[i].1)));
            row.push(fnum(sw[i].2));
        }
        t.row(row);
    }
    t
}

/// Monte-Carlo cross-check for one α.
pub fn mc_crosscheck(
    alpha: f64,
    reps: usize,
    seed: u64,
) -> Result<Vec<(usize, f64, f64, f64)>> {
    let tau = ServiceDist::pareto(SIGMA, alpha);
    let sweep = MonteCarlo::new(reps, seed).sweep(N, &tau)?;
    Ok(sweep
        .into_iter()
        .map(|(op, est)| {
            (op.batches, pareto_mean(N, op.batches, SIGMA, alpha), est.mean, est.ci95)
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_minima_move_right_with_alpha() {
        let argmin = |alpha: f64| {
            sweep(N, SIGMA, alpha)
                .into_iter()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
                .0
        };
        let b15 = argmin(1.5);
        let b35 = argmin(3.5);
        let b7 = argmin(7.0);
        assert!(b15 > 1 && b15 < N, "alpha=1.5 interior, got {b15}");
        assert!(b35 >= b15);
        // alpha=7 > alpha* ≈ 4.7 → full parallelism
        assert_eq!(b7, N);
    }

    #[test]
    fn fig10_cov_minimized_at_full_diversity() {
        // Theorem 10: for every α > 2 the CoV argmin is B = 1
        for alpha in [2.5, 3.5, 5.0, 7.0] {
            let sw = sweep(N, SIGMA, alpha);
            let (b_min, _, _) = sw
                .iter()
                .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
                .copied()
                .unwrap();
            assert_eq!(b_min, 1, "alpha={alpha}");
        }
    }

    #[test]
    fn heavy_alpha_below_2_has_infinite_cov() {
        let sw = sweep(N, SIGMA, 1.5);
        // variance infinite once 2B/(Nα) ≥ 1 → B ≥ 75: B=100 row
        assert!(sw.last().unwrap().2.is_infinite());
    }

    #[test]
    fn mc_crosscheck_agrees_for_light_tail() {
        let rows = mc_crosscheck(3.5, 8_000, 5).unwrap();
        for (b, analytic, simulated, ci) in rows {
            assert!(
                (analytic - simulated).abs() < (5.0 * ci).max(0.05 * analytic),
                "B={b}: {analytic} vs {simulated} (ci {ci})"
            );
        }
    }
}
