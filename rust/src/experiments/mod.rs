//! Paper-figure regeneration (DESIGN.md per-experiment index).
//!
//! Each submodule regenerates one table/figure of the paper's
//! evaluation and returns both printable [`Table`]s and exportable
//! [`SeriesExport`] curves. The bench harness (`rust/benches/`) and the
//! CLI (`replica experiment <id>`) are thin wrappers over these.
//!
//! [`Table`]: crate::metrics::Table
//! [`SeriesExport`]: crate::metrics::SeriesExport

pub mod assignment;
pub mod fig3;
pub mod fig6;
pub mod fig7_8;
pub mod fig9_10;
pub mod open_problem;
pub mod regimes;
pub mod traces_exp;

/// Standard Monte-Carlo replication count used by the figure
/// experiments (overridable per call) — one source of truth with the
/// estimator backends' default.
pub const DEFAULT_REPS: usize = crate::eval::DEFAULT_REPS;
