//! Fault-tolerant multi-process sweep cluster.
//!
//! `replica sweep --shard K/M` splits a grid *statically*: a killed
//! process stalls its slice until a human resumes it. This module is
//! the dynamic counterpart — a long-running coordinator
//! (`replica cluster-serve`) that leases contiguous grid slices to
//! worker processes (`replica cluster-work`) over a length-prefixed
//! JSON TCP protocol, with:
//!
//! * **heartbeats + lease deadlines** ([`leases`]): a worker renews its
//!   lease between evaluation chunks; a lease not renewed within the
//!   deadline is declared dead and its slice reassigned — SIGKILLed
//!   and straggling workers alike (the paper's relaunch-at-`t`
//!   policies, applied to the reproduction's own shards);
//! * **work stealing by shrinking leases**: lease sizes track the
//!   remaining grid, so the tail is spread across workers instead of
//!   one worker holding the last big slice;
//! * **first-copy-wins, byte-compared**: duplicate deliveries of a
//!   reassigned slice must match byte-for-byte (the same check
//!   `sweep-merge` applies to overlapping shards) — a mismatch means
//!   the determinism contract broke, and the serve aborts;
//! * **graceful degradation** ([`server`]): the coordinator persists
//!   every accepted result to the content-keyed estimate cache and the
//!   grid-ordered store; a restarted coordinator resumes from
//!   `store prefix ∪ cache hits` and leases only uncovered cases. A
//!   worker survives coordinator outages with exponential-backoff
//!   reconnect ([`client`]).
//!
//! Because each case's RNG stream is `substream(seed, key)` — a
//! function of *what* is asked, never of where or when it ran — the
//! assembled store is **byte-identical to a single-process
//! `replica sweep`** no matter how many workers died, how leases
//! moved, or how often a slice was recomputed. CI's `cluster-chaos`
//! job enforces exactly that with `cmp` under worker SIGKILL and a
//! coordinator restart.
//!
//! All timing goes through [`crate::util::clock::Clock`] (detlint
//! D1-TIME keeps `Instant::now` out of this module) and all knobs
//! through [`crate::config::ClusterConfig`].

pub mod client;
pub mod leases;
pub mod protocol;
pub mod server;

pub use client::{work, WorkOptions, WorkReport};
pub use leases::{Lease, LeaseTable};
pub use protocol::{Message, PROTO_VERSION};
pub use server::{serve, ServeOptions, ServeReport};
