//! The sweep worker: connects to a coordinator, leases grid slices,
//! evaluates them through the exact same path as a single-process
//! sweep, and ships back rendered store lines.
//!
//! The worker is a single synchronous loop: request → (lease | wait |
//! done). A leased slice is evaluated in chunks with a heartbeat
//! between chunks; a heartbeat answered `live: false` means the lease
//! expired (this worker straggled) and was reassigned, so the slice is
//! abandoned — any work already done stays in the worker's in-memory
//! estimate cache, making a re-grant of the same cases free.
//!
//! Connection loss at any point (a SIGKILLed or restarted coordinator)
//! triggers exponential-backoff reconnect; the coordinator's lease
//! expiry reclaims whatever this worker held. Because every case's
//! estimate depends only on its content key, none of this scheduling
//! churn can change a single output byte.

use std::net::TcpStream;
use std::time::Duration;

use crate::cluster::protocol::{read_frame, write_frame, Message, PROTO_VERSION};
use crate::config::ClusterConfig;
use crate::sweep::grid::ScenarioSet;
use crate::sweep::runner::evaluate_cases;
use crate::sweep::spec::SweepSpec;
use crate::sweep::store::{render_record, EstimateCache};
use crate::util::clock::Clock;
use crate::util::error::{Error, Result};

/// Everything `cluster-work` needs besides a clock.
pub struct WorkOptions {
    /// Coordinator address, e.g. `127.0.0.1:7700`.
    pub connect: String,
    /// Worker name used in leases and logs (e.g. `w-<pid>`).
    pub worker: String,
    /// Per-slice Monte-Carlo fan-out cap (0 = pool width).
    pub threads: usize,
    pub cfg: ClusterConfig,
}

/// What one worker accomplished.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkReport {
    /// Cases delivered and acknowledged.
    pub cases: usize,
    /// Leases completed.
    pub leases: usize,
    /// Leases abandoned after expiring under this worker.
    pub abandoned: usize,
    /// Times the connection was re-established.
    pub reconnects: u32,
}

/// The expanded grid this worker serves, checked against the
/// coordinator's identity on every (re)connect.
struct Grid {
    set: ScenarioSet,
    sweep_key: u64,
}

fn is_connection_error(e: &Error) -> bool {
    matches!(e, Error::Io(_) | Error::Parse(_))
}

fn connect(addr: &str, worker: &str, cfg: &ClusterConfig) -> Result<(TcpStream, Message)> {
    let mut stream = TcpStream::connect(addr)?;
    let timeout = Duration::from_millis(cfg.lease_timeout_ms);
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.set_nodelay(true)?;
    write_frame(
        &mut stream,
        &Message::Hello { proto: PROTO_VERSION, worker: worker.to_string() },
    )?;
    let welcome = read_frame(&mut stream)?;
    Ok((stream, welcome))
}

/// Build the scenario grid from the welcome frame and verify it is the
/// same grid the coordinator expanded (any drift in spec parsing or
/// keying between the two binaries is caught here, before any work).
fn build_grid(welcome: &Message) -> Result<Grid> {
    let Message::Welcome { proto, spec, reps, seed, sweep_key, cases, .. } = welcome else {
        if let Message::Error { message } = welcome {
            return Err(Error::Coordinator(message.clone()));
        }
        return Err(Error::Parse(format!("expected welcome frame, got {welcome:?}")));
    };
    if *proto != PROTO_VERSION {
        return Err(Error::Config(format!(
            "coordinator speaks protocol {proto}, this worker speaks {PROTO_VERSION}"
        )));
    }
    let mut parsed = SweepSpec::from_json(spec)?;
    parsed.reps = *reps;
    parsed.seed = *seed;
    let trace = parsed.load_trace()?;
    let set = ScenarioSet::from_trace(&trace, &parsed)?;
    if set.sweep_key() != *sweep_key || set.len() != *cases {
        return Err(Error::Config(format!(
            "grid mismatch: coordinator announced {cases} cases under sweep \
             {sweep_key:016x}, this worker expanded {} under {:016x} — \
             mixed binary versions?",
            set.len(),
            set.sweep_key()
        )));
    }
    Ok(Grid { set, sweep_key: *sweep_key })
}

/// Evaluate one leased slice, heartbeating between chunks. Returns the
/// rendered lines, or `None` if the lease expired and was abandoned.
fn evaluate_lease(
    stream: &mut TcpStream,
    grid: &Grid,
    cache: &mut EstimateCache,
    opts: &WorkOptions,
    id: u64,
    lo: usize,
    hi: usize,
) -> Result<Option<Vec<String>>> {
    let mut lines = Vec::with_capacity(hi - lo);
    let mut pos = lo;
    while pos < hi {
        let end = (pos + opts.cfg.chunk.max(1)).min(hi);
        let slice = &grid.set.cases[pos..end];
        let outcomes = evaluate_cases(slice, cache, opts.threads)?;
        lines.extend(
            slice.iter().zip(&outcomes).map(|(case, outcome)| render_record(case, outcome)),
        );
        pos = end;
        if pos < hi {
            write_frame(
                stream,
                &Message::Heartbeat { worker: opts.worker.clone(), lease: id },
            )?;
            match read_frame(stream)? {
                Message::Ok { live: true } => {}
                Message::Ok { live: false } => {
                    log::warn!(
                        "cluster: lease {id} expired under worker {} (straggling?); \
                         abandoning [{pos}, {hi})",
                        opts.worker
                    );
                    return Ok(None);
                }
                Message::Error { message } => return Err(Error::Coordinator(message)),
                other => {
                    return Err(Error::Parse(format!(
                        "unexpected heartbeat reply: {other:?}"
                    )))
                }
            }
        }
    }
    Ok(Some(lines))
}

/// One connected session: request/evaluate/deliver until `done`
/// (`Ok(())`) or a failure — connection errors bubble up as
/// `Error::Io`/`Error::Parse` and trigger a reconnect in [`work`].
fn session(
    stream: &mut TcpStream,
    grid: &Grid,
    cache: &mut EstimateCache,
    opts: &WorkOptions,
    clock: &dyn Clock,
    report: &mut WorkReport,
) -> Result<()> {
    loop {
        write_frame(stream, &Message::Request { worker: opts.worker.clone() })?;
        match read_frame(stream)? {
            Message::Done => {
                let _ = write_frame(stream, &Message::Bye { worker: opts.worker.clone() });
                return Ok(());
            }
            Message::Wait { ms } => {
                clock.sleep_millis(ms.max(1));
            }
            Message::Lease { id, lo, hi } => {
                if lo >= hi || hi > grid.set.len() {
                    return Err(Error::Coordinator(format!(
                        "coordinator leased nonsense slice [{lo}, {hi}) of a \
                         {}-case grid",
                        grid.set.len()
                    )));
                }
                match evaluate_lease(stream, grid, cache, opts, id, lo, hi)? {
                    None => report.abandoned += 1,
                    Some(lines) => {
                        write_frame(
                            stream,
                            &Message::Result {
                                worker: opts.worker.clone(),
                                lease: id,
                                lo,
                                hi,
                                lines,
                            },
                        )?;
                        match read_frame(stream)? {
                            Message::Ok { .. } => {
                                report.cases += hi - lo;
                                report.leases += 1;
                            }
                            Message::Error { message } => {
                                return Err(Error::Coordinator(message))
                            }
                            other => {
                                return Err(Error::Parse(format!(
                                    "unexpected result reply: {other:?}"
                                )))
                            }
                        }
                    }
                }
            }
            Message::Error { message } => return Err(Error::Coordinator(message)),
            other => {
                return Err(Error::Parse(format!("unexpected request reply: {other:?}")))
            }
        }
    }
}

/// Run a worker against the coordinator at `opts.connect` until the
/// sweep completes. Survives coordinator restarts via
/// exponential-backoff reconnect (sweep identity is re-verified on
/// every welcome).
pub fn work(opts: &WorkOptions, clock: &dyn Clock) -> Result<WorkReport> {
    opts.cfg.validate()?;
    let mut report = WorkReport::default();
    let mut grid: Option<Grid> = None;
    // in-memory: recomputing an abandoned-then-regranted slice is free,
    // while nothing this worker caches can outlive the process and leak
    // into another sweep
    let mut cache = EstimateCache::in_memory();
    let mut backoff = opts.cfg.reconnect_base_ms;
    let mut attempts: u32 = 0;
    let mut ever_connected = false;
    loop {
        let (mut stream, welcome) = match connect(&opts.connect, &opts.worker, &opts.cfg) {
            Ok(ok) => ok,
            Err(e) if is_connection_error(&e) => {
                attempts += 1;
                if attempts > opts.cfg.max_reconnects {
                    return Err(Error::Coordinator(format!(
                        "gave up on {} after {attempts} failed connection attempts \
                         (last error: {e})",
                        opts.connect
                    )));
                }
                log::warn!(
                    "cluster: connect to {} failed ({e}); retrying in {backoff} ms",
                    opts.connect
                );
                clock.sleep_millis(backoff);
                backoff = (backoff * 2).min(opts.cfg.reconnect_max_ms);
                continue;
            }
            Err(e) => return Err(e),
        };
        if ever_connected {
            report.reconnects += 1;
        }
        ever_connected = true;
        attempts = 0;
        backoff = opts.cfg.reconnect_base_ms;
        match &grid {
            None => grid = Some(build_grid(&welcome)?),
            Some(g) => {
                // a restarted coordinator must be serving the same
                // sweep; a different one is a hard error, not a retry
                let fresh = build_grid(&welcome)?;
                if fresh.sweep_key != g.sweep_key {
                    return Err(Error::Config(format!(
                        "coordinator at {} now serves sweep {:016x}, expected \
                         {:016x}; refusing to mix grids",
                        opts.connect, fresh.sweep_key, g.sweep_key
                    )));
                }
            }
        }
        let g = grid
            .as_ref()
            .ok_or_else(|| Error::Internal("grid vanished after build".into()))?;
        match session(&mut stream, g, &mut cache, opts, clock, &mut report) {
            Ok(()) => return Ok(report),
            Err(e) if is_connection_error(&e) => {
                log::warn!("cluster: connection to {} lost ({e}); reconnecting", opts.connect);
            }
            Err(e) => return Err(e),
        }
    }
}
